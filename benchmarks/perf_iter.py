"""§Perf hillclimbing driver: hypothesis → change → re-lower → measure.

Each named variant re-runs the single-pod dry-run cell with one change and
reports the three roofline terms next to the baseline.  Results append to
results/perf_iter.json; the narrative log lives in EXPERIMENTS.md §Perf.

MUST be the process entry point (imports repro.launch.dryrun first, which
pins 512 host devices):

  PYTHONPATH=src python -m benchmarks.perf_iter --cell deepseek-moe-16b/train_4k \\
      --variants baseline,seq_shard,cap1
"""

# dryrun import FIRST: sets XLA_FLAGS before jax initializes.
from repro.launch.dryrun import run_cell  # noqa: E402

import argparse
import json
import os
import time

VARIANTS = {
    # name -> kwargs for run_cell
    "baseline": {},
    # B: cut remat recompute (keep matmul outputs, recompute elementwise)
    "remat_dots": {"remat": "dots"},
    # A: MoE dispatch from sequence-sharded tokens (n_ep x smaller a2a)
    "seq_shard": {"cfg_overrides": {"moe_seq_shard": True}},
    # A: drop expert-capacity headroom 1.25 -> 1.0 (less padded compute)
    "cap1": {"cfg_overrides": {"capacity_factor": 1.0}},
    "seq_shard_cap1": {"cfg_overrides": {"moe_seq_shard": True,
                                         "capacity_factor": 1.0}},
    # C: serving layout — replicate params over the data axis (no FSDP
    # gathers at decode; weights stay resident)
    "serve_replicated": {"rule_overrides": {"embed": None}},
    # prefill: bigger flash KV block (fewer scan steps, more VMEM)
    "flash4k": {"cfg_overrides": {"attn_kv_block": 4096}},
    # microbatching: halve activation footprint per pass
    "microbatch2": {"microbatches": 2},
    # B: ZeRO-1 layout — params replicated over data (model dims still
    # sharded), optimizer states data-sharded; kills the hoisted per-scan
    # FSDP all-gathers
    "zero1": {"zero1": True},
    # B: sequence parallelism — activations' seq dim over the model axis
    # (rescues archs whose head counts don't divide the model axis)
    "sp": {"rule_overrides": {"seq": "model"}},
    "zero1_sp": {"zero1": True, "rule_overrides": {"seq": "model"}},
    "zero1_dots": {"zero1": True, "remat": "dots"},
    "zero1_sp_dots": {"zero1": True, "remat": "dots",
                      "rule_overrides": {"seq": "model"}},
    # combined winners (cell-specific, see EXPERIMENTS.md)
    "dots_seq_shard_cap1": {"remat": "dots",
                            "cfg_overrides": {"moe_seq_shard": True,
                                              "capacity_factor": 1.0}},
    "zero1_seq_shard": {"zero1": True,
                        "cfg_overrides": {"moe_seq_shard": True}},
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch/shape")
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="results/perf_iter.json")
    args = ap.parse_args(argv)
    arch, shape = args.cell.split("/")

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for name in args.variants.split(","):
        kw = dict(VARIANTS[name])
        t0 = time.time()
        rec = run_cell(arch, shape, multi_pod=False, **kw)
        rec["variant"] = name
        rec["wall_s"] = round(time.time() - t0, 1)
        results.append(rec)
        if rec["status"] == "ok":
            print(f"{args.cell} [{name:18s}] compute={rec['compute_s']:.4f}s "
                  f"memory={rec['memory_s']:.4f}s "
                  f"collective={rec['collective_s']:.4f}s "
                  f"dom={rec['dominant']} "
                  f"useful={rec['useful_flops_ratio']:.3f}", flush=True)
        else:
            print(f"{args.cell} [{name}] {rec['status']}: "
                  f"{rec.get('error', '')[:200]}", flush=True)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
