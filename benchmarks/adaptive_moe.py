"""Framework-level benchmark: invariant-governed MoE expert placement vs
unconditional / threshold re-placement under drifting routing loads.

The MoE analogue of Figures 6-9: the governor should match the best
placement quality (load imbalance ~ straggler time) with a fraction of the
re-placements (each re-placement = an expert-weight all-to-all + re-entry,
the deployment cost)."""

from __future__ import annotations

import argparse

import numpy as np

from repro.adaptive.placement import (ExpertPlacementGovernor, imbalance,
                                      lpt_placement)


def drifting_loads(rng, e, steps, regime="traffic"):
    """Synthetic per-expert token loads with the two regimes of §5.1."""
    base = rng.uniform(1, 10, e)
    for t in range(steps):
        if regime == "traffic":
            if rng.random() < 0.02:  # rare large shift
                i, j = rng.choice(e, 2, replace=False)
                base[i], base[j] = base[j] * 4, base[i] / 4
            yield base * rng.uniform(0.95, 1.05, e)
        else:  # stocks: frequent small drift
            base *= np.exp(rng.normal(0, 0.02, e))
            yield base.copy()


def run_policy(policy: str, loads_seq, e, groups, d=0.1):
    replans = deploys = 0
    total_imb = 0.0
    n = 0
    if policy == "invariant":
        gov = ExpertPlacementGovernor(e, groups, d=d, ema=0.7)
        for loads in loads_seq:
            gov.observe(loads)
            total_imb += imbalance(gov._loads, gov.placement)
            n += 1
        return gov.replans, gov.deployments, total_imb / n
    placement = None
    ref = None
    for loads in loads_seq:
        fire = False
        if policy == "unconditional" or placement is None:
            fire = True
        elif policy == "threshold":
            dev = np.abs(loads - ref) / np.maximum(np.abs(ref), 1e-9)
            fire = bool((dev >= 0.4).any())
        if fire:
            replans += 1
            new_p, _ = lpt_placement(loads, groups)
            ref = loads.copy()
            if placement is None or new_p.groups != placement.groups:
                placement = new_p
                deploys += 1
        total_imb += imbalance(loads, placement)
        n += 1
    return replans, deploys, total_imb / n


def main(argv=None, quick: bool = False):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--experts", type=int, default=64)
    ap.add_argument("--groups", type=int, default=16)
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args(argv)
    steps = 150 if (quick or args.quick) else args.steps

    print("regime,policy,replans,deployments,avg_imbalance")
    for regime in ("traffic", "stocks"):
        for policy in ("unconditional", "threshold", "invariant"):
            rng = np.random.default_rng(0)
            seq = list(drifting_loads(rng, args.experts, steps, regime))
            r, dep, imb = run_policy(policy, seq, args.experts,
                                     args.groups)
            print(f"{regime},{policy},{r},{dep},{imb:.4f}", flush=True)


if __name__ == "__main__":
    main()
