"""Roofline table: aggregate the dry-run JSON records into the per-cell
three-term analysis (EXPERIMENTS.md §Roofline).

Reads results/dryrun_single_*.json (and _multi_ for the multi-pod pass
status) and emits a markdown table: per (arch × shape) the compute /
memory / collective seconds, the dominant term, MODEL_FLOPS/HLO_FLOPs,
per-device memory, and the bottleneck note.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

# Peak envelopes for the join-kernel roofline (order-of-magnitude machine
# models; override per deployment via env).  TPU numbers are v5e-class;
# CPU numbers a single-socket container.  The three-term analysis below
# only needs relative magnitudes to name the dominant term.
_PEAKS = {
    "tpu": {"bytes_s": 8.1e11, "flops": 1.97e14},
    "cpu": {"bytes_s": 2.0e10, "flops": 5.0e10},
}


def join_roofline(C: int, M: int, B: int, sec: float,
                  platform: str = None) -> dict:
    """Three-term (compute / memory / collective) model of one packed
    windowed cross-join, mirroring the dry-run analysis above: each term
    is the time the operation would take if bound by that resource alone;
    the largest is the roof.

    Traffic model (packed layout): reads ``C(M+B)`` f32 operand strips,
    ``C`` int8 ops + ``C`` f32 thetas + ``M+B`` int8 validity, writes the
    ``MB`` int8 mask.  Work model: 3 comparison planes + the mask-select
    + the AND accumulate per (c, m, b) cell ~ 5 ops.  Collective bytes
    are zero — partitions are independent (see ``distributed.sharding``).
    """
    import os

    if platform is None:
        try:
            import jax
            platform = jax.devices()[0].platform
        except Exception:  # pragma: no cover
            platform = "cpu"
    peaks = _PEAKS.get(platform, _PEAKS["cpu"])
    peak_bytes = float(os.environ.get("REPRO_PEAK_BYTES_S",
                                      peaks["bytes_s"]))
    peak_flops = float(os.environ.get("REPRO_PEAK_FLOPS", peaks["flops"]))
    bytes_moved = 4 * C * (M + B) + C + 4 * C + (M + B) + M * B
    flops = 5 * C * M * B
    compute_s = flops / peak_flops
    memory_s = bytes_moved / peak_bytes
    collective_s = 0.0
    dominant = "compute" if compute_s >= memory_s else "memory"
    roof_s = max(compute_s, memory_s)
    return {
        "shape": f"C{C}_M{M}_B{B}",
        "platform": platform,
        "bytes": bytes_moved,
        "flops": flops,
        "intensity_flops_per_byte": round(flops / bytes_moved, 2),
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "achieved_gbytes_s": bytes_moved / max(sec, 1e-12) / 1e9,
        "achieved_gflops_s": flops / max(sec, 1e-12) / 1e9,
        "peak_gbytes_s": peak_bytes / 1e9,
        "peak_gflops_s": peak_flops / 1e9,
        "fraction_of_roof": round(roof_s / max(sec, 1e-12), 4),
        "seconds": sec,
    }


def load(pattern: str) -> List[dict]:
    out = []
    for path in sorted(glob.glob(pattern)):
        with open(path) as f:
            out.extend(json.load(f))
    return out


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.2f}ms"
    return f"{x:.2f}s"


def note_for(rec: dict) -> str:
    dom = rec["dominant"]
    if dom == "compute":
        return ("raise MXU utilization: bigger per-chip tiles / reduce "
                "remat recompute")
    if dom == "memory":
        return ("cut HBM traffic: fuse/reuse activations, bf16 "
                "everywhere, larger arithmetic intensity per pass")
    return ("cut collective bytes: reshard to reduce all-gathers / "
            "overlap with compute / compress")


def table(records: List[dict], multi: Dict[str, str]) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL/HLO | GB/dev | multi-pod | what would move the "
        "dominant term |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in records:
        if rec["status"] == "skipped":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | — | — | "
                f"— | — | SKIP: {rec['reason'][:60]}… |")
            continue
        if rec["status"] == "error":
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | ERR | | | | | | | "
                f"{rec['error'][:80]} |")
            continue
        if rec.get("rolled"):
            mem = rec.get("memory", {})
            gb = (mem.get("argument_size_in_bytes", 0)
                  + mem.get("temp_size_in_bytes", 0)
                  - mem.get("alias_size_in_bytes", 0)) / 1e9
            mp = multi.get(f"{rec['arch']}/{rec['shape']}", "?")
            lines.append(
                f"| {rec['arch']} | {rec['shape']} | — | — | — | — | — | "
                f"{gb:.1f} | {mp} | compiled (rolled fast mode; exact "
                "FLOP accounting pending) |")
            continue
        mem = rec.get("memory", {})
        gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)
              - mem.get("alias_size_in_bytes", 0)) / 1e9
        mp = multi.get(f"{rec['arch']}/{rec['shape']}", "?")
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | "
            f"{fmt_s(rec['compute_s'])} | {fmt_s(rec['memory_s'])} | "
            f"{fmt_s(rec['collective_s'])} | **{rec['dominant']}** | "
            f"{rec['useful_flops_ratio']:.2f} | {gb:.1f} | {mp} | "
            f"{note_for(rec)} |")
    return "\n".join(lines)


def main(argv=None, quick: bool = False):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)

    single = load(os.path.join(args.dir, "dryrun_single_*.json"))
    multi_recs = load(os.path.join(args.dir, "dryrun_multi_*.json"))
    multi = {}
    for r in multi_recs:
        key = f"{r['arch']}/{r['shape']}"
        multi[key] = ("ok" if r["status"] == "ok" else
                      "skip" if r["status"] == "skipped" else "ERR")

    order = {(a, s): (i, SHAPE_ORDER.index(s) if s in SHAPE_ORDER else 9)
             for i, a in enumerate(sorted({r["arch"] for r in single}))
             for s in SHAPE_ORDER}
    single.sort(key=lambda r: order.get((r["arch"], r["shape"]),
                                        (99, 99)))
    print(table(single, multi))
    ok = [r for r in single if r["status"] == "ok"]
    if ok:
        print(f"\n# cells ok={len(ok)} "
              f"skipped={sum(r['status'] == 'skipped' for r in single)} "
              f"error={sum(r['status'] == 'error' for r in single)}")
        worst = sorted(
            ok, key=lambda r: r["model_flops"]
            / max(r["hlo_flops"] * r["n_chips"], 1)
        )[:3]
        print("# worst useful-flops cells:",
              [(r["arch"], r["shape"],
                round(r["useful_flops_ratio"], 3)) for r in worst])
        collbound = [r for r in ok if r["dominant"] == "collective"]
        print("# collective-bound cells:",
              [(r["arch"], r["shape"]) for r in collbound])


if __name__ == "__main__":
    main()
