"""Figures 6-9 (+ Appendix A): the four adaptation methods compared per
(dataset × algorithm × pattern set × size): throughput, gain over static,
number of reoptimizations, computational overhead."""

from __future__ import annotations

import argparse
import json
import os

from .common import HEADER, PATTERN_SETS, run_one


def main(argv=None, quick: bool = False):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--sets", default=None,
                    help="comma list of pattern sets (default: per mode)")
    ap.add_argument("--d-opt", default="results/fig5.json")
    ap.add_argument("--out", default="results/fig69.json")
    args = ap.parse_args(argv)
    quick = quick or args.quick

    d_opt = {}
    if os.path.exists(args.d_opt):
        with open(args.d_opt) as f:
            d_opt = json.load(f)

    sets = (args.sets.split(",") if args.sets else
            (["seq"] if quick else PATTERN_SETS))
    sizes = [4] if quick else [3, 4, 6, 8]
    combos = ([("traffic", "greedy"), ("stocks", "greedy")] if quick else
              [(ds, al) for ds in ("traffic", "stocks")
               for al in ("greedy", "zstream")])
    n_chunks = 60 if quick else 120

    print(HEADER)
    rows = []
    for dataset, algo in combos:
        for set_name in sets:
            base = None
            for policy in ("static", "unconditional", "threshold",
                           "invariant"):
                for size in sizes:
                    d = d_opt.get(f"{dataset}/{algo}/{size}", 0.2)
                    r = run_one(dataset, algo, set_name, size, policy,
                                d=d, n_chunks=n_chunks)
                    rows.append(r)
                    print(r.row(), flush=True)

    # relative gains summary (Figures 6b-9b)
    by = {}
    for r in rows:
        by.setdefault((r.dataset, r.algo, r.pattern_set, r.size), {})[
            r.policy] = r
    print("# gain-over-static (dataset, algo, set, size): "
          "unconditional / threshold / invariant")
    for key, d_ in sorted(by.items()):
        if "static" not in d_:
            continue
        s = d_["static"].throughput
        gains = [d_.get(p).throughput / s if d_.get(p) else float("nan")
                 for p in ("unconditional", "threshold", "invariant")]
        print(f"# {key}: " + " / ".join(f"{g:.2f}x" for g in gains))
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump([r.__dict__ for r in rows], f, indent=1)


if __name__ == "__main__":
    main()
