"""Table 1: quality of the average-relative-difference estimate d_avg
(§3.4 approach 2) vs the empirically optimal d_opt from the Figure-5
sweep: min(d_avg/d_opt, d_opt/d_avg) per (dataset × algo × size)."""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro.core.adaptation import AdaptiveRunner
from repro.core.decision import InvariantPolicy
from repro.core.engine import EngineConfig
from repro.data.cep_streams import StreamConfig, make_stream

from .common import build_pattern


def measure_d_avg(dataset: str, algo: str, size: int,
                  n_chunks: int = 60) -> float:
    pat = build_pattern("seq", size)
    pol = InvariantPolicy(k=1, d_mode="avg")
    runner = AdaptiveRunner(
        pat, planner=algo, policy=pol,
        engine_cfg=EngineConfig(b_cap=128, m_cap=512),
        adaptive_caps=True)
    scfg = StreamConfig(n_types=size, n_attrs=1, n_chunks=n_chunks,
                        chunk_cap=512, base_rate=15.0, seed=3)
    runner.run(make_stream(dataset, scfg))
    return float(getattr(pol, "d_estimated", 0.0))


def main(argv=None, quick: bool = False):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--d-opt", default="results/fig5.json")
    args = ap.parse_args(argv)
    quick = quick or args.quick

    d_opt = {}
    if os.path.exists(args.d_opt):
        with open(args.d_opt) as f:
            d_opt = json.load(f)

    sizes = [4] if quick else [4, 5, 6, 7, 8]
    combos = ([("traffic", "greedy")] if quick else
              [(ds, al) for ds in ("traffic", "stocks")
               for al in ("greedy", "zstream")])
    print("dataset,algo,size,d_avg,d_opt,quality")
    for dataset, algo in combos:
        for size in sizes:
            davg = measure_d_avg(dataset, algo, size)
            dopt = d_opt.get(f"{dataset}/{algo}/{size}", 0.2)
            if davg <= 0 or dopt <= 0:
                q = 0.0
            else:
                q = min(davg / dopt, dopt / davg)
            print(f"{dataset},{algo},{size},{davg:.4f},{dopt:.4f},{q:.3f}",
                  flush=True)


if __name__ == "__main__":
    main()
