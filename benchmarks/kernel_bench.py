"""window_join kernel microbenchmark: jnp oracle vs Pallas (interpret on
CPU; the pallas path is the TPU deployment target) across join shapes."""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.kernels import ops


def bench(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


def main(argv=None, quick: bool = False):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args(argv)
    quick = quick or args.quick
    rng = np.random.default_rng(0)
    shapes = [(8, 256, 128), (12, 1024, 256), (16, 4096, 256)]
    if quick:
        shapes = shapes[:2]
    print("name,us_per_call,derived")
    for C, M, B in shapes:
        L = rng.normal(size=(C, M)).astype(np.float32)
        R = rng.normal(size=(C, B)).astype(np.float32)
        op = rng.integers(1, 4, size=(C,)).astype(np.int32)
        th = rng.normal(scale=0.5, size=(C,)).astype(np.float32)
        ref_jit = jax.jit(
            lambda a, b, o, t: ops.window_join(a, b, o, t, backend="ref"))
        t_ref = bench(lambda: ref_jit(L, R, op, th))
        cmp_per_s = C * M * B / (t_ref * 1e-6)
        print(f"window_join_ref_C{C}_M{M}_B{B},{t_ref:.1f},"
              f"{cmp_per_s:.3g}cmp/s")
        # interpret mode is a CORRECTNESS harness (python-executed kernel
        # body); time it once for the record, not as a perf claim.
        if quick:
            continue
        t_int = bench(lambda: ops.window_join(L, R, op, th,
                                              backend="interpret"),
                      iters=2)
        print(f"window_join_interpret_C{C}_M{M}_B{B},{t_int:.1f},"
              "correctness-harness")


if __name__ == "__main__":
    main()
