"""window_join kernel benchmark: packed vs baseline, autotune, roofline.

Sections (all feed ``BENCH_kernel.json``, schema ``kernel_bench/v1`` —
same record shape as ``BENCH_fleet.json`` rows):

* **packed-vs-baseline trajectory** — the engine-realistic unpacked join
  (C + 2 float32 validity rows, per-row ``where`` dispatch) against the
  packed formulation (int8 op strip, validity masks, mask-select,
  loop-accumulated AND) on the committed shapes.  Self-gating like
  ``fleet_bench``: packed must be no slower than baseline (tolerance-
  gated), and in ``--full`` mode at least 1.5x on the (16, 4096, 256)
  shape.
* **fused rowcount** — per-m counts via ``window_join_rowcount`` vs
  materialize-then-``sum(axis=1)``.
* **scanned-step section** — the superchunk scan with hoisted
  ``PredicateStrips`` across a chunk-size (S) sweep, plus the
  kernel-fraction estimate (kernel-only time / scan time) showing the
  fused step is bound by the join kernel, not operand assembly.
* **autotune sweep** (``--sweep``) — block_m x block_b over the Pallas
  kernel per shape class, winners persisted to
  ``benchmarks/autotune_cache.json`` (``repro.kernels.autotune``).  On
  CPU the Pallas body runs in interpret mode: entries are written (the
  table is consulted by shape class + platform) but flagged non-perf.

Interpret-mode timings are NEVER perf claims — the interpret backend is
a correctness harness (python-executed kernel body); such records carry
``"perf": false``.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import autotune, ops

SHAPES = [(8, 256, 128), (12, 1024, 256), (16, 4096, 256)]

# Packed may not regress vs baseline (CI gate; CPU timer noise allowance).
GATE_TOLERANCE = 1.15
# Full-mode gate on the flagship shape (ISSUE 6 acceptance criterion).
FULL_SPEEDUP_GATE = 1.5
FULL_GATE_SHAPE = (16, 4096, 256)


def bench(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters  # seconds per call


def _case(rng, C, M, B):
    """One engine-realistic join instance: values, ops, thetas, validity."""
    L = rng.normal(size=(C, M)).astype(np.float32)
    R = rng.normal(size=(C, B)).astype(np.float32)
    op = rng.integers(1, 4, size=(C,)).astype(np.int32)
    th = rng.normal(scale=0.5, size=(C,)).astype(np.float32)
    mv = (rng.random(M) > 0.2).astype(np.int8)
    bv = (rng.random(B) > 0.2).astype(np.int8)
    return L, R, op, th, mv, bv


def _baseline_operands(L, R, op, th, mv, bv):
    """The pre-packing stack: validity enters as two f32 constraint rows."""
    C, M = L.shape
    B = R.shape[1]
    Lv = np.concatenate(
        [L, mv[None, :].astype(np.float32), np.ones((1, M), np.float32)])
    Rv = np.concatenate(
        [R, np.ones((1, B), np.float32), bv[None, :].astype(np.float32)])
    opv = np.concatenate([op, [2, 1]]).astype(np.int32)
    thv = np.concatenate([th, [0.5, 0.5]]).astype(np.float32)
    return Lv, Rv, opv, thv


def _row(shape, config, seconds, cells, **extra):
    rec = {"shape": f"C{shape[0]}_M{shape[1]}_B{shape[2]}",
           "config": config, "seconds": round(seconds, 6),
           "cells": cells,
           "cells_per_s": round(cells / max(seconds, 1e-12), 1)}
    rec.update(extra)
    return rec


def bench_shapes(shapes, iters, full, backend):
    """Packed-vs-baseline + rowcount trajectory; returns (rows, gates)."""
    rng = np.random.default_rng(0)
    rows, gates = [], []
    print("shape,config,us_per_call,cells_per_s,speedup")
    for shape in shapes:
        C, M, B = shape
        cells = M * B
        L, R, op, th, mv, bv = _case(rng, C, M, B)
        Lv, Rv, opv, thv = _baseline_operands(L, R, op, th, mv, bv)
        base_jit = jax.jit(lambda a, b, o, t: ops.window_join(
            a, b, o, t, backend=backend))
        pack_jit = jax.jit(
            lambda a, b, o, t, m_, b_: ops.window_join_packed(
                a, b, o, t, m_, b_, backend=backend))
        # Parity first — a fast wrong kernel is not a result.
        assert (np.asarray(base_jit(Lv, Rv, opv, thv))
                == np.asarray(pack_jit(L, R, op.astype(np.int8), th,
                                       mv, bv))).all(), shape
        t_base = bench(base_jit, Lv, Rv, opv, thv, iters=iters)
        t_pack = bench(pack_jit, L, R, op.astype(np.int8), th, mv, bv,
                       iters=iters)
        speedup = t_base / max(t_pack, 1e-12)
        rows.append(_row(shape, "baseline_unpacked", t_base, cells))
        rows.append(_row(shape, "packed", t_pack, cells,
                         speedup_vs_baseline=round(speedup, 3)))
        print(f"C{C}_M{M}_B{B},baseline_unpacked,{t_base*1e6:.1f},"
              f"{cells/t_base:.3g},1.00")
        print(f"C{C}_M{M}_B{B},packed,{t_pack*1e6:.1f},"
              f"{cells/t_pack:.3g},{speedup:.2f}", flush=True)
        gates.append((shape, t_base, t_pack, speedup))

        # Fused per-m rowcount vs materialize + reduce.
        cnt_base = jax.jit(lambda a, b, o, t: ops.window_join(
            a, b, o, t, backend=backend).sum(axis=1).astype(jnp.int32))
        cnt_fuse = jax.jit(lambda a, b, o, t: ops.window_join_rowcount(
            a, b, o, t, backend=backend))
        assert (np.asarray(cnt_base(L, R, op, th))
                == np.asarray(cnt_fuse(L, R, op, th))).all(), shape
        t_cb = bench(cnt_base, L, R, op, th, iters=iters)
        t_cf = bench(cnt_fuse, L, R, op, th, iters=iters)
        rows.append(_row(shape, "rowcount_materialized", t_cb, cells))
        rows.append(_row(shape, "rowcount_fused", t_cf, cells,
                         speedup_vs_baseline=round(t_cb / max(t_cf, 1e-12),
                                                   3)))
        print(f"C{C}_M{M}_B{B},rowcount_fused,{t_cf*1e6:.1f},"
              f"{cells/t_cf:.3g},{t_cb/max(t_cf,1e-12):.2f}", flush=True)

        if full:
            # Interpret mode: correctness harness, one timing for the
            # record only — explicitly non-perf.
            t_int = bench(lambda: ops.window_join_packed(
                L, R, op.astype(np.int8), th, mv, bv,
                backend="interpret"), iters=1)
            rows.append(_row(shape, "packed_interpret", t_int, cells,
                             perf=False))
    return rows, gates


def check_gates(gates, full):
    for shape, t_base, t_pack, speedup in gates:
        assert t_pack <= t_base * GATE_TOLERANCE, (
            f"packed kernel regressed vs baseline on {shape}: "
            f"{t_pack*1e6:.1f}us vs {t_base*1e6:.1f}us")
        if full and shape == FULL_GATE_SHAPE:
            assert speedup >= FULL_SPEEDUP_GATE, (
                f"packed+cached speedup {speedup:.2f}x < "
                f"{FULL_SPEEDUP_GATE}x gate on {shape}")


def bench_scanned(s_values=(4, 8, 16), k=4, n_windows=3):
    """Superchunk scan: S sweep, strips-hoist payoff, join fraction.

    Three measurements on the same synthetic fleet: (1) scanned dispatch
    time across superchunk sizes S; (2) the same scan with the strip
    derivation left inside the per-chunk body (``plan_operands=None``) vs
    hoisted once per dispatch; (3) the join-step floor — packed kernel +
    compaction at the engine shape — to report what fraction of a chunk
    the join step itself accounts for (the rest is ingest + finalize).
    """
    from repro.core.engine import (Chunk, EngineConfig, packed_row_count)
    from repro.core.fleet import FleetEngine
    from repro.core.patterns import chain_predicates, seq_pattern
    from repro.core.scan import (make_superchunk_scan, stack_window,
                                 static_control)

    pat = seq_pattern([0, 1, 2], 10.0,
                      chain_predicates([0, 1, 2], theta=0.4))
    cap, b_cap, m_cap = 48, 128, 256
    rng = np.random.default_rng(7)
    fleet = FleetEngine("order", pat, k,
                        EngineConfig(b_cap=b_cap, m_cap=m_cap))
    rows_arr = jnp.asarray(
        np.tile(np.arange(3, dtype=np.int32), (k, 1)))

    def window(s, t_base):
        chunks, t0s, t1s = [], [], []
        for i in range(s):
            t0, t1 = t_base + 2.0 * i, t_base + 2.0 * (i + 1)
            tid = rng.integers(0, 3, (k, cap)).astype(np.int32)
            ts = np.sort(rng.uniform(t0, t1, (k, cap)),
                         axis=1).astype(np.float32)
            attr = rng.normal(size=(k, cap, 1)).astype(np.float32)
            chunks.append(Chunk(jnp.asarray(tid), jnp.asarray(ts),
                                jnp.asarray(attr),
                                jnp.ones((k, cap), bool)))
            t0s.append(t0)
            t1s.append(t1)
        return stack_window(chunks, t0s, t1s, static_control(k, s), s)

    def time_scan(scan, s):
        xs = [window(s, 100.0 * w) for w in range(n_windows)]
        state = fleet.init_state()
        st, _, _ = scan(state, None, rows_arr, rows_arr, None, xs[0])
        jax.block_until_ready(st)   # compile + warm outside the clock
        t0 = time.perf_counter()
        for x in xs:
            state, _, _ = scan(state, None, rows_arr, rows_arr, None, x)
        jax.block_until_ready(state)
        return (time.perf_counter() - t0) / n_windows

    scan = fleet.superchunk_scan(monitored=False)
    out_rows = []
    best = None
    print("scan_s,chunks,seconds,chunks_per_s")
    for s in s_values:
        sec = time_scan(scan, s)
        per_chunk = sec / s
        out_rows.append({"shape": f"scan_k{k}_S{s}", "config": "scanned",
                         "seconds": round(sec, 6), "cells": s * k,
                         "cells_per_s": round(s * k / max(sec, 1e-12), 1)})
        print(f"{s},{s*k},{sec:.4f},{s*k/max(sec,1e-12):.1f}", flush=True)
        if best is None or per_chunk < best[1]:
            best = (s, per_chunk)
    s_best, per_chunk_best = best

    # Strip-hoist payoff: identical scan, strips rebuilt inside the body.
    scan_inbody = make_superchunk_scan(fleet.base.process_fn,
                                       fleet.base.spec, monitored=False)
    sec_inbody = time_scan(scan_inbody, s_best)
    cached_speedup = sec_inbody / max(per_chunk_best * s_best, 1e-12)
    out_rows.append({"shape": f"scan_k{k}_S{s_best}",
                     "config": "scanned_strips_inbody",
                     "seconds": round(sec_inbody, 6),
                     "cells": s_best * k,
                     "cells_per_s": round(
                         s_best * k / max(sec_inbody, 1e-12), 1)})
    print(f"strips hoisted vs in-body at S={s_best}: "
          f"{cached_speedup:.2f}x", flush=True)

    # Join-step floor: packed kernel + compaction at the engine shape.
    spec = fleet.base.spec
    C = packed_row_count(spec)
    rk = np.random.default_rng(1)
    Lk = rk.normal(size=(C, m_cap)).astype(np.float32)
    Rk = rk.normal(size=(C, b_cap)).astype(np.float32)
    opk = rk.integers(1, 4, size=C).astype(np.int8)
    thk = np.full(C, 0.4, np.float32)
    mvk = np.ones(m_cap, np.int8)
    bvk = np.ones(b_cap, np.int8)
    tsk = rk.normal(size=(m_cap, spec.n)).astype(np.float32)

    @jax.jit
    def join_step(L, R, op, th, mv, bv, ts):
        ok = ops.window_join_packed(L, R, op, th, mv, bv)
        flat = ok.reshape(-1)
        idx = jnp.nonzero(flat, size=m_cap,
                          fill_value=m_cap * b_cap)[0]
        valid = jnp.take(flat, idx, mode="fill", fill_value=False)
        mi = jnp.clip(idx // b_cap, 0, m_cap - 1)
        return valid, ts[mi], ok.sum()

    t_join = bench(join_step, Lk, Rk, opk, thk, mvk, bvk, tsk, iters=20)
    joins_per_chunk = k * (spec.n - 1)
    join_fraction = (joins_per_chunk * t_join) / max(per_chunk_best, 1e-12)
    print(f"join_fraction at S={s_best}: {join_fraction:.2f} "
          f"({joins_per_chunk} join steps x {t_join*1e6:.0f}us / "
          f"{per_chunk_best*1e6:.0f}us chunk)", flush=True)
    summary = {"best_s": s_best,
               "per_chunk_s": round(per_chunk_best, 6),
               "strips_inbody_per_chunk_s": round(sec_inbody / s_best, 6),
               "cached_strips_speedup": round(cached_speedup, 3),
               "join_step_s": round(t_join, 6),
               "joins_per_chunk": joins_per_chunk,
               "join_fraction": round(join_fraction, 3)}
    return out_rows, summary


def autotune_sweep(shapes, iters=1, table_path=None):
    """block_m x block_b sweep of the Pallas kernel per shape class.

    On CPU the kernel body runs in interpret mode — entries are written
    (keyed by platform, so a TPU run never reads them) but flagged
    non-perf.  Winners land in ``benchmarks/autotune_cache.json``.
    """
    plat = autotune.platform()
    interpret = plat != "tpu"
    rng = np.random.default_rng(0)
    entries = dict(autotune.load_table(table_path))
    results = []
    for C, M, B in shapes:
        L, R, op, th, mv, bv = _case(rng, C, M, B)
        op8 = op.astype(np.int8)
        best = None
        for bm in autotune.BLOCK_M_CANDIDATES:
            if bm > max(M, 8) and bm != autotune.BLOCK_M_CANDIDATES[0]:
                continue
            for bb in autotune.BLOCK_B_CANDIDATES:
                if bb > max(B, 128) and bb != autotune.BLOCK_B_CANDIDATES[0]:
                    continue
                from repro.kernels.window_join import \
                    window_join_packed_pallas
                try:
                    sec = bench(
                        lambda: window_join_packed_pallas(
                            L, R, op8, th, mv, bv, block_m=bm, block_b=bb,
                            interpret=interpret),
                        iters=iters)
                except Exception as e:  # noqa: BLE001 - skip bad tiles
                    print(f"  C{C}_M{M}_B{B} bm={bm} bb={bb}: "
                          f"{type(e).__name__}")
                    continue
                if best is None or sec < best[0]:
                    best = (sec, bm, bb)
        if best is None:
            continue
        sec, bm, bb = best
        key = f"{plat}/{autotune.shape_class(C, M, B)}"
        entry = {"block_m": bm, "block_b": bb,
                 "us": round(sec * 1e6, 1), "kernel": "packed"}
        if interpret:
            entry["perf"] = False  # interpret-mode ranking, not a claim
        entries[key] = entry
        results.append((key, entry))
        print(f"{key}: block_m={bm} block_b={bb} ({sec*1e6:.0f}us"
              f"{' interpret' if interpret else ''})", flush=True)
    path = autotune.save_table(entries, table_path)
    print(f"wrote {path}")
    return results


def main(argv=None, quick: bool = False) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default="BENCH_kernel.json",
                    help="machine-readable output path ('' disables)")
    ap.add_argument("--sweep", action="store_true",
                    help="autotune block sizes and update the on-disk "
                         "table")
    ap.add_argument("--iters", type=int, default=None)
    args = ap.parse_args(argv)
    quick = (quick or args.quick) and not args.full
    full = not quick
    backend = ops.get_backend()
    shapes = SHAPES if full else SHAPES[:2]
    iters = args.iters or (20 if quick else 30)

    rows, gates = bench_shapes(shapes, iters, full, backend)
    scan_rows, scan_summary = bench_scanned(
        s_values=(4, 8) if quick else (4, 8, 16),
        n_windows=2 if quick else 3)
    rows.extend(scan_rows)

    sweep_results = None
    if args.sweep:
        sweep_shapes = SHAPES if full else SHAPES[:1]
        sweep_results = autotune_sweep(sweep_shapes)

    from .roofline import join_roofline
    roofline = [join_roofline(C, M, B, sec=next(
        r["seconds"] for r in rows
        if r["shape"] == f"C{C}_M{M}_B{B}" and r["config"] == "packed"))
        for (C, M, B) in shapes]
    for rec in roofline:
        print(f"roofline {rec['shape']}: {rec['achieved_gbytes_s']:.2f} "
              f"GB/s achieved vs {rec['peak_gbytes_s']:.0f} peak "
              f"({rec['fraction_of_roof']:.2f} of roof, "
              f"{rec['dominant']}-bound)", flush=True)

    check_gates(gates, full)

    if args.json:
        payload = {
            "schema": "kernel_bench/v1",
            "quick": quick,
            "backend": backend,
            "platform": autotune.platform(),
            "rows": rows,
            "scanned": scan_summary,
            "roofline": roofline,
        }
        if sweep_results:
            payload["autotune"] = {k: v for k, v in sweep_results}
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
