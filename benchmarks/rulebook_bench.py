"""Rulebook bench: one compiled data plane vs Q independent Sessions.

Five self-gates, all load-bearing for the multi-pattern story:

  1. Throughput — at Q=32 the rulebook must clear >= 2x the wall-clock
     throughput of stepping Q monitored Sessions over the same chunks.
     The win is structural: one dispatch per bucket instead of Q.
  2. Equivalence — per-rule match counts must be *bit-identical* to the
     Q independent Sessions.  This only holds with zero overflow (match
     truncation makes counts plan-dependent), so both sides assert
     overflow == 0; a capacity bump, not a tolerance, is the fix if
     this ever fires.
  3. Hot-add — adding a rule into a spare slot must not retrace any
     bucket plane (trace-count probe across the add *and* the next
     dispatch) and must land far under a cold rulebook compile.
  4. Superchunk — at Q=32 rolling config.superchunk = 8 chunks per
     scanned dispatch must clear >= 1.5x the per-chunk rulebook on the
     same stream, with per-rule counts *bit-identical* (the optimistic
     window re-run makes host syncs per-window without changing a
     single counter).
  5. Lattice — full sub-join sharing must beat opening-prefix-only
     sharing on the mixed-prefix suite: ``sharing_ratio()`` under
     ``sharing="lattice"`` strictly above ``sharing="prefix"`` (the
     4-arity families share a 3-position sub-join only the lattice
     can deduplicate).

Emits BENCH_rulebook.json for CI upload + `run.py --summary`.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

HEADER = "q,k,config,seconds,events,events_per_s,speedup"

_A = 2          # attribute width shared by every generated rule
_N_TYPES = 5
_CAP = 32       # event slots per chunk per partition


def make_rules(q: int):
    """Deterministic mixed rulebook: shared-prefix SEQ families, AND
    triples, bare pairs, plus NEG and Kleene representatives.

    The first 12 rules form four 3-member families sharing a first
    join (same leading pair + predicate), so prefix sharing is
    measurable at every Q >= 2.
    """
    from repro.cep.dsl import P

    rng = np.random.default_rng(11)
    rules = []
    for p0, p1 in ((0, 1), (2, 3), (1, 4), (3, 0)):
        th = round(float(rng.uniform(0.2, 0.6)), 3)
        for x in range(_N_TYPES):
            if x in (p0, p1):
                continue
            rules.append(P.seq(p0, p1, x)
                         .where(P.attr(0, 0) < P.attr(1, 0) + th)
                         .within(2.0).attrs(_A))
    rules.append(P.seq(0, P.neg(3), 1, 2)
                 .where(P.attr(0, 0) < P.attr(1, 0) + 0.3)
                 .within(3.0).attrs(_A))
    rules.append(P.seq(2, P.neg(0), 4, 1)
                 .where(P.attr(0, 1) < P.attr(1, 0) + 0.2)
                 .within(3.0).attrs(_A))
    rules.append(P.seq(3, P.kleene(4, 2), 1).within(2.5).attrs(_A))
    rules.append(P.seq(1, P.kleene(0, 2), 2).within(2.5).attrs(_A))
    # Two 4-arity families whose members agree on the first THREE
    # positions, types and both predicates: prefix-only sharing merges
    # just their opening pair-join, the full lattice also merges the
    # 3-position sub-join — the structural gap gate 5 measures.
    for p0, p1, p2 in ((0, 1, 2), (3, 4, 0)):
        th = round(float(rng.uniform(0.1, 0.4)), 3)
        for x in range(_N_TYPES):
            if x in (p0, p1, p2):
                continue
            rules.append(P.seq(p0, p1, p2, x)
                         .where(P.attr(0, 0) < P.attr(1, 0) + th,
                                P.attr(1, 1) < P.attr(2, 0) + th)
                         .within(3.0).attrs(_A))
    while len(rules) < q:
        kind = len(rules) % 3
        types = rng.choice(_N_TYPES, size=3, replace=False).tolist()
        th = round(float(rng.uniform(-0.2, 0.5)), 3)
        if kind == 0:
            rules.append(P.seq(*types)
                         .where(P.attr(0, 0) < P.attr(1, 1) + th)
                         .within(2.0).attrs(_A))
        elif kind == 1:
            rules.append(P.and_(*types)
                         .where(P.attr(0, 1) > P.attr(2, 0) - th)
                         .within(1.5).attrs(_A))
        else:
            rules.append(P.seq(types[0], types[1])
                         .within(1.5).attrs(_A))
    return rules[:q]


def make_chunks(n_chunks: int, k: int, seed: int = 7, cap: int = _CAP,
                lo: int = 4, hi: int = 10):
    """Pre-generated stacked (K-axis) chunks, identical for both sides.

    ``cap``/``lo``/``hi`` size the per-chunk event micro-batch: the
    defaults are the throughput suite's, the superchunk section shrinks
    them to the dispatch-bound regime."""
    import jax
    import jax.numpy as jnp

    from repro.core.engine import Chunk

    rng = np.random.default_rng(seed)
    out, events = [], 0

    def one(t0, t1):
        nonlocal events
        n = int(rng.integers(lo, hi))
        events += n
        tid = rng.integers(0, _N_TYPES, size=n).astype(np.int32)
        ts = np.sort(rng.uniform(t0, t1, size=n)).astype(np.float32)
        attr = rng.normal(size=(n, _A)).astype(np.float32)
        pad = cap - n
        return Chunk(
            type_id=jnp.asarray(np.pad(tid, (0, pad), constant_values=-1)),
            ts=jnp.asarray(np.pad(ts, (0, pad))),
            attr=jnp.asarray(np.pad(attr, ((0, pad), (0, 0)))),
            valid=jnp.asarray(np.arange(cap) < n))

    for step in range(n_chunks):
        t0, t1 = float(step), float(step + 1)
        parts = [one(t0, t1) for _ in range(k)]
        out.append((jax.tree.map(lambda *xs: jnp.stack(xs), *parts),
                    t0, t1))
    return out, events


def bench_q(q: int, k: int, n_chunks: int):
    import repro.cep as cep
    from repro.cep.config import RuntimeConfig
    from repro.cep.rulebook import open_rulebook

    # match_capacity is sized so overflow stays 0 — the equivalence
    # gate is only meaningful without truncation.
    cfg = RuntimeConfig(buffer_capacity=32, match_capacity=128,
                        estimator_buckets=8)
    rules = make_rules(q)
    chunks, events = make_chunks(n_chunks, k)

    t = time.time()
    rb = open_rulebook(rules, partitions=k, monitor=True, config=cfg,
                       spare_slots=1)
    rb.step(*chunks[0])
    cold_s = time.time() - t

    sessions = [cep.open(r, partitions=k, monitor=True, config=cfg)
                for r in rules]
    sess_counts = np.zeros((q, k), np.int64)
    for i, s in enumerate(sessions):
        sess_counts[i] += np.asarray(s.step(*chunks[0]))

    # timed region: identical chunk stream through both fronts
    t = time.time()
    for chunk, t0, t1 in chunks[1:]:
        rb.step(chunk, t0, t1)
    rb_s = time.time() - t

    t = time.time()
    for chunk, t0, t1 in chunks[1:]:
        for i, s in enumerate(sessions):
            sess_counts[i] += np.asarray(s.step(chunk, t0, t1))
    loop_s = time.time() - t

    tel = rb.telemetry()
    assert tel.overflow == 0, (
        f"rulebook overflow {tel.overflow} — counts are plan-dependent "
        "under truncation; raise match_capacity")
    for s in sessions:
        assert s.telemetry().overflow == 0, "session side overflowed"
    assert np.array_equal(rb.match_counts, sess_counts), (
        "per-rule counts diverge from Q independent Sessions:\n"
        f"{rb.match_counts}\nvs\n{sess_counts}")

    # Structural sharing comparison: building a prefix-mode rulebook is
    # pure host work (planning + layout, no dispatch), so reading its
    # sharing_ratio() costs no compile.
    prefix_ratio = open_rulebook(
        rules, partitions=k, monitor=False,
        config=RuntimeConfig(buffer_capacity=32, match_capacity=128,
                             estimator_buckets=8, sharing="prefix"),
        spare_slots=1).sharing_ratio()

    ev = events * 1  # per-partition streams are independent draws
    speedup = loop_s / max(rb_s, 1e-9)
    rows = [
        {"q": q, "k": k, "config": "rulebook", "seconds": round(rb_s, 4),
         "events": ev, "events_per_s": round(ev / max(rb_s, 1e-9), 1)},
        {"q": q, "k": k, "config": "session_loop",
         "seconds": round(loop_s, 4),
         "events": ev, "events_per_s": round(ev / max(loop_s, 1e-9), 1)},
    ]
    print(f"{q},{k},rulebook,{rb_s:.3f},{ev},{ev / max(rb_s, 1e-9):.1f},"
          f"{speedup:.2f}", flush=True)
    print(f"{q},{k},session_loop,{loop_s:.3f},{ev},"
          f"{ev / max(loop_s, 1e-9):.1f},1.00", flush=True)
    return rb, chunks, rows, {
        "q": q, "k": k, "events": ev, "rulebook_s": round(rb_s, 4),
        "session_loop_s": round(loop_s, 4), "speedup": round(speedup, 3),
        "cold_compile_s": round(cold_s, 4),
        "sharing_ratio": round(rb.sharing_ratio(), 3),
        "prefix_sharing_ratio": round(prefix_ratio, 3),
        "n_buckets": rb.n_buckets,
        "replans": tel.replans, "violations": tel.violations,
    }


def bench_superchunk(q: int, k: int, s_cap: int, warm: int, tail: int):
    """Superchunk gate: S chunks per scanned dispatch vs per-chunk
    stepping over the SAME stream and config — >= 1.5x on the timed
    tail, per-rule counts, overflow and violation flags bit-identical
    over the whole stream (warm region, flags and replans included, via
    the optimistic window re-run).

    Like the fleet bench's superchunk section this measures the
    dispatch-bound regime superchunking exists for: high-frequency
    micro-batch ticks (8-event chunks, minimal ring capacities) where
    per-chunk compute is small against the dispatch + host round-trip,
    and a statistically stable stream with the paper's §3.4 invariant
    distance d = 2 so steady-state flags are rare.  Each flag costs the
    scan a window split + prefix re-run, so a flag-dense regime (d = 0
    on a 128-cell plane flags every chunk) belongs on per-chunk
    stepping — that trade is the point of the distance knob (Fig. 5),
    not a superchunk regression.
    """
    from repro.cep.config import RuntimeConfig
    from repro.cep.rulebook import open_rulebook

    # A 128-cell plane needs more per-cell distance slack than a single
    # session for the same PLANE-level flag rate (any of K*Q cells
    # splits the window), hence d = 5 where the fleet bench uses d = 2.
    cfg_kw = dict(buffer_capacity=8, match_capacity=16,
                  estimator_buckets=32, policy_kw={"k": 1, "d": 5.0})
    rules = make_rules(q)
    chunks, _ = make_chunks(warm + tail, k, seed=9, cap=8, lo=3, hi=8)
    cs = [c for c, _, _ in chunks]
    edges = [(t0, t1) for _, t0, t1 in chunks]

    rb_pc = open_rulebook(rules, partitions=k, monitor=True,
                          config=RuntimeConfig(**cfg_kw), spare_slots=1)
    rb_sc = open_rulebook(rules, partitions=k, monitor=True,
                          config=RuntimeConfig(superchunk=s_cap, **cfg_kw),
                          spare_slots=1)
    # Pass 1 (untimed): the full cold trajectory on both sides — warm
    # region flags, replans and all.  This is the bit-identity evidence.
    for c, t0, t1 in chunks:
        rb_pc.step(c, t0, t1)
    rb_sc.step_superchunk(cs, edges)
    tel_pc, tel_sc = rb_pc.telemetry(), rb_sc.telemetry()

    def time_pc():
        rb_pc.reset()
        for c, t0, t1 in chunks[:warm]:
            rb_pc.step(c, t0, t1)
        t = time.time()
        for c, t0, t1 in chunks[warm:]:
            rb_pc.step(c, t0, t1)
        return time.time() - t

    def time_sc():
        rb_sc.reset()
        rb_sc.step_superchunk(cs[:warm], edges[:warm])
        t = time.time()
        rb_sc.step_superchunk(cs[warm:], edges[warm:])
        return time.time() - t

    # Two alternating timed replays per side over the adapted plans
    # (reset clears stream state but keeps deployments), min-time ratio:
    # each replay issues identical dispatches, so min wall time is the
    # structural cost and the rest is scheduler noise.
    pc_s = min(time_pc(), time_pc())
    sc_s = min(time_sc(), time_sc())
    # Bit-identity over the FULL stream, counters and control decisions
    # alike (overflow is deterministic truncation here, identical on
    # both sides, so it needs equality rather than zero).
    assert np.array_equal(rb_sc.match_counts, rb_pc.match_counts), (
        "superchunk counts diverge from per-chunk stepping:\n"
        f"{rb_sc.match_counts}\nvs\n{rb_pc.match_counts}")
    assert tel_sc.overflow == tel_pc.overflow, (
        f"overflow diverges: {tel_sc.overflow} vs {tel_pc.overflow}")
    assert tel_sc.violations == tel_pc.violations, (
        f"violation flags diverge: {tel_sc.violations} "
        f"vs {tel_pc.violations}")
    speedup = pc_s / max(sc_s, 1e-9)
    print(f"superchunk,s={s_cap},{sc_s:.3f}s,per_chunk,{pc_s:.3f}s,"
          f"speedup,{speedup:.2f},host_syncs,{tel_sc.host_syncs}vs"
          f"{tel_pc.host_syncs},replans,{tel_sc.replans}", flush=True)
    return {"superchunk": s_cap, "superchunk_s": round(sc_s, 4),
            "superchunk_per_chunk_s": round(pc_s, 4),
            "superchunk_speedup": round(speedup, 3),
            "superchunk_host_syncs": tel_sc.host_syncs,
            "per_chunk_host_syncs": tel_pc.host_syncs,
            "superchunk_replans": tel_sc.replans}


def bench_hot_add(rb, chunks, cold_s: float):
    """Hot-add gate: zero retraces across add + next dispatch, and the
    wall time (including that dispatch) lands far under a cold compile."""
    from repro.cep.dsl import P

    new_rule = (P.seq(4, 2, 0)
                .where(P.attr(0, 1) < P.attr(1, 0) + 0.5)
                .within(1.5).attrs(_A))
    pre = rb.trace_count()
    chunk, t0, t1 = chunks[-1]
    t = time.time()
    rid = rb.add_rule(new_rule)
    rb.step(chunk, t0 + 1.0, t1 + 1.0)
    hot_s = time.time() - t
    retraces = rb.trace_count() - pre
    assert retraces == 0, (
        f"hot-add retraced {retraces} plane(s) — spare-slot writes must "
        "not change any traced shape")
    assert hot_s < cold_s / 5.0, (
        f"hot-add {hot_s:.3f}s is not << cold compile {cold_s:.3f}s")
    assert rid in rb.rules
    print(f"hot_add,{hot_s:.4f}s,cold_compile,{cold_s:.3f}s,"
          f"retraces,{retraces}", flush=True)
    return {"hot_add_s": round(hot_s, 4), "cold_compile_s": round(cold_s, 4),
            "retraces": retraces}


def main(argv=None, quick: bool = True) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="bounded scale (the default); explicit flag for CI")
    ap.add_argument("--json", default="BENCH_rulebook.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)
    if args.full:
        quick = False
    k = 4
    qs = (8, 32)
    n_chunks = 12 if quick else 30

    all_rows, summaries = [], []
    print(HEADER)
    hot = None
    for q in qs:
        rb, chunks, rows, summary = bench_q(q, k, n_chunks)
        all_rows.extend(rows)
        if q == max(qs):
            hot = bench_hot_add(rb, chunks, summary["cold_compile_s"])
            summary.update(bench_superchunk(
                q, k, 8, warm=40 if quick else 60,
                tail=120 if quick else 240))
            # The headline gate: amortizing Q rules into per-bucket
            # dispatches must at least double throughput at Q=32.
            assert summary["speedup"] >= 2.0, (
                f"rulebook speedup {summary['speedup']:.2f}x at q={q} "
                "under the 2x bar")
            assert summary["sharing_ratio"] > 1.0, (
                "shared-prefix families failed to group")
            # Absolute slack absorbs scheduler noise on shared runners
            # (the fleet bench's superchunk gate does the same); a
            # structural regression lands far outside it.
            assert (summary["superchunk_s"]
                    <= summary["superchunk_per_chunk_s"] / 1.5 + 0.2), (
                f"superchunk speedup {summary['superchunk_speedup']:.2f}x "
                f"at q={q} under the 1.5x bar")
            assert (summary["sharing_ratio"]
                    > summary["prefix_sharing_ratio"]), (
                "lattice sharing no better than opening-prefix sharing "
                "on the mixed-prefix suite: "
                f"{summary['sharing_ratio']} vs "
                f"{summary['prefix_sharing_ratio']}")
        summaries.append(summary)

    if args.json:
        payload = {
            "schema": "rulebook_bench/v1",
            "quick": quick,
            "rows": all_rows,
            "summaries": summaries,
            "hot_add": hot,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
