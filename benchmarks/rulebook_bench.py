"""Rulebook bench: one compiled data plane vs Q independent Sessions.

Three self-gates, all load-bearing for the multi-pattern story:

  1. Throughput — at Q=32 the rulebook must clear >= 2x the wall-clock
     throughput of stepping Q monitored Sessions over the same chunks.
     The win is structural: one dispatch per bucket instead of Q.
  2. Equivalence — per-rule match counts must be *bit-identical* to the
     Q independent Sessions.  This only holds with zero overflow (match
     truncation makes counts plan-dependent), so both sides assert
     overflow == 0; a capacity bump, not a tolerance, is the fix if
     this ever fires.
  3. Hot-add — adding a rule into a spare slot must not retrace any
     bucket plane (trace-count probe across the add *and* the next
     dispatch) and must land far under a cold rulebook compile.

Emits BENCH_rulebook.json for CI upload + `run.py --summary`.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np

HEADER = "q,k,config,seconds,events,events_per_s,speedup"

_A = 2          # attribute width shared by every generated rule
_N_TYPES = 5
_CAP = 32       # event slots per chunk per partition


def make_rules(q: int):
    """Deterministic mixed rulebook: shared-prefix SEQ families, AND
    triples, bare pairs, plus NEG and Kleene representatives.

    The first 12 rules form four 3-member families sharing a first
    join (same leading pair + predicate), so prefix sharing is
    measurable at every Q >= 2.
    """
    from repro.cep.dsl import P

    rng = np.random.default_rng(11)
    rules = []
    for p0, p1 in ((0, 1), (2, 3), (1, 4), (3, 0)):
        th = round(float(rng.uniform(0.2, 0.6)), 3)
        for x in range(_N_TYPES):
            if x in (p0, p1):
                continue
            rules.append(P.seq(p0, p1, x)
                         .where(P.attr(0, 0) < P.attr(1, 0) + th)
                         .within(2.0).attrs(_A))
    rules.append(P.seq(0, P.neg(3), 1, 2)
                 .where(P.attr(0, 0) < P.attr(1, 0) + 0.3)
                 .within(3.0).attrs(_A))
    rules.append(P.seq(2, P.neg(0), 4, 1)
                 .where(P.attr(0, 1) < P.attr(1, 0) + 0.2)
                 .within(3.0).attrs(_A))
    rules.append(P.seq(3, P.kleene(4, 2), 1).within(2.5).attrs(_A))
    rules.append(P.seq(1, P.kleene(0, 2), 2).within(2.5).attrs(_A))
    while len(rules) < q:
        kind = len(rules) % 3
        types = rng.choice(_N_TYPES, size=3, replace=False).tolist()
        th = round(float(rng.uniform(-0.2, 0.5)), 3)
        if kind == 0:
            rules.append(P.seq(*types)
                         .where(P.attr(0, 0) < P.attr(1, 1) + th)
                         .within(2.0).attrs(_A))
        elif kind == 1:
            rules.append(P.and_(*types)
                         .where(P.attr(0, 1) > P.attr(2, 0) - th)
                         .within(1.5).attrs(_A))
        else:
            rules.append(P.seq(types[0], types[1])
                         .within(1.5).attrs(_A))
    return rules[:q]


def make_chunks(n_chunks: int, k: int, seed: int = 7):
    """Pre-generated stacked (K-axis) chunks, identical for both sides."""
    import jax
    import jax.numpy as jnp

    from repro.core.engine import Chunk

    rng = np.random.default_rng(seed)
    out, events = [], 0

    def one(t0, t1):
        nonlocal events
        n = int(rng.integers(4, 10))
        events += n
        tid = rng.integers(0, _N_TYPES, size=n).astype(np.int32)
        ts = np.sort(rng.uniform(t0, t1, size=n)).astype(np.float32)
        attr = rng.normal(size=(n, _A)).astype(np.float32)
        pad = _CAP - n
        return Chunk(
            type_id=jnp.asarray(np.pad(tid, (0, pad), constant_values=-1)),
            ts=jnp.asarray(np.pad(ts, (0, pad))),
            attr=jnp.asarray(np.pad(attr, ((0, pad), (0, 0)))),
            valid=jnp.asarray(np.arange(_CAP) < n))

    for step in range(n_chunks):
        t0, t1 = float(step), float(step + 1)
        parts = [one(t0, t1) for _ in range(k)]
        out.append((jax.tree.map(lambda *xs: jnp.stack(xs), *parts),
                    t0, t1))
    return out, events


def bench_q(q: int, k: int, n_chunks: int):
    import repro.cep as cep
    from repro.cep.config import RuntimeConfig
    from repro.cep.rulebook import open_rulebook

    # match_capacity is sized so overflow stays 0 — the equivalence
    # gate is only meaningful without truncation.
    cfg = RuntimeConfig(buffer_capacity=32, match_capacity=128,
                        estimator_buckets=8)
    rules = make_rules(q)
    chunks, events = make_chunks(n_chunks, k)

    t = time.time()
    rb = open_rulebook(rules, partitions=k, monitor=True, config=cfg,
                       spare_slots=1)
    rb.step(*chunks[0])
    cold_s = time.time() - t

    sessions = [cep.open(r, partitions=k, monitor=True, config=cfg)
                for r in rules]
    sess_counts = np.zeros((q, k), np.int64)
    for i, s in enumerate(sessions):
        sess_counts[i] += np.asarray(s.step(*chunks[0]))

    # timed region: identical chunk stream through both fronts
    t = time.time()
    for chunk, t0, t1 in chunks[1:]:
        rb.step(chunk, t0, t1)
    rb_s = time.time() - t

    t = time.time()
    for chunk, t0, t1 in chunks[1:]:
        for i, s in enumerate(sessions):
            sess_counts[i] += np.asarray(s.step(chunk, t0, t1))
    loop_s = time.time() - t

    tel = rb.telemetry()
    assert tel.overflow == 0, (
        f"rulebook overflow {tel.overflow} — counts are plan-dependent "
        "under truncation; raise match_capacity")
    for s in sessions:
        assert s.telemetry().overflow == 0, "session side overflowed"
    assert np.array_equal(rb.match_counts, sess_counts), (
        "per-rule counts diverge from Q independent Sessions:\n"
        f"{rb.match_counts}\nvs\n{sess_counts}")

    ev = events * 1  # per-partition streams are independent draws
    speedup = loop_s / max(rb_s, 1e-9)
    rows = [
        {"q": q, "k": k, "config": "rulebook", "seconds": round(rb_s, 4),
         "events": ev, "events_per_s": round(ev / max(rb_s, 1e-9), 1)},
        {"q": q, "k": k, "config": "session_loop",
         "seconds": round(loop_s, 4),
         "events": ev, "events_per_s": round(ev / max(loop_s, 1e-9), 1)},
    ]
    print(f"{q},{k},rulebook,{rb_s:.3f},{ev},{ev / max(rb_s, 1e-9):.1f},"
          f"{speedup:.2f}", flush=True)
    print(f"{q},{k},session_loop,{loop_s:.3f},{ev},"
          f"{ev / max(loop_s, 1e-9):.1f},1.00", flush=True)
    return rb, chunks, rows, {
        "q": q, "k": k, "events": ev, "rulebook_s": round(rb_s, 4),
        "session_loop_s": round(loop_s, 4), "speedup": round(speedup, 3),
        "cold_compile_s": round(cold_s, 4),
        "sharing_ratio": round(rb.sharing_ratio(), 3),
        "replans": tel.replans, "violations": tel.violations,
    }


def bench_hot_add(rb, chunks, cold_s: float):
    """Hot-add gate: zero retraces across add + next dispatch, and the
    wall time (including that dispatch) lands far under a cold compile."""
    from repro.cep.dsl import P

    new_rule = (P.seq(4, 2, 0)
                .where(P.attr(0, 1) < P.attr(1, 0) + 0.5)
                .within(1.5).attrs(_A))
    pre = rb.trace_count()
    chunk, t0, t1 = chunks[-1]
    t = time.time()
    rid = rb.add_rule(new_rule)
    rb.step(chunk, t0 + 1.0, t1 + 1.0)
    hot_s = time.time() - t
    retraces = rb.trace_count() - pre
    assert retraces == 0, (
        f"hot-add retraced {retraces} plane(s) — spare-slot writes must "
        "not change any traced shape")
    assert hot_s < cold_s / 5.0, (
        f"hot-add {hot_s:.3f}s is not << cold compile {cold_s:.3f}s")
    assert rid in rb.rules
    print(f"hot_add,{hot_s:.4f}s,cold_compile,{cold_s:.3f}s,"
          f"retraces,{retraces}", flush=True)
    return {"hot_add_s": round(hot_s, 4), "cold_compile_s": round(cold_s, 4),
            "retraces": retraces}


def main(argv=None, quick: bool = True) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="bounded scale (the default); explicit flag for CI")
    ap.add_argument("--json", default="BENCH_rulebook.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)
    if args.full:
        quick = False
    k = 4
    qs = (8, 32)
    n_chunks = 12 if quick else 30

    all_rows, summaries = [], []
    print(HEADER)
    hot = None
    for q in qs:
        rb, chunks, rows, summary = bench_q(q, k, n_chunks)
        all_rows.extend(rows)
        summaries.append(summary)
        if q == max(qs):
            hot = bench_hot_add(rb, chunks, summary["cold_compile_s"])
            # The headline gate: amortizing Q rules into per-bucket
            # dispatches must at least double throughput at Q=32.
            assert summary["speedup"] >= 2.0, (
                f"rulebook speedup {summary['speedup']:.2f}x at q={q} "
                "under the 2x bar")
            assert summary["sharing_ratio"] > 1.0, (
                "shared-prefix families failed to group")

    if args.json:
        payload = {
            "schema": "rulebook_bench/v1",
            "quick": quick,
            "rows": all_rows,
            "summaries": summaries,
            "hot_add": hot,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
