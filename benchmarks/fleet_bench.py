"""Fleet executor benchmark: vmapped fleet vs a Python loop of engines,
plus the cost of the ``repro.cep`` facade, of device-resident invariant
monitoring, and the superchunk/sharded scale-out configurations.

Measures end-to-end chunk-tick throughput for K independent stream
partitions executed four ways:

(a) ``loop``   — a host loop over K single-partition jitted engines (one
    compiled program, K dispatches + syncs per chunk);
(b) ``fleet``  — the raw data plane: ONE ``jit(vmap(process))``
    ``FleetEngine`` call per chunk over the stacked partition axis;
(c) ``facade`` — the same ticks driven through the public surface
    (``cep.open(...).step``), which is what examples and deployments use;
(d) ``mon``    — a monitored facade session: statistics rings + lowered
    invariant verification fused into the compiled step, violation →
    sync → replan → row-deploy control loop included.

Identical detection semantics (asserted on match counts), so (b)/(a) is
pure dispatch/batching efficiency, (c)/(b) is the facade overhead —
gated at < 5%, the API-redesign acceptance bar — and (d)/(c) is the
§3.3-§3.5 monitoring overhead, gated at < 10% while host statistic syncs
scale with violations, not with K.

A second section (``bench_superchunk``) measures the scale-out data
plane in the regime it exists for — high-frequency micro-batch ticks,
where the per-chunk host round-trip (dispatch + flag/counter syncs +
Python control) rivals the join compute itself:

(e) ``scan``  — the same monitored session stepped with ``superchunk=8``:
    8 chunks per compiled ``lax.scan`` dispatch, host control only at
    window boundaries.  Gated at ≥ 2× the per-chunk throughput at K=16
    (the host round-trip is ~half of every per-chunk tick; the scan
    removes it for 7 of every 8 chunks);
(f) ``shard`` — (e) with the K axis ``shard_map``-ped over all local
    devices (D=1 on CI CPU — same code path, reported not gated).

Every section feeds ``BENCH_fleet.json`` (machine-readable throughput
per configuration: baseline / vmapped / facade / monitored / scanned /
sharded), which CI uploads as an artifact so the bench trajectory is
tracked per commit.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--full] \\
        [--json BENCH_fleet.json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time

import jax
import numpy as np

from repro import cep
from repro.cep import OrderPlan, RuntimeConfig
from repro.core.engine import EngineConfig, OrderEngine
from repro.core.fleet import FleetEngine, stacked_streams
from repro.data.cep_streams import StreamConfig, make_stream

HEADER = ("k,events,loop_s,fleet_s,facade_s,mon_s,loop_ev_s,fleet_ev_s,"
          "facade_ev_s,mon_ev_s,speedup,facade_ovh,mon_ovh,violations")


def _records(k: int, n_chunks: int, chunk_cap: int, seed: int = 3):
    scfg = StreamConfig(n_types=3, n_chunks=n_chunks, chunk_cap=chunk_cap,
                        base_rate=10.0, seed=seed)
    streams = [make_stream("traffic", dataclasses.replace(scfg,
                                                          seed=seed + p))
               for p in range(k)]
    return list(stacked_streams(streams))


def _pattern():
    from repro.cep import P

    return (P.seq(0, 1, 2)
            .where(P.attr(0) < P.attr(1) - 0.3,
                   P.attr(1) < P.attr(2) - 0.3)
            .within(4.0))


def _session_pass(sess, recs, k, reps: int = 3):
    """Best-of-``reps`` timed sweep of one facade session over ``recs``."""
    sess.step(recs[0].chunk, -1e9, -1e9 + 1)  # warm (compile)
    best = float("inf")
    for _ in range(reps):
        sess.reset()
        t0 = time.perf_counter()
        counts = np.zeros(k, np.int64)
        for fc in recs:
            # step() syncs this tick's counts to the host, so the sweep is
            # end-to-end: nothing is left in flight at the timer stop.
            counts += sess.step(fc.chunk, fc.t0, fc.t1)
        best = min(best, time.perf_counter() - t0)
    return best, counts


def bench_k(k: int, n_chunks: int = 30, chunk_cap: int = 64) -> str:
    pat = _pattern()
    # Truncation-free capacity: overflow would make match counts depend on
    # the join order, so the monitored pass's violation-triggered replans
    # could legitimately change them and the cross-pass assertions would
    # compare noise.  The facade pass asserts overflow == 0 to keep the
    # bench honest at every scale.
    cfg = EngineConfig(b_cap=32, m_cap=256)
    rcfg = RuntimeConfig(buffer_capacity=32, match_capacity=256,
                         policy=None)
    plans = [OrderPlan(((2, 1, 0), (0, 1, 2), (1, 0, 2))[p % 3])
             for p in range(k)]
    recs = _records(k, n_chunks, chunk_cap)
    events = int(sum(np.asarray(fc.chunk.valid).sum() for fc in recs))

    # -- python loop over K single-partition engines (shared compile).
    # Chunks are pre-sliced OUTSIDE the timed window: a real per-partition
    # deployment receives its events unstacked, so the loop is charged
    # only dispatch + per-partition syncs, not the un-stacking.
    split = [[jax.tree.map(lambda x: x[p], fc.chunk) for p in range(k)]
             for fc in recs]
    jax.block_until_ready(split)
    eng = OrderEngine(pat.build(), cfg)
    states = [eng.init_state() for _ in range(k)]
    for p in range(k):  # warmup compile
        eng.process_chunk(states[p], split[0][p], plans[p], -1e9, -1e9 + 1)
    t0 = time.perf_counter()
    loop_counts = np.zeros(k, np.int64)
    res = None
    for ci, fc in enumerate(recs):
        for p in range(k):
            states[p], res = eng.process_chunk(
                states[p], split[ci][p], plans[p], fc.t0, fc.t1)
            loop_counts[p] += int(res.full_matches)
    jax.block_until_ready(res)
    loop_s = time.perf_counter() - t0

    # -- raw vmapped fleet: one compiled call per chunk -------------------
    # Best-of-2 timing on every side of the overhead gates: a scheduler
    # hiccup in one sweep would otherwise skew the ratios.
    fleet = FleetEngine("order", pat.build(), k, cfg)
    rows = fleet.plans_to_array(plans)
    fleet.process_chunk(fleet.init_state(), recs[0].chunk, rows,
                        -1e9, -1e9 + 1)  # warm
    fleet_s = float("inf")
    for _ in range(3):
        state = fleet.init_state()
        t0 = time.perf_counter()
        fleet_counts = np.zeros(k, np.int64)
        for fc in recs:
            state, res = fleet.process_chunk(state, fc.chunk, rows,
                                             fc.t0, fc.t1)
            fleet_counts += np.asarray(res.full_matches, np.int64)
        jax.block_until_ready(state)
        fleet_s = min(fleet_s, time.perf_counter() - t0)

    assert fleet_counts.tolist() == loop_counts.tolist(), (
        "fleet/loop disagree — semantics bug")

    # -- the public facade driving the same ticks -------------------------
    sess = cep.open(pat, partitions=k, plan="order", config=rcfg)
    for p, plan in enumerate(plans):
        sess.deploy(p, plan)
    facade_s, facade_counts = _session_pass(sess, recs, k)
    assert facade_counts.tolist() == fleet_counts.tolist(), (
        "facade/fleet disagree — semantics bug")
    assert sess.telemetry().overflow == 0, (
        "match-set truncation at bench scale; raise match_capacity so "
        "cross-pass count assertions stay meaningful")
    # The api_redesign acceptance bar: the facade is bookkeeping around
    # the same compiled call, so its overhead must stay under 5% (plus an
    # absolute slack absorbing scheduler noise — sub-second sweeps on a
    # shared CPU jitter by ~±0.1 s; a structural regression such as
    # re-uploading plan tensors per chunk shows up far above the bound).
    assert facade_s <= fleet_s * 1.05 + 0.1, (
        f"facade overhead {(facade_s - fleet_s) / fleet_s:+.1%} at k={k} "
        f"exceeds the 5% budget")

    # -- monitored facade: rings + invariant checks + replan loop ---------
    # d = 0.5 is the §3.4 distance knob at a production-shaped setting:
    # flags still fire on real drift (see the violations column) but the
    # violation → sync → replan follow-up stays rare, so the gate below
    # measures the *verification* overhead the §3.3 claim is about, not
    # the cost of near-unconditional replanning (d = 0 on a drifting
    # stream replans every few chunks by design).
    mon_sess = cep.open(pat, partitions=k, plan="order", monitor=True,
                        config=dataclasses.replace(
                            rcfg, policy="invariant",
                            policy_kw={"k": 1, "d": 0.5}))
    for p, plan in enumerate(plans):
        mon_sess.deploy(p, plan)
    mon_s, mon_counts = _session_pass(mon_sess, recs, k)
    violations = mon_sess.telemetry().violations

    assert mon_counts.tolist() == fleet_counts.tolist(), (
        "monitored/plain facade disagree — semantics bug")
    # The §3.3-§3.5 criterion: monitoring must cost < 10% of the data
    # plane.  The same absolute noise slack as the facade gate applies;
    # measured steady-state overhead is a few %, so a tripped bound means
    # a real regression (e.g. re-uploading the invariant tensors per
    # chunk).
    assert mon_s <= facade_s * 1.10 + 0.1, (
        f"monitored fleet overhead {(mon_s - facade_s) / facade_s:+.1%} "
        f"at k={k} exceeds the 10% §3.3 monitoring budget")
    line = (f"{k},{events},{loop_s:.3f},{fleet_s:.3f},{facade_s:.3f},"
            f"{mon_s:.3f},"
            f"{events / max(loop_s, 1e-9):.0f},"
            f"{events / max(fleet_s, 1e-9):.0f},"
            f"{events / max(facade_s, 1e-9):.0f},"
            f"{events / max(mon_s, 1e-9):.0f},"
            f"{loop_s / max(fleet_s, 1e-9):.2f},"
            f"{(facade_s - fleet_s) / max(fleet_s, 1e-9):+.1%},"
            f"{(mon_s - facade_s) / max(facade_s, 1e-9):+.1%},"
            f"{violations}")
    rows = [
        {"k": k, "config": name, "seconds": round(sec, 4), "events": events,
         "events_per_s": round(events / max(sec, 1e-9), 1)}
        for name, sec in (("baseline", loop_s), ("vmapped", fleet_s),
                          ("facade", facade_s), ("monitored", mon_s))
    ]
    return line, rows


# ---------------------------------------------------------------------------
# Superchunk / sharded section (scale-out data plane)
# ---------------------------------------------------------------------------


def bench_superchunk(k: int = 16, superchunk: int = 8, n_chunks: int = 260,
                     warm: int = 60):
    """Scanned + sharded throughput in the dispatch-bound regime.

    High-frequency micro-batch ticks: tiny chunks (8 events/partition),
    minimal ring capacities, a statistically stable stream (balanced type
    rates, deep 64-bucket estimator window, §3.4 distance d=2) so the
    steady state is violation-free — the regime the paper's low-overhead
    monitoring is designed for, and the one where the per-chunk host
    round-trip dominates.  The warm-up prefix (compiles + ring fill +
    initial adaptation) is excluded from the timed window; the timed
    violation count is printed so a regression into flag-thrashing is
    visible, and all three variants must agree on it and on every match
    count.
    """
    pat = _pattern()
    scfg = StreamConfig(n_types=3, n_chunks=n_chunks, chunk_cap=8,
                        base_rate=1.5, seed=3, shift_every=1e9, zipf_s=0.1)
    recs = list(stacked_streams(
        [make_stream("traffic", dataclasses.replace(scfg, seed=3 + p))
         for p in range(k)]))
    chunks = [fc.chunk for fc in recs]
    edges = [(fc.t0, fc.t1) for fc in recs]
    events = int(sum(np.asarray(fc.chunk.valid).sum()
                     for fc in recs[warm:]))
    rcfg = RuntimeConfig(buffer_capacity=8, match_capacity=16,
                         estimator_buckets=64, max_invariants=8,
                         max_terms=16, policy="invariant",
                         policy_kw={"k": 1, "d": 2.0})

    def sweep(s, mesh=None):
        sess = cep.open(_pattern(), partitions=k, plan="order",
                        monitor=True, config=rcfg, superchunk=s, mesh=mesh)
        if s == 1:
            for ch, (u, v) in zip(chunks[:warm], edges[:warm]):
                sess.step(ch, u, v)
            v0 = sess.telemetry().violations
            t0 = time.perf_counter()
            counts = np.zeros(k, np.int64)
            for ch, (u, v) in zip(chunks[warm:], edges[warm:]):
                counts += sess.step(ch, u, v)
            dt = time.perf_counter() - t0
        else:
            sess.step_superchunk(chunks[:warm], edges[:warm])
            v0 = sess.telemetry().violations
            t0 = time.perf_counter()
            counts = sess.step_superchunk(chunks[warm:],
                                          edges[warm:]).sum(axis=0)
            dt = time.perf_counter() - t0
        return dt, counts, sess.telemetry().violations - v0

    per_chunk_s, c1, v1 = sweep(1)
    scan_s, c8, v8 = sweep(superchunk)
    # Largest device count that divides K (an uneven split is rejected by
    # design); on single-device CI this is the D=1 shard_map code path.
    devices = math.gcd(k, len(jax.devices()))
    shard_s, cs, vs = sweep(superchunk, mesh=devices)

    assert c1.tolist() == c8.tolist() == cs.tolist(), (
        "scanned/sharded match counts diverge from per-chunk stepping — "
        "semantics bug")
    assert v1 == v8 == vs, (
        "scanned/sharded violation flags diverge from per-chunk stepping")
    # The scale-out acceptance bar: rolling S chunks per dispatch must at
    # least double dispatch-bound throughput at K=16 on CPU.  An absolute
    # slack absorbs scheduler noise on shared runners; a structural
    # regression (e.g. a host sync sneaking back into the scan window)
    # lands far outside it.
    assert scan_s <= per_chunk_s / 2.0 + 0.15, (
        f"superchunk={superchunk} speedup "
        f"{per_chunk_s / max(scan_s, 1e-9):.2f}x at k={k} is under the "
        f"2x scale-out budget")

    print("superchunk section (dispatch-bound regime)")
    print("k,events,per_chunk_s,scan_s,shard_s,scan_speedup,shard_speedup,"
          "devices,timed_violations")
    print(f"{k},{events},{per_chunk_s:.3f},{scan_s:.3f},{shard_s:.3f},"
          f"{per_chunk_s / max(scan_s, 1e-9):.2f},"
          f"{per_chunk_s / max(shard_s, 1e-9):.2f},"
          f"{devices},{v1}", flush=True)
    rows = [
        {"k": k, "config": name, "seconds": round(sec, 4),
         "events": events,
         "events_per_s": round(events / max(sec, 1e-9), 1)}
        for name, sec in (("per_chunk_monitored", per_chunk_s),
                          ("scanned", scan_s), ("sharded", shard_s))
    ]
    summary = {
        "k": k, "superchunk": superchunk, "devices": devices,
        "events": events, "timed_violations": int(v1),
        "per_chunk_s": round(per_chunk_s, 4),
        "scanned_s": round(scan_s, 4),
        "sharded_s": round(shard_s, 4),
        "speedup_scanned": round(per_chunk_s / max(scan_s, 1e-9), 3),
        "speedup_sharded": round(per_chunk_s / max(shard_s, 1e-9), 3),
    }
    return rows, summary


def main(argv=None, quick: bool = True) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--json", default="BENCH_fleet.json",
                    help="machine-readable output path ('' disables)")
    args = ap.parse_args(argv)
    if args.full:
        quick = False
    ks = (4, 16) if quick else (1, 4, 16, 64)
    n_chunks = 30 if quick else 80
    all_rows = []
    print(HEADER)
    for k in ks:
        line, rows = bench_k(k, n_chunks=n_chunks)
        all_rows.extend(rows)
        print(line, flush=True)
    sc_rows, sc_summary = bench_superchunk(
        n_chunks=260 if quick else 400)
    all_rows.extend(sc_rows)
    if args.json:
        payload = {
            "schema": "fleet_bench/v1",
            "quick": quick,
            "rows": all_rows,
            "superchunk": sc_summary,
        }
        with open(args.json, "w") as fh:
            json.dump(payload, fh, indent=2)
            fh.write("\n")
        print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
