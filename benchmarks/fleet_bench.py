"""Fleet executor benchmark: vmapped fleet vs a Python loop of engines,
plus the cost of device-resident invariant monitoring.

Measures end-to-end chunk-tick throughput for K independent stream
partitions executed (a) as a host loop over K single-partition jitted
engines (one compiled program, K dispatches + syncs per chunk), (b) as
the ``FleetEngine`` — ONE ``jit(vmap(process))`` call per chunk over the
stacked partition axis — and (c) as the *monitored* fleet: the same call
with the per-partition statistics rings and lowered invariant sets fused
in (``process_chunk_monitored``).  Identical detection semantics
(asserted on match counts), so (b)/(a) is pure dispatch/batching
efficiency and (c)/(b) is the §3.3-§3.5 monitoring overhead — the paper's
low-overhead claim holds when ``mon_ovh`` stays well under 10% while host
statistic syncs scale with violations, not with K.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--full]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.core.decision import InvariantPolicy
from repro.core.engine import EngineConfig, OrderEngine
from repro.core.fleet import FleetEngine, stacked_streams
from repro.core.greedy import greedy_order_plan
from repro.core.invariants import StackedLowered
from repro.core.patterns import chain_predicates, seq_pattern
from repro.core.plans import OrderPlan
from repro.core.stats import uniform_stat
from repro.data.cep_streams import StreamConfig, make_stream

HEADER = ("k,events,loop_s,fleet_s,mon_s,loop_ev_s,fleet_ev_s,mon_ev_s,"
          "speedup,mon_ovh,violations")


def _records(k: int, n_chunks: int, chunk_cap: int, seed: int = 3):
    scfg = StreamConfig(n_types=3, n_chunks=n_chunks, chunk_cap=chunk_cap,
                        base_rate=10.0, seed=seed)
    streams = [make_stream("traffic", dataclasses.replace(scfg,
                                                          seed=seed + p))
               for p in range(k)]
    return list(stacked_streams(streams))


def bench_k(k: int, n_chunks: int = 30, chunk_cap: int = 64) -> str:
    pat = seq_pattern([0, 1, 2], 4.0,
                      chain_predicates([0, 1, 2], theta=-0.3))
    cfg = EngineConfig(b_cap=32, m_cap=64)
    plans = [OrderPlan(((2, 1, 0), (0, 1, 2), (1, 0, 2))[p % 3])
             for p in range(k)]
    recs = _records(k, n_chunks, chunk_cap)
    events = int(sum(np.asarray(fc.chunk.valid).sum() for fc in recs))

    # -- python loop over K single-partition engines (shared compile).
    # Chunks are pre-sliced OUTSIDE the timed window: a real per-partition
    # deployment receives its events unstacked, so the loop is charged
    # only dispatch + per-partition syncs, not the un-stacking.
    split = [[jax.tree.map(lambda x: x[p], fc.chunk) for p in range(k)]
             for fc in recs]
    jax.block_until_ready(split)
    eng = OrderEngine(pat, cfg)
    states = [eng.init_state() for _ in range(k)]
    for p in range(k):  # warmup compile
        eng.process_chunk(states[p], split[0][p], plans[p], -1e9, -1e9 + 1)
    t0 = time.perf_counter()
    loop_counts = np.zeros(k, np.int64)
    res = None
    for ci, fc in enumerate(recs):
        for p in range(k):
            states[p], res = eng.process_chunk(
                states[p], split[ci][p], plans[p], fc.t0, fc.t1)
            loop_counts[p] += int(res.full_matches)
    jax.block_until_ready(res)
    loop_s = time.perf_counter() - t0

    # -- vmapped fleet: one compiled call per chunk -----------------------
    # Best-of-2 timing on both sides of the monitoring-overhead gate: a
    # scheduler hiccup in either loop would otherwise skew the ratio.
    fleet = FleetEngine("order", pat, k, cfg)
    rows = fleet.plans_to_array(plans)
    fleet.process_chunk(fleet.init_state(), recs[0].chunk, rows,
                        -1e9, -1e9 + 1)  # warm
    fleet_s = float("inf")
    for _ in range(2):
        state = fleet.init_state()
        t0 = time.perf_counter()
        fleet_counts = np.zeros(k, np.int64)
        for fc in recs:
            state, res = fleet.process_chunk(state, fc.chunk, rows,
                                             fc.t0, fc.t1)
            fleet_counts += np.asarray(res.full_matches, np.int64)
        jax.block_until_ready(state)
        fleet_s = min(fleet_s, time.perf_counter() - t0)

    assert fleet_counts.tolist() == loop_counts.tolist(), (
        "fleet/loop disagree — semantics bug")

    # -- monitored fleet: stats rings + invariant checks fused in --------
    stat0 = uniform_stat(pat.n)
    plan0, dcs0 = greedy_order_plan(pat, stat0)
    pols = [InvariantPolicy(k=1, d=0.0) for _ in range(k)]
    for pol in pols:
        pol.on_replan(plan0, dcs0, stat0)
    low = StackedLowered([pol.compile(pat.n) for pol in pols]).device()
    fleet.process_chunk_monitored(fleet.init_state(), fleet.init_monitor(),
                                  recs[0].chunk, rows, low,
                                  -1e9, -1e9 + 1)  # warm
    mon_s = float("inf")
    for _ in range(2):
        state = fleet.init_state()
        mon = fleet.init_monitor()
        t0 = time.perf_counter()
        mon_counts = np.zeros(k, np.int64)
        violations = 0
        for fc in recs:
            state, mon, res, violated, drift, rates, sel = \
                fleet.process_chunk_monitored(state, mon, fc.chunk, rows,
                                              low, fc.t0, fc.t1)
            mon_counts += np.asarray(res.full_matches, np.int64)
            violations += int(np.asarray(violated).sum())
        jax.block_until_ready(state)
        mon_s = min(mon_s, time.perf_counter() - t0)

    assert mon_counts.tolist() == fleet_counts.tolist(), (
        "monitored/plain fleet disagree — semantics bug")
    # The §3.3-§3.5 criterion: monitoring must cost < 10% of the data
    # plane.  A small absolute slack absorbs timer noise at --quick scale;
    # measured steady-state overhead is ≈ 0%, so a tripped bound means a
    # real regression (e.g. re-uploading the invariant tensors per chunk).
    assert mon_s <= fleet_s * 1.10 + 0.05, (
        f"monitored fleet overhead {(mon_s - fleet_s) / fleet_s:+.1%} "
        f"at k={k} exceeds the 10% §3.3 monitoring budget")
    return (f"{k},{events},{loop_s:.3f},{fleet_s:.3f},{mon_s:.3f},"
            f"{events / max(loop_s, 1e-9):.0f},"
            f"{events / max(fleet_s, 1e-9):.0f},"
            f"{events / max(mon_s, 1e-9):.0f},"
            f"{loop_s / max(fleet_s, 1e-9):.2f},"
            f"{(mon_s - fleet_s) / max(fleet_s, 1e-9):+.1%},"
            f"{violations}")


def main(argv=None, quick: bool = True) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.full:
        quick = False
    ks = (4, 16) if quick else (1, 4, 16, 64)
    n_chunks = 30 if quick else 80
    print(HEADER)
    for k in ks:
        print(bench_k(k, n_chunks=n_chunks), flush=True)


if __name__ == "__main__":
    main()
