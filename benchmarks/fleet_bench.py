"""Fleet executor benchmark: vmapped fleet vs a Python loop of engines.

Measures end-to-end chunk-tick throughput for K independent stream
partitions executed (a) as a host loop over K single-partition jitted
engines (one compiled program, K dispatches + syncs per chunk) and (b) as
the ``FleetEngine`` — ONE ``jit(vmap(process))`` call per chunk over the
stacked partition axis.  Identical detection semantics (asserted on match
counts), so the speedup is pure dispatch/batching efficiency — the
partition-parallel scaling a multi-tenant deployment rides on.

    PYTHONPATH=src python -m benchmarks.fleet_bench [--full]
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.core.engine import EngineConfig, OrderEngine
from repro.core.fleet import FleetEngine, stacked_streams
from repro.core.patterns import chain_predicates, seq_pattern
from repro.core.plans import OrderPlan
from repro.data.cep_streams import StreamConfig, make_stream

HEADER = "k,events,loop_s,fleet_s,loop_ev_s,fleet_ev_s,speedup"


def _records(k: int, n_chunks: int, chunk_cap: int, seed: int = 3):
    scfg = StreamConfig(n_types=3, n_chunks=n_chunks, chunk_cap=chunk_cap,
                        base_rate=10.0, seed=seed)
    streams = [make_stream("traffic", dataclasses.replace(scfg,
                                                          seed=seed + p))
               for p in range(k)]
    return list(stacked_streams(streams))


def bench_k(k: int, n_chunks: int = 30, chunk_cap: int = 64) -> str:
    pat = seq_pattern([0, 1, 2], 4.0,
                      chain_predicates([0, 1, 2], theta=-0.3))
    cfg = EngineConfig(b_cap=32, m_cap=64)
    plans = [OrderPlan(((2, 1, 0), (0, 1, 2), (1, 0, 2))[p % 3])
             for p in range(k)]
    recs = _records(k, n_chunks, chunk_cap)
    events = int(sum(np.asarray(fc.chunk.valid).sum() for fc in recs))

    # -- python loop over K single-partition engines (shared compile).
    # Chunks are pre-sliced OUTSIDE the timed window: a real per-partition
    # deployment receives its events unstacked, so the loop is charged
    # only dispatch + per-partition syncs, not the un-stacking.
    split = [[jax.tree.map(lambda x: x[p], fc.chunk) for p in range(k)]
             for fc in recs]
    jax.block_until_ready(split)
    eng = OrderEngine(pat, cfg)
    states = [eng.init_state() for _ in range(k)]
    for p in range(k):  # warmup compile
        eng.process_chunk(states[p], split[0][p], plans[p], -1e9, -1e9 + 1)
    t0 = time.perf_counter()
    loop_counts = np.zeros(k, np.int64)
    res = None
    for ci, fc in enumerate(recs):
        for p in range(k):
            states[p], res = eng.process_chunk(
                states[p], split[ci][p], plans[p], fc.t0, fc.t1)
            loop_counts[p] += int(res.full_matches)
    jax.block_until_ready(res)
    loop_s = time.perf_counter() - t0

    # -- vmapped fleet: one compiled call per chunk -----------------------
    fleet = FleetEngine("order", pat, k, cfg)
    state = fleet.init_state()
    rows = fleet.plans_to_array(plans)
    fleet.process_chunk(state, recs[0].chunk, rows, -1e9, -1e9 + 1)  # warm
    t0 = time.perf_counter()
    fleet_counts = np.zeros(k, np.int64)
    for fc in recs:
        state, res = fleet.process_chunk(state, fc.chunk, rows,
                                         fc.t0, fc.t1)
        fleet_counts += np.asarray(res.full_matches, np.int64)
    jax.block_until_ready(state)
    fleet_s = time.perf_counter() - t0

    assert fleet_counts.tolist() == loop_counts.tolist(), (
        "fleet/loop disagree — semantics bug")
    return (f"{k},{events},{loop_s:.3f},{fleet_s:.3f},"
            f"{events / max(loop_s, 1e-9):.0f},"
            f"{events / max(fleet_s, 1e-9):.0f},"
            f"{loop_s / max(fleet_s, 1e-9):.2f}")


def main(argv=None, quick: bool = True) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    if args.full:
        quick = False
    ks = (4, 16) if quick else (1, 4, 16, 64)
    n_chunks = 30 if quick else 80
    print(HEADER)
    for k in ks:
        print(bench_k(k, n_chunks=n_chunks), flush=True)


if __name__ == "__main__":
    main()
