"""High-rate replay harness over the real-workload scenario suite.

Streams every bundled scenario (``repro.data.scenarios``) through
``cep.open(...)`` segment by segment — warmup, stationary control, drift —
under four runtime configurations:

* ``adaptive``     — device-monitored invariant policy (the paper's loop);
* ``adaptive_s8``  — the same, dispatched as 8-chunk superchunk scans;
* ``static``       — no monitor, cold plan pinned, capacity escalation on
  overflow (the honest do-nothing baseline: it never loses matches, it
  just pays ever-larger join shapes once the regime shifts);
* ``pinned``       — cold plan *and* capacities pinned (no escalation):
  the lossy baseline, reported as recall.

Methodology: every configuration is replayed twice and the second pass is
timed — the first pass warms jax traces/compiles (standard JIT benchmark
practice; the persistent compilation cache plus the process-wide fleet
trace memo make the warm pass cheap).  Segments are replayed through one
resumable ``Session`` so segment boundaries are measurement boundaries,
not semantic ones: the full replay is bit-identical to one continuous run.

Self-gates (``--no-gate`` to disable; a failed gate exits non-zero):

* **adaptivity win**: adaptive throughput >= static on every drifting
  segment;
* **false-positive control**: zero replans *and* zero invariant violations
  on every stationary control segment;
* **detection invariance**: adaptive and static report identical match
  counts on every segment (plans change cost, never semantics);
* **expected adaptivity**: drift-segment deployments >= the scenario's
  ``expected["min_drift_deployments"]``;
* **pinned loss**: the pinned baseline's drift recall < 1, i.e. the
  overflow cost adaptivity avoids is real.

Results land in ``BENCH_scenarios.json`` (schema ``scenarios/v1``).

Usage::

    python benchmarks/replay_bench.py --quick           # CI smoke (~2 min)
    python benchmarks/replay_bench.py --full            # millions of events
    python benchmarks/replay_bench.py --scenario fraud --chunks-scale 4
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

# Must precede the first jax import: warm traces across the replay's many
# engine instances (and across runs on a dev box) instead of recompiling.
os.environ.setdefault("JAX_COMPILATION_CACHE_DIR",
                      os.path.join("/tmp", "jaxcache"))
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS", "0")
os.environ.setdefault("JAX_PERSISTENT_CACHE_MIN_ENTRY_SIZE_BYTES", "0")

from repro import cep                                       # noqa: E402
from repro.cep import RuntimeConfig                         # noqa: E402
from repro.data import scenarios                            # noqa: E402

SCHEMA = "scenarios/v1"

CONFIGS = ("adaptive", "adaptive_s8", "static", "pinned")


def _session(sc, config: str):
    """A fresh Session for one named runtime configuration."""
    rt = dict(sc.runtime)
    monitor, superchunk = False, 1
    if config.startswith("adaptive"):
        monitor = True
        if config == "adaptive_s8":
            superchunk = 8
    else:
        rt["policy"] = None
        rt.pop("policy_kw", None)
        rt["escalate_on_overflow"] = config != "pinned"
    return cep.open(sc.pattern, partitions=sc.partitions, monitor=monitor,
                    superchunk=superchunk, config=RuntimeConfig(**rt))


def _replay(sc, segs, config: str):
    """One full replay: per-segment ``(wall_s, Telemetry)`` rows."""
    s = _session(sc, config)
    rows = []
    for i, (seg, parts) in enumerate(segs):
        t0 = time.perf_counter()
        tel = s.run(parts, resume=(i > 0))
        rows.append((seg, time.perf_counter() - t0, tel))
    return rows


def _raise_nondet(config, seg):
    raise AssertionError(
        f"non-deterministic replay: {config} diverged on segment {seg.name}")


def _tel_row(seg, wall, tel) -> dict:
    return {
        "segment": seg.name, "gate": seg.gate,
        "events": int(tel.events), "matches": int(tel.matches),
        "wall_s": round(wall, 4),
        "tput_evps": round(tel.events / wall, 1) if wall > 0 else None,
        "replans": int(tel.replans), "deployments": int(tel.deployments),
        "violations": int(tel.violations), "overflow": int(tel.overflow),
        "escalations": int(tel.escalations),
    }


def run_scenario(sc, *, seed: int, rate: float, chunks_scale: float,
                 superchunk: bool) -> dict:
    segs = sc.segment_streams(seed=seed, rate_scale=rate,
                              chunks_scale=chunks_scale)
    configs = [c for c in CONFIGS if superchunk or c != "adaptive_s8"]
    runs: dict = {}
    for config in configs:
        _replay(sc, segs, config)            # warm pass: traces/compiles
        first = _replay(sc, segs, config)
        second = _replay(sc, segs, config)
        # Replays are deterministic, so telemetry is identical across
        # passes; keep the per-segment best wall so the throughput gate
        # measures the engine, not scheduler noise.
        runs[config] = [
            (seg, min(w1, w2), t1)
            for (seg, w1, t1), (_, w2, t2) in zip(first, second)
            if t1.matches == t2.matches or _raise_nondet(config, seg)]

    result = {
        "description": sc.description,
        "partitions": sc.partitions,
        "rate_scale": sc.rate_scale * rate,
        "chunks_scale": chunks_scale,
        "events": int(sum(t.events for _, _, t in runs["adaptive"])),
        "expected": dict(sc.expected),
        "segments": {c: [_tel_row(*row) for row in rows]
                     for c, rows in runs.items()},
    }

    # -- self-gates ---------------------------------------------------------
    gates = {}
    by_gate = lambda rows, g: [r for r in rows if r[0].gate == g]  # noqa: E731
    drift_a = by_gate(runs["adaptive"], "drift")
    drift_s = by_gate(runs["static"], "drift")
    drift_p = by_gate(runs["pinned"], "drift")
    ctrl_a = by_gate(runs["adaptive"], "control")

    gates["adaptive_ge_static_tput"] = all(
        (ta.events / wa) >= (ts.events / ws)
        for (_, wa, ta), (_, ws, ts) in zip(drift_a, drift_s))
    gates["zero_control_replans"] = all(
        t.replans == 0 and t.violations == 0 for _, _, t in ctrl_a)
    gates["detection_invariance"] = all(
        ta.matches == ts.matches
        for (_, _, ta), (_, _, ts) in zip(runs["adaptive"], runs["static"]))
    gates["expected_deployments"] = (
        sum(t.deployments for _, _, t in drift_a)
        >= int(sc.expected.get("min_drift_deployments", 1)))
    m_static = sum(t.matches for _, _, t in drift_s)
    m_pinned = sum(t.matches for _, _, t in drift_p)
    result["drift_recall_pinned"] = round(m_pinned / max(1, m_static), 4)
    gates["pinned_loses_matches"] = m_pinned < m_static
    if superchunk:
        ctrl_s8 = by_gate(runs["adaptive_s8"], "control")
        gates["superchunk_control_silent"] = all(
            t.replans == 0 and t.violations == 0 for _, _, t in ctrl_s8)

    result["gates"] = gates
    result["gates_pass"] = all(gates.values())
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n", 1)[0])
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="CI smoke: nominal segment lengths (default)")
    mode.add_argument("--full", action="store_true",
                      help="production-length replay (millions of events)")
    ap.add_argument("--scenario", choices=scenarios.names(),
                    help="run one scenario only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=1.0,
                    help="extra event-volume multiplier on the nominal")
    ap.add_argument("--chunks-scale", type=float, default=None,
                    help="segment-length multiplier (overrides mode)")
    ap.add_argument("--no-superchunk", action="store_true",
                    help="skip the adaptive superchunk=8 sweep point")
    ap.add_argument("--no-gate", action="store_true",
                    help="record results but never exit non-zero")
    ap.add_argument("--json", default="BENCH_scenarios.json")
    args = ap.parse_args(argv)

    chunks_scale = args.chunks_scale
    if chunks_scale is None:
        chunks_scale = 25.0 if args.full else 1.0

    names = [args.scenario] if args.scenario else scenarios.names()
    payload = {
        "schema": SCHEMA,
        "mode": "full" if args.full else "quick",
        "seed": args.seed, "rate": args.rate, "chunks_scale": chunks_scale,
        "scenarios": {},
    }
    for name in names:
        sc = scenarios.get(name)
        print(f"== {name} (K={sc.partitions}, nominal rate "
              f"{sc.rate_scale}x, chunks x{chunks_scale:g})", flush=True)
        res = run_scenario(sc, seed=args.seed, rate=args.rate,
                           chunks_scale=chunks_scale,
                           superchunk=not args.no_superchunk)
        payload["scenarios"][name] = res
        for config, rows in res["segments"].items():
            for r in rows:
                if r["gate"] == "drift":
                    print(f"   {config:12s} {r['segment']:9s} "
                          f"ev={r['events']:7d} m={r['matches']:6d} "
                          f"rp={r['replans']:2d} wall={r['wall_s']:8.2f}s "
                          f"tput={r['tput_evps']:9.1f} ev/s", flush=True)
        verdict = "PASS" if res["gates_pass"] else "FAIL"
        print(f"   gates: {verdict}  "
              + " ".join(f"{k}={'Y' if v else 'N'}"
                         for k, v in res["gates"].items()),
              flush=True)

    payload["events_total"] = sum(
        r["events"] for r in payload["scenarios"].values())
    payload["all_gates_pass"] = all(
        r["gates_pass"] for r in payload["scenarios"].values())
    with open(args.json, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"wrote {args.json}: {payload['events_total']} events/replay, "
          f"gates {'PASS' if payload['all_gates_pass'] else 'FAIL'}")
    if not payload["all_gates_pass"] and not args.no_gate:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
