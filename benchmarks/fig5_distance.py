"""Figure 5: throughput of the invariant method vs the distance ``d``.

For each (dataset × algorithm) the paper sweeps d in [0, 0.5] over the
sequence pattern set and finds a unimodal curve with an optimum d_opt.
Output: CSV rows + the located d_opt per combination (consumed by
table1_davg.py).
"""

from __future__ import annotations

import argparse
import json
import os

from .common import HEADER, run_one

D_GRID = [0.0, 0.1, 0.2, 0.3, 0.4, 0.5]


def main(argv=None, quick: bool = False):
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results/fig5.json")
    args = ap.parse_args(argv)
    quick = quick or args.quick

    sizes = [4] if quick else [3, 4, 5, 6, 7, 8]
    grid = D_GRID if not quick else [0.0, 0.2, 0.4]
    n_chunks = 60 if quick else 120
    combos = ([("traffic", "greedy"), ("stocks", "greedy")] if quick else
              [(ds, al) for ds in ("traffic", "stocks")
               for al in ("greedy", "zstream")])

    print(HEADER)
    d_opt = {}
    for dataset, algo in combos:
        best = {}
        for size in sizes:
            for d in grid:
                r = run_one(dataset, algo, "seq", size, "invariant", d=d,
                            n_chunks=n_chunks)
                print(r.row(), flush=True)
                key = (dataset, algo, size)
                if key not in best or r.throughput > best[key][1]:
                    best[key] = (d, r.throughput)
        for (ds, al, size), (d, thr) in best.items():
            d_opt[f"{ds}/{al}/{size}"] = d
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(d_opt, f, indent=1)
    print("# d_opt:", d_opt)


if __name__ == "__main__":
    main()
