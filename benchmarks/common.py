"""Shared benchmark harness: the paper's five pattern sets, both data
regimes, all four decision policies, wall-clock throughput measurement.

Throughput methodology (EXPERIMENTS.md §Benchmarks): runs use
``adaptive_caps`` — the engine's match-set capacity is the pow2 bucket of
the deployed plan's own expected partial-match count, so *real wall time*
tracks plan quality exactly the way the paper's Java engine does (fewer
partial matches => smaller joins => faster chunks).  Decision (D) and
plan-generation (A) host time is measured and included; migration chunks
run both plans, charging deployment cost to the policy that caused it.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

from repro.core.adaptation import AdaptiveRunner, RunMetrics
from repro.core.decision import make_policy
from repro.core.engine import EngineConfig
from repro.core.patterns import (CompositePattern, Pattern, Predicate,
                                 PRED_LT, and_pattern, chain_predicates,
                                 kleene_pattern, neg_pattern, seq_pattern)
from repro.data.cep_streams import StreamConfig, make_stream

PATTERN_SETS = ["seq", "conj", "neg", "kleene", "composite"]


def build_pattern(set_name: str, size: int, window: float = 4.0,
                  theta: float = -0.3):
    """The paper's five pattern sets (§5.1), parametrized by size."""
    ids = list(range(size))
    preds = chain_predicates(ids, theta=theta)
    if set_name == "seq":
        return seq_pattern(ids, window, preds)
    if set_name == "conj":
        return and_pattern(ids, window, preds)
    if set_name == "neg":
        # negated event = extra type `size`, absence between pos 0 and 1.
        return neg_pattern(
            ids, window, negated_type=size, negated_pos=1,
            predicates=preds,
            negated_predicates=(Predicate(size, 0, PRED_LT, 0, 0, 0.0),))
    if set_name == "kleene":
        return kleene_pattern(ids, window, kleene_pos=size // 2,
                              predicates=preds)
    if set_name == "composite":
        # disjunction of three independent sequences of `size` events
        return CompositePattern(tuple(
            seq_pattern(list(range(b * size, (b + 1) * size)), window,
                        chain_predicates(
                            list(range(b * size, (b + 1) * size)),
                            theta=theta))
            for b in range(3)))
    raise ValueError(set_name)


def stream_types_needed(set_name: str, size: int) -> int:
    if set_name == "neg":
        return size + 1
    if set_name == "composite":
        return 3 * size
    return size


POLICIES = {
    "static": dict(),
    "unconditional": dict(),
    "threshold": dict(t=0.4),
    "invariant": dict(k=1, d=0.0),
}


@dataclasses.dataclass
class BenchResult:
    dataset: str
    algo: str
    pattern_set: str
    size: int
    policy: str
    d: float
    throughput: float          # events / s (wall)
    events: int
    matches: int
    pm_created: int
    replans: int
    deployments: int
    false_positives: int
    overhead: float            # (D+A time) / total
    wall_s: float

    def row(self) -> str:
        return (f"{self.dataset},{self.algo},{self.pattern_set},"
                f"{self.size},{self.policy},{self.d:g},"
                f"{self.throughput:.0f},{self.events},{self.matches},"
                f"{self.pm_created},{self.replans},{self.deployments},"
                f"{self.false_positives},{self.overhead:.4f},"
                f"{self.wall_s:.2f}")


HEADER = ("dataset,algo,set,size,policy,d,throughput_ev_s,events,matches,"
          "pm,replans,deploys,fp,overhead,wall_s")


def run_one(dataset: str, algo: str, set_name: str, size: int,
            policy: str, d: Optional[float] = None, n_chunks: int = 120,
            base_rate: float = 15.0, seed: int = 3,
            policy_kw: Optional[dict] = None) -> BenchResult:
    pat = build_pattern(set_name, size)
    kw = dict(POLICIES[policy])
    if policy_kw:
        kw.update(policy_kw)
    if d is not None and policy == "invariant":
        kw["d"] = d
    scfg = StreamConfig(
        n_types=stream_types_needed(set_name, size), n_attrs=1,
        n_chunks=n_chunks, chunk_cap=512, base_rate=base_rate, seed=seed,
        # ~4 regime shifts per traffic run regardless of run length
        shift_every=max(n_chunks / 4.0, 10.0))
    ecfg = EngineConfig(b_cap=128, m_cap=512)

    def make_runner(p):
        return AdaptiveRunner(
            p, planner=algo, policy=make_policy(policy, **kw),
            engine_cfg=ecfg, adaptive_caps=True, cap_bounds=(256, 8192))

    t0 = time.perf_counter()
    if isinstance(pat, CompositePattern):
        metrics = RunMetrics()
        from repro.core.adaptation import merge_metrics
        ms = []
        for bi, branch in enumerate(pat.branches):
            r = make_runner(branch)
            ms.append(r.run(make_stream(
                dataset, dataclasses.replace(scfg, seed=seed + bi))))
        metrics = merge_metrics(ms)
    else:
        runner = make_runner(pat)
        metrics = runner.run(make_stream(dataset, scfg))
    wall = time.perf_counter() - t0

    return BenchResult(
        dataset=dataset, algo=algo, pattern_set=set_name, size=size,
        policy=policy, d=kw.get("d", 0.0),
        throughput=metrics.events / max(wall, 1e-9),
        events=metrics.events, matches=metrics.full_matches,
        pm_created=metrics.pm_created, replans=metrics.replans,
        deployments=metrics.deployments,
        false_positives=metrics.false_positives,
        overhead=metrics.adaptation_overhead, wall_s=wall)
