"""Benchmark aggregator: one harness per paper table/figure + the
framework-level benchmarks.  Default mode is `--quick` scale (bounded
minutes on a 1-core CPU container); pass --full for the complete grids.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig5,...]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--full", action="store_true")
    scale.add_argument("--quick", action="store_true",
                      help="bounded scale — the default; the explicit "
                           "flag exists for CI invocations and conflicts "
                           "with --full")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,table1,fig69,kernel,fleet,moe,"
                         "roofline")
    args = ap.parse_args(argv)
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (adaptive_moe, fig5_distance, fig69_methods,
                   fleet_bench, kernel_bench, roofline, table1_davg)

    sections = [
        ("fig5", "Figure 5 — throughput vs invariant distance d",
         lambda: fig5_distance.main([], quick=quick)),
        ("table1", "Table 1 — d_avg estimate quality",
         lambda: table1_davg.main([], quick=quick)),
        ("fig69", "Figures 6-9 — adaptation method comparison",
         lambda: fig69_methods.main([], quick=quick)),
        ("kernel", "window_join kernel microbenchmark",
         lambda: kernel_bench.main([], quick=quick)),
        ("fleet", "fleet executor — vmapped vs per-partition loop",
         lambda: fleet_bench.main([], quick=quick)),
        ("moe", "adaptive MoE expert placement",
         lambda: adaptive_moe.main([], quick=quick)),
        ("roofline", "roofline table from dry-run artifacts",
         lambda: roofline.main([], quick=quick)),
    ]
    failed = []
    for key, title, fn in sections:
        if only and key not in only:
            continue
        print(f"\n===== {title} =====", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001 - keep the suite running
            failed.append(key)
            print(f"!! {key} failed: {type(e).__name__}: {e}")
            if not quick:
                # A one-line message has hidden shape bugs before; --full
                # runs are for debugging, so show where it actually broke.
                traceback.print_exc(file=sys.stdout)
        print(f"===== {key} done in {time.time()-t0:.1f}s =====",
              flush=True)
    if failed:
        # Every selected section ran (failures don't mask each other),
        # but a red section must fail the invocation — CI smoke relies
        # on this exit code.
        print(f"\n!! failed sections: {', '.join(failed)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
