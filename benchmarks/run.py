"""Benchmark aggregator: one harness per paper table/figure + the
framework-level benchmarks.  Default mode is `--quick` scale (bounded
minutes on a 1-core CPU container); pass --full for the complete grids.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig5,...]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
import traceback


def _headline_rows(d) -> list:
    """Headline ``(metric, display, numeric|None)`` rows for one artifact.

    Every field access is defensive: a harness that was interrupted (or an
    older schema revision) may have written a partial document, and the
    summary must still render the rows it *can* extract rather than
    crashing the whole table on the first malformed artifact.
    """
    rows = []
    if not isinstance(d, dict):
        return [("(malformed artifact)", "-", None)]
    schema = str(d.get("schema", "?"))
    if schema.startswith("kernel_bench"):
        best = {}
        for r in d.get("rows", []) or []:
            s = r.get("speedup_vs_baseline") if isinstance(r, dict) else None
            if isinstance(s, (int, float)):
                cfg = r.get("config", "?")
                best[cfg] = max(best.get(cfg, 0.0), float(s))
        for cfg, s in sorted(best.items()):
            rows.append((f"{cfg} speedup vs baseline", f"{s:.2f}x", s))
    elif schema.startswith("fleet_bench"):
        by_k = {}
        for r in d.get("rows", []) or []:
            if isinstance(r, dict) and "k" in r:
                by_k.setdefault(r["k"], {})[r.get("config")] = r
        for k, cfgs in sorted(by_k.items()):
            base = cfgs.get("baseline")
            vm = cfgs.get("vmapped")
            if base and vm and "seconds" in base and "seconds" in vm:
                s = base["seconds"] / max(vm["seconds"], 1e-9)
                rows.append((f"k={k} vmapped speedup", f"{s:.2f}x", s))
        sc = d.get("superchunk") or {}
        if sc:
            for key, label in (("speedup_scanned", "superchunk"),
                               ("speedup_sharded", "sharded")):
                s = sc.get(key)
                if isinstance(s, (int, float)):
                    rows.append((f"k={sc.get('k')} {label} speedup",
                                 f"{s:.2f}x", float(s)))
    elif schema.startswith("scenarios"):
        for name, s in sorted((d.get("scenarios") or {}).items()):
            ev = s.get("events", "?") if isinstance(s, dict) else "?"
            num = float(ev) if isinstance(ev, (int, float)) else None
            rows.append((f"{name} events", str(ev), num))
        rows.append(("all gates pass", str(d.get("all_gates_pass")), None))
    elif schema.startswith("rulebook_bench"):
        for s in d.get("summaries", []) or []:
            if not isinstance(s, dict) or "q" not in s:
                continue
            q = s["q"]
            sp = s.get("speedup")
            if isinstance(sp, (int, float)):
                rows.append((f"q={q} rulebook vs session loop",
                             f"{sp:.2f}x", float(sp)))
            sc = s.get("superchunk_speedup")
            if isinstance(sc, (int, float)):
                rows.append((f"q={q} superchunk vs per-chunk",
                             f"{sc:.2f}x", float(sc)))
            sh = s.get("sharing_ratio")
            if isinstance(sh, (int, float)):
                rows.append((f"q={q} sharing ratio", f"{sh:.2f}", float(sh)))
        hot = d.get("hot_add") or {}
        if ("hot_add_s" in hot) and ("cold_compile_s" in hot):
            rows.append(("hot-add latency / cold compile",
                         f"{hot['hot_add_s']:.2f}s/"
                         f"{hot['cold_compile_s']:.1f}s", None))
        if "retraces" in hot:
            rows.append(("hot-add retraces", str(hot["retraces"]), None))
    else:
        rows.append((f"(unrecognized schema {schema})", "-", None))
    return rows


def _committed_artifact(fname: str, root: str):
    """The HEAD-committed version of a BENCH file, or None if unreadable."""
    try:
        blob = subprocess.run(
            ["git", "show", f"HEAD:{fname}"], cwd=root,
            capture_output=True, timeout=30)
        if blob.returncode != 0:
            return None
        return json.loads(blob.stdout.decode("utf-8"))
    except Exception:  # noqa: BLE001 - deltas are best-effort decoration
        return None


def summarize(root: str = ".") -> None:
    """Aggregate every BENCH_*.json into one trajectory table.

    Each benchmark harness emits its own schema; this prints the headline
    rows of each so CI logs carry a single at-a-glance performance
    trajectory across kernel, fleet, scenario, and rulebook layers.
    Missing, truncated, or partially-written artifacts degrade to warning
    rows instead of aborting the table.  When a working-tree artifact
    differs from its HEAD-committed version (i.e. this PR refreshed it),
    a delta column shows the per-PR movement of each numeric metric.
    """
    files = sorted(f for f in os.listdir(root)
                   if f.startswith("BENCH_") and f.endswith(".json"))
    if not files:
        print("no BENCH_*.json artifacts found")
        return
    print(f"{'artifact':<22} {'metric':<38} {'value':>12} {'vs HEAD':>10}")
    print("-" * 85)

    def row(art, metric, value, delta=""):
        print(f"{art:<22} {metric:<38} {value:>12} {delta:>10}")

    for fname in files:
        art = fname[len("BENCH_"):-len(".json")]
        try:
            with open(os.path.join(root, fname)) as fh:
                d = json.load(fh)
        except Exception as e:  # noqa: BLE001 - keep the table rendering
            row(art, f"(unreadable: {type(e).__name__})", "-")
            continue
        prev = _committed_artifact(fname, root)
        prev_num = {m: n for m, _, n in _headline_rows(prev)
                    if n is not None} if prev is not None else {}
        headline = _headline_rows(d) or [("(no headline metrics)", "-", None)]
        for metric, display, num in headline:
            delta = ""
            if num is not None and metric in prev_num:
                diff = num - prev_num[metric]
                if abs(diff) >= 0.005:
                    delta = f"{diff:+.2f}"
            row(art, metric, display, delta)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--full", action="store_true")
    scale.add_argument("--quick", action="store_true",
                      help="bounded scale — the default; the explicit "
                           "flag exists for CI invocations and conflicts "
                           "with --full")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,table1,fig69,kernel,fleet,moe,"
                         "roofline,rulebook")
    ap.add_argument("--summary", action="store_true",
                    help="print one trajectory table aggregated from the "
                         "committed BENCH_*.json artifacts and exit")
    args = ap.parse_args(argv)
    if args.summary:
        summarize()
        return
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (adaptive_moe, fig5_distance, fig69_methods,
                   fleet_bench, kernel_bench, roofline, rulebook_bench,
                   table1_davg)

    sections = [
        ("fig5", "Figure 5 — throughput vs invariant distance d",
         lambda: fig5_distance.main([], quick=quick)),
        ("table1", "Table 1 — d_avg estimate quality",
         lambda: table1_davg.main([], quick=quick)),
        ("fig69", "Figures 6-9 — adaptation method comparison",
         lambda: fig69_methods.main([], quick=quick)),
        ("kernel", "window_join kernel microbenchmark",
         lambda: kernel_bench.main([], quick=quick)),
        ("fleet", "fleet executor — vmapped vs per-partition loop",
         lambda: fleet_bench.main([], quick=quick)),
        ("rulebook", "rulebook — Q patterns on one compiled data plane",
         lambda: rulebook_bench.main([], quick=quick)),
        ("moe", "adaptive MoE expert placement",
         lambda: adaptive_moe.main([], quick=quick)),
        ("roofline", "roofline table from dry-run artifacts",
         lambda: roofline.main([], quick=quick)),
    ]
    failed = []
    for key, title, fn in sections:
        if only and key not in only:
            continue
        print(f"\n===== {title} =====", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001 - keep the suite running
            failed.append(key)
            print(f"!! {key} failed: {type(e).__name__}: {e}")
            if not quick:
                # A one-line message has hidden shape bugs before; --full
                # runs are for debugging, so show where it actually broke.
                traceback.print_exc(file=sys.stdout)
        print(f"===== {key} done in {time.time()-t0:.1f}s =====",
              flush=True)
    if failed:
        # Every selected section ran (failures don't mask each other),
        # but a red section must fail the invocation — CI smoke relies
        # on this exit code.
        print(f"\n!! failed sections: {', '.join(failed)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
