"""Benchmark aggregator: one harness per paper table/figure + the
framework-level benchmarks.  Default mode is `--quick` scale (bounded
minutes on a 1-core CPU container); pass --full for the complete grids.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig5,...]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback


def summarize(root: str = ".") -> None:
    """Aggregate every committed BENCH_*.json into one trajectory table.

    Each benchmark harness emits its own schema; this prints the headline
    rows of each so CI logs carry a single at-a-glance performance
    trajectory across kernel, fleet, scenario, and rulebook layers.
    """
    files = sorted(f for f in os.listdir(root)
                   if f.startswith("BENCH_") and f.endswith(".json"))
    if not files:
        print("no BENCH_*.json artifacts found")
        return
    print(f"{'artifact':<22} {'metric':<38} {'value':>12}")
    print("-" * 74)

    def row(art, metric, value):
        print(f"{art:<22} {metric:<38} {value:>12}")

    for fname in files:
        with open(os.path.join(root, fname)) as fh:
            d = json.load(fh)
        schema = d.get("schema", "?")
        art = fname[len("BENCH_"):-len(".json")]
        if schema.startswith("kernel_bench"):
            best = {}
            for r in d.get("rows", []):
                if "speedup_vs_baseline" in r:
                    best[r["config"]] = max(
                        best.get(r["config"], 0.0),
                        r["speedup_vs_baseline"])
            for cfg, s in sorted(best.items()):
                row(art, f"{cfg} speedup vs baseline", f"{s:.2f}x")
        elif schema.startswith("fleet_bench"):
            by_k = {}
            for r in d.get("rows", []):
                by_k.setdefault(r["k"], {})[r["config"]] = r
            for k, cfgs in sorted(by_k.items()):
                base = cfgs.get("baseline")
                vm = cfgs.get("vmapped")
                if base and vm:
                    row(art, f"k={k} vmapped speedup",
                        f"{base['seconds'] / max(vm['seconds'], 1e-9):.2f}x")
            sc = d.get("superchunk", {})
            if sc:
                row(art, f"k={sc.get('k')} superchunk speedup",
                    f"{sc.get('speedup_scanned', 0):.2f}x")
                row(art, f"k={sc.get('k')} sharded speedup",
                    f"{sc.get('speedup_sharded', 0):.2f}x")
        elif schema.startswith("scenarios"):
            for name, s in sorted(d.get("scenarios", {}).items()):
                row(art, f"{name} events", s.get("events", "?"))
            row(art, "all gates pass", str(d.get("all_gates_pass")))
        elif schema.startswith("rulebook_bench"):
            for s in d.get("summaries", []):
                row(art, f"q={s['q']} rulebook vs session loop",
                    f"{s['speedup']:.2f}x")
                row(art, f"q={s['q']} sharing ratio",
                    f"{s['sharing_ratio']:.2f}")
            hot = d.get("hot_add") or {}
            if hot:
                row(art, "hot-add latency / cold compile",
                    f"{hot['hot_add_s']:.2f}s/{hot['cold_compile_s']:.1f}s")
                row(art, "hot-add retraces", hot["retraces"])
        else:
            row(art, f"(unrecognized schema {schema})", "-")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--full", action="store_true")
    scale.add_argument("--quick", action="store_true",
                      help="bounded scale — the default; the explicit "
                           "flag exists for CI invocations and conflicts "
                           "with --full")
    ap.add_argument("--only", default=None,
                    help="comma list: fig5,table1,fig69,kernel,fleet,moe,"
                         "roofline,rulebook")
    ap.add_argument("--summary", action="store_true",
                    help="print one trajectory table aggregated from the "
                         "committed BENCH_*.json artifacts and exit")
    args = ap.parse_args(argv)
    if args.summary:
        summarize()
        return
    quick = not args.full
    only = set(args.only.split(",")) if args.only else None

    from . import (adaptive_moe, fig5_distance, fig69_methods,
                   fleet_bench, kernel_bench, roofline, rulebook_bench,
                   table1_davg)

    sections = [
        ("fig5", "Figure 5 — throughput vs invariant distance d",
         lambda: fig5_distance.main([], quick=quick)),
        ("table1", "Table 1 — d_avg estimate quality",
         lambda: table1_davg.main([], quick=quick)),
        ("fig69", "Figures 6-9 — adaptation method comparison",
         lambda: fig69_methods.main([], quick=quick)),
        ("kernel", "window_join kernel microbenchmark",
         lambda: kernel_bench.main([], quick=quick)),
        ("fleet", "fleet executor — vmapped vs per-partition loop",
         lambda: fleet_bench.main([], quick=quick)),
        ("rulebook", "rulebook — Q patterns on one compiled data plane",
         lambda: rulebook_bench.main([], quick=quick)),
        ("moe", "adaptive MoE expert placement",
         lambda: adaptive_moe.main([], quick=quick)),
        ("roofline", "roofline table from dry-run artifacts",
         lambda: roofline.main([], quick=quick)),
    ]
    failed = []
    for key, title, fn in sections:
        if only and key not in only:
            continue
        print(f"\n===== {title} =====", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # noqa: BLE001 - keep the suite running
            failed.append(key)
            print(f"!! {key} failed: {type(e).__name__}: {e}")
            if not quick:
                # A one-line message has hidden shape bugs before; --full
                # runs are for debugging, so show where it actually broke.
                traceback.print_exc(file=sys.stdout)
        print(f"===== {key} done in {time.time()-t0:.1f}s =====",
              flush=True)
    if failed:
        # Every selected section ran (failures don't mask each other),
        # but a red section must fail the invocation — CI smoke relies
        # on this exit code.
        print(f"\n!! failed sections: {', '.join(failed)}")
        sys.exit(1)


if __name__ == "__main__":
    main()
