"""Fault-tolerant checkpointing: atomic save, retention, async writer,
cross-mesh resharding restore."""

from .manager import CheckpointManager  # noqa: F401
