"""Checkpoint manager — the fault-tolerance substrate.

Design (scaled-down to this single-host container, architecture documented
for the 1000-node deployment in README §Operations):

* **Atomic**: each checkpoint writes to ``step_XXXXXXXX.tmp/`` and renames
  to ``step_XXXXXXXX/`` only after every leaf and the manifest are fsynced;
  a crash mid-write never corrupts the latest-complete pointer.
* **Self-describing**: a ``manifest.json`` records the step, the flattened
  tree structure (jax.tree key paths), shapes/dtypes, and the mesh the
  state was saved under.
* **Cross-mesh resharding restore**: leaves are saved as full (unsharded)
  host arrays; ``restore(..., shardings=...)`` device_puts them under ANY
  target sharding — e.g. restoring a (2,16,16) multi-pod checkpoint onto
  the (16,16) single-pod mesh after losing a pod (elastic scaling).  On a
  real cluster the same manifest drives per-shard files + a distributed
  barrier; the resharding math is identical.
* **Async**: ``save_async`` snapshots to host memory synchronously (one
  device->host copy) and writes in a background thread, overlapping
  checkpoint I/O with the next training steps (straggler-free writes).
* **Retention**: keeps the newest ``keep`` checkpoints, deleting older
  ones only after a newer one is complete.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _key_str(path) -> str:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):
            out.append(str(p.name))
        else:
            out.append(str(p))
    return "/".join(out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name[5:]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------

    def _write(self, step: int, host_leaves, paths, mesh_desc: str):
        tmp = self._step_dir(step) + ".tmp"
        final = self._step_dir(step)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "mesh": mesh_desc, "leaves": []}
        for i, (arr, path) in enumerate(zip(host_leaves, paths)):
            fname = f"leaf_{i:05d}.npy"
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"].append({
                "path": path, "file": fname,
                "shape": list(arr.shape), "dtype": str(arr.dtype),
            })
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._retain()

    def _retain(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def _snapshot(self, state):
        leaves_with_paths = jax.tree_util.tree_flatten_with_path(state)[0]
        paths = [_key_str(p) for p, _ in leaves_with_paths]
        # bf16 has no numpy dtype; ship as uint16 raw with marker.
        host = []
        for _, leaf in leaves_with_paths:
            a = np.asarray(jax.device_get(leaf))
            if a.dtype.name == "bfloat16":
                a = a.view(np.uint16)
                host.append(("bf16", a))
            else:
                host.append(("", a))
        return host, paths

    def save(self, step: int, state, mesh_desc: str = "") -> None:
        host, paths = self._snapshot(state)
        arrays = [a for _, a in host]
        paths = [p + ("|bf16" if tag else "")
                 for (tag, _), p in zip(host, paths)]
        self._write(step, arrays, paths, mesh_desc)

    def save_async(self, step: int, state, mesh_desc: str = "") -> None:
        """Snapshot synchronously, write in the background."""
        self.wait()  # one outstanding write at a time
        host, paths = self._snapshot(state)
        arrays = [a for _, a in host]
        paths = [p + ("|bf16" if tag else "")
                 for (tag, _), p in zip(host, paths)]

        def work():
            try:
                self._write(step, arrays, paths, mesh_desc)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    # ------------------------------------------------------------------

    def restore(self, like, step: Optional[int] = None,
                shardings: Any = None):
        """Restore into the structure of ``like``.

        ``shardings`` (optional pytree of NamedSharding matching ``like``)
        places each leaf directly onto the target mesh — this is the
        cross-mesh resharding path: the saved mesh is irrelevant.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        leaves_like, treedef = jax.tree_util.tree_flatten(like)
        saved = manifest["leaves"]
        if len(saved) != len(leaves_like):
            raise ValueError(
                f"checkpoint has {len(saved)} leaves, target structure "
                f"has {len(leaves_like)}")
        sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                     if shardings is not None else [None] * len(saved))
        out = []
        for meta, ref, sh in zip(saved, leaves_like, sh_leaves):
            a = np.load(os.path.join(d, meta["file"]))
            if meta["path"].endswith("|bf16"):
                a = a.view(jax.numpy.bfloat16.dtype)
            if tuple(a.shape) != tuple(ref.shape):
                raise ValueError(
                    f"shape mismatch for {meta['path']}: "
                    f"{a.shape} vs {ref.shape}")
            if sh is not None:
                out.append(jax.device_put(a, sh))
            else:
                out.append(jax.device_put(a))
        return jax.tree_util.tree_unflatten(treedef, out)
