"""Public jit'd entry points for the CEP join kernels.

Backend dispatch:

* ``"ref"``       — pure-jnp oracle (XLA fusion; default on CPU hosts).
* ``"pallas"``    — the TPU Pallas kernel (default when a TPU is present).
* ``"interpret"`` — the Pallas kernel in interpret mode (CPU correctness
                    validation of the TPU kernel body; used by tests).

The engine calls these through ``window_join(...)`` so the whole data plane
switches backend with one flag.
"""

from __future__ import annotations

import os

import jax

from . import ref as _ref
from .window_join import (
    window_join_count_pallas,
    window_join_packed_pallas,
    window_join_pallas,
    window_join_rowcount_pallas,
)

_BACKEND = None


def default_backend() -> str:
    # CI's parity matrix forces the engine-wide default through the
    # environment (set_backend / per-call overrides still win).
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        if env not in ("ref", "pallas", "interpret"):
            raise ValueError(f"REPRO_KERNEL_BACKEND={env!r} is not one of "
                             "'ref' | 'pallas' | 'interpret'")
        return env
    try:
        platform = jax.devices()[0].platform
    except Exception:  # pragma: no cover - no devices
        platform = "cpu"
    return "pallas" if platform == "tpu" else "ref"


def set_backend(name: str) -> None:
    """Force a kernel backend: 'ref' | 'pallas' | 'interpret'."""
    global _BACKEND
    if name not in ("ref", "pallas", "interpret", None):
        raise ValueError(f"unknown kernel backend {name!r}")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND or default_backend()


def window_join(L, R, ops, thetas, *, backend: str | None = None):
    """ok[m, b] = AND_c cmp(op[c], L[c, m], R[c, b], theta[c]) — (M, B) bool."""
    be = backend or get_backend()
    if be == "ref":
        return _ref.window_join_ref(L, R, ops, thetas)
    if be == "pallas":
        return window_join_pallas(L, R, ops, thetas)
    if be == "interpret":
        return window_join_pallas(L, R, ops, thetas, interpret=True)
    raise ValueError(f"unknown kernel backend {be!r}")


def window_join_count(L, R, ops, thetas, *, backend: str | None = None):
    """Count of matching pairs without materializing the mask."""
    be = backend or get_backend()
    if be == "ref":
        return _ref.window_join_ref(L, R, ops, thetas).sum()
    if be == "pallas":
        return window_join_count_pallas(L, R, ops, thetas)
    if be == "interpret":
        return window_join_count_pallas(L, R, ops, thetas, interpret=True)
    raise ValueError(f"unknown kernel backend {be!r}")


def window_join_packed(L, R, ops8, thetas, mvalid, bvalid, *,
                       backend: str | None = None):
    """Packed-strip join: validity as int8 vectors, op dispatch as
    mask-select — bit-identical to ``window_join`` over the equivalent
    unpacked stack (validity encoded as two extra f32 rows)."""
    be = backend or get_backend()
    if be == "ref":
        return _ref.window_join_packed_ref(L, R, ops8, thetas, mvalid,
                                           bvalid)
    if be == "pallas":
        return window_join_packed_pallas(L, R, ops8, thetas, mvalid, bvalid)
    if be == "interpret":
        return window_join_packed_pallas(L, R, ops8, thetas, mvalid, bvalid,
                                         interpret=True)
    raise ValueError(f"unknown kernel backend {be!r}")


def window_join_rowcount(L, R, ops, thetas, *, backend: str | None = None):
    """Per-m row counts — (M,) i32 — without materializing (M, B)."""
    be = backend or get_backend()
    if be == "ref":
        return _ref.window_join_rowcount_ref(L, R, ops, thetas)
    if be == "pallas":
        return window_join_rowcount_pallas(L, R, ops, thetas)
    if be == "interpret":
        return window_join_rowcount_pallas(L, R, ops, thetas,
                                           interpret=True)
    raise ValueError(f"unknown kernel backend {be!r}")
