"""Pure-jnp oracle for the ``window_join`` kernel.

Semantics (shared with the Pallas kernel): given ``C`` constraint rows,
left-side values ``L[c, m]``, right-side values ``R[c, b]``, per-row op-codes
and thresholds, compute

    ok[m, b] = AND_c  cmp(op[c], L[c, m], R[c, b], theta[c])

with the op-code table of ``repro.core.patterns``:

    0 (NONE)   -> True
    1 (LT)     -> l <  r + theta
    2 (GT)     -> l >  r - theta
    3 (ABS_LE) -> |l - r| <= theta

This single masked cross-comparison evaluates every constraint class of the
CEP engine — time-window membership, sequence ordering, pairwise predicates
and validity masks (encoded as 0/1 rows) — which is what makes the data
plane a dense, TPU-tileable operation.
"""

from __future__ import annotations

import jax.numpy as jnp


def cmp_op(op, l, r, theta):
    """Elementwise comparison dispatch; broadcasts ``l`` vs ``r``."""
    lt = l < r + theta
    gt = l > r - theta
    ab = jnp.abs(l - r) <= theta
    true = jnp.ones_like(lt)
    return jnp.where(
        op == 1, lt, jnp.where(op == 2, gt, jnp.where(op == 3, ab, true))
    )


def window_join_ref(L, R, ops, thetas):
    """ok[m, b] = AND over constraint rows.  L: (C, M), R: (C, B)."""
    l = L[:, :, None]              # (C, M, 1)
    r = R[:, None, :]              # (C, 1, B)
    op = ops[:, None, None]
    th = thetas[:, None, None]
    return jnp.all(cmp_op(op, l, r, th), axis=0)
