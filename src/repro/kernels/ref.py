"""Pure-jnp oracle for the ``window_join`` kernel.

Semantics (shared with the Pallas kernel): given ``C`` constraint rows,
left-side values ``L[c, m]``, right-side values ``R[c, b]``, per-row op-codes
and thresholds, compute

    ok[m, b] = AND_c  cmp(op[c], L[c, m], R[c, b], theta[c])

with the op-code table of ``repro.core.patterns``:

    0 (NONE)   -> True
    1 (LT)     -> l <  r + theta
    2 (GT)     -> l >  r - theta
    3 (ABS_LE) -> |l - r| <= theta

This single masked cross-comparison evaluates every constraint class of the
CEP engine — time-window membership, sequence ordering, pairwise predicates
and validity masks (encoded as 0/1 rows) — which is what makes the data
plane a dense, TPU-tileable operation.
"""

from __future__ import annotations

import jax.numpy as jnp


def cmp_op(op, l, r, theta):
    """Elementwise comparison dispatch; broadcasts ``l`` vs ``r``."""
    lt = l < r + theta
    gt = l > r - theta
    ab = jnp.abs(l - r) <= theta
    true = jnp.ones_like(lt)
    return jnp.where(
        op == 1, lt, jnp.where(op == 2, gt, jnp.where(op == 3, ab, true))
    )


def window_join_ref(L, R, ops, thetas):
    """ok[m, b] = AND over constraint rows.  L: (C, M), R: (C, B)."""
    l = L[:, :, None]              # (C, M, 1)
    r = R[:, None, :]              # (C, 1, B)
    op = ops[:, None, None]
    th = thetas[:, None, None]
    return jnp.all(cmp_op(op, l, r, th), axis=0)


# ---------------------------------------------------------------------------
# Packed operand layout (mirrors the packed Pallas kernel)
# ---------------------------------------------------------------------------
#
# The packed form replaces the per-row op dispatch (three nested selects on
# an int32 code) with a mask-select on precomputed comparison planes, and
# pulls row-validity out of the constraint stack into two int8 vectors that
# are AND-ed straight into the accumulator.  The float comparisons are the
# EXACT expressions of ``cmp_op`` — ``l < r + th`` / ``l > r - th`` /
# ``|l - r| <= th`` — so packed and unpacked evaluation are bit-identical
# (required: the engine's differential tests pin match counts across the
# kernel switch).
#
# The reduction is loop-accumulated over the (static) constraint dim: the
# working set stays one (M, B) boolean plane instead of a (C, M, B) stack,
# which is also what makes XLA fuse the whole chain into a single pass.


def window_join_packed_ref(L, R, ops8, thetas, mvalid, bvalid):
    """Packed oracle: ok[m, b] = mvalid & bvalid & AND_c row_c.

    L: (C, M) f32, R: (C, B) f32, ops8: (C,) i8, thetas: (C,) f32,
    mvalid: (M,) i8/bool, bvalid: (B,) i8/bool.  Returns (M, B) bool.
    """
    acc = (mvalid > 0)[:, None] & (bvalid > 0)[None, :]
    C = L.shape[0]
    for c in range(C):  # static unroll; keeps the working set at (M, B)
        l = L[c][:, None]
        r = R[c][None, :]
        th = thetas[c]
        o = ops8[c]
        lt = l < r + th
        gt = l > r - th
        ab = jnp.abs(l - r) <= th
        ok = (lt & (o == 1)) | (gt & (o == 2)) | (ab & (o == 3)) | (o == 0)
        acc = acc & ok
    return acc


def window_join_rowcount_ref(L, R, ops, thetas):
    """Per-m surviving-pair counts: cnt[m] = sum_b AND_c row_c[m, b].

    Same reduction as ``window_join_ref(...).sum(axis=1)`` but
    loop-accumulated so no (C, M, B) stack is materialized.  Feeds the
    negation veto (cnt > 0) and Kleene companion counts (cnt - 1) of the
    engine's finalize pass.
    """
    C, M = L.shape
    acc = jnp.ones((M, R.shape[1]), bool)
    for c in range(C):
        ok = cmp_op(ops[c], L[c][:, None], R[c][None, :], thetas[c])
        acc = acc & ok
    return acc.sum(axis=1).astype(jnp.int32)
