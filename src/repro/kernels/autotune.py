"""Block-size autotuning for the window-join Pallas kernels.

The join kernels tile the (M, B) output into ``(block_m, block_b)`` VMEM
tiles.  The best tile is a function of the join *shape class* — the
constraint count ``C`` and the padded extents of ``M`` (match capacity)
and ``B`` (buffer capacity) — and of the platform.  Because the engine
only ever instantiates a handful of shape classes (capacities are
config, not data), the tuning problem is tiny: sweep the block grid once
per shape class, persist the winners in a small on-disk table, and let
every kernel entry point consult it at trace time (block sizes are
static arguments — a table hit never recompiles anything that already
compiled with the same blocks).

Table location: ``benchmarks/autotune_cache.json`` at the repo root (the
committed table tracks the shapes ``benchmarks/kernel_bench.py`` sweeps;
override with ``REPRO_AUTOTUNE_TABLE=/path/to.json``, disable with
``REPRO_AUTOTUNE_TABLE=""``).  Missing table / missing class fall back
to the lane-aligned ``(128, 128)`` default, so the engine never depends
on the file existing.

Schema (versioned, one entry per shape class per platform)::

    {"schema": "autotune/v1",
     "entries": {"cpu/C16_M4096_B256": {"block_m": 128, "block_b": 128,
                                        "us": 812.4, "kernel": "packed"},
                 ...}}

``kernel_bench --sweep`` regenerates the table (see
``benchmarks/kernel_bench.py::autotune_sweep``).
"""

from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

_DEFAULT_BLOCKS = (128, 128)

# Candidate tile grid swept by the autotuner.  Lane dim (block_b) stays a
# multiple of 128 (TPU lane width); sublane dim (block_m) a multiple of 8.
BLOCK_M_CANDIDATES = (8, 32, 128, 256, 512)
BLOCK_B_CANDIDATES = (128, 256, 512)

_TABLE_CACHE: Optional[Dict[str, dict]] = None
_TABLE_PATH_CACHE: Optional[str] = None


def default_table_path() -> str:
    """benchmarks/autotune_cache.json relative to the repo root."""
    env = os.environ.get("REPRO_AUTOTUNE_TABLE")
    if env is not None:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    root = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    return os.path.join(root, "benchmarks", "autotune_cache.json")


def _pow2_bucket(x: int) -> int:
    """Round up to the next power of two (shape-class bucketing)."""
    p = 1
    while p < x:
        p *= 2
    return p


def shape_class(C: int, M: int, B: int) -> str:
    """Bucketed shape-class key: exact in C, pow2 in M and B.

    Capacities are configuration (b_cap / m_cap), already powers of two in
    every shipped config, so bucketing only matters for ad-hoc shapes.
    """
    return f"C{int(C)}_M{_pow2_bucket(int(M))}_B{_pow2_bucket(int(B))}"


def platform() -> str:
    try:
        import jax

        return jax.devices()[0].platform
    except Exception:  # pragma: no cover - no backend at all
        return "cpu"


def load_table(path: Optional[str] = None) -> Dict[str, dict]:
    """Load (and memoize) the on-disk table; {} when absent/disabled."""
    global _TABLE_CACHE, _TABLE_PATH_CACHE
    path = path if path is not None else default_table_path()
    if _TABLE_CACHE is not None and _TABLE_PATH_CACHE == path:
        return _TABLE_CACHE
    entries: Dict[str, dict] = {}
    if path and os.path.exists(path):
        try:
            with open(path) as fh:
                payload = json.load(fh)
            if payload.get("schema") == "autotune/v1":
                entries = dict(payload.get("entries", {}))
        except (OSError, ValueError):  # corrupt table == no table
            entries = {}
    _TABLE_CACHE = entries
    _TABLE_PATH_CACHE = path
    return entries


def invalidate_cache() -> None:
    """Drop the memoized table (tests / after a sweep rewrite)."""
    global _TABLE_CACHE, _TABLE_PATH_CACHE
    _TABLE_CACHE = None
    _TABLE_PATH_CACHE = None


def save_table(entries: Dict[str, dict], path: Optional[str] = None) -> str:
    path = path if path is not None else default_table_path()
    payload = {"schema": "autotune/v1", "entries": entries}
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True)
        fh.write("\n")
    invalidate_cache()
    return path


def best_blocks(C: int, M: int, B: int,
                plat: Optional[str] = None) -> Tuple[int, int]:
    """(block_m, block_b) for a join shape: table hit or (128, 128).

    Called by the kernel wrappers when the caller does not pin blocks
    explicitly; runs at trace time (shapes are static), so the lookup
    costs nothing per step.
    """
    plat = plat or platform()
    entry = load_table().get(f"{plat}/{shape_class(C, M, B)}")
    if entry:
        return int(entry["block_m"]), int(entry["block_b"])
    return _DEFAULT_BLOCKS
