"""Pallas TPU kernel for the CEP masked windowed cross-join.

The hot loop of the vectorized CEP engine is, per evaluation-plan step, a
dense cross-evaluation of ``C`` constraint rows between ``M`` partial matches
and ``B`` buffered events:

    ok[m, b] = AND_c cmp(op[c], L[c, m], R[c, b], theta[c]).

TPU mapping
-----------
* Grid tiles the (M, B) output into ``(block_m, block_b)`` VMEM tiles
  (default 128×128 — lane-aligned; the op is VPU-bound, 8×128 vregs).
* The constraint dimension ``C`` is small (≈ 2·n + predicate pairs ≤ ~32);
  each tile loads the full ``(C, block_m)`` / ``(C, block_b)`` operand strips
  into VMEM — a few KiB — and unrolls the AND-reduction over ``C``
  (``C`` is static at trace time; op-codes/thresholds are *data*, so one
  compiled kernel serves every pattern/plan of a given size — plan changes
  never recompile the data plane).
* Output is ``int8`` 0/1 (TPU-safe dense mask); the wrapper casts to bool.

VMEM budget per tile: 2·C·128·4 B (operands) + 128·128 B (mask) ≈ 48 KiB at
C = 32 — far under the ~16 MiB/core budget, leaving room for the pipeline's
double buffering.

Validated against ``ref.window_join_ref`` in ``interpret=True`` mode on CPU
(see ``tests/test_kernels.py``); TPU is the deployment target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(l_ref, r_ref, op_ref, th_ref, out_ref):
    C = l_ref.shape[0]
    bm = l_ref.shape[1]
    bb = r_ref.shape[1]
    acc = jnp.ones((bm, bb), jnp.bool_)
    for c in range(C):  # static unroll over the small constraint dim
        l = l_ref[c, :][:, None]          # (bm, 1)
        r = r_ref[c, :][None, :]          # (1, bb)
        op = op_ref[c]
        th = th_ref[c]
        lt = l < r + th
        gt = l > r - th
        ab = jnp.abs(l - r) <= th
        ok = jnp.where(
            op == 1, lt, jnp.where(op == 2, gt, jnp.where(op == 3, ab, True))
        )
        acc = jnp.logical_and(acc, ok)
    out_ref[...] = acc.astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_b", "interpret")
)
def window_join_pallas(
    L: jax.Array,
    R: jax.Array,
    ops: jax.Array,
    thetas: jax.Array,
    *,
    block_m: int = 128,
    block_b: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Tiled Pallas evaluation of the constraint cross-join.

    L: (C, M) f32, R: (C, B) f32, ops: (C,) i32, thetas: (C,) f32.
    Returns ok: (M, B) bool.  M and B are padded up to tile multiples
    internally; padding garbage is sliced away before returning.
    """
    C, M = L.shape
    _, B = R.shape
    bm = min(block_m, max(M, 8))
    bb = min(block_b, max(B, 128))
    Mp = (M + bm - 1) // bm * bm
    Bp = (B + bb - 1) // bb * bb
    if Mp != M:
        L = jnp.pad(L, ((0, 0), (0, Mp - M)))
    if Bp != B:
        R = jnp.pad(R, ((0, 0), (0, Bp - B)))

    grid = (Mp // bm, Bp // bb)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, bm), lambda i, j: (0, i)),
            pl.BlockSpec((C, bb), lambda i, j: (0, j)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Bp), jnp.int8),
        interpret=interpret,
    )(
        L.astype(jnp.float32),
        R.astype(jnp.float32),
        ops.astype(jnp.int32),
        thetas.astype(jnp.float32),
    )
    return out[:M, :B].astype(jnp.bool_)


def _count_kernel(l_ref, r_ref, op_ref, th_ref, out_ref, *, m_valid, b_valid):
    """Per-tile match counting — avoids materializing ok to HBM when only
    cardinalities are needed (statistics estimation, §2.2).

    ``m_valid`` / ``b_valid`` are the true (unpadded) extents, static at
    trace time.  Padded (m, b) cells are masked out explicitly: a pure
    value-based pad (e.g. NaN) only dies on rows whose op actually
    *compares* — an op ∉ {1, 2, 3} row takes the vacuous-True branch, so a
    constraint stack of only NONE rows would count the padding.
    """
    C = l_ref.shape[0]
    bm = l_ref.shape[1]
    bb = r_ref.shape[1]
    mi = pl.program_id(0) * bm + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bb), 0)
    bi = pl.program_id(1) * bb + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bb), 1)
    acc = (mi < m_valid) & (bi < b_valid)
    for c in range(C):
        l = l_ref[c, :][:, None]
        r = r_ref[c, :][None, :]
        op = op_ref[c]
        th = th_ref[c]
        lt = l < r + th
        gt = l > r - th
        ab = jnp.abs(l - r) <= th
        ok = jnp.where(
            op == 1, lt, jnp.where(op == 2, gt, jnp.where(op == 3, ab, True))
        )
        acc = jnp.logical_and(acc, ok)
    out_ref[0, 0] = jnp.sum(acc.astype(jnp.int32))


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_b", "interpret")
)
def window_join_count_pallas(
    L, R, ops, thetas, *, block_m: int = 128, block_b: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Total number of matching (m, b) pairs, computed tile-locally."""
    C, M = L.shape
    _, B = R.shape
    bm = min(block_m, max(M, 8))
    bb = min(block_b, max(B, 128))
    Mp = (M + bm - 1) // bm * bm
    Bp = (B + bb - 1) // bb * bb
    # Padding exactness: the kernel masks every (m, b) cell against the true
    # extents (static at trace time), so pad *values* are irrelevant — they
    # can never be counted, whatever the op codes are.  (An earlier NaN-pad
    # scheme relied on padded values failing a comparison, which a
    # vacuous-True op ∉ {1, 2, 3} row never performs.)
    if Mp != M:
        L = jnp.pad(L, ((0, 0), (0, Mp - M)))
    if Bp != B:
        R = jnp.pad(R, ((0, 0), (0, Bp - B)))
    grid = (Mp // bm, Bp // bb)
    counts = pl.pallas_call(
        functools.partial(_count_kernel, m_valid=M, b_valid=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, bm), lambda i, j: (0, i)),
            pl.BlockSpec((C, bb), lambda i, j: (0, j)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp // bm, Bp // bb), jnp.int32),
        interpret=interpret,
    )(
        L.astype(jnp.float32),
        R.astype(jnp.float32),
        ops.astype(jnp.int32),
        thetas.astype(jnp.float32),
    )
    return counts.sum()
