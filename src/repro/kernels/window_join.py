"""Pallas TPU kernel for the CEP masked windowed cross-join.

The hot loop of the vectorized CEP engine is, per evaluation-plan step, a
dense cross-evaluation of ``C`` constraint rows between ``M`` partial matches
and ``B`` buffered events:

    ok[m, b] = AND_c cmp(op[c], L[c, m], R[c, b], theta[c]).

TPU mapping
-----------
* Grid tiles the (M, B) output into ``(block_m, block_b)`` VMEM tiles
  (default 128×128 — lane-aligned; the op is VPU-bound, 8×128 vregs).
* The constraint dimension ``C`` is small (≈ 2·n + predicate pairs ≤ ~32);
  each tile loads the full ``(C, block_m)`` / ``(C, block_b)`` operand strips
  into VMEM — a few KiB — and unrolls the AND-reduction over ``C``
  (``C`` is static at trace time; op-codes/thresholds are *data*, so one
  compiled kernel serves every pattern/plan of a given size — plan changes
  never recompile the data plane).
* Output is ``int8`` 0/1 (TPU-safe dense mask); the wrapper casts to bool.

VMEM budget per tile: 2·C·128·4 B (operands) + 128·128 B (mask) ≈ 48 KiB at
C = 32 — far under the ~16 MiB/core budget, leaving room for the pipeline's
double buffering.

Validated against ``ref.window_join_ref`` in ``interpret=True`` mode on CPU
(see ``tests/test_kernels.py``); TPU is the deployment target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import autotune as _autotune
from . import ref as _ref


def _resolve_blocks(C, M, B, block_m, block_b):
    """Static block sizes: explicit caller pins win, else the autotune
    table (per shape-class, per platform), else (128, 128)."""
    if block_m is None or block_b is None:
        abm, abb = _autotune.best_blocks(C, M, B)
        block_m = block_m if block_m is not None else abm
        block_b = block_b if block_b is not None else abb
    return block_m, block_b


def _tile_waste(M, B, bm, bb) -> bool:
    """True when the padded grid does mostly-padding work: below one
    (8, 128) tile of real cells, or >= 4x padding blow-up (the B=4
    pathology: a 4-wide buffer pads to a full 128-lane tile, ~32x waste).
    Such shapes dispatch to the fused jnp reference instead — on small
    operands XLA's fusion beats a mostly-padded Pallas launch."""
    Mp = (M + bm - 1) // bm * bm
    Bp = (B + bb - 1) // bb * bb
    return (M * B < 8 * 128) or (Mp * Bp >= 4 * M * B)


def _kernel(l_ref, r_ref, op_ref, th_ref, out_ref):
    C = l_ref.shape[0]
    bm = l_ref.shape[1]
    bb = r_ref.shape[1]
    acc = jnp.ones((bm, bb), jnp.bool_)
    for c in range(C):  # static unroll over the small constraint dim
        l = l_ref[c, :][:, None]          # (bm, 1)
        r = r_ref[c, :][None, :]          # (1, bb)
        op = op_ref[c]
        th = th_ref[c]
        lt = l < r + th
        gt = l > r - th
        ab = jnp.abs(l - r) <= th
        ok = jnp.where(
            op == 1, lt, jnp.where(op == 2, gt, jnp.where(op == 3, ab, True))
        )
        acc = jnp.logical_and(acc, ok)
    out_ref[...] = acc.astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_b", "interpret")
)
def window_join_pallas(
    L: jax.Array,
    R: jax.Array,
    ops: jax.Array,
    thetas: jax.Array,
    *,
    block_m: int | None = None,
    block_b: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Tiled Pallas evaluation of the constraint cross-join.

    L: (C, M) f32, R: (C, B) f32, ops: (C,) i32, thetas: (C,) f32.
    Returns ok: (M, B) bool.  M and B are padded up to tile multiples
    internally; padding garbage is sliced away before returning.
    Block sizes default to the autotune table for the shape class.
    Interpret mode always runs the kernel body (it is the correctness
    harness); compiled mode falls back to the jnp reference for shapes
    that would be mostly tile padding.
    """
    C, M = L.shape
    _, B = R.shape
    block_m, block_b = _resolve_blocks(C, M, B, block_m, block_b)
    bm = min(block_m, max(M, 8))
    bb = min(block_b, max(B, 128))
    if not interpret and _tile_waste(M, B, bm, bb):
        return _ref.window_join_ref(L, R, ops, thetas)
    Mp = (M + bm - 1) // bm * bm
    Bp = (B + bb - 1) // bb * bb
    if Mp != M:
        L = jnp.pad(L, ((0, 0), (0, Mp - M)))
    if Bp != B:
        R = jnp.pad(R, ((0, 0), (0, Bp - B)))

    grid = (Mp // bm, Bp // bb)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, bm), lambda i, j: (0, i)),
            pl.BlockSpec((C, bb), lambda i, j: (0, j)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Bp), jnp.int8),
        interpret=interpret,
    )(
        L.astype(jnp.float32),
        R.astype(jnp.float32),
        ops.astype(jnp.int32),
        thetas.astype(jnp.float32),
    )
    return out[:M, :B].astype(jnp.bool_)


def _count_kernel(l_ref, r_ref, op_ref, th_ref, out_ref, *, m_valid, b_valid):
    """Per-tile match counting — avoids materializing ok to HBM when only
    cardinalities are needed (statistics estimation, §2.2).

    ``m_valid`` / ``b_valid`` are the true (unpadded) extents, static at
    trace time.  Padded (m, b) cells are masked out explicitly: a pure
    value-based pad (e.g. NaN) only dies on rows whose op actually
    *compares* — an op ∉ {1, 2, 3} row takes the vacuous-True branch, so a
    constraint stack of only NONE rows would count the padding.
    """
    C = l_ref.shape[0]
    bm = l_ref.shape[1]
    bb = r_ref.shape[1]
    mi = pl.program_id(0) * bm + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bb), 0)
    bi = pl.program_id(1) * bb + jax.lax.broadcasted_iota(
        jnp.int32, (bm, bb), 1)
    acc = (mi < m_valid) & (bi < b_valid)
    for c in range(C):
        l = l_ref[c, :][:, None]
        r = r_ref[c, :][None, :]
        op = op_ref[c]
        th = th_ref[c]
        lt = l < r + th
        gt = l > r - th
        ab = jnp.abs(l - r) <= th
        ok = jnp.where(
            op == 1, lt, jnp.where(op == 2, gt, jnp.where(op == 3, ab, True))
        )
        acc = jnp.logical_and(acc, ok)
    out_ref[0, 0] = jnp.sum(acc.astype(jnp.int32))


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_b", "interpret")
)
def window_join_count_pallas(
    L, R, ops, thetas, *, block_m: int | None = None,
    block_b: int | None = None, interpret: bool = False,
) -> jax.Array:
    """Total number of matching (m, b) pairs, computed tile-locally."""
    C, M = L.shape
    _, B = R.shape
    block_m, block_b = _resolve_blocks(C, M, B, block_m, block_b)
    bm = min(block_m, max(M, 8))
    bb = min(block_b, max(B, 128))
    if not interpret and _tile_waste(M, B, bm, bb):
        return _ref.window_join_ref(L, R, ops, thetas).sum(
            dtype=jnp.int32)
    Mp = (M + bm - 1) // bm * bm
    Bp = (B + bb - 1) // bb * bb
    # Padding exactness: the kernel masks every (m, b) cell against the true
    # extents (static at trace time), so pad *values* are irrelevant — they
    # can never be counted, whatever the op codes are.  (An earlier NaN-pad
    # scheme relied on padded values failing a comparison, which a
    # vacuous-True op ∉ {1, 2, 3} row never performs.)
    if Mp != M:
        L = jnp.pad(L, ((0, 0), (0, Mp - M)))
    if Bp != B:
        R = jnp.pad(R, ((0, 0), (0, Bp - B)))
    grid = (Mp // bm, Bp // bb)
    counts = pl.pallas_call(
        functools.partial(_count_kernel, m_valid=M, b_valid=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, bm), lambda i, j: (0, i)),
            pl.BlockSpec((C, bb), lambda i, j: (0, j)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp // bm, Bp // bb), jnp.int32),
        interpret=interpret,
    )(
        L.astype(jnp.float32),
        R.astype(jnp.float32),
        ops.astype(jnp.int32),
        thetas.astype(jnp.float32),
    )
    return counts.sum()


# ---------------------------------------------------------------------------
# Packed operand layout
# ---------------------------------------------------------------------------
#
# The packed variants take the engine's cached-strip layout:
#
# * op-codes enter as an ``int8`` strip and each constraint row is a
#   mask-select over the three precomputed comparison planes —
#   ``(lt & is_lt) | (gt & is_gt) | (ab & is_ab) | is_none`` — instead of
#   the unpacked kernel's nested ``jnp.where`` dispatch;
# * row-validity enters as two ``int8`` vectors seeding the accumulator,
#   not as two float32 constraint rows — the constraint stack shrinks by
#   two planes and, because padding extends the validity vectors with
#   zeros, padded (m, b) cells are excluded by construction (no iota
#   masking needed, for ANY op mix);
# * the AND-reduction accumulates in bool/int8 vregs throughout.
#
# The float comparisons are the exact unpacked expressions, so packed and
# unpacked agree bit-for-bit — the property the engine's differential
# tests pin across the kernel switch.


def _packed_kernel(l_ref, r_ref, op_ref, th_ref, mv_ref, bv_ref, out_ref):
    C = l_ref.shape[0]
    mv = mv_ref[0, :] > 0                     # (bm,)
    bv = bv_ref[0, :] > 0                     # (bb,)
    acc = mv[:, None] & bv[None, :]           # (bm, bb) bool
    for c in range(C):  # static unroll over the small constraint dim
        l = l_ref[c, :][:, None]
        r = r_ref[c, :][None, :]
        op = op_ref[c]
        th = th_ref[c]
        lt = l < r + th
        gt = l > r - th
        ab = jnp.abs(l - r) <= th
        ok = (lt & (op == 1)) | (gt & (op == 2)) | (ab & (op == 3)) \
            | (op == 0)
        acc = jnp.logical_and(acc, ok)
    out_ref[...] = acc.astype(jnp.int8)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_b", "interpret")
)
def window_join_packed_pallas(
    L, R, ops8, thetas, mvalid, bvalid, *, block_m: int | None = None,
    block_b: int | None = None, interpret: bool = False,
) -> jax.Array:
    """Packed-strip cross-join: ok[m, b] = mvalid & bvalid & AND_c row_c.

    L: (C, M) f32, R: (C, B) f32, ops8: (C,) i8, thetas: (C,) f32,
    mvalid: (M,), bvalid: (B,) i8/bool.  Returns (M, B) bool.
    """
    C, M = L.shape
    _, B = R.shape
    block_m, block_b = _resolve_blocks(C, M, B, block_m, block_b)
    bm = min(block_m, max(M, 8))
    bb = min(block_b, max(B, 128))
    if not interpret and _tile_waste(M, B, bm, bb):
        return _ref.window_join_packed_ref(L, R, ops8, thetas, mvalid,
                                           bvalid)
    Mp = (M + bm - 1) // bm * bm
    Bp = (B + bb - 1) // bb * bb
    if Mp != M:
        L = jnp.pad(L, ((0, 0), (0, Mp - M)))
    if Bp != B:
        R = jnp.pad(R, ((0, 0), (0, Bp - B)))
    # Validity doubles as the padding mask: padded slots are invalid rows.
    mv = jnp.pad(mvalid.astype(jnp.int8), (0, Mp - M))[None, :]
    bv = jnp.pad(bvalid.astype(jnp.int8), (0, Bp - B))[None, :]

    grid = (Mp // bm, Bp // bb)
    out = pl.pallas_call(
        _packed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, bm), lambda i, j: (0, i)),
            pl.BlockSpec((C, bb), lambda i, j: (0, j)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
            pl.BlockSpec((1, bm), lambda i, j: (0, i)),
            pl.BlockSpec((1, bb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Bp), jnp.int8),
        interpret=interpret,
    )(
        L.astype(jnp.float32),
        R.astype(jnp.float32),
        ops8.astype(jnp.int8),
        thetas.astype(jnp.float32),
        mv,
        bv,
    )
    return out[:M, :B].astype(jnp.bool_)


def _rowcount_kernel(l_ref, r_ref, op_ref, th_ref, out_ref, *, b_valid):
    """Per-m surviving-pair counts, accumulated across the B-tile grid.

    The (bm, bb) mask never leaves VMEM: each tile reduces over its lanes
    and accumulates into the (bm, 1) output block, which the sequential
    j-sweep of the grid revisits.  ``b_valid`` (true B extent, static)
    masks lane padding; m padding needs no mask — the wrapper slices it.
    """
    C = l_ref.shape[0]
    bm = l_ref.shape[1]
    bb = r_ref.shape[1]
    j = pl.program_id(1)
    bi = j * bb + jax.lax.broadcasted_iota(jnp.int32, (bm, bb), 1)
    acc = bi < b_valid
    for c in range(C):
        l = l_ref[c, :][:, None]
        r = r_ref[c, :][None, :]
        op = op_ref[c]
        th = th_ref[c]
        lt = l < r + th
        gt = l > r - th
        ab = jnp.abs(l - r) <= th
        ok = (lt & (op == 1)) | (gt & (op == 2)) | (ab & (op == 3)) \
            | (op == 0)
        acc = jnp.logical_and(acc, ok)
    partial = acc.astype(jnp.int32).sum(axis=1, keepdims=True)  # (bm, 1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = partial

    @pl.when(j != 0)
    def _accum():
        out_ref[...] = out_ref[...] + partial


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_b", "interpret")
)
def window_join_rowcount_pallas(
    L, R, ops, thetas, *, block_m: int | None = None,
    block_b: int | None = None, interpret: bool = False,
) -> jax.Array:
    """Fused per-m row counts: cnt[m] = sum_b AND_c cmp(...) — (M,) i32.

    What the finalize pass actually consumes for negation (cnt > 0) and
    Kleene closure (cnt - 1): the (M, B) mask is reduced tile-locally and
    never materialized to HBM.
    """
    C, M = L.shape
    _, B = R.shape
    block_m, block_b = _resolve_blocks(C, M, B, block_m, block_b)
    bm = min(block_m, max(M, 8))
    bb = min(block_b, max(B, 128))
    if not interpret and _tile_waste(M, B, bm, bb):
        return _ref.window_join_rowcount_ref(L, R, ops, thetas)
    Mp = (M + bm - 1) // bm * bm
    Bp = (B + bb - 1) // bb * bb
    if Mp != M:
        L = jnp.pad(L, ((0, 0), (0, Mp - M)))
    if Bp != B:
        R = jnp.pad(R, ((0, 0), (0, Bp - B)))
    grid = (Mp // bm, Bp // bb)
    counts = pl.pallas_call(
        functools.partial(_rowcount_kernel, b_valid=B),
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, bm), lambda i, j: (0, i)),
            pl.BlockSpec((C, bb), lambda i, j: (0, j)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
            pl.BlockSpec((C,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Mp, 1), jnp.int32),
        interpret=interpret,
    )(
        L.astype(jnp.float32),
        R.astype(jnp.float32),
        ops.astype(jnp.int32),
        thetas.astype(jnp.float32),
    )
    return counts[:M, 0]
