"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) expert d_ff=10752
vocab=100352; 16 experts top-4, fine-grained [hf:databricks/dbrx-base]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352, norm="rms",
    n_experts=16, n_shared_experts=0, top_k=4,
)

SMOKE = FULL.with_(
    name="dbrx-smoke", n_layers=2, d_model=48, n_heads=6, n_kv_heads=2,
    head_dim=8, d_ff=64, vocab=256, n_experts=4, top_k=2,
)
