"""musicgen-large [audio] — 48L d_model=2048 32H (kv=32) d_ff=8192
vocab=2048; decoder-only over EnCodec tokens; the EnCodec frontend is
STUBBED per assignment: ``input_specs()`` provides precomputed frame
embeddings [arXiv:2306.05284]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, norm="rms",
    frontend_is_embedding=True,
)

SMOKE = FULL.with_(
    name="musicgen-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=64,
)
