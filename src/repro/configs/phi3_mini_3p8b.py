"""phi3-mini-3.8b [dense] — 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064; RoPE + SwiGLU, full MHA (GQA group 1) [arXiv:2404.14219]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, norm="rms",
)

SMOKE = FULL.with_(
    name="phi3-mini-smoke", n_layers=2, d_model=64, n_heads=4,
    n_kv_heads=4, d_ff=128, vocab=256,
)
