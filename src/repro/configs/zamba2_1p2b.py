"""zamba2-1.2b [hybrid] — 38L d_model=2048 Mamba2 backbone + one SHARED
attention block (32H kv=32, d_ff=8192) applied every 6 layers, vocab=32000,
ssm_state=64 [arXiv:2411.15242].

``attn_window=4096`` gives the shared block a sliding-window ring KV cache
for the ``long_500k`` decode shape, keeping the hybrid sub-quadratic in
context length (hardware-adaptation note in DESIGN.md)."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32000, norm="rms",
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4,
    ssm_chunk=256, attn_every=6, attn_window=4096,
)

SMOKE = FULL.with_(
    name="zamba2-smoke", n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
    attn_every=2, attn_window=16,
)
