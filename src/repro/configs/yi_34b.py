"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000;
llama-arch GQA [arXiv:2403.04652]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab=64000, norm="rms",
)

SMOKE = FULL.with_(
    name="yi-smoke", n_layers=2, d_model=56, n_heads=7, n_kv_heads=1,
    head_dim=8, d_ff=128, vocab=256,
)
