"""Architecture registry: the 10 assigned configs (+ the paper's own CEP
default).  ``get_config(name)`` returns the FULL production config;
``get_smoke(name)`` a reduced same-family config for CPU smoke tests."""

from __future__ import annotations

import importlib
from typing import List

from ..models.config import ModelConfig

ARCHS = [
    "phi3_mini_3p8b",
    "olmo_1b",
    "yi_34b",
    "stablelm_12b",
    "deepseek_moe_16b",
    "dbrx_132b",
    "paligemma_3b",
    "musicgen_large",
    "mamba2_1p3b",
    "zamba2_1p2b",
]

# CLI aliases (assignment ids) -> module names
ALIASES = {
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "olmo-1b": "olmo_1b",
    "yi-34b": "yi_34b",
    "stablelm-12b": "stablelm_12b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "dbrx-132b": "dbrx_132b",
    "paligemma-3b": "paligemma_3b",
    "musicgen-large": "musicgen_large",
    "mamba2-1.3b": "mamba2_1p3b",
    "zamba2-1.2b": "zamba2_1p2b",
}


def _module(name: str):
    mod = ALIASES.get(name, name)
    if mod not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; know {sorted(ALIASES)}")
    return importlib.import_module(f".{mod}", __package__)


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).FULL
    return cfg.with_(**overrides) if overrides else cfg


def get_smoke(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).SMOKE
    return cfg.with_(**overrides) if overrides else cfg


def list_archs() -> List[str]:
    return list(ALIASES)
