"""paligemma-3b [vlm] — 18L d_model=2048 8H (MQA kv=1, head_dim=256)
d_ff=16384 vocab=257216; SigLIP frontend STUBBED per assignment:
``input_specs()`` provides 256 precomputed patch embeddings, consumed with
a bidirectional prefix mask [arXiv:2407.07726]."""

from ..models.config import ModelConfig

FULL = ModelConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257216, norm="rms",
    n_frontend_tokens=256,
)

SMOKE = FULL.with_(
    name="paligemma-smoke", n_layers=2, d_model=64, n_heads=4, n_kv_heads=1,
    head_dim=16, d_ff=128, vocab=256, n_frontend_tokens=8,
)
