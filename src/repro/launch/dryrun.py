import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, and extract the roofline terms.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line above executes before any jax import, which locks the
host platform to 512 placeholder devices.  Do NOT import this module from
code that already initialized jax with a different device count.

Per cell we record:
  * per-device peak memory from ``compiled.memory_analysis()``
    (proves the cell fits a 16 GB v5e chip),
  * HLO FLOPs / bytes from ``compiled.cost_analysis()``,
  * collective operand bytes parsed from the compiled HLO text
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute),
  * the sharding fallbacks the divisibility resolver applied,
  * the three roofline terms for TPU v5e
    (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).

Usage:
  python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod-only|--single-pod-only]
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax

from ..configs import get_config, list_archs
from ..models.model import Model
from ..train.optimizer import AdamWConfig
from ..train.train_step import lower_serve_step, lower_train_step
from .mesh import make_production_mesh
from .shapes import SHAPES, applicable

# TPU v5e hardware constants (per chip).
PEAK_FLOPS = 197e12         # bf16
HBM_BW = 819e9              # bytes/s
ICI_BW = 50e9               # bytes/s/link

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\b")
_SHAPE_RE = re.compile(r"(bf16|f32|f16|f64|s32|s8|u8|s16|u32|pred|s64)"
                       r"\[([0-9,]*)\]")

_DTYPE_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "f64": 8, "s32": 4,
                "u32": 4, "s16": 2, "s8": 1, "u8": 1, "pred": 1, "s64": 8}


def collective_bytes(hlo_text: str) -> dict:
    """Sum output operand bytes of every collective op in the HLO.

    HLO line form: ``%name = <shape(s)> all-reduce(...)`` — output shapes
    sit between '=' and the op name.  ``-done`` halves of async pairs are
    skipped so each collective counts once.
    """
    out = {}
    for line in hlo_text.splitlines():
        if "-done" in line or "=" not in line:
            continue
        eq = line.index("=")
        m = _COLL_RE.search(line, eq)  # search rhs only (lhs = var name)
        if not m:
            continue
        kind = m.group(1)
        seg = line[eq + 1:m.start()]
        total = 0
        for dm in _SHAPE_RE.finditer(seg):
            dt, dims = dm.group(1), dm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        if total:
            out[kind] = out.get(kind, 0) + total
    return out


def memory_stats(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes"]
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def roofline_terms(flops, hbm_bytes, coll_bytes, n_chips) -> dict:
    # cost_analysis is per-program (global); divide by chip count.
    compute_s = flops / (n_chips * PEAK_FLOPS)
    memory_s = hbm_bytes / (n_chips * HBM_BW)
    collective_s = coll_bytes / ICI_BW  # HLO is per-device already
    dominant = max(
        ("compute", compute_s), ("memory", memory_s),
        ("collective", collective_s), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
    }


def _compile_metrics(cfg, shape, mesh, *, microbatches, remat,
                     rule_overrides, unroll_layers, opt_overrides=None,
                     zero1=False, want_memory=False):
    """Lower + compile one variant; return (metrics dict, rules, compiled)."""
    model = Model(cfg, remat=remat, unroll_layers=unroll_layers)
    spec = SHAPES[shape]
    t0 = time.time()
    if spec.kind == "train":
        opt_kw = dict(total_steps=10000)
        if opt_overrides:
            opt_kw.update(opt_overrides)
        lowered, rules = lower_train_step(
            model, AdamWConfig(**opt_kw), mesh, shape,
            microbatches=microbatches, rule_overrides=rule_overrides,
            zero1=zero1)
    else:
        lowered, rules = lower_serve_step(
            model, mesh, shape, rule_overrides=rule_overrides)
    compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    out = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll": coll,
        "coll_total": float(sum(coll.values())),
        "t_s": time.time() - t0,
    }
    if want_memory:
        out["memory"] = memory_stats(compiled)
    return out, rules


def _needs_flash(cfg, spec) -> bool:
    return (cfg.family != "ssm" and spec.kind in ("train", "prefill")
            and spec.seq > cfg.attn_direct_max)


def _needs_ssd_fit(cfg, spec) -> bool:
    """All non-decode SSM/hybrid cells use the 3-point quadratic fit: the
    SSD body cost is exactly (a·q² + b·q) in the chunk size, so probes at
    q, 2q, 4q identify the per-chunk cost with three *small* compiles —
    unrolling the chunk scan inside 48 unrolled layers would instead
    produce a colossal HLO (50+ min compiles on this 1-core host)."""
    return cfg.family in ("ssm", "hybrid") and spec.kind != "decode"


def run_cell(arch: str, shape: str, multi_pod: bool,
             rule_overrides=None, microbatches: int = 1,
             remat: str = "full", dtype: str = "bf16",
             opt_overrides=None, rolled: bool = False,
             cfg_overrides=None, zero1: bool = False) -> dict:
    """Lower + compile one (arch × shape × mesh) cell; return the record.

    FLOP/byte/collective accounting (EXPERIMENTS.md §Dry-run methodology):
    XLA's cost_analysis counts a while-loop body once regardless of trip
    count, so (a) the layer scan is unrolled, (b) the SSD chunk scan is
    unrolled when its trip count is small (train_4k), and (c) remaining
    inner loops (flash-attention KV blocks; SSD chunks at 32k prefill) are
    corrected by probe compiles: the loop-body cost is linear in the flash
    block size and quadratic in the SSD chunk size, so one or two extra
    compiles identify it exactly.  Peak-memory stats come from a separate
    compile of the *rolled* program — the artifact that would actually
    ship.
    """
    cfg = get_config(arch, param_dtype=dtype, dtype=dtype,
                     **(cfg_overrides or {}))
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    spec = SHAPES[shape]
    kw = dict(microbatches=microbatches, remat=remat,
              rule_overrides=rule_overrides, opt_overrides=opt_overrides,
              zero1=zero1)
    try:
        cfg_main = cfg
        if rolled:
            # Fast mode (multi-pod pass): compile the deployable rolled
            # program only — proves sharding/compile/memory; FLOP and
            # collective counts are per-loop-body (approximate) and the
            # roofline table uses the single-pod exact numbers instead.
            main, rules = _compile_metrics(
                cfg, shape, mesh, unroll_layers=False, want_memory=True,
                **kw)
            return {
                "arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "ok", "n_chips": n_chips, "rolled": True,
                "t_compile_s": round(main["t_s"], 1),
                "hlo_flops_body": main["flops"],
                "collective_bytes_body": main["coll_total"],
                "memory": main.get("memory", {}),
                "fallbacks": rules.fallbacks,
            }
        main, rules = _compile_metrics(
            cfg_main, shape, mesh, unroll_layers=True, **kw)
        t_compile = main["t_s"]
        corrections = {}

        flops = main["flops"]
        hbm = main["bytes"]
        coll = dict(main["coll"])
        coll_total = main["coll_total"]

        if _needs_flash(cfg, spec):
            blk = cfg.attn_kv_block
            probe, _ = _compile_metrics(
                cfg_main.with_(attn_kv_block=2 * blk), shape, mesh,
                unroll_layers=True, **kw)
            T = spec.seq + (cfg.n_frontend_tokens
                            if cfg.family == "vlm" else 0)
            for key in ("flops", "bytes", "coll_total"):
                c = (probe[key] - main[key]) / blk
                extra = c * (T - blk)
                corrections[f"flash_{key}"] = extra
            flops += corrections["flash_flops"]
            hbm += corrections["flash_bytes"]
            coll_total += corrections["flash_coll_total"]

        if _needs_ssd_fit(cfg, spec):
            q1 = cfg.ssm_chunk
            p2, _ = _compile_metrics(
                cfg_main.with_(ssm_chunk=2 * q1), shape, mesh,
                unroll_layers=True, **kw)
            p3, _ = _compile_metrics(
                cfg_main.with_(ssm_chunk=4 * q1), shape, mesh,
                unroll_layers=True, **kw)
            S = spec.seq
            for key in ("flops", "bytes", "coll_total"):
                # f(q) = base + a q^2 + b q  ->  true = base + a S q1 + b S
                f1, f2, f3 = main[key], p2[key], p3[key]
                # Solve with q, 2q, 4q:  f2-f1 = 3a q^2 + b q;
                #                        f3-f2 = 12a q^2 + 2b q.
                a = (f3 - 3 * f2 + 2 * f1) / (6 * q1 * q1)
                b = ((f2 - f1) - 3 * a * q1 * q1) / q1
                base = f1 - a * q1 * q1 - b * q1
                true = base + a * S * q1 + b * S
                corrections[f"ssd_{key}"] = true - main[key]
            flops += corrections["ssd_flops"]
            hbm += corrections["ssd_bytes"]
            coll_total += corrections["ssd_coll_total"]

        # Memory of the deployable (rolled) program.
        rolled, _ = _compile_metrics(
            cfg, shape, mesh, unroll_layers=False, want_memory=True, **kw)
        mem = rolled.get("memory", {})
        n = cfg.param_count()
        if spec.kind == "train":
            tokens = spec.global_batch * spec.seq
            model_flops = 6 * cfg.active_param_count() * tokens
        elif spec.kind == "prefill":
            tokens = spec.global_batch * spec.seq
            model_flops = 2 * cfg.active_param_count() * tokens
        else:
            tokens = spec.global_batch
            model_flops = 2 * cfg.active_param_count() * tokens
        rec = {
            "arch": arch, "shape": shape,
            "mesh": "multi" if multi_pod else "single",
            "status": "ok",
            "n_chips": n_chips,
            "t_compile_s": round(t_compile, 1),
            "hlo_flops": flops,
            "hlo_bytes": hbm,
            "collectives": coll,
            "collective_bytes": coll_total,
            "corrections": corrections,
            "memory": mem,
            "fallbacks": rules.fallbacks,
            "params": n,
            "active_params": cfg.active_param_count(),
            "model_flops": model_flops,
            "useful_flops_ratio": (model_flops / (flops * n_chips)
                                   if flops else 0.0),
            **roofline_terms(flops, hbm, coll_total, 1),
        }
        return rec
    except Exception as e:  # noqa: BLE001 - report per-cell failures
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "trace": traceback.format_exc()[-2000:]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--single-pod-only", action="store_true")
    ap.add_argument("--multi-pod-only", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--rolled", action="store_true",
                    help="fast mode: rolled program only (compile + "
                         "memory proof; no exact FLOP accounting)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True]
    if args.single_pod_only:
        meshes = [False]
    if args.multi_pod_only:
        meshes = [True]

    def flush(records):
        if args.out:
            os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
            with open(args.out, "w") as f:
                json.dump(records, f, indent=1)

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp,
                               microbatches=args.microbatches,
                               remat=args.remat, rolled=args.rolled)
                records.append(rec)
                flush(records)  # incremental: survive timeouts/crashes
                status = rec["status"]
                extra = ""
                if status == "ok" and not rec.get("rolled"):
                    extra = (f"compile={rec['t_compile_s']}s "
                             f"flops={rec['hlo_flops']:.3g} "
                             f"coll={rec['collective_bytes']:.3g}B "
                             f"dom={rec['dominant']}")
                elif status == "ok":
                    mem = rec.get("memory", {})
                    gb = (mem.get("argument_size_in_bytes", 0)
                          + mem.get("temp_size_in_bytes", 0)
                          - mem.get("alias_size_in_bytes", 0)) / 1e9
                    extra = (f"compile={rec['t_compile_s']}s "
                             f"mem={gb:.1f}GB/dev (rolled)")
                elif status == "error":
                    extra = rec["error"][:120]
                else:
                    extra = "skip"
                print(f"[{rec['mesh']:6s}] {arch:18s} {shape:12s} "
                      f"{status:7s} {extra}", flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    bad = [r for r in records if r["status"] == "error"]
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
