"""Training driver.

Runs real steps on whatever devices exist (CPU smoke configs through TPU
pods — the step function is the same one the dry-run lowers).  Features:

* deterministic restart: data is a pure function of (seed, step); resuming
  from a checkpoint replays the exact same batch sequence;
* fault tolerance: atomic async checkpoints every ``--ckpt-every`` steps,
  `--resume` restores params+optimizer (+ the governor's EMA loads);
* adaptive MoE expert placement: for MoE archs the invariant governor
  watches per-expert loads and triggers weight re-permutation only on
  invariant violation (the paper's technique in the training loop).

Example:
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-moe-16b \\
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ck --resume
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..adaptive.placement import (ExpertPlacementGovernor,
                                  permute_expert_params, relocation)
from ..checkpoint import CheckpointManager
from ..configs import get_config, get_smoke
from ..data.lm_data import DataConfig, make_batch
from ..models.model import Model
from ..train.optimizer import AdamWConfig, init_state
from ..train.train_step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--adaptive-placement", action="store_true",
                    help="invariant-governed MoE expert re-placement")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_smoke(args.arch) if args.smoke else get_config(args.arch))
    if cfg.ssm_chunk > args.seq:
        cfg = cfg.with_(ssm_chunk=max(8, args.seq // 4))
    model = Model(cfg, remat=args.remat)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=10,
                          total_steps=args.steps)
    dcfg = DataConfig(batch=args.batch, seq=args.seq, seed=args.seed)

    params = model.init(jax.random.PRNGKey(args.seed))
    opt_state = init_state(opt_cfg, params)
    start = 0

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        params, opt_state = ckpt.restore((params, opt_state))
        start = int(np.asarray(opt_state.step))
        print(f"resumed from step {start}")

    step_fn = jax.jit(make_train_step(model, opt_cfg))

    governor = None
    cur_perm = np.arange(cfg.n_experts) if cfg.family == "moe" else None
    if args.adaptive_placement and cfg.family == "moe":
        n_groups = max(jax.device_count(), 2)
        while cfg.n_experts % n_groups:
            n_groups -= 1
        governor = ExpertPlacementGovernor(cfg.n_experts,
                                           n_groups=n_groups)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, dcfg, step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)

        if governor is not None and "expert_load" in metrics:
            phys_loads = np.asarray(metrics["expert_load"]).sum(axis=0)
            # Governor reasons about *logical* experts; loads arrive per
            # physical slot: logical e currently lives at cur_perm[e].
            logical_loads = phys_loads[cur_perm]
            new_placement = governor.observe(logical_loads)
            if new_placement is not None and step > start:
                # Deployment: physically relocate expert weights (+router
                # columns) — the expensive all-to-all the invariants gate.
                rel = relocation(cur_perm, new_placement.perm)

                def relocate(tree):
                    layers = dict(tree["layers"])
                    layers["moe"] = permute_expert_params(
                        tree["layers"]["moe"], rel)
                    return dict(tree, layers=layers)

                params = relocate(params)
                # Optimizer moments travel with their weights.
                opt_state = opt_state._replace(
                    m=relocate(opt_state.m), v=relocate(opt_state.v),
                    master=(relocate(opt_state.master)
                            if opt_state.master != () else ()))
                cur_perm = np.asarray(new_placement.perm)
                print(f"step {step}: expert re-placement deployed "
                      f"(replans={governor.replans})")

        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['ce']):.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time() - t0):.1f}s)")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save_async(step + 1, (params, opt_state))
    if ckpt:
        ckpt.wait()
        ckpt.save(args.steps, (params, opt_state))
    print("done")
    return params, opt_state


if __name__ == "__main__":
    main()
