"""Serving driver: batched prefill/decode with the adaptive scheduler.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --smoke \\
      --requests 24 --slots 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_smoke
from ..models.model import Model
from ..serving.engine import ServingEngine
from ..serving.scheduler import Request, Scheduler


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke(args.arch) if args.smoke else get_config(args.arch))
    model = Model(cfg, remat="none")
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServingEngine(cfg, params, batch_slots=args.slots,
                           cache_len=args.cache_len)
    classes = [16, 32, 64]
    sched = Scheduler(engine, classes)

    rng = np.random.default_rng(args.seed)
    for rid in range(args.requests):
        plen = int(rng.choice(classes, p=[0.6, 0.3, 0.1]))
        prompt = rng.integers(0, cfg.vocab, plen).astype(np.int32)
        sched.submit(Request(rid=rid, prompt=prompt,
                             max_new=args.max_new))

    t0 = time.time()
    ticks = 0
    while sched.pending or any(s is not None for s in sched.slots):
        sched.tick()
        ticks += 1
        if ticks > 10000:
            raise RuntimeError("scheduler did not drain")
    dt = time.time() - t0
    toks = sum(len(r.out) for r in sched.completed)
    print(f"served {len(sched.completed)} requests, {toks} tokens in "
          f"{dt:.1f}s ({toks/dt:.1f} tok/s); "
          f"batch replans={sched.planner.replans} "
          f"deployments={sched.planner.deployments}")
    return sched


if __name__ == "__main__":
    main()
