"""Production mesh construction (multi-pod dry-run spec).

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state.  The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before any
jax import* to obtain 512 placeholder devices; real deployments get the
same meshes from the TPU runtime.

Single pod:  (16, 16)       axes ("data", "model")      — 256 chips.
Multi-pod:   (2, 16, 16)    axes ("pod", "data", "model") — 512 chips.
"""

from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"production mesh needs {n} devices, found {len(devices)}; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (see launch/dryrun.py)")
    return jax.make_mesh(
        shape, axes, devices=devices[:n],
        axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = data * model
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    return jax.make_mesh(
        (data, model), ("data", "model"), devices=devices[:n],
        axis_types=(AxisType.Auto, AxisType.Auto))
