"""Assigned input shapes × architectures: abstract input specs for the
multi-pod dry-run (ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, no device allocation) and their logical sharding axes.

Shapes (per assignment):
  train_4k      seq 4,096   global_batch 256   -> train_step
  prefill_32k   seq 32,768  global_batch 32    -> prefill_step
  decode_32k    seq 32,768  global_batch 128   -> serve_step (1 new token,
                                                  KV/SSM state of seq_len)
  long_500k     seq 524,288 global_batch 1     -> serve_step; SSM/hybrid
                                                  only (sub-quadratic);
                                                  skipped + documented for
                                                  pure full-attention archs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ModelConfig
from ..models.model import Model


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped) — the 8 documented long_500k skips."""
    if shape == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is a pure full-attention architecture "
            "(skip documented in DESIGN.md §5.1 Architecture "
            "applicability)")
    return True, ""


def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _emb(shape, cfg: ModelConfig):
    return jax.ShapeDtypeStruct(shape, cfg.adtype)


def batch_specs(cfg: ModelConfig, spec: ShapeSpec,
                with_labels: bool) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """-> (ShapeDtypeStruct tree, logical-axes tree) for a batch dict."""
    B, S = spec.global_batch, spec.seq
    specs: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    if cfg.family == "vlm":
        specs["tokens"] = _tok((B, S))
        axes["tokens"] = ("batch", "seq")
        specs["patch_embeds"] = _emb((B, cfg.n_frontend_tokens,
                                      cfg.d_model), cfg)
        axes["patch_embeds"] = ("batch", "frontend", "act_embed")
    elif cfg.frontend_is_embedding:
        specs["embeds"] = _emb((B, S, cfg.d_model), cfg)
        axes["embeds"] = ("batch", "seq", "act_embed")
    else:
        specs["tokens"] = _tok((B, S))
        axes["tokens"] = ("batch", "seq")
    if with_labels:
        specs["labels"] = _tok((B, S))
        axes["labels"] = ("batch", "seq")
    return specs, axes


def cache_specs(cfg: ModelConfig, batch: int, length: int):
    """Abstract decode cache + logical axes (via eval_shape — no alloc)."""
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(batch, length))

    def kv_axes(c):
        from ..models.layers import KVCache
        return KVCache(
            k=("layers", "batch", "cache_seq", "kv_heads", None),
            v=("layers", "batch", "cache_seq", "kv_heads", None),
            pos=("layers", "batch", "cache_seq"),
        )

    def ssm_axes(c):
        from ..models.ssm import SSMState
        return SSMState(
            conv=("layers", "batch", None, "ssm_inner"),
            ssd=("layers", "batch", "ssm_heads", None, None),
        )

    from ..models.model import Cache
    axes = Cache(
        kv=kv_axes(cache.kv) if cache.kv != () else (),
        ssm=ssm_axes(cache.ssm) if cache.ssm != () else (),
        index=("batch",),
    )
    return cache, axes


def decode_input_specs(cfg: ModelConfig, spec: ShapeSpec):
    """-> ((cache, tokens) structs, (cache_axes, token_axes))."""
    B, S = spec.global_batch, spec.seq
    if cfg.family == "vlm":
        S += cfg.n_frontend_tokens  # cache also holds the image prefix
    cache, cache_axes = cache_specs(cfg, B, S)
    if cfg.frontend_is_embedding:
        tok = _emb((B, 1, cfg.d_model), cfg)
        tok_axes = ("batch", None, "act_embed")
    else:
        tok = _tok((B, 1))
        tok_axes = ("batch", None)
    return (cache, tok), (cache_axes, tok_axes)


def input_specs(cfg: ModelConfig, shape_name: str):
    """Public entry: abstract inputs for (arch × shape).

    train   -> (batch_structs, batch_axes)
    prefill -> (batch_structs, batch_axes)
    decode  -> ((cache, tokens), (cache_axes, token_axes))
    """
    spec = SHAPES[shape_name]
    ok, why = applicable(cfg, shape_name)
    if not ok:
        raise ValueError(why)
    if spec.kind == "train":
        return batch_specs(cfg, spec, with_labels=True)
    if spec.kind == "prefill":
        return batch_specs(cfg, spec, with_labels=False)
    return decode_input_specs(cfg, spec)
