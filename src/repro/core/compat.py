"""Deprecation plumbing for the pre-``repro.cep`` class ladder.

The eight-class public surface (``make_engine``/``MonitoredEngine``/
``FleetRunner``/``MonitoredFleetRunner``/``CEPFleetServingEngine``/
``MonitoredCEPFleetServingEngine``) is superseded by the ``repro.cep``
facade, where plan family, monitoring, and fleet size are configuration.
The ladder classes remain the implementation — the facade composes them —
but *direct* construction warns so downstream code migrates.

``legacy_ok()`` is how the facade (and tests that intentionally exercise
the shims) constructs ladder objects without surfacing the warning to the
end user.
"""

from __future__ import annotations

import contextlib
import warnings

_MSG = ("{name} is a legacy entry point; use the repro.cep facade instead: "
        "cep.open(pattern, partitions=K, plan='order'|'tree'|'auto', "
        "monitor=True|False, config=RuntimeConfig(...))")


def warn_legacy(name: str) -> None:
    """Emit the ladder deprecation warning, attributed to the caller's
    caller (the user code constructing the legacy object)."""
    warnings.warn(_MSG.format(name=name), DeprecationWarning, stacklevel=3)


@contextlib.contextmanager
def legacy_ok():
    """Suppress ladder deprecation warnings for internal construction."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        yield
