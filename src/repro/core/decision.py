"""Reoptimizing decision functions ``D`` (paper §2.3, §5).

Four policies, matching the experimental study:

* ``StaticPolicy``        — never re-optimize (the "static plan" baseline).
* ``UnconditionalPolicy`` — re-optimize every iteration (tree-NFA [36]).
* ``ThresholdPolicy``     — re-optimize when any monitored value deviates
                            from its value at the last re-optimization by at
                            least ``t`` (ZStream [42]); relative deviation.
* ``InvariantPolicy``     — the paper's contribution: verify the invariant
                            list (K-invariant §3.3, distance-d §3.4,
                            selection strategy §3.1/§3.5).

Each policy observes the replans through ``on_replan`` so it can rebase its
internal state (thresholds rebase the reference vector; invariants rebuild
the list from the fresh DCSs).

Control-plane flow at fleet scale: ``InvariantPolicy`` owns the *selection*
of invariants (host-side, once per replan) while the per-chunk
*verification* can run either on the host (``should_reoptimize`` /
``decide``) or on device — ``InvariantPolicy.compile()`` lowers the current
invariant set into ``LoweredInvariants`` tensors that the fused monitored
step (``engine.make_monitored_process``, vmapped by ``fleet.FleetEngine``)
evaluates inside the jitted data plane.  The host then consults only the
returned violation flags and replans flagged partitions, so per-chunk host
work scales with violations, not with fleet size.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from .invariants import (
    DCSList,
    DecidingCondition,
    InvariantSet,
    d_avg_estimate,
    select_invariants,
)
from .stats import Stat


class DecisionPolicy:
    """Interface: ``decide(stat) -> bool`` plus replan notifications."""

    name = "base"

    def decide(self, stat: Stat) -> bool:
        raise NotImplementedError

    def should_reoptimize(self, stat: Stat) -> bool:
        """Alias of ``decide`` mirroring the paper's reoptimizing-decision
        naming; the device-monitoring differential tests compare the fleet's
        violation flags against this."""
        return self.decide(stat)

    def on_replan(self, plan, dcs_list: DCSList, stat: Stat) -> None:
        """Called after every run of ``A`` (including the initial one)."""

    def cost_counter(self) -> int:
        """Number of elementary condition checks performed so far (for the
        overhead accounting in §5's Figures 6d-9d)."""
        return getattr(self, "_checks", 0)


class StaticPolicy(DecisionPolicy):
    name = "static"

    def decide(self, stat: Stat) -> bool:
        return False


class UnconditionalPolicy(DecisionPolicy):
    """Re-generate the plan for every observed statistics snapshot [36]."""

    name = "unconditional"

    def decide(self, stat: Stat) -> bool:
        return True


class ThresholdPolicy(DecisionPolicy):
    """Constant threshold ``t`` on relative deviation of any statistic [42]."""

    name = "threshold"

    def __init__(self, t: float):
        self.t = float(t)
        self._ref: Optional[np.ndarray] = None
        self._checks = 0

    def on_replan(self, plan, dcs_list: DCSList, stat: Stat) -> None:
        self._ref = stat.values().copy()

    def decide(self, stat: Stat) -> bool:
        if self._ref is None:
            self._ref = stat.values().copy()
            return False
        cur = stat.values()
        self._checks += cur.size
        denom = np.maximum(np.abs(self._ref), 1e-12)
        return bool(np.any(np.abs(cur - self._ref) / denom >= self.t))


class InvariantPolicy(DecisionPolicy):
    """The invariant-based method (§3) with K, d and selection knobs."""

    name = "invariant"

    def __init__(
        self,
        k: int = 1,
        d: float = 0.0,
        strategy: str = "tightest",
        d_mode: str = "fixed",  # "fixed" | "avg"  (§3.4 approach 2)
        violation_prob: Optional[
            Callable[[DecidingCondition, Stat], float]
        ] = None,
    ):
        self.k = int(k)
        self.d = float(d)
        self.strategy = strategy
        self.d_mode = d_mode
        self.violation_prob = violation_prob
        self._set: Optional[InvariantSet] = None
        self._checks = 0

    def on_replan(self, plan, dcs_list: DCSList, stat: Stat) -> None:
        d = self.d
        if self.d_mode == "avg":
            d = d_avg_estimate(dcs_list, stat)
            self.d_estimated = d
        invs = select_invariants(
            dcs_list, stat, k=self.k, strategy=self.strategy,
            violation_prob=self.violation_prob,
        )
        self._set = InvariantSet(invs, d=d)

    def decide(self, stat: Stat) -> bool:
        if self._set is None:
            return True  # never planned yet
        self._checks += len(self._set)
        return self._set.check(stat)

    def compile(self, n: int, max_inv: Optional[int] = None,
                max_terms: Optional[int] = None):
        """Lower the current invariant set to device tensors.

        Returns ``invariants.LoweredInvariants`` with static shape
        ``(max_inv, 2, max_terms, ...)`` suitable for stacking across a
        fleet (pass the fleet-wide caps so every partition's row matches).
        Must be called after ``on_replan`` has installed an invariant set.
        """
        if self._set is None:
            raise ValueError("compile() before the first on_replan(); the "
                             "policy has no invariant set yet")
        return self._set.lower(n, max_inv=max_inv, max_terms=max_terms)

    @property
    def invariant_set(self) -> Optional[InvariantSet]:
        return self._set


def make_policy(name: str, **kw) -> DecisionPolicy:
    """Factory used by benchmarks and the adaptive framework layer."""
    if name == "static":
        return StaticPolicy()
    if name == "unconditional":
        return UnconditionalPolicy()
    if name == "threshold":
        return ThresholdPolicy(t=kw.get("t", 0.5))
    if name == "invariant":
        return InvariantPolicy(
            k=kw.get("k", 1),
            d=kw.get("d", 0.0),
            strategy=kw.get("strategy", "tightest"),
            d_mode=kw.get("d_mode", "fixed"),
            violation_prob=kw.get("violation_prob"),
        )
    raise ValueError(f"unknown policy {name!r}")
