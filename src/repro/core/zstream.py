"""Instrumented ZStream dynamic-programming tree planner (paper §4.2, Alg. 3).

Interval DP in the style of matrix-chain ordering: ``best[i][j]`` holds the
cheapest tree over the ``i`` consecutive pattern positions starting at ``j``,
with

    Cost(T) = Cost(L) + Cost(R) + Card(L ∪ R),
    Card(T) = Card(L) · Card(R) · SEL(L, R) · order_factor,

where ``SEL(L, R)`` is the product of cross predicate selectivities and
``order_factor = |L|!·|R|!/|T|!`` accounts for the single valid temporal
interleaving of sequence patterns (1 for conjunctions).

Instrumentation (§3.1/§4.2): a building block is an internal node of the
final plan; the DCS of the node over interval ``I`` holds one deciding
condition per *alternative split* of ``I`` — ``cost(winning split) <
cost(alternative split)``.  Intervals of length 2 have a single split and
hence an empty DCS, mirroring the paper's "last block" case.

Deciding-condition representation — two modes:

* ``freeze="none"`` (default, beyond-paper accuracy): the ZStream cost has
  the closed form ``Cost(T) = Σ_nodes Card(node) + Σ leaves r·sel`` where
  every ``Card`` is a *product* of live statistics — so each condition
  side is an exact ``ExprSum`` of O(n) product terms and Theorem 1 holds
  for tree plans with the same rigor as for the greedy planner
  (empirically 0 false positives vs >25% under frozen constants at large
  drifts; see tests/test_invariants.py).  Verification is O(n) per
  invariant instead of O(1) — for n <= 8 this is nanoseconds either way.

* ``freeze="paper"`` — the paper's §4.2 subtree-cost-as-constant trick:
  subtrees with >= 3 leaves (which carry their own, earlier-verified
  invariants) enter conditions as constants frozen at plan-creation time;
  leaves and 2-leaf subtrees (whose DCS is empty) stay live.  O(1)
  verification, approximate under large drifts.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from .invariants import DCSList, DecidingCondition, ExprSum
from .patterns import Pattern
from .plans import Expr, TreeNode, TreePlan, cardinality_expr
from .stats import Stat


@dataclasses.dataclass
class _Cell:
    """One DP cell: best tree over an interval + its symbolic description."""

    tree: TreeNode
    cost: float
    card: float
    cost_sum: ExprSum      # symbolic cost (frozen/live mix, see module doc)
    card_expr: Expr        # symbolic cardinality (live for leaves)
    conds: List[DecidingCondition]


def _leaf_cell(pos: int, stat: Stat, has_self_pred: bool) -> _Cell:
    card = float(stat.rates[pos]) * float(stat.sel[pos, pos])
    sel_pairs = ((pos, pos),) if has_self_pred else ()
    e = Expr(rate_idx=(pos,), sel_pairs=sel_pairs)
    return _Cell(
        tree=TreeNode(leaf=pos), cost=card, card=card,
        cost_sum=(e,), card_expr=e, conds=[],
    )


def _freeze(cell: _Cell, mode: str) -> Tuple[ExprSum, Expr]:
    """Symbolic (cost, card) forms for a subtree, per the module docstring.

    In "paper" mode, leaves and 2-leaf subtrees stay LIVE even though the
    paper freezes all subtree costs: a 2-leaf node has an *empty* DCS
    (single possible split), so no earlier invariant would notice drift in
    its cost — freezing it would blind the parent.  Subtrees with >= 3
    leaves carry their own invariants (verified earlier in the bottom-up
    order), which is exactly the paper's justification for constants
    (§4.2).
    """
    if mode == "none":
        return cell.cost_sum, cell.card_expr
    if cell.tree.is_leaf or len(cell.tree.leaves()) == 2:
        return cell.cost_sum, cell.card_expr
    return (Expr(scale=cell.cost),), Expr(scale=cell.card)


def _cross_pairs(
    left: Tuple[int, ...], right: Tuple[int, ...], with_pred: frozenset
) -> Tuple[Tuple[int, int], ...]:
    out = []
    for a in left:
        for b in right:
            key = (min(a, b), max(a, b))
            if key in with_pred:
                out.append(key)
    return tuple(out)


def zstream_tree_plan(
    pattern: Pattern, stat: Stat, freeze: str = "none"
) -> Tuple[TreePlan, DCSList]:
    """Run Algorithm 3 and capture per-node deciding condition sets."""
    assert freeze in ("none", "paper"), freeze
    n = pattern.n
    is_seq = pattern.is_sequence
    op = pattern.pred_tensors()["op"]
    with_pred = frozenset(
        {(p, q) for p, q in pattern.selectivity_pairs()}
        | {(p, p) for p in range(n) if op[p, p] != 0}
    )

    # best[(start, length)] -> _Cell
    best: Dict[Tuple[int, int], _Cell] = {}
    for p in range(n):
        best[(p, 1)] = _leaf_cell(p, stat, (p, p) in with_pred)

    for length in range(2, n + 1):
        for start in range(0, n - length + 1):
            cand: List[Tuple[float, int, _Cell]] = []
            exprs: Dict[int, ExprSum] = {}
            for split in range(1, length):  # left length
                L = best[(start, split)]
                R = best[(start + split, length - split)]
                lleaves = L.tree.leaves()
                rleaves = R.tree.leaves()
                factor = (
                    math.factorial(split) * math.factorial(length - split)
                    / math.factorial(length)
                ) if is_seq else 1.0
                cross = _cross_pairs(lleaves, rleaves, with_pred)
                sel_cross = 1.0
                for i, j in cross:
                    sel_cross *= float(stat.sel[i, j])
                card = L.card * R.card * sel_cross * factor
                cost = L.cost + R.cost + card

                # Symbolic forms with the freezing convention.
                l_cost_sym, l_card_sym = _freeze(L, freeze)
                r_cost_sym, r_card_sym = _freeze(R, freeze)
                if freeze == "none":
                    # Exact node cardinality over the interval's leaves.
                    card_expr = cardinality_expr(
                        sorted(lleaves + rleaves), with_pred, is_seq)
                else:
                    card_expr = Expr(
                        rate_idx=l_card_sym.rate_idx + r_card_sym.rate_idx,
                        sel_pairs=l_card_sym.sel_pairs
                        + r_card_sym.sel_pairs + cross,
                        scale=l_card_sym.scale * r_card_sym.scale * factor,
                    )
                cost_sum: ExprSum = l_cost_sym + r_cost_sym + (card_expr,)
                exprs[split] = cost_sum
                cell = _Cell(
                    tree=TreeNode(left=L.tree, right=R.tree),
                    cost=cost, card=card, cost_sum=cost_sum,
                    card_expr=card_expr, conds=[],
                )
                cand.append((cost, split, cell))

            # Deterministic argmin (ties -> smaller split index).
            cand.sort(key=lambda c: (c[0], c[1]))
            w_cost, w_split, w_cell = cand[0]
            block = f"node:{start}..{start + length - 1}"
            w_cell.conds = [
                DecidingCondition.make(exprs[w_split], exprs[s], block)
                for _, s, _ in cand[1:]
            ]
            best[(start, length)] = w_cell

    root = best[(0, n)]
    plan = TreePlan(root.tree)

    # Collect DCSs for final-plan internal nodes, bottom-up (§3.2 order).
    dcs_list: DCSList = []

    def walk(node: TreeNode, start: int) -> int:
        """Post-order walk; returns interval length under ``node``."""
        if node.is_leaf:
            return 1
        llen = walk(node.left, start)
        rlen = walk(node.right, start + llen)
        length = llen + rlen
        cell = best[(start, length)]
        block = f"node:{start}..{start + length - 1}"
        dcs_list.append((block, cell.conds))
        return length

    walk(root.tree, 0)
    return plan, dcs_list
