"""Vectorized CEP evaluation engine (data plane) in JAX.

Classical CEP engines (lazy-NFA [36], ZStream [42]) are event-at-a-time
pointer-chasing state machines — the worst possible shape for a TPU.  This
module re-thinks the data structures for the TPU memory hierarchy while
preserving the paper's semantics and cost model:

* **Per-type ring buffers** hold the recent stream history (struct-of-arrays,
  fixed capacity, masked).
* **Match sets are dense masked tensors**: a set of (partial) matches is a
  ``(M_cap, n)`` timestamp/attribute block plus a validity mask and a
  position-membership vector.
* **Every plan step is one masked windowed cross-join** — a stack of ``C``
  constraint rows (validity, time window, sequence order, pairwise
  predicates) evaluated between ``M`` partial matches and ``B`` candidate
  events by the ``window_join`` kernel (Pallas on TPU, jnp oracle on CPU),
  followed by prefix-sum compaction.  The number of surviving pairs is
  exactly the partial-match count the paper's plans minimize, so plan
  quality maps 1:1 onto join work.

* **Plans are data, not code.**  An order-based plan enters as a length-``n``
  permutation vector; a tree-based plan as ``(n-1, 2)`` slot-join indices.
  One compiled executor therefore serves *every* plan of a given pattern —
  an adaptation (plan switch) never recompiles the data plane.  This is the
  TPU-native answer to the paper's requirement that plan deployment be cheap
  relative to detection (§2.2).

Chunked semantics: the engine consumes the stream in chunks ``(t0, t1]``.
Each chunk is ingested into the ring buffers, the full join cascade runs
over the in-window history, and a match is **counted exactly once** — in the
chunk where its latest event arrives (``max_ts ∈ (t0, t1]``).  This is the
sliding-window re-evaluation formulation: it preserves SASE detection
semantics while keeping every tensor shape static.

Operator support beyond SEQ/AND (§2.1, via the paper's transformation-rule
approach): negation is a post-join anti-filter against the negated type's
buffer; Kleene closure is a bounded companion count per base match
(count-only semantics — see DESIGN.md); OR-composites are evaluated as
independent branches by the adaptation layer.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .patterns import PRED_GT, PRED_LT, PRED_NONE, Pattern
from .plans import OrderPlan, TreeNode, TreePlan

_LT = PRED_LT
_GT = PRED_GT
_NONE = PRED_NONE

# Born-window sentinels shared by every stepping contract (f32-safe ±inf).
# The pure step signature is ``process_fn(buffers, chunk, plan, t0, t1,
# born_lo, born_hi) -> (buffers, StepResult)`` — state first, outputs
# second — which is what lets one function serve jit (single stream),
# jit(vmap) (fleet), lax.scan (superchunk) and shard_map (multi-device)
# without adaptation shims; see ``core/scan.py``.
NEG_INF = -3.0e38
POS_INF = 3.0e38


class Chunk(NamedTuple):
    """One stream chunk (struct-of-arrays)."""

    type_id: jax.Array  # (N,) i32 global event-type ids
    ts: jax.Array       # (N,) f32 timestamps (non-decreasing)
    attr: jax.Array     # (N, A) f32 attributes
    valid: jax.Array    # (N,) bool


class Buffers(NamedTuple):
    """Per-position ring buffers (+ one extra row for a negated type)."""

    ts: jax.Array      # (T, B) f32
    attr: jax.Array    # (T, B, A) f32
    valid: jax.Array   # (T, B) bool
    ptr: jax.Array     # (T,) i32 cumulative writes


class MatchSet(NamedTuple):
    """A dense masked set of (partial) matches."""

    ts: jax.Array       # (M, n) f32 per-position timestamps
    attr: jax.Array     # (M, n, A) f32 per-position attributes
    min_ts: jax.Array   # (M,) f32
    max_ts: jax.Array   # (M,) f32
    valid: jax.Array    # (M,) bool
    member: jax.Array   # (n,) bool — positions filled in this set


class StepResult(NamedTuple):
    full_matches: jax.Array        # i32 — completed this chunk
    pm_created: jax.Array          # i32 — total partial matches materialized
    overflow: jax.Array            # i32 — candidates dropped by capacity
    closure_expansions: jax.Array  # i32 — Kleene companion count
    neg_rejected: jax.Array        # i32 — matches vetoed by negation


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    b_cap: int = 128   # ring-buffer capacity per event type
    m_cap: int = 256   # match-set row capacity (>= b_cap)
    backend: Optional[str] = None  # kernel backend override

    def __post_init__(self):
        if self.m_cap < self.b_cap:
            raise ValueError("m_cap must be >= b_cap")


# ---------------------------------------------------------------------------
# Shared join machinery
# ---------------------------------------------------------------------------


def _rows_to_stacks(rows, m, b):
    """rows: list of (lvals (M,), rvals (B,), op scalar, theta scalar)."""
    L = jnp.stack([jnp.broadcast_to(r[0], (m,)).astype(jnp.float32)
                   for r in rows])
    R = jnp.stack([jnp.broadcast_to(r[1], (b,)).astype(jnp.float32)
                   for r in rows])
    ops_ = jnp.stack([jnp.asarray(r[2], jnp.int32) for r in rows])
    ths = jnp.stack([jnp.asarray(r[3], jnp.float32) for r in rows])
    return L, R, ops_, ths


def _validity_rows(l_valid, r_valid, m, b):
    return [
        (l_valid.astype(jnp.float32), jnp.ones((b,), jnp.float32), _GT, 0.5),
        (jnp.ones((m,), jnp.float32), r_valid.astype(jnp.float32), _LT, 0.5),
    ]


def _window_rows(l_min, l_max, r_min, r_max, window):
    # span(L ∪ R) <= W  ⇔  maxL < minR + W  ∧  minL > maxR − W.
    return [
        (l_max, r_min, _LT, float(window)),
        (l_min, r_max, _GT, float(window)),
    ]


def _pred_rows(spec, L: MatchSet, R: MatchSet):
    """Two orientation rows per static predicate pair, masked by membership."""
    rows = []
    for (p, q) in spec.pred_pairs:
        for (a, b_) in ((p, q), (q, p)):
            active = L.member[a] & R.member[b_]
            op = jnp.where(active, spec.op_t[a, b_], _NONE)
            lv = L.attr[:, a, spec.a_attr_t[a, b_]]
            rv = R.attr[:, b_, spec.b_attr_t[a, b_]]
            rows.append((lv, rv, op, spec.theta_t[a, b_]))
    return rows


def _compact(L: MatchSet, R: MatchSet, ok, pm_created, out_cap: int):
    """Prefix-sum compaction of the surviving (m, b) pairs into a MatchSet."""
    m = L.valid.shape[0]
    b = R.valid.shape[0]
    flat = ok.reshape(-1)
    idx = jnp.nonzero(flat, size=out_cap, fill_value=m * b)[0]
    new_valid = jnp.take(flat, idx, mode="fill", fill_value=False)
    mi = jnp.clip(idx // b, 0, m - 1)
    bi = jnp.clip(idx % b, 0, b - 1)

    memL = L.member[None, :]
    ts = jnp.where(memL, L.ts[mi], R.ts[bi])
    attr = jnp.where(memL[:, :, None], L.attr[mi], R.attr[bi])
    out = MatchSet(
        ts=ts,
        attr=attr,
        min_ts=jnp.minimum(L.min_ts[mi], R.min_ts[bi]),
        max_ts=jnp.maximum(L.max_ts[mi], R.max_ts[bi]),
        valid=new_valid,
        member=L.member | R.member,
    )
    overflow = jnp.maximum(0, pm_created - out_cap).astype(jnp.int32)
    return out, pm_created, overflow


def _join(spec, cfg, L: MatchSet, R: MatchSet, order_rows, out_cap: int):
    """One plan step: constraint cross-join + compaction."""
    m = L.valid.shape[0]
    b = R.valid.shape[0]
    rows = (
        _validity_rows(L.valid, R.valid, m, b)
        + _window_rows(L.min_ts, L.max_ts, R.min_ts, R.max_ts, spec.window)
        + order_rows
        + _pred_rows(spec, L, R)
    )
    Ls, Rs, ops_, ths = _rows_to_stacks(rows, m, b)
    ok = kops.window_join(Ls, Rs, ops_, ths, backend=cfg.backend)
    pm_created = ok.sum().astype(jnp.int32)
    return _compact(L, R, ok, pm_created, out_cap)


def _row_counts(cfg, rows, m, b):
    """Per-m 'compatible event' counts (negation veto / Kleene count).

    Routed through the fused rowcount kernel, which reduces each tile in
    VMEM instead of materializing the (m, b) mask to HBM."""
    Ls, Rs, ops_, ths = _rows_to_stacks(rows, m, b)
    return kops.window_join_rowcount(Ls, Rs, ops_, ths,
                                     backend=cfg.backend)


# ---------------------------------------------------------------------------
# Spec: static pattern-derived data shared by both engines
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _Spec:
    n: int
    n_attrs: int
    window: float
    is_seq: bool
    pred_pairs: Tuple[Tuple[int, int], ...]
    op_t: np.ndarray
    a_attr_t: np.ndarray
    b_attr_t: np.ndarray
    theta_t: np.ndarray
    kleene_pos: Optional[int]
    kleene_bound: Optional[int]
    has_neg: bool
    negated_pos: Optional[int]
    # negated-predicate rows: (match_pos, op, match_attr, neg_attr, theta)
    neg_rows: Tuple[Tuple[int, int, int, int, float], ...]
    type_ids: Tuple[int, ...]
    negated_type: Optional[int]


def make_spec(pattern: Pattern) -> _Spec:
    t = pattern.pred_tensors()
    mirror = {PRED_NONE: PRED_NONE, PRED_LT: PRED_GT, PRED_GT: PRED_LT, 3: 3}
    neg_rows = []
    if pattern.negated_type is not None:
        pos_of = {tid: p for p, tid in enumerate(pattern.type_ids)}
        for pr in pattern.negated_predicates:
            if pr.a_type == pattern.negated_type:
                # cmp(neg, match) -> mirror so the match side is L.
                neg_rows.append((pos_of[pr.b_type], mirror[pr.op],
                                 pr.b_attr, pr.a_attr, pr.theta))
            else:
                neg_rows.append((pos_of[pr.a_type], pr.op,
                                 pr.a_attr, pr.b_attr, pr.theta))
    return _Spec(
        n=pattern.n,
        n_attrs=pattern.n_attrs,
        window=pattern.window,
        is_seq=pattern.is_sequence,
        pred_pairs=pattern.selectivity_pairs(),
        op_t=t["op"],
        a_attr_t=t["a_attr"],
        b_attr_t=t["b_attr"],
        theta_t=t["theta"],
        kleene_pos=pattern.kleene_pos,
        kleene_bound=pattern.kleene_bound,
        has_neg=pattern.negated_type is not None,
        negated_pos=pattern.negated_pos,
        neg_rows=tuple(neg_rows),
        type_ids=pattern.type_ids,
        negated_type=pattern.negated_type,
    )


# ---------------------------------------------------------------------------
# Buffers
# ---------------------------------------------------------------------------


def init_buffers(spec: _Spec, cfg: EngineConfig) -> Buffers:
    t = spec.n + (1 if spec.has_neg else 0)
    b, a = cfg.b_cap, spec.n_attrs
    return Buffers(
        ts=jnp.zeros((t, b), jnp.float32),
        attr=jnp.zeros((t, b, a), jnp.float32),
        valid=jnp.zeros((t, b), bool),
        ptr=jnp.zeros((t,), jnp.int32),
    )


def _ingest(spec: _Spec, cfg: EngineConfig, buffers: Buffers,
            chunk: Chunk) -> Buffers:
    """Route chunk events into their per-type ring buffers."""
    bcap = cfg.b_cap
    gids = list(spec.type_ids)
    if spec.has_neg:
        gids.append(spec.negated_type)
    ts, attr, valid, ptr = buffers
    for row, gid in enumerate(gids):  # static loop, n+1 rows max
        mask = (chunk.type_id == gid) & chunk.valid
        k = jnp.cumsum(mask.astype(jnp.int32)) - 1
        slot = jnp.where(mask, (ptr[row] + k) % bcap, bcap)  # bcap -> drop
        ts = ts.at[row, slot].set(chunk.ts, mode="drop")
        attr = attr.at[row, slot].set(chunk.attr, mode="drop")
        valid = valid.at[row, slot].set(True, mode="drop")
        ptr = ptr.at[row].add(mask.sum().astype(jnp.int32))
    return Buffers(ts, attr, valid, ptr)


def _leaf(spec: _Spec, cfg: EngineConfig, buffers: Buffers, row, pos,
          t0, out_rows: int) -> MatchSet:
    """View one buffer row as a single-position match set (padded).

    Eviction threshold is ``t0 - W``: a match completed in (t0, t1] may
    reference events up to one window older than the chunk start.
    """
    n, a, b = spec.n, spec.n_attrs, cfg.b_cap
    ts_b = buffers.ts[row]                       # (B,)
    attr_b = buffers.attr[row]                   # (B, A)
    valid = buffers.valid[row] & (ts_b > t0 - spec.window)
    onehot = (jnp.arange(n) == pos)              # (n,) bool
    ts = jnp.where(onehot[None, :], ts_b[:, None], 0.0)
    attr = jnp.where(onehot[None, :, None], attr_b[:, None, :], 0.0)
    ms = MatchSet(ts, attr, ts_b, ts_b, valid, onehot)
    if out_rows != b:
        pad = out_rows - b
        ms = MatchSet(
            ts=jnp.pad(ms.ts, ((0, pad), (0, 0))),
            attr=jnp.pad(ms.attr, ((0, pad), (0, 0), (0, 0))),
            min_ts=jnp.pad(ms.min_ts, (0, pad)),
            max_ts=jnp.pad(ms.max_ts, (0, pad)),
            valid=jnp.pad(ms.valid, (0, pad)),
            member=ms.member,
        )
    return ms


# ---------------------------------------------------------------------------
# Post-processing: completion filter, negation, Kleene
# ---------------------------------------------------------------------------


def _finalize(spec: _Spec, cfg: EngineConfig, buffers: Buffers,
              pm: MatchSet, t0, t1, born_lo,
              born_hi) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Count full matches completed in (t0, t1]; apply negation and Kleene.

    ``born_lo <= min_ts < born_hi`` implements the [36] plan-migration
    split: during a migration window the old plan is responsible for
    matches containing at least one pre-replan event (min_ts < t_replan)
    and the new plan for matches born entirely after it — disjoint sets,
    so no match is detected twice (§2.2).
    """
    n = spec.n
    m = pm.valid.shape[0]
    b = cfg.b_cap
    completed = (pm.valid & (pm.max_ts > t0) & (pm.max_ts <= t1)
                 & (pm.min_ts >= born_lo) & (pm.min_ts < born_hi))
    neg_rejected = jnp.int32(0)

    if spec.has_neg:
        row = n  # negated buffer row
        nts = buffers.ts[row]
        nvalid = buffers.valid[row] & (nts > t0 - spec.window)
        rows = _validity_rows(completed, nvalid, m, b)
        rows += _window_rows(pm.min_ts, pm.max_ts, nts, nts, spec.window)
        np_ = spec.negated_pos
        if np_ is not None and np_ > 0:
            rows.append((pm.ts[:, np_ - 1], nts, _LT, 0.0))
        if np_ is not None and np_ < n:
            rows.append((pm.ts[:, np_], nts, _GT, 0.0))
        for (pos, op, ma, na, th) in spec.neg_rows:
            rows.append((pm.attr[:, pos, ma], buffers.attr[row][:, na],
                         op, th))
        cnt = _row_counts(cfg, rows, m, b)
        veto = cnt > 0
        neg_rejected = (completed & veto).sum().astype(jnp.int32)
        completed = completed & ~veto

    closure = jnp.int32(0)
    if spec.kleene_pos is not None:
        kp = spec.kleene_pos
        kts = buffers.ts[kp]
        kvalid = buffers.valid[kp] & (kts > t0 - spec.window)
        rows = _validity_rows(completed, kvalid, m, b)
        rows += _window_rows(pm.min_ts, pm.max_ts, kts, kts, spec.window)
        if spec.is_seq and kp > 0:
            rows.append((pm.ts[:, kp - 1], kts, _LT, 0.0))
        if spec.is_seq and kp < n - 1:
            rows.append((pm.ts[:, kp + 1], kts, _GT, 0.0))
        for (p, q) in spec.pred_pairs:
            if q == kp:
                rows.append((pm.attr[:, p, spec.a_attr_t[p, kp]],
                             buffers.attr[kp][:, spec.b_attr_t[p, kp]],
                             spec.op_t[p, kp], spec.theta_t[p, kp]))
            elif p == kp:
                rows.append((pm.attr[:, q, spec.a_attr_t[q, kp]],
                             buffers.attr[kp][:, spec.b_attr_t[q, kp]],
                             spec.op_t[q, kp], spec.theta_t[q, kp]))
        cnt = _row_counts(cfg, rows, m, b)
        comp = jnp.maximum(cnt - 1, 0)  # exclude the match's own
        if spec.kleene_bound is not None:
            comp = jnp.minimum(comp, spec.kleene_bound)
        closure = jnp.where(completed, comp, 0).sum().astype(jnp.int32)

    return completed.sum().astype(jnp.int32), neg_rejected, closure


# ---------------------------------------------------------------------------
# Predicate strips: the plan-constant half of the join operands
# ---------------------------------------------------------------------------
#
# The constraint stack fed to the kernel at plan step ``i`` splits into two
# halves with very different lifetimes:
#
# * **stream-dependent values** (timestamps, attributes, validity) — these
#   change every chunk and are pure gathers from the ring buffers / match
#   set;
# * **plan-dependent structure** (which op applies per row, and which
#   already-placed position anchors the sequence-order rows) — a function
#   of the order vector alone, constant for as long as the plan is
#   deployed.
#
# ``PredicateStrips`` captures the second half.  The per-chunk step used to
# rebuild it inside every trace; precomputing it once per deployed plan
# (``OrderEngine.plan_operands``) and carrying it through the superchunk
# scan turns the per-chunk work into gather + kernel.  Thresholds and the
# attribute gather columns are static pattern data and are baked into the
# compiled step directly (``_packed_thetas`` / ``_pred_cols``).


class PredicateStrips(NamedTuple):
    """Plan-constant packed join operands for an order plan (n-1 steps)."""

    ops8: jax.Array    # (n-1, C) i8 — per-step op-code strip
    lo_idx: jax.Array  # (n-1,) i32 — clipped lower order-anchor position
    hi_idx: jax.Array  # (n-1,) i32 — clipped upper order-anchor position


class PlanOperands(NamedTuple):
    """An order row together with its precomputed strips.

    The engine's ``process`` accepts either the raw row (strips are then
    derived in-trace — the per-chunk path) or this pair (the scanned path,
    where the derivation runs once per superchunk dispatch).  Both are
    pytrees, so the same vmapped/scanned executor serves both.
    """

    row: jax.Array           # (n,) i32 order vector
    strips: PredicateStrips


def packed_row_count(spec: _Spec) -> int:
    """Rows in the packed constraint stack (validity lives in the masks)."""
    return 2 + (2 if spec.is_seq else 0) + 2 * len(spec.pred_pairs)


def _packed_thetas(spec: _Spec) -> jnp.ndarray:
    """Static per-row thresholds matching the packed row layout."""
    ths = [float(spec.window), float(spec.window)]
    if spec.is_seq:
        ths += [0.0, 0.0]
    for (p, q) in spec.pred_pairs:
        for (a, b_) in ((p, q), (q, p)):
            ths.append(float(spec.theta_t[a, b_]))
    return jnp.asarray(ths, jnp.float32)


def _pred_cols(spec: _Spec):
    """Static (a, b, a_attr_col, b_attr_col) per packed predicate row."""
    cols = []
    for (p, q) in spec.pred_pairs:
        for (a, b_) in ((p, q), (q, p)):
            cols.append((a, b_, int(spec.a_attr_t[a, b_]),
                         int(spec.b_attr_t[a, b_])))
    return tuple(cols)


def build_order_strips(spec: _Spec, order) -> PredicateStrips:
    """Derive the plan-constant strips from an order vector.

    Step ``i`` joins the accumulated prefix {order[0..i-1]} with the leaf
    of position ``order[i]``; row activation therefore depends only on the
    order vector: a predicate row (a, b) fires iff ``a`` is already placed
    and ``b == order[i]``, and the sequence-order rows anchor on the
    nearest placed position below/above ``order[i]``.  O(n^2) scalar work
    — negligible once per plan, pure waste once per chunk.
    """
    n = spec.n
    C = packed_row_count(spec)
    if n <= 1:
        return PredicateStrips(
            ops8=jnp.zeros((0, C), jnp.int8),
            lo_idx=jnp.zeros((0,), jnp.int32),
            hi_idx=jnp.zeros((0,), jnp.int32))
    order = jnp.asarray(order, jnp.int32)
    pos = jnp.arange(n)
    member = (pos == order[0])
    ops_steps, lo_steps, hi_steps = [], [], []
    for i in range(1, n):
        q = order[i]
        row_ops = [jnp.asarray(_LT, jnp.int8), jnp.asarray(_GT, jnp.int8)]
        lo = jnp.int32(0)
        hi = jnp.int32(0)
        if spec.is_seq:
            lo_cand = jnp.where(member & (pos < q), pos, -1)
            p_lo = lo_cand.max()
            hi_cand = jnp.where(member & (pos > q), pos, n)
            p_hi = hi_cand.min()
            row_ops.append(
                jnp.where(p_lo >= 0, _LT, _NONE).astype(jnp.int8))
            row_ops.append(
                jnp.where(p_hi < n, _GT, _NONE).astype(jnp.int8))
            lo = jnp.clip(p_lo, 0, n - 1).astype(jnp.int32)
            hi = jnp.clip(p_hi, 0, n - 1).astype(jnp.int32)
        for (a, b_, _ac, _bc) in _pred_cols(spec):
            active = member[a] & (q == b_)
            row_ops.append(jnp.where(
                active, jnp.int8(spec.op_t[a, b_]), jnp.int8(_NONE)))
        ops_steps.append(jnp.stack(row_ops))
        lo_steps.append(lo)
        hi_steps.append(hi)
        member = member | (pos == q)
    return PredicateStrips(
        ops8=jnp.stack(ops_steps),
        lo_idx=jnp.stack(lo_steps),
        hi_idx=jnp.stack(hi_steps))


# ---------------------------------------------------------------------------
# Order-based engine (lazy-NFA style)
# ---------------------------------------------------------------------------


class OrderEngine:
    """Executes order-based plans; the order vector is a dynamic argument."""

    def __init__(self, pattern: Pattern, cfg: EngineConfig = EngineConfig()):
        self.pattern = pattern
        self.spec = make_spec(pattern)
        self.cfg = cfg
        # The raw (un-jitted) pure function is kept for vmapping: the fleet
        # executor batches K partitions through one compiled vmap of it.
        self.process_fn = self._make_process()
        self._process = jax.jit(self.process_fn)

    def init_state(self) -> Buffers:
        return init_buffers(self.spec, self.cfg)

    def plan_operands(self, rows) -> PlanOperands:
        """Precompute the strips for one (n,) or a stacked (K, n) row set.

        Used by the superchunk scan to hoist the strip derivation out of
        the per-chunk body — it runs once per scanned dispatch instead of
        once per chunk.  Traceable (rows may be device arrays).
        """
        spec = self.spec
        rows = jnp.asarray(rows, jnp.int32)
        if rows.ndim == 1:
            return PlanOperands(rows, build_order_strips(spec, rows))
        return jax.vmap(
            lambda r: PlanOperands(r, build_order_strips(spec, r)))(rows)

    def _make_process(self):
        spec, cfg = self.spec, self.cfg
        n = spec.n
        ths_const = _packed_thetas(spec)
        pred_cols = _pred_cols(spec)

        def packed_step(buffers, pm, q, sops, lo, hi, t0):
            """gather + packed kernel + compaction — one plan step."""
            R = _leaf(spec, cfg, buffers, q, q, t0, cfg.b_cap)
            attr_b = buffers.attr[q]
            Lr = [pm.max_ts, pm.min_ts]
            Rr = [R.min_ts, R.max_ts]
            if spec.is_seq:
                Lr += [pm.ts[:, lo], pm.ts[:, hi]]
                Rr += [R.min_ts, R.min_ts]
            for (a, _b, ac, bc) in pred_cols:
                Lr.append(pm.attr[:, a, ac])
                Rr.append(attr_b[:, bc])
            Ls = jnp.stack([x.astype(jnp.float32) for x in Lr])
            Rs = jnp.stack([x.astype(jnp.float32) for x in Rr])
            ok = kops.window_join_packed(Ls, Rs, sops, ths_const,
                                         pm.valid, R.valid,
                                         backend=cfg.backend)
            created = ok.sum().astype(jnp.int32)
            return _compact(pm, R, ok, created, cfg.m_cap)

        def process(buffers: Buffers, chunk: Chunk, plan, t0, t1,
                    born_lo, born_hi):
            if isinstance(plan, PlanOperands):
                order, strips = plan.row, plan.strips
            else:
                order = plan
                strips = build_order_strips(spec, order)
            buffers = _ingest(spec, cfg, buffers, chunk)
            pm = _leaf(spec, cfg, buffers, order[0], order[0], t0, cfg.m_cap)
            pm_total = pm.valid.sum().astype(jnp.int32)
            overflow = jnp.int32(0)
            for i in range(1, n):  # static loop over plan steps
                pm, created, ov = packed_step(
                    buffers, pm, order[i], strips.ops8[i - 1],
                    strips.lo_idx[i - 1], strips.hi_idx[i - 1], t0)
                pm_total = pm_total + created
                overflow = overflow + ov
            full, neg_rej, closure = _finalize(
                spec, cfg, buffers, pm, t0, t1, born_lo, born_hi)
            return buffers, StepResult(full, pm_total, overflow, closure,
                                       neg_rej)

        return process

    def process_chunk(self, buffers: Buffers, chunk: Chunk, plan: OrderPlan,
                      t0: float, t1: float,
                      born_lo: float = -3.0e38, born_hi: float = 3.0e38):
        order = jnp.asarray(plan.order, jnp.int32)
        return self._process(buffers, chunk, order,
                             jnp.float32(t0), jnp.float32(t1),
                             jnp.float32(born_lo), jnp.float32(born_hi))


# ---------------------------------------------------------------------------
# Tree-based engine (ZStream style)
# ---------------------------------------------------------------------------


def tree_plan_to_slots(plan: TreePlan) -> np.ndarray:
    """Convert a TreePlan into an (n-1, 2) slot-join program.

    Slots 0..n-1 are the leaves (pattern positions); slot n+s is the result
    of join step s.  The interval DP guarantees every node's left child
    covers the earlier contiguous interval, which the tree engine's single
    cross-order constraint relies on for sequence patterns.
    """
    n = plan.n
    slot_of = {}
    steps = []

    def walk(node: TreeNode) -> int:
        if node.is_leaf:
            return node.leaf
        li = walk(node.left)
        ri = walk(node.right)
        # Contiguity + ordering sanity (host-side).
        ll, rl = node.left.leaves(), node.right.leaves()
        leaves = sorted(ll + rl)
        assert leaves == list(range(leaves[0], leaves[-1] + 1)), (
            "tree engine requires contiguous-interval plans")
        assert max(ll) < min(rl), "left child must cover earlier interval"
        sid = n + len(steps)
        steps.append((li, ri))
        return sid

    walk(plan.root)
    return np.asarray(steps, np.int32)


class TreeEngine:
    """Executes tree-based plans; the slot program is a dynamic argument."""

    def __init__(self, pattern: Pattern, cfg: EngineConfig = EngineConfig()):
        self.pattern = pattern
        self.spec = make_spec(pattern)
        self.cfg = cfg
        self.process_fn = self._make_process()
        self._process = jax.jit(self.process_fn)

    def init_state(self) -> Buffers:
        return init_buffers(self.spec, self.cfg)

    def _make_process(self):
        spec, cfg = self.spec, self.cfg
        n = spec.n
        m = cfg.m_cap

        def process(buffers: Buffers, chunk: Chunk, steps, t0, t1,
                    born_lo, born_hi):
            buffers = _ingest(spec, cfg, buffers, chunk)
            # Stacked slots: leaves first, then one per join step.
            leaves = [
                _leaf(spec, cfg, buffers, p, p, t0, m) for p in range(n)
            ]
            empty = MatchSet(
                ts=jnp.zeros((m, n), jnp.float32),
                attr=jnp.zeros((m, n, spec.n_attrs), jnp.float32),
                min_ts=jnp.zeros((m,), jnp.float32),
                max_ts=jnp.zeros((m,), jnp.float32),
                valid=jnp.zeros((m,), bool),
                member=jnp.zeros((n,), bool),
            )
            slots = jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *(leaves + [empty] * (n - 1)),
            )
            # Leaf cardinalities count as materialized state (ZStream cost).
            pm_total = sum(
                l.valid.sum() for l in leaves).astype(jnp.int32)
            overflow = jnp.int32(0)
            pm = leaves[0]
            for s in range(n - 1):  # static loop; slot gathers are dynamic
                L = jax.tree.map(lambda x: x[steps[s, 0]], slots)
                R = jax.tree.map(lambda x: x[steps[s, 1]], slots)
                rows = []
                if spec.is_seq:
                    rows.append((L.max_ts, R.min_ts, _LT, 0.0))
                pm, created, ov = _join(spec, cfg, L, R, rows, m)
                pm_total = pm_total + created
                overflow = overflow + ov
                slots = jax.tree.map(
                    lambda full, new: full.at[n + s].set(new), slots, pm)
            full, neg_rej, closure = _finalize(
                spec, cfg, buffers, pm, t0, t1, born_lo, born_hi)
            return buffers, StepResult(full, pm_total, overflow, closure,
                                       neg_rej)

        return process

    def process_chunk(self, buffers: Buffers, chunk: Chunk, plan: TreePlan,
                      t0: float, t1: float,
                      born_lo: float = -3.0e38, born_hi: float = 3.0e38):
        steps = jnp.asarray(tree_plan_to_slots(plan), jnp.int32)
        return self._process(buffers, chunk, steps,
                             jnp.float32(t0), jnp.float32(t1),
                             jnp.float32(born_lo), jnp.float32(born_hi))


def _make_engine(kind: str, pattern: Pattern,
                 cfg: EngineConfig = EngineConfig()):
    if kind == "order":
        return OrderEngine(pattern, cfg)
    if kind == "tree":
        return TreeEngine(pattern, cfg)
    raise ValueError(f"unknown engine kind {kind!r}")


def make_engine(kind: str, pattern: Pattern,
                cfg: EngineConfig = EngineConfig()):
    """Deprecated: the ``repro.cep`` facade selects the plan family via
    ``cep.open(..., plan="order"|"tree"|"auto")``."""
    from .compat import warn_legacy

    warn_legacy("make_engine")
    return _make_engine(kind, pattern, cfg)


# ---------------------------------------------------------------------------
# Device-resident monitoring: process + statistics + invariants in one step
# ---------------------------------------------------------------------------


def make_monitored_process(process_fn, spec: _Spec, laplace: float = 1.0):
    """Fuse a plan-execution step with invariant monitoring (paper §3.3-§3.5).

    The returned pure function runs, inside ONE traced program:

    1. the join cascade (``process_fn`` — the plan is still data);
    2. the per-chunk statistics observation (``stats.chunk_observations``)
       and the sliding-window ring update (``stats.monitor_update``);
    3. the lowered deciding-condition evaluation
       (``invariants.eval_lowered``) over the fresh snapshot.

    It returns ``(buffers, monitor, StepResult, violated, drift, rates,
    sel)``.  Only ``violated`` (one bool) and ``drift`` (one f32) need to
    reach the host each chunk; ``rates``/``sel`` stay device-resident and
    are pulled **only** when the flag fired — this is the paper's
    low-overhead-monitoring claim realized in the data plane.  Vmapping
    over a leading partition axis gives the fleet variant.
    """
    from .invariants import eval_lowered
    from .stats import chunk_observations, monitor_snapshot, monitor_update

    def mprocess(buffers, monitor, chunk, plan, lowered, t0, t1,
                 born_lo, born_hi):
        buffers, res = process_fn(buffers, chunk, plan, t0, t1,
                                  born_lo, born_hi)
        counts, trials, hits = chunk_observations(
            chunk.type_id, chunk.attr, chunk.valid, spec.type_ids,
            {"op": spec.op_t, "a_attr": spec.a_attr_t,
             "b_attr": spec.b_attr_t, "theta": spec.theta_t})
        monitor = monitor_update(monitor, counts, t1 - t0, trials, hits)
        rates, sel = monitor_snapshot(monitor, laplace)
        violated, drift = eval_lowered(lowered, rates, sel)
        return buffers, monitor, res, violated, drift, rates, sel

    return mprocess


class MonitoredEngine:
    """Single-stream engine with the monitored step compiled in.

    The fleet executor (`fleet.FleetEngine`) vmaps the same fused step; this
    wrapper is the K = 1 building block used by examples and tests.  Plans
    enter as rows (``plan_row``) and invariant sets as ``LoweredInvariants``
    tensors, so neither a replan nor an invariant redeployment recompiles.
    """

    def __init__(self, kind: str, pattern: Pattern,
                 cfg: EngineConfig = EngineConfig(),
                 monitor_buckets: int = 16, laplace: float = 1.0):
        from .compat import warn_legacy

        warn_legacy("MonitoredEngine")
        self.base = _make_engine(kind, pattern, cfg)
        self.kind = kind
        self.pattern = pattern
        self.cfg = cfg
        self.monitor_buckets = monitor_buckets
        self._step = jax.jit(make_monitored_process(
            self.base.process_fn, self.base.spec, laplace))

    def init_state(self) -> Buffers:
        return self.base.init_state()

    def init_monitor(self):
        from .stats import monitor_init

        return monitor_init(self.pattern.n, self.monitor_buckets)

    def plan_row(self, plan) -> np.ndarray:
        if self.kind == "order":
            return np.asarray(plan.order, np.int32)
        return tree_plan_to_slots(plan)

    def process_chunk(self, buffers, monitor, chunk, plan_row, lowered,
                      t0: float, t1: float,
                      born_lo: float = -3.0e38, born_hi: float = 3.0e38):
        lowered = jax.tree.map(jnp.asarray, lowered)
        return self._step(buffers, monitor, chunk,
                          jnp.asarray(plan_row), lowered,
                          jnp.float32(t0), jnp.float32(t1),
                          jnp.float32(born_lo), jnp.float32(born_hi))
