"""Pattern specification for the adaptive CEP engine.

A pattern (paper §2.1) combines primitive event types, operators
(SEQ / AND / OR / negation / Kleene closure), a Boolean formula of pairwise
predicates, and a time window.

To keep the data plane JAX-compilable with static shapes, predicates are
*structural tensors* rather than callables: for every ordered pair of event
types ``(i, j)`` we store an op-code, the attribute indices compared on each
side, and a threshold.  One compiled executor therefore serves any pattern of
a given size; changing the pattern (or the evaluation plan) never recompiles
the data plane.

Supported predicate op-codes (evaluated as ``cmp(a_attr, b_attr)``):

====  =============================================
code  semantics
====  =============================================
0     no predicate (always true, selectivity 1.0)
1     ``a < b + theta``
2     ``a > b - theta``
3     ``|a - b| <= theta``   (equality within eps)
====  =============================================
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence, Tuple

import numpy as np

# Predicate op-codes (shared with kernels/window_join).
PRED_NONE = 0
PRED_LT = 1
PRED_GT = 2
PRED_ABS_LE = 3

_PRED_NAMES = {PRED_NONE: "-", PRED_LT: "<", PRED_GT: ">", PRED_ABS_LE: "~"}


class Operator(enum.Enum):
    SEQ = "SEQ"
    AND = "AND"
    OR = "OR"          # disjunction of sub-patterns (composite)
    NEG = "NEG"        # sequence with one negated event
    KLEENE = "KLEENE"  # sequence with one event under Kleene closure


@dataclasses.dataclass(frozen=True)
class Predicate:
    """A single pairwise predicate between two event types."""

    a_type: int
    b_type: int
    op: int
    a_attr: int = 0
    b_attr: int = 0
    theta: float = 0.0

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        return (
            f"e{self.a_type}.a{self.a_attr} {_PRED_NAMES[self.op]} "
            f"e{self.b_type}.a{self.b_attr} (θ={self.theta:g})"
        )


@dataclasses.dataclass(frozen=True)
class Pattern:
    """A single-operator pattern over ``n`` primitive event types.

    ``type_ids`` are global event-type identifiers (indices into the stream's
    type space); positions inside the pattern are 0..n-1 and, for SEQ-like
    operators, double as the required temporal order.

    ``negated`` / ``kleene`` give the *pattern position* of the event under
    negation / Kleene closure, or ``None``.  Per the paper (§5), negated
    events are excluded from the pattern size ``n`` used for plan generation;
    we model them as an extra type attached as a post-processing block.
    """

    operator: Operator
    type_ids: Tuple[int, ...]
    window: float
    predicates: Tuple[Predicate, ...] = ()
    n_attrs: int = 1
    negated_type: Optional[int] = None      # global type id under negation
    negated_predicates: Tuple[Predicate, ...] = ()
    negated_pos: Optional[int] = None       # absence required between
                                            # positions (negated_pos-1,
                                            # negated_pos); 0 = before all,
                                            # n = after all
    kleene_pos: Optional[int] = None        # pattern position under closure
    kleene_bound: Optional[int] = None      # max counted closure expansions
                                            # per match; None = unbounded
    name: str = "pattern"

    @property
    def n(self) -> int:
        return len(self.type_ids)

    @property
    def is_sequence(self) -> bool:
        return self.operator in (Operator.SEQ, Operator.NEG, Operator.KLEENE)

    def pred_tensors(self) -> dict:
        """Structural predicate tensors, indexed by *pattern position*.

        Returns op/a_attr/b_attr/theta arrays of shape (n, n).  Entry (p, q)
        constrains the pair (position p, position q); only p != q entries are
        used.  Predicates are stored symmetrically: a predicate (a, b, op) is
        materialized at (pos_a, pos_b) as given and at (pos_b, pos_a) with the
        mirrored op so the executor can evaluate in either join direction.
        """
        n = self.n
        op = np.zeros((n, n), np.int32)
        aa = np.zeros((n, n), np.int32)
        bb = np.zeros((n, n), np.int32)
        th = np.zeros((n, n), np.float32)
        pos_of = {t: p for p, t in enumerate(self.type_ids)}
        mirror = {PRED_NONE: PRED_NONE, PRED_LT: PRED_GT, PRED_GT: PRED_LT,
                  PRED_ABS_LE: PRED_ABS_LE}
        for pr in self.predicates:
            p, q = pos_of[pr.a_type], pos_of[pr.b_type]
            op[p, q], aa[p, q], bb[p, q], th[p, q] = pr.op, pr.a_attr, pr.b_attr, pr.theta
            op[q, p], aa[q, p], bb[q, p], th[q, p] = mirror[pr.op], pr.b_attr, pr.a_attr, pr.theta
        return {"op": op, "a_attr": aa, "b_attr": bb, "theta": th}

    def selectivity_pairs(self) -> Tuple[Tuple[int, int], ...]:
        """Pattern-position pairs (p < q) that carry a real predicate."""
        n = self.n
        t = self.pred_tensors()["op"]
        return tuple(
            (p, q) for p in range(n) for q in range(p + 1, n) if t[p, q] != PRED_NONE
        )


@dataclasses.dataclass(frozen=True)
class CompositePattern:
    """OR-composite: a disjunction of independent sub-patterns (paper set 5).

    Each sub-pattern is planned and evaluated independently; detection is the
    union of the sub-detections, and adaptation state is kept per branch.
    """

    branches: Tuple[Pattern, ...]
    name: str = "composite"

    @property
    def window(self) -> float:
        return max(b.window for b in self.branches)


def seq_pattern(
    type_ids: Sequence[int],
    window: float,
    predicates: Sequence[Predicate] = (),
    n_attrs: int = 1,
    name: str = "seq",
) -> Pattern:
    return Pattern(Operator.SEQ, tuple(type_ids), float(window),
                   tuple(predicates), n_attrs, name=name)


def and_pattern(
    type_ids: Sequence[int],
    window: float,
    predicates: Sequence[Predicate] = (),
    n_attrs: int = 1,
    name: str = "and",
) -> Pattern:
    return Pattern(Operator.AND, tuple(type_ids), float(window),
                   tuple(predicates), n_attrs, name=name)


def neg_pattern(
    type_ids: Sequence[int],
    window: float,
    negated_type: int,
    negated_pos: int,
    predicates: Sequence[Predicate] = (),
    negated_predicates: Sequence[Predicate] = (),
    n_attrs: int = 1,
    name: str = "neg",
) -> Pattern:
    return Pattern(Operator.NEG, tuple(type_ids), float(window),
                   tuple(predicates), n_attrs, negated_type=negated_type,
                   negated_predicates=tuple(negated_predicates),
                   negated_pos=negated_pos, name=name)


def kleene_pattern(
    type_ids: Sequence[int],
    window: float,
    kleene_pos: int,
    predicates: Sequence[Predicate] = (),
    n_attrs: int = 1,
    kleene_bound: Optional[int] = None,
    name: str = "kleene",
) -> Pattern:
    return Pattern(Operator.KLEENE, tuple(type_ids), float(window),
                   tuple(predicates), n_attrs, kleene_pos=kleene_pos,
                   kleene_bound=kleene_bound, name=name)


def chain_predicates(
    type_ids: Sequence[int], op: int = PRED_LT, attr: int = 0, theta: float = 0.0
) -> Tuple[Predicate, ...]:
    """Adjacent-pair predicate chain (e.g. ``A.diff < B.diff < C.diff``)."""
    return tuple(
        Predicate(a, b, op, attr, attr, theta)
        for a, b in zip(type_ids[:-1], type_ids[1:])
    )
