"""The ACEP detection-adaptation loop (paper Algorithm 1, §2.2).

Wires together the four components of Figure 2:

* the **evaluation mechanism** — the vectorized order/tree engine
  (``engine.py``), whose plan is a *dynamic* argument, so redeployment never
  recompiles the data plane;
* the **statistics estimator** — sliding-window rates/selectivities
  (``stats.py``);
* the **optimizer** — a reoptimizing decision function ``D``
  (``decision.py``);
* the **plan generation algorithm** ``A`` — instrumented greedy or ZStream
  (``greedy.py`` / ``zstream.py``), which returns the plan together with the
  deciding-condition sets the invariant policies consume.

Plan migration follows [36] (§2.2): when a new plan is deployed at time
``t_r``, the old plan remains responsible for matches containing at least
one event accepted before ``t_r`` (``min_ts < t_r``) while the new plan
handles matches born entirely after it (``min_ts >= t_r``); the sets are
disjoint, so nothing is detected twice, and the old plan retires at
``t_r + W``.  During the migration window both plans run — the doubled join
work is the *deployment cost* the paper's decision problem tries to
minimize, and it is charged to whichever policy caused the replan.

Composite (OR) patterns evaluate as independent branches, each with its own
engine, statistics, planner state and invariants (§5 pattern set 5).
"""

from __future__ import annotations

import dataclasses
import time
from typing import (TYPE_CHECKING, Callable, Iterable, List, Optional,
                    Tuple)

import numpy as np

if TYPE_CHECKING:  # annotation-only; a runtime import would be circular
    # (data.cep_streams imports core.engine, whose package imports us)
    from ..data.cep_streams import ChunkRecord

from .decision import DecisionPolicy
from .engine import EngineConfig, OrderEngine, TreeEngine
from .greedy import greedy_order_plan
from .invariants import DCSList
from .patterns import CompositePattern, Pattern
from .plans import plan_cost
from .stats import SlidingWindowEstimator, Stat, sample_selectivities
from .zstream import zstream_tree_plan


def make_planner(kind: str) -> Callable[[Pattern, Stat], Tuple[object, DCSList]]:
    if kind == "greedy":
        return greedy_order_plan
    if kind == "zstream":
        return zstream_tree_plan
    raise ValueError(f"unknown planner {kind!r}")


@dataclasses.dataclass
class RunMetrics:
    """Aggregated measurements for one detection-adaptation run (§5.2)."""

    chunks: int = 0
    events: int = 0
    full_matches: int = 0
    pm_created: int = 0            # partial matches materialized (work)
    overflow: int = 0
    closure_expansions: int = 0
    replans: int = 0               # A invocations triggered by D
    deployments: int = 0           # plan actually changed
    false_positives: int = 0       # D fired but A returned the same plan
    decision_time_s: float = 0.0   # host time spent in D
    plan_time_s: float = 0.0       # host time spent in A
    engine_time_s: float = 0.0     # device time spent joining
    migration_chunks: int = 0      # chunks processed under two plans
    condition_checks: int = 0      # elementary checks performed by D
    regret: float = 0.0            # Σ (cost(curr) − cost(opt)) / cost(opt)
    regret_samples: int = 0

    @property
    def adaptation_overhead(self) -> float:
        """Fraction of total accounted time spent deciding + replanning."""
        total = (self.decision_time_s + self.plan_time_s
                 + self.engine_time_s)
        if total <= 0:
            return 0.0
        return (self.decision_time_s + self.plan_time_s) / total


class AdaptiveRunner:
    """Algorithm 1 for a single (non-composite) pattern."""

    def __init__(
        self,
        pattern: Pattern,
        planner: str = "greedy",
        policy: Optional[DecisionPolicy] = None,
        engine_cfg: EngineConfig = EngineConfig(),
        estimator_buckets: int = 16,
        sel_samples: int = 64,
        measure_regret: bool = False,
        adaptive_caps: bool = False,
        cap_bounds: Tuple[int, int] = (256, 8192),
        seed: int = 0,
    ):
        self.pattern = pattern
        self.planner_kind = planner
        self.planner = make_planner(planner)
        self.policy = policy
        self.engine_cfg = engine_cfg
        self._engine_cls = (OrderEngine if planner == "greedy"
                            else TreeEngine)
        # adaptive_caps: pick the match-set capacity from the plan's own
        # cost model (pow2 bucket) so join work — and hence wall time —
        # tracks plan quality; each bucket compiles once (TPU-native
        # static shapes).  Engine state (ring buffers) is cap-independent,
        # so switching buckets preserves detection state.
        self.adaptive_caps = adaptive_caps
        self.cap_bounds = cap_bounds
        self._engines = {engine_cfg.m_cap: self._engine_cls(
            pattern, engine_cfg)}
        self.engine = self._engines[engine_cfg.m_cap]
        self.estimator = SlidingWindowEstimator(
            pattern.n, num_buckets=estimator_buckets)
        self.sel_samples = sel_samples
        self.measure_regret = measure_regret
        self._rng = np.random.default_rng(seed)
        self._pred_tensors = pattern.pred_tensors()
        self._pos_of_type = {t: p for p, t in enumerate(pattern.type_ids)}

    # -- adaptive capacity selection ---------------------------------------

    def _expected_peak_pm(self, plan, stat: Stat) -> float:
        """Max expected per-step partial matches over one window."""
        from .plans import OrderPlan, cardinality
        w = self.pattern.window
        scaled = Stat(stat.rates * w, stat.sel)
        seq = self.pattern.is_sequence
        if isinstance(plan, OrderPlan):
            groups = [plan.order[:i] for i in range(1, plan.n + 1)]
        else:
            groups = [nd.leaves()
                      for nd in plan.root.internal_nodes_bottom_up()]
        return max(cardinality(scaled, g, seq) for g in groups)

    def _engine_for(self, plan, stat: Stat):
        if not self.adaptive_caps:
            return self.engine
        lo, hi = self.cap_bounds
        want = self._expected_peak_pm(plan, stat) * 2.0  # safety factor
        want = max(want, getattr(self, "_cap_floor", lo))
        cap = 1 << int(np.ceil(np.log2(np.clip(want, lo, hi))))
        cap = max(cap, self.engine_cfg.b_cap)
        if cap not in self._engines:
            self._engines[cap] = self._engine_cls(
                self.pattern,
                EngineConfig(b_cap=self.engine_cfg.b_cap, m_cap=cap,
                             backend=self.engine_cfg.backend))
        return self._engines[cap]

    def _escalate(self, engine):
        """Reactive overflow escalation: jump to the next pow2 bucket so
        the cost-model misestimate cannot silently drop matches."""
        cap = min(engine.cfg.m_cap * 2, self.cap_bounds[1] * 4)
        self._cap_floor = cap
        if cap not in self._engines:
            self._engines[cap] = self._engine_cls(
                self.pattern,
                EngineConfig(b_cap=self.engine_cfg.b_cap, m_cap=cap,
                             backend=self.engine_cfg.backend))
        return self._engines[cap]

    # -- statistics -------------------------------------------------------

    def _observe(self, rec: ChunkRecord) -> None:
        chunk = rec.chunk
        valid = np.asarray(chunk.valid)
        tid = np.asarray(chunk.type_id)[valid]
        attrs = np.asarray(chunk.attr)[valid]
        counts = np.zeros(self.pattern.n)
        for p, t in enumerate(self.pattern.type_ids):
            counts[p] = float((tid == t).sum())
        trials, hits = sample_selectivities(
            self._rng, tid, attrs, self._pred_tensors, self._pos_of_type,
            self.pattern.n, self.sel_samples)
        self.estimator.update(counts, rec.t1 - rec.t0, trials, hits)

    # -- main loop --------------------------------------------------------

    def run(self, stream: Iterable[ChunkRecord]) -> RunMetrics:
        m = RunMetrics()
        state = self.engine.init_state()
        cur_plan = None
        cur_engine = self.engine
        old_plan = None
        old_engine = self.engine
        migration_until = -np.inf
        replan_t = -np.inf

        for rec in stream:
            self._observe(rec)
            stat = self.estimator.snapshot()

            # ---- optimizer: D then (maybe) A ----------------------------
            if cur_plan is None:
                t0 = time.perf_counter()
                cur_plan, dcs = self.planner(self.pattern, stat)
                m.plan_time_s += time.perf_counter() - t0
                cur_engine = self._engine_for(cur_plan, stat)
                if self.policy is not None:
                    self.policy.on_replan(cur_plan, dcs, stat)
            elif self.policy is not None:
                t0 = time.perf_counter()
                fire = self.policy.decide(stat)
                m.decision_time_s += time.perf_counter() - t0
                if fire:
                    t0 = time.perf_counter()
                    new_plan, dcs = self.planner(self.pattern, stat)
                    m.plan_time_s += time.perf_counter() - t0
                    m.replans += 1
                    if new_plan == cur_plan:
                        # A returned the same plan: a false positive of D
                        # (impossible for the invariant policy at d=0 —
                        # Theorem 1; property-tested).
                        m.false_positives += 1
                    else:
                        # A's output *is* the system's best plan for the
                        # current statistics (Alg. 1's "better" check is
                        # subsumed by A-optimality, §2.1) — deploy, with
                        # the [36] migration split.
                        old_plan = cur_plan
                        old_engine = cur_engine
                        cur_plan = new_plan
                        cur_engine = self._engine_for(new_plan, stat)
                        replan_t = rec.t0
                        migration_until = rec.t0 + self.pattern.window
                        m.deployments += 1
                    # Rebase the policy on the fresh DCSs either way.
                    self.policy.on_replan(cur_plan, dcs, stat)

            if self.measure_regret:
                opt_plan, _ = self.planner(self.pattern, stat)
                c_cur = plan_cost(cur_plan, stat, self.pattern.is_sequence)
                c_opt = plan_cost(opt_plan, stat, self.pattern.is_sequence)
                if c_opt > 0:
                    m.regret += max(0.0, (c_cur - c_opt) / c_opt)
                    m.regret_samples += 1

            # ---- evaluation mechanism -----------------------------------
            t_eng = time.perf_counter()
            in_migration = (old_plan is not None
                            and rec.t0 < migration_until)
            if not in_migration:
                old_plan = None

            def process(chunk, pm_extra=0):
                nonlocal state
                if in_migration:
                    # Old plan: matches with >=1 pre-replan event; new
                    # plan: matches born entirely after the replan.
                    state, r_old = old_engine.process_chunk(
                        state, chunk, old_plan, rec.t0, rec.t1,
                        born_lo=-3.0e38, born_hi=replan_t)
                    empty = chunk._replace(
                        valid=np.zeros_like(np.asarray(chunk.valid)))
                    state, r_new = cur_engine.process_chunk(
                        state, empty, cur_plan, rec.t0, rec.t1,
                        born_lo=replan_t, born_hi=3.0e38)
                    return (
                        int(r_old.full_matches) + int(r_new.full_matches),
                        pm_extra + int(r_old.pm_created)
                        + int(r_new.pm_created),
                        int(r_old.overflow) + int(r_new.overflow),
                        int(r_old.closure_expansions)
                        + int(r_new.closure_expansions))
                state, res = cur_engine.process_chunk(
                    state, chunk, cur_plan, rec.t0, rec.t1)
                return (int(res.full_matches),
                        pm_extra + int(res.pm_created),
                        int(res.overflow), int(res.closure_expansions))

            full, pm, ov, cl = process(rec.chunk)
            # Reactive capacity escalation: a capacity overflow may have
            # dropped candidates mid-join, so re-evaluate the window with
            # the next pow2 bucket (events are already ingested; the
            # duplicate join work is charged to pm).  Exactly-once
            # counting is preserved because the recount replaces the
            # truncated one.
            tries = 0
            while ov > 0 and self.adaptive_caps and tries < 4:
                cur_engine = self._escalate(cur_engine)
                if old_plan is not None:
                    old_engine = self._escalate(old_engine)
                empty = rec.chunk._replace(
                    valid=np.zeros_like(np.asarray(rec.chunk.valid)))
                full, pm, ov, cl = process(empty, pm_extra=pm)
                tries += 1
            if in_migration:
                m.migration_chunks += 1
            m.engine_time_s += time.perf_counter() - t_eng

            m.chunks += 1
            m.events += rec.n_events
            m.full_matches += full
            m.pm_created += pm
            m.overflow += ov
            m.closure_expansions += cl

        if self.policy is not None:
            m.condition_checks = self.policy.cost_counter()
        return m


class CompositeAdaptiveRunner:
    """OR-composite pattern: independent branch runners (§5 set 5)."""

    def __init__(self, pattern: CompositePattern, **kw):
        self.runners = [AdaptiveRunner(b, **kw) for b in pattern.branches]

    def run(self, streams: List[Iterable[ChunkRecord]]) -> List[RunMetrics]:
        if len(streams) != len(self.runners):
            raise ValueError("one stream per branch required")
        return [r.run(s) for r, s in zip(self.runners, streams)]


def merge_metrics(ms: List[RunMetrics]) -> RunMetrics:
    out = RunMetrics()
    for f in dataclasses.fields(RunMetrics):
        setattr(out, f.name, sum(getattr(x, f.name) for x in ms))
    return out
