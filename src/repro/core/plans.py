"""Evaluation plans and their cost model (paper §2.1, §4).

Two plan families from the paper:

* **Order-based plans** (lazy-NFA [36]): a permutation of the pattern
  positions; the engine accumulates partial matches by joining one event type
  at a time in that order.  A *building block* is "process position ``p`` at
  step ``i``" (§4.1).

* **Tree-based plans** (ZStream [42]): a binary tree whose leaves are the
  pattern positions; internal nodes join their children's match sets.  A
  *building block* is an internal node (§4.2).

The cost model follows the paper: the expected number of partial matches a
plan materializes.  ``Expr`` is the shared symbolic form for both plan
families' *deciding conditions*: every score/cost compared during plan
generation is (additive constant) + (scale × ∏ rates × ∏ selectivities),
which makes invariant verification a constant-time product evaluation
(§4.2's subtree-cost-as-constant trick sets ``const_add``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import numpy as np

from .stats import Stat


@dataclasses.dataclass(frozen=True)
class Expr:
    """``const_add + scale * ∏ rates[rate_idx] * ∏ sel[sel_pairs]``."""

    rate_idx: Tuple[int, ...] = ()
    sel_pairs: Tuple[Tuple[int, int], ...] = ()
    scale: float = 1.0
    const_add: float = 0.0

    def eval(self, stat: Stat) -> float:
        v = self.scale
        for i in self.rate_idx:
            v *= float(stat.rates[i])
        for i, j in self.sel_pairs:
            v *= float(stat.sel[i, j])
        return self.const_add + v

    def __str__(self) -> str:
        parts = []
        if self.const_add:
            parts.append(f"{self.const_add:.4g}")
        factors = [f"{self.scale:g}"] if self.scale != 1.0 else []
        factors += [f"r{i}" for i in self.rate_idx]
        factors += [f"s{i}{j}" for i, j in self.sel_pairs]
        term = "*".join(factors) or "1"
        parts.append(term)
        return " + ".join(parts)


def order_step_score_expr(
    candidate: int, prefix: Tuple[int, ...], sel_pairs_with_pred: frozenset
) -> Expr:
    """Greedy step score r_j · sel_jj · ∏_{k∈prefix} sel_kj (paper §4.1).

    Pairs without a defined predicate have selectivity 1 and are omitted so
    that verification touches only real statistics ("near-constant time",
    §4.1).
    """
    pairs = []
    if (candidate, candidate) in sel_pairs_with_pred:
        pairs.append((candidate, candidate))
    for k in prefix:
        key = (min(k, candidate), max(k, candidate))
        if key in sel_pairs_with_pred:
            pairs.append((k, candidate))
    return Expr(rate_idx=(candidate,), sel_pairs=tuple(pairs))


@dataclasses.dataclass(frozen=True)
class OrderPlan:
    """Order-based plan: ``order[i]`` = pattern position joined at step i."""

    order: Tuple[int, ...]

    @property
    def n(self) -> int:
        return len(self.order)

    def blocks(self) -> Tuple[str, ...]:
        return tuple(
            f"step{i}:pos{p}" for i, p in enumerate(self.order)
        )

    def __str__(self) -> str:
        return "Order(" + "->".join(map(str, self.order)) + ")"


# ---------------------------------------------------------------------------
# Tree plans
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TreeNode:
    """Binary plan-tree node.  Leaves carry a pattern position."""

    leaf: Optional[int] = None
    left: Optional["TreeNode"] = None
    right: Optional["TreeNode"] = None

    @property
    def is_leaf(self) -> bool:
        return self.leaf is not None

    def leaves(self) -> Tuple[int, ...]:
        if self.is_leaf:
            return (self.leaf,)
        return self.left.leaves() + self.right.leaves()

    def internal_nodes_bottom_up(self) -> Tuple["TreeNode", ...]:
        if self.is_leaf:
            return ()
        return (
            self.left.internal_nodes_bottom_up()
            + self.right.internal_nodes_bottom_up()
            + (self,)
        )

    def __str__(self) -> str:
        if self.is_leaf:
            return str(self.leaf)
        return f"({self.left},{self.right})"


@dataclasses.dataclass(frozen=True)
class TreePlan:
    root: TreeNode

    @property
    def n(self) -> int:
        return len(self.root.leaves())

    def blocks(self) -> Tuple[str, ...]:
        return tuple(
            "node:" + ",".join(map(str, nd.leaves()))
            for nd in self.root.internal_nodes_bottom_up()
        )

    def __str__(self) -> str:
        return f"Tree{self.root}"


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------


def _pair_sel(stat: Stat, group: Sequence[int]) -> float:
    v = 1.0
    g = list(group)
    for a in range(len(g)):
        for b in range(a + 1, len(g)):
            v *= float(stat.sel[g[a], g[b]])
    return v


def cardinality(stat: Stat, leaves: Sequence[int], is_sequence: bool) -> float:
    """Expected number of (partial) matches over a leaf group (§4.2).

    ``∏ rates × ∏ pairwise selectivities``, with the standard ``1/k!``
    temporal-ordering factor for sequence patterns (each unordered event
    combination admits exactly one valid temporal order).
    """
    v = 1.0
    for i in leaves:
        v *= float(stat.rates[i]) * float(stat.sel[i, i])
    v *= _pair_sel(stat, leaves)
    if is_sequence and len(leaves) > 1:
        v /= math.factorial(len(leaves))
    return v


def order_plan_cost(plan: OrderPlan, stat: Stat, is_sequence: bool = True) -> float:
    """Σ over prefixes of the expected partial-match count (paper §4.1)."""
    total = 0.0
    for i in range(1, plan.n + 1):
        total += cardinality(stat, plan.order[:i], is_sequence)
    return total


def tree_cost(node: TreeNode, stat: Stat, is_sequence: bool = True) -> float:
    """ZStream cost: Cost(T) = Cost(L) + Cost(R) + Card(T) (§4.2)."""
    if node.is_leaf:
        return float(stat.rates[node.leaf]) * float(stat.sel[node.leaf, node.leaf])
    return (
        tree_cost(node.left, stat, is_sequence)
        + tree_cost(node.right, stat, is_sequence)
        + cardinality(stat, node.leaves(), is_sequence)
    )


def plan_cost(plan, stat: Stat, is_sequence: bool = True) -> float:
    if isinstance(plan, OrderPlan):
        return order_plan_cost(plan, stat, is_sequence)
    if isinstance(plan, TreePlan):
        return tree_cost(plan.root, stat, is_sequence)
    raise TypeError(f"unknown plan type {type(plan)}")


def cardinality_expr(
    leaves: Sequence[int],
    sel_pairs_with_pred: frozenset,
    is_sequence: bool,
    const_add: float = 0.0,
) -> Expr:
    """Symbolic ``Card(leaves)`` for deciding conditions (§4.2)."""
    pairs = []
    for i in leaves:
        if (i, i) in sel_pairs_with_pred:
            pairs.append((i, i))
    g = sorted(leaves)
    for a in range(len(g)):
        for b in range(a + 1, len(g)):
            if (g[a], g[b]) in sel_pairs_with_pred:
                pairs.append((g[a], g[b]))
    scale = 1.0 / math.factorial(len(leaves)) if (is_sequence and len(leaves) > 1) else 1.0
    return Expr(
        rate_idx=tuple(sorted(leaves)),
        sel_pairs=tuple(pairs),
        scale=scale,
        const_add=const_add,
    )
