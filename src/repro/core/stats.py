"""Sliding-window statistics estimation (paper §2.2, refs [14, 27]).

The monitored set ``Stat`` consists of per-type event arrival rates and
pairwise predicate selectivities.  We maintain both over a sliding window of
recent stream history using a ring of time buckets — a simplified (exact
count, bounded memory) variant of the exponential-histogram techniques of
Datar et al. [27]: the engine processes chunks, each chunk contributes one
bucket of per-type counts and per-pair (trials, successes) selectivity
samples, and the estimate is the aggregate over the last ``num_buckets``
buckets.  This costs O(n + n²) memory and O(1) amortized update time, which
matches the paper's "negligible system resources" requirement.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Stat:
    """A snapshot of the monitored statistic values.

    rates: (n,) arrival rate per pattern position [events / time unit].
    sel:   (n, n) predicate selectivity per position pair; 1.0 where no
           predicate is defined (paper §4.1).  ``sel[i, i]`` holds the
           selectivity of conditions defined solely on type i.
    """

    rates: np.ndarray
    sel: np.ndarray

    @property
    def n(self) -> int:
        return int(self.rates.shape[0])

    def values(self) -> np.ndarray:
        """Flat view of all monitored values (for threshold policies)."""
        iu = np.triu_indices(self.n)
        return np.concatenate([self.rates, self.sel[iu]])

    def copy(self) -> "Stat":
        return Stat(self.rates.copy(), self.sel.copy())


def uniform_stat(n: int, rate: float = 1.0, sel: float = 1.0) -> Stat:
    s = np.full((n, n), sel, np.float64)
    return Stat(np.full((n,), rate, np.float64), s)


class SlidingWindowEstimator:
    """Windowed arrival-rate + selectivity estimator.

    Parameters
    ----------
    n: number of pattern positions (event types) monitored.
    num_buckets: sliding-window length in chunks.
    laplace: additive smoothing for selectivity (avoids 0/0 on cold pairs).
    """

    def __init__(self, n: int, num_buckets: int = 16, laplace: float = 1.0):
        self.n = n
        self.num_buckets = num_buckets
        self.laplace = float(laplace)
        self._counts = np.zeros((num_buckets, n), np.float64)
        self._durations = np.zeros((num_buckets,), np.float64)
        self._sel_trials = np.zeros((num_buckets, n, n), np.float64)
        self._sel_hits = np.zeros((num_buckets, n, n), np.float64)
        self._head = 0
        self._filled = 0

    def update(
        self,
        counts: np.ndarray,
        duration: float,
        sel_trials: Optional[np.ndarray] = None,
        sel_hits: Optional[np.ndarray] = None,
    ) -> None:
        """Push one chunk worth of observations into the window."""
        h = self._head
        self._counts[h] = counts
        self._durations[h] = max(float(duration), 1e-9)
        self._sel_trials[h] = 0.0 if sel_trials is None else sel_trials
        self._sel_hits[h] = 0.0 if sel_hits is None else sel_hits
        self._head = (h + 1) % self.num_buckets
        self._filled = min(self._filled + 1, self.num_buckets)

    def snapshot(self) -> Stat:
        k = max(self._filled, 1)
        total_t = self._durations[:k].sum() if self._filled else 1.0
        # Use the whole ring; un-filled buckets are zero and do not bias sums.
        rates = self._counts.sum(axis=0) / max(total_t, 1e-9)
        trials = self._sel_trials.sum(axis=0)
        hits = self._sel_hits.sum(axis=0)
        lp = self.laplace
        sel = (hits + lp) / (trials + 2.0 * lp)
        # Pairs with no predicate ever sampled: selectivity 1 (paper §4.1).
        sel = np.where(trials > 0, sel, 1.0)
        return Stat(rates, sel)

    @property
    def ready(self) -> bool:
        return self._filled > 0


def sample_selectivities(
    rng: np.random.Generator,
    type_id: np.ndarray,
    attrs: np.ndarray,
    pred_tensors: dict,
    pos_of_type: dict,
    n: int,
    samples_per_pair: int = 64,
) -> tuple[np.ndarray, np.ndarray]:
    """Monte-Carlo selectivity sampling over one chunk (host-side, cheap).

    For every pattern-position pair (p, q) carrying a real predicate, draw up
    to ``samples_per_pair`` random event pairs of the corresponding types from
    the chunk and evaluate the predicate.  Returns (trials, hits) matrices of
    shape (n, n) — symmetric, filled on the upper triangle and mirrored.

    The planner needs selectivities for *all* predicate pairs, including ones
    the currently deployed plan never joins, so passive estimates from the
    live join matrices are not enough (paper §2.2 keeps estimation
    plan-independent for the same reason).
    """
    from .patterns import PRED_NONE, PRED_LT, PRED_GT, PRED_ABS_LE

    op = pred_tensors["op"]
    a_attr = pred_tensors["a_attr"]
    b_attr = pred_tensors["b_attr"]
    theta = pred_tensors["theta"]
    trials = np.zeros((n, n), np.float64)
    hits = np.zeros((n, n), np.float64)

    idx_by_pos = {}
    for t, p in pos_of_type.items():
        idx_by_pos[p] = np.nonzero(type_id == t)[0]

    for p in range(n):
        for q in range(p + 1, n):
            if op[p, q] == PRED_NONE:
                continue
            ip, iq = idx_by_pos.get(p), idx_by_pos.get(q)
            if ip is None or iq is None or len(ip) == 0 or len(iq) == 0:
                continue
            m = samples_per_pair
            sa = attrs[rng.choice(ip, m), a_attr[p, q]]
            sb = attrs[rng.choice(iq, m), b_attr[p, q]]
            o, th = int(op[p, q]), float(theta[p, q])
            if o == PRED_LT:
                ok = sa < sb + th
            elif o == PRED_GT:
                ok = sa > sb - th
            elif o == PRED_ABS_LE:
                ok = np.abs(sa - sb) <= th
            else:  # pragma: no cover - PRED_NONE filtered above
                ok = np.ones(m, bool)
            trials[p, q] = trials[q, p] = m
            hits[p, q] = hits[q, p] = float(ok.sum())
    return trials, hits
