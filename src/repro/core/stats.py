"""Sliding-window statistics estimation (paper §2.2, refs [14, 27]).

The monitored set ``Stat`` consists of per-type event arrival rates and
pairwise predicate selectivities.  We maintain both over a sliding window of
recent stream history using a ring of time buckets — a simplified (exact
count, bounded memory) variant of the exponential-histogram techniques of
Datar et al. [27]: the engine processes chunks, each chunk contributes one
bucket of per-type counts and per-pair (trials, successes) selectivity
samples, and the estimate is the aggregate over the last ``num_buckets``
buckets.  This costs O(n + n²) memory and O(1) amortized update time, which
matches the paper's "negligible system resources" requirement.

Two implementations of the same window semantics live here:

* ``SlidingWindowEstimator`` — the host (numpy) estimator used by the
  single-stream adaptation loop, fed by Monte-Carlo ``sample_selectivities``.
* ``MonitorState`` + the ``monitor_*`` pure functions — the **device**
  ring used by the fused monitored step (`engine.make_monitored_process`),
  fed by exhaustive, RNG-free ``chunk_observations``.  The device ring
  lives inside the jitted data plane, so per-chunk monitoring costs no
  device→host sync; the host pulls a partition's ``(rates, sel)`` snapshot
  only when that partition's invariant flag fired.  The numpy twin
  ``exhaustive_selectivities`` computes identical trials/hits on the host,
  which is what makes host-vs-device differential tests exact.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Stat:
    """A snapshot of the monitored statistic values.

    rates: (n,) arrival rate per pattern position [events / time unit].
    sel:   (n, n) predicate selectivity per position pair; 1.0 where no
           predicate is defined (paper §4.1).  ``sel[i, i]`` holds the
           selectivity of conditions defined solely on type i.
    """

    rates: np.ndarray
    sel: np.ndarray

    @property
    def n(self) -> int:
        return int(self.rates.shape[0])

    def values(self) -> np.ndarray:
        """Flat view of all monitored values (for threshold policies)."""
        iu = np.triu_indices(self.n)
        return np.concatenate([self.rates, self.sel[iu]])

    def copy(self) -> "Stat":
        return Stat(self.rates.copy(), self.sel.copy())


def uniform_stat(n: int, rate: float = 1.0, sel: float = 1.0) -> Stat:
    s = np.full((n, n), sel, np.float64)
    return Stat(np.full((n,), rate, np.float64), s)


class SlidingWindowEstimator:
    """Windowed arrival-rate + selectivity estimator.

    Parameters
    ----------
    n: number of pattern positions (event types) monitored.
    num_buckets: sliding-window length in chunks.
    laplace: additive smoothing for selectivity (avoids 0/0 on cold pairs).
    """

    def __init__(self, n: int, num_buckets: int = 16, laplace: float = 1.0):
        self.n = n
        self.num_buckets = num_buckets
        self.laplace = float(laplace)
        self._counts = np.zeros((num_buckets, n), np.float64)
        self._durations = np.zeros((num_buckets,), np.float64)
        self._sel_trials = np.zeros((num_buckets, n, n), np.float64)
        self._sel_hits = np.zeros((num_buckets, n, n), np.float64)
        self._head = 0
        self._filled = 0

    def update(
        self,
        counts: np.ndarray,
        duration: float,
        sel_trials: Optional[np.ndarray] = None,
        sel_hits: Optional[np.ndarray] = None,
    ) -> None:
        """Push one chunk worth of observations into the window."""
        h = self._head
        self._counts[h] = counts
        self._durations[h] = max(float(duration), 1e-9)
        self._sel_trials[h] = 0.0 if sel_trials is None else sel_trials
        self._sel_hits[h] = 0.0 if sel_hits is None else sel_hits
        self._head = (h + 1) % self.num_buckets
        self._filled = min(self._filled + 1, self.num_buckets)

    def snapshot(self) -> Stat:
        k = max(self._filled, 1)
        total_t = self._durations[:k].sum() if self._filled else 1.0
        # Use the whole ring; un-filled buckets are zero and do not bias sums.
        rates = self._counts.sum(axis=0) / max(total_t, 1e-9)
        trials = self._sel_trials.sum(axis=0)
        hits = self._sel_hits.sum(axis=0)
        lp = self.laplace
        sel = (hits + lp) / (trials + 2.0 * lp)
        # Pairs with no predicate ever sampled: selectivity 1 (paper §4.1).
        sel = np.where(trials > 0, sel, 1.0)
        return Stat(rates, sel)

    @property
    def ready(self) -> bool:
        return self._filled > 0


# ---------------------------------------------------------------------------
# Device-resident window estimator (used by the fused monitored step)
# ---------------------------------------------------------------------------


class MonitorState(NamedTuple):
    """Device twin of one partition's sliding statistics window.

    Same ring-of-buckets semantics as ``SlidingWindowEstimator`` (and one
    row of ``fleet.FleetEstimator``), but a jax pytree updated inside the
    jitted step.  Stacking along a leading K axis (``jax.vmap``) yields the
    fleet's stacked statistics rings.
    """

    counts: "object"     # (buckets, n) f32 per-type counts per bucket
    durations: "object"  # (buckets,)   f32 chunk durations
    trials: "object"     # (buckets, n, n) f32 predicate pair trials
    hits: "object"       # (buckets, n, n) f32 predicate pair hits
    head: "object"       # () i32 ring head
    filled: "object"     # () i32 buckets filled so far


def monitor_init(n: int, num_buckets: int = 16) -> MonitorState:
    import jax.numpy as jnp

    return MonitorState(
        counts=jnp.zeros((num_buckets, n), jnp.float32),
        durations=jnp.zeros((num_buckets,), jnp.float32),
        trials=jnp.zeros((num_buckets, n, n), jnp.float32),
        hits=jnp.zeros((num_buckets, n, n), jnp.float32),
        head=jnp.int32(0),
        filled=jnp.int32(0),
    )


def fleet_monitor_init(k: int, n: int, num_buckets: int = 16) -> MonitorState:
    """Stacked per-partition statistics rings: every leaf leads with K.

    This is the monitor half of the superchunk scan carry
    (``core.scan``): a pure pytree that ``monitor_update`` threads through
    ``vmap`` (fleet) and ``lax.scan`` (superchunk) alike — including the
    ring ``head``/``filled`` scalars, which stack to ``(K,)`` so a device
    mesh can split the whole carry on its leading axis.
    """
    import jax
    import jax.numpy as jnp

    one = monitor_init(n, num_buckets)
    return jax.tree.map(
        lambda x: jnp.tile(x[None], (k,) + (1,) * x.ndim), one)


def monitor_update(state: MonitorState, counts, duration, trials,
                   hits) -> MonitorState:
    """Push one chunk of observations into the ring (device mirror of
    ``SlidingWindowEstimator.update``)."""
    import jax.numpy as jnp

    h = state.head
    buckets = state.durations.shape[0]
    return MonitorState(
        counts=state.counts.at[h].set(counts),
        durations=state.durations.at[h].set(
            jnp.maximum(jnp.float32(duration), 1e-9)),
        trials=state.trials.at[h].set(trials),
        hits=state.hits.at[h].set(hits),
        head=(h + 1) % buckets,
        filled=jnp.minimum(state.filled + 1, buckets),
    )


def monitor_snapshot(state: MonitorState, laplace: float = 1.0):
    """(rates (n,), sel (n, n)) — device mirror of ``snapshot``."""
    import jax.numpy as jnp

    total_t = jnp.where(state.filled > 0, state.durations.sum(), 1.0)
    rates = state.counts.sum(axis=0) / jnp.maximum(total_t, 1e-9)
    trials = state.trials.sum(axis=0)
    hits = state.hits.sum(axis=0)
    lp = laplace
    sel = (hits + lp) / (trials + 2.0 * lp)
    sel = jnp.where(trials > 0, sel, 1.0)
    return rates, sel


def _pred_ok(xp, op: int, theta: float, a, b):
    from .patterns import PRED_ABS_LE, PRED_GT, PRED_LT

    if op == PRED_LT:
        return a < b + theta
    if op == PRED_GT:
        return a > b - theta
    if op == PRED_ABS_LE:
        return xp.abs(a - b) <= theta
    raise ValueError(f"unexpected predicate op {op}")  # pragma: no cover


def chunk_observations(tid, attr, valid, type_ids: Sequence[int],
                       pred_tensors: dict):
    """Per-chunk monitored observations, computed on device.

    Returns (counts (n,), trials (n, n), hits (n, n)).  Selectivities are
    **exhaustive**: for every pattern-position pair carrying a predicate,
    every cross pair of in-chunk events of the two types is evaluated —
    O(cap²) bitwise work per pair, trivial next to the join cascade, and
    deterministic (no RNG), which is what lets the host verify the device
    flags bit-for-bit.  Pair structure is static (baked at trace time), so
    one compiled step serves every chunk.
    """
    import jax.numpy as jnp

    from .patterns import PRED_NONE

    n = len(type_ids)
    op_t = np.asarray(pred_tensors["op"])
    a_attr = np.asarray(pred_tensors["a_attr"])
    b_attr = np.asarray(pred_tensors["b_attr"])
    theta = np.asarray(pred_tensors["theta"])

    masks = [valid & (tid == t) for t in type_ids]
    counts = jnp.stack([m.sum().astype(jnp.float32) for m in masks])
    trials = jnp.zeros((n, n), jnp.float32)
    hits = jnp.zeros((n, n), jnp.float32)
    for p in range(n):
        for q in range(p + 1, n):
            if op_t[p, q] == PRED_NONE:
                continue
            a = attr[:, a_attr[p, q]]
            b = attr[:, b_attr[p, q]]
            ok = _pred_ok(jnp, int(op_t[p, q]), float(theta[p, q]),
                          a[:, None], b[None, :])
            pair_mask = masks[p][:, None] & masks[q][None, :]
            t_pq = counts[p] * counts[q]
            h_pq = (ok & pair_mask).sum().astype(jnp.float32)
            trials = trials.at[p, q].set(t_pq).at[q, p].set(t_pq)
            hits = hits.at[p, q].set(h_pq).at[q, p].set(h_pq)
    return counts, trials, hits


def exhaustive_selectivities(
    tid: np.ndarray,
    attrs: np.ndarray,
    pred_tensors: dict,
    type_ids: Sequence[int],
    n: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Host numpy twin of ``chunk_observations``'s selectivity part.

    Same exhaustive pair counting over one (already valid-filtered) chunk;
    returns float64 (trials, hits) for the host estimator rings.  Used by
    differential tests and by host-side catch-up after a violation.
    """
    from .patterns import PRED_NONE

    op_t = np.asarray(pred_tensors["op"])
    a_attr = np.asarray(pred_tensors["a_attr"])
    b_attr = np.asarray(pred_tensors["b_attr"])
    theta = np.asarray(pred_tensors["theta"])
    trials = np.zeros((n, n), np.float64)
    hits = np.zeros((n, n), np.float64)
    masks = [tid == t for t in type_ids]
    for p in range(n):
        for q in range(p + 1, n):
            if op_t[p, q] == PRED_NONE:
                continue
            a = attrs[masks[p]][:, a_attr[p, q]]
            b = attrs[masks[q]][:, b_attr[p, q]]
            ok = _pred_ok(np, int(op_t[p, q]), float(theta[p, q]),
                          a[:, None], b[None, :])
            trials[p, q] = trials[q, p] = float(len(a) * len(b))
            hits[p, q] = hits[q, p] = float(np.sum(ok))
    return trials, hits


def sample_selectivities(
    rng: np.random.Generator,
    type_id: np.ndarray,
    attrs: np.ndarray,
    pred_tensors: dict,
    pos_of_type: dict,
    n: int,
    samples_per_pair: int = 64,
) -> tuple[np.ndarray, np.ndarray]:
    """Monte-Carlo selectivity sampling over one chunk (host-side, cheap).

    For every pattern-position pair (p, q) carrying a real predicate, draw up
    to ``samples_per_pair`` random event pairs of the corresponding types from
    the chunk and evaluate the predicate.  Returns (trials, hits) matrices of
    shape (n, n) — symmetric, filled on the upper triangle and mirrored.

    The planner needs selectivities for *all* predicate pairs, including ones
    the currently deployed plan never joins, so passive estimates from the
    live join matrices are not enough (paper §2.2 keeps estimation
    plan-independent for the same reason).
    """
    from .patterns import PRED_NONE

    op = pred_tensors["op"]
    a_attr = pred_tensors["a_attr"]
    b_attr = pred_tensors["b_attr"]
    theta = pred_tensors["theta"]
    trials = np.zeros((n, n), np.float64)
    hits = np.zeros((n, n), np.float64)

    idx_by_pos = {}
    for t, p in pos_of_type.items():
        idx_by_pos[p] = np.nonzero(type_id == t)[0]

    for p in range(n):
        for q in range(p + 1, n):
            if op[p, q] == PRED_NONE:
                continue
            ip, iq = idx_by_pos.get(p), idx_by_pos.get(q)
            if ip is None or iq is None or len(ip) == 0 or len(iq) == 0:
                continue
            m = samples_per_pair
            sa = attrs[rng.choice(ip, m), a_attr[p, q]]
            sb = attrs[rng.choice(iq, m), b_attr[p, q]]
            # Same dispatch as the device/exhaustive paths (_pred_ok), so
            # host Monte-Carlo and device statistics can never diverge in
            # predicate convention.
            ok = _pred_ok(np, int(op[p, q]), float(theta[p, q]), sa, sb)
            trials[p, q] = trials[q, p] = m
            hits[p, q] = hits[q, p] = float(ok.sum())
    return trials, hits
