"""Partitioned fleet executor: K independent streams, one compiled plane.

The paper's adaptation loop (§2.2, Algorithm 1) is formulated for a single
stream.  Production traffic is *many* independent stream partitions
(tenants, symbols, sensor groups), each with its own statistical regime —
partition-parallel CEP in the spirit of Xiao & Aritsugi (2018).  Because
this engine's plans are **data, not code** (an order vector / slot
program), the whole data plane can be ``vmap``-ped over a leading
partition axis without recompilation:

* ``Buffers`` gains a leading ``K`` axis — stacked per-partition ring
  buffers;
* every partition carries its **own plan array** and its own
  ``born_lo/born_hi`` migration window, so partitions replan and migrate
  independently while sharing the single compiled ``process_chunk``;
* statistics (``FleetEstimator``) and invariant monitors
  (one ``DecisionPolicy`` per partition, ``FleetRunner``) live on the
  host, exactly as in the single-stream loop — the control plane stays
  per-partition, the data plane is one XLA program.

This is the §2.2 cheap-deployment property at fleet scale: deploying a new
plan for partition ``p`` writes one row of the stacked plan matrix.

Differential guarantee: ``FleetEngine`` must return bit-identical match
counts to a Python loop of K single-partition engines and to the
brute-force oracle (``ref_engine``); see ``tests/test_fleet.py``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .decision import DecisionPolicy
from .engine import (Buffers, Chunk, EngineConfig, OrderEngine, StepResult,
                     TreeEngine, tree_plan_to_slots)
from .patterns import Pattern
from .plans import OrderPlan, TreePlan
from .stats import Stat, sample_selectivities

_NEG_INF = -3.0e38
_POS_INF = 3.0e38


# ---------------------------------------------------------------------------
# Chunk routing / stacking
# ---------------------------------------------------------------------------


class FleetChunk(NamedTuple):
    """A stacked chunk: every field carries a leading partition axis."""

    chunk: Chunk          # (K, cap) / (K, cap, A) fields
    t0: float
    t1: float
    dropped: int = 0      # events dropped by per-partition capacity


def stack_chunks(chunks: Sequence[Chunk]) -> Chunk:
    """Stack K equally-shaped chunks along a new leading partition axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *chunks)


def route_events(
    type_id: np.ndarray,
    ts: np.ndarray,
    attr: np.ndarray,
    keys: np.ndarray,
    k: int,
    cap: int,
) -> Tuple[Chunk, int]:
    """Scatter one keyed event stream into K per-partition padded chunks.

    ``keys`` are arbitrary integer routing keys (tenant/symbol ids); events
    land in partition ``key % k``.  Per-partition overflow beyond ``cap``
    is dropped and counted (the serving layer surfaces it as back-pressure).
    Events within a partition keep their stream order.
    """
    n_attrs = attr.shape[1]
    out_tid = np.full((k, cap), -1, np.int32)
    out_ts = np.zeros((k, cap), np.float32)
    out_attr = np.zeros((k, cap, n_attrs), np.float32)
    out_valid = np.zeros((k, cap), bool)
    part = np.asarray(keys) % k
    dropped = 0
    for p in range(k):
        idx = np.nonzero(part == p)[0]
        m = len(idx)
        if m > cap:
            dropped += m - cap
            idx = idx[:cap]
            m = cap
        out_tid[p, :m] = type_id[idx]
        out_ts[p, :m] = ts[idx]
        out_attr[p, :m] = attr[idx]
        out_valid[p, :m] = True
    chunk = Chunk(jnp.asarray(out_tid), jnp.asarray(out_ts),
                  jnp.asarray(out_attr), jnp.asarray(out_valid))
    return chunk, dropped


def stacked_streams(streams: Sequence[Iterable]) -> Iterable[FleetChunk]:
    """Zip K ``ChunkRecord`` streams (shared chunk clock) into FleetChunks.

    All streams must tick with the same ``(t0, t1]`` edges (true for
    ``data.cep_streams`` generators built from one ``StreamConfig``).
    """
    for recs in zip(*streams):
        t0s = {r.t0 for r in recs}
        t1s = {r.t1 for r in recs}
        if len(t0s) != 1 or len(t1s) != 1:
            raise ValueError("partition streams disagree on chunk edges")
        yield FleetChunk(stack_chunks([r.chunk for r in recs]),
                         recs[0].t0, recs[0].t1)


# ---------------------------------------------------------------------------
# Fleet engine (vmapped data plane)
# ---------------------------------------------------------------------------


class FleetEngine:
    """K partitions through one ``jit(vmap(process))`` of the base engine.

    ``kind`` selects the plan family ("order" | "tree"); plans may differ
    per partition (they are stacked plan arrays), the pattern and engine
    capacities are shared — that is what makes the single compiled program
    possible.
    """

    def __init__(self, kind: str, pattern: Pattern, k: int,
                 cfg: EngineConfig = EngineConfig()):
        if kind == "order":
            self.base = OrderEngine(pattern, cfg)
        elif kind == "tree":
            self.base = TreeEngine(pattern, cfg)
        else:
            raise ValueError(f"unknown engine kind {kind!r}")
        self.kind = kind
        self.pattern = pattern
        self.cfg = cfg
        self.k = int(k)
        self._process = jax.jit(jax.vmap(self.base.process_fn))

    # -- state -------------------------------------------------------------

    def init_state(self) -> Buffers:
        one = self.base.init_state()
        return jax.tree.map(
            lambda x: jnp.tile(x[None], (self.k,) + (1,) * x.ndim), one)

    # -- plan stacking -----------------------------------------------------

    def plan_row(self, plan) -> np.ndarray:
        """A single plan as its row of the stacked plan matrix."""
        if self.kind == "order":
            return np.asarray(plan.order, np.int32)
        return tree_plan_to_slots(plan)

    def plans_to_array(self, plans) -> jnp.ndarray:
        """One plan (broadcast) or a length-K sequence -> stacked array."""
        if isinstance(plans, (OrderPlan, TreePlan)):
            plans = [plans] * self.k
        if len(plans) != self.k:
            raise ValueError(f"expected {self.k} plans, got {len(plans)}")
        return jnp.asarray(np.stack([self.plan_row(p) for p in plans]))

    # -- execution ---------------------------------------------------------

    def _bcast(self, v, dtype=jnp.float32) -> jnp.ndarray:
        arr = jnp.asarray(v, dtype)
        if arr.ndim == 0:
            arr = jnp.broadcast_to(arr, (self.k,))
        return arr

    def process_chunk(self, state: Buffers, chunks: Chunk, plans,
                      t0, t1, born_lo=_NEG_INF, born_hi=_POS_INF
                      ) -> Tuple[Buffers, StepResult]:
        """One chunk tick for the whole fleet.

        ``chunks`` fields carry a leading K axis; ``t0/t1/born_*`` may be
        scalars (shared clock) or per-partition ``(K,)`` vectors.  Returns
        the stacked state and a ``StepResult`` of ``(K,)`` counters.
        """
        plan_arr = (jnp.asarray(plans)
                    if isinstance(plans, (np.ndarray, jnp.ndarray))
                    else self.plans_to_array(plans))
        return self._process(
            state, chunks, plan_arr,
            self._bcast(t0), self._bcast(t1),
            self._bcast(born_lo), self._bcast(born_hi))


# ---------------------------------------------------------------------------
# Per-partition statistics
# ---------------------------------------------------------------------------


class FleetEstimator:
    """Vectorized per-partition sliding-window estimator.

    The single-stream ``SlidingWindowEstimator`` keeps ring arrays of shape
    ``(buckets, n)``; the fleet version prepends the partition axis so one
    numpy update serves all K partitions.  Snapshots are per-partition
    ``Stat`` views, which the planners and invariant monitors consume
    unchanged.
    """

    def __init__(self, k: int, n: int, num_buckets: int = 16,
                 laplace: float = 1.0):
        self.k, self.n = k, n
        self.num_buckets = num_buckets
        self.laplace = float(laplace)
        self._counts = np.zeros((k, num_buckets, n), np.float64)
        self._durations = np.zeros((k, num_buckets), np.float64)
        self._sel_trials = np.zeros((k, num_buckets, n, n), np.float64)
        self._sel_hits = np.zeros((k, num_buckets, n, n), np.float64)
        self._head = 0
        self._filled = 0

    def update(self, counts: np.ndarray, duration: float,
               sel_trials: Optional[np.ndarray] = None,
               sel_hits: Optional[np.ndarray] = None) -> None:
        """Push one chunk of per-partition observations ((K, n) counts)."""
        h = self._head
        self._counts[:, h] = counts
        self._durations[:, h] = max(float(duration), 1e-9)
        self._sel_trials[:, h] = 0.0 if sel_trials is None else sel_trials
        self._sel_hits[:, h] = 0.0 if sel_hits is None else sel_hits
        self._head = (h + 1) % self.num_buckets
        self._filled = min(self._filled + 1, self.num_buckets)

    def snapshot(self, p: int) -> Stat:
        total_t = self._durations[p].sum() if self._filled else 1.0
        rates = self._counts[p].sum(axis=0) / max(total_t, 1e-9)
        trials = self._sel_trials[p].sum(axis=0)
        hits = self._sel_hits[p].sum(axis=0)
        lp = self.laplace
        sel = (hits + lp) / (trials + 2.0 * lp)
        sel = np.where(trials > 0, sel, 1.0)
        return Stat(rates, sel)

    def snapshots(self) -> List[Stat]:
        return [self.snapshot(p) for p in range(self.k)]


# ---------------------------------------------------------------------------
# Fleet adaptation loop (per-partition control plane)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetMetrics:
    """Aggregated fleet counters plus the per-partition breakdown."""

    chunks: int = 0
    events: int = 0
    full_matches: int = 0
    pm_created: int = 0
    overflow: int = 0
    closure_expansions: int = 0
    neg_rejected: int = 0
    replans: int = 0
    deployments: int = 0
    escalations: int = 0
    migration_partition_chunks: int = 0
    engine_time_s: float = 0.0
    control_time_s: float = 0.0
    per_partition_matches: Optional[np.ndarray] = None
    per_partition_deployments: Optional[np.ndarray] = None


class FleetRunner:
    """Algorithm 1 replicated per partition over one vmapped data plane.

    Each partition owns its statistics window, its decision policy
    (invariant monitor), its current/old plan rows and its [36] migration
    split; every chunk tick runs ONE compiled fleet call (two while any
    partition is migrating — the doubled pass is the fleet-level deployment
    cost, charged only when at least one partition is mid-migration).
    """

    def __init__(
        self,
        pattern: Pattern,
        k: int,
        planner=None,
        policy_factory=None,
        engine_cfg: EngineConfig = EngineConfig(),
        estimator_buckets: int = 16,
        sel_samples: int = 64,
        escalate_on_overflow: bool = True,
        max_escalations: int = 4,
        seed: int = 0,
    ):
        from .adaptation import make_planner

        self.pattern = pattern
        self.k = int(k)
        planner = planner or "greedy"
        self.planner_kind = planner
        self.planner = make_planner(planner)
        kind = "order" if planner == "greedy" else "tree"
        self.engine_cfg = engine_cfg
        self.fleet = FleetEngine(kind, pattern, k, engine_cfg)
        # Overflow escalation mirrors AdaptiveRunner: a truncated join may
        # have dropped matches, so the chunk is re-evaluated with the next
        # pow2 match-set capacity (shared by the whole fleet — the stacked
        # plane has one m_cap).  Escalated engines are cached and persist.
        self.escalate_on_overflow = escalate_on_overflow
        self.max_escalations = max_escalations
        self._fleets = {engine_cfg.m_cap: self.fleet}
        self._active_fleet = self.fleet
        self.estimator = FleetEstimator(
            k, pattern.n, num_buckets=estimator_buckets)
        self.policies: List[Optional[DecisionPolicy]] = [
            policy_factory() if policy_factory else None for _ in range(k)]
        self.sel_samples = sel_samples
        self._rng = np.random.default_rng(seed)
        self._pred_tensors = pattern.pred_tensors()
        self._pos_of_type = {t: p for p, t in enumerate(pattern.type_ids)}
        # Per-partition control state.
        self.cur_plans: List[Optional[object]] = [None] * k
        self.old_plans: List[Optional[object]] = [None] * k
        self._replan_t = np.full(k, _NEG_INF, np.float64)
        self._migration_until = np.full(k, _NEG_INF, np.float64)
        self._cur_rows: Optional[np.ndarray] = None
        self._old_rows: Optional[np.ndarray] = None

    # -- statistics --------------------------------------------------------

    def _observe(self, fc: FleetChunk) -> None:
        chunk = fc.chunk
        tid_all = np.asarray(chunk.type_id)
        attr_all = np.asarray(chunk.attr)
        valid_all = np.asarray(chunk.valid)
        n = self.pattern.n
        counts = np.zeros((self.k, n))
        trials = np.zeros((self.k, n, n))
        hits = np.zeros((self.k, n, n))
        for p in range(self.k):
            v = valid_all[p]
            tid = tid_all[p][v]
            attrs = attr_all[p][v]
            for pos, t in enumerate(self.pattern.type_ids):
                counts[p, pos] = float((tid == t).sum())
            trials[p], hits[p] = sample_selectivities(
                self._rng, tid, attrs, self._pred_tensors,
                self._pos_of_type, n, self.sel_samples)
        self.estimator.update(counts, fc.t1 - fc.t0, trials, hits)

    # -- plan bookkeeping --------------------------------------------------

    def _plan_row(self, plan) -> np.ndarray:
        return self.fleet.plan_row(plan)

    def _escalated_fleet(self) -> FleetEngine:
        cap = self._active_fleet.cfg.m_cap * 2
        if cap not in self._fleets:
            self._fleets[cap] = FleetEngine(
                self.fleet.kind, self.pattern, self.k,
                EngineConfig(b_cap=self.engine_cfg.b_cap, m_cap=cap,
                             backend=self.engine_cfg.backend))
        return self._fleets[cap]

    def _replan_partition(self, p: int, stat: Stat, t0: float,
                          m: FleetMetrics) -> None:
        policy = self.policies[p]
        if self.cur_plans[p] is None:
            plan, dcs = self.planner(self.pattern, stat)
            self.cur_plans[p] = plan
            self._cur_rows[p] = self._plan_row(plan)
            self._old_rows[p] = self._cur_rows[p]
            if policy is not None:
                policy.on_replan(plan, dcs, stat)
            return
        if policy is None or not policy.decide(stat):
            return
        new_plan, dcs = self.planner(self.pattern, stat)
        m.replans += 1
        if new_plan != self.cur_plans[p]:
            # Deploy with the [36] migration split: the old plan row keeps
            # serving matches born before t0, the new row everything after.
            self.old_plans[p] = self.cur_plans[p]
            self._old_rows[p] = self._cur_rows[p]
            self.cur_plans[p] = new_plan
            self._cur_rows[p] = self._plan_row(new_plan)
            self._replan_t[p] = t0
            self._migration_until[p] = t0 + self.pattern.window
            m.deployments += 1
            m.per_partition_deployments[p] += 1
        policy.on_replan(self.cur_plans[p], dcs, stat)

    # -- main loop ---------------------------------------------------------

    def run(self, fleet_stream: Iterable[FleetChunk]) -> FleetMetrics:
        m = FleetMetrics(
            per_partition_matches=np.zeros(self.k, np.int64),
            per_partition_deployments=np.zeros(self.k, np.int64))
        state = self.fleet.init_state()
        if self._cur_rows is None:
            probe = self._plan_row(
                self.planner(self.pattern,
                             self.estimator.snapshot(0))[0])
            self._cur_rows = np.tile(probe, (self.k,) + (1,) * probe.ndim)
            self._old_rows = self._cur_rows.copy()
            self.cur_plans = [None] * self.k  # real plans set per partition

        for fc in fleet_stream:
            t_ctl = time.perf_counter()
            self._observe(fc)
            for p in range(self.k):
                self._replan_partition(
                    p, self.estimator.snapshot(p), fc.t0, m)
            # Partitions whose migration window lapsed fold back to one row.
            lapsed = (self._replan_t > _NEG_INF) & \
                (fc.t0 >= self._migration_until)
            for p in np.nonzero(lapsed)[0]:
                self.old_plans[p] = None
                self._old_rows[p] = self._cur_rows[p]
                self._replan_t[p] = _NEG_INF
            migrating = self._replan_t > _NEG_INF
            m.control_time_s += time.perf_counter() - t_ctl

            t_eng = time.perf_counter()

            def passes(chunk, state):
                # Pass A: current plans ingest the chunk; completed
                # matches are restricted to those born at/after each
                # partition's replan time (no restriction at -inf).
                state, res = self._active_fleet.process_chunk(
                    state, chunk, jnp.asarray(self._cur_rows),
                    fc.t0, fc.t1,
                    born_lo=self._replan_t.astype(np.float32),
                    born_hi=_POS_INF)
                out = [np.asarray(x, np.int64)
                       for x in (res.full_matches, res.pm_created,
                                 res.overflow, res.closure_expansions,
                                 res.neg_rejected)]
                if migrating.any():
                    # Pass B: old plans over an empty chunk (events
                    # already ingested) pick up matches born before the
                    # replan.  Non-migrating partitions have an empty
                    # born-window (born_hi = -inf) and contribute zero.
                    empty = chunk._replace(
                        valid=jnp.zeros_like(chunk.valid))
                    state, res_b = self._active_fleet.process_chunk(
                        state, empty, jnp.asarray(self._old_rows),
                        fc.t0, fc.t1,
                        born_lo=_NEG_INF,
                        born_hi=self._replan_t.astype(np.float32))
                    # Non-migrating partitions ran pass B with old_rows ==
                    # cur_rows and an empty born-window: their match
                    # counters are zero by construction, but pm/overflow
                    # measure join work regardless of the born filter —
                    # mask them so fleet counters aren't double-charged.
                    for i, x in enumerate(
                            (res_b.full_matches, res_b.pm_created,
                             res_b.overflow, res_b.closure_expansions,
                             res_b.neg_rejected)):
                        out[i] += np.where(migrating,
                                           np.asarray(x, np.int64), 0)
                return state, out

            state, (full, pm, ov, cl, ng) = passes(fc.chunk, state)
            # Overflow recovery: a truncated join may have dropped
            # matches, so re-evaluate the window at the next pow2 capacity
            # (events already ingested; the recount replaces the truncated
            # one and the duplicate join work is charged to pm).
            tries = 0
            while (ov.sum() > 0 and self.escalate_on_overflow
                   and tries < self.max_escalations):
                self._active_fleet = self._escalated_fleet()
                m.escalations += 1
                tries += 1
                empty = fc.chunk._replace(
                    valid=jnp.zeros_like(fc.chunk.valid))
                pm_so_far = pm
                state, (full, pm, ov, cl, ng) = passes(empty, state)
                pm = pm + pm_so_far
            if migrating.any():
                m.migration_partition_chunks += int(migrating.sum())
            m.engine_time_s += time.perf_counter() - t_eng

            m.chunks += 1
            m.events += int(np.asarray(fc.chunk.valid).sum())
            m.full_matches += int(full.sum())
            m.pm_created += int(pm.sum())
            m.overflow += int(ov.sum())
            m.closure_expansions += int(cl.sum())
            m.neg_rejected += int(ng.sum())
            m.per_partition_matches += full
        return m
