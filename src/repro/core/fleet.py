"""Partitioned fleet executor: K independent streams, one compiled plane.

The paper's adaptation loop (§2.2, Algorithm 1) is formulated for a single
stream.  Production traffic is *many* independent stream partitions
(tenants, symbols, sensor groups), each with its own statistical regime —
partition-parallel CEP in the spirit of Xiao & Aritsugi (2018).  Because
this engine's plans are **data, not code** (an order vector / slot
program), the whole data plane can be ``vmap``-ped over a leading
partition axis without recompilation:

* ``Buffers`` gains a leading ``K`` axis — stacked per-partition ring
  buffers;
* every partition carries its **own plan array** and its own
  ``born_lo/born_hi`` migration window, so partitions replan and migrate
  independently while sharing the single compiled ``process_chunk``;
* monitoring runs in either of two control planes: ``FleetRunner`` keeps
  statistics (``FleetEstimator``) and invariant monitors (one
  ``DecisionPolicy`` per partition) on the host, as in the single-stream
  loop; ``MonitoredFleetRunner`` keeps the statistics rings **on device**
  and verifies each partition's lowered invariant set inside the same
  jitted/vmapped step (§3.3-§3.5's low-overhead monitoring at fleet
  scale), so the host sees only a ``(K,)`` violation-flag vector and
  syncs/replans flagged partitions alone — O(violations) host work per
  chunk instead of O(K·stats).

This is the §2.2 cheap-deployment property at fleet scale: deploying a new
plan for partition ``p`` writes one row of the stacked plan matrix (and,
when device-monitored, one row of the stacked invariant tensors).

Differential guarantees: ``FleetEngine`` must return bit-identical match
counts to a Python loop of K single-partition engines and to the
brute-force oracle (``ref_engine``); the device-evaluated violation flags
must agree with the host ``InvariantPolicy`` decisions on the synced
statistics; see ``tests/test_fleet.py`` and ``tests/test_monitor.py``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict
from typing import Iterable, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .decision import DecisionPolicy, InvariantPolicy
from .engine import (NEG_INF, POS_INF, Buffers, Chunk, EngineConfig,
                     OrderEngine, StepResult, TreeEngine,
                     make_monitored_process, tree_plan_to_slots)
from .invariants import LoweredInvariants, StackedLowered
from .patterns import Pattern
from .plans import OrderPlan, TreePlan
from .stats import (MonitorState, Stat, fleet_monitor_init,
                    sample_selectivities, uniform_stat)

_NEG_INF = NEG_INF
_POS_INF = POS_INF


# ---------------------------------------------------------------------------
# Chunk routing / stacking
# ---------------------------------------------------------------------------


class FleetChunk(NamedTuple):
    """A stacked chunk: every field carries a leading partition axis."""

    chunk: Chunk          # (K, cap) / (K, cap, A) fields
    t0: float
    t1: float
    dropped: int = 0      # events dropped by per-partition capacity


def stack_chunks(chunks: Sequence[Chunk]) -> Chunk:
    """Stack K equally-shaped chunks along a new leading partition axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *chunks)


def route_events(
    type_id: np.ndarray,
    ts: np.ndarray,
    attr: np.ndarray,
    keys: np.ndarray,
    k: int,
    cap: int,
) -> Tuple[Chunk, int]:
    """Scatter one keyed event stream into K per-partition padded chunks.

    ``keys`` are arbitrary integer routing keys (tenant/symbol ids); events
    land in partition ``key % k``.  Per-partition overflow beyond ``cap``
    is dropped and counted (the serving layer surfaces it as back-pressure).
    Events within a partition keep their stream order.
    """
    n_attrs = attr.shape[1]
    out_tid = np.full((k, cap), -1, np.int32)
    out_ts = np.zeros((k, cap), np.float32)
    out_attr = np.zeros((k, cap, n_attrs), np.float32)
    out_valid = np.zeros((k, cap), bool)
    part = np.asarray(keys) % k
    dropped = 0
    for p in range(k):
        idx = np.nonzero(part == p)[0]
        m = len(idx)
        if m > cap:
            dropped += m - cap
            idx = idx[:cap]
            m = cap
        out_tid[p, :m] = type_id[idx]
        out_ts[p, :m] = ts[idx]
        out_attr[p, :m] = attr[idx]
        out_valid[p, :m] = True
    chunk = Chunk(jnp.asarray(out_tid), jnp.asarray(out_ts),
                  jnp.asarray(out_attr), jnp.asarray(out_valid))
    return chunk, dropped


def stacked_streams(streams: Sequence[Iterable]) -> Iterable[FleetChunk]:
    """Zip K ``ChunkRecord`` streams (shared chunk clock) into FleetChunks.

    All streams must tick with the same ``(t0, t1]`` edges (true for
    ``data.cep_streams`` generators built from one ``StreamConfig``).
    """
    for recs in zip(*streams):
        t0s = {r.t0 for r in recs}
        t1s = {r.t1 for r in recs}
        if len(t0s) != 1 or len(t1s) != 1:
            raise ValueError("partition streams disagree on chunk edges")
        yield FleetChunk(stack_chunks([r.chunk for r in recs]),
                         recs[0].t0, recs[0].t1)


# ---------------------------------------------------------------------------
# Fleet engine (vmapped data plane)
# ---------------------------------------------------------------------------

# Process-wide trace memo.  FleetEngine instances are cheap and plentiful —
# escalation ladders, replays, and benchmarks build one per (capacity,
# monitored) rung — but instances with equal (kind, pattern, k, cfg,
# monitor_laplace) lower to identical programs, and jax's trace/compile
# cache hangs off the *callable*, so per-instance ``jax.jit`` pays the
# multi-second trace again for every rung.  Sharing the jitted callable
# shares the cache.  Meshed engines are excluded: mesh objects are not
# value-hashable and shard_map closures pin device orders.
#
# The memo is LRU-bounded: long-lived processes that churn configurations
# (capacity sweeps, many-tenant rulebooks, test suites) would otherwise
# pin every jitted program they ever built.  Eviction drops our reference
# to the callable — jax's compile cache entries die with it once callers
# let go too.
_TRACE_MEMO: "OrderedDict" = OrderedDict()
_TRACE_MEMO_CAP = 64


def _shared_trace(key, build):
    if key is None:
        return build()
    fn = _TRACE_MEMO.get(key)
    if fn is None:
        fn = _TRACE_MEMO[key] = build()
        while len(_TRACE_MEMO) > _TRACE_MEMO_CAP:
            _TRACE_MEMO.popitem(last=False)
    else:
        _TRACE_MEMO.move_to_end(key)
    return fn


def clear_trace_memo() -> None:
    """Drop every memoized jitted fleet/rulebook program.

    Existing engines keep working (they hold their own references); new
    equal-config engines re-trace once.  Useful to release compile-cache
    memory in long-lived processes, and in tests that assert tracing
    behavior from a clean slate.
    """
    _TRACE_MEMO.clear()


class FleetEngine:
    """K partitions through one ``jit(vmap(process))`` of the base engine.

    ``kind`` selects the plan family ("order" | "tree"); plans may differ
    per partition (they are stacked plan arrays), the pattern and engine
    capacities are shared — that is what makes the single compiled program
    possible.
    """

    def __init__(self, kind: str, pattern: Pattern, k: int,
                 cfg: EngineConfig = EngineConfig(),
                 monitor_laplace: float = 1.0, mesh=None):
        if kind == "order":
            self.base = OrderEngine(pattern, cfg)
        elif kind == "tree":
            self.base = TreeEngine(pattern, cfg)
        else:
            raise ValueError(f"unknown engine kind {kind!r}")
        self.kind = kind
        self.pattern = pattern
        self.cfg = cfg
        self.k = int(k)
        self.monitor_laplace = monitor_laplace
        # Optional 1-D device mesh: the K-partition axis is split over the
        # mesh's "cep" axis (see distributed.sharding).  Partitions are
        # independent, so sharding never changes semantics — D=1 meshes
        # exercise the identical code path on a single device.
        from ..distributed.sharding import resolve_cep_mesh
        self.mesh = resolve_cep_mesh(mesh, self.k)
        self._process = _shared_trace(
            self._trace_key("plain"),
            lambda: jax.jit(self._wrap(jax.vmap(self.base.process_fn))))
        self._mprocess = None  # monitored variant, compiled on first use
        self._scans = {}       # superchunk scans keyed by `monitored`

    def _trace_key(self, flavor):
        """Memo key for the process-wide trace cache; None = don't share."""
        if self.mesh is not None:
            return None
        return (self.kind, self.pattern, self.k, self.cfg,
                self.monitor_laplace, flavor)

    def _wrap(self, fn):
        """shard_map the vmapped step over the fleet mesh, if any."""
        if self.mesh is None:
            return fn
        from ..distributed.sharding import shard_fleet_fn
        return shard_fleet_fn(fn, self.mesh)

    # -- state -------------------------------------------------------------

    def init_state(self) -> Buffers:
        one = self.base.init_state()
        return jax.tree.map(
            lambda x: jnp.tile(x[None], (self.k,) + (1,) * x.ndim), one)

    def init_monitor(self, num_buckets: int = 16) -> MonitorState:
        """Stacked per-partition statistics rings, device-resident."""
        return fleet_monitor_init(self.k, self.pattern.n, num_buckets)

    # -- plan stacking -----------------------------------------------------

    def plan_row(self, plan) -> np.ndarray:
        """A single plan as its row of the stacked plan matrix."""
        if self.kind == "order":
            return np.asarray(plan.order, np.int32)
        return tree_plan_to_slots(plan)

    def plans_to_array(self, plans) -> jnp.ndarray:
        """One plan (broadcast) or a length-K sequence -> stacked array."""
        if isinstance(plans, (OrderPlan, TreePlan)):
            plans = [plans] * self.k
        if len(plans) != self.k:
            raise ValueError(f"expected {self.k} plans, got {len(plans)}")
        return jnp.asarray(np.stack([self.plan_row(p) for p in plans]))

    # -- execution ---------------------------------------------------------

    def _bcast(self, v, dtype=jnp.float32) -> jnp.ndarray:
        arr = jnp.asarray(v, dtype)
        if arr.ndim == 0:
            arr = jnp.broadcast_to(arr, (self.k,))
        return arr

    def process_chunk(self, state: Buffers, chunks: Chunk, plans,
                      t0, t1, born_lo=_NEG_INF, born_hi=_POS_INF
                      ) -> Tuple[Buffers, StepResult]:
        """One chunk tick for the whole fleet.

        ``chunks`` fields carry a leading K axis; ``t0/t1/born_*`` may be
        scalars (shared clock) or per-partition ``(K,)`` vectors.  Returns
        the stacked state and a ``StepResult`` of ``(K,)`` counters.
        """
        plan_arr = (jnp.asarray(plans)
                    if isinstance(plans, (np.ndarray, jnp.ndarray))
                    else self.plans_to_array(plans))
        return self._process(
            state, chunks, plan_arr,
            self._bcast(t0), self._bcast(t1),
            self._bcast(born_lo), self._bcast(born_hi))

    def process_chunk_monitored(self, state: Buffers, monitor: MonitorState,
                                chunks: Chunk, plans,
                                lowered: LoweredInvariants,
                                t0, t1, born_lo=_NEG_INF, born_hi=_POS_INF):
        """One fused chunk tick: joins + statistics rings + invariants.

        ``lowered`` carries a leading K axis (one ``LoweredInvariants`` row
        per partition, see ``invariants.stack_lowered``).  Returns
        ``(state, monitor, StepResult, violated (K,), drift (K,),
        rates (K, n), sel (K, n, n))``.  ``rates``/``sel`` are device
        arrays — index a single partition before ``np.asarray`` so host
        syncs stay proportional to violations, not to K.
        """
        if self._mprocess is None:
            self._mprocess = _shared_trace(
                self._trace_key("monitored"),
                lambda: jax.jit(self._wrap(jax.vmap(
                    make_monitored_process(self.base.process_fn,
                                           self.base.spec,
                                           self.monitor_laplace)))))
        plan_arr = (jnp.asarray(plans)
                    if isinstance(plans, (np.ndarray, jnp.ndarray))
                    else self.plans_to_array(plans))
        lowered = jax.tree.map(jnp.asarray, lowered)
        return self._mprocess(
            state, monitor, chunks, plan_arr, lowered,
            self._bcast(t0), self._bcast(t1),
            self._bcast(born_lo), self._bcast(born_hi))

    def superchunk_scan(self, monitored: bool):
        """The compiled S-chunks-per-dispatch scan (see ``core.scan``).

        One cached compile per (engine config, monitored) pair — like the
        per-chunk step, it is plan- and invariant-agnostic (both enter as
        data), so replans and invariant redeployments never recompile.
        """
        from .scan import make_superchunk_scan

        if monitored not in self._scans:
            self._scans[monitored] = _shared_trace(
                self._trace_key(("scan", monitored)),
                lambda: make_superchunk_scan(
                    self.base.process_fn, self.base.spec, monitored,
                    self.monitor_laplace, mesh=self.mesh,
                    plan_operands=getattr(self.base, "plan_operands", None)))
        return self._scans[monitored]


# ---------------------------------------------------------------------------
# Per-partition statistics
# ---------------------------------------------------------------------------


class FleetEstimator:
    """Vectorized per-partition sliding-window estimator.

    The single-stream ``SlidingWindowEstimator`` keeps ring arrays of shape
    ``(buckets, n)``; the fleet version prepends the partition axis so one
    numpy update serves all K partitions.  Snapshots are per-partition
    ``Stat`` views, which the planners and invariant monitors consume
    unchanged.
    """

    def __init__(self, k: int, n: int, num_buckets: int = 16,
                 laplace: float = 1.0):
        self.k, self.n = k, n
        self.num_buckets = num_buckets
        self.laplace = float(laplace)
        self._counts = np.zeros((k, num_buckets, n), np.float64)
        self._durations = np.zeros((k, num_buckets), np.float64)
        self._sel_trials = np.zeros((k, num_buckets, n, n), np.float64)
        self._sel_hits = np.zeros((k, num_buckets, n, n), np.float64)
        self._head = 0
        self._filled = 0

    def update(self, counts: np.ndarray, duration: float,
               sel_trials: Optional[np.ndarray] = None,
               sel_hits: Optional[np.ndarray] = None) -> None:
        """Push one chunk of per-partition observations ((K, n) counts)."""
        h = self._head
        self._counts[:, h] = counts
        self._durations[:, h] = max(float(duration), 1e-9)
        self._sel_trials[:, h] = 0.0 if sel_trials is None else sel_trials
        self._sel_hits[:, h] = 0.0 if sel_hits is None else sel_hits
        self._head = (h + 1) % self.num_buckets
        self._filled = min(self._filled + 1, self.num_buckets)

    def snapshot(self, p: int) -> Stat:
        total_t = self._durations[p].sum() if self._filled else 1.0
        rates = self._counts[p].sum(axis=0) / max(total_t, 1e-9)
        trials = self._sel_trials[p].sum(axis=0)
        hits = self._sel_hits[p].sum(axis=0)
        lp = self.laplace
        sel = (hits + lp) / (trials + 2.0 * lp)
        sel = np.where(trials > 0, sel, 1.0)
        return Stat(rates, sel)

    def snapshots(self) -> List[Stat]:
        return [self.snapshot(p) for p in range(self.k)]


# ---------------------------------------------------------------------------
# Fleet adaptation loop (per-partition control plane)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetMetrics:
    """Aggregated fleet counters plus the per-partition breakdown."""

    chunks: int = 0
    events: int = 0
    full_matches: int = 0
    pm_created: int = 0
    overflow: int = 0
    closure_expansions: int = 0
    neg_rejected: int = 0
    replans: int = 0
    deployments: int = 0
    escalations: int = 0
    migration_partition_chunks: int = 0
    engine_time_s: float = 0.0
    control_time_s: float = 0.0
    violations: int = 0            # device invariant flags fired
    host_syncs: int = 0            # per-partition statistic pulls
    per_partition_matches: Optional[np.ndarray] = None
    per_partition_deployments: Optional[np.ndarray] = None
    last_drift: Optional[np.ndarray] = None  # (K,) §3.4-style margins


class FleetRunner:
    """Algorithm 1 replicated per partition over one vmapped data plane.

    Each partition owns its statistics window, its decision policy
    (invariant monitor), its current/old plan rows and its [36] migration
    split; every chunk tick runs ONE compiled fleet call (two while any
    partition is migrating — the doubled pass is the fleet-level deployment
    cost, charged only when at least one partition is mid-migration).
    """

    def __init__(
        self,
        pattern: Pattern,
        k: int,
        planner=None,
        policy_factory=None,
        engine_cfg: EngineConfig = EngineConfig(),
        estimator_buckets: int = 16,
        sel_samples: int = 64,
        laplace: float = 1.0,
        escalate_on_overflow: bool = True,
        max_escalations: int = 4,
        seed: int = 0,
        mesh=None,
    ):
        from .adaptation import make_planner
        from .compat import warn_legacy

        if type(self) is FleetRunner:
            warn_legacy("FleetRunner")
        self.pattern = pattern
        self.k = int(k)
        planner = planner or "greedy"
        self.planner_kind = planner
        self.planner = make_planner(planner)
        kind = "order" if planner == "greedy" else "tree"
        self.engine_cfg = engine_cfg
        self.laplace = float(laplace)
        self.mesh = mesh
        self.fleet = FleetEngine(kind, pattern, k, engine_cfg,
                                 monitor_laplace=laplace, mesh=mesh)
        # Overflow escalation mirrors AdaptiveRunner: a truncated join may
        # have dropped matches, so the chunk is re-evaluated with the next
        # pow2 match-set capacity (shared by the whole fleet — the stacked
        # plane has one m_cap).  Escalated engines are cached and persist.
        self.escalate_on_overflow = escalate_on_overflow
        self.max_escalations = max_escalations
        self._fleets = {engine_cfg.m_cap: self.fleet}
        self._active_fleet = self.fleet
        self.estimator = FleetEstimator(
            k, pattern.n, num_buckets=estimator_buckets, laplace=laplace)
        self.policies: List[Optional[DecisionPolicy]] = [
            policy_factory() if policy_factory else None for _ in range(k)]
        self.sel_samples = sel_samples
        self._rng = np.random.default_rng(seed)
        self._pred_tensors = pattern.pred_tensors()
        self._pos_of_type = {t: p for p, t in enumerate(pattern.type_ids)}
        # Per-partition control state.
        self.cur_plans: List[Optional[object]] = [None] * k
        self.old_plans: List[Optional[object]] = [None] * k
        self._replan_t = np.full(k, _NEG_INF, np.float64)
        self._migration_until = np.full(k, _NEG_INF, np.float64)
        self._cur_rows: Optional[np.ndarray] = None
        self._old_rows: Optional[np.ndarray] = None
        # Stream carry for run(..., resume=True): ring-buffer state (and,
        # for the monitored subclass, monitor rings + deferred flags)
        # persists across run calls so segmented replays are one
        # continuous stream.
        self._state = None

    # -- statistics --------------------------------------------------------

    def _observe(self, fc: FleetChunk) -> None:
        chunk = fc.chunk
        tid_all = np.asarray(chunk.type_id)
        attr_all = np.asarray(chunk.attr)
        valid_all = np.asarray(chunk.valid)
        n = self.pattern.n
        counts = np.zeros((self.k, n))
        trials = np.zeros((self.k, n, n))
        hits = np.zeros((self.k, n, n))
        for p in range(self.k):
            v = valid_all[p]
            tid = tid_all[p][v]
            attrs = attr_all[p][v]
            for pos, t in enumerate(self.pattern.type_ids):
                counts[p, pos] = float((tid == t).sum())
            trials[p], hits[p] = sample_selectivities(
                self._rng, tid, attrs, self._pred_tensors,
                self._pos_of_type, n, self.sel_samples)
        self.estimator.update(counts, fc.t1 - fc.t0, trials, hits)

    # -- plan bookkeeping --------------------------------------------------

    def _plan_row(self, plan) -> np.ndarray:
        return self.fleet.plan_row(plan)

    def _escalated_fleet(self) -> FleetEngine:
        cap = self._active_fleet.cfg.m_cap * 2
        if cap not in self._fleets:
            self._fleets[cap] = FleetEngine(
                self.fleet.kind, self.pattern, self.k,
                EngineConfig(b_cap=self.engine_cfg.b_cap, m_cap=cap,
                             backend=self.engine_cfg.backend),
                monitor_laplace=self.laplace, mesh=self.mesh)
        return self._fleets[cap]

    def _deploy(self, p: int, new_plan, t0: float, m: FleetMetrics) -> None:
        """Deploy with the [36] migration split: the old plan row keeps
        serving matches born before ``t0``, the new row everything after.

        Deployment also retires any capacity escalation: the blown-up
        match sets belonged to the plan era being replaced — the planner
        just chose a plan to shrink them — so the fleet drops back to its
        base match capacity.  If the new plan still overflows, the
        per-chunk recovery loop re-escalates; a pinned-plan run never
        deploys, so it keeps paying the escalated-shape join cost — that
        asymmetry *is* the adaptivity win the replay harness gates on."""
        self.old_plans[p] = self.cur_plans[p]
        self._old_rows[p] = self._cur_rows[p]
        self.cur_plans[p] = new_plan
        self._cur_rows[p] = self._plan_row(new_plan)
        self._replan_t[p] = t0
        self._migration_until[p] = t0 + self.pattern.window
        self._active_fleet = self.fleet
        m.deployments += 1
        m.per_partition_deployments[p] += 1

    def _fold_lapsed(self, t0: float) -> np.ndarray:
        """Fold partitions whose migration window lapsed back to one row;
        returns the still-migrating mask."""
        lapsed = (self._replan_t > _NEG_INF) & (t0 >= self._migration_until)
        for p in np.nonzero(lapsed)[0]:
            self.old_plans[p] = None
            self._old_rows[p] = self._cur_rows[p]
            self._replan_t[p] = _NEG_INF
        return self._replan_t > _NEG_INF

    def _replan_partition(self, p: int, stat: Stat, t0: float,
                          m: FleetMetrics) -> None:
        policy = self.policies[p]
        if self.cur_plans[p] is None:
            plan, dcs = self.planner(self.pattern, stat)
            self.cur_plans[p] = plan
            self._cur_rows[p] = self._plan_row(plan)
            self._old_rows[p] = self._cur_rows[p]
            if policy is not None:
                policy.on_replan(plan, dcs, stat)
            return
        if policy is None or not policy.decide(stat):
            return
        new_plan, dcs = self.planner(self.pattern, stat)
        m.replans += 1
        if new_plan != self.cur_plans[p]:
            self._deploy(p, new_plan, t0, m)
        policy.on_replan(self.cur_plans[p], dcs, stat)

    # -- engine passes -----------------------------------------------------

    def _counters(self, res: StepResult) -> List[np.ndarray]:
        return [np.asarray(x, np.int64)
                for x in (res.full_matches, res.pm_created, res.overflow,
                          res.closure_expansions, res.neg_rejected)]

    def _pass_b(self, state, fc, out, migrating, chunk):
        """Pass B: old plans over an empty chunk (events already ingested)
        pick up matches born before each partition's replan.  Non-migrating
        partitions have an empty born-window (born_hi = -inf) and
        contribute zero matches; their pm/overflow measure join work
        regardless of the born filter, so they are masked out to avoid
        double-charging the fleet counters."""
        if migrating.any():
            empty = chunk._replace(valid=jnp.zeros_like(chunk.valid))
            state, res_b = self._active_fleet.process_chunk(
                state, empty, jnp.asarray(self._old_rows), fc.t0, fc.t1,
                born_lo=_NEG_INF,
                born_hi=self._replan_t.astype(np.float32))
            for i, x in enumerate(self._counters(res_b)):
                out[i] += np.where(migrating, x, 0)
        return state, out

    def _plain_passes(self, state, fc, chunk, migrating):
        """Pass A (current plans ingest the chunk; completed matches are
        restricted to those born at/after each partition's replan time, no
        restriction at -inf) followed by pass B while migrating."""
        state, res = self._active_fleet.process_chunk(
            state, chunk, jnp.asarray(self._cur_rows), fc.t0, fc.t1,
            born_lo=self._replan_t.astype(np.float32), born_hi=_POS_INF)
        return self._pass_b(state, fc, self._counters(res), migrating,
                            chunk)

    # -- main loop ---------------------------------------------------------

    def run(self, fleet_stream: Iterable[FleetChunk],
            resume: bool = False) -> FleetMetrics:
        """Consume a fleet stream through the adaptive loop.

        ``resume=True`` continues the previous ``run``'s stream instead of
        starting a fresh one: ring buffers, estimator windows, deployed
        plans and escalated capacities all carry over, so running a stream
        in segments is equivalent to running it in one call (metrics are
        still per-call).
        """
        m = FleetMetrics(
            per_partition_matches=np.zeros(self.k, np.int64),
            per_partition_deployments=np.zeros(self.k, np.int64))
        state = (self._state if resume and self._state is not None
                 else self.fleet.init_state())
        if self._cur_rows is None:
            probe = self._plan_row(
                self.planner(self.pattern,
                             self.estimator.snapshot(0))[0])
            self._cur_rows = np.tile(probe, (self.k,) + (1,) * probe.ndim)
            self._old_rows = self._cur_rows.copy()
            self.cur_plans = [None] * self.k  # real plans set per partition
        # A policy-free runner is a *pinned-plan* baseline: nothing
        # consumes the statistics, so the per-chunk host Monte-Carlo
        # selectivity sampling would be pure overhead charged to a run
        # that cannot adapt — skip it once the cold plans are planted.
        adaptive = any(pol is not None for pol in self.policies)

        for fc in fleet_stream:
            t_ctl = time.perf_counter()
            if adaptive or any(pl is None for pl in self.cur_plans):
                if adaptive:
                    self._observe(fc)
                for p in range(self.k):
                    self._replan_partition(
                        p, self.estimator.snapshot(p), fc.t0, m)
            migrating = self._fold_lapsed(fc.t0)
            m.control_time_s += time.perf_counter() - t_ctl

            t_eng = time.perf_counter()
            pre_fleet = self._active_fleet
            state, (full, pm, ov, cl, ng) = self._plain_passes(
                state, fc, fc.chunk, migrating)
            # Overflow recovery: a truncated join may have dropped
            # matches, so re-evaluate the window at the next pow2 capacity
            # (events already ingested; the recount replaces the truncated
            # one and the duplicate join work is charged to pm).
            tries = 0
            while (ov.sum() > 0 and self.escalate_on_overflow
                   and tries < self.max_escalations):
                self._active_fleet = self._escalated_fleet()
                m.escalations += 1
                tries += 1
                empty = fc.chunk._replace(
                    valid=jnp.zeros_like(fc.chunk.valid))
                pm_so_far = pm
                state, (full, pm, ov, cl, ng) = self._plain_passes(
                    state, fc, empty, migrating)
                pm = pm + pm_so_far
            if migrating.any():
                # A mid-migration overflow is the retiring plan's: recount
                # at escalated capacity, but don't let the old era's shape
                # outlive its migration window.
                self._active_fleet = pre_fleet
                m.migration_partition_chunks += int(migrating.sum())
            m.engine_time_s += time.perf_counter() - t_eng

            m.chunks += 1
            m.events += int(np.asarray(fc.chunk.valid).sum())
            m.full_matches += int(full.sum())
            m.pm_created += int(pm.sum())
            m.overflow += int(ov.sum())
            m.closure_expansions += int(cl.sum())
            m.neg_rejected += int(ng.sum())
            m.per_partition_matches += full
        self._state = state
        return m


# ---------------------------------------------------------------------------
# Device-monitored fleet loop
# ---------------------------------------------------------------------------


def prime_invariant_policies(pattern: Pattern, planner, policies,
                             caps: Tuple[Optional[int], Optional[int]]):
    """Cold start shared by the monitored runner and the serving front.

    Plans once from the uniform prior, installs that plan's invariant set
    into every partition's policy, and compiles the lowered rows.  Caps
    left as ``None`` default to the cold-start set's exact sizes (stat-
    independent for the greedy planner).  Returns
    ``(plan0, StackedLowered, caps)``.
    """
    stat0 = uniform_stat(pattern.n)
    plan0, dcs0 = planner(pattern, stat0)
    lows = []
    for pol in policies:
        pol.on_replan(plan0, dcs0, stat0)
        lows.append(pol.compile(pattern.n, *caps))
    if caps[0] is None or caps[1] is None:
        caps = (lows[0].active.shape[0], lows[0].scale.shape[-1])
    return plan0, StackedLowered(lows), caps


def replan_flagged_partition(pattern: Pattern, planner, policy,
                             low: StackedLowered, p: int, stat: Stat,
                             caps) -> object:
    """Violation follow-up for one flagged partition: re-run ``A`` on the
    synced statistics, rebase the policy on the fresh DCSs, and redeploy
    the partition's lowered invariant row.  Returns the new plan (the
    caller decides how to deploy it — migration split vs immediate swap).
    """
    new_plan, dcs = planner(pattern, stat)
    policy.on_replan(new_plan, dcs, stat)
    low.write_row(p, policy.compile(pattern.n, *caps))
    return new_plan


class MonitoredFleetRunner(FleetRunner):
    """FleetRunner with §3 invariant verification fused into the data plane.

    The host ``FleetRunner`` evaluates every partition's ``DecisionPolicy``
    in Python each chunk, which requires a device→host sync of the full
    statistics windows for all K partitions.  This runner instead:

    * keeps the statistics rings **on device** (``FleetEngine.init_monitor``
      — exhaustive, RNG-free selectivity observation, see
      ``stats.chunk_observations``);
    * lowers each partition's invariant set into stacked
      ``LoweredInvariants`` tensors (``InvariantPolicy.compile``), so the
      deciding conditions are verified inside the same jitted/vmapped step
      that joins the chunk;
    * pulls only the ``(K,)`` violation-flag vector (plus drift telemetry)
      per chunk and syncs a partition's ``(rates, sel)`` snapshot **only
      when its flag fired** — per-chunk host work is O(violations), not
      O(K·stats).

    Violation-flag contract: flags computed over chunk ``c`` trigger a
    replan that deploys at chunk ``c+1``'s ``t0`` (a *deferred* replan).
    Exactly-once detection is unaffected: deployment still uses the [36]
    born-time migration split at the deployment chunk's ``t0``, and plan
    choice never changes *which* matches exist, only the join work to find
    them.  A deployment remains a plan-matrix row write plus an
    invariant-matrix row write — never a recompile.

    ``max_inv`` / ``max_terms`` fix the stacked invariant tensor shape.
    They default to the sizes of the cold-start (uniform-prior) invariant
    set, which is exact for the greedy planner (its DCS structure is
    stat-independent); for tree planners pass explicit worst-case caps —
    an overflowing replan raises rather than silently truncating.
    """

    def __init__(self, pattern: Pattern, k: int, planner=None,
                 policy_factory=None,
                 engine_cfg: EngineConfig = EngineConfig(),
                 estimator_buckets: int = 16,
                 max_inv: Optional[int] = None,
                 max_terms: Optional[int] = None,
                 laplace: float = 1.0,
                 escalate_on_overflow: bool = True,
                 max_escalations: int = 4, seed: int = 0,
                 superchunk: int = 1, mesh=None):
        from .compat import warn_legacy

        warn_legacy("MonitoredFleetRunner")
        policy_factory = policy_factory or (
            lambda: InvariantPolicy(k=1, d=0.0))
        super().__init__(pattern, k, planner=planner,
                         policy_factory=policy_factory,
                         engine_cfg=engine_cfg,
                         estimator_buckets=estimator_buckets,
                         laplace=laplace,
                         escalate_on_overflow=escalate_on_overflow,
                         max_escalations=max_escalations, seed=seed,
                         mesh=mesh)
        for pol in self.policies:
            if not isinstance(pol, InvariantPolicy):
                raise TypeError(
                    "device monitoring verifies lowered invariant sets; "
                    "policy_factory must produce InvariantPolicy")
        if superchunk < 1:
            raise ValueError("superchunk must be >= 1")
        self.superchunk = int(superchunk)
        self.monitor_buckets = estimator_buckets
        self._caps = (max_inv, max_terms)
        self._low: Optional[StackedLowered] = None
        # resume carry (alongside FleetRunner._state): monitor rings and
        # the deferred flag from the previous run's final chunk — which a
        # single-call run can never apply, but a resumed continuation
        # must, to stay equivalent to one continuous stream.
        self._monitor = None
        self._pending: Optional[np.ndarray] = None
        self._pend_rates = None
        self._pend_sel = None

    # -- invariant deployment ---------------------------------------------

    def _prime(self) -> None:
        """Cold start: plan every partition from the uniform prior; real
        statistics arrive with the first chunks and fire the invariants."""
        plan0, self._low, self._caps = prime_invariant_policies(
            self.pattern, self.planner, self.policies, self._caps)
        row0 = self._plan_row(plan0)
        self._cur_rows = np.tile(row0, (self.k,) + (1,) * row0.ndim)
        self._old_rows = self._cur_rows.copy()
        self.cur_plans = [plan0] * self.k

    # -- main loop ---------------------------------------------------------

    def _apply_pending(self, pending, rates, sel, t0: float,
                       m: FleetMetrics) -> None:
        """Deferred flag-triggered replans: the planner runs only for
        partitions whose device flag fired on the last processed chunk,
        each costing exactly one statistics sync.  Violations are counted
        here, at application time, so ``violations == host_syncs ==
        replans`` holds by construction (a flag on the stream's final
        chunk never gets applied and is not counted)."""
        for p in np.nonzero(pending)[0]:
            stat = Stat(np.asarray(rates[p], np.float64),
                        np.asarray(sel[p], np.float64))
            m.violations += 1
            m.host_syncs += 1
            new_plan = replan_flagged_partition(
                self.pattern, self.planner, self.policies[p],
                self._low, p, stat, self._caps)
            m.replans += 1
            if new_plan != self.cur_plans[p]:
                self._deploy(p, new_plan, t0, m)

    def _carry(self, resume: bool):
        """Stream carry shared by both monitored loops: either the
        previous run's (state, monitor, pending flags + statistic slices)
        or a fresh stream."""
        if resume and self._state is not None:
            return (self._state, self._monitor, self._pending,
                    self._pend_rates, self._pend_sel)
        return (self.fleet.init_state(),
                self.fleet.init_monitor(self.monitor_buckets),
                np.zeros(self.k, bool), None, None)

    def _save_carry(self, state, monitor, pending, rates, sel) -> None:
        self._state, self._monitor = state, monitor
        self._pending = pending
        self._pend_rates, self._pend_sel = rates, sel

    def run(self, fleet_stream: Iterable[FleetChunk],
            resume: bool = False) -> FleetMetrics:
        if self.superchunk > 1:
            return self._run_scanned(fleet_stream, resume)
        m = FleetMetrics(
            per_partition_matches=np.zeros(self.k, np.int64),
            per_partition_deployments=np.zeros(self.k, np.int64))
        state, monitor, pending, rates_dev, sel_dev = self._carry(resume)
        if self._low is None:
            self._prime()

        for fc in fleet_stream:
            t_ctl = time.perf_counter()
            self._apply_pending(pending, rates_dev, sel_dev, fc.t0, m)
            pending[:] = False
            migrating = self._fold_lapsed(fc.t0)
            m.control_time_s += time.perf_counter() - t_ctl

            t_eng = time.perf_counter()
            # Pass A, fused: joins + ring update + invariant verification
            # in ONE compiled vmapped call.
            state, monitor, res, violated, drift, rates_dev, sel_dev = \
                self._active_fleet.process_chunk_monitored(
                    state, monitor, fc.chunk, jnp.asarray(self._cur_rows),
                    self._low.device(), fc.t0, fc.t1,
                    born_lo=self._replan_t.astype(np.float32),
                    born_hi=_POS_INF)
            state, out = self._pass_b(state, fc, self._counters(res),
                                      migrating, fc.chunk)
            full, pm, ov, cl, ng = out
            # Overflow-escalation recounts run the *plain* passes so the
            # statistics ring is updated exactly once per chunk (by the
            # monitored pass above) and flags are never double-observed.
            pre_fleet = self._active_fleet
            tries = 0
            while (ov.sum() > 0 and self.escalate_on_overflow
                   and tries < self.max_escalations):
                self._active_fleet = self._escalated_fleet()
                m.escalations += 1
                tries += 1
                empty = fc.chunk._replace(
                    valid=jnp.zeros_like(fc.chunk.valid))
                pm_so_far = pm
                state, (full, pm, ov, cl, ng) = self._plain_passes(
                    state, fc, empty, migrating)
                pm = pm + pm_so_far
            if migrating.any():
                # Mid-migration overflow: transient recount, not a regime.
                self._active_fleet = pre_fleet
                m.migration_partition_chunks += int(migrating.sum())

            # The entire per-chunk host round-trip: one (K,) bool vector.
            pending = np.asarray(violated).copy()
            m.last_drift = np.asarray(drift, np.float32)
            m.engine_time_s += time.perf_counter() - t_eng

            m.chunks += 1
            m.events += int(np.asarray(fc.chunk.valid).sum())
            m.full_matches += int(full.sum())
            m.pm_created += int(pm.sum())
            m.overflow += int(ov.sum())
            m.closure_expansions += int(cl.sum())
            m.neg_rejected += int(ng.sum())
            m.per_partition_matches += full
        self._save_carry(state, monitor, pending, rates_dev, sel_dev)
        return m

    # -- superchunk (scanned) loop -----------------------------------------

    def _run_scanned(self, fleet_stream: Iterable[FleetChunk],
                     resume: bool = False) -> FleetMetrics:
        """The per-chunk loop above with the host taken out of it.

        ``lax.scan`` rolls up to ``superchunk`` chunks per dispatch; flags,
        drift and counters accumulate on device (``core.scan``).  The host
        surfaces only at window boundaries — or, via the optimistic prefix
        re-run, immediately after an in-window invariant flag / overflow,
        so deferred-replan and escalation semantics stay **bit-identical**
        to per-chunk stepping (asserted by ``tests/test_superchunk.py``).
        """
        from .scan import first_event, stack_window, window_control

        s_cap = self.superchunk
        m = FleetMetrics(
            per_partition_matches=np.zeros(self.k, np.int64),
            per_partition_deployments=np.zeros(self.k, np.int64))
        state, monitor, pending, pend_rates, pend_sel = self._carry(resume)
        if self._low is None:
            self._prime()
        it = iter(fleet_stream)
        buf: List[FleetChunk] = []
        exhausted = False

        while True:
            while len(buf) < s_cap and not exhausted:
                try:
                    buf.append(next(it))
                except StopIteration:
                    exhausted = True
            if not buf:
                break
            t_ctl = time.perf_counter()
            self._apply_pending(pending, pend_rates, pend_sel,
                                buf[0].t0, m)
            pending[:] = False
            n_en = len(buf)
            ctl = window_control(self._replan_t, self._migration_until,
                                 [fc.t0 for fc in buf], s_cap)
            xs = stack_window([fc.chunk for fc in buf],
                              [fc.t0 for fc in buf],
                              [fc.t1 for fc in buf], ctl, s_cap)
            cur_rows = jnp.asarray(self._cur_rows)
            old_rows = jnp.asarray(self._old_rows)
            m.control_time_s += time.perf_counter() - t_ctl

            t_eng = time.perf_counter()
            scan = self._active_fleet.superchunk_scan(monitored=True)
            low_dev = self._low.device()
            state2, monitor2, ys = scan(state, monitor, cur_rows, old_rows,
                                        low_dev, xs)
            # Eager readback is counters + flags + drift only; the (S, K,
            # n[, n]) statistic stacks stay on device and are pulled
            # per-partition at application time — host traffic stays
            # O(violations), not O(S·K·stats), exactly as per-chunk.
            (full_h, pm_h, ov_h, cl_h, ng_h, violated_h, drift_h) = \
                jax.device_get((ys.full, ys.pm, ys.overflow, ys.closure,
                                ys.neg, ys.violated, ys.drift))
            f = first_event(violated_h, ov_h, n_en,
                            self.escalate_on_overflow)
            if f is not None and f < n_en - 1:
                # In-window event: replay the prefix [0..f] from the saved
                # pre-window carry (bitwise-identical compute) so the host
                # can replan / escalate before chunk f+1 runs — exactly
                # the per-chunk contract.  Costs one extra dispatch, only
                # when an event actually fired.
                en = np.zeros(s_cap, bool)
                en[:f + 1] = True
                xs_pre = xs._replace(enabled=jnp.asarray(en))
                state2, monitor2, _ = scan(state, monitor, cur_rows,
                                           old_rows, low_dev, xs_pre)
            accept = n_en if f is None else f + 1
            last = accept - 1
            state, monitor = state2, monitor2

            # Commit host mirrors to the fold state at the last accepted
            # chunk (float64, same trajectory the per-chunk loop walks —
            # including retiring the lapsed partitions' old plans).
            self._replan_t = ctl.replan_seq[last].copy()
            lapsed = ctl.old_sel[last]
            self._old_rows[lapsed] = self._cur_rows[lapsed]
            for p in np.nonzero(lapsed)[0]:
                self.old_plans[p] = None

            counters = [np.asarray(c, np.int64)
                        for c in (full_h, pm_h, ov_h, cl_h, ng_h)]
            full_l, pm_l, ov_l, cl_l, ng_l = (c[last].copy()
                                              for c in counters)
            pre_fleet = self._active_fleet
            if (self.escalate_on_overflow and ov_l.sum() > 0):
                # Overflow recovery for the event chunk, identical to the
                # per-chunk loop: re-evaluate at the next pow2 match
                # capacity from the post-chunk state (events are already
                # ingested); the escalated fleet persists for the
                # following windows.
                migrating_l = ctl.migrating[last]
                tries = 0
                while ov_l.sum() > 0 and tries < self.max_escalations:
                    self._active_fleet = self._escalated_fleet()
                    m.escalations += 1
                    tries += 1
                    empty = buf[last].chunk._replace(
                        valid=jnp.zeros_like(buf[last].chunk.valid))
                    pm_so_far = pm_l
                    state, (full_l, pm_l, ov_l, cl_l, ng_l) = \
                        self._plain_passes(state, buf[last], empty,
                                           migrating_l)
                    pm_l = pm_l + pm_so_far
            if ctl.migrating[last].any():
                # Mid-migration overflow: transient recount, not a regime
                # (mirrors the per-chunk loop chunk-for-chunk).
                self._active_fleet = pre_fleet

            for s in range(accept):
                m.chunks += 1
                m.events += int(np.asarray(buf[s].chunk.valid).sum())
                row = ((full_l, pm_l, ov_l, cl_l, ng_l) if s == last
                       else tuple(c[s] for c in counters))
                full, pm, ov, cl, ng = row
                m.full_matches += int(full.sum())
                m.pm_created += int(pm.sum())
                m.overflow += int(ov.sum())
                m.closure_expansions += int(cl.sum())
                m.neg_rejected += int(ng.sum())
                m.per_partition_matches += np.asarray(full, np.int64)
            m.migration_partition_chunks += int(
                ctl.migrating[:accept].sum())
            m.last_drift = np.asarray(drift_h[last], np.float32)
            pending = np.asarray(violated_h[last]).copy()
            # Device slices: _apply_pending materializes row p only for
            # partitions whose flag actually fired.
            pend_rates = ys.rates[last]
            pend_sel = ys.sel[last]
            m.engine_time_s += time.perf_counter() - t_eng
            buf = buf[accept:]
        self._save_carry(state, monitor, pending, pend_rates, pend_sel)
        return m
