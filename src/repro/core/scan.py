"""Superchunk data plane: roll S chunks through one compiled ``lax.scan``.

The per-chunk runners (``FleetRunner`` / ``MonitoredFleetRunner`` and the
serving fronts) cross the host↔device boundary once per chunk: dispatch a
compiled step, read back a ``(K,)`` flag vector, decide, repeat.  At fleet
scale the Python dispatch loop — not the join kernel — becomes the
bottleneck, inverting the paper's §2.2 premise that adaptation decisions
are cheap relative to detection.  This module removes the host from the
per-chunk loop:

* the fused process(+monitor) step is re-expressed as a **pure scan step**
  ``body(carry, x) -> (carry, out)`` with ``carry = (Buffers,
  MonitorState)`` — exactly the state the per-chunk loop threads by hand;
* ``lax.scan`` rolls ``S`` chunks ("a superchunk") through ONE dispatch;
  violation flags, drift telemetry and per-chunk counters accumulate on
  device as stacked ``(S, K, ...)`` outputs;
* the host syncs, replans, and deploys only at superchunk boundaries.

Per-chunk control that the runners used to do on the host *between* steps
is split in two:

* **Precomputed control (host, exact)** — migration folding depends only
  on ``replan_t`` / ``migration_until`` and each chunk's ``t0``, all known
  before the window is dispatched.  The host precomputes, in float64
  (bit-identical to the per-chunk runner's ``_fold_lapsed``), the per-chunk
  ``born_lo`` vectors, migrating masks and old-row selectors and feeds
  them to the scan as inputs (``SuperchunkXs``).  Plan rows and lowered
  invariant tensors are window-constant arguments — they change only at
  boundaries, which is what makes the scan legal.
* **Reactive control (optimistic restart)** — an invariant violation (or
  an overflow needing escalation) at in-window chunk ``f`` must surface to
  the host so the replan deploys at chunk ``f+1``, exactly as in the
  per-chunk loop.  The scan cannot early-exit, so the driver runs the
  window optimistically, inspects the stacked flags, and — in the rare
  event case — re-runs the *prefix* ``[0..f]`` from the saved pre-window
  carry with the remaining chunks disabled (the ``enabled`` input;
  deterministic compute makes the prefix bitwise identical), then resumes
  from ``f+1`` after replanning.  Violation-free windows (the common case,
  by §3's low-violation-rate design) cost exactly one dispatch for S
  chunks; each event costs one extra dispatch.  Semantics are therefore
  **bit-identical** to per-chunk stepping for every superchunk size.

Sharding: every carry/row/lowered leaf carries a leading K axis and every
scan input/output a leading (S, K), so the whole scanned function maps
onto a 1-D device mesh with ``shard_map`` under a single partition rule
(K split over the ``cep`` axis, everything else replicated).  Partitions
are independent — the sharded scan needs **zero** cross-device
collectives; see ``distributed.sharding.fleet_pspec``.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .engine import NEG_INF, POS_INF, Chunk, make_monitored_process


class SuperchunkXs(NamedTuple):
    """Per-chunk scan inputs; every leaf has a leading ``S`` axis.

    ``enabled`` gates the whole step (disabled chunks pass the carry
    through untouched) — it implements both tail padding of a short final
    window and the prefix re-run after an in-window event.  ``born_lo`` /
    ``migrating`` / ``old_sel`` are the host-precomputed migration fold
    (see module docstring); for control planes without the [36] migration
    split they are just ``-inf`` / ``False`` / ``False``.
    """

    chunk: Chunk          # (S, K, cap) / (S, K, cap, A) fields
    t0: jax.Array         # (S,) f32 shared chunk clock
    t1: jax.Array         # (S,) f32
    enabled: jax.Array    # (S,) bool
    born_lo: jax.Array    # (S, K) f32 — post-fold replan_t per chunk
    migrating: jax.Array  # (S, K) bool — partition mid-migration this chunk
    old_sel: jax.Array    # (S, K) bool — migration lapsed: old row := cur row


class SuperchunkOut(NamedTuple):
    """Per-chunk scan outputs; every leaf has a leading ``(S, K)``."""

    full: jax.Array       # i32 full matches (pass A + masked pass B)
    pm: jax.Array         # i32 partial matches materialized
    overflow: jax.Array   # i32 candidates dropped by capacity
    closure: jax.Array    # i32 Kleene companion count
    neg: jax.Array        # i32 negation vetoes
    violated: jax.Array   # bool invariant flags (monitored; else False)
    drift: jax.Array      # f32 §3.4 relative margins (monitored; else -inf)
    rates: jax.Array      # (S, K, n) f32 monitor snapshot at each chunk
    sel: jax.Array        # (S, K, n, n) f32


def make_superchunk_scan(process_fn, spec, monitored: bool,
                         laplace: float = 1.0, mesh=None,
                         plan_operands=None):
    """Build the compiled superchunk scan for one engine configuration.

    Returns ``scan(buffers, monitor, cur_rows, old_rows, lowered, xs) ->
    (buffers, monitor, SuperchunkOut)`` where state/rows/lowered carry a
    leading K axis and ``xs`` is a :class:`SuperchunkXs`.  ``monitored``
    fuses the statistics rings + lowered-invariant verification into each
    step (``monitor``/``lowered`` may be ``None`` otherwise).  With
    ``mesh`` the whole scan is ``shard_map``-ped over the mesh's ``cep``
    axis — one dispatch drives D devices for S chunks with no collectives.

    ``plan_operands`` (engines that support it) maps stacked plan rows to
    their precomputed join operands (e.g. ``OrderEngine.plan_operands``);
    it runs inside the compiled scan but OUTSIDE the ``lax.scan`` body, so
    the plan-constant operand strips are derived once per dispatch and the
    per-chunk step is reduced to gather + kernel.  Strips are a per-row
    function, so blending cur/old per chunk leaf-wise commutes with the
    derivation — per-chunk semantics stay bit-identical.
    """
    n = spec.n
    process = jax.vmap(process_fn)
    mprocess = (jax.vmap(make_monitored_process(process_fn, spec, laplace))
                if monitored else None)

    def body(cur_rows, old_rows, lowered, carry, x: SuperchunkXs):
        def run(carry):
            buffers, monitor = carry
            kk = x.born_lo.shape[0]
            t0v = jnp.broadcast_to(x.t0.astype(jnp.float32), (kk,))
            t1v = jnp.broadcast_to(x.t1.astype(jnp.float32), (kk,))
            neg_v = jnp.full((kk,), NEG_INF, jnp.float32)
            pos_v = jnp.full((kk,), POS_INF, jnp.float32)

            def blend(c, o):  # per-partition row select (pytree-safe)
                sel = x.old_sel.reshape((kk,) + (1,) * (c.ndim - 1))
                return jnp.where(sel, c, o)

            old_eff = jax.tree.map(blend, cur_rows, old_rows)

            # Pass A: current plans ingest the chunk; completed matches
            # restricted to those born at/after each partition's replan.
            if monitored:
                buffers, monitor, res, violated, drift, rates, sel = \
                    mprocess(buffers, monitor, x.chunk, cur_rows, lowered,
                             t0v, t1v, x.born_lo, pos_v)
            else:
                buffers, res = process(buffers, x.chunk, cur_rows,
                                       t0v, t1v, x.born_lo, pos_v)
                violated = jnp.zeros((kk,), bool)
                drift = jnp.full((kk,), NEG_INF, jnp.float32)
                rates = jnp.zeros((kk, n), jnp.float32)
                sel = jnp.zeros((kk, n, n), jnp.float32)
            counters = tuple(
                jnp.asarray(c, jnp.int32)
                for c in (res.full_matches, res.pm_created, res.overflow,
                          res.closure_expansions, res.neg_rejected))

            # Pass B: old plans over an empty chunk pick up matches born
            # before each partition's replan; non-migrating partitions are
            # masked out of the counters (their born-window is empty but
            # pm/overflow measure join work regardless).
            def with_pass_b(args):
                buffers, counters = args
                empty = x.chunk._replace(
                    valid=jnp.zeros_like(x.chunk.valid))
                buffers, res_b = process(buffers, empty, old_eff,
                                         t0v, t1v, neg_v, x.born_lo)
                extra = (res_b.full_matches, res_b.pm_created,
                         res_b.overflow, res_b.closure_expansions,
                         res_b.neg_rejected)
                counters = tuple(
                    c + jnp.where(x.migrating, e.astype(jnp.int32), 0)
                    for c, e in zip(counters, extra))
                return buffers, counters

            buffers, counters = jax.lax.cond(
                x.migrating.any(), with_pass_b, lambda a: a,
                (buffers, counters))
            out = SuperchunkOut(*counters, violated, drift, rates, sel)
            return (buffers, monitor), out

        def skip(carry):
            kk = x.born_lo.shape[0]
            out = SuperchunkOut(
                *(jnp.zeros((kk,), jnp.int32) for _ in range(5)),
                jnp.zeros((kk,), bool),
                jnp.full((kk,), NEG_INF, jnp.float32),
                jnp.zeros((kk, n), jnp.float32),
                jnp.zeros((kk, n, n), jnp.float32))
            return carry, out

        return jax.lax.cond(x.enabled, run, skip, carry)

    def scan_fn(buffers, monitor, cur_rows, old_rows, lowered, xs):
        if plan_operands is not None:
            # Hoisted: once per superchunk dispatch, not once per chunk.
            cur_rows = plan_operands(cur_rows)
            old_rows = plan_operands(old_rows)
        carry, ys = jax.lax.scan(
            functools.partial(body, cur_rows, old_rows, lowered),
            (buffers, monitor), xs)
        return carry[0], carry[1], ys

    if mesh is not None:
        from ..distributed.sharding import shard_fleet_scan
        scan_fn = shard_fleet_scan(scan_fn, mesh)
    return jax.jit(scan_fn)


# ---------------------------------------------------------------------------
# Host-side window control (exact float64 twin of the per-chunk fold)
# ---------------------------------------------------------------------------


class WindowControl(NamedTuple):
    """Precomputed per-chunk migration control for one superchunk window.

    ``replan_seq[s]`` is the float64 ``replan_t`` state *after* the fold at
    chunk ``s`` — the host rolls its mirrors forward to row ``f`` once the
    window's first ``f+1`` chunks are accepted.
    """

    born_lo: np.ndarray     # (S, K) f32 — pass-A born_lo / pass-B born_hi
    migrating: np.ndarray   # (S, K) bool
    old_sel: np.ndarray     # (S, K) bool — cumulative "old row := cur row"
    replan_seq: np.ndarray  # (S, K) f64


def window_control(replan_t: np.ndarray, migration_until: np.ndarray,
                   t0s: Sequence[float], s_pad: int) -> WindowControl:
    """Roll the [36] migration fold over a window of chunk starts.

    Bit-identical to ``FleetRunner._fold_lapsed`` applied per chunk: all
    comparisons in float64 on the host, only the final ``born_lo`` cast to
    f32 (exactly what the per-chunk runner feeds the device).  Does NOT
    mutate its inputs — the caller commits row ``f`` after acceptance.
    ``s_pad`` rows beyond ``len(t0s)`` are emitted disabled-shaped (zeros).
    """
    k = replan_t.shape[0]
    s = len(t0s)
    rt = np.asarray(replan_t, np.float64).copy()
    born_lo = np.full((s_pad, k), NEG_INF, np.float32)
    migrating = np.zeros((s_pad, k), bool)
    old_sel = np.zeros((s_pad, k), bool)
    replan_seq = np.full((s_pad, k), NEG_INF, np.float64)
    folded = np.zeros(k, bool)
    for i, t0 in enumerate(t0s):
        lapsed = (rt > NEG_INF) & (t0 >= migration_until)
        rt[lapsed] = NEG_INF
        folded |= lapsed
        born_lo[i] = rt.astype(np.float32)
        migrating[i] = rt > NEG_INF
        old_sel[i] = folded
        replan_seq[i] = rt
    return WindowControl(born_lo, migrating, old_sel, replan_seq)


def static_control(k: int, s_pad: int) -> WindowControl:
    """No-migration window control (the serving fronts deploy immediately,
    so born-windows are unbounded and pass B never runs)."""
    return WindowControl(
        born_lo=np.full((s_pad, k), NEG_INF, np.float32),
        migrating=np.zeros((s_pad, k), bool),
        old_sel=np.zeros((s_pad, k), bool),
        replan_seq=np.full((s_pad, k), NEG_INF, np.float64))


def stack_window(chunks: Sequence[Chunk], t0s, t1s, ctl: WindowControl,
                 s_pad: int) -> SuperchunkXs:
    """Stack a window of stacked ``(K, ...)`` chunks into scan inputs.

    Short windows (stream tail, prefix re-runs) are padded to ``s_pad``
    with disabled repeats of the last chunk so one compiled scan serves
    every window length.
    """
    s = len(chunks)
    if s == 0:
        raise ValueError("empty superchunk window")
    padded = list(chunks) + [chunks[-1]] * (s_pad - s)
    chunk = jax.tree.map(lambda *xs: jnp.stack(xs), *padded)
    t0a = np.zeros(s_pad, np.float32)
    t1a = np.zeros(s_pad, np.float32)
    t0a[:s] = np.asarray(t0s, np.float32)
    t1a[:s] = np.asarray(t1s, np.float32)
    enabled = np.zeros(s_pad, bool)
    enabled[:s] = True
    return SuperchunkXs(
        chunk=chunk,
        t0=jnp.asarray(t0a),
        t1=jnp.asarray(t1a),
        enabled=jnp.asarray(enabled),
        born_lo=jnp.asarray(ctl.born_lo),
        migrating=jnp.asarray(ctl.migrating),
        old_sel=jnp.asarray(ctl.old_sel),
    )


def first_event(violated: np.ndarray, overflow: np.ndarray,
                n_enabled: int, escalate: bool) -> Optional[int]:
    """Index of the first in-window chunk needing host attention.

    An *event* is an invariant flag on any partition, or (when escalation
    is on) a truncated join — both require the host before the *next*
    chunk runs.  Returns None when the window is event-free.  Flags may
    carry any trailing shape after the leading chunk axis — ``(S, K)`` for
    the single-pattern fleet, ``(S, K, Qb)`` for the rulebook plane.
    """
    ev = violated[:n_enabled].reshape(n_enabled, -1).any(axis=1)
    if escalate:
        ev = ev | (overflow[:n_enabled].reshape(n_enabled, -1).sum(axis=1)
                   > 0)
    idx = np.nonzero(ev)[0]
    return int(idx[0]) if idx.size else None


# ---------------------------------------------------------------------------
# The scanned rulebook plane: S chunks × K partitions × Qb rules / dispatch
# ---------------------------------------------------------------------------


class RulebookXs(NamedTuple):
    """Rulebook scan inputs; every leaf has a leading ``S`` axis.

    The rulebook control plane deploys plan rows immediately (serving
    semantics: no [36] migration split), so the only reactive control is
    the invariant flag — ``enabled`` implements tail padding and the
    optimistic prefix re-run exactly as on the single-pattern plane.
    """

    chunk: Chunk        # (S, K, cap) / (S, K, cap, A) fields
    t0: jax.Array       # (S,) f32
    t1: jax.Array       # (S,) f32
    enabled: jax.Array  # (S,) bool


class RulebookOut(NamedTuple):
    """Rulebook scan outputs; every leaf has a leading ``(S, K, Qb)``."""

    full: jax.Array      # i32 full matches per rule
    pm: jax.Array        # i32 partial matches materialized
    overflow: jax.Array  # i32 candidates dropped by capacity
    closure: jax.Array   # i32 Kleene companion count
    neg: jax.Array       # i32 negation vetoes
    violated: jax.Array  # bool per-(q, k) invariant flags
    drift: jax.Array     # f32 relative margins (monitored; else -inf)
    rates: jax.Array     # (S, K, Qb, n) f32 monitor snapshot per chunk
    sel: jax.Array       # (S, K, Qb, n, n) f32


def stack_rulebook_window(chunks: Sequence[Chunk], t0s, t1s,
                          s_pad: int) -> RulebookXs:
    """Stack a window of stacked ``(K, ...)`` chunks into rulebook scan
    inputs, padding short windows with disabled repeats of the last chunk
    (one compiled scan serves every window length)."""
    s = len(chunks)
    if s == 0:
        raise ValueError("empty superchunk window")
    padded = list(chunks) + [chunks[-1]] * (s_pad - s)
    chunk = jax.tree.map(lambda *xs: jnp.stack(xs), *padded)
    t0a = np.zeros(s_pad, np.float32)
    t1a = np.zeros(s_pad, np.float32)
    t0a[:s] = np.asarray(t0s, np.float32)
    t1a[:s] = np.asarray(t1s, np.float32)
    enabled = np.zeros(s_pad, bool)
    enabled[:s] = True
    return RulebookXs(chunk=chunk, t0=jnp.asarray(t0a),
                      t1=jnp.asarray(t1a), enabled=jnp.asarray(enabled))


def make_rulebook_scan(bspec, cfg, k: int, monitored: bool,
                       laplace: float = 1.0, mesh=None):
    """Compile (or fetch from the trace memo) the scanned rulebook plane.

    Returns a ``multipattern._Plane`` whose ``fn`` has signature::

        scan(state, monitor, ops, share, plans, lowered, xs)
            -> (state, monitor, RulebookOut)

    with ``state``/``monitor``/``plans``/``lowered`` leading with K,
    ``ops``/``share`` fleet-wide, and ``xs`` a :class:`RulebookXs`.
    ``monitor``/``lowered`` are ``None`` when unmonitored.  Like the
    per-chunk rulebook plane, the memo key excludes every capacity (Qb,
    lattice class counts, S): growing a bucket under superchunk re-enters
    the SAME jitted callable with a new shape — one retrace, no new memo
    entry.  Meshed planes are never shared (mesh objects pin device
    orders).
    """
    from .fleet import _shared_trace
    from .multipattern import _Plane, _make_bucket_step

    key = (None if mesh is not None
           else ("rulebook-scan", bspec, cfg, int(k), bool(monitored),
                 float(laplace)))

    def build() -> _Plane:
        plane = _Plane()
        step = _make_bucket_step(bspec, cfg, monitored, laplace)
        n = bspec.n
        if monitored:
            kstep = jax.vmap(
                step, in_axes=(0, 0, 0, None, None, 0, 0, None, None))
        else:
            kstep = jax.vmap(step, in_axes=(0, 0, None, None, 0, None, None))

        def body(ops, share, plans, lowered, carry, x: RulebookXs):
            def run(carry):
                state, monitor = carry
                kk, qb = state.ts.shape[:2]
                if monitored:
                    state, monitor, res, violated, drift, rates, sel = \
                        kstep(state, monitor, x.chunk, ops, share, plans,
                              lowered, x.t0, x.t1)
                else:
                    state, res = kstep(state, x.chunk, ops, share, plans,
                                       x.t0, x.t1)
                    violated = jnp.zeros((kk, qb), bool)
                    drift = jnp.full((kk, qb), NEG_INF, jnp.float32)
                    rates = jnp.zeros((kk, qb, n), jnp.float32)
                    sel = jnp.zeros((kk, qb, n, n), jnp.float32)
                out = RulebookOut(res.full, res.pm, res.overflow,
                                  res.closure, res.neg, violated, drift,
                                  rates, sel)
                return (state, monitor), out

            def skip(carry):
                state, _ = carry
                kk, qb = state.ts.shape[:2]
                out = RulebookOut(
                    *(jnp.zeros((kk, qb), jnp.int32) for _ in range(5)),
                    jnp.zeros((kk, qb), bool),
                    jnp.full((kk, qb), NEG_INF, jnp.float32),
                    jnp.zeros((kk, qb, n), jnp.float32),
                    jnp.zeros((kk, qb, n, n), jnp.float32))
                return carry, out

            return jax.lax.cond(x.enabled, run, skip, carry)

        def scan_fn(state, monitor, ops, share, plans, lowered, xs):
            plane.traces += 1  # python side effect: once per (re)trace
            carry, ys = jax.lax.scan(
                functools.partial(body, ops, share, plans, lowered),
                (state, monitor), xs)
            return carry[0], carry[1], ys

        plane.fn = jax.jit(_shard_rulebook_scan(scan_fn, mesh))
        return plane

    return _shared_trace(key, build)


def _shard_rulebook_scan(fn, mesh):
    """shard_map the rulebook scan over the 1-D "cep" mesh: state and
    per-partition control K-lead, ops/share are fleet-wide (replicated),
    xs chunks lead with (S, K).  Partitions stay independent — zero
    collectives, sharding never changes semantics."""
    if mesh is None:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from ..distributed.sharding import CEP_AXIS

    kl = PartitionSpec(CEP_AXIS)
    skl = PartitionSpec(None, CEP_AXIS)
    rep = PartitionSpec()
    xs_spec = RulebookXs(chunk=skl, t0=rep, t1=rep, enabled=rep)
    in_specs = (kl, kl, rep, rep, kl, kl, xs_spec)
    out_specs = (kl, kl, skl)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)
