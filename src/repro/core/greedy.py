"""Instrumented greedy order-based plan generation (paper §4.1, Algorithm 2).

The greedy heuristic of Swami [47], as adapted to CEP in [36, 35]: iteratively
append the event type minimizing

    r_j · sel_jj · ∏_{k already selected} sel_{pk, j},

i.e. the marginal growth of the expected partial-match count.  With no
predicates this degenerates to sorting by arrival rate (Example 1).

Instrumentation (§3.1): each greedy step ``i`` fixes one building block
("process position ``p_i`` at step ``i``").  Every argmin comparison the
winner survives is a block-building comparison; its deciding condition
``score_i(winner) < score_i(candidate)`` joins the block's DCS.  Step ``i``
therefore contributes exactly ``n − i`` conditions, mirroring the paper's
min-sort example (DCS sizes n−1, n−2, …, 0).

Determinism: ties are broken toward the lower pattern position, making ``A``
a deterministic function of ``Stat`` as Theorems 1–2 require.
"""

from __future__ import annotations

from typing import Tuple

from .invariants import DCSList, DecidingCondition
from .patterns import Pattern
from .plans import OrderPlan, order_step_score_expr
from .stats import Stat


def greedy_order_plan(
    pattern: Pattern, stat: Stat, pin: Tuple[int, ...] = ()
) -> Tuple[OrderPlan, DCSList]:
    """Run Algorithm 2 and capture per-block deciding condition sets.

    ``pin`` forces the first ``len(pin)`` plan steps to the given
    positions regardless of statistics.  The rulebook's sharing lattice
    uses pins of arbitrary depth: a rule whose deepest shared sub-join
    sits at lattice depth ``d`` is planned with ``pin`` equal to the
    class representative's first ``d + 2`` order positions, so every
    member of a shared class walks the identical interior sub-join
    chain and only the *unshared* suffix is chosen by statistics.
    Pinned steps are decided by fiat, not by argmin comparisons, so
    they contribute empty deciding-condition sets — the invariant
    machinery simply has nothing to verify for them.
    """
    if len(pin) > pattern.n:
        raise ValueError(f"pin of length {len(pin)} exceeds pattern "
                         f"arity {pattern.n}")
    n = pattern.n
    sel_pairs = frozenset(
        {(p, q) for p, q in pattern.selectivity_pairs()}
        | {(p, p) for p in range(n) if pattern.pred_tensors()["op"][p, p] != 0}
    )
    remaining = list(range(n))
    prefix: Tuple[int, ...] = ()
    order = []
    dcs_list: DCSList = []

    for step in range(n):
        if step < len(pin):
            winner = pin[step]
            if winner not in remaining:
                raise ValueError(f"pinned position {winner} not available "
                                 f"at step {step}")
            dcs_list.append((f"pin{step}:pos{winner}", []))
            order.append(winner)
            prefix = prefix + (winner,)
            remaining.remove(winner)
            continue
        # Score every remaining candidate under the current prefix.
        exprs = {
            j: order_step_score_expr(j, prefix, sel_pairs) for j in remaining
        }
        scores = {j: exprs[j].eval(stat) for j in remaining}
        # Deterministic argmin (ties -> lower position index).
        winner = min(remaining, key=lambda j: (scores[j], j))
        block = f"step{step}:pos{winner}"
        conds = [
            DecidingCondition.make(exprs[winner], exprs[j], block)
            for j in remaining
            if j != winner
        ]
        dcs_list.append((block, conds))
        order.append(winner)
        prefix = prefix + (winner,)
        remaining.remove(winner)

    return OrderPlan(tuple(order)), dcs_list
