"""Multi-pattern data plane: Q heterogeneous rules through one compiled step.

``core.engine`` compiles ONE pattern into a fused join cascade whose plan is
data.  This module generalizes the remaining static ingredient — the pattern
itself — into data: every structural quantity the engine bakes into the
trace (type ids, predicate op/attr/theta tensors, the window, the negation
and Kleene annotations, sequence-ness) becomes a tensor with a leading
**rule axis** (``Qb``), so one traced program evaluates a whole *bucket* of
same-arity rules per dispatch.  Stacked next to the existing K-partition
axis this yields the Q×K rulebook plane:

* ``RuleOps`` — the per-rule structural tensors (host-lowered from a
  ``Pattern`` by :func:`lower_rule`, stacked by :func:`stack_rule_ops`).
  Adding / removing / editing a rule is a **row write**, never a recompile;
  only growing the bucket's rule capacity retraces (same callable, new
  shape — exactly like growing K).
* ``BucketSpec`` — the static residue that *must* stay trace-constant:
  arity ``n``, whether the bucket carries negation / Kleene post-blocks,
  the attribute width, and the negation-predicate row capacity.  Rules are
  bucketed by this spec; buckets are padded with inert rows
  (:func:`pad_rule`) whose joins are empty by construction.
* **Sub-join sharing lattice** (multi-query optimization after Kolchinsky
  & Schuster's join-query-sharing work, arXiv 1801.09413): rules whose
  plans open with the identical sub-join *chain* — same positions, types,
  window, sequence-ness and every pairwise predicate live at each step —
  are grouped per *depth* at compile time.  Depth ``d`` covers the
  ``d + 2``-position sub-join after plan step ``d + 1``; ``ShareOps.rep[d]``
  gathers the rule slot whose operands drive each depth-``d`` equivalence
  class, ``ShareOps.parent[d]`` chains each class to the depth-``d-1``
  class it extends, and ``ShareOps.expand`` fans the final-depth partial
  match sets out to every rule for the per-rule post-blocks.  Each shared
  sub-join therefore runs **once per class per step** instead of once per
  rule; the opening-prefix grouping of PR 8 is the ``d = 0`` slice of this
  lattice.  Sound because a ``MatchSet`` stores event *values*, not buffer
  indices, and the class key pins every operand of the shared steps (only
  strip rows whose right operand is the newly joined position are active
  at that step, the rest are ``PRED_NONE`` — vacuous).

Bit-identity with the single-pattern engine is a design invariant, not an
aspiration: every generalized helper below mirrors its ``core.engine``
twin row for row, with rule-varying structure entering only through
op-code strips whose inactive rows carry ``PRED_NONE`` — vacuous-true in
the join kernels — so the surviving masks, the compaction order and hence
all counters are bitwise equal to Q independent ``OrderEngine`` runs
(asserted by ``tests/test_rulebook.py`` and ``benchmarks/rulebook_bench``).
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from .engine import (Buffers, Chunk, EngineConfig, MatchSet, PredicateStrips,
                     _compact, _row_counts, _rows_to_stacks, _validity_rows,
                     make_spec)
from .patterns import PRED_ABS_LE, PRED_GT, PRED_LT, PRED_NONE, Pattern

_LT = PRED_LT
_GT = PRED_GT
_NONE = PRED_NONE

# Kleene bound sentinel for "unbounded": large enough that min() is a no-op
# for any physical companion count, small enough to stay exact in int32.
KLEENE_UNBOUNDED = 1 << 30


# ---------------------------------------------------------------------------
# Bucket spec: the static residue of a rule set
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """Trace-constant shape of one arity bucket.

    Everything else a pattern specifies lives in ``RuleOps`` rows.  Two
    rules land in the same bucket iff they agree on this spec (with
    ``neg_rows_cap`` an upper bound, not an exact match).  ``n_attrs`` is
    the rulebook-wide attribute width — chunks are shared across rules, so
    every rule's buffers carry the same A.
    """

    n: int                 # pattern arity (primitive positions)
    has_neg: bool          # bucket carries the negation post-block
    has_kleene: bool       # bucket carries the Kleene post-block
    n_attrs: int           # shared attribute width A
    neg_rows_cap: int = 0  # max negated-predicate rows per rule

    @property
    def rows(self) -> int:
        """Ring-buffer rows per rule (one extra for the negated type)."""
        return self.n + (1 if self.has_neg else 0)


def packed_rule_row_count(n: int) -> int:
    """Packed constraint rows per plan step, bucket-wide.

    Unlike the single-pattern engine (which emits rows only for predicate
    pairs the pattern actually has), the bucket layout reserves two rows
    for EVERY ordered position pair plus the two sequence-anchor rows —
    rules activate their subset via the int8 op strip, the rest are
    ``PRED_NONE`` (vacuous-true, exact padding in the kernels).
    """
    return 4 + n * (n - 1)


def _ordered_pairs(n: int) -> Tuple[Tuple[int, int], ...]:
    """Both orientations of every position pair, in strip-row order."""
    out = []
    for p in range(n):
        for q in range(p + 1, n):
            out.append((p, q))
            out.append((q, p))
    return tuple(out)


# ---------------------------------------------------------------------------
# RuleOps: one rule as data
# ---------------------------------------------------------------------------


class RuleOps(NamedTuple):
    """Structural tensors for one rule (stack along a leading Qb axis).

    All shapes are per-rule; ``stack_rule_ops`` prepends the rule axis.
    ``type_rows[r] == -1`` marks an inactive buffer row (padding slots
    ingest nothing, so their joins are empty).  ``has_neg``/``has_kleene``
    gate the post-blocks *per rule* so buckets fused across shape classes
    (a plain rule riding in a Kleene-capable bucket) stay bit-identical to
    their solo engines: the blocks run bucket-wide, the rule-less ones are
    masked to zero.
    """

    valid: np.ndarray        # ()  bool — False for padding slots
    window: np.ndarray       # ()  f32
    is_seq: np.ndarray       # ()  bool
    has_neg: np.ndarray      # ()  bool — rule uses the negation post-block
    has_kleene: np.ndarray   # ()  bool — rule uses the Kleene post-block
    type_rows: np.ndarray    # (rows,) i32 global type per buffer row
    op_t: np.ndarray         # (n, n) i32 predicate op codes
    a_attr: np.ndarray       # (n, n) i32
    b_attr: np.ndarray       # (n, n) i32
    theta: np.ndarray        # (n, n) f32
    ths: np.ndarray          # (C,) f32 packed per-row thresholds
    neg_pos: np.ndarray      # ()  i32 required-absence position
    neg_row_op: np.ndarray   # (Rn,) i32 negation predicate rows (padded)
    neg_row_pos: np.ndarray  # (Rn,) i32
    neg_row_ma: np.ndarray   # (Rn,) i32
    neg_row_na: np.ndarray   # (Rn,) i32
    neg_row_th: np.ndarray   # (Rn,) f32
    kleene_pos: np.ndarray   # ()  i32
    kleene_bound: np.ndarray  # () i32 (KLEENE_UNBOUNDED = no bound)


class ShareOps(NamedTuple):
    """Sub-join sharing lattice routing for one bucket.

    One entry per lattice depth ``d in [0, n - 2]``; depth ``d`` holds the
    equivalence classes of the ``d + 2``-position sub-joins after plan step
    ``d + 1``.  Classes are capacity-padded like rule slots (free classes
    compute garbage that is never fanned out); growing a depth's class
    capacity retraces the same callable, exactly like growing Qb.
    """

    rep: Tuple[jnp.ndarray, ...]     # [d]: (U_d,) i32 rule slot driving
                                     #      each depth-d class's operands
    parent: Tuple[jnp.ndarray, ...]  # [d]: (U_d,) i32 depth-(d-1) class
                                     #      each class extends (d=0: zeros)
    expand: jnp.ndarray              # (Qb,) i32 final-depth class per rule


class RuleStepResult(NamedTuple):
    """Per-rule counters for one chunk tick (each leads with Qb)."""

    full: jnp.ndarray      # i32 full matches completed this chunk
    pm: jnp.ndarray        # i32 partial matches materialized
    overflow: jnp.ndarray  # i32 candidates dropped by m_cap
    closure: jnp.ndarray   # i32 Kleene companion count
    neg: jnp.ndarray       # i32 matches vetoed by negation


def lower_rule(pattern: Pattern, bspec: BucketSpec) -> RuleOps:
    """Lower one pattern into its bucket's row layout (host numpy).

    The bucket spec is a *superset* contract, not an exact match: a rule
    without negation / Kleene may ride in a bucket that carries those
    post-blocks (cross-bucket fusion pads the spec up); the rule's
    ``has_neg``/``has_kleene`` flags mask the blocks it does not use.
    """
    spec = make_spec(pattern)
    if spec.n != bspec.n:
        raise ValueError(f"rule arity {spec.n} != bucket arity {bspec.n}")
    if spec.has_neg and not bspec.has_neg:
        raise ValueError("rule needs negation; bucket has no neg post-block")
    if (spec.kleene_pos is not None) and not bspec.has_kleene:
        raise ValueError("rule needs Kleene; bucket has no Kleene post-block")
    if spec.n_attrs > bspec.n_attrs:
        raise ValueError(
            f"rule has {spec.n_attrs} attributes; rulebook width is "
            f"{bspec.n_attrs}")
    if len(spec.neg_rows) > bspec.neg_rows_cap:
        raise ValueError(
            f"{len(spec.neg_rows)} negation predicate rows exceed the "
            f"bucket capacity {bspec.neg_rows_cap}")
    n = bspec.n
    type_rows = list(spec.type_ids)
    if bspec.has_neg:
        # A rule without negation in a neg-capable bucket gets an inert
        # extra row (-1 ingests nothing, so its veto count is always 0).
        type_rows.append(spec.negated_type if spec.has_neg else -1)
    ths = [spec.window, spec.window, 0.0, 0.0]
    for (a, b_) in _ordered_pairs(n):
        ths.append(float(spec.theta_t[a, b_]))
    rn = bspec.neg_rows_cap
    nr_op = np.zeros((rn,), np.int32)
    nr_pos = np.zeros((rn,), np.int32)
    nr_ma = np.zeros((rn,), np.int32)
    nr_na = np.zeros((rn,), np.int32)
    nr_th = np.zeros((rn,), np.float32)
    for i, (pos, op, ma, na, th) in enumerate(spec.neg_rows):
        nr_op[i], nr_pos[i], nr_ma[i], nr_na[i], nr_th[i] = (
            op, pos, ma, na, th)
    return RuleOps(
        valid=np.asarray(True),
        window=np.float32(spec.window),
        is_seq=np.asarray(bool(spec.is_seq)),
        has_neg=np.asarray(bool(spec.has_neg)),
        has_kleene=np.asarray(spec.kleene_pos is not None),
        type_rows=np.asarray(type_rows, np.int32),
        op_t=np.asarray(spec.op_t, np.int32),
        a_attr=np.asarray(spec.a_attr_t, np.int32),
        b_attr=np.asarray(spec.b_attr_t, np.int32),
        theta=np.asarray(spec.theta_t, np.float32),
        ths=np.asarray(ths, np.float32),
        neg_pos=np.int32(spec.negated_pos if spec.negated_pos is not None
                         else 0),
        neg_row_op=nr_op, neg_row_pos=nr_pos, neg_row_ma=nr_ma,
        neg_row_na=nr_na, neg_row_th=nr_th,
        kleene_pos=np.int32(spec.kleene_pos or 0),
        kleene_bound=np.int32(spec.kleene_bound
                              if spec.kleene_bound is not None
                              else KLEENE_UNBOUNDED),
    )


def pad_rule(bspec: BucketSpec) -> RuleOps:
    """An inert slot: ingests nothing, joins empty, counters masked out."""
    n, rn = bspec.n, bspec.neg_rows_cap
    return RuleOps(
        valid=np.asarray(False),
        window=np.float32(1.0),
        is_seq=np.asarray(False),
        has_neg=np.asarray(False),
        has_kleene=np.asarray(False),
        type_rows=np.full((bspec.rows,), -1, np.int32),
        op_t=np.zeros((n, n), np.int32),
        a_attr=np.zeros((n, n), np.int32),
        b_attr=np.zeros((n, n), np.int32),
        theta=np.zeros((n, n), np.float32),
        ths=np.zeros((packed_rule_row_count(n),), np.float32),
        neg_pos=np.int32(0),
        neg_row_op=np.zeros((rn,), np.int32),
        neg_row_pos=np.zeros((rn,), np.int32),
        neg_row_ma=np.zeros((rn,), np.int32),
        neg_row_na=np.zeros((rn,), np.int32),
        neg_row_th=np.zeros((rn,), np.float32),
        kleene_pos=np.int32(0),
        kleene_bound=np.int32(KLEENE_UNBOUNDED),
    )


def stack_rule_ops(rows: Sequence[RuleOps]) -> RuleOps:
    """Stack per-rule ops along the leading Qb axis (host numpy)."""
    return RuleOps(*(np.stack([np.asarray(getattr(r, f)) for r in rows])
                     for f in RuleOps._fields))


# ---------------------------------------------------------------------------
# Traced generalizations of the engine's per-pattern helpers
# ---------------------------------------------------------------------------


def build_rule_strips(bspec: BucketSpec, ops: RuleOps,
                      order) -> PredicateStrips:
    """Per-step int8 op strips for one rule's order plan (traced twin of
    ``engine.build_order_strips`` — the pattern structure enters through
    ``ops`` instead of the closed-over spec).  Rows beyond the rule's own
    predicates carry ``PRED_NONE``, so the strip layout is bucket-wide."""
    n = bspec.n
    order = jnp.asarray(order, jnp.int32)
    pos = jnp.arange(n)
    member = (pos == order[0])
    ops_steps, lo_steps, hi_steps = [], [], []
    for i in range(1, n):
        q = order[i]
        row_ops = [jnp.asarray(_LT, jnp.int8), jnp.asarray(_GT, jnp.int8)]
        lo_cand = jnp.where(member & (pos < q), pos, -1)
        p_lo = lo_cand.max()
        hi_cand = jnp.where(member & (pos > q), pos, n)
        p_hi = hi_cand.min()
        # Sequence-anchor rows are always present in the bucket layout and
        # op-gated per rule (AND rules keep them vacuous).
        row_ops.append(jnp.where(ops.is_seq & (p_lo >= 0),
                                 _LT, _NONE).astype(jnp.int8))
        row_ops.append(jnp.where(ops.is_seq & (p_hi < n),
                                 _GT, _NONE).astype(jnp.int8))
        lo = jnp.clip(p_lo, 0, n - 1).astype(jnp.int32)
        hi = jnp.clip(p_hi, 0, n - 1).astype(jnp.int32)
        for (a, b_) in _ordered_pairs(n):
            active = member[a] & (q == b_)
            row_ops.append(jnp.where(active, ops.op_t[a, b_],
                                     _NONE).astype(jnp.int8))
        ops_steps.append(jnp.stack(row_ops))
        lo_steps.append(lo)
        hi_steps.append(hi)
        member = member | (pos == q)
    return PredicateStrips(
        ops8=jnp.stack(ops_steps),
        lo_idx=jnp.stack(lo_steps),
        hi_idx=jnp.stack(hi_steps))


def _rule_ingest(bspec: BucketSpec, cfg: EngineConfig, buffers: Buffers,
                 chunk: Chunk, type_rows) -> Buffers:
    """Route chunk events into one rule's ring rows (``engine._ingest``
    with the row→type map as data; ``-1`` rows match nothing)."""
    bcap = cfg.b_cap
    ts, attr, valid, ptr = buffers
    for row in range(bspec.rows):  # static loop
        gid = type_rows[row]
        mask = (chunk.type_id == gid) & chunk.valid & (gid >= 0)
        k = jnp.cumsum(mask.astype(jnp.int32)) - 1
        slot = jnp.where(mask, (ptr[row] + k) % bcap, bcap)  # bcap -> drop
        ts = ts.at[row, slot].set(chunk.ts, mode="drop")
        attr = attr.at[row, slot].set(chunk.attr, mode="drop")
        valid = valid.at[row, slot].set(True, mode="drop")
        ptr = ptr.at[row].add(mask.sum().astype(jnp.int32))
    return Buffers(ts, attr, valid, ptr)


def _rule_leaf(bspec: BucketSpec, cfg: EngineConfig, buffers: Buffers,
               row, pos, t0, window, out_rows: int) -> MatchSet:
    """One buffer row as a single-position match set (``engine._leaf`` with
    traced row/pos/window)."""
    n, b = bspec.n, cfg.b_cap
    ts_b = buffers.ts[row]
    attr_b = buffers.attr[row]
    valid = buffers.valid[row] & (ts_b > t0 - window)
    onehot = (jnp.arange(n) == pos)
    ts = jnp.where(onehot[None, :], ts_b[:, None], 0.0)
    attr = jnp.where(onehot[None, :, None], attr_b[:, None, :], 0.0)
    ms = MatchSet(ts, attr, ts_b, ts_b, valid, onehot)
    if out_rows != b:
        pad = out_rows - b
        ms = MatchSet(
            ts=jnp.pad(ms.ts, ((0, pad), (0, 0))),
            attr=jnp.pad(ms.attr, ((0, pad), (0, 0), (0, 0))),
            min_ts=jnp.pad(ms.min_ts, (0, pad)),
            max_ts=jnp.pad(ms.max_ts, (0, pad)),
            valid=jnp.pad(ms.valid, (0, pad)),
            member=ms.member,
        )
    return ms


def _rule_step(bspec: BucketSpec, cfg: EngineConfig, buffers: Buffers,
               ops: RuleOps, pm: MatchSet, q, sops, lo, hi, t0):
    """One plan step: gather + packed kernel + compaction (the traced twin
    of ``OrderEngine``'s ``packed_step``; thresholds come from the rule's
    packed ``ths`` strip instead of trace constants)."""
    R = _rule_leaf(bspec, cfg, buffers, q, q, t0, ops.window, cfg.b_cap)
    attr_b = buffers.attr[q]
    Lr = [pm.max_ts, pm.min_ts, pm.ts[:, lo], pm.ts[:, hi]]
    Rr = [R.min_ts, R.max_ts, R.min_ts, R.min_ts]
    for (a, b_) in _ordered_pairs(bspec.n):
        Lr.append(pm.attr[:, a, ops.a_attr[a, b_]])
        Rr.append(attr_b[:, ops.b_attr[a, b_]])
    Ls = jnp.stack([x.astype(jnp.float32) for x in Lr])
    Rs = jnp.stack([x.astype(jnp.float32) for x in Rr])
    ok = kops.window_join_packed(Ls, Rs, sops, ops.ths, pm.valid, R.valid,
                                 backend=cfg.backend)
    created = ok.sum().astype(jnp.int32)
    return _compact(pm, R, ok, created, cfg.m_cap)


def _rule_finalize(bspec: BucketSpec, cfg: EngineConfig, ops: RuleOps,
                   buffers: Buffers, pm: MatchSet, t0, t1):
    """Completion filter + negation veto + Kleene count for one rule.

    Serving semantics (no born split): the rulebook control plane deploys
    plan rows immediately — partial matches rebuild from the rings every
    chunk, so a row swap changes join *work*, never *which* matches are
    counted (same contract as ``serving.MonitoredCEPFleetServingEngine``).
    The negation / Kleene blocks are bucket-static; within a block the
    rule-varying pieces (positions, ops, thetas, the window) are traced.
    Window rows are inlined (the engine's ``_window_rows`` casts the
    window to a Python float, which a traced per-rule window cannot do).
    """
    n = bspec.n
    m = pm.valid.shape[0]
    b = cfg.b_cap
    W = ops.window
    completed = pm.valid & (pm.max_ts > t0) & (pm.max_ts <= t1)
    neg_rejected = jnp.int32(0)

    if bspec.has_neg:
        row = n
        nts = buffers.ts[row]
        nvalid = buffers.valid[row] & (nts > t0 - W)
        rows = _validity_rows(completed, nvalid, m, b)
        rows += [(pm.max_ts, nts, _LT, W), (pm.min_ts, nts, _GT, W)]
        np_ = ops.neg_pos
        rows.append((pm.ts[:, jnp.clip(np_ - 1, 0, n - 1)], nts,
                     jnp.where(np_ > 0, _LT, _NONE), 0.0))
        rows.append((pm.ts[:, jnp.clip(np_, 0, n - 1)], nts,
                     jnp.where(np_ < n, _GT, _NONE), 0.0))
        for i in range(bspec.neg_rows_cap):  # static loop, op-gated rows
            rows.append((pm.attr[:, ops.neg_row_pos[i], ops.neg_row_ma[i]],
                         buffers.attr[row][:, ops.neg_row_na[i]],
                         ops.neg_row_op[i], ops.neg_row_th[i]))
        cnt = _row_counts(cfg, rows, m, b)
        veto = (cnt > 0) & ops.has_neg  # fused buckets: gate per rule
        neg_rejected = (completed & veto).sum().astype(jnp.int32)
        completed = completed & ~veto

    closure = jnp.int32(0)
    if bspec.has_kleene:
        kp = ops.kleene_pos
        kts = buffers.ts[kp]
        kvalid = buffers.valid[kp] & (kts > t0 - W)
        attr_k = buffers.attr[kp]
        rows = _validity_rows(completed, kvalid, m, b)
        rows += [(pm.max_ts, kts, _LT, W), (pm.min_ts, kts, _GT, W)]
        rows.append((pm.ts[:, jnp.clip(kp - 1, 0, n - 1)], kts,
                     jnp.where(ops.is_seq & (kp > 0), _LT, _NONE), 0.0))
        rows.append((pm.ts[:, jnp.clip(kp + 1, 0, n - 1)], kts,
                     jnp.where(ops.is_seq & (kp < n - 1), _GT, _NONE), 0.0))
        for o in range(n):  # static loop over partner positions
            op = jnp.where(o == kp, _NONE, ops.op_t[o, kp])
            rows.append((pm.attr[:, o, ops.a_attr[o, kp]],
                         attr_k[:, ops.b_attr[o, kp]],
                         op, ops.theta[o, kp]))
        cnt = _row_counts(cfg, rows, m, b)
        comp = jnp.minimum(jnp.maximum(cnt - 1, 0), ops.kleene_bound)
        # Non-Kleene rules in a fused bucket point kleene_pos at a real
        # row; gating (not just masking padding) is what keeps them exact.
        closure = jnp.where(ops.has_kleene & completed, comp,
                            0).sum().astype(jnp.int32)

    return completed.sum().astype(jnp.int32), neg_rejected, closure


def _observe_one(bspec: BucketSpec, ops: RuleOps, chunk: Chunk):
    """Per-rule monitored observation (``stats.chunk_observations`` with
    the pair structure as data).  Pairs without a predicate contribute
    exactly 0 trials/hits, matching the engine's static skip."""
    n = bspec.n
    masks = [chunk.valid & (chunk.type_id == ops.type_rows[p])
             for p in range(n)]
    counts = jnp.stack([mk.sum().astype(jnp.float32) for mk in masks])
    trials = jnp.zeros((n, n), jnp.float32)
    hits = jnp.zeros((n, n), jnp.float32)
    for p in range(n):
        for q in range(p + 1, n):
            op = ops.op_t[p, q]
            th = ops.theta[p, q]
            a = chunk.attr[:, ops.a_attr[p, q]]
            b = chunk.attr[:, ops.b_attr[p, q]]
            lt = a[:, None] < b[None, :] + th
            gt = a[:, None] > b[None, :] - th
            ab = jnp.abs(a[:, None] - b[None, :]) <= th
            ok = jnp.where(op == _LT, lt,
                           jnp.where(op == _GT, gt, ab))
            pair_mask = masks[p][:, None] & masks[q][None, :]
            has = op != _NONE
            t_pq = jnp.where(has, counts[p] * counts[q], 0.0)
            h_pq = jnp.where(
                has, (ok & pair_mask).sum().astype(jnp.float32), 0.0)
            trials = trials.at[p, q].set(t_pq).at[q, p].set(t_pq)
            hits = hits.at[p, q].set(h_pq).at[q, p].set(h_pq)
    return counts, trials, hits


# ---------------------------------------------------------------------------
# The bucket step: ingest -> shared sub-join lattice -> per-rule post-blocks
# ---------------------------------------------------------------------------


def _make_bucket_step(bspec: BucketSpec, cfg: EngineConfig,
                      monitored: bool, laplace: float):
    """Build the per-partition bucket step (vmapped over K by the plane).

    Plain signature::

        step(state, chunk, ops, share, plans, t0, t1) -> (state, res)

    where ``state`` leads with Qb, ``ops`` is the stacked ``RuleOps``,
    ``share`` routes the sub-join sharing lattice and ``plans`` is the
    (Qb, n) order matrix.  Join work walks the lattice depth by depth —
    each depth extends its parent classes' partial-match sets by one plan
    step, once per class — and only the finalize post-blocks run per rule,
    on the final-depth sets fanned out through ``share.expand``.  The
    monitored variant threads a per-rule ``MonitorState`` and stacked
    ``LoweredInvariants`` and appends (violated, drift, rates, sel) per
    rule.
    """
    from .invariants import eval_lowered
    from .stats import monitor_snapshot, monitor_update

    n = bspec.n

    def open_one(buffers, ops, order, strips, t0):
        """Leaf + opening join — the depth-0 sub-join, once per class."""
        pm = _rule_leaf(bspec, cfg, buffers, order[0], order[0], t0,
                        ops.window, cfg.m_cap)
        total = pm.valid.sum().astype(jnp.int32)
        pm, created, ov = _rule_step(
            bspec, cfg, buffers, ops, pm, order[1], strips.ops8[0],
            strips.lo_idx[0], strips.hi_idx[0], t0)
        return pm, total + created, ov

    def extend_at(d: int):
        """Depth-d extension: one plan step on the parent class's set."""
        def extend_one(buffers, ops, order, strips, pm, total, overflow,
                       t0):
            pm, created, ov = _rule_step(
                bspec, cfg, buffers, ops, pm, order[d + 1], strips.ops8[d],
                strips.lo_idx[d], strips.hi_idx[d], t0)
            return pm, total + created, overflow + ov
        return extend_one

    def finalize_one(buffers, ops, pm, total, overflow, t0, t1):
        """Completion + negation + Kleene — always per rule."""
        full, neg_rej, closure = _rule_finalize(
            bspec, cfg, ops, buffers, pm, t0, t1)
        return RuleStepResult(full, total, overflow, closure, neg_rej)

    def _joins(state, chunk, ops, share, plans, t0, t1):
        buffers = jax.vmap(
            lambda buf, trows: _rule_ingest(bspec, cfg, buf, chunk, trows)
        )(state, ops.type_rows)
        strips = jax.vmap(
            lambda o, r: build_rule_strips(bspec, o, r))(ops, plans)
        take = lambda tree, idx: jax.tree.map(lambda x: x[idx], tree)
        # Depth 0: leaf + opening join once per depth-0 class.
        r0 = share.rep[0]
        pm, tot, ov = jax.vmap(open_one, in_axes=(0, 0, 0, 0, None))(
            take(buffers, r0), take(ops, r0), plans[r0],
            take(strips, r0), t0)
        # Interior depths: extend the parent class's set by one step, once
        # per class (static loop — depths are trace constants).
        for d in range(1, n - 1):
            rd, pd = share.rep[d], share.parent[d]
            pm, tot, ov = jax.vmap(
                extend_at(d), in_axes=(0, 0, 0, 0, 0, 0, 0, None))(
                    take(buffers, rd), take(ops, rd), plans[rd],
                    take(strips, rd), take(pm, pd), tot[pd], ov[pd], t0)
        # Fan the final-depth sets out to rules for the post-blocks.
        ex = share.expand
        res = jax.vmap(
            finalize_one, in_axes=(0, 0, 0, 0, 0, None, None))(
                buffers, ops, take(pm, ex), tot[ex], ov[ex], t0, t1)
        live = ops.valid
        res = RuleStepResult(*(jnp.where(live, x, 0) for x in res))
        return buffers, res

    if not monitored:
        def bucket_step(state, chunk, ops, share, plans, t0, t1):
            return _joins(state, chunk, ops, share, plans, t0, t1)
        return bucket_step

    def mon_one(ops, monitor, lowered, chunk, t0, t1):
        counts, trials, hits = _observe_one(bspec, ops, chunk)
        monitor = monitor_update(monitor, counts, t1 - t0, trials, hits)
        rates, sel = monitor_snapshot(monitor, laplace)
        violated, drift = eval_lowered(lowered, rates, sel)
        return monitor, violated, drift, rates, sel

    def bucket_step_monitored(state, monitor, chunk, ops, share, plans,
                              lowered, t0, t1):
        buffers, res = _joins(state, chunk, ops, share, plans, t0, t1)
        monitor, violated, drift, rates, sel = jax.vmap(
            mon_one, in_axes=(0, 0, 0, None, None, None))(
                ops, monitor, lowered, chunk, t0, t1)
        violated = violated & ops.valid
        return buffers, monitor, res, violated, drift, rates, sel

    return bucket_step_monitored


# ---------------------------------------------------------------------------
# The compiled plane: jit(vmap over K) with a trace-count probe
# ---------------------------------------------------------------------------


class _Plane:
    """One compiled bucket plane plus its retrace counter.

    ``traces`` increments each time jax (re)traces the wrapped function —
    i.e. once per distinct (K, Qb, chunk-cap) shape signature.  The
    rulebook's zero-recompile hot-add guarantee is asserted against this
    counter: adding a rule into a free slot must leave it unchanged;
    growing the bucket's capacity is the one sanctioned retrace.
    """

    def __init__(self):
        self.fn = None
        self.traces = 0


def make_rulebook_plane(bspec: BucketSpec, cfg: EngineConfig, k: int,
                        monitored: bool, laplace: float = 1.0,
                        mesh=None) -> _Plane:
    """Compile (or fetch from the process-wide trace memo) the K×Qb plane.

    The memo key deliberately excludes the rule capacity Qb: growing a
    bucket re-enters the SAME jitted callable with a new shape — one
    retrace, no new cache entry — and two rulebooks with equal config
    share all compiled code.  Meshed planes are never shared (mesh objects
    pin device orders), mirroring ``FleetEngine``.
    """
    from .fleet import _shared_trace

    key = (None if mesh is not None
           else ("rulebook", bspec, cfg, int(k), bool(monitored),
                 float(laplace)))

    def build() -> _Plane:
        plane = _Plane()
        step = _make_bucket_step(bspec, cfg, monitored, laplace)
        if monitored:
            def fleet_fn(state, monitor, chunk, ops, share, plans,
                         lowered, t0, t1):
                plane.traces += 1  # python side effect: once per (re)trace
                return jax.vmap(
                    step, in_axes=(0, 0, 0, None, None, 0, 0, None, None))(
                        state, monitor, chunk, ops, share, plans, lowered,
                        t0, t1)
        else:
            def fleet_fn(state, chunk, ops, share, plans, t0, t1):
                plane.traces += 1
                return jax.vmap(
                    step, in_axes=(0, 0, None, None, 0, None, None))(
                        state, chunk, ops, share, plans, t0, t1)
        plane.fn = jax.jit(_shard_plane(fleet_fn, mesh, monitored))
        return plane

    return _shared_trace(key, build)


def _shard_plane(fn, mesh, monitored: bool):
    """shard_map the plane over a 1-D "cep" mesh (K leads; rules/share
    replicated).  ``sharding.shard_fleet_fn`` K-leads every argument, which
    the rulebook signature violates (ops/share are fleet-wide), so the
    specs are spelled per argument here."""
    if mesh is None:
        return fn
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    from ..distributed.sharding import CEP_AXIS

    kl = PartitionSpec(CEP_AXIS)
    rep = PartitionSpec()
    if monitored:
        in_specs = (kl, kl, kl, rep, rep, kl, kl, rep, rep)
        out_specs = (kl, kl, kl, kl, kl, kl, kl)
    else:
        in_specs = (kl, kl, rep, rep, kl, rep, rep)
        out_specs = (kl, kl)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# State constructors
# ---------------------------------------------------------------------------


def init_rule_buffers(bspec: BucketSpec, cfg: EngineConfig, k: int,
                      q_cap: int) -> Buffers:
    """Stacked ring buffers for one bucket: every leaf leads with (K, Qb)."""
    t, b, a = bspec.rows, cfg.b_cap, bspec.n_attrs
    return Buffers(
        ts=jnp.zeros((k, q_cap, t, b), jnp.float32),
        attr=jnp.zeros((k, q_cap, t, b, a), jnp.float32),
        valid=jnp.zeros((k, q_cap, t, b), bool),
        ptr=jnp.zeros((k, q_cap, t), jnp.int32),
    )


def init_rule_monitor(bspec: BucketSpec, k: int, q_cap: int,
                      num_buckets: int = 16):
    """Stacked statistics rings: every leaf leads with (K, Qb)."""
    from .stats import monitor_init

    one = monitor_init(bspec.n, num_buckets)
    return jax.tree.map(
        lambda x: jnp.tile(x[None, None], (k, q_cap) + (1,) * x.ndim), one)
