"""The paper's contribution: adaptive CEP with invariant-based
reoptimization decisions.

This package is the *implementation* layer; the public runtime surface is
the ``repro.cep`` facade (pattern DSL + ``Session`` + ``RuntimeConfig``),
and the legacy control-plane entry points here (``make_engine``,
``MonitoredEngine``, ``fleet.FleetRunner``, …) now emit
``DeprecationWarning``s pointing at it.

Control plane: instrumented plan generators (``greedy``, ``zstream``),
invariant machinery (``invariants``), decision policies (``decision``),
statistics estimation (``stats``), the detection-adaptation loop
(``adaptation``).  Data plane: the vectorized engine (``engine``) backed by
the ``repro.kernels`` window-join kernel; ``fleet`` vmaps it across stream
partitions.  ``ref_engine`` is the slow brute-force ground-truth oracle.
"""

from .adaptation import AdaptiveRunner, RunMetrics  # noqa: F401
from .decision import make_policy  # noqa: F401
from .engine import EngineConfig, OrderEngine, TreeEngine  # noqa: F401
from .engine import MonitoredEngine  # noqa: F401
from .fleet import (  # noqa: F401
    FleetEngine,
    FleetEstimator,
    FleetMetrics,
    FleetRunner,
    MonitoredFleetRunner,
    route_events,
    stack_chunks,
    stacked_streams,
)
from .invariants import (  # noqa: F401
    InvariantSet,
    LoweredInvariants,
    StackedLowered,
    d_avg_estimate,
    lower_invariants,
    stack_lowered,
    write_lowered_row,
)
from .ref_engine import RefEngine, brute_force_matches  # noqa: F401
from .greedy import greedy_order_plan  # noqa: F401
from .patterns import (  # noqa: F401
    CompositePattern,
    Pattern,
    Predicate,
    and_pattern,
    kleene_pattern,
    neg_pattern,
    seq_pattern,
)
from .plans import OrderPlan, TreePlan, plan_cost  # noqa: F401
from .stats import SlidingWindowEstimator, Stat  # noqa: F401
from .zstream import zstream_tree_plan  # noqa: F401
