"""Brute-force reference CEP matcher — the ground-truth oracle.

A deliberately slow, pure-Python/numpy re-implementation of the detection
semantics the vectorized engine (``engine.py``) promises:

* SEQ / AND over ``n`` primitive event types with pairwise structural
  predicates and a sliding time window (span ≤ W);
* chunked **exactly-once** counting — a match is counted in the chunk
  ``(t0, t1]`` containing its latest event;
* negation as a veto: a completed match is discarded when any event of the
  negated type falls between the required positions, inside the combined
  window, and satisfies the negated predicates;
* **count-only bounded Kleene closure**: a completed match contributes
  ``min(#compatible closure events − 1, bound)`` closure expansions (the
  match's own event at the Kleene position is excluded; ``bound=None``
  means unbounded).

It enumerates every candidate combination (``∏ per-type counts`` work), so
it is only usable at test scale — which is exactly the point: differential
tests drive ``OrderEngine`` / ``TreeEngine`` / ``FleetEngine`` against this
oracle over randomized streams to prove the compiled data plane preserves
the paper's semantics.

History retention matches the engine's eviction rule: events strictly newer
than ``t0 − W`` are kept, since a match completed in ``(t0, t1]`` may reach
back at most one window before the chunk start.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Optional, Tuple

import numpy as np

from .patterns import PRED_ABS_LE, PRED_GT, PRED_LT, PRED_NONE, Pattern


@dataclasses.dataclass
class RefResult:
    """Mirror of the engine's ``StepResult`` counters the oracle can model."""

    full_matches: int = 0
    neg_rejected: int = 0
    closure_expansions: int = 0

    def __iadd__(self, other: "RefResult") -> "RefResult":
        self.full_matches += other.full_matches
        self.neg_rejected += other.neg_rejected
        self.closure_expansions += other.closure_expansions
        return self


def _pred_ok(op: int, a: float, b: float, theta: float) -> bool:
    if op == PRED_NONE:
        return True
    if op == PRED_LT:
        return a < b + theta
    if op == PRED_GT:
        return a > b - theta
    if op == PRED_ABS_LE:
        return abs(a - b) <= theta
    raise ValueError(f"unknown predicate op {op}")


def _neg_vetoed(pattern: Pattern, combo_idx, tss, tid, ts, attr) -> bool:
    npos = pattern.negated_pos
    n = pattern.n
    lo = tss[npos - 1] if npos is not None and npos > 0 else -np.inf
    hi = tss[npos] if npos is not None and npos < n else np.inf
    pos_of = {t: p for p, t in enumerate(pattern.type_ids)}
    for j in np.nonzero(tid == pattern.negated_type)[0]:
        tj = ts[j]
        if not (lo < tj < hi):
            continue
        if max(tss.max(), tj) - min(tss.min(), tj) > pattern.window:
            continue
        ok = True
        for pr in pattern.negated_predicates:
            if pr.a_type == pattern.negated_type:
                a = attr[j, pr.a_attr]
                b = attr[combo_idx[pos_of[pr.b_type]], pr.b_attr]
            else:
                a = attr[combo_idx[pos_of[pr.a_type]], pr.a_attr]
                b = attr[j, pr.b_attr]
            if not _pred_ok(pr.op, a, b, pr.theta):
                ok = False
                break
        if ok:
            return True
    return False


def _closure_count(pattern: Pattern, pt, combo_idx, tss, tid, ts,
                   attr) -> int:
    """Compatible closure events minus the match's own (engine semantics)."""
    kp = pattern.kleene_pos
    n = pattern.n
    lo = tss[kp - 1] if pattern.is_sequence and kp > 0 else -np.inf
    hi = tss[kp + 1] if pattern.is_sequence and kp < n - 1 else np.inf
    count = 0
    for j in np.nonzero(tid == pattern.type_ids[kp])[0]:
        tj = ts[j]
        if not (lo < tj < hi):
            continue
        if max(tss.max(), tj) - min(tss.min(), tj) > pattern.window:
            continue
        ok = True
        for p in range(n):
            if p == kp or pt["op"][p, kp] == PRED_NONE:
                continue
            a = attr[combo_idx[p], pt["a_attr"][p, kp]]
            b = attr[j, pt["b_attr"][p, kp]]
            if not _pred_ok(pt["op"][p, kp], a, b, pt["theta"][p, kp]):
                ok = False
                break
        if ok:
            count += 1
    comp = max(count - 1, 0)
    if pattern.kleene_bound is not None:
        comp = min(comp, pattern.kleene_bound)
    return comp


def brute_force_matches(
    pattern: Pattern,
    tid: np.ndarray,
    ts: np.ndarray,
    attr: np.ndarray,
    t0: float = -np.inf,
    t1: float = np.inf,
) -> RefResult:
    """Enumerate all matches of ``pattern`` completed in ``(t0, t1]``."""
    n = pattern.n
    pt = pattern.pred_tensors()
    idx_by_pos = [np.nonzero(tid == t)[0] for t in pattern.type_ids]
    res = RefResult()
    for combo in itertools.product(*idx_by_pos):
        combo = list(combo)
        tss = ts[combo]
        if tss.max() - tss.min() > pattern.window:
            continue
        if not (t0 < tss.max() <= t1):
            continue
        if pattern.is_sequence and not all(
                tss[i] < tss[i + 1] for i in range(n - 1)):
            continue
        ok = True
        for p in range(n):
            for q in range(n):
                if p == q or pt["op"][p, q] == PRED_NONE:
                    continue
                a = attr[combo[p], pt["a_attr"][p, q]]
                b = attr[combo[q], pt["b_attr"][p, q]]
                if not _pred_ok(pt["op"][p, q], a, b, pt["theta"][p, q]):
                    ok = False
                    break
            if not ok:
                break
        if not ok:
            continue
        if pattern.negated_type is not None and _neg_vetoed(
                pattern, combo, tss, tid, ts, attr):
            res.neg_rejected += 1
            continue
        res.full_matches += 1
        if pattern.kleene_pos is not None:
            res.closure_expansions += _closure_count(
                pattern, pt, combo, tss, tid, ts, attr)
    return res


class RefEngine:
    """Stateful chunked oracle: feed chunks in time order, get per-chunk
    exactly-once counts with the same history-eviction rule as the engine."""

    def __init__(self, pattern: Pattern):
        self.pattern = pattern
        n_attrs = pattern.n_attrs
        self._tid = np.zeros(0, np.int64)
        self._ts = np.zeros(0, np.float64)
        self._attr = np.zeros((0, n_attrs), np.float64)

    def process_chunk(self, tid, ts, attr, t0: float, t1: float,
                      valid=None) -> RefResult:
        tid = np.asarray(tid)
        ts = np.asarray(ts, np.float64)
        attr = np.asarray(attr, np.float64)
        if valid is not None:
            valid = np.asarray(valid, bool)
            tid, ts, attr = tid[valid], ts[valid], attr[valid]
        self._tid = np.concatenate([self._tid, tid])
        self._ts = np.concatenate([self._ts, ts])
        self._attr = np.concatenate([self._attr, attr])
        # Evict events the engine's leaf-validity rule can no longer see.
        keep = self._ts > t0 - self.pattern.window
        self._tid, self._ts = self._tid[keep], self._ts[keep]
        self._attr = self._attr[keep]
        return brute_force_matches(
            self.pattern, self._tid, self._ts, self._attr, t0, t1)

    def run(self, records: Iterable) -> RefResult:
        """Consume ``ChunkRecord``s (data.cep_streams) end-to-end."""
        total = RefResult()
        for rec in records:
            c = rec.chunk
            total += self.process_chunk(
                np.asarray(c.type_id), np.asarray(c.ts), np.asarray(c.attr),
                rec.t0, rec.t1, valid=np.asarray(c.valid))
        return total
