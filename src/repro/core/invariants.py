"""Invariant-based reoptimizing decision machinery (paper §3).

A *deciding condition* is an inequality ``f1(stat1) < f2(stat2)`` whose
verification (a *block-building comparison*, BBC) led the plan generation
algorithm ``A`` to include a specific *building block* in the final plan
(§3.1).  All deciding conditions of a block form its *deciding condition set*
(DCS); DCSs of distinct blocks are disjoint by construction.

Each side of a condition is a **sum of product terms** (``ExprSum``): greedy
step scores are single products ``r_j·∏sel``; ZStream tree costs are
``frozen_subtree_costs + live_cardinality_product`` (§4.2's
subtree-cost-as-constant trick).  Every side therefore evaluates in constant
time, as the paper's complexity analysis requires.

From each DCS we select up to ``K`` conditions as *invariants* (§3.3), by
default the *tightest* ones — smallest ``f2 − f1`` at plan-creation time
(§3.1) — or, when variance estimates are available, the ones most likely to
be violated (§3.5).  The decision function ``D`` is the ordered conjunction
of the invariants: it returns ``true`` iff at least one invariant is violated
under the current statistics, using the *distance* margin ``d`` (§3.4):

    violated  ⇔  f1(stat) >= (1 + d) · f2(stat).

Note on the direction of ``d``: the paper prints the verified invariant as
``(1+d)·f1 < f2``, which taken literally *lowers* the firing bar below the
basic method — contradicting §3.4's stated purpose (damping plan-flapping
when two statistics oscillate around each other) and Figure 5 (throughput
*increases* with d up to ``d_opt`` because *fewer* replans fire).  We
therefore implement the semantics the section describes: a violation
requires the inequality to flip *by a relative margin of at least d*.
``d = 0`` coincides exactly with the basic method either way.

Theorem 1 (d = 0): a violation guarantees the next run of ``A`` yields a
different plan — no false positives.  Theorem 2 (strategy = "all"): keeping
*all* conditions also eliminates false negatives.  Both are exercised as
property tests in ``tests/test_invariants.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from .plans import Expr
from .stats import Stat

# A condition side: sum of product-form terms.
ExprSum = Tuple[Expr, ...]


def eval_sum(side: ExprSum, stat: Stat) -> float:
    return float(sum(e.eval(stat) for e in side))


def _as_sum(side) -> ExprSum:
    if isinstance(side, Expr):
        return (side,)
    return tuple(side)


@dataclasses.dataclass(frozen=True)
class DecidingCondition:
    """``sum(lhs) < sum(rhs)`` attributed to building block ``block``."""

    lhs: ExprSum
    rhs: ExprSum
    block: str

    @staticmethod
    def make(lhs, rhs, block: str) -> "DecidingCondition":
        return DecidingCondition(_as_sum(lhs), _as_sum(rhs), block)

    def margin(self, stat: Stat) -> float:
        """``f2 − f1`` under ``stat`` — positive while the condition holds."""
        return eval_sum(self.rhs, stat) - eval_sum(self.lhs, stat)

    def rel_margin(self, stat: Stat) -> float:
        """``|f2 − f1| / min(f1, f2)`` — the §3.4 relative-difference term."""
        a, b = eval_sum(self.lhs, stat), eval_sum(self.rhs, stat)
        lo = min(a, b)
        return abs(b - a) / max(lo, 1e-12)

    def holds(self, stat: Stat, d: float = 0.0) -> bool:
        """Condition (with distance margin) still holds — not violated."""
        return eval_sum(self.lhs, stat) < (1.0 + d) * eval_sum(self.rhs, stat)

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        l = " + ".join(map(str, self.lhs))
        r = " + ".join(map(str, self.rhs))
        return f"[{self.block}] {l} < {r}"


# A DCS list is ordered by the plan's block order (order-based: step order;
# tree-based: bottom-up node order) — §3.2 verification order.
DCSList = List[Tuple[str, List[DecidingCondition]]]


def select_invariants(
    dcs_list: DCSList,
    stat: Stat,
    k: int = 1,
    strategy: str = "tightest",
    violation_prob: Optional[Callable[[DecidingCondition, Stat], float]] = None,
) -> List[DecidingCondition]:
    """Pick up to ``k`` invariants per DCS (§3.1, §3.3, §3.5).

    strategy:
      * ``"tightest"``  — smallest absolute margin ``f2 − f1`` (paper default).
      * ``"rel"``       — smallest relative margin (scale-free variant).
      * ``"prob"``      — largest estimated violation probability; requires
                          ``violation_prob`` (§3.5 optimization).
      * ``"all"``       — keep every condition (Theorem 2 regime).
    """
    out: List[DecidingCondition] = []
    for _, conds in dcs_list:
        if not conds:
            continue
        if strategy == "all":
            chosen = list(conds)
        elif strategy == "tightest":
            chosen = sorted(conds, key=lambda c: c.margin(stat))[:k]
        elif strategy == "rel":
            chosen = sorted(conds, key=lambda c: c.rel_margin(stat))[:k]
        elif strategy == "prob":
            if violation_prob is None:
                raise ValueError("strategy='prob' requires violation_prob")
            chosen = sorted(
                conds, key=lambda c: -violation_prob(c, stat)
            )[:k]
        else:
            raise ValueError(f"unknown strategy {strategy!r}")
        out.extend(chosen)
    return out


def d_avg_estimate(dcs_list: DCSList, stat: Stat, clip: float = 5.0
                   ) -> float:
    """§3.4 data-analysis heuristic: average relative slack of all deciding
    conditions observed during the initial run of ``A``.

    Each term is clipped (default 5.0): with multiplicative score
    expressions a near-zero side makes a single ratio astronomically
    large, and an unclipped mean is dominated by it (a failure mode of
    the paper's formula on low-selectivity patterns; d > 5 would disable
    adaptation entirely anyway).
    """
    rels = [min(c.rel_margin(stat), clip)
            for _, conds in dcs_list for c in conds]
    if not rels:
        return 0.0
    return float(np.mean(rels))


class InvariantSet:
    """The ordered invariant list verified by ``D`` each loop iteration.

    Verification cost is O(#invariants) ≤ O(K·(B−1)) with each check a
    constant-size sum-of-products evaluation (§3.2); the evaluation is
    vectorized over flattened term arrays so the per-iteration overhead stays
    in the microsecond range even for K-invariant configurations.
    """

    def __init__(self, invariants: Sequence[DecidingCondition], d: float = 0.0):
        self.invariants = list(invariants)
        self.d = float(d)
        self._compile()

    def _compile(self) -> None:
        """Flatten both sides into term-level gather/product arrays.

        Row = one product term.  Products accumulate at the term level via
        ``np.multiply.at``; term values then segment-sum into per-invariant
        side values.
        """
        rows = []  # (inv_idx, side_sign, scale, const, rate_ids, sel_pairs)
        for i, c in enumerate(self.invariants):
            for side, which in ((c.lhs, 0), (c.rhs, 1)):
                for e in side:
                    rows.append((i, which, e.scale, e.const_add,
                                 e.rate_idx, e.sel_pairs))
        t = len(rows)
        self._m = len(self.invariants)
        self._t = t
        self._term_inv = np.array([r[0] for r in rows], np.int64)
        self._term_side = np.array([r[1] for r in rows], np.int64)
        self._term_scale = np.array([r[2] for r in rows], np.float64)
        self._term_const = np.array([r[3] for r in rows], np.float64)
        rate_idx, rate_seg, sel_idx, sel_seg = [], [], [], []
        for ti, r in enumerate(rows):
            for ri in r[4]:
                rate_idx.append(ri)
                rate_seg.append(ti)
            for p in r[5]:
                sel_idx.append(p)
                sel_seg.append(ti)
        self._rate_idx = np.asarray(rate_idx, np.int64)
        self._rate_seg = np.asarray(rate_seg, np.int64)
        self._sel_idx = np.asarray(sel_idx, np.int64).reshape(-1, 2)
        self._sel_seg = np.asarray(sel_seg, np.int64)

    def _sides(self, stat: Stat) -> Tuple[np.ndarray, np.ndarray]:
        m, t = self._m, self._t
        if m == 0:
            return np.zeros(0), np.zeros(0)
        prod = np.copy(self._term_scale)
        if len(self._rate_seg):
            np.multiply.at(prod, self._rate_seg, stat.rates[self._rate_idx])
        if len(self._sel_seg):
            np.multiply.at(
                prod, self._sel_seg,
                stat.sel[self._sel_idx[:, 0], self._sel_idx[:, 1]])
        term_val = self._term_const + prod
        lhs = np.zeros(m, np.float64)
        rhs = np.zeros(m, np.float64)
        is_rhs = self._term_side == 1
        np.add.at(lhs, self._term_inv[~is_rhs], term_val[~is_rhs])
        np.add.at(rhs, self._term_inv[is_rhs], term_val[is_rhs])
        return lhs, rhs

    def first_violation(self, stat: Stat) -> Optional[int]:
        """Index of the first violated invariant in plan order, else None."""
        lhs, rhs = self._sides(stat)
        # Strict crossing: on an exact tie a deterministic re-run of A can
        # legitimately re-pick the incumbent (tie-break), so firing on
        # equality would manufacture false positives.
        bad = lhs > (1.0 + self.d) * rhs
        idx = np.nonzero(bad)[0]
        return int(idx[0]) if idx.size else None

    def check(self, stat: Stat) -> bool:
        """``D(stat)``: true iff some invariant is violated (§3.2)."""
        return self.first_violation(stat) is not None

    def __len__(self) -> int:
        return len(self.invariants)


def make_variance_violation_prob(
    std_rates: np.ndarray, std_sel: np.ndarray
) -> Callable[[DecidingCondition, Stat], float]:
    """§3.5 hook: a Gaussian first-order estimate of violation probability.

    Treats each statistic as independently normal around its current value
    with the supplied standard deviations; linearizes each side of the
    condition and returns P[lhs' >= rhs'] under the induced normal of the
    margin.  This is deliberately simple — the paper leaves the estimator
    open — but it is monotone in the right quantities (small margin, high
    variance ⇒ high probability).
    """
    from math import erf, sqrt

    def prob(c: DecidingCondition, stat: Stat) -> float:
        margin = c.margin(stat)
        var = 0.0
        for side, sign in ((c.lhs, -1.0), (c.rhs, 1.0)):
            for e in side:
                base = e.eval(stat) - e.const_add
                for r in e.rate_idx:
                    v = float(stat.rates[r])
                    if v > 0:
                        # d(term)/d(rate_r) = base / rate_r (product form)
                        var += (base / v * float(std_rates[r])) ** 2
                for i, j in e.sel_pairs:
                    v = float(stat.sel[i, j])
                    if v > 0:
                        var += (base / v * float(std_sel[i, j])) ** 2
        if var <= 0:
            return 0.0 if margin > 0 else 1.0
        z = margin / sqrt(var)
        return 0.5 * (1.0 - erf(z / sqrt(2.0)))

    return prob
