"""Invariant-based reoptimizing decision machinery (paper §3).

A *deciding condition* is an inequality ``f1(stat1) < f2(stat2)`` whose
verification (a *block-building comparison*, BBC) led the plan generation
algorithm ``A`` to include a specific *building block* in the final plan
(§3.1).  All deciding conditions of a block form its *deciding condition set*
(DCS); DCSs of distinct blocks are disjoint by construction.

Each side of a condition is a **sum of product terms** (``ExprSum``): greedy
step scores are single products ``r_j·∏sel``; ZStream tree costs are
``frozen_subtree_costs + live_cardinality_product`` (§4.2's
subtree-cost-as-constant trick).  Every side therefore evaluates in constant
time, as the paper's complexity analysis requires.

From each DCS we select up to ``K`` conditions as *invariants* (§3.3), by
default the *tightest* ones — smallest ``f2 − f1`` at plan-creation time
(§3.1) — or, when variance estimates are available, the ones most likely to
be violated (§3.5).  The decision function ``D`` is the ordered conjunction
of the invariants: it returns ``true`` iff at least one invariant is violated
under the current statistics, using the *distance* margin ``d`` (§3.4):

    violated  ⇔  f1(stat) >= (1 + d) · f2(stat).

Note on the direction of ``d``: the paper prints the verified invariant as
``(1+d)·f1 < f2``, which taken literally *lowers* the firing bar below the
basic method — contradicting §3.4's stated purpose (damping plan-flapping
when two statistics oscillate around each other) and Figure 5 (throughput
*increases* with d up to ``d_opt`` because *fewer* replans fire).  We
therefore implement the semantics the section describes: a violation
requires the inequality to flip *by a relative margin of at least d*.
``d = 0`` coincides exactly with the basic method either way.

Theorem 1 (d = 0): a violation guarantees the next run of ``A`` yields a
different plan — no false positives.  Theorem 2 (strategy = "all"): keeping
*all* conditions also eliminates false negatives.  Both are exercised as
property tests in ``tests/test_invariants.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from .plans import Expr
from .stats import Stat

# A condition side: sum of product-form terms.
ExprSum = Tuple[Expr, ...]


def eval_sum(side: ExprSum, stat: Stat) -> float:
    return float(sum(e.eval(stat) for e in side))


def _as_sum(side) -> ExprSum:
    if isinstance(side, Expr):
        return (side,)
    return tuple(side)


@dataclasses.dataclass(frozen=True)
class DecidingCondition:
    """``sum(lhs) < sum(rhs)`` attributed to building block ``block``."""

    lhs: ExprSum
    rhs: ExprSum
    block: str

    @staticmethod
    def make(lhs, rhs, block: str) -> "DecidingCondition":
        return DecidingCondition(_as_sum(lhs), _as_sum(rhs), block)

    def margin(self, stat: Stat) -> float:
        """``f2 − f1`` under ``stat`` — positive while the condition holds."""
        return eval_sum(self.rhs, stat) - eval_sum(self.lhs, stat)

    def rel_margin(self, stat: Stat) -> float:
        """``|f2 − f1| / min(f1, f2)`` — the §3.4 relative-difference term."""
        a, b = eval_sum(self.lhs, stat), eval_sum(self.rhs, stat)
        lo = min(a, b)
        return abs(b - a) / max(lo, 1e-12)

    def holds(self, stat: Stat, d: float = 0.0) -> bool:
        """Condition (with distance margin) still holds — not violated."""
        return eval_sum(self.lhs, stat) < (1.0 + d) * eval_sum(self.rhs, stat)

    def __str__(self) -> str:  # pragma: no cover - debugging aid
        l = " + ".join(map(str, self.lhs))
        r = " + ".join(map(str, self.rhs))
        return f"[{self.block}] {l} < {r}"


# A DCS list is ordered by the plan's block order (order-based: step order;
# tree-based: bottom-up node order) — §3.2 verification order.
DCSList = List[Tuple[str, List[DecidingCondition]]]


def select_invariants(
    dcs_list: DCSList,
    stat: Stat,
    k: int = 1,
    strategy: str = "tightest",
    violation_prob: Optional[Callable[[DecidingCondition, Stat], float]] = None,
) -> List[DecidingCondition]:
    """Pick up to ``k`` invariants per DCS (§3.1, §3.3, §3.5).

    strategy:
      * ``"tightest"``  — smallest absolute margin ``f2 − f1`` (paper default).
      * ``"rel"``       — smallest relative margin (scale-free variant).
      * ``"prob"``      — largest estimated violation probability; requires
                          ``violation_prob`` (§3.5 optimization).
      * ``"all"``       — keep every condition (Theorem 2 regime).
    """
    out: List[DecidingCondition] = []
    for _, conds in dcs_list:
        if not conds:
            continue
        if strategy == "all":
            chosen = list(conds)
        elif strategy == "tightest":
            chosen = sorted(conds, key=lambda c: c.margin(stat))[:k]
        elif strategy == "rel":
            chosen = sorted(conds, key=lambda c: c.rel_margin(stat))[:k]
        elif strategy == "prob":
            if violation_prob is None:
                raise ValueError("strategy='prob' requires violation_prob")
            chosen = sorted(
                conds, key=lambda c: -violation_prob(c, stat)
            )[:k]
        else:
            raise ValueError(f"unknown strategy {strategy!r}")
        out.extend(chosen)
    return out


def d_avg_estimate(dcs_list: DCSList, stat: Stat, clip: float = 5.0
                   ) -> float:
    """§3.4 data-analysis heuristic: average relative slack of all deciding
    conditions observed during the initial run of ``A``.

    Each term is clipped (default 5.0): with multiplicative score
    expressions a near-zero side makes a single ratio astronomically
    large, and an unclipped mean is dominated by it (a failure mode of
    the paper's formula on low-selectivity patterns; d > 5 would disable
    adaptation entirely anyway).
    """
    rels = [min(c.rel_margin(stat), clip)
            for _, conds in dcs_list for c in conds]
    if not rels:
        return 0.0
    return float(np.mean(rels))


class InvariantSet:
    """The ordered invariant list verified by ``D`` each loop iteration.

    Verification cost is O(#invariants) ≤ O(K·(B−1)) with each check a
    constant-size sum-of-products evaluation (§3.2); the evaluation is
    vectorized over flattened term arrays so the per-iteration overhead stays
    in the microsecond range even for K-invariant configurations.
    """

    def __init__(self, invariants: Sequence[DecidingCondition], d: float = 0.0):
        self.invariants = list(invariants)
        self.d = float(d)
        self._compile()

    def _compile(self) -> None:
        """Flatten both sides into term-level gather/product arrays.

        Row = one product term.  Products accumulate at the term level via
        ``np.multiply.at``; term values then segment-sum into per-invariant
        side values.
        """
        rows = []  # (inv_idx, side_sign, scale, const, rate_ids, sel_pairs)
        for i, c in enumerate(self.invariants):
            for side, which in ((c.lhs, 0), (c.rhs, 1)):
                for e in side:
                    rows.append((i, which, e.scale, e.const_add,
                                 e.rate_idx, e.sel_pairs))
        t = len(rows)
        self._m = len(self.invariants)
        self._t = t
        self._term_inv = np.array([r[0] for r in rows], np.int64)
        self._term_side = np.array([r[1] for r in rows], np.int64)
        self._term_scale = np.array([r[2] for r in rows], np.float64)
        self._term_const = np.array([r[3] for r in rows], np.float64)
        rate_idx, rate_seg, sel_idx, sel_seg = [], [], [], []
        for ti, r in enumerate(rows):
            for ri in r[4]:
                rate_idx.append(ri)
                rate_seg.append(ti)
            for p in r[5]:
                sel_idx.append(p)
                sel_seg.append(ti)
        self._rate_idx = np.asarray(rate_idx, np.int64)
        self._rate_seg = np.asarray(rate_seg, np.int64)
        self._sel_idx = np.asarray(sel_idx, np.int64).reshape(-1, 2)
        self._sel_seg = np.asarray(sel_seg, np.int64)

    def _sides(self, stat: Stat) -> Tuple[np.ndarray, np.ndarray]:
        m, t = self._m, self._t
        if m == 0:
            return np.zeros(0), np.zeros(0)
        prod = np.copy(self._term_scale)
        if len(self._rate_seg):
            np.multiply.at(prod, self._rate_seg, stat.rates[self._rate_idx])
        if len(self._sel_seg):
            np.multiply.at(
                prod, self._sel_seg,
                stat.sel[self._sel_idx[:, 0], self._sel_idx[:, 1]])
        term_val = self._term_const + prod
        lhs = np.zeros(m, np.float64)
        rhs = np.zeros(m, np.float64)
        is_rhs = self._term_side == 1
        np.add.at(lhs, self._term_inv[~is_rhs], term_val[~is_rhs])
        np.add.at(rhs, self._term_inv[is_rhs], term_val[is_rhs])
        return lhs, rhs

    def first_violation(self, stat: Stat) -> Optional[int]:
        """Index of the first violated invariant in plan order, else None."""
        lhs, rhs = self._sides(stat)
        # Strict crossing: on an exact tie a deterministic re-run of A can
        # legitimately re-pick the incumbent (tie-break), so firing on
        # equality would manufacture false positives.
        bad = lhs > (1.0 + self.d) * rhs
        idx = np.nonzero(bad)[0]
        return int(idx[0]) if idx.size else None

    def check(self, stat: Stat) -> bool:
        """``D(stat)``: true iff some invariant is violated (§3.2)."""
        return self.first_violation(stat) is not None

    def lower(self, n: int, max_inv: Optional[int] = None,
              max_terms: Optional[int] = None) -> "LoweredInvariants":
        """Lower this set into device tensors (see ``lower_invariants``)."""
        return lower_invariants(self.invariants, self.d, n,
                                max_inv=max_inv, max_terms=max_terms)

    def __len__(self) -> int:
        return len(self.invariants)


# ---------------------------------------------------------------------------
# Device lowering (§3.3-§3.5 at fleet scale)
# ---------------------------------------------------------------------------
#
# ``InvariantSet`` evaluates on the host in numpy.  For the fleet executor
# that forces a device→host statistics sync per partition per chunk, so the
# invariant set is *lowered* into fixed-shape tensors that evaluate inside
# the jitted data plane:
#
#   term value  = const + scale · ∏_j rates[j]^rate_exp[j]
#                               · ∏_{jk} sel[j,k]^sel_exp[j,k]
#   side value  = Σ over the term axis
#   violated    = any(active ∧ lhs > (1+d)·rhs)
#
# Exponent form covers every ``Expr`` the planners emit (products of
# distinct statistics → exponents in {0, 1}) while keeping one static shape
# per (max_inv, max_terms, n) triple.  Padding rows have scale = const = 0,
# so they evaluate to exactly 0 on both sides and — with the strict ``>``
# and ``active`` mask — can never fire.


class LoweredInvariants(NamedTuple):
    """An invariant set as fixed-shape tensors (a jax pytree).

    Shapes (I = max_inv, T = max_terms, n = pattern size); side axis is
    [0] = lhs, [1] = rhs.  Stacking K of these along a new leading axis
    yields the fleet's per-partition invariant matrix; deploying a fresh
    set for one partition writes one row of each field.
    """

    scale: np.ndarray     # (I, 2, T) f32
    const: np.ndarray     # (I, 2, T) f32
    rate_exp: np.ndarray  # (I, 2, T, n) f32
    sel_exp: np.ndarray   # (I, 2, T, n, n) f32
    active: np.ndarray    # (I,) bool
    d: np.ndarray         # ()  f32 — distance margin of this set


def lower_invariants(
    invariants: Sequence[DecidingCondition],
    d: float,
    n: int,
    max_inv: Optional[int] = None,
    max_terms: Optional[int] = None,
) -> LoweredInvariants:
    """Lower deciding conditions into ``LoweredInvariants`` tensors.

    ``max_inv`` / ``max_terms`` fix the static shape (so K lowered sets can
    be stacked and re-deployed row-wise without recompiling); they default
    to the exact sizes needed.  Raises ``ValueError`` when the set exceeds
    the caps — callers stacking across partitions should size the caps for
    the worst case their planner can emit.
    """
    need_i = len(invariants)
    need_t = max(
        [len(side) for c in invariants for side in (c.lhs, c.rhs)],
        default=1)
    i_cap = need_i if max_inv is None else int(max_inv)
    t_cap = need_t if max_terms is None else int(max_terms)
    if need_i > i_cap:
        raise ValueError(
            f"{need_i} invariants exceed max_inv={i_cap}; raise the cap")
    if need_t > t_cap:
        raise ValueError(
            f"{need_t} terms/side exceed max_terms={t_cap}; raise the cap")
    i_cap, t_cap = max(i_cap, 1), max(t_cap, 1)

    scale = np.zeros((i_cap, 2, t_cap), np.float32)
    const = np.zeros((i_cap, 2, t_cap), np.float32)
    rate_exp = np.zeros((i_cap, 2, t_cap, n), np.float32)
    sel_exp = np.zeros((i_cap, 2, t_cap, n, n), np.float32)
    active = np.zeros((i_cap,), bool)
    for i, c in enumerate(invariants):
        active[i] = True
        for s, side in enumerate((c.lhs, c.rhs)):
            for t, e in enumerate(side):
                scale[i, s, t] = e.scale
                const[i, s, t] = e.const_add
                for r in e.rate_idx:
                    rate_exp[i, s, t, r] += 1.0
                for (a, b) in e.sel_pairs:
                    sel_exp[i, s, t, a, b] += 1.0
    return LoweredInvariants(scale, const, rate_exp, sel_exp, active,
                             np.float32(d))


def stack_lowered(rows: Sequence[LoweredInvariants]) -> LoweredInvariants:
    """Stack per-partition lowered sets along a new leading K axis.

    The result's arrays are host numpy so the control plane can rewrite one
    partition's row in place on deployment (mirroring the plan matrix).
    """
    return LoweredInvariants(*(np.stack([np.asarray(getattr(r, f))
                                         for r in rows])
                               for f in LoweredInvariants._fields))


def write_lowered_row(stacked: LoweredInvariants, p: int,
                      row: LoweredInvariants) -> None:
    """Deploy a fresh invariant set for partition ``p``: one row write per
    field, never a recompile (shapes must match the stacked caps)."""
    for f in LoweredInvariants._fields:
        dst, src = getattr(stacked, f), np.asarray(getattr(row, f))
        if dst[p].shape != src.shape:
            raise ValueError(
                f"lowered field {f!r}: row shape {src.shape} != stacked "
                f"{dst[p].shape}; lower with the fleet's max_inv/max_terms")
        dst[p] = src


class StackedLowered:
    """Fleet invariant matrix: host-writable rows, device-cached tensors.

    The control plane rewrites one partition's row on deployment (numpy,
    in place); the data plane consumes ``device()``, which re-uploads the
    stacked tensors only after a write.  Without the cache every chunk
    tick would pay K×6 host→device transfers — measurably more than the
    monitoring math itself.
    """

    def __init__(self, rows: Sequence[LoweredInvariants]):
        self.host = stack_lowered(rows)
        self._dev: Optional[LoweredInvariants] = None

    def write_row(self, p: int, row: LoweredInvariants) -> None:
        write_lowered_row(self.host, p, row)
        if self._dev is not None:
            # Patch the device copy in place (one-row transfer per field)
            # rather than invalidating it — otherwise every deployment
            # would re-upload all K partitions' tensors on the next chunk.
            import jax.numpy as jnp

            self._dev = LoweredInvariants(*(
                getattr(self._dev, f).at[p].set(
                    jnp.asarray(getattr(row, f)))
                for f in LoweredInvariants._fields))

    def device(self) -> LoweredInvariants:
        if self._dev is None:
            import jax.numpy as jnp

            self._dev = LoweredInvariants(
                *(jnp.asarray(x) for x in self.host))
        return self._dev


def _lowered_sides(low: LoweredInvariants, rates, sel, xp):
    """Shared jnp/numpy evaluation: per-invariant (lhs, rhs) side values."""
    rt = xp.prod(rates[None, None, None, :] ** low.rate_exp, axis=-1)
    sl = xp.prod(sel[None, None, None, :, :] ** low.sel_exp, axis=(-2, -1))
    term = low.const + low.scale * rt * sl          # (I, 2, T)
    sides = term.sum(axis=-1)                       # (I, 2)
    return sides[:, 0], sides[:, 1]


def eval_lowered(low: LoweredInvariants, rates, sel):
    """Device-side ``D``: (violated scalar bool, drift scalar f32).

    ``drift`` is the §3.4-style signed relative margin of the tightest
    invariant — ``max_i (lhs − (1+d)·rhs) / max(min(|lhs|,|rhs|), ε)`` —
    positive iff violated; its magnitude is the telemetry distance.
    Pure jnp, vmappable over a leading partition axis.
    """
    import jax.numpy as jnp

    if low.active.shape[0] == 0:
        return jnp.asarray(False), jnp.float32(-3.0e38)
    lhs, rhs = _lowered_sides(low, rates, sel, jnp)
    gap = lhs - (1.0 + low.d) * rhs
    bad = low.active & (gap > 0.0)
    rel = gap / jnp.maximum(jnp.minimum(jnp.abs(lhs), jnp.abs(rhs)), 1e-12)
    drift = jnp.max(jnp.where(low.active, rel, -3.0e38))
    return jnp.any(bad), drift


def check_lowered_np(low: LoweredInvariants, rates: np.ndarray,
                     sel: np.ndarray) -> Tuple[bool, float]:
    """Host float32 mirror of ``eval_lowered`` (bit-level reference for the
    differential tests — same dtype, same operation order)."""
    if low.active.shape[0] == 0:
        return False, -3.0e38
    lhs, rhs = _lowered_sides(
        low, np.asarray(rates, np.float32), np.asarray(sel, np.float32), np)
    gap = lhs - (np.float32(1.0) + low.d) * rhs
    bad = low.active & (gap > 0.0)
    rel = gap / np.maximum(np.minimum(np.abs(lhs), np.abs(rhs)),
                           np.float32(1e-12))
    drift = float(np.max(np.where(low.active, rel, -3.0e38)))
    return bool(np.any(bad)), drift


def make_variance_violation_prob(
    std_rates: np.ndarray, std_sel: np.ndarray
) -> Callable[[DecidingCondition, Stat], float]:
    """§3.5 hook: a Gaussian first-order estimate of violation probability.

    Treats each statistic as independently normal around its current value
    with the supplied standard deviations; linearizes each side of the
    condition and returns P[lhs' >= rhs'] under the induced normal of the
    margin.  This is deliberately simple — the paper leaves the estimator
    open — but it is monotone in the right quantities (small margin, high
    variance ⇒ high probability).
    """
    from math import erf, sqrt

    def prob(c: DecidingCondition, stat: Stat) -> float:
        margin = c.margin(stat)
        var = 0.0
        for side, sign in ((c.lhs, -1.0), (c.rhs, 1.0)):
            for e in side:
                base = e.eval(stat) - e.const_add
                for r in e.rate_idx:
                    v = float(stat.rates[r])
                    if v > 0:
                        # d(term)/d(rate_r) = base / rate_r (product form)
                        var += (base / v * float(std_rates[r])) ** 2
                for i, j in e.sel_pairs:
                    v = float(stat.sel[i, j])
                    if v > 0:
                        var += (base / v * float(std_sel[i, j])) ** 2
        if var <= 0:
            return 0.0 if margin > 0 else 1.0
        z = margin / sqrt(var)
        return 0.5 * (1.0 - erf(z / sqrt(2.0)))

    return prob
