"""AdamW optimizer + LR schedules, built from scratch (no optax offline).

* fp32 first/second moments regardless of parameter dtype;
* optional fp32 master copy when parameters are bf16 (mixed-precision
  training: updates accumulate in fp32, params round to bf16);
* global-norm gradient clipping;
* linear-warmup + cosine-decay schedule;
* optional int8 error-feedback state for compressed gradient all-reduce
  (``distributed.collectives``) — the error-feedback residual lives next to
  the moments so checkpointing captures it.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any       # fp32 master params, or () when params are fp32
    ef: Any           # error-feedback residuals, or () when uncompressed


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    lr_min_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    use_master: bool = True         # fp32 master when params are low-prec
    error_feedback: bool = False    # allocate EF residuals


def cosine_lr(cfg: AdamWConfig, step) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.lr_min_ratio + (1 - cfg.lr_min_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * t))
    return cfg.lr_peak * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params) -> AdamWState:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    low_prec = any(
        x.dtype != jnp.float32 for x in jax.tree.leaves(params))
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros32, params),
        v=jax.tree.map(zeros32, params),
        master=(jax.tree.map(lambda p: p.astype(jnp.float32), params)
                if (cfg.use_master and low_prec) else ()),
        ef=(jax.tree.map(zeros32, params) if cfg.error_feedback else ()),
    )


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def apply_update(cfg: AdamWConfig, params, grads, state: AdamWState
                 ) -> Tuple[Any, AdamWState, dict]:
    """One AdamW step.  Returns (params, state, metrics)."""
    step = state.step + 1
    lr = cosine_lr(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(
        lambda g: g.astype(jnp.float32) * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                     state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    ref = state.master if state.master != () else params

    def upd(p32, m_, v_):
        mh = m_ / bc1
        vh = v_ / bc2
        return (p32.astype(jnp.float32)
                - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                        + cfg.weight_decay * p32.astype(jnp.float32)))

    new_ref = jax.tree.map(upd, ref, m, v)
    if state.master != ():
        new_params = jax.tree.map(
            lambda r, p: r.astype(p.dtype), new_ref, params)
        new_master = new_ref
    else:
        new_params = jax.tree.map(
            lambda r, p: r.astype(p.dtype), new_ref, params)
        new_master = ()

    new_state = AdamWState(step=step, m=m, v=v, master=new_master,
                           ef=state.ef)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def state_logical_axes(param_axes, cfg: AdamWConfig, low_prec: bool):
    """Optimizer-state logical axes mirror the parameter axes."""
    return AdamWState(
        step=(),
        m=param_axes,
        v=param_axes,
        master=param_axes if (cfg.use_master and low_prec) else (),
        ef=param_axes if cfg.error_feedback else (),
    )
