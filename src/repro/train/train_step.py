"""Sharded train/serve step factories.

``make_train_step`` builds the jitted ``(params, opt_state, batch) ->
(params, opt_state, metrics)`` function with in/out shardings resolved from
the logical-axis trees (``distributed.sharding``), optional gradient
accumulation (scan over microbatches, fp32 accumulator), and the optional
int8 error-feedback compressed gradient all-reduce.

``make_serve_steps`` builds the jitted ``prefill`` / ``decode`` pair with
cache shardings (split-T flash-decoding layout over the model axis).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from ..distributed.collectives import compressed_psum_tree
from ..distributed.sharding import MeshRules, current_rules, use_rules
from ..launch import shapes as shapes_lib
from ..models.model import Model
from .optimizer import AdamWConfig, AdamWState, apply_update, init_state


def tree_shardings(rules: MeshRules, structs, axes):
    """Resolve a ShapeDtypeStruct tree + logical-axes tree -> shardings."""
    def one(s, ax):
        if ax == () or ax is None:
            return NamedSharding(rules.mesh, PartitionSpec())
        return rules.sharding(s.shape, ax, tag=str(ax))
    return jax.tree.map(one, structs, axes,
                        is_leaf=lambda x: hasattr(x, "shape"))


def _opt_axes(model: Model, opt_cfg: AdamWConfig, zero1: bool = False):
    param_axes = model.axes()
    if zero1:
        # ZeRO-1: optimizer states shard their d_model dims over "data"
        # even though the parameters themselves replicate over it.
        def z(ax):
            return tuple("opt_embed" if a == "embed" else a for a in ax)
        param_axes = jax.tree.map(
            z, param_axes, is_leaf=lambda x: isinstance(x, tuple)
            and all(isinstance(a, (str, type(None))) for a in x))
    low_prec = model.cfg.param_dtype != "f32"
    return AdamWState(
        step=(),
        m=param_axes,
        v=param_axes,
        master=(param_axes if (opt_cfg.use_master and low_prec) else ()),
        ef=(param_axes if opt_cfg.error_feedback else ()),
    )


def make_train_step(
    model: Model,
    opt_cfg: AdamWConfig,
    *,
    microbatches: int = 1,
    compressed_grads: bool = False,
    mesh: Optional[Mesh] = None,
):
    """Returns (train_step, shardings) — jit-ready with explicit shardings.

    With ``microbatches > 1`` the global batch splits along dim 0 and
    gradients accumulate in fp32 across a ``lax.scan`` (memory for
    activations scales with the microbatch, not the batch).
    """
    cfg = model.cfg

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads
        # Gradient accumulation: scan over microbatch slices.
        def reshape(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape((microbatches, b // microbatches)
                             + x.shape[1:])
        mb = jax.tree.map(reshape, batch)

        def step(acc, one):
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, one)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                acc, grads)
            return acc, (loss, metrics)

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, (losses, metricses) = jax.lax.scan(step, zero, mb)
        loss = losses.mean()
        metrics = jax.tree.map(
            lambda m: m.mean(axis=0) if hasattr(m, "ndim") and m.ndim > 0
            else m, metricses)
        return loss, metrics, grads

    def train_step(params, opt_state: AdamWState, batch):
        loss, metrics, grads = compute_grads(params, batch)
        if compressed_grads and mesh is not None and "data" in mesh.shape:
            grads, new_ef = compressed_psum_tree(
                grads, opt_state.ef, mesh, axis="data")
            opt_state = opt_state._replace(ef=new_ef)
        params, opt_state, om = apply_update(
            opt_cfg, params, grads, opt_state)
        return params, opt_state, {**metrics, **om, "loss_out": loss}

    return train_step


def lower_train_step(model: Model, opt_cfg: AdamWConfig, mesh: Mesh,
                     shape_name: str, *, microbatches: int = 1,
                     rule_overrides: Optional[Dict] = None,
                     compressed_grads: bool = False,
                     zero1: bool = False,
                     donate: bool = True):
    """Lower (no compile) the train step for (arch × shape × mesh).

    ``zero1``: ZeRO-1 layout — parameters replicate over "data" (their
    model-axis dims stay sharded) while optimizer moments/master shard
    their d_model dims over "data".  Removes the per-layer FSDP parameter
    all-gathers (which XLA hoists out of the layer scan, defeating FSDP's
    memory promise) at the price of the params+grads being data-replicated.
    """
    cfg = model.cfg
    if zero1:
        rule_overrides = {**(rule_overrides or {}), "embed": None}
    with use_rules(mesh, rule_overrides) as rules:
        batch_structs, batch_axes = shapes_lib.input_specs(cfg, shape_name)
        param_structs = model.abstract()
        param_axes = model.axes()
        opt_structs = jax.eval_shape(
            lambda p: init_state(opt_cfg, p), param_structs)
        opt_axes = _opt_axes(model, opt_cfg, zero1=zero1)

        param_sh = tree_shardings(rules, param_structs, param_axes)
        opt_sh = tree_shardings(rules, opt_structs, opt_axes)
        batch_sh = tree_shardings(rules, batch_structs, batch_axes)

        step = make_train_step(
            model, opt_cfg, microbatches=microbatches,
            compressed_grads=compressed_grads, mesh=mesh)
        jitted = jax.jit(
            step,
            in_shardings=(param_sh, opt_sh, batch_sh),
            out_shardings=(param_sh, opt_sh, None),
            donate_argnums=(0, 1) if donate else (),
        )
        with mesh:
            lowered = jitted.lower(param_structs, opt_structs,
                                   batch_structs)
        return lowered, rules


def lower_serve_step(model: Model, mesh: Mesh, shape_name: str,
                     rule_overrides: Optional[Dict] = None):
    """Lower prefill (shape kind 'prefill') or decode ('decode')."""
    cfg = model.cfg
    spec = shapes_lib.SHAPES[shape_name]
    with use_rules(mesh, rule_overrides) as rules:
        param_structs = model.abstract()
        param_sh = tree_shardings(rules, param_structs, model.axes())
        if spec.kind == "prefill":
            batch_structs, batch_axes = shapes_lib.input_specs(
                cfg, shape_name)
            batch_sh = tree_shardings(rules, batch_structs, batch_axes)

            cache_len = spec.seq + (cfg.n_frontend_tokens
                                    if cfg.family == "vlm" else 0)

            def prefill(params, batch):
                return model.prefill(params, batch, cache_len)

            jitted = jax.jit(prefill,
                             in_shardings=(param_sh, batch_sh))
            with mesh:
                lowered = jitted.lower(param_structs, batch_structs)
        elif spec.kind == "decode":
            (cache_structs, tok_structs), (cache_axes, tok_axes) = \
                shapes_lib.input_specs(cfg, shape_name)
            cache_sh = tree_shardings(rules, cache_structs, cache_axes)
            tok_sh = tree_shardings(rules, tok_structs, tok_axes)

            def decode(params, cache, tok):
                return model.decode_step(params, cache, tok)

            jitted = jax.jit(decode,
                             in_shardings=(param_sh, cache_sh, tok_sh),
                             donate_argnums=(1,))
            with mesh:
                lowered = jitted.lower(param_structs, cache_structs,
                                       tok_structs)
        else:
            raise ValueError(spec.kind)
        return lowered, rules
