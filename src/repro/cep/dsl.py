"""Fluent pattern-builder DSL compiling to structural-tensor ``Pattern``s.

The engine's native pattern form (``core.patterns.Pattern``) is built from
hand-assembled ``Predicate`` op-code tuples — precise, but hostile as a
public surface.  This module provides the algebra the paper writes its
queries in:

    P.seq(0, 1, 2).where(P.attr(0) < P.attr(1) - 0.3,
                         P.attr(1) < P.attr(2) - 0.3).within(4.0)

* ``P.seq(...)`` / ``P.and_(...)`` take event *type ids*; an element may be
  wrapped in ``P.neg(t)`` (required absence) or ``P.kleene(t, bound=...)``
  (counted closure) — at most one of each, sequences only, matching the
  engine's single-operator patterns.
* ``P.attr(i, k)`` references attribute ``k`` of the *i*-th primitive
  element (negated elements do not consume a position index, mirroring the
  paper's convention that negated events are outside the plan size ``n``);
  ``P.neg_attr(k)`` references the negated event.  Comparisons build
  predicates with exactly the engine's op-codes:

      a < b + θ   →  PRED_LT, theta=θ        (shift folds into θ)
      a > b - θ   →  PRED_GT, theta=θ
      abs(a - b) <= θ  →  PRED_ABS_LE, theta=θ

  The engine evaluates strict inequalities only, so ``<=``/``>=`` between
  attributes raise instead of silently weakening the predicate.
* ``P.or_(...)`` builds an OR-composite: a disjunction of independently
  planned and executed branches (``CompositePattern``); the ``Session``
  facade decomposes it into per-branch sub-sessions and aggregates counts.

Builders are immutable: ``where``/``within``/``named``/``attrs`` return new
builders, so partial patterns can be shared and specialized.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple, Union

from ..core.patterns import (PRED_ABS_LE, PRED_GT, PRED_LT, CompositePattern,
                             Operator, Pattern, Predicate)

__all__ = ["P", "PatternBuilder", "CompositeBuilder"]


# ---------------------------------------------------------------------------
# Attribute references and predicate expressions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttrRef:
    """``P.attr(pos, k)`` (or ``P.neg_attr(k)``), plus a folded scalar shift."""

    pos: Optional[int]        # primitive position; None for the negated event
    attr: int = 0
    shift: float = 0.0

    @property
    def is_neg(self) -> bool:
        return self.pos is None

    # -- scalar shifts (fold into theta) ------------------------------------

    def __add__(self, c: float) -> "AttrRef":
        return dataclasses.replace(self, shift=self.shift + float(c))

    __radd__ = __add__

    def __sub__(self, other: Union["AttrRef", float]):
        if isinstance(other, AttrRef):
            return AttrDiff(self, other)
        return dataclasses.replace(self, shift=self.shift - float(other))

    # -- comparisons --------------------------------------------------------

    def __lt__(self, other: "AttrRef") -> "Cond":
        # a + sa < b + sb  ⇔  a < b + (sb − sa)  →  PRED_LT, θ = sb − sa
        _check_pair(self, other)
        return Cond(self, other, PRED_LT, other.shift - self.shift)

    def __gt__(self, other: "AttrRef") -> "Cond":
        # a + sa > b + sb  ⇔  a > b − (sa − sb)  →  PRED_GT, θ = sa − sb
        _check_pair(self, other)
        return Cond(self, other, PRED_GT, self.shift - other.shift)

    def __le__(self, other):
        raise TypeError("the engine evaluates strict inequalities only; "
                        "use < / > (or abs(a - b) <= theta)")

    __ge__ = __le__


@dataclasses.dataclass(frozen=True)
class AttrDiff:
    """``a - b`` between two attribute refs; only ``abs(...)`` is consumable."""

    a: AttrRef
    b: AttrRef

    def __abs__(self) -> "AbsDiff":
        return AbsDiff(self.a, self.b)


@dataclasses.dataclass(frozen=True)
class AbsDiff:
    a: AttrRef
    b: AttrRef

    def __le__(self, theta: float) -> "Cond":
        _check_pair(self.a, self.b)
        if self.a.shift or self.b.shift:
            raise ValueError("abs-difference predicates do not support "
                             "scalar shifts; compare unshifted attributes")
        return Cond(self.a, self.b, PRED_ABS_LE, float(theta))

    def __lt__(self, theta):
        raise TypeError("the engine evaluates abs-difference as <=; "
                        "write abs(a - b) <= theta")


def _check_pair(a: AttrRef, b: AttrRef) -> None:
    if not isinstance(b, AttrRef):
        raise TypeError("predicates compare two attribute references; "
                        f"got {type(b).__name__} (unary/constant predicates "
                        "are not supported by the data plane)")
    if a.is_neg and b.is_neg:
        raise ValueError("a predicate cannot relate the negated event "
                         "to itself")


@dataclasses.dataclass(frozen=True)
class Cond:
    """One pairwise predicate in DSL form (positions, not type ids)."""

    a: AttrRef
    b: AttrRef
    op: int
    theta: float

    def __bool__(self) -> bool:
        # Python rewrites `a < b < c` as `(a < b) and (b < c)`, which
        # truth-tests the first Cond and would silently discard it —
        # a weaker pattern with no error.  Refuse to be a boolean.
        raise TypeError(
            "predicate expressions cannot be chained (`a < b < c`) or "
            "used as booleans; pass each comparison to where() separately")


# ---------------------------------------------------------------------------
# Pattern elements
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class NegElement:
    type_id: int


@dataclasses.dataclass(frozen=True)
class KleeneElement:
    type_id: int
    bound: Optional[int] = None


Element = Union[int, NegElement, KleeneElement]


# ---------------------------------------------------------------------------
# Builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PatternBuilder:
    """Immutable, chainable single-operator pattern under construction."""

    base: Operator                      # SEQ or AND (refined at build time)
    elements: Tuple[Element, ...]
    window: Optional[float] = None
    conds: Tuple[Cond, ...] = ()
    n_attrs: Optional[int] = None       # None -> inferred from predicates
    name: Optional[str] = None

    # -- chainable refinements ---------------------------------------------

    def where(self, *conds: Cond) -> "PatternBuilder":
        for c in conds:
            if not isinstance(c, Cond):
                raise TypeError(
                    f"where() takes predicate expressions built from "
                    f"P.attr(...); got {type(c).__name__}")
        return dataclasses.replace(self, conds=self.conds + tuple(conds))

    def within(self, window: float) -> "PatternBuilder":
        if window <= 0:
            raise ValueError("within() needs a positive time window")
        return dataclasses.replace(self, window=float(window))

    def attrs(self, n_attrs: int) -> "PatternBuilder":
        return dataclasses.replace(self, n_attrs=int(n_attrs))

    def named(self, name: str) -> "PatternBuilder":
        return dataclasses.replace(self, name=str(name))

    # -- compilation --------------------------------------------------------

    def build(self) -> Pattern:
        if self.window is None:
            raise ValueError("pattern has no time window; call .within(W)")
        prim_types, neg, kleene_pos, kleene_bound = [], None, None, None
        neg_pos = None
        for el in self.elements:
            if isinstance(el, NegElement):
                if self.base is not Operator.SEQ:
                    raise ValueError("P.neg(...) elements require P.seq")
                if neg is not None:
                    raise ValueError("at most one negated element")
                neg, neg_pos = el.type_id, len(prim_types)
            elif isinstance(el, KleeneElement):
                if self.base is not Operator.SEQ:
                    raise ValueError("P.kleene(...) elements require P.seq")
                if kleene_pos is not None:
                    raise ValueError("at most one Kleene element")
                kleene_pos, kleene_bound = len(prim_types), el.bound
                prim_types.append(int(el.type_id))
            else:
                prim_types.append(int(el))
        if neg is not None and kleene_pos is not None:
            raise ValueError("negation and Kleene closure cannot be "
                             "combined in one pattern")
        if len(prim_types) < 2:
            raise ValueError("a pattern needs at least two primitive "
                             "(non-negated) elements")
        all_types = prim_types + ([neg] if neg is not None else [])
        if len(set(all_types)) != len(all_types):
            raise ValueError("event types must be distinct within a "
                             "pattern (structural predicate tensors are "
                             "keyed by type)")

        preds, neg_preds = [], []
        for c in self.conds:
            pr = self._compile_cond(c, prim_types, neg)
            (neg_preds if (c.a.is_neg or c.b.is_neg) else preds).append(pr)

        operator = self.base
        if neg is not None:
            operator = Operator.NEG
        elif kleene_pos is not None:
            operator = Operator.KLEENE
        return Pattern(
            operator=operator,
            type_ids=tuple(prim_types),
            window=float(self.window),
            predicates=tuple(preds),
            n_attrs=self._n_attrs(),
            negated_type=neg,
            negated_predicates=tuple(neg_preds),
            negated_pos=neg_pos,
            kleene_pos=kleene_pos,
            kleene_bound=kleene_bound,
            name=self.name or operator.value.lower(),
        )

    def _compile_cond(self, c: Cond, prim_types, neg) -> Predicate:
        def tid(ref: AttrRef) -> int:
            if ref.is_neg:
                if neg is None:
                    raise ValueError("P.neg_attr(...) used but the pattern "
                                     "has no negated element")
                return neg
            if not 0 <= ref.pos < len(prim_types):
                raise ValueError(
                    f"P.attr({ref.pos}, ...) out of range for a pattern "
                    f"with {len(prim_types)} primitive elements")
            return prim_types[ref.pos]

        return Predicate(tid(c.a), tid(c.b), c.op,
                         c.a.attr, c.b.attr, c.theta)

    def _n_attrs(self) -> int:
        if self.n_attrs is not None:
            return self.n_attrs
        used = [c.a.attr for c in self.conds] + [c.b.attr for c in self.conds]
        return max(used, default=0) + 1


@dataclasses.dataclass(frozen=True)
class CompositeBuilder:
    """OR-composite of independent branches (paper §5 pattern set 5)."""

    branches: Tuple[Union[PatternBuilder, Pattern], ...]
    name: str = "or"

    def named(self, name: str) -> "CompositeBuilder":
        return dataclasses.replace(self, name=str(name))

    def build(self) -> CompositePattern:
        built = tuple(b.build() if isinstance(b, PatternBuilder) else b
                      for b in self.branches)
        return CompositePattern(built, name=self.name)


def as_pattern(p) -> Union[Pattern, CompositePattern]:
    """Accept builders or already-compiled patterns (facade entry point)."""
    if isinstance(p, (PatternBuilder, CompositeBuilder)):
        return p.build()
    if isinstance(p, (Pattern, CompositePattern)):
        return p
    raise TypeError(
        f"expected a P.seq/P.and_/P.or_ builder, Pattern, or "
        f"CompositePattern; got {type(p).__name__}")


# ---------------------------------------------------------------------------
# The public namespace
# ---------------------------------------------------------------------------


class P:
    """Pattern-builder namespace: combinators and attribute references."""

    @staticmethod
    def seq(*elements: Element) -> PatternBuilder:
        """Temporally ordered pattern (SEQ; NEG/KLEENE via wrapped items)."""
        return PatternBuilder(Operator.SEQ, tuple(elements))

    @staticmethod
    def and_(*elements: int) -> PatternBuilder:
        """Unordered conjunction (AND) of plain event types."""
        return PatternBuilder(Operator.AND, tuple(elements))

    @staticmethod
    def or_(*branches: Union[PatternBuilder, Pattern]) -> CompositeBuilder:
        """Disjunction of sub-patterns, each planned/adapted independently."""
        if len(branches) < 2:
            raise ValueError("P.or_ needs at least two branches")
        return CompositeBuilder(tuple(branches))

    @staticmethod
    def neg(type_id: int) -> NegElement:
        """Required absence of ``type_id`` between its seq neighbours."""
        return NegElement(int(type_id))

    @staticmethod
    def kleene(type_id: int, bound: Optional[int] = None) -> KleeneElement:
        """Counted Kleene closure over ``type_id`` (count-only semantics)."""
        return KleeneElement(int(type_id), bound)

    @staticmethod
    def attr(pos: int, attr: int = 0) -> AttrRef:
        """Attribute ``attr`` of the ``pos``-th primitive element."""
        return AttrRef(int(pos), int(attr))

    @staticmethod
    def neg_attr(attr: int = 0) -> AttrRef:
        """Attribute ``attr`` of the pattern's negated element."""
        return AttrRef(None, int(attr))
