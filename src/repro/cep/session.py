"""The unified CEP runtime facade: one ``Session``, everything else config.

The paper's thesis is that a single adaptive mechanism serves *any* plan
family; this module is that thesis applied to our own public API.  The
pre-facade surface encoded "plan kind", "monitored", and "fleet" as a
ladder of eight classes; here they are three arguments:

    session = cep.open(pattern, partitions=K,
                       plan="order" | "tree" | "auto",
                       monitor=True | False,
                       config=RuntimeConfig(...))

* ``partitions``: K = 1 is simply a fleet of one — the data plane is always
  the vmapped fleet executor, so scaling out never changes semantics.
* ``plan``: the plan family ("auto" compares the two planners' cold-start
  costs under the uniform prior and picks the cheaper family).
* ``monitor``: where invariant verification runs — ``False`` keeps the
  decision policy on the host (statistics sync per chunk), ``True`` fuses
  the statistics rings and lowered invariant sets into the compiled step
  (host work ∝ violations, §3.3–§3.5).

Two control planes hang off one session, both driving the same compiled
data plane:

* **Batch** — ``run(stream)`` consumes a whole chunk stream through the
  adaptive loop (Algorithm 1 per partition: estimator → decision policy →
  planner → [36] migration split) and returns a ``Telemetry``.
* **Incremental** — ``process(...)`` / ``step(...)`` / ``deploy(...)``
  advance the session one keyed batch or pre-stacked chunk at a time
  (serving style: immediate plan swaps, cumulative counters).

OR-composites (``P.or_``) decompose into one sub-session per branch;
detection is the union of branch detections, so counters aggregate as
per-branch sums and ``telemetry().branches`` keeps the breakdown.

The legacy ladder (``FleetRunner``, ``MonitoredCEPFleetServingEngine``, …)
still implements the mechanics; this facade owns configuration and
composition, and the ladder's public constructors now carry
``DeprecationWarning``s pointing here.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Iterable, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.adaptation import make_planner
from ..core.compat import legacy_ok
from ..core.engine import Chunk
from ..core.fleet import (FleetChunk, FleetMetrics, FleetRunner,
                          MonitoredFleetRunner, stack_chunks, stacked_streams)
from ..core.patterns import CompositePattern, Pattern
from ..core.plans import plan_cost
from ..core.stats import uniform_stat
from ..data.cep_streams import ChunkRecord
from ..serving.engine import (CEPFleetServingEngine,
                              MonitoredCEPFleetServingEngine)
from .config import RuntimeConfig
from .dsl import as_pattern

__all__ = ["Session", "Telemetry", "open"]

_COUNTERS = (
    "chunks", "events", "matches", "replans", "deployments", "violations",
    "host_syncs", "overflow", "dropped", "neg_rejected",
    "closure_expansions", "escalations", "migration_partition_chunks",
)


@dataclasses.dataclass
class Telemetry:
    """Uniform counter snapshot across both control planes.

    ``matches`` is the exactly-once full-match total (summed over branches
    for OR-composites); ``per_partition_matches`` keeps the (K,) split.
    ``violations``/``host_syncs`` are nonzero only for monitored sessions;
    ``dropped`` counts keyed-batch routing overflow (back-pressure).
    ``events`` is maintained by ``run`` and ``process`` — ``step`` skips
    it to avoid a per-tick device sync.
    """

    partitions: int = 1
    chunks: int = 0
    events: int = 0
    matches: int = 0
    per_partition_matches: Optional[np.ndarray] = None
    replans: int = 0
    deployments: int = 0
    violations: int = 0
    host_syncs: int = 0
    overflow: int = 0
    dropped: int = 0
    neg_rejected: int = 0
    closure_expansions: int = 0
    escalations: int = 0
    migration_partition_chunks: int = 0
    engine_time_s: float = 0.0
    control_time_s: float = 0.0
    last_drift: Optional[np.ndarray] = None
    branches: Optional[Tuple["Telemetry", ...]] = None

    def merge(self, other: "Telemetry") -> "Telemetry":
        """Accumulate ``other`` into self (counters add, arrays add)."""
        for f in _COUNTERS:
            setattr(self, f, getattr(self, f) + getattr(other, f))
        self.engine_time_s += other.engine_time_s
        self.control_time_s += other.control_time_s
        if other.per_partition_matches is not None:
            if self.per_partition_matches is None:
                self.per_partition_matches = np.zeros(
                    other.per_partition_matches.shape, np.int64)
            self.per_partition_matches = (
                self.per_partition_matches + other.per_partition_matches)
        if other.last_drift is not None:
            self.last_drift = other.last_drift
        return self


def _from_fleet_metrics(m: FleetMetrics, k: int) -> Telemetry:
    return Telemetry(
        partitions=k,
        chunks=m.chunks,
        events=m.events,
        matches=m.full_matches,
        per_partition_matches=(None if m.per_partition_matches is None
                               else m.per_partition_matches.copy()),
        replans=m.replans,
        deployments=m.deployments,
        violations=m.violations,
        host_syncs=m.host_syncs,
        overflow=m.overflow,
        neg_rejected=m.neg_rejected,
        closure_expansions=m.closure_expansions,
        escalations=m.escalations,
        migration_partition_chunks=m.migration_partition_chunks,
        engine_time_s=m.engine_time_s,
        control_time_s=m.control_time_s,
        last_drift=(None if m.last_drift is None else m.last_drift.copy()),
    )


# ---------------------------------------------------------------------------
# Stream normalization
# ---------------------------------------------------------------------------


Stream = Union[Iterable[ChunkRecord], Iterable[FleetChunk],
               Sequence[Iterable[ChunkRecord]]]


def _wrap_single(records: Iterable[ChunkRecord]) -> Iterable[FleetChunk]:
    for r in records:
        yield FleetChunk(stack_chunks([r.chunk]), r.t0, r.t1)


def _normalize_stream(stream: Stream, k: int) -> Iterable[FleetChunk]:
    """Accept the three natural stream shapes and yield ``FleetChunk``s.

    * an iterable of ``ChunkRecord`` (single-partition session, K = 1);
    * an iterable of ``FleetChunk`` (already stacked);
    * a sequence of K per-partition ``ChunkRecord`` iterables (zipped on a
      shared chunk clock, as ``core.fleet.stacked_streams``).
    """
    if isinstance(stream, (list, tuple)) and stream \
            and not isinstance(stream[0], (ChunkRecord, FleetChunk)):
        if len(stream) != k:
            raise ValueError(
                f"got {len(stream)} partition streams for {k} partitions")
        return stacked_streams(stream)
    it = iter(stream)
    try:
        first = next(it)
    except StopIteration:
        return iter(())
    rest = itertools.chain([first], it)
    if isinstance(first, FleetChunk):
        return rest
    if isinstance(first, ChunkRecord):
        if k != 1:
            raise ValueError(
                "a bare ChunkRecord stream feeds a single partition; pass "
                f"{k} per-partition streams (or FleetChunks) for K={k}")
        return _wrap_single(rest)
    raise TypeError(f"cannot interpret stream element "
                    f"{type(first).__name__} as chunked input")


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------


def _resolve_plan_kind(pattern: Pattern, plan: str) -> str:
    if plan in ("order", "tree"):
        return plan
    if plan != "auto":
        raise ValueError(f"plan must be 'order', 'tree' or 'auto'; "
                         f"got {plan!r}")
    stat0 = uniform_stat(pattern.n)
    order_plan, _ = make_planner("greedy")(pattern, stat0)
    tree_plan, _ = make_planner("zstream")(pattern, stat0)
    c_order = plan_cost(order_plan, stat0, pattern.is_sequence)
    c_tree = plan_cost(tree_plan, stat0, pattern.is_sequence)
    return "order" if c_order <= c_tree else "tree"


class Session:
    """One CEP runtime: pattern + partitions + plan family + monitoring.

    Construct via :func:`repro.cep.open`.  The session is lazy: the
    incremental serving plane (compiled fleet state, plan matrix, monitor
    rings) is built on first ``process``/``step``/``deploy``; ``run`` spins
    up a fresh adaptive loop per call and folds its metrics into the
    session telemetry.
    """

    def __init__(self, pattern, *, partitions: int = 1, plan: str = "auto",
                 monitor: bool = False,
                 config: Optional[RuntimeConfig] = None):
        self.config = config or RuntimeConfig()
        self.config.validate(monitor=bool(monitor),
                             partitions=int(partitions))
        self.k = int(partitions)
        self.monitor = bool(monitor)
        self.pattern = as_pattern(pattern)
        self._tel = Telemetry(partitions=self.k)
        if isinstance(self.pattern, CompositePattern):
            self.branches: Tuple["Session", ...] = tuple(
                Session(b, partitions=partitions, plan=plan, monitor=monitor,
                        config=self.config) for b in self.pattern.branches)
            self.plan_kind: Union[str, Tuple[str, ...]] = tuple(
                b.plan_kind for b in self.branches)
            self._serving = None
            return
        self.branches = ()
        self.plan_kind = _resolve_plan_kind(self.pattern, plan)
        self.planner_name = ("greedy" if self.plan_kind == "order"
                             else "zstream")
        self._serving: Optional[CEPFleetServingEngine] = None
        self._runner = None  # batch-plane runner, kept for run(resume=True)

    # -- composite helpers --------------------------------------------------

    @property
    def is_composite(self) -> bool:
        return bool(self.branches)

    # -- batch control plane ------------------------------------------------

    def _make_runner(self):
        cfg = self.config
        common = dict(
            planner=self.planner_name,
            policy_factory=cfg.policy_factory(),
            engine_cfg=cfg.engine(),
            estimator_buckets=cfg.estimator_buckets,
            laplace=cfg.laplace,
            escalate_on_overflow=cfg.escalate_on_overflow,
            max_escalations=cfg.max_escalations,
            seed=cfg.seed,
            mesh=cfg.mesh,
        )
        with legacy_ok():
            if self.monitor:
                return MonitoredFleetRunner(
                    self.pattern, self.k, max_inv=cfg.max_invariants,
                    max_terms=cfg.max_terms, superchunk=cfg.superchunk,
                    **common)
            cfg.require_device_control(self.monitor)
            return FleetRunner(self.pattern, self.k,
                               sel_samples=cfg.sel_samples, **common)

    def run(self, stream: Stream, *, resume: bool = False) -> Telemetry:
        """Consume a chunk stream through the adaptive loop (Algorithm 1
        per partition) and return this run's ``Telemetry``.

        ``resume=True`` continues the previous ``run``'s stream rather
        than starting a fresh one: ring buffers, estimator/monitor
        windows, deployed plans and pending invariant flags carry over,
        so replaying a stream segment-by-segment (with per-segment
        telemetry) is equivalent to one continuous ``run`` — the replay
        harness measures each scenario segment exactly this way.

        For OR-composites the stream is materialized once and each branch
        runs its own adaptive loop over it; counters aggregate as sums and
        ``telemetry.branches`` keeps the per-branch breakdown.
        """
        if self.is_composite:
            chunks = list(_normalize_stream(stream, self.k))
            parts = [b.run(chunks, resume=resume) for b in self.branches]
            tel = Telemetry(partitions=self.k)
            for p in parts:
                tel.merge(p)
            # chunks/events are shared input, not per-branch work
            tel.chunks = parts[0].chunks if parts else 0
            tel.events = parts[0].events if parts else 0
            tel.branches = tuple(parts)
            self._tel.merge(dataclasses.replace(tel, branches=None))
            return tel
        if not (resume and self._runner is not None):
            self._runner = self._make_runner()
        metrics = self._runner.run(_normalize_stream(stream, self.k),
                                   resume=resume)
        tel = _from_fleet_metrics(metrics, self.k)
        self._tel.merge(tel)
        return tel

    # -- incremental (serving) control plane --------------------------------

    def _ensure_serving(self) -> CEPFleetServingEngine:
        if self._serving is None:
            cfg = self.config
            with legacy_ok():
                if self.monitor:
                    self._serving = MonitoredCEPFleetServingEngine(
                        self.pattern, self.k, engine_cfg=cfg.engine(),
                        kind=self.plan_kind, chunk_cap=cfg.chunk_capacity,
                        planner=self.planner_name, policy_kw=cfg.policy_kw,
                        monitor_buckets=cfg.estimator_buckets,
                        max_inv=cfg.max_invariants,
                        max_terms=cfg.max_terms, laplace=cfg.laplace,
                        superchunk=cfg.superchunk, mesh=cfg.mesh)
                else:
                    plan0, _ = make_planner(self.planner_name)(
                        self.pattern, uniform_stat(self.pattern.n))
                    self._serving = CEPFleetServingEngine(
                        self.pattern, self.k, plan0, cfg.engine(),
                        self.plan_kind, cfg.chunk_capacity,
                        laplace=cfg.laplace, superchunk=cfg.superchunk,
                        mesh=cfg.mesh)
        return self._serving

    def step(self, chunk: Chunk, t0: float, t1: float) -> np.ndarray:
        """Advance the fleet one tick over an already-stacked chunk.

        ``chunk`` fields carry a leading K axis (a bare single-partition
        ``Chunk`` is accepted when K = 1).  Returns this tick's
        per-partition full-match counts.  Monitored sessions also run the
        violation → sync → replan → row-deploy control loop inside the
        call.  ``telemetry().events`` is not updated here — counting the
        valid mask would cost one extra device→host sync per tick; use
        ``process``/``run`` when event totals matter.
        """
        if self.is_composite:
            self._tel.chunks += 1
            return sum(b.step(chunk, t0, t1) for b in self.branches)
        eng = self._ensure_serving()
        if chunk.type_id.ndim == 1:
            if self.k != 1:
                raise ValueError("unstacked chunk on a multi-partition "
                                 "session; stack K per-partition chunks")
            chunk = stack_chunks([chunk])
        self._tel.chunks += 1
        return eng.process_chunk(chunk, float(t0), float(t1))

    def step_superchunk(self, chunks: Sequence[Chunk],
                        edges: Sequence[Tuple[float, float]]) -> np.ndarray:
        """Advance the fleet over a sequence of stacked chunks with
        ``config.superchunk`` chunks per compiled dispatch.

        Bit-identical to looping :meth:`step` (monitored sessions re-run a
        window prefix when a flag fires mid-window, so replans still
        deploy on the very next chunk); the host round-trips once per
        superchunk instead of once per chunk.  Returns the per-chunk
        ``(len(chunks), K)`` full-match counts.  Like ``step``, event
        totals are not maintained here.
        """
        if self.is_composite:
            self._tel.chunks += len(chunks)
            return sum(b.step_superchunk(chunks, edges)
                       for b in self.branches)
        eng = self._ensure_serving()
        self._tel.chunks += len(chunks)
        return eng.process_superchunk(chunks, edges)

    def process(self, type_id, ts, attr, keys, t0: float,
                t1: float) -> np.ndarray:
        """Route one keyed event batch (``key % K``) covering ``(t0, t1]``
        and tick the fleet once; returns per-partition match counts."""
        if self.is_composite:
            self._tel.chunks += 1
            self._tel.events += int(len(np.asarray(type_id)))
            return sum(b.process(type_id, ts, attr, keys, t0, t1)
                       for b in self.branches)
        eng = self._ensure_serving()
        self._tel.chunks += 1
        self._tel.events += int(len(np.asarray(type_id)))
        return eng.process_batch(type_id, ts, attr, keys,
                                 float(t0), float(t1))

    def deploy(self, partition: int, plan) -> None:
        """Deploy an evaluation plan for one partition: a stacked-matrix
        row write, never a recompile (§2.2 cheap deployment).

        On a monitored session the partition's invariant row keeps
        guarding the last *planner* output (deciding conditions exist only
        for planner-generated plans); a later violation re-runs the
        planner and overrides the manual plan."""
        if self.is_composite:
            raise ValueError("deploy on a composite session is ambiguous; "
                             "use session.branches[i].deploy(...)")
        self._ensure_serving().deploy_plan(partition, plan)
        self._tel.deployments += 1

    def reset(self) -> None:
        """Clear stream state (ring buffers, monitor rings, counters) while
        keeping compiled programs and deployed plans."""
        if self.is_composite:
            for b in self.branches:
                b.reset()
        else:
            if self._serving is not None:
                self._serving.reset()
            self._runner = None  # next run(resume=True) starts fresh
        self._tel = Telemetry(partitions=self.k)

    # -- telemetry ----------------------------------------------------------

    def _serving_telemetry(self) -> Telemetry:
        eng = self._serving
        tel = Telemetry(partitions=self.k)
        if eng is None:
            return tel
        tel.matches = int(eng.matches.sum())
        tel.per_partition_matches = eng.matches.copy()
        tel.overflow = int(eng.overflow.sum())
        tel.neg_rejected = int(eng.neg_rejected.sum())
        tel.closure_expansions = int(eng.closure_expansions.sum())
        tel.dropped = int(eng.dropped)
        if self.monitor:
            tel.violations = int(eng.violations.sum())
            tel.replans = int(eng.replans.sum())
            tel.host_syncs = int(eng.host_syncs)
            tel.last_drift = eng.last_drift.copy()
        return tel

    def telemetry(self) -> Telemetry:
        """Cumulative session telemetry across both control planes."""
        if self.is_composite:
            parts = tuple(b.telemetry() for b in self.branches)
            tel = Telemetry(partitions=self.k)
            for p in parts:
                tel.merge(p)
            # Shared input is counted once by the composite itself (run,
            # step, and process all maintain self._tel), not per branch.
            tel.chunks = self._tel.chunks
            tel.events = self._tel.events
            tel.branches = parts
            return tel
        tel = Telemetry(partitions=self.k)
        tel.merge(self._tel)
        tel.merge(self._serving_telemetry())
        return tel


def open(pattern, *, partitions: int = 1, plan: str = "auto",
         monitor: bool = False,
         config: Optional[RuntimeConfig] = None,
         superchunk: Optional[int] = None,
         mesh=None) -> Session:
    """Open a CEP session — the single entry point to the runtime.

    Parameters
    ----------
    pattern:    a ``P.seq``/``P.and_``/``P.or_`` builder, a ``Pattern``, or
                a ``CompositePattern``.
    partitions: K independent stream partitions sharing one compiled,
                vmapped data plane (K = 1 is a fleet of one).
    plan:       evaluation-plan family — "order" (lazy-NFA-style
                permutations, greedy planner), "tree" (ZStream-style join
                trees, dynamic-programming planner), or "auto" (cheaper
                cold-start cost under the uniform prior).
    monitor:    ``True`` fuses statistics rings + lowered invariant
                verification into the compiled step (host work scales with
                violations) on *both* control planes.  ``False`` evaluates
                the decision policy on the host each chunk of a ``run``;
                the incremental plane (``process``/``step``) is then
                static — plans change only via ``deploy`` — because
                host-side per-batch estimation would reintroduce the
                O(K·stats) sync the monitored path exists to avoid.
    config:     a :class:`RuntimeConfig`; defaults are production-shaped.
    superchunk: convenience override of ``config.superchunk`` — chunks
                rolled through one compiled ``lax.scan`` dispatch; the
                host syncs/replans only at superchunk boundaries (or at
                an invariant flag), with detection, flags and replan
                points bit-identical to per-chunk stepping.
    mesh:       convenience override of ``config.mesh`` — shard the
                K-partition axis over devices (``"auto"``, an int count,
                or a 1-D ``Mesh`` with a ``"cep"`` axis).
    """
    config = config or RuntimeConfig()
    overrides = {}
    if superchunk is not None:
        overrides["superchunk"] = int(superchunk)
    if mesh is not None:
        overrides["mesh"] = mesh
    if overrides:
        config = dataclasses.replace(config, **overrides)
    return Session(pattern, partitions=partitions, plan=plan,
                   monitor=monitor, config=config)
