"""Consolidated runtime configuration for the CEP facade.

Before the facade, capacity/bucket/laplace/escalation knobs were scattered
as constructor kwargs across ``core/engine.py`` (``EngineConfig``,
``MonitoredEngine``), ``core/fleet.py`` (``FleetRunner`` /
``MonitoredFleetRunner``) and ``serving/engine.py`` (the serving fronts).
``RuntimeConfig`` is the single source of truth: every knob any of the
eight legacy configurations accepted, with one name and one default, and
adapters (``engine()``, ``policy_factory()``) that translate back to the
internal structures.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

from ..core.decision import DecisionPolicy, make_policy
from ..core.engine import EngineConfig


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """All tunables of a CEP session, in one place.

    Data plane
    ----------
    buffer_capacity: per-type ring-buffer rows (events of recent history).
    match_capacity:  match-set rows; overflow beyond this triggers the
                     escalation recount (``escalate_on_overflow``).
    backend:         kernel backend override (None = auto: Pallas on TPU,
                     jnp elsewhere).
    chunk_capacity:  per-partition padded chunk rows for keyed-batch
                     routing (``Session.process``); overflow is counted as
                     back-pressure, never silently dropped.

    Scale-out
    ---------
    superchunk: chunks rolled through one compiled ``lax.scan`` dispatch
                (1 = classic per-chunk stepping).  The host surfaces only
                at superchunk boundaries — or immediately after an
                invariant flag / escalating overflow via the optimistic
                prefix re-run — so detection, flags and replan points are
                bit-identical for every value (``core/scan.py``).  Values
                > 1 require device-side control: ``monitor=True`` for the
                adaptive batch plane (a host decision policy would need a
                per-chunk statistics sync, the exact O(K·stats) loop
                superchunking removes).
    mesh:       shard the K-partition axis across devices — ``None`` (no
                sharding), ``"auto"`` (all local devices), an int device
                count, or a 1-D ``jax.sharding.Mesh`` with a ``"cep"``
                axis.  K must divide by the device count; a D=1 mesh runs
                the identical ``shard_map`` code path on one device.

    Statistics
    ----------
    estimator_buckets: sliding-window length in chunks (host estimator and
                       device monitor rings alike).
    laplace:           additive smoothing for selectivity estimates (host
                       estimator and device monitor snapshots alike).
    sel_samples:       Monte-Carlo pairs sampled per chunk by the *host*
                       estimator (device monitoring observes exhaustively).

    Adaptation
    ----------
    policy:    reoptimizing decision function ``D`` — "invariant",
               "threshold", "unconditional", "static", or None (plan once
               from the uniform prior, never adapt).  Monitored sessions
               require "invariant" (the only policy with a device
               lowering).
    policy_kw: kwargs for the policy (e.g. ``{"k": 1, "d": 0.0}``).
    escalate_on_overflow / max_escalations: re-evaluate a chunk at the
               next pow2 match capacity when a join truncated.
    max_invariants / max_terms: static caps for the stacked lowered
               invariant tensors (monitored sessions).  None = the
               cold-start set's exact sizes — exact for the greedy/order
               planner; pass explicit worst-case caps for tree plans.
    seed:      RNG seed for the host estimator's selectivity sampling.

    Rulebook
    --------
    sharing:       multi-query join sharing across a bucket's rules —
                   "lattice" (full interior sub-join sharing, arXiv
                   1801.09413), "prefix" (opening two-position joins only,
                   the PR 8 behavior) or "none".  Pure work elimination:
                   counters are bit-identical across all three.
    bucket_fusion: fuse same-arity buckets whose shapes differ only in
                   negation/Kleene post-blocks into one superset bucket
                   (fewer dispatches per tick; rules gate the blocks they
                   do not use, so counters are unchanged).
    """

    # data plane
    buffer_capacity: int = 128
    match_capacity: int = 256
    backend: Optional[str] = None
    chunk_capacity: int = 512
    # scale-out
    superchunk: int = 1
    mesh: Optional[Any] = None
    # statistics
    estimator_buckets: int = 16
    laplace: float = 1.0
    sel_samples: int = 64
    # adaptation
    policy: Optional[str] = "invariant"
    policy_kw: Dict[str, Any] = dataclasses.field(default_factory=dict)
    escalate_on_overflow: bool = True
    max_escalations: int = 4
    max_invariants: Optional[int] = None
    max_terms: Optional[int] = None
    seed: int = 0
    # rulebook
    sharing: str = "lattice"
    bucket_fusion: bool = True

    def __post_init__(self):
        if self.match_capacity < self.buffer_capacity:
            raise ValueError("match_capacity must be >= buffer_capacity")
        if self.superchunk < 1:
            raise ValueError("superchunk must be >= 1")
        if self.policy not in (None, "static", "unconditional", "threshold",
                               "invariant"):
            raise ValueError(f"unknown policy {self.policy!r}")
        if self.sharing not in ("lattice", "prefix", "none"):
            raise ValueError(f"unknown sharing mode {self.sharing!r}")

    # -- cross-field validation (one checkpoint for every runtime front) ----

    def validate(self, *, monitor: bool, partitions: int) -> None:
        """Checks that need context beyond the config's own fields.

        ``Session`` and ``Rulebook`` both call this once at open time
        instead of re-spelling the constraints ad hoc; keep any new
        front's checks here so error messages stay uniform.
        """
        if partitions < 1:
            raise ValueError("partitions must be >= 1")
        if monitor and self.policy != "invariant":
            raise ValueError(
                "monitored runtimes verify invariants on device; "
                f"config.policy must be 'invariant' (got {self.policy!r})")

    def require_device_control(self, monitor: bool) -> None:
        """Superchunk scans keep control on device between host syncs; a
        host-side decision policy would need the per-chunk statistics sync
        that superchunking exists to remove."""
        if self.superchunk > 1 and not monitor:
            raise ValueError(
                "superchunk > 1 requires monitor=True: host decision "
                "policies sync statistics every chunk, which defeats the "
                "scanned plane (set monitor=True or superchunk=1)")

    # -- adapters to the internal structures --------------------------------

    def engine(self) -> EngineConfig:
        return EngineConfig(b_cap=self.buffer_capacity,
                            m_cap=self.match_capacity,
                            backend=self.backend)

    def policy_factory(self) -> Optional[Callable[[], DecisionPolicy]]:
        if self.policy is None:
            return None
        return lambda: make_policy(self.policy, **self.policy_kw)
