"""Rulebook: one compiled data plane serving Q heterogeneous patterns.

``cep.open`` gives one pattern one data plane; production CEP serves a
*rule set* — thousands of distinct patterns per tenant.  A :class:`Rulebook`
compiles Q patterns (any mix the ``P`` DSL can build, minus OR-composites)
into the stacked structural tensors of ``core.multipattern``: rules are
bucketed by arity/shape, each bucket runs Qb rules × K partitions through
ONE jitted dispatch per chunk, and everything a rule *is* lives in row
``q`` of the bucket's tensors — so the paper's plans-as-data discipline now
covers the rule set itself:

* **hot add / remove are row writes.**  ``add_rule`` lowers the pattern
  into a free slot (ops row + plan rows + invariant rows + zeroed state
  rows) and ``remove_rule`` masks a slot out; neither recompiles anything.
  The only sanctioned retrace is bucket-capacity growth (the same jitted
  callable re-entered with a bigger Qb — asserted via the plane's
  trace-count probe in the bench).
* **adaptation is per (q, k) cell.**  Each cell owns an
  ``InvariantPolicy``; the monitored plane returns a (K, Qb) violation
  bitmap and the host replans exactly the flagged cells (host work ∝
  violations, as in the single-pattern serving front), deploying the fresh
  plan + lowered invariant set as two row writes.
* **common sub-joins run once, at every depth.**  Rules whose cold plans
  open on the same sub-join *chain* (same positions, event types, window,
  sequence-ness and every live pairwise predicate, cumulatively per plan
  step) share a node in the bucket's sub-join lattice (arXiv 1801.09413):
  each shared node executes once per chunk and its partial-match set fans
  out to every extension, down to the per-rule post-blocks
  (``sharing_ratio()`` reports per-rule join steps / executed lattice
  nodes).  Shared rules keep their common plan prefix pinned
  (``greedy_order_plan(pin=...)``) so later replans never break the
  share; hot-added rules always start their own singleton chain, since
  joining a node retroactively would constrain plans chosen before the
  rule existed.  ``config.sharing`` selects "lattice" (default),
  "prefix" (opening joins only — the PR 8 behavior) or "none".
* **small buckets fuse.**  With ``config.bucket_fusion`` (default), rules
  of one arity share a single bucket even when only some carry negation /
  Kleene post-blocks: the bucket's spec is the superset, per-rule
  ``has_neg``/``has_kleene`` flags mask the blocks a rule lacks, and a
  mixed-arity Q=32 rulebook steps in as many dispatches as *arities*, not
  shape classes.
* **superchunk scans.**  ``config.superchunk = S`` rolls S chunks per
  bucket through one compiled ``lax.scan`` dispatch (``core.scan.
  make_rulebook_scan``): counters and per-(q, k) invariant flags
  accumulate on device and the host syncs once per window — or
  immediately after a flag via the optimistic prefix re-run, so replans
  still deploy on the very next chunk and counters stay bit-identical to
  per-chunk stepping for every S.

Counter semantics are the serving front's: immediate deployment, no
migration split, exactly-once chunked counting — and per-rule counters are
bit-identical to Q independent Sessions over the same stream (the bench
and property tests gate this).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import Chunk, EngineConfig, make_spec
from ..core.fleet import stack_chunks
from ..core.greedy import greedy_order_plan
from ..core.invariants import LoweredInvariants
from ..core.multipattern import (BucketSpec, RuleOps, ShareOps,
                                 init_rule_buffers, init_rule_monitor,
                                 lower_rule, make_rulebook_plane, pad_rule,
                                 stack_rule_ops)
from ..core.patterns import PRED_NONE, CompositePattern, Pattern
from ..core.scan import (first_event, make_rulebook_scan,
                         stack_rulebook_window)
from ..core.stats import Stat, uniform_stat
from ..distributed.sharding import resolve_cep_mesh
from .config import RuntimeConfig
from .dsl import as_pattern
from .session import Stream, Telemetry, _normalize_stream

__all__ = ["Rulebook", "open_rulebook"]


def _subjoin_chain(pattern: Pattern,
                   order: Sequence[int]) -> Tuple[tuple, ...]:
    """Cumulative identity of a rule's sub-joins along one plan order.

    ``chain[d]`` identifies the ``d + 2``-position sub-join after plan
    step ``d + 1``; two rules with equal ``chain[d]`` produce bit-identical
    partial-match sets at that depth.  Each step key pins the buffer
    contents (types), the eviction horizon (window), the sequence anchors
    (positions + is_seq) and every live constraint row of the packed
    join — at the step that joins position ``q``, the only active strip
    rows are ``(a, q)`` for already-joined ``a`` (the rest are
    ``PRED_NONE``, vacuous in the kernels) — plus the positions the
    values land in.  Cumulative keys make sharing prefix-closed: equal at
    depth d implies equal at every shallower depth.
    """
    spec = make_spec(pattern)
    member = [int(order[0])]
    key = (float(spec.window), bool(spec.is_seq), int(order[0]),
           int(spec.type_ids[int(order[0])]))
    chain = []
    for i in range(1, spec.n):
        q = int(order[i])
        rows = []
        for a in sorted(member):
            op = int(spec.op_t[a, q])
            if op == PRED_NONE:
                rows.append((a, op, 0, 0, 0.0))
            else:
                rows.append((a, op, int(spec.a_attr_t[a, q]),
                             int(spec.b_attr_t[a, q]),
                             float(spec.theta_t[a, q])))
        key = key + (q, int(spec.type_ids[q]), tuple(rows))
        chain.append(key)
        member.append(q)
    return tuple(chain)


class _Lowered2D:
    """(K, Qb) invariant matrix: host-writable rows, device-cached.

    The fleet's ``StackedLowered`` with a rule axis next to the partition
    axis — a deployment patches one (k, q) cell, capacity growth pads the
    rule axis and invalidates the cache.
    """

    def __init__(self, host: LoweredInvariants):
        self.host = host
        self._dev: Optional[LoweredInvariants] = None

    @classmethod
    def build(cls, rows_kq: Sequence[Sequence[LoweredInvariants]]):
        return cls(LoweredInvariants(
            *(np.stack([np.stack([np.asarray(getattr(r, f)) for r in krow])
                        for krow in rows_kq])
              for f in LoweredInvariants._fields)))

    def write(self, k: int, q: int, row: LoweredInvariants) -> None:
        for f in LoweredInvariants._fields:
            dst, src = getattr(self.host, f), np.asarray(getattr(row, f))
            if dst[k, q].shape != src.shape:
                raise ValueError(
                    f"lowered field {f!r}: row shape {src.shape} != "
                    f"stacked {dst[k, q].shape}")
            dst[k, q] = src
        # Invalidate instead of patching: any number of cell deployments
        # within one tick amortize into a single upload per field at the
        # next dispatch.
        self._dev = None

    def grow(self, new_qcap: int) -> None:
        q_cap = self.host.active.shape[1]
        pad = new_qcap - q_cap
        self.host = LoweredInvariants(*(
            np.pad(getattr(self.host, f),
                   ((0, 0), (0, pad)) + ((0, 0),) * (getattr(
                       self.host, f).ndim - 2))
            for f in LoweredInvariants._fields))
        self._dev = None

    def device(self) -> LoweredInvariants:
        if self._dev is None:
            self._dev = LoweredInvariants(
                *(jnp.asarray(x) for x in self.host))
        return self._dev


@dataclasses.dataclass
class _RuleEntry:
    """Host bookkeeping + cumulative counters for one rule."""

    rid: int
    pattern: Pattern
    bucket: "_Bucket"
    slot: int                # q row in the bucket (fixed while active)
    chain: Tuple[int, ...]   # lattice class per depth (len = n - 1)
    pinned: Tuple[int, ...]  # () or the pinned shared plan prefix
    active: bool = True
    matches: np.ndarray = None       # (K,) int64
    overflow: int = 0
    neg_rejected: int = 0
    closure_expansions: int = 0
    pm_created: int = 0
    replans: int = 0
    deployments: int = 0
    violations: int = 0
    chunks: int = 0


class _Bucket:
    """One arity bucket: stacked tensors + plane + per-cell policies."""

    def __init__(self, rb: "Rulebook", bspec: BucketSpec):
        self.rb = rb
        self.bspec = bspec
        self.depth = bspec.n - 1            # lattice depths (>= 1)
        self.q_cap = 0
        self.u_caps: List[int] = []         # class capacity per depth
        self.slots: List[Optional[_RuleEntry]] = []
        # [d][u] -> member slots of the depth-d class u
        self.class_members: List[List[List[int]]] = []
        self.free_slots: List[int] = []
        self.free_classes: List[List[int]] = []     # per depth
        # Host mirrors (device copies are patched in lockstep).
        self.ops_h: Optional[RuleOps] = None
        self.ops_d: Optional[RuleOps] = None
        self.plans_h: Optional[np.ndarray] = None   # (K, Qb, n) i32
        self.plans_d = None
        self.rep_h: List[np.ndarray] = []           # [d]: (U_d,) i32
        self.parent_h: List[np.ndarray] = []        # [d]: (U_d,) i32
        self.expand_h: Optional[np.ndarray] = None  # (Qb,) i32
        self.share_d: Optional[ShareOps] = None
        self.state = None
        self.monitor = None
        self.lowered: Optional[_Lowered2D] = None
        self.policies: List[List] = []              # [k][q] -> policy
        self.caps: Tuple[int, int] = (1, 1)
        self.plane = None
        self.scan_plane = None              # built lazily on first scan

    # -- layout ------------------------------------------------------------

    def _refresh_share(self) -> None:
        self.share_d = ShareOps(
            rep=tuple(jnp.asarray(r, jnp.int32) for r in self.rep_h),
            parent=tuple(jnp.asarray(p, jnp.int32) for p in self.parent_h),
            expand=jnp.asarray(self.expand_h, jnp.int32))

    def _make_plane(self) -> None:
        rb = self.rb
        self.plane = make_rulebook_plane(
            self.bspec, rb.engine_cfg, rb.k, rb.monitored,
            laplace=rb.config.laplace, mesh=rb.mesh)

    def scan_plane_ref(self):
        """The scanned plane, built on first superchunk dispatch (shares
        the per-chunk plane's trace-memo discipline: keyed sans capacity,
        growth re-enters the same callable)."""
        if self.scan_plane is None:
            rb = self.rb
            self.scan_plane = make_rulebook_scan(
                self.bspec, rb.engine_cfg, rb.k, rb.monitored,
                laplace=rb.config.laplace, mesh=rb.mesh)
        return self.scan_plane

    def build(self, entries: Sequence[Tuple[_RuleEntry, RuleOps,
                                            np.ndarray, list, object]],
              spare: int,
              probe_patterns: Optional[Sequence[Pattern]] = None) -> None:
        """Initial layout from (entry, ops_row, order, dcs, stat) tuples.

        Entries arrive pre-grouped (``entry.chain`` / ``entry.slot`` set);
        ``spare`` free rule slots and per-depth class slots are
        pre-provisioned so the first hot-adds are pure row writes.
        ``probe_patterns`` seeds the invariant-cap probe when the bucket
        opens empty (hot-add into a new shape) — the incoming rule must
        fit the caps.
        """
        rb = self.rb
        n_rules = len(entries)
        n_classes = [1 + max((e.chain[d] for e, *_ in entries), default=-1)
                     for d in range(self.depth)]
        self.q_cap = n_rules + spare
        self.u_caps = [max(1, nc + spare) for nc in n_classes]
        rows = [None] * self.q_cap
        self.slots = [None] * self.q_cap
        self.class_members = [[[] for _ in range(uc)] for uc in self.u_caps]
        self.free_classes = [[] for _ in range(self.depth)]
        self.rep_h = [np.zeros((uc,), np.int32) for uc in self.u_caps]
        self.parent_h = [np.zeros((uc,), np.int32) for uc in self.u_caps]
        self.expand_h = np.zeros((self.q_cap,), np.int32)
        self.plans_h = np.tile(np.arange(self.bspec.n, dtype=np.int32),
                               (rb.k, self.q_cap, 1))
        if rb.monitored:
            self.policies = [[None] * self.q_cap for _ in range(rb.k)]
            self.caps = self._probe_caps(
                probe_patterns if probe_patterns is not None
                else [e.pattern for e, *_ in entries])
        low_rows: List[List[LoweredInvariants]] = [
            [None] * self.q_cap for _ in range(rb.k)]
        for entry, ops_row, order, dcs, stat in entries:
            q = entry.slot
            rows[q] = ops_row
            self.slots[q] = entry
            for d, u in enumerate(entry.chain):
                self.class_members[d][u].append(q)
                if d:
                    self.parent_h[d][u] = entry.chain[d - 1]
            self.expand_h[q] = entry.chain[-1]
            self.plans_h[:, q] = order
            if rb.monitored:
                for k in range(rb.k):
                    pol = rb.config.policy_factory()()
                    plan = _OrderRow(order)
                    pol.on_replan(plan, dcs, stat)
                    self.policies[k][q] = pol
                    low_rows[k][q] = pol.compile(
                        self.bspec.n, max_inv=self.caps[0],
                        max_terms=self.caps[1])
        for d in range(self.depth):
            for u, members in enumerate(self.class_members[d]):
                if members:
                    self.rep_h[d][u] = members[0]
                else:
                    self.free_classes[d].append(u)
        for q in range(self.q_cap):
            if rows[q] is None:
                rows[q] = pad_rule(self.bspec)
                self.free_slots.append(q)
        if rb.monitored:
            empty = self._empty_lowered()
            for k in range(rb.k):
                for q in range(self.q_cap):
                    if low_rows[k][q] is None:
                        low_rows[k][q] = empty
            self.lowered = _Lowered2D.build(low_rows)
            self.monitor = init_rule_monitor(
                self.bspec, rb.k, self.q_cap, rb.config.estimator_buckets)
        self.ops_h = stack_rule_ops(rows)
        self.ops_d = jax.tree.map(jnp.asarray, self.ops_h)
        self.plans_d = jnp.asarray(self.plans_h)
        self._refresh_share()
        self.state = init_rule_buffers(self.bspec, rb.engine_cfg, rb.k,
                                       self.q_cap)
        self._make_plane()

    def _probe_caps(self, patterns: Sequence[Pattern]) -> Tuple[int, int]:
        """Bucket-wide lowered-invariant caps from UNPINNED cold plans.

        Pinning only removes deciding conditions (pinned blocks are
        empty), so the free plan's invariant set is the per-rule worst
        case; every cell then lowers at the bucket max so invariant
        deployments stay row writes.  ``config.max_invariants/max_terms``
        override upward.
        """
        rb = self.rb
        i_cap = t_cap = 1
        stat0 = uniform_stat(self.bspec.n)
        for p in patterns:
            plan, dcs = greedy_order_plan(p, stat0)
            pol = rb.config.policy_factory()()
            pol.on_replan(plan, dcs, stat0)
            low = pol.compile(self.bspec.n)
            i_cap = max(i_cap, low.active.shape[0])
            t_cap = max(t_cap, low.scale.shape[-1])
        if rb.config.max_invariants is not None:
            i_cap = max(i_cap, int(rb.config.max_invariants))
        if rb.config.max_terms is not None:
            t_cap = max(t_cap, int(rb.config.max_terms))
        return (i_cap, t_cap)

    def _empty_lowered(self) -> LoweredInvariants:
        """An inert invariant row (active all-False) for empty slots."""
        from ..core.invariants import lower_invariants

        return lower_invariants([], 0.0, self.bspec.n,
                                max_inv=self.caps[0],
                                max_terms=self.caps[1])

    # -- growth (the one retrace point) ------------------------------------

    def grow_slots(self) -> None:
        """Double the rule capacity: pad every host/device tensor along the
        rule axis.  The next dispatch re-enters the same jitted plane with
        the new Qb — one retrace, no new compile cache entry."""
        rb = self.rb
        old, new = self.q_cap, max(1, self.q_cap * 2)
        pad_n = new - old
        pad_rows = [pad_rule(self.bspec)] * pad_n
        self.ops_h = RuleOps(*(
            np.concatenate([getattr(self.ops_h, f),
                            np.stack([np.asarray(getattr(r, f))
                                      for r in pad_rows])])
            for f in RuleOps._fields))
        self.ops_d = jax.tree.map(jnp.asarray, self.ops_h)
        self.plans_h = np.concatenate(
            [self.plans_h,
             np.tile(np.arange(self.bspec.n, dtype=np.int32),
                     (rb.k, pad_n, 1))], axis=1)
        self.plans_d = jnp.asarray(self.plans_h)
        self.expand_h = np.concatenate(
            [self.expand_h, np.zeros((pad_n,), np.int32)])
        self._refresh_share()
        self.state = jax.tree.map(
            lambda x: jnp.pad(x, ((0, 0), (0, pad_n)) +
                              ((0, 0),) * (x.ndim - 2)), self.state)
        if rb.monitored:
            self.monitor = jax.tree.map(
                lambda x: jnp.pad(x, ((0, 0), (0, pad_n)) +
                                  ((0, 0),) * (x.ndim - 2)), self.monitor)
            self.lowered.grow(new)
            empty = self._empty_lowered()
            for k in range(rb.k):
                self.policies[k].extend([None] * pad_n)
                for q in range(old, new):
                    self.lowered.write(k, q, empty)
        self.slots.extend([None] * pad_n)
        self.free_slots.extend(range(old, new))
        self.q_cap = new

    def grow_classes(self, d: int) -> None:
        """Double depth ``d``'s class capacity.  Like ``grow_slots`` this
        changes the plane's shape signature — the next dispatch is the
        sanctioned retrace of the same memoized callable."""
        old, new = self.u_caps[d], max(1, self.u_caps[d] * 2)
        self.rep_h[d] = np.concatenate(
            [self.rep_h[d], np.zeros((new - old,), np.int32)])
        self.parent_h[d] = np.concatenate(
            [self.parent_h[d], np.zeros((new - old,), np.int32)])
        self.class_members[d].extend([] for _ in range(new - old))
        self.free_classes[d].extend(range(old, new))
        self._refresh_share()
        self.u_caps[d] = new

    # -- row writes --------------------------------------------------------

    def write_ops_row(self, q: int, row: RuleOps) -> None:
        for f in RuleOps._fields:
            np.asarray(getattr(self.ops_h, f))[q] = np.asarray(
                getattr(row, f))
        self.ops_d = None

    def write_plan_row(self, k: int, q: int, order: np.ndarray) -> None:
        self.plans_h[k, q] = order
        self.plans_d = None

    def write_plan_all_k(self, q: int, order: np.ndarray) -> None:
        self.plans_h[:, q] = order
        self.plans_d = None

    def ops_device(self) -> RuleOps:
        if self.ops_d is None:
            self.ops_d = jax.tree.map(jnp.asarray, self.ops_h)
        return self.ops_d

    def plans_device(self):
        if self.plans_d is None:
            self.plans_d = jnp.asarray(self.plans_h)
        return self.plans_d

    def zero_state_row(self, q: int) -> None:
        self.state = jax.tree.map(
            lambda x: x.at[:, q].set(jnp.zeros_like(x[:, q])), self.state)
        if self.monitor is not None:
            self.monitor = jax.tree.map(
                lambda x: x.at[:, q].set(jnp.zeros_like(x[:, q])),
                self.monitor)


class _OrderRow:
    """Minimal plan object handed to decision policies (order-only)."""

    def __init__(self, order):
        self.order = tuple(int(o) for o in order)


class Rulebook:
    """Q patterns, one compiled data plane per arity bucket.

    Construct via :func:`open_rulebook`.  ``step``/``run`` advance every
    rule at once; ``add_rule``/``remove_rule`` mutate the rule set live.
    """

    def __init__(self, rules: Sequence, *, partitions: int = 1,
                 monitor: bool = True,
                 config: Optional[RuntimeConfig] = None,
                 spare_slots: int = 0):
        self.config = config or RuntimeConfig()
        # One central checkpoint (superchunk needs no monitor here: the
        # rulebook's only per-chunk control is the invariant flag, which
        # the scanned plane carries on device).
        self.config.validate(monitor=bool(monitor),
                             partitions=int(partitions))
        self.k = int(partitions)
        self.monitored = bool(monitor)
        self.engine_cfg: EngineConfig = self.config.engine()
        self.mesh = resolve_cep_mesh(self.config.mesh, self.k)
        self.spare_slots = int(spare_slots)
        patterns = [self._check_pattern(as_pattern(r)) for r in rules]
        if not patterns:
            raise ValueError("open_rulebook needs at least one rule")
        # Rulebook-wide attribute width: chunks are shared by every rule.
        self.n_attrs = max(p.n_attrs for p in patterns)
        patterns = [self._widen(p) for p in patterns]
        self._rules: List[_RuleEntry] = []
        self._buckets: List[_Bucket] = []
        self._chunks = 0
        self._host_syncs = 0
        self._build(patterns)

    # -- construction -------------------------------------------------------

    def _check_pattern(self, p) -> Pattern:
        if isinstance(p, CompositePattern):
            raise ValueError(
                "OR-composites decompose into independent branches; add "
                "each branch to the rulebook as its own rule")
        return p

    def _widen(self, p: Pattern) -> Pattern:
        if p.n_attrs > self.n_attrs:
            raise ValueError("rule exceeds rulebook attribute width")
        if p.n_attrs != self.n_attrs:
            p = dataclasses.replace(p, n_attrs=self.n_attrs)
        return p

    def _bucket_key(self, p: Pattern):
        spec = make_spec(p)
        return (spec.n, spec.has_neg, spec.kleene_pos is not None,
                len(spec.neg_rows))

    def _build(self, patterns: Sequence[Pattern]) -> None:
        # rid == position in the caller's rule list; buckets regroup the
        # rules physically but never renumber them.
        base = len(self._rules)
        self._rules.extend([None] * len(patterns))
        by_shape: Dict[tuple, List[Tuple[int, Pattern]]] = {}
        for idx, p in enumerate(patterns):
            n, has_neg, has_kl, _ = self._bucket_key(p)
            # Fused: one bucket per arity, spec'd to the superset of its
            # members' post-blocks (per-rule has_neg/has_kleene flags mask
            # the rest).  Unfused: one bucket per exact shape class.
            fkey = ((n,) if self.config.bucket_fusion
                    else (n, has_neg, has_kl))
            by_shape.setdefault(fkey, []).append((idx, p))
        stat0_cache: Dict[int, Stat] = {}
        mode = self.config.sharing
        for fkey, ps in by_shape.items():
            n = fkey[0]
            specs = [make_spec(p) for _, p in ps]
            bspec = BucketSpec(
                n=n,
                has_neg=any(s.has_neg for s in specs),
                has_kleene=any(s.kleene_pos is not None for s in specs),
                n_attrs=self.n_attrs,
                neg_rows_cap=max(len(s.neg_rows) for s in specs))
            bucket = _Bucket(self, bspec)
            stat0 = stat0_cache.setdefault(n, uniform_stat(n))
            # Cold-plan free, then build the sharing lattice from the
            # cumulative sub-join chains along each free plan.
            cold = [greedy_order_plan(p, stat0) for _, p in ps]
            depth = n - 1
            class_maps: List[Dict[tuple, int]] = [{} for _ in range(depth)]
            assign = []
            for r, ((_, p), (plan, _)) in enumerate(zip(ps, cold)):
                ck = _subjoin_chain(p, plan.order)
                row = []
                for d in range(depth):
                    if mode == "none" or (mode == "prefix" and d > 0):
                        key = ("solo", r, d)
                    else:
                        key = ck[d]
                    row.append(class_maps[d].setdefault(
                        key, len(class_maps[d])))
                assign.append(tuple(row))
            sizes = [np.bincount([a[d] for a in assign],
                                 minlength=len(class_maps[d]))
                     for d in range(depth)]
            entries = []
            for slot, ((idx, p), (plan, dcs)) in enumerate(zip(ps, cold)):
                # Deepest depth actually shared (>= 2 members); cumulative
                # keys make this a plan prefix, which gets pinned so later
                # replans never break the share.
                shared = -1
                for d in range(depth):
                    if sizes[d][assign[slot][d]] >= 2:
                        shared = d
                    else:
                        break
                pinned: Tuple[int, ...] = ()
                if shared >= 0:
                    pinned = tuple(int(o) for o in plan.order[:shared + 2])
                    plan, dcs = greedy_order_plan(p, stat0, pin=pinned)
                entry = _RuleEntry(
                    rid=base + idx, pattern=p, bucket=bucket,
                    slot=slot, chain=assign[slot], pinned=pinned,
                    matches=np.zeros((self.k,), np.int64))
                self._rules[base + idx] = entry
                entries.append((entry, lower_rule(p, bspec),
                                np.asarray(plan.order, np.int32), dcs,
                                stat0))
            bucket.build(entries, self.spare_slots)
            self._buckets.append(bucket)

    # -- data plane ---------------------------------------------------------

    def _check_chunk(self, chunk: Chunk) -> Chunk:
        if chunk.type_id.ndim == 1:
            if self.k != 1:
                raise ValueError("unstacked chunk on a multi-partition "
                                 "rulebook; stack K per-partition chunks")
            chunk = stack_chunks([chunk])
        if chunk.attr.shape[-1] != self.n_attrs:
            raise ValueError(
                f"chunk has {chunk.attr.shape[-1]} attributes; this "
                f"rulebook is compiled for {self.n_attrs}")
        return chunk

    def step(self, chunk: Chunk, t0: float, t1: float) -> np.ndarray:
        """Advance every rule one tick over an already-stacked chunk.

        ``chunk`` fields carry a leading K axis (a bare single-partition
        ``Chunk`` is accepted when K = 1).  Returns this tick's full-match
        counts as an (R, K) array over rules in insertion order (removed
        rules contribute zero rows).  Monitored rulebooks also run the
        violation → sync → replan → row-deploy loop per flagged (q, k)
        cell inside the call.
        """
        chunk = self._check_chunk(chunk)
        t0j, t1j = jnp.float32(t0), jnp.float32(t1)
        self._chunks += 1
        out = np.zeros((len(self._rules), self.k), np.int64)
        for bucket in self._buckets:
            if self.monitored:
                (bucket.state, bucket.monitor, res, violated, _drift,
                 rates, sel) = bucket.plane.fn(
                     bucket.state, bucket.monitor, chunk,
                     bucket.ops_device(), bucket.share_d,
                     bucket.plans_device(),
                     bucket.lowered.device(), t0j, t1j)
            else:
                bucket.state, res = bucket.plane.fn(
                    bucket.state, chunk, bucket.ops_device(),
                    bucket.share_d, bucket.plans_device(), t0j, t1j)
            # One coalesced counter transfer per bucket per tick.
            cnt = np.asarray(jnp.stack(
                [res.full, res.pm, res.overflow, res.closure, res.neg]))
            self._host_syncs += 1
            for q, entry in enumerate(bucket.slots):
                if entry is None or not entry.active:
                    continue
                full_k = cnt[0, :, q].astype(np.int64)
                entry.matches += full_k
                entry.pm_created += int(cnt[1, :, q].sum())
                entry.overflow += int(cnt[2, :, q].sum())
                entry.closure_expansions += int(cnt[3, :, q].sum())
                entry.neg_rejected += int(cnt[4, :, q].sum())
                entry.chunks += 1
                out[entry.rid] = full_k
            if self.monitored:
                fired = np.nonzero(np.asarray(violated))
                if fired[0].size:
                    # One coalesced stats transfer serves every fired
                    # cell; per-cell device indexing costs a sync each.
                    self._host_syncs += 1
                    rates_h = np.asarray(rates, np.float64)
                    sel_h = np.asarray(sel, np.float64)
                    for k, q in zip(*fired):
                        self._replan_cell(bucket, int(k), int(q),
                                          rates_h, sel_h)
        return out

    def _replan_cell(self, bucket: _Bucket, k: int, q: int,
                     rates, sel) -> None:
        """Invariant violation at cell (k, q): re-run the planner on that
        cell's device statistics and deploy plan + invariant rows."""
        entry = bucket.slots[q]
        if entry is None or not entry.active:
            return
        entry.violations += 1
        stat = Stat(np.asarray(rates[k, q], np.float64),
                    np.asarray(sel[k, q], np.float64))
        plan, dcs = greedy_order_plan(entry.pattern, stat,
                                      pin=entry.pinned)
        order = np.asarray(plan.order, np.int32)
        changed = not np.array_equal(order, bucket.plans_h[k, q])
        bucket.write_plan_row(k, q, order)
        pol = bucket.policies[k][q]
        pol.on_replan(plan, dcs, stat)
        bucket.lowered.write(k, q, pol.compile(
            bucket.bspec.n, max_inv=bucket.caps[0],
            max_terms=bucket.caps[1]))
        entry.replans += 1
        if changed:
            entry.deployments += 1

    def step_superchunk(self, chunks: Sequence[Chunk],
                        edges: Sequence[Tuple[float, float]]) -> np.ndarray:
        """Advance every rule over a sequence of stacked chunks with
        ``config.superchunk`` chunks per compiled ``lax.scan`` dispatch.

        Bit-identical to looping :meth:`step`: the scanned plane carries
        (Buffers, MonitorState) per bucket, counters and per-(q, k)
        invariant flags accumulate on device, and a flag at in-window
        chunk ``f`` triggers the optimistic prefix re-run — the window's
        first ``f + 1`` chunks are re-committed from the saved pre-window
        state (deterministic, so bitwise equal), the flagged cells replan,
        and the next window resumes at ``f + 1`` — so replans still
        deploy on the very next chunk.  Buckets hold disjoint state, so
        scanning them window-by-window commutes with the per-chunk
        bucket interleave.  Returns the per-chunk ``(len(chunks), R, K)``
        full-match counts over rules in insertion order.
        """
        chunks = [self._check_chunk(c) for c in chunks]
        t0s = [float(t0) for t0, _ in edges]
        t1s = [float(t1) for _, t1 in edges]
        if len(chunks) != len(t0s):
            raise ValueError("chunks and edges length mismatch")
        s_cap = max(2, self.config.superchunk)
        n_chunks = len(chunks)
        out = np.zeros((n_chunks, len(self._rules), self.k), np.int64)
        # Buckets walk the same window boundaries until a flag splits one;
        # cache the stacked xs per (i, j) range so the common aligned case
        # stacks each window once, not once per bucket.
        xs_cache: Dict[Tuple[int, int], object] = {}
        for bucket in self._buckets:
            i = 0
            while i < n_chunks:
                j = min(i + s_cap, n_chunks)
                xs = xs_cache.get((i, j))
                if xs is None:
                    xs = stack_rulebook_window(
                        chunks[i:j], t0s[i:j], t1s[i:j], s_cap)
                    xs_cache[(i, j)] = xs
                accept = self._scan_window(bucket, xs, j - i, out, i)
                i += accept
        self._chunks += n_chunks
        return out

    def _scan_window(self, bucket: _Bucket, xs, n_en: int,
                     out: np.ndarray, base: int) -> int:
        """One optimistic scan dispatch over a pre-stacked window of one
        bucket (``n_en`` of the window's padded rows are enabled).

        Commits the accepted prefix (state, counters, ``out`` rows) and
        applies invariant replans for flags at the last accepted chunk;
        returns the number of chunks accepted (>= 1).
        """
        plane = bucket.scan_plane_ref()
        state0, mon0 = bucket.state, bucket.monitor
        lowered = bucket.lowered.device() if self.monitored else None

        def dispatch(xs):
            return plane.fn(state0, mon0, bucket.ops_device(),
                            bucket.share_d, bucket.plans_device(),
                            lowered, xs)

        state, monitor, ys = dispatch(xs)
        full_h, pm_h, ov_h, cl_h, ng_h, vio_h = jax.device_get(
            (ys.full, ys.pm, ys.overflow, ys.closure, ys.neg,
             ys.violated))
        self._host_syncs += 1
        f = (first_event(vio_h, ov_h, n_en, escalate=False)
             if self.monitored else None)
        if f is not None and f < n_en - 1:
            # Re-run the prefix [0..f] from the saved pre-window state;
            # deterministic compute makes the accepted rows bitwise
            # identical to the optimistic pass.
            en = np.zeros(int(xs.enabled.shape[0]), bool)
            en[:f + 1] = True
            state, monitor, ys = dispatch(
                xs._replace(enabled=jnp.asarray(en)))
            full_h, pm_h, ov_h, cl_h, ng_h, vio_h = jax.device_get(
                (ys.full, ys.pm, ys.overflow, ys.closure, ys.neg,
                 ys.violated))
            self._host_syncs += 1
        accept = n_en if f is None else f + 1
        bucket.state, bucket.monitor = state, monitor
        for q, entry in enumerate(bucket.slots):
            if entry is None or not entry.active:
                continue
            full_k = full_h[:accept, :, q].astype(np.int64)
            entry.matches += full_k.sum(axis=0)
            entry.pm_created += int(pm_h[:accept, :, q].sum())
            entry.overflow += int(ov_h[:accept, :, q].sum())
            entry.closure_expansions += int(cl_h[:accept, :, q].sum())
            entry.neg_rejected += int(ng_h[:accept, :, q].sum())
            entry.chunks += accept
            out[base:base + accept, entry.rid] += full_k
        if f is not None:
            last = accept - 1
            fired = np.nonzero(vio_h[last])
            if fired[0].size:
                # One coalesced stats transfer serves every fired cell.
                self._host_syncs += 1
                rates_h = np.asarray(
                    jax.device_get(ys.rates[last]), np.float64)
                sel_h = np.asarray(jax.device_get(ys.sel[last]), np.float64)
                for k, q in zip(*fired):
                    self._replan_cell(bucket, int(k), int(q),
                                      rates_h, sel_h)
        return accept

    def run(self, stream: Stream) -> Telemetry:
        """Consume a chunk stream (any shape ``cep.Session.run`` accepts)
        and return this run's aggregate ``Telemetry``.  Stream state
        persists across calls, so feeding a stream in segments is
        equivalent to one continuous run.  With ``config.superchunk > 1``
        chunks are windowed through :meth:`step_superchunk` (bit-identical,
        one host sync per window instead of per chunk)."""
        before = self.telemetry()
        s_cap = self.config.superchunk
        if s_cap > 1:
            win: List[Chunk] = []
            edges: List[Tuple[float, float]] = []
            for fc in _normalize_stream(stream, self.k):
                win.append(fc.chunk)
                edges.append((fc.t0, fc.t1))
                if len(win) == s_cap:
                    self.step_superchunk(win, edges)
                    win, edges = [], []
            if win:
                self.step_superchunk(win, edges)
        else:
            for fc in _normalize_stream(stream, self.k):
                self.step(fc.chunk, fc.t0, fc.t1)
        after = self.telemetry()
        delta = Telemetry(partitions=self.k)
        for f in ("chunks", "matches", "replans", "deployments",
                  "violations", "host_syncs", "overflow", "neg_rejected",
                  "closure_expansions"):
            setattr(delta, f, getattr(after, f) - getattr(before, f))
        if after.per_partition_matches is not None:
            base = (before.per_partition_matches
                    if before.per_partition_matches is not None
                    else np.zeros((self.k,), np.int64))
            delta.per_partition_matches = (
                after.per_partition_matches - base)
        return delta

    # -- rule lifecycle ------------------------------------------------------

    def add_rule(self, rule) -> int:
        """Hot-add a rule; returns its rule id.

        Pure row writes into a free slot when one exists (ops row, plan
        rows, invariant rows, zeroed state rows — zero recompiles,
        asserted by ``trace_count()`` staying flat); growing a full
        bucket's capacity, or opening a bucket for a shape the rulebook
        has never seen, is the documented retrace/compile point.  The new
        rule always starts its own singleton lattice chain.
        """
        p = self._widen(self._check_pattern(as_pattern(rule)))
        n, has_neg, has_kl, neg_rows = self._bucket_key(p)
        bucket = None
        for b in self._buckets:
            # Coverage, not equality: a fused bucket's spec is a superset
            # its members gate per rule.  Without fusion, require the
            # exact shape class (keeps dispatch cost predictable).
            if b.bspec.n != n or neg_rows > b.bspec.neg_rows_cap:
                continue
            if has_neg and not b.bspec.has_neg:
                continue
            if has_kl and not b.bspec.has_kleene:
                continue
            if not self.config.bucket_fusion and \
                    (b.bspec.has_neg, b.bspec.has_kleene) != \
                    (has_neg, has_kl):
                continue
            bucket = b
            break
        if bucket is None:
            bucket = _Bucket(self, BucketSpec(
                n=n, has_neg=has_neg, has_kleene=has_kl,
                n_attrs=self.n_attrs, neg_rows_cap=neg_rows))
            bucket.build([], max(1, self.spare_slots),
                         probe_patterns=[p])
            self._buckets.append(bucket)
        if not bucket.free_slots:
            bucket.grow_slots()
        for d in range(bucket.depth):
            if not bucket.free_classes[d]:
                bucket.grow_classes(d)
        q = bucket.free_slots.pop(0)
        chain = tuple(bucket.free_classes[d].pop(0)
                      for d in range(bucket.depth))
        stat0 = uniform_stat(n)
        plan, dcs = greedy_order_plan(p, stat0)
        order = np.asarray(plan.order, np.int32)
        entry = _RuleEntry(
            rid=len(self._rules), pattern=p, bucket=bucket, slot=q,
            chain=chain, pinned=(), matches=np.zeros((self.k,), np.int64))
        self._rules.append(entry)
        bucket.slots[q] = entry
        for d, u in enumerate(chain):
            bucket.class_members[d][u] = [q]
            bucket.rep_h[d][u] = q
            bucket.parent_h[d][u] = chain[d - 1] if d else 0
        bucket.expand_h[q] = chain[-1]
        bucket._refresh_share()
        bucket.zero_state_row(q)
        bucket.write_ops_row(q, lower_rule(p, bucket.bspec))
        bucket.write_plan_all_k(q, order)
        if self.monitored:
            for k in range(self.k):
                pol = self.config.policy_factory()()
                pol.on_replan(_OrderRow(order), dcs, stat0)
                bucket.policies[k][q] = pol
                bucket.lowered.write(k, q, pol.compile(
                    n, max_inv=bucket.caps[0], max_terms=bucket.caps[1]))
        entry.deployments += 1
        return entry.rid

    def remove_rule(self, rid: int) -> None:
        """Hot-remove a rule: mask its slot out (row writes, no recompile).
        The slot is recycled by a later ``add_rule``."""
        entry = self._entry(rid)
        if not entry.active:
            raise ValueError(f"rule {rid} already removed")
        bucket, q = entry.bucket, entry.slot
        entry.active = False
        pad = pad_rule(bucket.bspec)
        bucket.write_ops_row(q, pad)
        bucket.slots[q] = None
        bucket.free_slots.append(q)
        reroute = False
        for d, u in enumerate(entry.chain):
            members = bucket.class_members[d][u]
            members.remove(q)
            if not members:
                bucket.free_classes[d].append(u)
            elif int(bucket.rep_h[d][u]) == q:
                # Any member can represent the class: the chain key pins
                # every operand of the shared join steps.
                bucket.rep_h[d][u] = members[0]
                reroute = True
        if reroute:
            bucket._refresh_share()
        if self.monitored:
            for k in range(self.k):
                bucket.policies[k][q] = None
                bucket.lowered.write(k, q, bucket._empty_lowered())

    def _entry(self, rid: int) -> _RuleEntry:
        if not (0 <= rid < len(self._rules)):
            raise KeyError(f"unknown rule id {rid}")
        return self._rules[rid]

    # -- introspection -------------------------------------------------------

    @property
    def rules(self) -> Tuple[int, ...]:
        """Active rule ids, insertion-ordered."""
        return tuple(e.rid for e in self._rules if e.active)

    @property
    def match_counts(self) -> np.ndarray:
        """(R, K) cumulative full-match counts over all rules ever added
        (removed rules keep their totals)."""
        return np.stack([e.matches for e in self._rules])

    def sharing_ratio(self) -> float:
        """Join work avoided by the sub-join lattice: per-rule plan steps
        over executed lattice node evaluations per chunk (1.0 = no
        sharing; opening-prefix-only sharing tops out just above 1 on
        deep rules, the full lattice keeps climbing with shared depth)."""
        steps = nodes = 0
        for b in self._buckets:
            n_active = sum(1 for e in b.slots
                           if e is not None and e.active)
            steps += n_active * b.depth
            for d in range(b.depth):
                nodes += sum(1 for m in b.class_members[d] if m)
        return steps / max(nodes, 1)

    def trace_count(self) -> int:
        """Total plane (re)traces — the hot-add zero-recompile probe.
        Counts the per-chunk and scanned planes alike."""
        return sum(b.plane.traces +
                   (b.scan_plane.traces if b.scan_plane is not None else 0)
                   for b in self._buckets)

    @property
    def n_buckets(self) -> int:
        """Compiled dispatches per tick (fusion folds shape classes of one
        arity into a single bucket)."""
        return len(self._buckets)

    def telemetry(self, rule: Optional[int] = None) -> Telemetry:
        """Cumulative telemetry, aggregate or for one rule id."""
        entries = ([self._entry(rule)] if rule is not None
                   else self._rules)
        tel = Telemetry(partitions=self.k)
        tel.per_partition_matches = np.zeros((self.k,), np.int64)
        for e in entries:
            tel.matches += int(e.matches.sum())
            tel.per_partition_matches += e.matches
            tel.overflow += e.overflow
            tel.neg_rejected += e.neg_rejected
            tel.closure_expansions += e.closure_expansions
            tel.replans += e.replans
            tel.deployments += e.deployments
            tel.violations += e.violations
        tel.chunks = (self._entry(rule).chunks if rule is not None
                      else self._chunks)
        tel.host_syncs = self._host_syncs
        return tel

    def reset(self) -> None:
        """Clear stream state (rings, monitors, counters); keep compiled
        planes, the rule set and deployed plans."""
        for bucket in self._buckets:
            bucket.state = init_rule_buffers(
                bucket.bspec, self.engine_cfg, self.k, bucket.q_cap)
            if self.monitored:
                bucket.monitor = init_rule_monitor(
                    bucket.bspec, self.k, bucket.q_cap,
                    self.config.estimator_buckets)
        for e in self._rules:
            e.matches = np.zeros((self.k,), np.int64)
            e.overflow = e.neg_rejected = e.closure_expansions = 0
            e.pm_created = e.chunks = 0
        self._chunks = 0
        self._host_syncs = 0


def open_rulebook(rules: Iterable, *, partitions: int = 1,
                  monitor: bool = True,
                  config: Optional[RuntimeConfig] = None,
                  spare_slots: int = 0) -> Rulebook:
    """Open a rulebook: Q patterns behind one compiled data plane per
    arity bucket.

    Parameters
    ----------
    rules:       patterns (``P`` builders or ``Pattern``s; OR-composites
                 must be added branch-by-branch).
    partitions:  K stream partitions, exactly as ``cep.open``; the Q×K
                 plane shards over ``config.mesh`` when set.
    monitor:     fuse statistics rings + per-(q, k) invariant verification
                 into the plane; ``False`` runs static cold plans.
    config:      a :class:`RuntimeConfig`; ``superchunk = S`` scans S
                 chunks per compiled dispatch (``run`` windows the stream,
                 ``step_superchunk`` takes explicit windows), ``sharing``
                 and ``bucket_fusion`` tune the multi-query optimizer.
    spare_slots: pre-provisioned free rule/lattice-class slots per bucket
                 so that many hot-adds are pure row writes (zero
                 retraces).
    """
    return Rulebook(list(rules), partitions=partitions, monitor=monitor,
                    config=config, spare_slots=spare_slots)
