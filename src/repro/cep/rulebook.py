"""Rulebook: one compiled data plane serving Q heterogeneous patterns.

``cep.open`` gives one pattern one data plane; production CEP serves a
*rule set* — thousands of distinct patterns per tenant.  A :class:`Rulebook`
compiles Q patterns (any mix the ``P`` DSL can build, minus OR-composites)
into the stacked structural tensors of ``core.multipattern``: rules are
bucketed by arity/shape, each bucket runs Qb rules × K partitions through
ONE jitted dispatch per chunk, and everything a rule *is* lives in row
``q`` of the bucket's tensors — so the paper's plans-as-data discipline now
covers the rule set itself:

* **hot add / remove are row writes.**  ``add_rule`` lowers the pattern
  into a free slot (ops row + plan rows + invariant rows + zeroed state
  rows) and ``remove_rule`` masks a slot out; neither recompiles anything.
  The only sanctioned retrace is bucket-capacity growth (the same jitted
  callable re-entered with a bigger Qb — asserted via the plane's
  trace-count probe in the bench).
* **adaptation is per (q, k) cell.**  Each cell owns an
  ``InvariantPolicy``; the monitored plane returns a (K, Qb) violation
  bitmap and the host replans exactly the flagged cells (host work ∝
  violations, as in the single-pattern serving front), deploying the fresh
  plan + lowered invariant set as two row writes.
* **common sub-joins run once.**  Rules whose cold plans open on the same
  two-position sub-join (same positions, event types, window,
  sequence-ness and pairwise predicate) form a prefix group: the shared
  prefix join executes once per group and fans out to members
  (``sharing_ratio()`` reports rules / groups).  Grouped rules keep their
  leading two plan steps pinned (``greedy_order_plan(pin=...)``) so later
  replans never break the share; hot-added rules always start their own
  singleton group, since joining one retroactively would constrain plans
  chosen before the rule existed.

Counter semantics are the serving front's: immediate deployment, no
migration split, exactly-once chunked counting — and per-rule counters are
bit-identical to Q independent Sessions over the same stream (the bench
and property tests gate this).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import Chunk, EngineConfig, make_spec
from ..core.fleet import stack_chunks
from ..core.greedy import greedy_order_plan
from ..core.invariants import LoweredInvariants
from ..core.multipattern import (BucketSpec, RuleOps, ShareOps,
                                 init_rule_buffers, init_rule_monitor,
                                 lower_rule, make_rulebook_plane, pad_rule,
                                 stack_rule_ops)
from ..core.patterns import CompositePattern, Pattern
from ..core.stats import Stat, uniform_stat
from ..distributed.sharding import resolve_cep_mesh
from .config import RuntimeConfig
from .dsl import as_pattern
from .session import Stream, Telemetry, _normalize_stream

__all__ = ["Rulebook", "open_rulebook"]


def _prefix_key(pattern: Pattern, order: Sequence[int]):
    """Identity of a rule's leading two-position sub-join.

    Two rules with equal keys produce bit-identical partial-match sets
    after plan step 1: the key pins the buffer contents (types), the
    eviction horizon (window), every active constraint row of the first
    packed join (window rows, sequence anchors via positions + is_seq,
    and the single live predicate row (o0, o1)) and the positions the
    values land in.  Inactive rows are PRED_NONE on both sides.
    """
    spec = make_spec(pattern)
    o0, o1 = int(order[0]), int(order[1])
    return (o0, o1, spec.type_ids[o0], spec.type_ids[o1],
            float(spec.window), bool(spec.is_seq),
            int(spec.op_t[o0, o1]), int(spec.a_attr_t[o0, o1]),
            int(spec.b_attr_t[o0, o1]), float(spec.theta_t[o0, o1]))


class _Lowered2D:
    """(K, Qb) invariant matrix: host-writable rows, device-cached.

    The fleet's ``StackedLowered`` with a rule axis next to the partition
    axis — a deployment patches one (k, q) cell, capacity growth pads the
    rule axis and invalidates the cache.
    """

    def __init__(self, host: LoweredInvariants):
        self.host = host
        self._dev: Optional[LoweredInvariants] = None

    @classmethod
    def build(cls, rows_kq: Sequence[Sequence[LoweredInvariants]]):
        return cls(LoweredInvariants(
            *(np.stack([np.stack([np.asarray(getattr(r, f)) for r in krow])
                        for krow in rows_kq])
              for f in LoweredInvariants._fields)))

    def write(self, k: int, q: int, row: LoweredInvariants) -> None:
        for f in LoweredInvariants._fields:
            dst, src = getattr(self.host, f), np.asarray(getattr(row, f))
            if dst[k, q].shape != src.shape:
                raise ValueError(
                    f"lowered field {f!r}: row shape {src.shape} != "
                    f"stacked {dst[k, q].shape}")
            dst[k, q] = src
        # Invalidate instead of patching: any number of cell deployments
        # within one tick amortize into a single upload per field at the
        # next dispatch.
        self._dev = None

    def grow(self, new_qcap: int) -> None:
        q_cap = self.host.active.shape[1]
        pad = new_qcap - q_cap
        self.host = LoweredInvariants(*(
            np.pad(getattr(self.host, f),
                   ((0, 0), (0, pad)) + ((0, 0),) * (getattr(
                       self.host, f).ndim - 2))
            for f in LoweredInvariants._fields))
        self._dev = None

    def device(self) -> LoweredInvariants:
        if self._dev is None:
            self._dev = LoweredInvariants(
                *(jnp.asarray(x) for x in self.host))
        return self._dev


@dataclasses.dataclass
class _RuleEntry:
    """Host bookkeeping + cumulative counters for one rule."""

    rid: int
    pattern: Pattern
    bucket: "_Bucket"
    slot: int               # q row in the bucket (fixed while active)
    group: int              # u slot of its prefix group
    pinned: Tuple[int, ...]  # () or the pinned 2-step prefix
    active: bool = True
    matches: np.ndarray = None       # (K,) int64
    overflow: int = 0
    neg_rejected: int = 0
    closure_expansions: int = 0
    pm_created: int = 0
    replans: int = 0
    deployments: int = 0
    violations: int = 0
    chunks: int = 0


class _Bucket:
    """One arity bucket: stacked tensors + plane + per-cell policies."""

    def __init__(self, rb: "Rulebook", bspec: BucketSpec):
        self.rb = rb
        self.bspec = bspec
        self.q_cap = 0
        self.u_cap = 0
        self.slots: List[Optional[_RuleEntry]] = []
        self.group_members: List[List[int]] = []  # u -> member slots
        self.free_slots: List[int] = []
        self.free_groups: List[int] = []
        # Host mirrors (device copies are patched in lockstep).
        self.ops_h: Optional[RuleOps] = None
        self.ops_d: Optional[RuleOps] = None
        self.plans_h: Optional[np.ndarray] = None   # (K, Qb, n) i32
        self.plans_d = None
        self.rep_h: Optional[np.ndarray] = None     # (U,) i32
        self.expand_h: Optional[np.ndarray] = None  # (Qb,) i32
        self.share_d: Optional[ShareOps] = None
        self.state = None
        self.monitor = None
        self.lowered: Optional[_Lowered2D] = None
        self.policies: List[List] = []              # [k][q] -> policy
        self.caps: Tuple[int, int] = (1, 1)
        self.plane = None

    # -- layout ------------------------------------------------------------

    def _refresh_share(self) -> None:
        self.share_d = ShareOps(
            rep_idx=jnp.asarray(self.rep_h, jnp.int32),
            expand_idx=jnp.asarray(self.expand_h, jnp.int32))

    def _make_plane(self) -> None:
        rb = self.rb
        self.plane = make_rulebook_plane(
            self.bspec, rb.engine_cfg, rb.k, rb.monitored,
            laplace=rb.config.laplace, mesh=rb.mesh)

    def build(self, entries: Sequence[Tuple[_RuleEntry, RuleOps,
                                            np.ndarray, list, object]],
              spare: int,
              probe_patterns: Optional[Sequence[Pattern]] = None) -> None:
        """Initial layout from (entry, ops_row, order, dcs, stat) tuples.

        Entries arrive pre-grouped (``entry.group`` / ``entry.slot`` set);
        ``spare`` free rule slots and group slots are pre-provisioned so
        the first hot-adds are pure row writes.  ``probe_patterns`` seeds
        the invariant-cap probe when the bucket opens empty (hot-add into
        a new shape) — the incoming rule must fit the caps.
        """
        rb = self.rb
        n_rules = len(entries)
        n_groups = 1 + max((e.group for e, *_ in entries), default=-1)
        self.q_cap = n_rules + spare
        self.u_cap = n_groups + spare
        rows = [None] * self.q_cap
        self.slots = [None] * self.q_cap
        self.group_members = [[] for _ in range(self.u_cap)]
        self.rep_h = np.zeros((self.u_cap,), np.int32)
        self.expand_h = np.zeros((self.q_cap,), np.int32)
        self.plans_h = np.tile(np.arange(self.bspec.n, dtype=np.int32),
                               (rb.k, self.q_cap, 1))
        if rb.monitored:
            self.policies = [[None] * self.q_cap for _ in range(rb.k)]
            self.caps = self._probe_caps(
                probe_patterns if probe_patterns is not None
                else [e.pattern for e, *_ in entries])
        low_rows: List[List[LoweredInvariants]] = [
            [None] * self.q_cap for _ in range(rb.k)]
        for entry, ops_row, order, dcs, stat in entries:
            q, u = entry.slot, entry.group
            rows[q] = ops_row
            self.slots[q] = entry
            self.group_members[u].append(q)
            self.expand_h[q] = u
            self.plans_h[:, q] = order
            if rb.monitored:
                for k in range(rb.k):
                    pol = rb.config.policy_factory()()
                    plan = _OrderRow(order)
                    pol.on_replan(plan, dcs, stat)
                    self.policies[k][q] = pol
                    low_rows[k][q] = pol.compile(
                        self.bspec.n, max_inv=self.caps[0],
                        max_terms=self.caps[1])
        for u, members in enumerate(self.group_members):
            self.rep_h[u] = members[0] if members else 0
        for q in range(self.q_cap):
            if rows[q] is None:
                rows[q] = pad_rule(self.bspec)
                self.free_slots.append(q)
        for u in range(self.u_cap):
            if not self.group_members[u]:
                self.free_groups.append(u)
        if rb.monitored:
            empty = self._empty_lowered()
            for k in range(rb.k):
                for q in range(self.q_cap):
                    if low_rows[k][q] is None:
                        low_rows[k][q] = empty
            self.lowered = _Lowered2D.build(low_rows)
            self.monitor = init_rule_monitor(
                self.bspec, rb.k, self.q_cap, rb.config.estimator_buckets)
        self.ops_h = stack_rule_ops(rows)
        self.ops_d = jax.tree.map(jnp.asarray, self.ops_h)
        self.plans_d = jnp.asarray(self.plans_h)
        self._refresh_share()
        self.state = init_rule_buffers(self.bspec, rb.engine_cfg, rb.k,
                                       self.q_cap)
        self._make_plane()

    def _probe_caps(self, patterns: Sequence[Pattern]) -> Tuple[int, int]:
        """Bucket-wide lowered-invariant caps from UNPINNED cold plans.

        Pinning only removes deciding conditions (pinned blocks are
        empty), so the free plan's invariant set is the per-rule worst
        case; every cell then lowers at the bucket max so invariant
        deployments stay row writes.  ``config.max_invariants/max_terms``
        override upward.
        """
        rb = self.rb
        i_cap = t_cap = 1
        stat0 = uniform_stat(self.bspec.n)
        for p in patterns:
            plan, dcs = greedy_order_plan(p, stat0)
            pol = rb.config.policy_factory()()
            pol.on_replan(plan, dcs, stat0)
            low = pol.compile(self.bspec.n)
            i_cap = max(i_cap, low.active.shape[0])
            t_cap = max(t_cap, low.scale.shape[-1])
        if rb.config.max_invariants is not None:
            i_cap = max(i_cap, int(rb.config.max_invariants))
        if rb.config.max_terms is not None:
            t_cap = max(t_cap, int(rb.config.max_terms))
        return (i_cap, t_cap)

    def _empty_lowered(self) -> LoweredInvariants:
        """An inert invariant row (active all-False) for empty slots."""
        from ..core.invariants import lower_invariants

        return lower_invariants([], 0.0, self.bspec.n,
                                max_inv=self.caps[0],
                                max_terms=self.caps[1])

    # -- growth (the one retrace point) ------------------------------------

    def grow_slots(self) -> None:
        """Double the rule capacity: pad every host/device tensor along the
        rule axis.  The next dispatch re-enters the same jitted plane with
        the new Qb — one retrace, no new compile cache entry."""
        rb = self.rb
        old, new = self.q_cap, max(1, self.q_cap * 2)
        pad_n = new - old
        pad_rows = [pad_rule(self.bspec)] * pad_n
        self.ops_h = RuleOps(*(
            np.concatenate([getattr(self.ops_h, f),
                            np.stack([np.asarray(getattr(r, f))
                                      for r in pad_rows])])
            for f in RuleOps._fields))
        self.ops_d = jax.tree.map(jnp.asarray, self.ops_h)
        self.plans_h = np.concatenate(
            [self.plans_h,
             np.tile(np.arange(self.bspec.n, dtype=np.int32),
                     (rb.k, pad_n, 1))], axis=1)
        self.plans_d = jnp.asarray(self.plans_h)
        self.expand_h = np.concatenate(
            [self.expand_h, np.zeros((pad_n,), np.int32)])
        self._refresh_share()
        self.state = jax.tree.map(
            lambda x: jnp.pad(x, ((0, 0), (0, pad_n)) +
                              ((0, 0),) * (x.ndim - 2)), self.state)
        if rb.monitored:
            self.monitor = jax.tree.map(
                lambda x: jnp.pad(x, ((0, 0), (0, pad_n)) +
                                  ((0, 0),) * (x.ndim - 2)), self.monitor)
            self.lowered.grow(new)
            empty = self._empty_lowered()
            for k in range(rb.k):
                self.policies[k].extend([None] * pad_n)
                for q in range(old, new):
                    self.lowered.write(k, q, empty)
        self.slots.extend([None] * pad_n)
        self.free_slots.extend(range(old, new))
        self.q_cap = new

    def grow_groups(self) -> None:
        old, new = self.u_cap, max(1, self.u_cap * 2)
        self.rep_h = np.concatenate(
            [self.rep_h, np.zeros((new - old,), np.int32)])
        self.group_members.extend([] for _ in range(new - old))
        self.free_groups.extend(range(old, new))
        self._refresh_share()
        self.u_cap = new

    # -- row writes --------------------------------------------------------

    def write_ops_row(self, q: int, row: RuleOps) -> None:
        for f in RuleOps._fields:
            np.asarray(getattr(self.ops_h, f))[q] = np.asarray(
                getattr(row, f))
        self.ops_d = None

    def write_plan_row(self, k: int, q: int, order: np.ndarray) -> None:
        self.plans_h[k, q] = order
        self.plans_d = None

    def write_plan_all_k(self, q: int, order: np.ndarray) -> None:
        self.plans_h[:, q] = order
        self.plans_d = None

    def ops_device(self) -> RuleOps:
        if self.ops_d is None:
            self.ops_d = jax.tree.map(jnp.asarray, self.ops_h)
        return self.ops_d

    def plans_device(self):
        if self.plans_d is None:
            self.plans_d = jnp.asarray(self.plans_h)
        return self.plans_d

    def zero_state_row(self, q: int) -> None:
        self.state = jax.tree.map(
            lambda x: x.at[:, q].set(jnp.zeros_like(x[:, q])), self.state)
        if self.monitor is not None:
            self.monitor = jax.tree.map(
                lambda x: x.at[:, q].set(jnp.zeros_like(x[:, q])),
                self.monitor)


class _OrderRow:
    """Minimal plan object handed to decision policies (order-only)."""

    def __init__(self, order):
        self.order = tuple(int(o) for o in order)


class Rulebook:
    """Q patterns, one compiled data plane per arity bucket.

    Construct via :func:`open_rulebook`.  ``step``/``run`` advance every
    rule at once; ``add_rule``/``remove_rule`` mutate the rule set live.
    """

    def __init__(self, rules: Sequence, *, partitions: int = 1,
                 monitor: bool = True,
                 config: Optional[RuntimeConfig] = None,
                 spare_slots: int = 0):
        if partitions < 1:
            raise ValueError("partitions must be >= 1")
        self.config = config or RuntimeConfig()
        if self.config.superchunk > 1:
            raise ValueError("rulebooks step per chunk; superchunk > 1 is "
                             "not supported yet")
        self.k = int(partitions)
        self.monitored = bool(monitor)
        if self.monitored and self.config.policy != "invariant":
            raise ValueError(
                "monitored rulebooks verify lowered invariant sets on "
                "device; config.policy must be 'invariant' "
                f"(got {self.config.policy!r})")
        self.engine_cfg: EngineConfig = self.config.engine()
        self.mesh = resolve_cep_mesh(self.config.mesh, self.k)
        self.spare_slots = int(spare_slots)
        patterns = [self._check_pattern(as_pattern(r)) for r in rules]
        if not patterns:
            raise ValueError("open_rulebook needs at least one rule")
        # Rulebook-wide attribute width: chunks are shared by every rule.
        self.n_attrs = max(p.n_attrs for p in patterns)
        patterns = [self._widen(p) for p in patterns]
        self._rules: List[_RuleEntry] = []
        self._buckets: List[_Bucket] = []
        self._chunks = 0
        self._host_syncs = 0
        self._build(patterns)

    # -- construction -------------------------------------------------------

    def _check_pattern(self, p) -> Pattern:
        if isinstance(p, CompositePattern):
            raise ValueError(
                "OR-composites decompose into independent branches; add "
                "each branch to the rulebook as its own rule")
        return p

    def _widen(self, p: Pattern) -> Pattern:
        if p.n_attrs > self.n_attrs:
            raise ValueError("rule exceeds rulebook attribute width")
        if p.n_attrs != self.n_attrs:
            p = dataclasses.replace(p, n_attrs=self.n_attrs)
        return p

    def _bucket_key(self, p: Pattern):
        spec = make_spec(p)
        return (spec.n, spec.has_neg, spec.kleene_pos is not None,
                len(spec.neg_rows))

    def _build(self, patterns: Sequence[Pattern]) -> None:
        # rid == position in the caller's rule list; buckets regroup the
        # rules physically but never renumber them.
        base = len(self._rules)
        self._rules.extend([None] * len(patterns))
        by_shape: Dict[tuple, List[Tuple[int, Pattern]]] = {}
        for idx, p in enumerate(patterns):
            n, has_neg, has_kl, _ = self._bucket_key(p)
            by_shape.setdefault((n, has_neg, has_kl), []).append((idx, p))
        stat0_cache: Dict[int, Stat] = {}
        for (n, has_neg, has_kl), ps in by_shape.items():
            neg_cap = max((len(make_spec(p).neg_rows) for _, p in ps),
                          default=0)
            bspec = BucketSpec(n=n, has_neg=has_neg, has_kleene=has_kl,
                               n_attrs=self.n_attrs, neg_rows_cap=neg_cap)
            bucket = _Bucket(self, bspec)
            stat0 = stat0_cache.setdefault(n, uniform_stat(n))
            # Cold-plan free, then group by the leading sub-join.
            cold = [greedy_order_plan(p, stat0) for _, p in ps]
            groups: Dict[tuple, int] = {}
            assignments = []
            for (_, p), (plan, _) in zip(ps, cold):
                key = _prefix_key(p, plan.order)
                assignments.append(groups.setdefault(key, len(groups)))
            group_sizes = np.bincount(assignments, minlength=len(groups))
            entries = []
            for slot, ((idx, p), (plan, dcs), u) in enumerate(
                    zip(ps, cold, assignments)):
                pinned: Tuple[int, ...] = ()
                if group_sizes[u] >= 2:
                    pinned = tuple(int(o) for o in plan.order[:2])
                    plan, dcs = greedy_order_plan(p, stat0, pin=pinned)
                entry = _RuleEntry(
                    rid=base + idx, pattern=p, bucket=bucket,
                    slot=slot, group=u, pinned=pinned,
                    matches=np.zeros((self.k,), np.int64))
                self._rules[base + idx] = entry
                entries.append((entry, lower_rule(p, bspec),
                                np.asarray(plan.order, np.int32), dcs,
                                stat0))
            bucket.build(entries, self.spare_slots)
            self._buckets.append(bucket)

    # -- data plane ---------------------------------------------------------

    def step(self, chunk: Chunk, t0: float, t1: float) -> np.ndarray:
        """Advance every rule one tick over an already-stacked chunk.

        ``chunk`` fields carry a leading K axis (a bare single-partition
        ``Chunk`` is accepted when K = 1).  Returns this tick's full-match
        counts as an (R, K) array over rules in insertion order (removed
        rules contribute zero rows).  Monitored rulebooks also run the
        violation → sync → replan → row-deploy loop per flagged (q, k)
        cell inside the call.
        """
        if chunk.type_id.ndim == 1:
            if self.k != 1:
                raise ValueError("unstacked chunk on a multi-partition "
                                 "rulebook; stack K per-partition chunks")
            chunk = stack_chunks([chunk])
        if chunk.attr.shape[-1] != self.n_attrs:
            raise ValueError(
                f"chunk has {chunk.attr.shape[-1]} attributes; this "
                f"rulebook is compiled for {self.n_attrs}")
        t0j, t1j = jnp.float32(t0), jnp.float32(t1)
        self._chunks += 1
        out = np.zeros((len(self._rules), self.k), np.int64)
        for bucket in self._buckets:
            if self.monitored:
                (bucket.state, bucket.monitor, res, violated, _drift,
                 rates, sel) = bucket.plane.fn(
                     bucket.state, bucket.monitor, chunk,
                     bucket.ops_device(), bucket.share_d,
                     bucket.plans_device(),
                     bucket.lowered.device(), t0j, t1j)
            else:
                bucket.state, res = bucket.plane.fn(
                    bucket.state, chunk, bucket.ops_device(),
                    bucket.share_d, bucket.plans_device(), t0j, t1j)
            # One coalesced counter transfer per bucket per tick.
            cnt = np.asarray(jnp.stack(
                [res.full, res.pm, res.overflow, res.closure, res.neg]))
            self._host_syncs += 1
            for q, entry in enumerate(bucket.slots):
                if entry is None or not entry.active:
                    continue
                full_k = cnt[0, :, q].astype(np.int64)
                entry.matches += full_k
                entry.pm_created += int(cnt[1, :, q].sum())
                entry.overflow += int(cnt[2, :, q].sum())
                entry.closure_expansions += int(cnt[3, :, q].sum())
                entry.neg_rejected += int(cnt[4, :, q].sum())
                entry.chunks += 1
                out[entry.rid] = full_k
            if self.monitored:
                fired = np.nonzero(np.asarray(violated))
                if fired[0].size:
                    # One coalesced stats transfer serves every fired
                    # cell; per-cell device indexing costs a sync each.
                    self._host_syncs += 1
                    rates_h = np.asarray(rates, np.float64)
                    sel_h = np.asarray(sel, np.float64)
                    for k, q in zip(*fired):
                        self._replan_cell(bucket, int(k), int(q),
                                          rates_h, sel_h)
        return out

    def _replan_cell(self, bucket: _Bucket, k: int, q: int,
                     rates, sel) -> None:
        """Invariant violation at cell (k, q): re-run the planner on that
        cell's device statistics and deploy plan + invariant rows."""
        entry = bucket.slots[q]
        if entry is None or not entry.active:
            return
        entry.violations += 1
        stat = Stat(np.asarray(rates[k, q], np.float64),
                    np.asarray(sel[k, q], np.float64))
        plan, dcs = greedy_order_plan(entry.pattern, stat,
                                      pin=entry.pinned)
        order = np.asarray(plan.order, np.int32)
        changed = not np.array_equal(order, bucket.plans_h[k, q])
        bucket.write_plan_row(k, q, order)
        pol = bucket.policies[k][q]
        pol.on_replan(plan, dcs, stat)
        bucket.lowered.write(k, q, pol.compile(
            bucket.bspec.n, max_inv=bucket.caps[0],
            max_terms=bucket.caps[1]))
        entry.replans += 1
        if changed:
            entry.deployments += 1

    def run(self, stream: Stream) -> Telemetry:
        """Consume a chunk stream (any shape ``cep.Session.run`` accepts)
        and return this run's aggregate ``Telemetry``.  Stream state
        persists across calls, so feeding a stream in segments is
        equivalent to one continuous run."""
        before = self.telemetry()
        for fc in _normalize_stream(stream, self.k):
            self.step(fc.chunk, fc.t0, fc.t1)
        after = self.telemetry()
        delta = Telemetry(partitions=self.k)
        for f in ("chunks", "matches", "replans", "deployments",
                  "violations", "host_syncs", "overflow", "neg_rejected",
                  "closure_expansions"):
            setattr(delta, f, getattr(after, f) - getattr(before, f))
        if after.per_partition_matches is not None:
            base = (before.per_partition_matches
                    if before.per_partition_matches is not None
                    else np.zeros((self.k,), np.int64))
            delta.per_partition_matches = (
                after.per_partition_matches - base)
        return delta

    # -- rule lifecycle ------------------------------------------------------

    def add_rule(self, rule) -> int:
        """Hot-add a rule; returns its rule id.

        Pure row writes into a free slot when one exists (ops row, plan
        rows, invariant rows, zeroed state rows — zero recompiles,
        asserted by ``trace_count()`` staying flat); growing a full
        bucket's capacity, or opening a bucket for a shape the rulebook
        has never seen, is the documented retrace/compile point.  The new
        rule always starts its own prefix group.
        """
        p = self._widen(self._check_pattern(as_pattern(rule)))
        n, has_neg, has_kl, neg_rows = self._bucket_key(p)
        bucket = None
        for b in self._buckets:
            if (b.bspec.n, b.bspec.has_neg, b.bspec.has_kleene) == \
                    (n, has_neg, has_kl) and \
                    neg_rows <= b.bspec.neg_rows_cap:
                bucket = b
                break
        if bucket is None:
            bucket = _Bucket(self, BucketSpec(
                n=n, has_neg=has_neg, has_kleene=has_kl,
                n_attrs=self.n_attrs, neg_rows_cap=neg_rows))
            bucket.build([], max(1, self.spare_slots),
                         probe_patterns=[p])
            self._buckets.append(bucket)
        if not bucket.free_slots:
            bucket.grow_slots()
        if not bucket.free_groups:
            bucket.grow_groups()
        q = bucket.free_slots.pop(0)
        u = bucket.free_groups.pop(0)
        stat0 = uniform_stat(n)
        plan, dcs = greedy_order_plan(p, stat0)
        order = np.asarray(plan.order, np.int32)
        entry = _RuleEntry(
            rid=len(self._rules), pattern=p, bucket=bucket, slot=q,
            group=u, pinned=(), matches=np.zeros((self.k,), np.int64))
        self._rules.append(entry)
        bucket.slots[q] = entry
        bucket.group_members[u] = [q]
        bucket.rep_h[u] = q
        bucket.expand_h[q] = u
        bucket._refresh_share()
        bucket.zero_state_row(q)
        bucket.write_ops_row(q, lower_rule(p, bucket.bspec))
        bucket.write_plan_all_k(q, order)
        if self.monitored:
            for k in range(self.k):
                pol = self.config.policy_factory()()
                pol.on_replan(_OrderRow(order), dcs, stat0)
                bucket.policies[k][q] = pol
                bucket.lowered.write(k, q, pol.compile(
                    n, max_inv=bucket.caps[0], max_terms=bucket.caps[1]))
        entry.deployments += 1
        return entry.rid

    def remove_rule(self, rid: int) -> None:
        """Hot-remove a rule: mask its slot out (row writes, no recompile).
        The slot is recycled by a later ``add_rule``."""
        entry = self._entry(rid)
        if not entry.active:
            raise ValueError(f"rule {rid} already removed")
        bucket, q, u = entry.bucket, entry.slot, entry.group
        entry.active = False
        pad = pad_rule(bucket.bspec)
        bucket.write_ops_row(q, pad)
        bucket.slots[q] = None
        bucket.free_slots.append(q)
        members = bucket.group_members[u]
        members.remove(q)
        if not members:
            bucket.free_groups.append(u)
        elif int(bucket.rep_h[u]) == q:
            # Any member can represent the group: the prefix key pins
            # every operand of the shared first join step.
            bucket.rep_h[u] = members[0]
            bucket._refresh_share()
        if self.monitored:
            for k in range(self.k):
                bucket.policies[k][q] = None
                bucket.lowered.write(k, q, bucket._empty_lowered())

    def _entry(self, rid: int) -> _RuleEntry:
        if not (0 <= rid < len(self._rules)):
            raise KeyError(f"unknown rule id {rid}")
        return self._rules[rid]

    # -- introspection -------------------------------------------------------

    @property
    def rules(self) -> Tuple[int, ...]:
        """Active rule ids, insertion-ordered."""
        return tuple(e.rid for e in self._rules if e.active)

    @property
    def match_counts(self) -> np.ndarray:
        """(R, K) cumulative full-match counts over all rules ever added
        (removed rules keep their totals)."""
        return np.stack([e.matches for e in self._rules])

    def sharing_ratio(self) -> float:
        """Active rules per active prefix group (1.0 = no sharing)."""
        n_rules = sum(1 for e in self._rules if e.active)
        n_groups = sum(1 for b in self._buckets
                       for m in b.group_members if m)
        return n_rules / max(n_groups, 1)

    def trace_count(self) -> int:
        """Total plane (re)traces — the hot-add zero-recompile probe."""
        return sum(b.plane.traces for b in self._buckets)

    def telemetry(self, rule: Optional[int] = None) -> Telemetry:
        """Cumulative telemetry, aggregate or for one rule id."""
        entries = ([self._entry(rule)] if rule is not None
                   else self._rules)
        tel = Telemetry(partitions=self.k)
        tel.per_partition_matches = np.zeros((self.k,), np.int64)
        for e in entries:
            tel.matches += int(e.matches.sum())
            tel.per_partition_matches += e.matches
            tel.overflow += e.overflow
            tel.neg_rejected += e.neg_rejected
            tel.closure_expansions += e.closure_expansions
            tel.replans += e.replans
            tel.deployments += e.deployments
            tel.violations += e.violations
        tel.chunks = (self._entry(rule).chunks if rule is not None
                      else self._chunks)
        tel.host_syncs = self._host_syncs
        return tel

    def reset(self) -> None:
        """Clear stream state (rings, monitors, counters); keep compiled
        planes, the rule set and deployed plans."""
        for bucket in self._buckets:
            bucket.state = init_rule_buffers(
                bucket.bspec, self.engine_cfg, self.k, bucket.q_cap)
            if self.monitored:
                bucket.monitor = init_rule_monitor(
                    bucket.bspec, self.k, bucket.q_cap,
                    self.config.estimator_buckets)
        for e in self._rules:
            e.matches = np.zeros((self.k,), np.int64)
            e.overflow = e.neg_rejected = e.closure_expansions = 0
            e.pm_created = e.chunks = 0
        self._chunks = 0
        self._host_syncs = 0


def open_rulebook(rules: Iterable, *, partitions: int = 1,
                  monitor: bool = True,
                  config: Optional[RuntimeConfig] = None,
                  spare_slots: int = 0) -> Rulebook:
    """Open a rulebook: Q patterns behind one compiled data plane per
    arity bucket.

    Parameters
    ----------
    rules:       patterns (``P`` builders or ``Pattern``s; OR-composites
                 must be added branch-by-branch).
    partitions:  K stream partitions, exactly as ``cep.open``; the Q×K
                 plane shards over ``config.mesh`` when set.
    monitor:     fuse statistics rings + per-(q, k) invariant verification
                 into the plane; ``False`` runs static cold plans.
    config:      a :class:`RuntimeConfig` (``superchunk`` must stay 1).
    spare_slots: pre-provisioned free rule/group slots per bucket so that
                 many hot-adds are pure row writes (zero retraces).
    """
    return Rulebook(list(rules), partitions=partitions, monitor=monitor,
                    config=config, spare_slots=spare_slots)
