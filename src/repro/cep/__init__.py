"""``repro.cep`` — the public CEP runtime surface.

One entry point, everything else configuration:

    from repro import cep
    from repro.cep import P, RuntimeConfig

    pattern = (P.seq(0, 1, 2)
               .where(P.attr(0) < P.attr(1) - 0.3,
                      P.attr(1) < P.attr(2) - 0.3)
               .within(4.0))

    session = cep.open(pattern, partitions=8, plan="auto", monitor=True,
                       config=RuntimeConfig(match_capacity=1024))
    telemetry = session.run(streams)          # batch adaptive loop
    counts = session.process(tid, ts, attr, keys, t0, t1)  # keyed serving

The documented surface is exactly ``__all__``; CI asserts it.  ``RefEngine``
is exported so downstream deployments can cross-check any session against
the brute-force oracle, exactly as our own tests and examples do.
"""

from ..core.patterns import CompositePattern, Pattern  # noqa: F401
from ..core.plans import OrderPlan, TreePlan  # noqa: F401
from ..core.ref_engine import RefEngine  # noqa: F401
from .config import RuntimeConfig  # noqa: F401
from .dsl import P  # noqa: F401
from .rulebook import Rulebook, open_rulebook  # noqa: F401
from .session import Session, Telemetry, open  # noqa: F401

__all__ = [
    "P",
    "open",
    "open_rulebook",
    "Session",
    "Rulebook",
    "Telemetry",
    "RuntimeConfig",
    "Pattern",
    "CompositePattern",
    "OrderPlan",
    "TreePlan",
    "RefEngine",
]
