"""Data pipelines: CEP stream generators and synthetic LM token data."""
