"""Synthetic LM data pipeline — deterministic, shardable, frontend-aware.

Tokens follow a Zipf unigram distribution filtered through a first-order
Markov mixing kernel, giving the loss curve actual structure to learn
(bigram statistics) while remaining fully offline and reproducible.  Each
batch is a pure function of ``(seed, step)`` so any worker — or a restarted
job — regenerates exactly the same global batch: data-parallel shards slice
the same global batch by row, which is what makes checkpoint/restart and
elastic rescaling bit-exact.

For the stubbed-frontend families the pipeline fabricates the precomputed
embeddings the assignment specifies (VLM patch embeddings / audio frame
embeddings) from the same ``(seed, step)`` stream.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np

from ..models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int = 8
    seq: int = 128
    seed: int = 0
    zipf_a: float = 1.3
    markov_shift: int = 7      # deterministic bigram structure


def _unigram(vocab: int, a: float) -> np.ndarray:
    p = 1.0 / np.arange(1, vocab + 1) ** a
    return p / p.sum()


def make_batch(cfg: ModelConfig, dcfg: DataConfig, step: int
               ) -> Dict[str, np.ndarray]:
    """Global batch for ``step`` — pure function of (seed, step)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([dcfg.seed, step]))
    B, S, V = dcfg.batch, dcfg.seq, cfg.vocab
    p = _unigram(V, dcfg.zipf_a)
    base = rng.choice(V, size=(B, S + 1), p=p).astype(np.int32)
    # Markov structure: with prob 1/2 the next token is a deterministic
    # function of the previous one — learnable bigram signal.
    follow = rng.random((B, S)) < 0.5
    nxt = (base[:, :-1] * dcfg.markov_shift + 1) % V
    tokens = base.copy()
    tokens[:, 1:] = np.where(follow, nxt, base[:, 1:])

    out: Dict[str, np.ndarray] = {
        "labels": tokens[:, 1:].astype(np.int32),
    }
    if cfg.family == "vlm":
        out["tokens"] = tokens[:, :-1].astype(np.int32)
        out["patch_embeds"] = rng.normal(
            0, 1, (B, cfg.n_frontend_tokens, cfg.d_model)
        ).astype(np.float32)
        # Loss over text positions only; logits already text-aligned.
    elif cfg.frontend_is_embedding:
        # Audio: embeddings stand in for EnCodec frame embeddings; labels
        # are the (synthetic) codec ids of the next frame.
        out["embeds"] = rng.normal(0, 1, (B, S, cfg.d_model)) \
            .astype(np.float32)
        out["labels"] = tokens[:, 1:].astype(np.int32)
    else:
        out["tokens"] = tokens[:, :-1].astype(np.int32)
    return out


def batch_iterator(cfg: ModelConfig, dcfg: DataConfig,
                   start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, dcfg, step)
        step += 1
