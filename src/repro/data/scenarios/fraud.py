"""Fraud-style card-transaction sequences with abrupt regime shifts.

The paper's credit-card domain: an authorization probe, an escalating
purchase, then a large cross-border transfer within a short window, with
strictly increasing amounts (the classic card-testing ladder).  One global
stream (K = 1) — the adaptivity story here is purely temporal.

Statistical design: baseline traffic has probes rare and transfers as
routine bulk (settlement chatter), keeping the cold-start plan optimal
through the stationary control segment.  A fraud campaign then lands as
*abrupt* shocks (the traffic-regime shape from the paper's Aarhus data:
rare but extreme): probe volume explodes ~12x while legitimate transfer
chatter collapses, and a second mid-campaign shock pushes amounts (and so
predicate selectivities) up as the fraudsters scale.  The pinned plan
seeds on the probe flood and overflows; an adaptive session replans at
the first shock.
"""

from __future__ import annotations

import numpy as np

from ...cep.dsl import P
from .base import Scenario, Segment

__all__ = ["make"]

AUTH, PURCHASE, XFER = 0, 1, 2

_CONTROL_RATES = np.array([0.5, 1.6, 4.5])
_SHOCK1_RATES = np.array([4.5, 3.2, 0.35])
_SHOCK2_RATES = np.array([5.5, 4.0, 0.25])
# Baseline amounts drift *down* the ladder (escalation is rare); campaign
# amounts escalate, so the chain predicates open up exactly when the rate
# order inverts — selectivity and rate drift together, like the paper's
# real regimes.
_ATTR_MEAN = np.array([[0.0], [-0.4], [-0.8]])
_SHOCK1_ATTR = np.array([[0.2], [0.7], [1.2]])
_SHOCK2_ATTR = np.array([[0.4], [1.0], [1.6]])


def _pattern():
    return (P.seq(AUTH, PURCHASE, XFER)
            .where(P.attr(0) < P.attr(1) - 0.4,
                   P.attr(1) < P.attr(2) - 0.4)
            .within(4.0))


def _trajectory(partition: int, seed: int, sc: Scenario):
    warm, control, campaign = sc.segments
    for _ in range(warm.n_chunks + control.n_chunks):
        yield _CONTROL_RATES, _ATTR_MEAN
    second = campaign.n_chunks // 2
    for i in range(campaign.n_chunks):
        if i >= second:
            yield _SHOCK2_RATES, _SHOCK2_ATTR
        else:
            yield _SHOCK1_RATES, _ATTR_MEAN


def make() -> Scenario:
    return Scenario(
        name="fraud",
        description="card-testing ladder sequences; a fraud campaign "
                    "lands as two abrupt shocks inverting probe/transfer "
                    "rates and shifting amount selectivities",
        pattern_factory=_pattern,
        partitions=1,
        n_types=3,
        segments=(Segment("warmup", 8, "none"),
                  Segment("baseline", 24, "control"),
                  Segment("campaign", 48, "drift")),
        trajectory_factory=_trajectory,
        runtime=dict(buffer_capacity=64, match_capacity=128,
                     estimator_buckets=8,
                     policy="invariant", policy_kw={"k": 1, "d": 0.1}),
        expected=dict(control_replans=0, min_drift_deployments=1,
                      drift_kind="shock"),
        chunk_duration=1.0,
        chunk_cap=256,
        rate_scale=3.0,
    )
