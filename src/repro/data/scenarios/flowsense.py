"""FlowSense-style multi-tenant IoT telemetry with per-tenant alert rules.

Models FlowSense's rule table: each tenant (partition, K = 4) watches for
an unacknowledged environmental alert chain — a temperature spike, *no*
operator acknowledgement, then a humidity drop followed by a gas alarm,
inside one reporting window.  The acknowledgement is the pattern's negated
element: its presence vetoes the alert.

Statistical design: in steady state spikes are rare, routine gas-sensor
chatter dominates, and acks are plentiful — the cold-start plan (seed on
the rare spike) stays optimal and the control gate demands silence.  A
staggered firmware rollout then degrades tenants one by one (tenant ``p``
regresses ``p`` stagger-steps into the drift segment): spike rates jump
~9x, chatter thins, and acks nearly vanish.  Each tenant's invariant row
must fire at *its own* rollout step — per-partition adaptation, not a
global replan — and the pinned plan, still seeding on now-dominant spikes,
overflows its match set.
"""

from __future__ import annotations

import numpy as np

from ...cep.dsl import P
from .base import Scenario, Segment

__all__ = ["make", "rulebook_patterns"]

TEMP, HUMID, GAS, ACK = 0, 1, 2, 3

_CONTROL_RATES = np.array([0.5, 1.6, 4.5, 2.0])
_ROLLOUT_RATES = np.array([4.5, 3.2, 0.45, 0.15])
# In steady state the readings sit in the "calm" order (spike mild,
# humidity nominal, gas low) so the ascending alert chain rarely closes;
# the rollout regression pushes the faulty fleet's readings up together.
_ATTR_MEAN = np.array([[0.4], [0.0], [-0.5], [0.0]])
_ROLLOUT_ATTR = np.array([[0.2], [0.4], [0.6], [0.0]])


def _pattern():
    return (P.seq(TEMP, P.neg(ACK), HUMID, GAS)
            .where(P.attr(0) < P.attr(1) + 0.3,
                   P.attr(1) < P.attr(2) + 0.3)
            .within(3.0))


def _ack_pattern():
    # The benign counterpart of the alert: a spike the operator
    # acknowledged inside the reporting window.  Seeds on the rare spike,
    # so the cold plan stays optimal through the control segment.
    return P.seq(TEMP, ACK).within(3.0)


def _combo_pattern():
    # Fraud-style combo: humidity drop and gas alarm co-occurring (either
    # order) with ascending readings — the cross-sensor correlation rule
    # a tenant layers on top of the alert chain.
    return (P.and_(HUMID, GAS)
            .where(P.attr(0) < P.attr(1) + 0.3)
            .within(2.0))


def rulebook_patterns():
    """The 3-rule tenant rulebook (alert + ack + fraud-combo) used by the
    rulebook replay tie-in; rule 0 is the scenario's gated alert chain."""
    return [_pattern(), _ack_pattern(), _combo_pattern()]


def _trajectory(partition: int, seed: int, sc: Scenario):
    # Tenants carry Zipf-ish volume skew; the rollout reaches tenant p
    # after p stagger-steps so flags must fire per-partition.
    vol = 1.0 / (1.0 + 0.2 * partition)
    warm, control, rollout = sc.segments
    stagger = max(1, rollout.n_chunks // 8)
    onset = partition * stagger
    for _ in range(warm.n_chunks + control.n_chunks):
        yield _CONTROL_RATES * vol, _ATTR_MEAN
    for i in range(rollout.n_chunks):
        if i >= onset:
            yield _ROLLOUT_RATES * vol, _ROLLOUT_ATTR
        else:
            yield _CONTROL_RATES * vol, _ATTR_MEAN


def make() -> Scenario:
    return Scenario(
        name="flowsense",
        description="multi-tenant IoT alert rules (negated ack) under a "
                    "staggered firmware rollout that inverts per-tenant "
                    "sensor statistics",
        pattern_factory=_pattern,
        partitions=4,
        n_types=4,
        segments=(Segment("warmup", 8, "none"),
                  Segment("steady", 24, "control"),
                  Segment("rollout", 48, "drift")),
        trajectory_factory=_trajectory,
        runtime=dict(buffer_capacity=64, match_capacity=128,
                     estimator_buckets=8,
                     policy="invariant", policy_kw={"k": 1, "d": 0.1}),
        expected=dict(control_replans=0, min_drift_deployments=4,
                      drift_kind="staggered-step"),
        chunk_duration=1.0,
        chunk_cap=256,
        rate_scale=1.5,
    )
