"""Real-workload scenario adapters (distribution-matched, deterministic).

Three workloads shaped after real deployments — CitiBike hot-path Kleene
chains, FlowSense multi-tenant IoT alert rules, and the paper's fraud
sequence domain — each a :class:`~repro.data.scenarios.base.Scenario`
record: pattern(s) in ``P`` DSL form, per-partition padded chunk streams,
ground-truth drift trajectories, segment structure, and the expected-
adaptivity metadata that ``benchmarks/replay_bench.py`` turns into gates.
"""

from .base import Scenario, Segment
from . import citibike, flowsense, fraud

__all__ = ["Scenario", "Segment", "SCENARIOS", "get", "names"]

_FACTORIES = {
    "citibike": citibike.make,
    "flowsense": flowsense.make,
    "fraud": fraud.make,
}


def names():
    return list(_FACTORIES)


def get(name: str) -> Scenario:
    try:
        return _FACTORIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; available: {sorted(_FACTORIES)}"
        ) from None


SCENARIOS = tuple(_FACTORIES)
