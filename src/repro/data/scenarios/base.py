"""Scenario records: real-workload shapes as deterministic, gated streams.

A ``Scenario`` packages everything the replay harness and the differential
tests need to treat a real-world workload as a regression artifact:

* the pattern(s) in ``P`` DSL form (built lazily so a scenario module
  import never touches jax);
* a deterministic per-partition chunk stream (padded ``Chunk``s via
  ``data.cep_streams.emit_chunk``), fully reproducible from
  ``(seed, partition)``;
* the ground-truth drift trajectory — the exact per-chunk true rates and
  attribute means the emitter sampled from, separable from the event noise
  so tests can assert stationarity/drift structurally;
* segment structure (warmup → control → drift) with per-segment gate
  roles, and expected-adaptivity metadata consumed by
  ``benchmarks/replay_bench.py``'s self-gates.

The three bundled scenarios (``citibike``, ``flowsense``, ``fraud``) share
one statistical design: the *control* segment keeps the cold-start
(uniform-prior) plan optimal with a wide margin, so a correct invariant
policy must stay silent there (the paper's no-false-positives claim as a
gate), while every *drift* segment inverts the rate order so the pinned
cold plan seeds on the now-dominant type and blows through the match
capacity — the cost adaptivity exists to avoid.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from ..cep_streams import ChunkRecord, emit_chunk

__all__ = ["Segment", "Scenario", "Trajectory"]

# One trajectory step: (true_rates (n_types,), attr_mean (n_types, n_attrs))
Trajectory = Iterator[Tuple[np.ndarray, np.ndarray]]


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous run of chunks with one gate role.

    ``gate``:
      * ``"none"``    — warmup: rings fill, compile happens, nothing gated;
      * ``"control"`` — stationary: adaptive sessions must report zero
        replans (false-positive gate);
      * ``"drift"``   — statistics invert: adaptive throughput must be >=
        the pinned-static baseline's (adaptivity-win gate).
    """

    name: str
    n_chunks: int
    gate: str = "none"

    def __post_init__(self):
        if self.gate not in ("none", "control", "drift"):
            raise ValueError(f"unknown segment gate {self.gate!r}")

    @property
    def drifting(self) -> bool:
        return self.gate == "drift"


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One distribution-matched real-workload adapter (see module doc)."""

    name: str
    description: str
    pattern_factory: Callable[[], object]   # () -> P builder
    partitions: int                         # production-shaped K
    n_types: int
    segments: Tuple[Segment, ...]
    trajectory_factory: Callable[[int, int, "Scenario"], Trajectory]
    runtime: Dict[str, object]              # tuned RuntimeConfig kwargs
    expected: Dict[str, object]             # adaptivity metadata (gates)
    chunk_duration: float = 1.0
    chunk_cap: int = 256
    n_attrs: int = 1
    # Nominal event-volume multiplier, tuned per scenario so the drifting
    # segment sits where adaptivity pays: the cold plan's candidates blow
    # through the match capacity while true matches still fit the adapted
    # plan's base shape.  ``stream(rate_scale=...)`` multiplies on top.
    rate_scale: float = 1.0

    # -- structure ----------------------------------------------------------

    @property
    def pattern(self):
        return self.pattern_factory()

    @property
    def n_chunks(self) -> int:
        return sum(s.n_chunks for s in self.segments)

    def segment_slices(self) -> List[Tuple[Segment, int, int]]:
        """``[(segment, start_chunk, stop_chunk), ...]`` in stream order."""
        out, start = [], 0
        for seg in self.segments:
            out.append((seg, start, start + seg.n_chunks))
            start += seg.n_chunks
        return out

    # -- ground truth -------------------------------------------------------

    def trajectory(self, partition: int = 0, *, seed: int = 0,
                   chunks: Optional[int] = None) -> Trajectory:
        """The exact (rates, attr_mean) sequence the emitter will use —
        the scenario's ground-truth drift trajectory, free of event noise.
        """
        it = self.trajectory_factory(partition, seed, self)
        return itertools.islice(it, chunks) if chunks is not None else it

    def drift_trajectory(self, partition: int = 0, *, seed: int = 0,
                         chunks: Optional[int] = None) -> np.ndarray:
        """Stacked true rates, shape ``(n_chunks, n_types)``."""
        return np.stack([r for r, _ in self.trajectory(
            partition, seed=seed, chunks=chunks)])

    # -- event streams ------------------------------------------------------

    def stream(self, partition: int = 0, *, seed: int = 0,
               rate_scale: float = 1.0, chunk_cap: Optional[int] = None,
               chunks: Optional[int] = None) -> Iterator[ChunkRecord]:
        """Deterministic padded chunk stream for one partition.

        The trajectory rng and the event-noise rng are split so the
        ground truth from :meth:`trajectory` matches this stream exactly.
        ``rate_scale`` scales event volume *relative to the scenario's
        nominal* ``self.rate_scale`` without changing the statistics the
        planner sees; ``chunks`` truncates (tests run a short prefix
        through the brute-force oracle).
        """
        cap = self.chunk_cap if chunk_cap is None else int(chunk_cap)
        scale = self.rate_scale * rate_scale
        ev_rng = np.random.default_rng(
            (seed * 1_000_003 + partition * 7919 + 1) % (2 ** 63))
        traj = self.trajectory(partition, seed=seed, chunks=chunks)
        t0 = 0.0
        for rates, attr_mean in traj:
            yield emit_chunk(ev_rng, rates * scale, attr_mean, t0,
                             chunk_duration=self.chunk_duration,
                             chunk_cap=cap, n_attrs=self.n_attrs)
            t0 += self.chunk_duration

    def streams(self, k: Optional[int] = None, **kw
                ) -> List[Iterator[ChunkRecord]]:
        """K per-partition streams (defaults to the scenario's native K),
        in the shape ``Session.run`` accepts directly."""
        k = self.partitions if k is None else int(k)
        return [self.stream(p, **kw) for p in range(k)]

    def segment_streams(self, k: Optional[int] = None, *, seed: int = 0,
                        rate_scale: float = 1.0,
                        chunk_cap: Optional[int] = None,
                        chunks_scale: float = 1.0,
                        ) -> List[Tuple[Segment, List[List[ChunkRecord]]]]:
        """Materialize the stream split by segment: ``[(segment,
        [per-partition chunk lists]), ...]``.

        ``chunks_scale`` stretches every segment's length (the replay
        driver's --quick/full knob); segment boundaries stay aligned with
        the trajectory because scaling happens in the trajectory factory's
        view of the scenario, i.e. here, by re-slicing the same stream.
        """
        k = self.partitions if k is None else int(k)
        lengths = [max(1, int(round(s.n_chunks * chunks_scale)))
                   for s in self.segments]
        scaled = dataclasses.replace(self, segments=tuple(
            dataclasses.replace(s, n_chunks=n)
            for s, n in zip(self.segments, lengths)))
        streams = [scaled.stream(p, seed=seed, rate_scale=rate_scale,
                                 chunk_cap=chunk_cap) for p in range(k)]
        out = []
        for seg, n in zip(scaled.segments, lengths):
            out.append((seg, [list(itertools.islice(s, n))
                              for s in streams]))
        return out
