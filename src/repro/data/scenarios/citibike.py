"""CitiBike-style hot-path chains: Kleene ride sequences with a rush surge.

Models the CS-E4780 course workload: detect an *unlock* of a promoted
bike class, followed by a bounded run of *ride* telemetry pings (Kleene
closure), closed by a *dock* — all within a short trip window.  Partitions
are station groups (K = 2).

Statistical design (shared across the suite, see ``base``): off-peak the
promoted unlocks are rare and docks dominate, which keeps the cold-start
plan — seed on the unlock, the rarest type — optimal, so a sound invariant
policy stays silent (zero-replan control gate).  The evening rush ramps
unlocks and ride pings up ~8x while dock events thin out (bikes pile up
downtown): the rate order inverts, the pinned cold plan now seeds on the
most frequent type and its Kleene join overflows the match set, while an
adaptive session flags the inversion during the ramp and re-seeds on the
now-rare dock events.
"""

from __future__ import annotations

import numpy as np

from ...cep.dsl import P
from .base import Scenario, Segment

__all__ = ["make"]

UNLOCK, RIDE, DOCK = 0, 1, 2

_CONTROL_RATES = np.array([0.5, 1.8, 4.5])
_RUSH_RATES = np.array([4.5, 3.5, 0.5])
# Stationary attribute regime: trip attributes (e.g. battery level along
# the hot path) descend, so the ascending chain predicate keeps matches
# rare — the rush drifts *rates*, which is what inverts the plan space.
_ATTR_MEAN = np.array([[0.6], [0.0], [-0.6]])
_RAMP = 6  # chunks of linear ramp into the rush regime


def _pattern():
    return (P.seq(UNLOCK, P.kleene(RIDE, bound=3), DOCK)
            .where(P.attr(0) < P.attr(1) + 0.4,
                   P.attr(1) < P.attr(2) + 0.4)
            .within(3.0))


def _trajectory(partition: int, seed: int, sc: Scenario):
    # Station groups differ in volume, not in rate *order* — the plan
    # space is shared, the statistics are per-partition.
    vol = 1.0 + 0.15 * partition
    warm, control, rush = sc.segments
    for _ in range(warm.n_chunks + control.n_chunks):
        yield _CONTROL_RATES * vol, _ATTR_MEAN
    for i in range(rush.n_chunks):
        f = min(1.0, (i + 1) / _RAMP)
        yield ((1 - f) * _CONTROL_RATES + f * _RUSH_RATES) * vol, _ATTR_MEAN


def make() -> Scenario:
    return Scenario(
        name="citibike",
        description="Kleene hot-path trip chains with an evening rush "
                    "surge inverting the unlock/dock rate order",
        pattern_factory=_pattern,
        partitions=2,
        n_types=3,
        segments=(Segment("warmup", 8, "none"),
                  Segment("offpeak", 24, "control"),
                  Segment("rush", 48, "drift")),
        trajectory_factory=_trajectory,
        runtime=dict(buffer_capacity=64, match_capacity=128,
                     estimator_buckets=8,
                     policy="invariant", policy_kw={"k": 1, "d": 0.1}),
        expected=dict(control_replans=0, min_drift_deployments=2,
                      drift_kind="ramp"),
        chunk_duration=1.0,
        chunk_cap=256,
        rate_scale=1.5,
    )
