"""Synthetic event-stream generators matching the paper's two data regimes.

The paper evaluates on two real-world datasets whose *statistical regimes*
drive all of its findings (§5.1):

* **traffic** (City of Aarhus vehicle sensors): arrival rates and
  selectivities are *highly skewed and stable*, with *rare but extreme*
  on-the-fly changes.
* **stocks** (NASDAQ per-minute price updates): *near-uniform* statistics
  with *frequent but minor* drift.

This container is offline, so we reproduce those regimes with
distribution-matched generators (DESIGN.md §2).  Every generator is fully
deterministic given its seed, emits fixed-capacity padded chunks (static
shapes for the jitted engine) and exposes its ground-truth rate trajectory
for debugging and tests.

Attributes: each event carries ``n_attrs`` float attributes drawn around a
per-type mean that drifts with the regime; predicate selectivities therefore
drift together with the attribute means, exactly like the real datasets
(speed/vehicle-count correlations; stock price diffs).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np

from ..core.engine import Chunk


@dataclasses.dataclass
class StreamConfig:
    n_types: int = 3
    n_attrs: int = 1
    chunk_duration: float = 1.0
    chunk_cap: int = 512           # padded chunk capacity (static shape)
    n_chunks: int = 500
    seed: int = 0
    base_rate: float = 30.0        # mean total events per time unit
    # traffic regime
    zipf_s: float = 1.4            # rate skew exponent
    shift_every: float = 120.0     # mean time between regime shifts
    shift_magnitude: float = 8.0   # multiplicative shock size
    # stocks regime
    walk_sigma: float = 0.02       # per-chunk log-rate random-walk step
    attr_walk_sigma: float = 0.03  # per-chunk attribute-mean drift


@dataclasses.dataclass
class ChunkRecord:
    chunk: Chunk          # padded, masked
    t0: float
    t1: float
    counts: np.ndarray    # (n_types,) true per-type event counts
    true_rates: np.ndarray

    @property
    def n_events(self) -> int:
        return int(self.counts.sum())


def emit_chunk(rng, rates, attr_mean, t0, *, chunk_duration: float = 1.0,
               chunk_cap: int = 512, n_attrs: int = 1,
               attr_sigma: float = 1.0) -> ChunkRecord:
    """Emit one padded chunk of Poisson arrivals at the given true rates.

    The shared emission kernel behind every generator in this module and
    the scenario adapters (``data.scenarios``): per-type Poisson counts
    over ``chunk_duration``, uniform timestamps within the slice, types
    interleaved over time, attributes Gaussian around ``attr_mean`` —
    fully deterministic given ``rng``.  ``rates`` has shape ``(n_types,)``
    and ``attr_mean`` ``(n_types, n_attrs)``; the true rates ride along in
    the record as the ground-truth drift trajectory.
    """
    t1 = t0 + chunk_duration
    n_types = len(rates)
    counts = rng.poisson(np.asarray(rates, np.float64) * chunk_duration)
    total = int(counts.sum())
    cap = chunk_cap
    if total > cap:  # clip proportionally, keeping determinism
        scale = cap / total
        counts = np.floor(counts * scale).astype(counts.dtype)
        total = int(counts.sum())
    type_id = np.repeat(np.arange(n_types, dtype=np.int32), counts)
    ts = np.sort(rng.uniform(t0, t1, total)).astype(np.float32)
    order = rng.permutation(total)  # interleave types over time
    type_id = type_id[order]
    attrs = (np.asarray(attr_mean, np.float64)[type_id]
             + rng.normal(0, attr_sigma, (total, n_attrs))).astype(np.float32)
    # pad to capacity
    pad = cap - total
    type_id = np.concatenate([type_id, np.full(pad, -1, np.int32)])
    ts = np.concatenate([ts, np.zeros(pad, np.float32)])
    attrs = np.concatenate([attrs, np.zeros((pad, n_attrs), np.float32)])
    valid = np.concatenate([np.ones(total, bool), np.zeros(pad, bool)])
    return ChunkRecord(
        chunk=Chunk(type_id, ts, attrs, valid),
        t0=float(t0), t1=float(t1),
        counts=counts.astype(np.float64),
        true_rates=np.asarray(rates, np.float64).copy(),
    )


def _emit(rng, cfg: StreamConfig, rates, attr_mean, t0) -> ChunkRecord:
    return emit_chunk(rng, rates, attr_mean, t0,
                      chunk_duration=cfg.chunk_duration,
                      chunk_cap=cfg.chunk_cap, n_attrs=cfg.n_attrs)


def traffic_stream(cfg: StreamConfig) -> Iterator[ChunkRecord]:
    """High skew, stable, rare extreme shifts (Aarhus-like)."""
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_types
    # Zipf-skewed base rates, normalized to base_rate total.
    raw = 1.0 / np.arange(1, n + 1, dtype=np.float64) ** cfg.zipf_s
    rng.shuffle(raw)
    rates = raw / raw.sum() * cfg.base_rate
    attr_mean = rng.normal(0, 1.0, (n, cfg.n_attrs))
    t = 0.0
    next_shift = rng.exponential(cfg.shift_every)
    for _ in range(cfg.n_chunks):
        if t >= next_shift:
            # Extreme shock: pick two types and swap + rescale their rates;
            # shift one attribute mean far enough to flip selectivities.
            i, j = rng.choice(n, 2, replace=False)
            rates[i], rates[j] = rates[j] * cfg.shift_magnitude, \
                rates[i] / cfg.shift_magnitude
            rates = rates / rates.sum() * cfg.base_rate
            k = rng.integers(n)
            attr_mean[k] += rng.normal(0, 2.0, cfg.n_attrs)
            next_shift = t + rng.exponential(cfg.shift_every)
        yield _emit(rng, cfg, rates, attr_mean, t)
        t += cfg.chunk_duration


def stocks_stream(cfg: StreamConfig) -> Iterator[ChunkRecord]:
    """Near-uniform rates, frequent small random-walk drift (NASDAQ-like)."""
    rng = np.random.default_rng(cfg.seed)
    n = cfg.n_types
    # Nearly identical initial rates (paper: "initial values nearly
    # identical for all event types").
    log_rates = np.log(np.full(n, cfg.base_rate / n)) \
        + rng.normal(0, 0.01, n)
    attr_mean = rng.normal(0, 0.1, (n, cfg.n_attrs))
    t = 0.0
    for _ in range(cfg.n_chunks):
        log_rates += rng.normal(0, cfg.walk_sigma, n)
        # soft renormalization keeps total rate bounded
        log_rates -= (log_rates.mean() - np.log(cfg.base_rate / n)) * 0.05
        attr_mean += rng.normal(0, cfg.attr_walk_sigma, (n, cfg.n_attrs))
        rates = np.exp(log_rates)
        yield _emit(rng, cfg, rates, attr_mean, t)
        t += cfg.chunk_duration


def make_stream(kind: str, cfg: StreamConfig) -> Iterator[ChunkRecord]:
    if kind == "traffic":
        return traffic_stream(cfg)
    if kind == "stocks":
        return stocks_stream(cfg)
    raise ValueError(f"unknown stream kind {kind!r}")
