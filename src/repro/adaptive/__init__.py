"""The paper's invariant-based reoptimization as a *framework* feature.

A large training/serving system has exactly the paper's problem shape: an
expensive deterministic plan generator (expert placement + recompile /
batch-plan rebuild) driven by drifting runtime statistics (expert routing
loads, request-class arrival rates).  These governors port the paper's
decision machinery verbatim — greedy plan generation with block-building
comparison capture, tightest-condition invariants, distance-d damping — so
Theorem 1's no-false-positive guarantee applies to recompilation decisions.
"""

from .placement import ExpertPlacementGovernor  # noqa: F401
from .batching import AdaptiveBatchPlanner  # noqa: F401
