"""Invariant-governed adaptive serving batch plans.

Serving-side instance of the paper's problem:

* **statistics** — arrival rates of request *classes* (sequence-length
  buckets); the serving analogue of event-type arrival rates.
* **plan** — the order in which classes claim slots of the fixed token
  budget of a decode batch (a greedy packing order).  The plan determines
  which bucketed batch shapes stay compiled/warm; changing it means
  compiling new shapes and draining in-flight batches — the deployment
  cost.
* **generator ``A``** — greedy: classes in decreasing ``rate × tokens``
  (work-demand) order.  Each comparison the winner survives is a BBC;
  conditions are single-product ``rate[i]·tokens_i`` terms, directly the
  paper's §4.1 shape (tokens_i acts as the per-type constant factor).

The planner re-plans only on invariant violation — e.g. a burst of long
prompts flips a ``demand(long) < demand(short)`` invariant and promotes
the long-class bucket in the packing order.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.decision import InvariantPolicy
from ..core.invariants import DCSList, DecidingCondition
from ..core.plans import Expr
from ..core.stats import Stat


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """Packing priority over request classes + per-class slot quotas."""

    order: Tuple[int, ...]
    quotas: Tuple[int, ...]      # slots per class in one assembly round


def _stat(rates: np.ndarray) -> Stat:
    n = rates.shape[0]
    return Stat(rates=np.asarray(rates, np.float64),
                sel=np.ones((n, n), np.float64))


def greedy_batch_plan(rates: np.ndarray, class_tokens: Sequence[int],
                      token_budget: int) -> Tuple[BatchPlan, DCSList]:
    """Deterministic greedy packing-order generator with BBC capture."""
    n = rates.shape[0]
    demand = [float(rates[i]) * class_tokens[i] for i in range(n)]
    remaining = list(range(n))
    order: List[int] = []
    dcs_list: DCSList = []
    for step in range(n):
        win = max(remaining, key=lambda i: (demand[i], -i))
        block = f"rank{step}:class{win}"
        w_expr = (Expr(rate_idx=(win,), scale=class_tokens[win]),)
        conds = [
            DecidingCondition.make(
                (Expr(rate_idx=(i,), scale=class_tokens[i]),),
                w_expr, block)
            for i in remaining if i != win
        ]
        dcs_list.append((block, conds))
        order.append(win)
        remaining.remove(win)

    # Quotas: proportional to demand in plan order, greedy water-filling.
    quotas = [0] * n
    budget = token_budget
    total = sum(demand) or 1.0
    for i in order:
        q = int(round(token_budget * demand[i] / total
                      / max(class_tokens[i], 1)))
        q = max(q, 1)
        q = min(q, budget // max(class_tokens[i], 1))
        quotas[i] = q
        budget -= q * class_tokens[i]
    return BatchPlan(tuple(order), tuple(quotas)), dcs_list


class AdaptiveBatchPlanner:
    """Detection-adaptation loop for serving batch assembly."""

    def __init__(self, class_tokens: Sequence[int], token_budget: int,
                 *, k: int = 1, d: float = 0.15, ema: float = 0.8):
        self.class_tokens = tuple(class_tokens)
        self.token_budget = token_budget
        self.ema = ema
        self.policy = InvariantPolicy(k=k, d=d)
        self._rates: Optional[np.ndarray] = None
        self.plan: Optional[BatchPlan] = None
        self.replans = 0
        self.deployments = 0

    def _replan(self) -> Optional[BatchPlan]:
        new_plan, dcs = greedy_batch_plan(
            self._rates, self.class_tokens, self.token_budget)
        self.policy.on_replan(new_plan, dcs, _stat(self._rates))
        if self.plan is None or new_plan.order != self.plan.order:
            self.plan = new_plan
            self.deployments += 1
            return new_plan
        return None

    def observe(self, class_counts: np.ndarray) -> Optional[BatchPlan]:
        """Feed one scheduling tick's per-class arrival counts."""
        class_counts = np.asarray(class_counts, np.float64)
        if self._rates is None:
            self._rates = class_counts + 1e-6
            self.replans += 1
            return self._replan()
        self._rates = self.ema * self._rates + (1 - self.ema) * class_counts
        if self.policy.decide(_stat(self._rates)):
            self.replans += 1
            return self._replan()
        return None
