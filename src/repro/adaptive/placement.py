"""Invariant-governed adaptive MoE expert placement.

Problem (the paper's shape, §4.3 "any greedy algorithm"):

* **statistics** — measured per-expert token loads (EMA over train steps);
  these play the role of the paper's event arrival rates.
* **plan** — an assignment of the ``E`` logical experts to the ``G``
  expert-parallel device groups (the ``model`` mesh axis).  A skewed
  assignment makes the hottest group the straggler of every MoE layer.
* **generator ``A``** — deterministic LPT (longest-processing-time) greedy:
  experts in decreasing load order, each to the currently lightest group.
  Every "group g is lighter than group g'" comparison that the winning
  group survives is a block-building comparison; its deciding condition
  ``sum(loads of g) < sum(loads of g')`` joins the step's DCS.  Sums of
  loads are exactly the ``ExprSum`` sides of ``core.invariants`` (each
  expert load is one product term ``rate[e]``), so the paper's machinery
  applies unchanged.
* **deployment cost** — relabeling experts means permuting the expert-
  indexed weight rows across devices (an all-to-all of expert weights) and
  re-entering the jitted step; this is why unconditional re-placement every
  step is exactly the over-adaptation failure mode of [36].

The governor verifies the invariant list every ``check_every`` steps and
triggers a re-placement only on violation (distance-``d`` damped).
Theorem 1 transfers: a violation guarantees LPT produces a *different*
assignment.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from ..core.decision import InvariantPolicy
from ..core.invariants import DCSList, DecidingCondition
from ..core.plans import Expr
from ..core.stats import Stat


@dataclasses.dataclass(frozen=True)
class Placement:
    """perm[logical_expert] = physical slot; group = slot // (E // G)."""

    perm: Tuple[int, ...]
    groups: Tuple[Tuple[int, ...], ...]   # group -> logical expert ids

    @property
    def n_experts(self) -> int:
        return len(self.perm)


def _load_stat(loads: np.ndarray) -> Stat:
    """Wrap per-expert loads as the paper's Stat (rates only)."""
    e = loads.shape[0]
    return Stat(rates=np.asarray(loads, np.float64),
                sel=np.ones((e, e), np.float64))


def lpt_placement(loads: np.ndarray, n_groups: int
                  ) -> Tuple[Placement, DCSList]:
    """Deterministic LPT with BBC capture.

    Ties break toward the lower expert id / lower group id, keeping the
    generator a deterministic function of the statistics (Theorem 1's
    requirement).
    """
    e = loads.shape[0]
    assert e % n_groups == 0, (e, n_groups)
    cap = e // n_groups
    order = sorted(range(e), key=lambda i: (-float(loads[i]), i))
    group_members: List[List[int]] = [[] for _ in range(n_groups)]
    group_load = np.zeros(n_groups)
    dcs_list: DCSList = []

    # The descending sort is itself a sequence of block-building
    # comparisons (the paper's min-sort example, §3.1): the expert at rank
    # r beat every not-yet-ranked expert.  Omitting these conditions makes
    # order flips invisible — a false-negative class caught by
    # tests/test_adaptive.py::test_governor_reacts_to_shift.
    for r, ex in enumerate(order):
        block = f"rank{r}:e{ex}"
        conds = [
            DecidingCondition.make(
                (Expr(rate_idx=(j,)),), (Expr(rate_idx=(ex,)),), block)
            for j in order[r + 1:]
        ]
        dcs_list.append((block, conds))

    for step, ex in enumerate(order):
        open_groups = [g for g in range(n_groups)
                       if len(group_members[g]) < cap]
        win = min(open_groups,
                  key=lambda g: (float(group_load[g]), g))
        block = f"assign{step}:e{ex}->g{win}"
        win_sum = tuple(Expr(rate_idx=(i,)) for i in group_members[win]) \
            or (Expr(scale=0.0),)
        conds = []
        for g in open_groups:
            if g == win:
                continue
            other = tuple(Expr(rate_idx=(i,)) for i in group_members[g]) \
                or (Expr(scale=0.0),)
            conds.append(DecidingCondition.make(win_sum, other, block))
        dcs_list.append((block, conds))
        group_members[win].append(ex)
        group_load[win] += float(loads[ex])

    perm = [0] * e
    for g, members in enumerate(group_members):
        for slot, ex in enumerate(members):
            perm[ex] = g * cap + slot
    return Placement(tuple(perm),
                     tuple(tuple(m) for m in group_members)), dcs_list


def imbalance(loads: np.ndarray, placement: Placement) -> float:
    """max group load / mean group load (1.0 = perfect balance)."""
    gl = np.array([sum(loads[list(g)]) for g in placement.groups])
    mean = gl.mean()
    return float(gl.max() / mean) if mean > 0 else 1.0


class ExpertPlacementGovernor:
    """Detection-adaptation loop for expert placement (Algorithm 1 shape)."""

    def __init__(self, n_experts: int, n_groups: int, *, k: int = 1,
                 d: float = 0.1, ema: float = 0.9,
                 check_every: int = 1):
        self.n_experts = n_experts
        self.n_groups = n_groups
        self.ema = ema
        self.check_every = check_every
        self.policy = InvariantPolicy(k=k, d=d)
        self._loads: Optional[np.ndarray] = None
        self.placement: Optional[Placement] = None
        self._step = 0
        self.replans = 0
        self.deployments = 0
        self.false_positives = 0

    def _replan(self) -> Optional[Placement]:
        new_p, dcs = lpt_placement(self._loads, self.n_groups)
        self.policy.on_replan(new_p, dcs, _load_stat(self._loads))
        if self.placement is None or new_p.groups != self.placement.groups:
            self.placement = new_p
            self.deployments += 1
            return new_p
        self.false_positives += 1
        return None

    def observe(self, expert_load: np.ndarray) -> Optional[Placement]:
        """Feed one step's per-expert token counts (summed over layers).

        Returns a new Placement when (and only when) the invariant check
        demanded a re-plan that produced a different assignment.
        """
        expert_load = np.asarray(expert_load, np.float64)
        if self._loads is None:
            self._loads = expert_load + 1e-6
            self.replans += 1
            return self._replan()
        self._loads = self.ema * self._loads + (1 - self.ema) * expert_load
        self._step += 1
        if self._step % self.check_every:
            return None
        if self.policy.decide(_load_stat(self._loads)):
            self.replans += 1
            return self._replan()
        return None


def permute_expert_params(moe_params: dict, perm) -> dict:
    """Physically relocate expert weights to their new slots.

    ``perm[old_slot] = new_slot``; expert-major leaves (w_gate/w_up/w_down,
    first dim E) move so new slot ``perm[e]`` holds the expert previously
    at slot ``e``, and the router's output columns move with them (routing
    then addresses physical slots directly — no per-token indirection).
    On a real mesh this lowers to the expert-weight all-to-all that
    constitutes the deployment cost.

    Leading ``layers`` dims (stacked layer params) are handled because the
    expert axis is located by name, not position.
    """
    import jax.numpy as jnp
    perm = np.asarray(perm)
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    out = dict(moe_params)
    for k in ("w_gate", "w_up", "w_down"):
        out[k] = jnp.take(moe_params[k], inv, axis=-3)
    out["router"] = jnp.take(moe_params["router"], inv, axis=-1)
    return out


def relocation(cur_perm, new_perm) -> np.ndarray:
    """old physical slot -> new physical slot for a placement change."""
    cur = np.asarray(cur_perm)
    new = np.asarray(new_perm)
    inv_cur = np.empty_like(cur)
    inv_cur[cur] = np.arange(len(cur))
    return new[inv_cur]
