"""Distribution substrate: logical-axis sharding rules, mesh helpers,
gradient compression collectives."""

from .sharding import (  # noqa: F401
    MeshRules,
    current_rules,
    logical_constraint,
    logical_sharding,
    set_rules,
    use_rules,
)
