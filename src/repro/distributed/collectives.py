"""Distributed-optimization collectives: compressed gradient all-reduce.

``compressed_psum_tree``: int8-on-the-wire data-parallel gradient
all-reduce with error feedback.  A ring fp32 all-reduce moves ~8 bytes per
element (4 B reduce-scatter + 4 B all-gather).  We replace it with:

1. add the carried error-feedback residual to the local gradient;
2. quantize to int8 with a *shared* per-tensor scale (``pmax`` of local
   max-abs — one scalar hop);
3. **reduce-scatter via int8 ``all_to_all``** (1 B/element on the wire),
   summing the received shards locally in int32 — no accumulator overflow
   since 512 × 127 « 2³¹;
4. requantize the summed chunk to int8 with a second shared scale and
   **all-gather int8** (1 B/element);
5. dequantize; store the phase-1 quantization error into the residual
   (error feedback compensates it over subsequent steps).

Net wire cost ≈ 2 B/element — a 4× reduction, visible to the dry-run's
collective-bytes parser as ``all-to-all`` + ``all-gather`` of ``s8``
operands instead of ``f32`` all-reduce.  Built with ``shard_map`` so the
collectives are explicit in the lowered HLO.

This is a beyond-paper distributed-optimization feature (recorded in
EXPERIMENTS.md §Perf); default training keeps XLA's fp32 all-reduce.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _compressed_allreduce(x, ef, axis_name: str, n_shards: int):
    """x, ef: identical shape on every shard.  Returns (mean, new_ef)."""
    shape = x.shape
    size = x.size
    x = x.astype(jnp.float32).reshape(-1) + ef.reshape(-1)

    pad = (-size) % n_shards
    xp = jnp.pad(x, (0, pad))
    chunk = xp.size // n_shards

    # Phase 1: shared-scale int8 quantization.
    scale1 = jax.lax.pmax(jnp.max(jnp.abs(xp)) / 127.0, axis_name) + 1e-12
    q1 = jnp.clip(jnp.round(xp / scale1), -127, 127).astype(jnp.int8)
    deq_local = q1.astype(jnp.float32) * scale1
    new_ef = (x - deq_local[:size]).reshape(shape)

    # Phase 2: int8 reduce-scatter (all_to_all + local int32 sum).
    qs = q1.reshape(n_shards, chunk)
    recv = jax.lax.all_to_all(qs, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    ssum = recv.astype(jnp.int32).sum(axis=0)          # (chunk,) int32
    part = ssum.astype(jnp.float32) * scale1           # summed fp32 chunk

    # Phase 3: requantize + int8 all-gather.
    scale2 = jax.lax.pmax(jnp.max(jnp.abs(part)) / 127.0,
                          axis_name) + 1e-12
    q2 = jnp.clip(jnp.round(part / scale2), -127, 127).astype(jnp.int8)
    gathered = jax.lax.all_gather(q2, axis_name)       # (N, chunk) int8
    out = gathered.astype(jnp.float32).reshape(-1)[:size] * scale2
    return (out / n_shards).reshape(shape), new_ef


def compressed_psum_tree(grads, ef_tree, mesh: Mesh, axis: str = "data"
                         ) -> Tuple[Any, Any]:
    """Leaf-wise compressed all-reduce (mean) over mesh axis ``axis``.

    Gradients are expected replicated over the other mesh axes and holding
    per-shard partial sums along ``axis`` (the state right after a
    per-shard backward pass under shard_map-style DP).
    """
    n_shards = mesh.shape[axis]
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = (jax.tree.leaves(ef_tree) if ef_tree != () else
                 [jnp.zeros(l.shape, jnp.float32) for l in leaves])

    def body(*args):
        n = len(args) // 2
        gs, efs = args[:n], args[n:]
        outs, nefs = [], []
        for g, e in zip(gs, efs):
            o, ne = _compressed_allreduce(g, e, axis, n_shards)
            outs.append(o)
            nefs.append(ne)
        return tuple(outs) + tuple(nefs)

    specs = tuple(P() for _ in range(2 * len(leaves)))
    fn = shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs,
                   check_rep=False)
    res = fn(*leaves, *ef_leaves)
    n = len(leaves)
    return (jax.tree.unflatten(treedef, res[:n]),
            jax.tree.unflatten(treedef, res[n:]))
