"""Logical-axis sharding with divisibility fallback.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", "experts", …).  A ``MeshRules`` table maps logical names to physical
mesh axes; resolution checks divisibility and **falls back to replication**
on any axis that does not divide evenly (e.g. paligemma's 8 query heads or
its single KV head on a 16-way model axis).  Fallbacks are recorded so the
dry-run can report them per cell.

The default rule set implements the production layout of DESIGN.md §5:

* ``batch``    → ("pod", "data")   — data parallelism across pods and rows;
* ``embed``    → "data"            — FSDP: parameters' d_model dim sharded
                                      over the data axis (gathered per layer);
* ``heads`` / ``kv_heads`` / ``ff`` / ``experts`` / ``vocab`` → "model"
                                   — tensor/expert parallelism;
* ``seq``      → None              — sequence kept unsharded by default
                                      (sequence parallelism is opt-in via
                                      ``seq → "model"`` in §Perf experiments);
* activation ``act_embed`` → None  — activations replicated over model axis
                                      after collectives.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisVal = Union[None, str, Tuple[str, ...]]


DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "embed": "data",        # FSDP shard of parameter d_model dims
    "opt_embed": "data",    # ZeRO-1: optimizer-state d_model dims
    "heads": "model",
    "kv_heads": "model",
    "qkv_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_cap": None,
    "vocab": "model",
    "cache_seq": "model",   # decode KV caches: split-T (flash-decoding)
    "layers": None,
    "conv": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "frontend": None,
}


@dataclasses.dataclass
class MeshRules:
    mesh: Optional[Mesh]
    rules: Dict[str, AxisVal]
    fallbacks: List[str] = dataclasses.field(default_factory=list)

    def axis_size(self, phys: AxisVal) -> int:
        if phys is None or self.mesh is None:
            return 1
        if isinstance(phys, str):
            phys = (phys,)
        size = 1
        for a in phys:
            size *= self.mesh.shape.get(a, 1)
        return size

    def resolve(self, shape: Sequence[int],
                logical: Sequence[Optional[str]],
                tag: str = "") -> PartitionSpec:
        """Logical names -> PartitionSpec with divisibility fallback."""
        assert len(shape) == len(logical), (shape, logical, tag)
        out = []
        used: set = set()
        for dim, name in zip(shape, logical):
            if name is None:
                out.append(None)
                continue
            phys = self.rules.get(name)
            if phys is None:
                out.append(None)
                continue
            phys_t = (phys,) if isinstance(phys, str) else tuple(phys)
            # Drop mesh axes missing from the current mesh (e.g. "pod" on
            # the single-pod mesh) and axes already used by an earlier dim
            # of this tensor (a mesh axis may appear only once per spec —
            # e.g. MoE expert weights (E, D, F) map experts->model and must
            # then leave ff unsharded).
            dropped_dup = [a for a in phys_t
                           if self.mesh is not None
                           and a in self.mesh.shape and a in used]
            phys_t = tuple(a for a in phys_t
                           if (self.mesh is None or a in self.mesh.shape)
                           and a not in used)
            if dropped_dup:
                self.fallbacks.append(
                    f"{tag}: dim {dim} ({name}) axis {dropped_dup} already "
                    "used by an earlier dim -> replicated")
            size = self.axis_size(phys_t)
            if size <= 1:
                out.append(None)
            elif dim % size == 0:
                used.update(phys_t)
                out.append(phys_t[0] if len(phys_t) == 1 else phys_t)
            else:
                self.fallbacks.append(
                    f"{tag}: dim {dim} ({name}) not divisible by "
                    f"{phys_t} ({size}) -> replicated")
                out.append(None)
        return PartitionSpec(*out)

    def sharding(self, shape, logical, tag: str = "") -> NamedSharding:
        assert self.mesh is not None, "sharding requires an active mesh"
        return NamedSharding(self.mesh, self.resolve(shape, logical, tag))


_local = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_local, "rules", None)


def set_rules(rules: Optional[MeshRules]) -> None:
    _local.rules = rules


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh],
              overrides: Optional[Dict[str, AxisVal]] = None):
    """Activate a mesh + logical-rule table for model tracing."""
    table = dict(DEFAULT_RULES)
    if overrides:
        table.update(overrides)
    prev = current_rules()
    set_rules(MeshRules(mesh=mesh, rules=table))
    try:
        yield current_rules()
    finally:
        set_rules(prev)


def logical_constraint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.resolve(x.shape, logical, tag="activation")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, spec))


def logical_sharding(shape, logical, tag: str = "") -> Optional[NamedSharding]:
    r = current_rules()
    if r is None or r.mesh is None:
        return None
    return r.sharding(shape, logical, tag)
