"""Logical-axis sharding with divisibility fallback.

Model code annotates tensors with *logical* axis names ("batch", "heads",
"ff", "experts", …).  A ``MeshRules`` table maps logical names to physical
mesh axes; resolution checks divisibility and **falls back to replication**
on any axis that does not divide evenly (e.g. paligemma's 8 query heads or
its single KV head on a 16-way model axis).  Fallbacks are recorded so the
dry-run can report them per cell.

The default rule set implements the production layout of DESIGN.md §5:

* ``batch``    → ("pod", "data")   — data parallelism across pods and rows;
* ``embed``    → "data"            — FSDP: parameters' d_model dim sharded
                                      over the data axis (gathered per layer);
* ``heads`` / ``kv_heads`` / ``ff`` / ``experts`` / ``vocab`` → "model"
                                   — tensor/expert parallelism;
* ``seq``      → None              — sequence kept unsharded by default
                                      (sequence parallelism is opt-in via
                                      ``seq → "model"`` in §Perf experiments);
* activation ``act_embed`` → None  — activations replicated over model axis
                                      after collectives.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisVal = Union[None, str, Tuple[str, ...]]


DEFAULT_RULES: Dict[str, AxisVal] = {
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "embed": "data",        # FSDP shard of parameter d_model dims
    "opt_embed": "data",    # ZeRO-1: optimizer-state d_model dims
    "heads": "model",
    "kv_heads": "model",
    "qkv_dim": None,
    "ff": "model",
    "experts": "model",
    "expert_cap": None,
    "vocab": "model",
    "cache_seq": "model",   # decode KV caches: split-T (flash-decoding)
    "layers": None,
    "conv": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "ssm_heads": "model",
    "frontend": None,
    # CEP fleet: the leading K-partition axis of every data-plane tensor.
    # Partitions are independent streams, so this is the one logical axis
    # the CEP runtime shards; everything else stays replicated.
    "cep_partitions": "cep",
}


@dataclasses.dataclass
class MeshRules:
    mesh: Optional[Mesh]
    rules: Dict[str, AxisVal]
    fallbacks: List[str] = dataclasses.field(default_factory=list)

    def axis_size(self, phys: AxisVal) -> int:
        if phys is None or self.mesh is None:
            return 1
        if isinstance(phys, str):
            phys = (phys,)
        size = 1
        for a in phys:
            size *= self.mesh.shape.get(a, 1)
        return size

    def resolve(self, shape: Sequence[int],
                logical: Sequence[Optional[str]],
                tag: str = "") -> PartitionSpec:
        """Logical names -> PartitionSpec with divisibility fallback."""
        assert len(shape) == len(logical), (shape, logical, tag)
        out = []
        used: set = set()
        for dim, name in zip(shape, logical):
            if name is None:
                out.append(None)
                continue
            phys = self.rules.get(name)
            if phys is None:
                out.append(None)
                continue
            phys_t = (phys,) if isinstance(phys, str) else tuple(phys)
            # Drop mesh axes missing from the current mesh (e.g. "pod" on
            # the single-pod mesh) and axes already used by an earlier dim
            # of this tensor (a mesh axis may appear only once per spec —
            # e.g. MoE expert weights (E, D, F) map experts->model and must
            # then leave ff unsharded).
            dropped_dup = [a for a in phys_t
                           if self.mesh is not None
                           and a in self.mesh.shape and a in used]
            phys_t = tuple(a for a in phys_t
                           if (self.mesh is None or a in self.mesh.shape)
                           and a not in used)
            if dropped_dup:
                self.fallbacks.append(
                    f"{tag}: dim {dim} ({name}) axis {dropped_dup} already "
                    "used by an earlier dim -> replicated")
            size = self.axis_size(phys_t)
            if size <= 1:
                out.append(None)
            elif dim % size == 0:
                used.update(phys_t)
                out.append(phys_t[0] if len(phys_t) == 1 else phys_t)
            else:
                self.fallbacks.append(
                    f"{tag}: dim {dim} ({name}) not divisible by "
                    f"{phys_t} ({size}) -> replicated")
                out.append(None)
        return PartitionSpec(*out)

    def sharding(self, shape, logical, tag: str = "") -> NamedSharding:
        assert self.mesh is not None, "sharding requires an active mesh"
        return NamedSharding(self.mesh, self.resolve(shape, logical, tag))


_local = threading.local()


def current_rules() -> Optional[MeshRules]:
    return getattr(_local, "rules", None)


def set_rules(rules: Optional[MeshRules]) -> None:
    _local.rules = rules


@contextlib.contextmanager
def use_rules(mesh: Optional[Mesh],
              overrides: Optional[Dict[str, AxisVal]] = None):
    """Activate a mesh + logical-rule table for model tracing."""
    table = dict(DEFAULT_RULES)
    if overrides:
        table.update(overrides)
    prev = current_rules()
    set_rules(MeshRules(mesh=mesh, rules=table))
    try:
        yield current_rules()
    finally:
        set_rules(prev)


def logical_constraint(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; no-op without a mesh."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = r.resolve(x.shape, logical, tag="activation")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(r.mesh, spec))


def logical_sharding(shape, logical, tag: str = "") -> Optional[NamedSharding]:
    r = current_rules()
    if r is None or r.mesh is None:
        return None
    return r.sharding(shape, logical, tag)


# ---------------------------------------------------------------------------
# CEP fleet mesh layer
# ---------------------------------------------------------------------------
#
# The CEP data plane is a pytree whose every leaf leads with the K-partition
# axis (stacked ring buffers, monitor rings, plan rows, lowered invariant
# tensors, per-partition counters).  Partitions are fully independent
# streams, so the fleet maps onto a 1-D device mesh with ONE rule — split K
# over the "cep" axis, replicate the rest — and needs zero collectives.
# The rule lives in DEFAULT_RULES ("cep_partitions") so dry-runs and
# fallback reporting treat the CEP fleet like any other sharded workload.

CEP_AXIS = "cep"


def cep_mesh(n_devices: Optional[int] = None, devices=None) -> Mesh:
    """A 1-D mesh over local devices with the ``cep`` partition axis."""
    import numpy as np

    devs = list(devices if devices is not None else jax.devices())
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"mesh wants {n_devices} devices, only {len(devs)} present")
        devs = devs[:n_devices]
    return Mesh(np.asarray(devs), (CEP_AXIS,))


def resolve_cep_mesh(mesh, k: int) -> Optional[Mesh]:
    """Normalize the facade's ``mesh=`` config into a fleet mesh.

    Accepts ``None`` (no sharding), ``"auto"`` (all local devices), an
    ``int`` device count, or a prebuilt 1-D :class:`Mesh` carrying a
    ``cep`` axis.  The K-partition axis must divide evenly — an uneven
    split would silently unbalance per-partition semantics, so it raises
    (the logical-rule fallback-to-replication is for model weights, not
    for the stream data plane).
    """
    if mesh is None:
        return None
    if isinstance(mesh, Mesh):
        if CEP_AXIS not in mesh.shape:
            raise ValueError(
                f"fleet mesh must carry a {CEP_AXIS!r} axis; "
                f"got axes {tuple(mesh.shape)}")
        m = mesh
    elif mesh == "auto":
        m = cep_mesh()
    elif isinstance(mesh, int):
        m = cep_mesh(mesh)
    else:
        raise TypeError(f"mesh must be None, 'auto', an int device count "
                        f"or a jax Mesh; got {type(mesh).__name__}")
    d = m.shape[CEP_AXIS]
    if k % d != 0:
        raise ValueError(
            f"K={k} partitions do not divide over {d} devices; choose K "
            f"as a multiple of the mesh size")
    return m


def fleet_pspec(leading_k: bool = True) -> PartitionSpec:
    """The one CEP partition rule as a PartitionSpec tree prefix.

    ``leading_k=True`` shards a leaf's first axis over ``cep`` (state,
    plan rows, lowered tensors, per-partition outputs); ``False`` gives
    the scan layout — a leading superchunk axis, partitions second.
    """
    if leading_k:
        return PartitionSpec(CEP_AXIS)
    return PartitionSpec(None, CEP_AXIS)


def shard_fleet_fn(fn, mesh: Mesh):
    """``shard_map`` a per-chunk fleet step: every arg/out leads with K."""
    from jax.experimental.shard_map import shard_map

    return shard_map(fn, mesh=mesh, in_specs=fleet_pspec(),
                     out_specs=fleet_pspec(), check_rep=False)


def shard_fleet_scan(scan_fn, mesh: Mesh):
    """``shard_map`` the superchunk scan.

    Signature: ``scan_fn(buffers, monitor, cur_rows, old_rows, lowered,
    xs) -> (buffers, monitor, ys)``.  State/rows/lowered lead with K;
    ``xs``/``ys`` lead with (S, K) except the shared chunk clock and the
    ``enabled`` gate, which are replicated so every device gates the same
    chunks.  The body is collective-free (partitions are independent), so
    device-local ``lax.cond`` divergence — e.g. pass B running only on
    devices that own a migrating partition — is safe and free.
    """
    from jax.experimental.shard_map import shard_map

    from ..core.scan import SuperchunkXs

    k_led = fleet_pspec()
    sk_led = fleet_pspec(leading_k=False)
    rep = PartitionSpec()
    xs_spec = SuperchunkXs(
        chunk=sk_led, t0=rep, t1=rep, enabled=rep,
        born_lo=sk_led, migrating=sk_led, old_sel=sk_led)
    return shard_map(
        scan_fn, mesh=mesh,
        in_specs=(k_led, k_led, k_led, k_led, k_led, xs_spec),
        out_specs=(k_led, k_led, sk_led),
        check_rep=False)
