"""Continuous-batching scheduler with invariant-governed batch plans.

Requests queue per length-class (pow2 prompt buckets).  Each scheduling
tick the scheduler fills free batch slots following the current
``BatchPlan``'s class priority/quotas (``adaptive.batching``), prefills the
admitted prompts, then advances the whole batch one decode step.

The batch plan is re-generated only when a class-rate invariant is
violated — a rate flip between short and long prompt classes re-orders
admission without ever recompiling the decode step (slots are data).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from ..adaptive.batching import AdaptiveBatchPlanner
from .engine import CEPFleetServingEngine, ServingEngine


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new


class Scheduler:
    def __init__(self, engine: ServingEngine, class_tokens: List[int],
                 *, d: float = 0.15):
        self.engine = engine
        self.class_tokens = class_tokens
        self.planner = AdaptiveBatchPlanner(
            class_tokens, token_budget=engine.batch_slots * 64, d=d)
        self.queues: Dict[int, List[Request]] = {
            i: [] for i in range(len(class_tokens))}
        self.slots: List[Optional[Request]] = \
            [None] * engine.batch_slots
        self.completed: List[Request] = []
        self._tick_counts = np.zeros(len(class_tokens))

    def _class_of(self, plen: int) -> int:
        for i, t in enumerate(self.class_tokens):
            if plen <= t:
                return i
        return len(self.class_tokens) - 1

    def submit(self, req: Request) -> None:
        c = self._class_of(len(req.prompt))
        self.queues[c].append(req)
        self._tick_counts[c] += 1

    def tick(self) -> int:
        """One scheduling round: replan-if-needed, admit, decode."""
        self.planner.observe(self._tick_counts)
        self._tick_counts[:] = 0
        plan = self.planner.plan

        # Admit requests into free slots in plan order.
        free = [i for i, r in enumerate(self.slots) if r is None]
        order = plan.order if plan else range(len(self.class_tokens))
        for c in order:
            while free and self.queues[c]:
                req = self.queues[c].pop(0)
                slot = free.pop(0)
                first = self.engine.prefill_one(req.prompt, slot)
                req.out.append(first)
                req.slot = slot
                self.slots[slot] = req

        # One decode step for every occupied slot.
        tokens = np.zeros(self.engine.batch_slots, np.int32)
        active = False
        for i, r in enumerate(self.slots):
            if r is not None:
                tokens[i] = r.out[-1]
                active = True
        if active:
            nxt = self.engine.decode(tokens)
            for i, r in enumerate(self.slots):
                if r is None:
                    continue
                r.out.append(int(nxt[i]))
                if r.done:
                    self.completed.append(r)
                    self.engine.reset_slot(i)
                    self.slots[i] = None
        return sum(r is not None for r in self.slots)

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self.queues.values())


class CEPStreamRouter:
    """Time-sliced router feeding keyed events into the CEP fleet.

    Producers ``submit`` events tagged with an integer routing key (tenant
    / symbol id); each ``tick`` closes the current time slice ``(t0, t1]``,
    routes the buffered events to their partitions (``key % K``) and
    advances the whole fleet with one compiled call.  Events with
    timestamps past the current slice stay queued for later ticks, so an
    out-of-order producer is tolerated as long as the event arrives before
    its own slice closes.  Events submitted *after* their slice closed
    (``ts <= t0``) can never be counted exactly-once by the engine's
    latest-event rule, so they are dropped and surfaced in
    ``late_dropped`` rather than silently routed into a slice that will
    ignore the matches they complete.

    The router is engine-agnostic: hand it a plain
    ``CEPFleetServingEngine`` (static plans, ``deploy_plan`` driven by an
    external control loop) or a ``MonitoredCEPFleetServingEngine``, in
    which case every ``tick`` also verifies the per-partition invariant
    sets on device and self-replans flagged partitions; adaptation
    telemetry is then available via ``monitor_telemetry``.
    """

    def __init__(self, engine: CEPFleetServingEngine,
                 slice_duration: float = 1.0, t_start: float = 0.0):
        self.engine = engine
        self.slice_duration = float(slice_duration)
        self.t0 = float(t_start)
        self._tid: List[int] = []
        self._ts: List[float] = []
        self._attr: List[np.ndarray] = []
        self._keys: List[int] = []
        self.slices = 0
        self.late_dropped = 0
        self.routed = 0

    def submit(self, key: int, type_id: int, ts: float,
               attr: np.ndarray) -> None:
        self._keys.append(int(key))
        self._tid.append(int(type_id))
        self._ts.append(float(ts))
        self._attr.append(np.asarray(attr, np.float32))

    @property
    def pending(self) -> int:
        return len(self._ts)

    def monitor_telemetry(self) -> Optional[dict]:
        """Adaptation counters when the engine is device-monitored:
        ``{violations, replans, host_syncs, last_drift}``; None otherwise.
        """
        if not hasattr(self.engine, "violations"):
            return None
        return {
            "violations": self.engine.violations.copy(),
            "replans": self.engine.replans.copy(),
            "host_syncs": self.engine.host_syncs,
            "last_drift": self.engine.last_drift.copy(),
        }

    def _slice_batch(self, ts, idx):
        """Materialize one slice's ``(tid, ts, attr, keys)`` arrays."""
        tid = np.asarray(self._tid, np.int32)[idx]
        n_attrs = self.engine.fleet.pattern.n_attrs
        attr = (np.stack([self._attr[i] for i in idx])
                if len(idx) else np.zeros((0, n_attrs), np.float32))
        keys = np.asarray(self._keys, np.int64)[idx] if len(idx) \
            else np.zeros(0, np.int64)
        self.routed += len(idx)
        return tid, ts[idx], attr, keys

    def _retain(self, keep) -> None:
        self._tid = [self._tid[i] for i in keep]
        self._ts = [self._ts[i] for i in keep]
        self._attr = [self._attr[i] for i in keep]
        self._keys = [self._keys[i] for i in keep]

    def tick(self) -> np.ndarray:
        """Close one slice; returns per-partition match counts for it."""
        t1 = self.t0 + self.slice_duration
        ts = np.asarray(self._ts, np.float32)
        late = ts <= self.t0
        self.late_dropped += int(late.sum())
        take = (ts > self.t0) & (ts <= t1)
        idx = np.nonzero(take)[0]
        keep = np.nonzero(~take & ~late)[0]
        tid, tss, attr, keys = self._slice_batch(ts, idx)
        full = self.engine.process_batch(tid, tss, attr, keys, self.t0, t1)
        self._retain(keep)
        self.t0 = t1
        self.slices += 1
        return full

    def tick_superchunk(self, n: int) -> np.ndarray:
        """Close ``n`` consecutive slices in one superchunk dispatch.

        Returns the ``(n, K)`` per-slice match counts.  Drop accounting is
        *identical* to ``n`` sequential :meth:`tick` calls: an event older
        than the first slice is late exactly once, an event inside slice
        ``j`` routes to slice ``j`` (capacity drops land in
        ``engine.dropped`` per slice, same as per-tick routing), and an
        event past the last slice stays queued.  Slice edges are produced
        by the same repeated addition as sequential ticks so boundary
        comparisons are bit-identical — an event on a slice edge lands in
        the same slice either way.
        """
        if n < 1:
            raise ValueError("tick_superchunk needs n >= 1")
        edges = []
        t0 = self.t0
        for _ in range(n):
            t1 = t0 + self.slice_duration
            edges.append((t0, t1))
            t0 = t1
        ts = np.asarray(self._ts, np.float32)
        late = ts <= self.t0
        self.late_dropped += int(late.sum())
        future = ts > edges[-1][1]
        keep = np.nonzero(future & ~late)[0]
        chunks = []
        for e0, e1 in edges:
            idx = np.nonzero((ts > e0) & (ts <= e1))[0]
            chunks.append(self.engine.route(*self._slice_batch(ts, idx)))
        full = self.engine.process_superchunk(chunks, edges)
        self._retain(keep)
        self.t0 = edges[-1][1]
        self.slices += n
        return full
