"""Serving substrate: prefill/decode engine, adaptive batch scheduler, and
the keyed-stream router for the partitioned CEP fleet (plain or with
device-resident invariant monitoring)."""

from .engine import (  # noqa: F401
    CEPFleetServingEngine,
    MonitoredCEPFleetServingEngine,
    ServingEngine,
)
from .scheduler import CEPStreamRouter, Request, Scheduler  # noqa: F401
