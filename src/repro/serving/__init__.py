"""Serving substrate: prefill/decode engine, adaptive batch scheduler, and
the keyed-stream router for the partitioned CEP fleet."""

from .engine import CEPFleetServingEngine, ServingEngine  # noqa: F401
from .scheduler import CEPStreamRouter, Request, Scheduler  # noqa: F401
