"""Serving substrate: prefill/decode engine + adaptive batch scheduler."""

from .engine import ServingEngine  # noqa: F401
from .scheduler import Request, Scheduler  # noqa: F401
