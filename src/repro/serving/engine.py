"""Batched serving engines: LM prefill/decode and the CEP fleet front.

``ServingEngine`` wraps ``Model.prefill`` / ``Model.decode_step`` into
jitted entry points with a fixed batch capacity.  Requests occupy batch
*slots*; finished slots are refilled by the scheduler without recompiling
(slot state is data).  Per-request cache write indices support
heterogeneous positions in one batch — the decode step is one compiled
program regardless of the request mix, mirroring the CEP engine's
plans-are-data design.

``CEPFleetServingEngine`` is the same idea for event streams: K stream
partitions occupy fleet *rows*; a keyed event batch is routed by
``key % K`` into stacked per-partition chunks and the whole fleet advances
with ONE compiled vmapped ``process_chunk``.  Deploying a new plan for a
partition writes one row of the stacked plan matrix — never a recompile.

``MonitoredCEPFleetServingEngine`` adds the device-resident control loop:
per-partition statistics rings and lowered invariant sets ride inside the
same compiled call, the host reads back only a ``(K,)`` violation-flag
vector, and a flagged partition is re-planned from its synced device
statistics before the next batch — per-batch host work is O(violations),
not O(K·stats).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.adaptation import make_planner
from ..core.decision import InvariantPolicy
from ..core.engine import EngineConfig
from ..core.fleet import (FleetEngine, prime_invariant_policies,
                          replan_flagged_partition, route_events)
from ..core.patterns import Pattern
from ..core.stats import Stat
from ..models.config import ModelConfig
from ..models.model import Cache, Model


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 cache_len: int):
        self.cfg = cfg
        self.model = Model(cfg, remat="none")
        self.params = params
        self.batch_slots = batch_slots
        self.cache_len = cache_len
        self._decode = jax.jit(self.model.decode_step)
        self._prefill_cache: Dict[int, object] = {}
        self.cache: Cache = self.model.init_cache(batch_slots, cache_len)

    def prefill_one(self, tokens: np.ndarray, slot: int) -> int:
        """Prefill a single request's prompt into ``slot``.

        Prompt lengths are bucketed to powers of two so each bucket
        compiles once (static shapes; the adaptive batch planner keeps the
        hot buckets warm).  Returns the first generated token.
        """
        plen = len(tokens)
        bucket = 1 << max(4, (plen - 1).bit_length())
        if self.cfg.family in ("ssm", "hybrid") and bucket != plen:
            raise ValueError(
                "SSM-state prefill needs exact-length prompts; generate "
                f"prompts at bucket sizes (got {plen}, bucket {bucket})")
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = tokens
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(
                functools.partial(self.model.prefill,
                                  cache_len=self.cache_len))
        tl = (None if self.cfg.family in ("ssm", "hybrid")
              else jnp.asarray([plen], jnp.int32))
        logits, one_cache = self._prefill_cache[bucket](
            self.params, {"tokens": jnp.asarray(padded)}, true_lens=tl)
        # Merge the single-request cache into the batch cache at `slot`:
        # kv leaves (L, B, T, K, hd); ssm conv (L, B, W, CH); ssd
        # (L, B, H, P, N); index (B,).
        def set_slot(big, small):
            return big.at[:, slot].set(small[:, 0]) if big.ndim >= 2 \
                else big.at[slot].set(small[0])
        kv = (jax.tree.map(set_slot, self.cache.kv, one_cache.kv)
              if self.cache.kv != () else ())
        ssm = (jax.tree.map(set_slot, self.cache.ssm, one_cache.ssm)
               if self.cache.ssm != () else ())
        index = self.cache.index.at[slot].set(plen)
        self.cache = Cache(kv=kv, ssm=ssm, index=index)
        return int(jnp.argmax(logits[0, 0]))

    def decode(self, tokens: np.ndarray) -> np.ndarray:
        """One decode step for the whole batch; tokens: (slots,) i32."""
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens)[:, None])
        return np.asarray(jnp.argmax(logits[:, 0], axis=-1))

    def reset_slot(self, slot: int) -> None:
        self.cache = self.cache._replace(
            index=self.cache.index.at[slot].set(0))


class CEPFleetServingEngine:
    """Serving front for the partitioned CEP fleet.

    Owns the stacked ring-buffer state and the per-partition plan rows;
    ``process_batch`` takes one keyed event batch covering the time slice
    ``(t0, t1]``, routes it to partitions and advances all K partitions in
    one compiled call.  Per-partition cumulative match counts and
    capacity-drop back-pressure are exposed for the scheduler.
    """

    def __init__(self, pattern: Pattern, k: int, plans,
                 engine_cfg: EngineConfig = EngineConfig(),
                 kind: str = "order", chunk_cap: int = 512,
                 laplace: float = 1.0, superchunk: int = 1, mesh=None):
        from ..core.compat import warn_legacy

        if type(self) is CEPFleetServingEngine:
            warn_legacy("CEPFleetServingEngine")
        self.fleet = FleetEngine(kind, pattern, k, engine_cfg,
                                 monitor_laplace=laplace, mesh=mesh)
        self.k = k
        self.chunk_cap = chunk_cap
        if superchunk < 1:
            raise ValueError("superchunk must be >= 1")
        self.superchunk = int(superchunk)
        self.state = self.fleet.init_state()
        # Host-owned copy: plan rows must stay writable for deploy_plan
        # (np.asarray of a jax array is a read-only view).
        self._rows = np.array(self.fleet.plans_to_array(plans))
        self.matches = np.zeros(k, np.int64)
        self.neg_rejected = np.zeros(k, np.int64)
        self.closure_expansions = np.zeros(k, np.int64)
        self.overflow = np.zeros(k, np.int64)
        self.dropped = 0

    def reset(self) -> None:
        """Clear stream state and counters; compiled programs and deployed
        plan rows survive (a reset is a fresh stream, not a fresh fleet)."""
        self.state = self.fleet.init_state()
        for arr in (self.matches, self.neg_rejected,
                    self.closure_expansions, self.overflow):
            arr[:] = 0
        self.dropped = 0

    def deploy_plan(self, partition: int, plan) -> None:
        """Cheap deployment (§2.2): rewrite one stacked plan row."""
        self._rows[partition] = self.fleet.plan_row(plan)

    def route(self, type_id, ts, attr, keys):
        """Route one keyed event batch to a stacked per-partition chunk.

        Capacity-clipped events accumulate in ``dropped`` — the only
        engine-side drop channel; the router's ``late_dropped`` is the
        only other one, so ``submitted == reached-engine + late_dropped +
        dropped + pending`` is checkable end to end."""
        chunk, dropped = route_events(
            np.asarray(type_id), np.asarray(ts), np.asarray(attr),
            np.asarray(keys), self.k, self.chunk_cap)
        self.dropped += dropped
        return chunk

    def _accumulate(self, res) -> np.ndarray:
        # One device→host transfer for all four counters: per-array
        # fetches cost a dispatch + transfer each and dominate the serving
        # tick at small chunk sizes (the facade-overhead budget in
        # benchmarks/fleet_bench.py watches this path).
        full, neg, clo, ov = np.asarray(jnp.stack(
            [res.full_matches, res.neg_rejected, res.closure_expansions,
             res.overflow]), np.int64)
        self.matches += full
        self.neg_rejected += neg
        self.closure_expansions += clo
        # Match-set truncation undercounts matches; surface it per
        # partition so undercounting is never silent.
        self.overflow += ov
        return full

    def process_chunk(self, chunk, t0: float, t1: float) -> np.ndarray:
        """Tick the fleet once over an already-routed stacked chunk."""
        self.state, res = self.fleet.process_chunk(
            self.state, chunk, self._rows, t0, t1)
        return self._accumulate(res)

    def process_batch(self, type_id, ts, attr, keys,
                      t0: float, t1: float) -> np.ndarray:
        """Route one keyed event batch and tick the fleet once.

        Returns the per-partition full-match counts for this slice.
        """
        return self.process_chunk(self.route(type_id, ts, attr, keys),
                                  t0, t1)

    # -- superchunk control plane ------------------------------------------

    def _accumulate_rows(self, counters, n_rows: int) -> np.ndarray:
        """Fold accepted rows of host (full, neg, closure, overflow)
        counter stacks into the cumulative per-partition totals."""
        full_h, neg_h, cl_h, ov_h = counters
        full = np.asarray(full_h[:n_rows], np.int64)
        self.matches += full.sum(axis=0)
        self.neg_rejected += np.asarray(neg_h[:n_rows],
                                        np.int64).sum(axis=0)
        self.closure_expansions += np.asarray(cl_h[:n_rows],
                                              np.int64).sum(axis=0)
        self.overflow += np.asarray(ov_h[:n_rows], np.int64).sum(axis=0)
        return full

    def process_superchunk(self, chunks, edges) -> np.ndarray:
        """Roll a sequence of already-routed stacked chunks through the
        fleet, ``superchunk`` chunks per compiled dispatch (``core.scan``).

        ``chunks``: stacked ``Chunk``s (leading K axis); ``edges``: their
        ``(t0, t1]`` slices.  Plans are static between ``deploy_plan``
        calls, so the host never needs to surface mid-window — every
        window is exactly one dispatch.  Returns the per-chunk ``(S, K)``
        full-match counts; cumulative counters update as in
        ``process_chunk``.
        """
        from ..core.scan import stack_window, static_control

        s_cap = self.superchunk
        n = len(chunks)
        if n != len(edges):
            raise ValueError(f"{n} chunks vs {len(edges)} edges")
        out = np.zeros((n, self.k), np.int64)
        scan = self.fleet.superchunk_scan(monitored=False)
        ctl = static_control(self.k, s_cap)
        i = 0
        while i < n:
            win = chunks[i:i + s_cap]
            t0s = [e[0] for e in edges[i:i + len(win)]]
            t1s = [e[1] for e in edges[i:i + len(win)]]
            xs = stack_window(win, t0s, t1s, ctl, s_cap)
            rows = jnp.asarray(self._rows)
            self.state, _, ys = scan(self.state, None, rows, rows,
                                     None, xs)
            ys_h = jax.device_get((ys.full, ys.neg, ys.closure,
                                   ys.overflow))
            out[i:i + len(win)] = self._accumulate_rows(ys_h, len(win))
            i += len(win)
        return out


class MonitoredCEPFleetServingEngine(CEPFleetServingEngine):
    """Serving fleet with on-device invariant monitoring (§3.3-§3.5).

    Partitions start on a plan generated from the uniform prior; real
    per-partition statistics accumulate in device-resident rings inside
    the compiled batch call.  When a partition's lowered invariant set
    flags a violation, the host syncs that partition's ``(rates, sel)``
    snapshot, re-runs the planner, and deploys the new plan row and the
    freshly compiled invariant row — all array writes, never a recompile.

    The serving front deploys immediately (no [36] migration split):
    partial matches are rebuilt from the ring buffers every slice, so a
    row swap between batches changes only join *work*, never *which*
    matches are counted — exactly-once detection is preserved (see
    DESIGN.md §7).

    Telemetry: ``violations`` / ``replans`` (per partition),
    ``host_syncs`` (total statistic pulls — ∝ violations, not K·batches),
    and ``last_drift`` (the §3.4-style relative margin of each
    partition's tightest invariant after the latest batch).
    """

    def __init__(self, pattern: Pattern, k: int,
                 engine_cfg: EngineConfig = EngineConfig(),
                 kind: Optional[str] = None, chunk_cap: int = 512,
                 planner: str = "greedy", policy_kw: Optional[dict] = None,
                 monitor_buckets: int = 16,
                 max_inv: Optional[int] = None,
                 max_terms: Optional[int] = None,
                 laplace: float = 1.0, superchunk: int = 1, mesh=None):
        from ..core.compat import warn_legacy

        warn_legacy("MonitoredCEPFleetServingEngine")
        self.pattern = pattern
        self.planner = make_planner(planner)
        # The plan family must match the planner's output (an order vector
        # vs a slot-join program); derive it unless explicitly overridden.
        kind = kind or ("order" if planner == "greedy" else "tree")
        self.policies = [InvariantPolicy(**(policy_kw or {}))
                         for _ in range(k)]
        plan0, self._low, self._caps = prime_invariant_policies(
            pattern, self.planner, self.policies, (max_inv, max_terms))
        super().__init__(pattern, k, plan0, engine_cfg, kind, chunk_cap,
                         laplace=laplace, superchunk=superchunk, mesh=mesh)
        self.plans = [plan0] * k
        self.monitor = self.fleet.init_monitor(monitor_buckets)
        self.violations = np.zeros(k, np.int64)
        self.replans = np.zeros(k, np.int64)
        self.host_syncs = 0
        self.last_drift = np.full(k, -np.inf, np.float32)

    def reset(self) -> None:
        """Clear stream state, monitor rings and counters; deployed plan
        rows and the compiled invariant rows survive."""
        super().reset()
        self.monitor = self.fleet.init_monitor(self.monitor.counts.shape[1])
        self.violations[:] = 0
        self.replans[:] = 0
        self.host_syncs = 0
        self.last_drift = np.full(self.k, -np.inf, np.float32)

    def deploy_plan(self, partition: int, plan) -> None:
        """Manually deploy a plan row for one partition.

        The partition's *invariant* row is intentionally left as the last
        planner output's: deciding-condition sets exist only for plans the
        instrumented planner generated, so the monitor keeps answering the
        §3 question — "would re-running ``A`` change its choice?" — and a
        violation re-establishes planner control (overwriting the manual
        plan via the flag-triggered replan)."""
        super().deploy_plan(partition, plan)
        self.plans[partition] = plan

    def _apply_flags(self, fired_mask, rates, sel) -> None:
        """The O(violations) control plane: sync + replan flagged rows only.

        ``rates``/``sel`` may be device or host arrays; a partition's
        snapshot is materialized only when its flag fired.
        """
        for p in np.nonzero(np.asarray(fired_mask))[0]:
            self.violations[p] += 1
            self.host_syncs += 1
            stat = Stat(np.asarray(rates[p], np.float64),
                        np.asarray(sel[p], np.float64))
            new_plan = replan_flagged_partition(
                self.pattern, self.planner, self.policies[p],
                self._low, p, stat, self._caps)
            if new_plan != self.plans[p]:
                self.deploy_plan(p, new_plan)  # also records self.plans[p]
                self.replans[p] += 1

    def process_chunk(self, chunk, t0: float, t1: float) -> np.ndarray:
        """Tick the fused monitored fleet over an already-routed chunk and
        replan any partition whose invariant flag fired."""
        self.state, self.monitor, res, violated, drift, rates, sel = \
            self.fleet.process_chunk_monitored(
                self.state, self.monitor, chunk, self._rows,
                self._low.device(), t0, t1)
        full = self._accumulate(res)
        # Coalesce the flag + drift readback into one transfer (the only
        # extra per-tick host traffic device monitoring costs).
        vd = np.asarray(jnp.stack([violated.astype(jnp.float32), drift]))
        self.last_drift = vd[1].astype(np.float32)
        self._apply_flags(vd[0] > 0.5, rates, sel)
        return full

    def process_superchunk(self, chunks, edges) -> np.ndarray:
        """Monitored superchunk ticks: S chunks per dispatch, flags and
        telemetry accumulated on device, host control only at boundaries.

        Bit-identical to looping ``process_chunk``: the scan is run
        optimistically, and when a flag fires at in-window chunk ``f`` the
        prefix ``[0..f]`` is re-run from the pre-window state so the
        replanned rows deploy before chunk ``f+1`` — exactly the per-tick
        contract (see ``core.scan``).  Violation-free windows cost one
        dispatch; host work stays O(violations).
        """
        from ..core.scan import first_event, stack_window, static_control

        s_cap = self.superchunk
        n = len(chunks)
        if n != len(edges):
            raise ValueError(f"{n} chunks vs {len(edges)} edges")
        out = np.zeros((n, self.k), np.int64)
        scan = self.fleet.superchunk_scan(monitored=True)
        ctl = static_control(self.k, s_cap)
        i = 0
        while i < n:
            win = chunks[i:i + s_cap]
            n_en = len(win)
            t0s = [e[0] for e in edges[i:i + n_en]]
            t1s = [e[1] for e in edges[i:i + n_en]]
            xs = stack_window(win, t0s, t1s, ctl, s_cap)
            rows = jnp.asarray(self._rows)
            low_dev = self._low.device()
            state2, mon2, ys = scan(self.state, self.monitor, rows, rows,
                                    low_dev, xs)
            # Counters + flags + drift come back eagerly; the statistic
            # stacks stay device-resident and are materialized
            # per-partition only when a flag fired (O(violations) host
            # traffic, as in the per-tick path).
            ys_h = jax.device_get(
                (ys.full, ys.pm, ys.overflow, ys.closure, ys.neg,
                 ys.violated, ys.drift))
            (full_h, pm_h, ov_h, cl_h, ng_h, violated_h, drift_h) = ys_h
            f = first_event(violated_h, ov_h, n_en, escalate=False)
            if f is not None and f < n_en - 1:
                en = np.zeros(s_cap, bool)
                en[:f + 1] = True
                state2, mon2, _ = scan(
                    self.state, self.monitor, rows, rows, low_dev,
                    xs._replace(enabled=jnp.asarray(en)))
            accept = n_en if f is None else f + 1
            self.state, self.monitor = state2, mon2
            out[i:i + accept] = self._accumulate_rows(
                (full_h, ng_h, cl_h, ov_h), accept)
            last = accept - 1
            self.last_drift = np.asarray(drift_h[last], np.float32)
            self._apply_flags(violated_h[last], ys.rates[last],
                              ys.sel[last])
            i += accept
        return out
