"""Batched serving engine: prefill + decode with a unified cache.

Wraps ``Model.prefill`` / ``Model.decode_step`` into jitted entry points
with a fixed batch capacity.  Requests occupy batch *slots*; finished slots
are refilled by the scheduler without recompiling (slot state is data).
Per-request cache write indices support heterogeneous positions in one
batch — the decode step is one compiled program regardless of the request
mix, mirroring the CEP engine's plans-are-data design.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig
from ..models.model import Cache, Model


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int,
                 cache_len: int):
        self.cfg = cfg
        self.model = Model(cfg, remat="none")
        self.params = params
        self.batch_slots = batch_slots
        self.cache_len = cache_len
        self._decode = jax.jit(self.model.decode_step)
        self._prefill_cache: Dict[int, object] = {}
        self.cache: Cache = self.model.init_cache(batch_slots, cache_len)

    def prefill_one(self, tokens: np.ndarray, slot: int) -> int:
        """Prefill a single request's prompt into ``slot``.

        Prompt lengths are bucketed to powers of two so each bucket
        compiles once (static shapes; the adaptive batch planner keeps the
        hot buckets warm).  Returns the first generated token.
        """
        plen = len(tokens)
        bucket = 1 << max(4, (plen - 1).bit_length())
        if self.cfg.family in ("ssm", "hybrid") and bucket != plen:
            raise ValueError(
                "SSM-state prefill needs exact-length prompts; generate "
                f"prompts at bucket sizes (got {plen}, bucket {bucket})")
        padded = np.zeros((1, bucket), np.int32)
        padded[0, :plen] = tokens
        if bucket not in self._prefill_cache:
            self._prefill_cache[bucket] = jax.jit(
                functools.partial(self.model.prefill,
                                  cache_len=self.cache_len))
        tl = (None if self.cfg.family in ("ssm", "hybrid")
              else jnp.asarray([plen], jnp.int32))
        logits, one_cache = self._prefill_cache[bucket](
            self.params, {"tokens": jnp.asarray(padded)}, true_lens=tl)
        # Merge the single-request cache into the batch cache at `slot`:
        # kv leaves (L, B, T, K, hd); ssm conv (L, B, W, CH); ssd
        # (L, B, H, P, N); index (B,).
        def set_slot(big, small):
            return big.at[:, slot].set(small[:, 0]) if big.ndim >= 2 \
                else big.at[slot].set(small[0])
        kv = (jax.tree.map(set_slot, self.cache.kv, one_cache.kv)
              if self.cache.kv != () else ())
        ssm = (jax.tree.map(set_slot, self.cache.ssm, one_cache.ssm)
               if self.cache.ssm != () else ())
        index = self.cache.index.at[slot].set(plen)
        self.cache = Cache(kv=kv, ssm=ssm, index=index)
        return int(jnp.argmax(logits[0, 0]))

    def decode(self, tokens: np.ndarray) -> np.ndarray:
        """One decode step for the whole batch; tokens: (slots,) i32."""
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens)[:, None])
        return np.asarray(jnp.argmax(logits[:, 0], axis=-1))

    def reset_slot(self, slot: int) -> None:
        self.cache = self.cache._replace(
            index=self.cache.index.at[slot].set(0))
