"""Model zoo: dense GQA transformers, fine-grained MoE, Mamba2 SSD, hybrids,
and VLM/audio backbones — pure-JAX, explicit param pytrees, scan-over-layers
with remat, logical-axis sharding annotations."""

from .config import ModelConfig  # noqa: F401
