"""Mamba2 (SSD — state-space duality) block, arXiv:2405.21060.

The SSD layer computes, per head ``h`` with scalar decay ``A_h < 0``:

    h_t = exp(dt_t A) h_{t-1} + dt_t (B_t ⊗ x_t),      y_t = C_t · h_t + D x_t

Training/prefill uses the paper's **chunked matmul form** (Listing 1): the
sequence splits into chunks of length ``Q``; intra-chunk terms are a masked
``C Bᵀ`` product (MXU-friendly ``Q×Q`` matmuls), inter-chunk terms flow
through a tiny recurrence over per-chunk states — ``O(S·Q)`` work with all
FLOPs in matmuls, the TPU-native reformulation of Mamba's CUDA scan.

Decode maintains (conv_state, ssd_state) and costs O(1) per token — which is
why the SSM/hybrid architectures are the ones assigned the ``long_500k``
shape.

Layout: x/B/C pass through a short causal depthwise conv (width
``ssm_conv``); gating ``z`` and the dt head come straight from the input
projection; output is ``out_proj(rms_norm(y) * silu(z))``.
"""

from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_constraint as lc
from .config import ModelConfig
from .layers import rms_norm
from .params import ParamDef


class SSMState(NamedTuple):
    conv: jax.Array  # (B, conv_w - 1, conv_ch) rolling conv inputs
    ssd: jax.Array   # (B, H, P, N) recurrent state


def ssm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    conv_ch = di + 2 * n
    return {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "in_proj": ParamDef((d, 2 * di + 2 * n + h), ("embed", "ssm_inner")),
        "conv_w": ParamDef((cfg.ssm_conv, conv_ch), ("conv", "ssm_inner"),
                           scale=0.5),
        "A_log": ParamDef((h,), ("ssm_heads",), "zeros"),
        "D": ParamDef((h,), ("ssm_heads",), "ones"),
        "dt_bias": ParamDef((h,), ("ssm_heads",), "zeros"),
        "norm_w": ParamDef((di,), ("ssm_inner",), "ones"),
        "out_proj": ParamDef((di, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, proj):
    di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xBC = proj[..., di:di + di + 2 * n]
    dt = proj[..., di + di + 2 * n:]
    assert dt.shape[-1] == h
    return z, xBC, dt


def _causal_conv(xBC, w):
    """Depthwise causal conv over time.  xBC: (B,S,CH), w: (W,CH)."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(W):  # small static unroll (W = 4)
        out = out + pad[:, i:i + xBC.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out)


def _segsum(a):
    """Stable 'segment sum': out[..., i, j] = sum_{k=j+1..i} a[..., k].

    Lower-triangular; -inf above the diagonal.  a: (..., L).
    """
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(L)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int, unroll: int = 1):
    """Chunked SSD scan, streamed over chunks.

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) negative;
    B, C: (b, s, n) (single group, broadcast over heads).
    Returns y: (b, s, h, p) and final state (b, h, p, n).

    One ``lax.scan`` over the ``s/chunk`` chunks carries the recurrent
    state; per-step live memory is the chunk-local decay mask
    ``(b, h, q, q)`` — a naively materialized all-chunks mask
    ``(b, h, nc, q, q)`` would be terabytes at 32k prefill.  All heavy
    FLOPs are q×q / q×n matmuls (MXU-shaped).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    q = chunk
    assert s % q == 0, (s, q)
    nc = s // q

    xd = x * dt[..., None]                                  # dt-weighted
    a = dt * A[None, None, :]                               # (b, s, h) <= 0
    # Chunked, scan-major layouts.
    xc = xd.reshape(b, nc, q, h, p).transpose(1, 0, 2, 3, 4)
    Bc = B.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    Cc = C.reshape(b, nc, q, n).transpose(1, 0, 2, 3)
    ac = a.reshape(b, nc, q, h).transpose(1, 0, 3, 2)       # (nc,b,h,q)

    def step(h_state, inp):
        x_c, B_c, C_c, a_c = inp                            # chunk-local
        a_cum = jnp.cumsum(a_c, axis=-1)                    # (b,h,q)
        Lm = jnp.exp(_segsum(a_c))                          # (b,h,q,q)
        scores = jnp.einsum("bln,bsn->bls", C_c, B_c)       # (b,q,q)
        y_diag = jnp.einsum("bhls,bls,bshp->blhp",
                            Lm, scores, x_c)
        decay_states = jnp.exp(a_cum[..., -1:] - a_cum)     # (b,h,q)
        contrib = jnp.einsum("bln,bhl,blhp->bhpn",
                             B_c, decay_states, x_c)
        y_off = jnp.einsum("bln,bhpn,bhl->blhp",
                           C_c, h_state, jnp.exp(a_cum))
        h_new = (h_state * jnp.exp(a_cum[..., -1])[..., None, None]
                 + contrib)
        return h_new, y_diag + y_off

    init = jnp.zeros((b, h, p, n), x.dtype)
    final, ys = jax.lax.scan(step, init, (xc, Bc, Cc, ac),
                             unroll=min(unroll, nc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, final


def ssm_block(x, p, cfg: ModelConfig, state: SSMState | None = None
              ) -> Tuple[jax.Array, SSMState]:
    """One Mamba2 block.  x: (B, S, D).

    With ``state`` and S == 1: O(1) recurrent decode step.
    Without: chunked scan over the sequence (train / prefill); the returned
    state allows seamless continuation into decode.
    """
    bsz, S, _ = x.shape
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    proj = lc(proj, "batch", "seq", "ssm_inner")
    z, xBC, dt_raw = _split_proj(cfg, proj)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (h,)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    w = p["conv_w"].astype(x.dtype)
    W = cfg.ssm_conv

    if state is not None and S == 1:
        # ---- decode ----
        window = jnp.concatenate([state.conv, xBC], axis=1)  # (B, W, CH)
        conv_out = jax.nn.silu(
            jnp.einsum("bwc,wc->bc", window, w))[:, None, :]  # (B,1,CH)
        new_conv = window[:, 1:, :]
        xs = conv_out[..., :di].reshape(bsz, 1, h, pdim)
        Bv = conv_out[..., di:di + n][:, 0]                  # (B, n)
        Cv = conv_out[..., di + n:][:, 0]                    # (B, n)
        dt1 = dt[:, 0]                                       # (B, h)
        decay = jnp.exp(dt1 * A[None, :])                    # (B, h)
        xd = xs[:, 0] * dt1[..., None]                       # (B, h, p)
        upd = jnp.einsum("bhp,bn->bhpn", xd, Bv)
        new_ssd = state.ssd * decay[..., None, None].astype(x.dtype) \
            + upd.astype(x.dtype)
        y = jnp.einsum("bhpn,bn->bhp", new_ssd, Cv)
        y = y + xs[:, 0] * p["D"].astype(x.dtype)[None, :, None]
        y = y.reshape(bsz, 1, di)
        new_state = SSMState(new_conv, new_ssd)
    else:
        # ---- train / prefill ----
        conv_out = _causal_conv(xBC, w)                      # (B,S,CH)
        xs = conv_out[..., :di].reshape(bsz, S, h, pdim)
        Bv = conv_out[..., di:di + n]
        Cv = conv_out[..., di + n:]
        y, final = ssd_chunked(
            xs.astype(jnp.float32), dt, A,
            Bv.astype(jnp.float32), Cv.astype(jnp.float32),
            min(cfg.ssm_chunk, S), unroll=cfg.ssm_scan_unroll)
        y = y + xs.astype(jnp.float32) \
            * p["D"].astype(jnp.float32)[None, None, :, None]
        y = y.reshape(bsz, S, di).astype(x.dtype)
        new_conv = jnp.pad(
            xBC, ((0, 0), (max(W - 1 - S, 0), 0), (0, 0))
        )[:, -(W - 1):, :]
        new_state = SSMState(new_conv, final.astype(x.dtype))

    y = rms_norm(y, p["norm_w"]) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return lc(out, "batch", "seq", "act_embed"), new_state


def init_ssm_state(cfg: ModelConfig, batch: int, dtype) -> SSMState:
    conv_ch = cfg.d_inner + 2 * cfg.ssm_state
    return SSMState(
        conv=jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        ssd=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                       cfg.ssm_state), dtype),
    )
