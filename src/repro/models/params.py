"""Parameter definition machinery — one source of truth per architecture.

Each model family provides a nested dict of ``ParamDef``s (shape, logical
axes, initializer).  From that single structure we derive:

* materialized parameters (``init_params``),
* logical-axis trees (``logical_axes``) for pjit in/out shardings,
* abstract ``ShapeDtypeStruct`` trees for the dry-run (no allocation).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones
    scale: Optional[float] = None   # None -> 1/sqrt(fan_in) with fan_in =
                                    # last-but-one dim (matmul convention)

    def stacked(self, n: int) -> "ParamDef":
        return ParamDef((n,) + self.shape, ("layers",) + self.axes,
                        self.init, self.scale)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _init_leaf(d: ParamDef, key, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "normal":
        scale = d.scale
        if scale is None:
            fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
            scale = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, d.shape, jnp.float32)
                * scale).astype(dtype)
    raise ValueError(d.init)


def init_params(defs, key, dtype):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(d, k, dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def logical_axes(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def abstract_params(defs, dtype):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs, is_leaf=is_def)


def param_bytes(defs, dtype) -> int:
    itemsize = jnp.dtype(dtype).itemsize
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(math.prod(d.shape) * itemsize for d in leaves)


def count_params(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(math.prod(d.shape) for d in leaves)
