"""Core transformer layers: norms, RoPE, GQA attention (train / prefill /
decode with KV cache, prefix-LM and sliding-window masks), SwiGLU FFN.

All functions are pure; parameters are explicit pytrees built from the
``params.ParamDef`` machinery.  Activations carry logical sharding
annotations (``distributed.sharding``) so the same code traces correctly on
a laptop CPU and on the multi-pod production mesh.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_constraint as lc
from .config import ModelConfig
from .params import ParamDef

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    y = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def np_layer_norm(x, eps: float = 1e-5):
    """Non-parametric LayerNorm (OLMo): no scale, no bias."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def apply_norm(x, w, kind: str):
    if kind == "rms":
        return rms_norm(x, w)
    if kind == "np_ln":
        return np_layer_norm(x)
    raise ValueError(kind)


def norm_def(cfg: ModelConfig) -> ParamDef:
    # np_ln keeps a (unused, zero-size-free) ones vector for tree uniformity.
    return ParamDef((cfg.d_model,), ("embed",), "ones")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


class KVCache(NamedTuple):
    k: jax.Array    # (B, T, K, hd)
    v: jax.Array    # (B, T, K, hd)
    pos: jax.Array  # (B, T) i32 absolute positions (-1 = empty)


def attn_defs(cfg: ModelConfig) -> dict:
    d, h, k, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": ParamDef((d, h, hd), ("embed", "heads", "qkv_dim")),
        "wk": ParamDef((d, k, hd), ("embed", "kv_heads", "qkv_dim")),
        "wv": ParamDef((d, k, hd), ("embed", "kv_heads", "qkv_dim")),
        "wo": ParamDef((h, hd, d), ("heads", "qkv_dim", "embed")),
    }


def _gqa_scores(q, k, cfg: ModelConfig):
    """q: (B,S,K,G,hd), k: (B,T,K,hd) -> (B,K,G,S,T) fp32."""
    s = jnp.einsum("bskgh,btkh->bkgst", q, k,
                   preferred_element_type=jnp.float32)
    return s / (cfg.hd ** 0.5)


def _flash_attention(q, k, v, cfg: ModelConfig, pos_q, pos_k,
                     prefix_len: int, window: int):
    """Blockwise streaming-softmax attention (FlashAttention schedule).

    q: (B,S,K,G,hd); k, v: (B,T,K,hd); pos_q: (B,S); pos_k: (B,T).
    ``lax.scan`` over KV blocks keeps live memory at
    O(B·K·G·S·block) instead of the O(S·T) score matrix — mandatory for
    the 32k-prefill shapes.  Numerics follow the standard running
    (max, denom, acc) recurrence in fp32.
    """
    B, S, K, G, hd = q.shape
    T = k.shape[1]
    blk = min(cfg.attn_kv_block, T)
    pad = (-T) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        # Padding gets a huge position: fails causal and prefix masks.
        pos_k = jnp.pad(pos_k, ((0, 0), (0, pad)),
                        constant_values=jnp.iinfo(jnp.int32).max)
    nb = (T + pad) // blk
    kb = k.reshape(B, nb, blk, K, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nb, blk, K, hd).transpose(1, 0, 2, 3, 4)
    pb = pos_k.reshape(B, nb, blk).transpose(1, 0, 2)

    def step(carry, inp):
        m, l, acc = carry
        kc, vc, pkc = inp                                   # (B,blk,...)
        s = jnp.einsum("bskgh,btkh->bkgst", q, kc,
                       preferred_element_type=jnp.float32) / (hd ** 0.5)
        ok = pos_q[:, :, None] >= pkc[:, None, :]           # (B,S,blk)
        if prefix_len > 0:
            ok = ok | (pkc[:, None, :] < prefix_len)
        if window > 0:
            ok = ok & (pos_q[:, :, None] - pkc[:, None, :] < window)
        s = jnp.where(ok[:, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))              # (B,K,G,S)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bkgst,btkh->bskgh", p.astype(v.dtype), vc)
        acc_new = acc * corr.transpose(0, 3, 1, 2)[..., None] \
            + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), ()

    m0 = jnp.full((B, K, G, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kb, vb, pb))
    denom = jnp.maximum(l, 1e-30).transpose(0, 3, 1, 2)[..., None]
    return (acc / denom).astype(q.dtype)


def attention(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    positions: jax.Array,
    *,
    prefix_len: int = 0,
    cache: Optional[KVCache] = None,
    cache_index: Optional[jax.Array] = None,
    window: int = 0,
) -> Tuple[jax.Array, Optional[KVCache]]:
    """GQA attention.

    Without ``cache``: full-sequence causal (optionally prefix-LM over the
    first ``prefix_len`` positions — PaliGemma-style bidirectional prefix).

    With ``cache``: single-step decode; the new token's K/V is written at
    ``cache_index`` (ring-buffer slot when ``window > 0``) and attention
    runs over the whole cache with position-validity masking.
    """
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // K

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    q = lc(q, "batch", "seq", "heads", None)
    k = lc(k, "batch", "seq", "kv_heads", None)
    v = lc(v, "batch", "seq", "kv_heads", None)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = q.reshape(B, S, K, G, hd)

    if cache is None:
        if S > cfg.attn_direct_max:
            # Long sequences: blockwise streaming softmax (flash).
            out = _flash_attention(q, k, v, cfg, positions, positions,
                                   prefix_len, window)
        else:
            scores = _gqa_scores(q, k, cfg)  # (B,K,G,S,T) T=S
            pos_q = positions[:, :, None]
            pos_k = positions[:, None, :]
            causal = pos_q >= pos_k                      # (B,S,T)
            if prefix_len > 0:
                causal = causal | (pos_k < prefix_len)   # bidir prefix
            if window > 0:
                causal = causal & (pos_q - pos_k < window)
            scores = jnp.where(causal[:, None, None, :, :], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
            out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
        new_cache = None
    else:
        # Decode: S == 1; cache_index: (B,) per-request write slots.
        assert S == 1
        T = cache.k.shape[1]
        slot = cache_index if window == 0 else cache_index % T
        bidx = jnp.arange(B)
        ck = cache.k.at[bidx, slot].set(k[:, 0].astype(cache.k.dtype))
        cv = cache.v.at[bidx, slot].set(v[:, 0].astype(cache.v.dtype))
        cpos = cache.pos.at[bidx, slot].set(positions[:, 0])
        scores = _gqa_scores(q, ck.astype(x.dtype), cfg)  # (B,K,G,1,T)
        valid = (cpos >= 0) & (cpos <= positions[:, :1])  # (B,T)
        if window > 0:
            valid = valid & (positions[:, :1] - cpos < window)
        scores = jnp.where(valid[:, None, None, None, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, cv.astype(x.dtype))
        new_cache = KVCache(ck, cv, cpos)

    out = out.reshape(B, S, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return lc(out, "batch", "seq", "act_embed"), new_cache


def init_kv_cache(cfg: ModelConfig, batch: int, length: int,
                  dtype) -> KVCache:
    K, hd = cfg.n_kv_heads, cfg.hd
    return KVCache(
        k=jnp.zeros((batch, length, K, hd), dtype),
        v=jnp.zeros((batch, length, K, hd), dtype),
        pos=jnp.full((batch, length), -1, jnp.int32),
    )


def prefill_kv_cache(cfg: ModelConfig, x_k, x_v, positions) -> KVCache:
    """Build a cache directly from a prefill pass's K/V tensors."""
    B = x_k.shape[0]
    return KVCache(k=x_k, v=x_v,
                   pos=jnp.broadcast_to(positions, (B, x_k.shape[1])))


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn_defs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    return {
        "w_gate": ParamDef((d, f), ("embed", "ff")),
        "w_up": ParamDef((d, f), ("embed", "ff")),
        "w_down": ParamDef((f, d), ("ff", "embed")),
    }


def swiglu(x, p):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = lc(h, "batch", "seq", "ff")
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))
    return lc(out, "batch", "seq", "act_embed")


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def embed_defs(cfg: ModelConfig) -> dict:
    out = {"tok": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"),
                           scale=1.0)}
    if not cfg.tie_embeddings:
        out["head"] = ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return out


def embed(tokens, p, cfg: ModelConfig):
    e = jnp.take(p["tok"], tokens, axis=0).astype(cfg.adtype)
    return lc(e, "batch", "seq", "act_embed")


def unembed(x, p, cfg: ModelConfig):
    w = (p["tok"].T if cfg.tie_embeddings else p["head"]).astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return lc(logits, "batch", "seq", "vocab")
