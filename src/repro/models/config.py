"""Unified architecture configuration.

One dataclass describes every assigned architecture family:

* ``dense``  — GQA/MQA decoder transformer (RoPE + SwiGLU).
* ``moe``    — dense attention + shared/routed fine-grained expert FFN.
* ``vlm``    — dense backbone consuming precomputed patch embeddings
               prepended to the token sequence (frontend is a stub per the
               assignment).
* ``audio``  — dense backbone consuming precomputed frame embeddings
               (EnCodec-token decoder; frontend stubbed).
* ``ssm``    — attention-free Mamba2 (SSD) stack.
* ``hybrid`` — Mamba2 backbone with a *shared* attention block applied every
               ``attn_every`` layers (Zamba2 style).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp

_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    vocab: int
    d_ff: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: Optional[int] = None
    norm: str = "rms"             # rms | np_ln (non-parametric LayerNorm)
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_seq_shard: bool = False   # §Perf: dispatch from seq-sharded tokens
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0            # N
    ssm_head_dim: int = 64        # P
    ssm_expand: int = 2           # d_inner = expand * d_model
    ssm_conv: int = 4             # causal conv width
    ssm_chunk: int = 128          # SSD chunk length
    ssm_scan_unroll: int = 1      # dry-run accounting: unroll SSD scan
    # --- hybrid (Zamba2) ---
    attn_every: int = 0           # shared attn block period; 0 = never
    attn_window: int = 0          # sliding-window KV for long decode; 0=full
    # --- modality frontends (stubs per assignment) ---
    n_frontend_tokens: int = 0    # VLM: # patch embeddings prepended
    frontend_is_embedding: bool = False  # audio: inputs are embeddings
    # --- attention execution ---
    attn_direct_max: int = 4096   # S above this -> blockwise (flash) attn
    attn_kv_block: int = 2048     # KV block length for the flash scan
    # --- numerics ---
    param_dtype: str = "f32"
    dtype: str = "f32"            # activation/compute dtype

    def __post_init__(self):
        if self.family in ("dense", "moe", "vlm", "audio"):
            assert self.n_heads > 0 and self.n_kv_heads > 0
            assert self.n_heads % self.n_kv_heads == 0
        if self.family == "moe":
            assert self.n_experts > 0 and self.top_k > 0
        if self.family in ("ssm", "hybrid"):
            assert self.ssm_state > 0

    @property
    def hd(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def pdtype(self):
        return _DTYPES[self.param_dtype]

    @property
    def adtype(self):
        return _DTYPES[self.dtype]

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def n_shared_attn_calls(self) -> int:
        """Hybrid: number of shared-attention invocations over the stack."""
        if self.family != "hybrid" or self.attn_every <= 0:
            return 0
        return (self.n_layers + self.attn_every - 1) // self.attn_every

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6·N·D) ----------

    def param_count(self) -> int:
        d, ff, v = self.d_model, self.d_ff, self.vocab
        n = 0
        if self.family in ("dense", "moe", "vlm", "audio"):
            hd = self.hd
            attn = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads \
                + hd * self.n_heads * d
            if self.family == "moe":
                ffn = self.n_experts * 3 * d * ff \
                    + self.n_shared_experts * 3 * d * ff + d * self.n_experts
            else:
                ffn = 3 * d * ff
            norms = 2 * d  # materialized even for np_ln (tree uniformity)
            n = self.n_layers * (attn + ffn + norms)
        elif self.family in ("ssm", "hybrid"):
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            conv_ch = di + 2 * N
            ssm = (d * (2 * di + 2 * N + H)      # in_proj (z,x,B,C,dt)
                   + conv_ch * self.ssm_conv      # depthwise conv
                   + 2 * H + H                    # A_log, D, dt_bias
                   + di * d                       # out_proj
                   + d + di)                      # layer norm + gate norm
            n = self.n_layers * ssm
            if self.family == "hybrid":
                hd = self.hd
                attn = (d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads
                        + hd * self.n_heads * d + 3 * d * self.d_ff
                        + 2 * d)
                n += attn  # shared block counted once
        n += v * d  # token embedding
        n += d      # final norm
        if not self.tie_embeddings:
            n += v * d  # output head
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: shared + top_k routed only)."""
        if self.family != "moe":
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive = (self.n_experts - self.top_k) * 3 * d * ff * self.n_layers
        return self.param_count() - inactive
