"""Unified model: one class covering all six assigned architecture families.

Layer stacks run under ``jax.lax.scan`` over *stacked* layer parameters with
configurable rematerialization, so HLO size and compile time stay flat in
depth (60-layer yi-34b compiles as fast as 16-layer olmo).  Decode carries a
unified ``Cache`` (stacked KV caches and/or stacked SSM states + a per-
request write index), giving every family the same ``prefill`` /
``decode_step`` serving interface.

Family specifics
----------------
* ``dense``   — pre-norm GQA + SwiGLU.
* ``moe``     — GQA + shared/routed expert FFN; scan accumulates the router
                aux loss and per-expert loads (the statistics the adaptive
                placement governor monitors).
* ``vlm``     — dense backbone over [patch embeddings ; token embeddings]
                with a bidirectional prefix mask (PaliGemma); the vision
                frontend is a stub per the assignment (``input_specs``
                provides the patch embeddings).
* ``audio``   — dense backbone over precomputed frame embeddings (MusicGen
                over EnCodec tokens; frontend stubbed).
* ``ssm``     — Mamba2/SSD stack (attention-free).
* ``hybrid``  — Mamba2 stack + one *shared* attention block applied every
                ``attn_every`` layers (Zamba2); for ``long_500k`` decode the
                shared block uses a sliding-window ring cache
                (``attn_window``), keeping the architecture sub-quadratic.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.sharding import logical_constraint as lc
from .config import ModelConfig
from .layers import (
    KVCache,
    apply_norm,
    attention,
    attn_defs,
    embed,
    embed_defs,
    ffn_defs,
    init_kv_cache,
    norm_def,
    swiglu,
    unembed,
)
from .moe import moe_defs, moe_ffn
from .params import abstract_params, init_params, logical_axes
from .ssm import SSMState, init_ssm_state, ssm_block, ssm_defs


class Cache(NamedTuple):
    """Unified decode state across families (unused slots are ())."""

    kv: Any        # stacked KVCache (L or n_calls leading dim) or ()
    ssm: Any       # stacked SSMState (L leading dim) or ()
    index: Any     # (B,) i32 next write slot


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "full":
        return jax.checkpoint(fn,
                              policy=jax.checkpoint_policies.nothing_saveable)
    if mode == "dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    raise ValueError(mode)


class Model:
    """Pure-function model; parameters are explicit pytrees."""

    def __init__(self, cfg: ModelConfig, remat: str = "full",
                 unroll_layers: bool = False):
        self.cfg = cfg
        self.remat = remat
        # Dry-run accounting mode: XLA's cost_analysis counts a while-loop
        # body once regardless of trip count, so the roofline pass unrolls
        # the layer scan to get exact HLO FLOPs / collective bytes.
        self.unroll = cfg.n_layers if unroll_layers else 1

    # ------------------------------------------------------------------
    # Parameter structure
    # ------------------------------------------------------------------

    def _layer_defs(self) -> dict:
        cfg = self.cfg
        fam = cfg.family
        if fam in ("dense", "vlm", "audio"):
            return {
                "ln1": norm_def(cfg), "attn": attn_defs(cfg),
                "ln2": norm_def(cfg), "ffn": ffn_defs(cfg),
            }
        if fam == "moe":
            return {
                "ln1": norm_def(cfg), "attn": attn_defs(cfg),
                "ln2": norm_def(cfg), "moe": moe_defs(cfg),
            }
        if fam in ("ssm", "hybrid"):
            return {"ln": norm_def(cfg), "ssm": ssm_defs(cfg)}
        raise ValueError(fam)

    def param_defs(self) -> dict:
        cfg = self.cfg
        layer = self._layer_defs()
        stacked = jax.tree.map(
            lambda d: d.stacked(cfg.n_layers), layer,
            is_leaf=lambda x: hasattr(x, "stacked"))
        out = {"embed": embed_defs(cfg), "layers": stacked,
               "final_norm": norm_def(cfg)}
        if cfg.family == "hybrid":
            out["shared_attn"] = {
                "ln1": norm_def(cfg), "attn": attn_defs(cfg),
                "ln2": norm_def(cfg), "ffn": ffn_defs(cfg),
            }
        return out

    def init(self, key) -> dict:
        return init_params(self.param_defs(), key, self.cfg.pdtype)

    def abstract(self) -> dict:
        return abstract_params(self.param_defs(), self.cfg.pdtype)

    def axes(self) -> dict:
        return logical_axes(self.param_defs())

    # ------------------------------------------------------------------
    # Layer bodies
    # ------------------------------------------------------------------

    def _attn_block(self, x, p, positions, prefix_len=0, cache=None,
                    cache_index=None, window=0):
        cfg = self.cfg
        h, new_cache = attention(
            apply_norm(x, p["ln1"], cfg.norm), p["attn"], cfg, positions,
            prefix_len=prefix_len, cache=cache, cache_index=cache_index,
            window=window)
        x = x + h
        ffn_in = apply_norm(x, p["ln2"], cfg.norm)
        if cfg.family == "moe":
            f, aux, load = moe_ffn(ffn_in, p["moe"], cfg)
        else:
            f, aux, load = swiglu(ffn_in, p["ffn"]), 0.0, None
        return x + f, new_cache, aux, load

    def _ssm_layer(self, x, p, state=None):
        cfg = self.cfg
        h, new_state = ssm_block(
            apply_norm(x, p["ln"], cfg.norm), p["ssm"], cfg, state=state)
        return x + h, new_state

    # ------------------------------------------------------------------
    # Forward (train) — also used for prefill via return_cache
    # ------------------------------------------------------------------

    def _inputs_to_h0(self, params, batch) -> Tuple[jax.Array, jax.Array, int]:
        """-> (h0 (B,S,D), positions (B,S), prefix_len)."""
        cfg = self.cfg
        if cfg.family == "vlm":
            tok = embed(batch["tokens"], params["embed"], cfg)
            pe = batch["patch_embeds"].astype(cfg.adtype)
            h0 = jnp.concatenate([pe, tok], axis=1)
            prefix = cfg.n_frontend_tokens
        elif cfg.family == "audio" or cfg.frontend_is_embedding:
            h0 = batch["embeds"].astype(cfg.adtype)
            prefix = 0
        else:
            h0 = embed(batch["tokens"], params["embed"], cfg)
            prefix = 0
        B, S = h0.shape[0], h0.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                     (B, S))
        return lc(h0, "batch", "seq", "act_embed"), positions, prefix

    def forward(self, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        """Full-sequence forward -> (logits, metrics)."""
        cfg = self.cfg
        h0, positions, prefix = self._inputs_to_h0(params, batch)

        if cfg.family in ("dense", "moe", "vlm", "audio"):
            def body(x, lp):
                x, _, aux, load = self._attn_block(
                    x, lp, positions, prefix_len=prefix)
                return x, (jnp.asarray(aux, jnp.float32),
                           load if load is not None else jnp.zeros((1,)))
            body = _remat(body, self.remat)
            x, (auxs, loads) = jax.lax.scan(body, h0, params["layers"],
                                            unroll=self.unroll)
            metrics = {"aux_loss": auxs.sum()}
            if cfg.family == "moe":
                metrics["expert_load"] = loads  # (L, E)
        elif cfg.family == "ssm":
            def body(x, lp):
                x, _ = self._ssm_layer(x, lp)
                return x, ()
            body = _remat(body, self.remat)
            x, _ = jax.lax.scan(body, h0, params["layers"],
                                unroll=self.unroll)
            metrics = {"aux_loss": jnp.float32(0.0)}
        elif cfg.family == "hybrid":
            sp = params["shared_attn"]
            every = cfg.attn_every

            def body(carry, inp):
                x = carry
                i, lp = inp

                def with_attn(x):
                    y, _, _, _ = self._attn_block(x, sp, positions)
                    return y

                x = jax.lax.cond(i % every == 0, with_attn, lambda x: x, x)
                x, _ = self._ssm_layer(x, lp)
                return x, ()
            body = _remat(body, self.remat)
            idx = jnp.arange(cfg.n_layers)
            x, _ = jax.lax.scan(body, h0, (idx, params["layers"]),
                                unroll=self.unroll)
            metrics = {"aux_loss": jnp.float32(0.0)}
        else:
            raise ValueError(cfg.family)

        x = apply_norm(x, params["final_norm"], cfg.norm)
        logits = unembed(x, params["embed"], cfg)
        if cfg.family == "vlm":
            logits = logits[:, cfg.n_frontend_tokens:]
        return logits, metrics

    # ------------------------------------------------------------------
    # Loss
    # ------------------------------------------------------------------

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.cfg
        logits, metrics = self.forward(params, batch)
        labels = batch["labels"]
        mask = batch.get("mask")
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
        nll = logz - gold
        if mask is None:
            mask = jnp.ones_like(nll)
        denom = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / denom
        total = ce + cfg.router_aux_weight * metrics["aux_loss"]
        metrics = dict(metrics, ce=ce, loss=total)
        return total, metrics

    # ------------------------------------------------------------------
    # Serving: prefill + single-token decode
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, length: int) -> Cache:
        cfg = self.cfg
        dt = cfg.adtype
        kv = ()
        ssm = ()
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            kv = jax.tree.map(
                lambda x: jnp.stack([x] * cfg.n_layers),
                init_kv_cache(cfg, batch, length, dt))
        elif cfg.family == "ssm":
            ssm = jax.tree.map(
                lambda x: jnp.stack([x] * cfg.n_layers),
                init_ssm_state(cfg, batch, dt))
        elif cfg.family == "hybrid":
            n_calls = cfg.n_shared_attn_calls
            win = cfg.attn_window or length
            kv = jax.tree.map(
                lambda x: jnp.stack([x] * n_calls),
                init_kv_cache(cfg, batch, min(win, length), dt))
            ssm = jax.tree.map(
                lambda x: jnp.stack([x] * cfg.n_layers),
                init_ssm_state(cfg, batch, dt))
        return Cache(kv=kv, ssm=ssm,
                     index=jnp.zeros((batch,), jnp.int32))

    def prefill(self, params, batch, cache_len: int, true_lens=None
                ) -> Tuple[jax.Array, Cache]:
        """Run the full prompt, building the decode cache.

        For attention families the K/V of every position land in the cache;
        for SSM families only the final recurrent state is kept (that is
        the whole point of the assigned ``long_500k`` shape).

        ``true_lens`` (B,) i32 supports right-padded prompts for attention
        families: cache positions beyond a request's true length are
        marked empty (-1) and the returned logits are taken at each
        request's last real token.  SSM/hybrid state absorbs every fed
        token, so serving callers must feed exact-length prompts there
        (the scheduler's pow2 buckets are exact for those families).
        """
        cfg = self.cfg
        h0, positions, prefix = self._inputs_to_h0(params, batch)
        B, S = h0.shape[0], h0.shape[1]
        cache = self.init_cache(B, cache_len)
        if true_lens is not None:
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError(
                    "padded prefill is unsupported for SSM state "
                    "(see docstring); feed exact-length prompts")
            store_pos = jnp.where(
                positions < true_lens[:, None], positions, -1)
        else:
            store_pos = positions

        if cfg.family in ("dense", "moe", "vlm", "audio"):
            def body(x, inp):
                lp, kv = inp
                xin = apply_norm(x, lp["ln1"], cfg.norm)
                # Full-sequence attention; also emit K/V for the cache.
                h, _ = attention(xin, lp["attn"], cfg, positions,
                                 prefix_len=prefix)
                k = jnp.einsum("bsd,dhk->bshk", xin,
                               lp["attn"]["wk"].astype(x.dtype))
                v = jnp.einsum("bsd,dhk->bshk", xin,
                               lp["attn"]["wv"].astype(x.dtype))
                from .layers import rope
                k = rope(k, positions, cfg.rope_theta)
                x = x + h
                fin = apply_norm(x, lp["ln2"], cfg.norm)
                if cfg.family == "moe":
                    f, _, _ = moe_ffn(fin, lp["moe"], cfg)
                else:
                    f = swiglu(fin, lp["ffn"])
                nk = kv.k.at[:, :S].set(k.astype(kv.k.dtype))
                nv = kv.v.at[:, :S].set(v.astype(kv.v.dtype))
                npos = kv.pos.at[:, :S].set(store_pos)
                return x + f, KVCache(nk, nv, npos)
            x, kv = jax.lax.scan(body, h0, (params["layers"], cache.kv),
                                 unroll=self.unroll)
            cache = cache._replace(kv=kv)
        elif cfg.family == "ssm":
            def body(x, inp):
                lp, st = inp
                h, new_st = ssm_block(
                    apply_norm(x, lp["ln"], cfg.norm), lp["ssm"], cfg)
                return x + h, new_st
            x, ssm = jax.lax.scan(body, h0, (params["layers"], cache.ssm),
                                   unroll=self.unroll)
            cache = cache._replace(ssm=ssm)
        elif cfg.family == "hybrid":
            sp = params["shared_attn"]
            every = cfg.attn_every
            win = cfg.attn_window or cache_len
            kv_cache = cache.kv

            def body(carry, inp):
                x, kv_all = carry
                i, lp = inp

                def with_attn(args):
                    x, kv_all = args
                    call = i // every
                    xin = apply_norm(x, sp["ln1"], cfg.norm)
                    h, _ = attention(xin, sp["attn"], cfg, positions,
                                     window=win)
                    from .layers import rope
                    k = jnp.einsum("bsd,dhk->bshk", xin,
                                   sp["attn"]["wk"].astype(x.dtype))
                    v = jnp.einsum("bsd,dhk->bshk", xin,
                                   sp["attn"]["wv"].astype(x.dtype))
                    k = rope(k, positions, cfg.rope_theta)
                    x = x + h
                    x = x + swiglu(apply_norm(x, sp["ln2"], cfg.norm),
                                   sp["ffn"])
                    # Ring-write the last `win` positions.
                    T = kv_all.k.shape[2]
                    keep = min(S, T)
                    slots = (positions[:, -keep:]) % T
                    bidx = jnp.arange(B)[:, None]
                    nk = kv_all.k.at[call, bidx, slots].set(
                        k[:, -keep:].astype(kv_all.k.dtype))
                    nv = kv_all.v.at[call, bidx, slots].set(
                        v[:, -keep:].astype(kv_all.v.dtype))
                    npos = kv_all.pos.at[call, bidx, slots].set(
                        positions[:, -keep:])
                    return x, KVCache(nk, nv, npos)

                x, kv_all = jax.lax.cond(
                    i % every == 0, with_attn, lambda a: a, (x, kv_all))
                x, new_st = self._ssm_layer(x, lp, state=None)
                return (x, kv_all), new_st

            idx = jnp.arange(cfg.n_layers)
            (x, kv_cache), ssm = jax.lax.scan(
                body, (h0, kv_cache), (idx, params["layers"]),
                unroll=self.unroll)
            cache = cache._replace(kv=kv_cache, ssm=ssm)
        else:
            raise ValueError(cfg.family)

        x = apply_norm(x, params["final_norm"], cfg.norm)
        if true_lens is not None:
            last = jnp.clip(true_lens - 1, 0, S - 1)
            x_last = x[jnp.arange(B), last][:, None]
            cache = cache._replace(index=true_lens)
        else:
            x_last = x[:, -1:]
            cache = cache._replace(index=jnp.full((B,), S, jnp.int32))
        logits = unembed(x_last, params["embed"], cfg)
        return logits, cache

    def decode_step(self, params, cache: Cache, tokens
                    ) -> Tuple[jax.Array, Cache]:
        """One token per request.  tokens: (B, 1) i32 (or (B,1,D) embeds)."""
        cfg = self.cfg
        if cfg.family == "audio" or cfg.frontend_is_embedding:
            x = tokens.astype(cfg.adtype)  # (B, 1, D) frame embedding
            B = x.shape[0]
        else:
            x = embed(tokens, params["embed"], cfg)
            B = tokens.shape[0]
        positions = cache.index[:, None]  # (B, 1)

        if cfg.family in ("dense", "moe", "vlm", "audio"):
            def body(x, inp):
                lp, kv = inp
                x, new_kv, _, _ = self._attn_block(
                    x, lp, positions, cache=kv, cache_index=cache.index)
                return x, new_kv
            x, kv = jax.lax.scan(body, x, (params["layers"], cache.kv),
                                 unroll=self.unroll)
            cache = cache._replace(kv=kv)
        elif cfg.family == "ssm":
            def body(x, inp):
                lp, st = inp
                x, new_st = self._ssm_layer(x, lp, state=st)
                return x, new_st
            x, ssm = jax.lax.scan(body, x, (params["layers"], cache.ssm),
                                   unroll=self.unroll)
            cache = cache._replace(ssm=ssm)
        elif cfg.family == "hybrid":
            sp = params["shared_attn"]
            every = cfg.attn_every
            win = cfg.attn_window

            def body(carry, inp):
                x, kv_all = carry
                i, lp, st = inp

                def with_attn(args):
                    x, kv_all = args
                    call = i // every
                    kv = jax.tree.map(lambda a: a[call], kv_all)
                    xin = apply_norm(x, sp["ln1"], cfg.norm)
                    h, new_kv = attention(
                        xin, sp["attn"], cfg, positions, cache=kv,
                        cache_index=cache.index,
                        window=win if win else 0)
                    x = x + h
                    x = x + swiglu(apply_norm(x, sp["ln2"], cfg.norm),
                                   sp["ffn"])
                    kv_all = jax.tree.map(
                        lambda a, n: a.at[call].set(n), kv_all, new_kv)
                    return x, kv_all

                x, kv_all = jax.lax.cond(
                    i % every == 0, with_attn, lambda a: a, (x, kv_all))
                x, new_st = self._ssm_layer(x, lp, state=st)
                return (x, kv_all), new_st

            idx = jnp.arange(cfg.n_layers)
            (x, kv), ssm = jax.lax.scan(
                body, (x, cache.kv), (idx, params["layers"], cache.ssm),
                unroll=self.unroll)
            cache = cache._replace(kv=kv, ssm=ssm)
        else:
            raise ValueError(cfg.family)

        x = apply_norm(x, params["final_norm"], cfg.norm)
        logits = unembed(x, params["embed"], cfg)
        cache = cache._replace(index=cache.index + 1)
        return logits, cache
