"""Fine-grained mixture-of-experts FFN (DeepSeekMoE / DBRX style).

Shared experts (always active) + top-k routed experts with sort-based
capacity dispatch:

1. router logits -> fp32 softmax -> top-k (weight renormalized);
2. flatten the (token, slot) assignments, sort by expert id, rank within
   each expert group and drop overflow beyond capacity ``C`` (static shape);
3. gather tokens into an ``(E, C, D)`` buffer;
4. batched per-expert SwiGLU via ``(E, C, D) x (E, D, F)`` einsums;
5. weighted scatter-add back to token order.

Two execution paths:

* **dense/pjit** (no mesh, or no expert-parallel axis): the steps above as
  plain jnp — used by CPU smoke tests and single-device runs.
* **explicit expert parallelism** (`shard_map`): XLA's SPMD partitioner
  cannot shard a *global* sort/scatter dispatch — left to pjit it
  all-gathers the token stream per shard (the dry-run measured a 3.7
  TB/device program for deepseek-moe train_4k).  Under ``shard_map`` each
  data shard dispatches its LOCAL tokens into per-expert buffers and a
  single ``all_to_all`` over the ``model`` axis routes them to their
  expert's owner — the canonical GShard pattern, with wire cost
  ``T_local · top_k · D`` per direction per layer.

The layer returns the per-expert token load — the "arrival rate" statistic
that the adaptive placement governor (``repro.adaptive``) monitors with the
paper's invariant machinery — plus the Switch-style load-balance auxiliary
loss.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.sharding import current_rules, logical_constraint as lc
from .config import ModelConfig
from .layers import ffn_defs, swiglu
from .params import ParamDef


def moe_defs(cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    out = {
        "router": ParamDef((d, e), ("embed", "experts"), scale=0.02),
        "w_gate": ParamDef((e, d, f), ("experts", "embed", "ff")),
        "w_up": ParamDef((e, d, f), ("experts", "embed", "ff")),
        "w_down": ParamDef((e, f, d), ("experts", "ff", "embed")),
    }
    if cfg.n_shared_experts > 0:
        # Shared experts fused into one wide SwiGLU.
        out["shared"] = ffn_defs(cfg, d_ff=cfg.n_shared_experts * f)
    return out


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8 for lane alignment


def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig,
            expert_perm: jax.Array | None = None
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss, expert_load (E,)).

    ``expert_perm`` (optional, (E,) i32) applies a logical->physical expert
    relabeling before dispatch — the adaptive placement governor's output.
    Routing decisions are unaffected (weights follow the permutation); only
    *where* each expert's tokens land changes.
    """
    rules = current_rules()
    if (rules is not None and rules.mesh is not None
            and rules.mesh.shape.get("model", 1) > 1
            and cfg.n_experts % rules.mesh.shape["model"] == 0):
        mesh = rules.mesh
        n_dp = 1
        for a in ("pod", "data"):
            n_dp *= mesh.shape.get(a, 1)
        if x.shape[0] % n_dp == 0:
            return _moe_ffn_ep(x, p, cfg, mesh, expert_perm)
    return _moe_ffn_dense(x, p, cfg, expert_perm)


def _moe_ffn_dense(x, p, cfg, expert_perm=None):
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    C = capacity(cfg, T)
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (T, E)
    top_w, top_e = jax.lax.top_k(probs, K)                        # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    if expert_perm is not None:
        top_e = jnp.take(expert_perm, top_e)

    # Load-balance auxiliary loss (Switch): E * sum_e f_e * P_e.
    mean_probs = probs.mean(axis=0)                               # (E,)
    frac = jnp.zeros(E, jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (T * K))
    aux = E * jnp.sum(frac * mean_probs)
    expert_load = frac * T * K                                    # tokens/e

    # ---- sort-based dispatch -------------------------------------------
    flat_e = top_e.reshape(-1)                                    # (T*K,)
    flat_w = top_w.reshape(-1).astype(x.dtype)
    flat_t = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(flat_e)                                   # stable
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(E))                  # (E,)
    rank = jnp.arange(T * K) - starts[se]
    keep = rank < C
    dest = jnp.where(keep, se * C + rank, E * C)                  # drop slot

    buf = jnp.zeros((E * C, D), x.dtype).at[dest].set(
        xt[st], mode="drop").reshape(E, C, D)
    buf = lc(buf, "experts", "expert_cap", "act_embed")

    # ---- per-expert SwiGLU ---------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    h = lc(h, "experts", "expert_cap", "ff")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(x.dtype))
    out_buf = lc(out_buf, "experts", "expert_cap", "act_embed")

    # ---- weighted combine ----------------------------------------------
    flat_out = out_buf.reshape(E * C, D)
    vals = jnp.take(flat_out, jnp.minimum(dest, E * C - 1), axis=0)
    vals = jnp.where(keep[:, None], vals, 0.0) * sw[:, None]
    out = jnp.zeros((T, D), x.dtype).at[st].add(vals)

    if cfg.n_shared_experts > 0:
        out = out + swiglu(x, p["shared"]).reshape(T, D)

    return (lc(out.reshape(B, S, D), "batch", "seq", "act_embed"),
            aux.astype(jnp.float32), expert_load)


# ---------------------------------------------------------------------------
# Explicit expert parallelism (shard_map) — see module docstring.
# ---------------------------------------------------------------------------


def _local_dispatch(xt, probs, top_w, top_e, E, K, C, dtype):
    """Sort-based dispatch of LOCAL tokens into (E, C, D) buffers."""
    T, D = xt.shape
    flat_e = top_e.reshape(-1)
    flat_w = top_w.reshape(-1).astype(dtype)
    flat_t = jnp.repeat(jnp.arange(T), K)
    order = jnp.argsort(flat_e)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(E))
    rank = jnp.arange(T * K) - starts[se]
    keep = rank < C
    dest = jnp.where(keep, se * C + rank, E * C)
    buf = jnp.zeros((E * C, D), dtype).at[dest].set(
        xt[st], mode="drop").reshape(E, C, D)
    return buf, (se, st, sw, keep, dest)


def _moe_ffn_ep(x, p, cfg: ModelConfig, mesh, expert_perm=None):
    """Expert-parallel MoE with explicit all-to-all over the model axis.

    Per shard: local top-k routing -> local (E, C_loc, D) buffers ->
    all_to_all sends each expert group to its owner -> local-expert SwiGLU
    over (E_loc, n_ep*C_loc, D) -> reverse all_to_all -> weighted combine.
    """
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    n_ep = mesh.shape["model"]
    E_loc = E // n_ep
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_dp = 1
    for a in batch_axes:
        n_dp *= mesh.shape[a]
    B_loc = B // n_dp if B % n_dp == 0 else B
    T_loc = B_loc * S
    adt = x.dtype

    perm = (expert_perm if expert_perm is not None
            else jnp.arange(E, dtype=jnp.int32))

    # §Perf lever: dispatch from sequence-sharded tokens.  Activations are
    # replicated over the model axis, so each model shard can own 1/n_ep
    # of the local tokens: the dispatch all_to_all payload shrinks n_ep×
    # at the cost of one output all-gather over "model".
    seq_shard = cfg.moe_seq_shard and (T_loc % n_ep == 0)
    T_disp = T_loc // n_ep if seq_shard else T_loc
    C = capacity(cfg, T_disp)

    def local_fn(x_loc, router, wg, wu, wd, perm_):
        # x_loc: (B_loc, S, D); router: (D, E) replicated;
        # wg/wu/wd: (E_loc, D, F) local experts.
        xt = x_loc.reshape(-1, D)
        if seq_shard:
            me = jax.lax.axis_index("model")
            xt = jax.lax.dynamic_slice_in_dim(xt, me * T_disp, T_disp, 0)
        logits = jnp.einsum("td,de->te", xt, router.astype(adt))
        probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        top_w, top_e = jax.lax.top_k(probs, K)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        top_e = jnp.take(perm_, top_e)

        # Statistics (summed over data; and over model when seq-sharded).
        mean_probs = probs.mean(axis=0)
        frac = jnp.zeros(E, jnp.float32).at[top_e.reshape(-1)].add(
            1.0 / (T_disp * K))
        aux = E * jnp.sum(frac * mean_probs)
        load_loc = frac * T_disp * K
        stat_axes = batch_axes + (("model",) if seq_shard else ())
        if stat_axes:
            aux = jax.lax.pmean(aux, stat_axes)
            load = jax.lax.psum(load_loc, stat_axes)
        else:
            load = load_loc

        buf, (se, st, sw, keep, dest) = _local_dispatch(
            xt, probs, top_w, top_e, E, K, C, adt)

        # (E, C, D) -> (n_ep, E_loc*C, D) -> all_to_all -> peers' tokens
        # for MY experts: (n_ep, E_loc*C, D).
        send = buf.reshape(n_ep, E_loc * C, D)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=False)
        work = recv.reshape(n_ep, E_loc, C, D).transpose(1, 0, 2, 3) \
            .reshape(E_loc, n_ep * C, D)

        g = jnp.einsum("ecd,edf->ecf", work, wg.astype(adt))
        u = jnp.einsum("ecd,edf->ecf", work, wu.astype(adt))
        h = jax.nn.silu(g) * u
        out_w = jnp.einsum("ecf,efd->ecd", h, wd.astype(adt))

        # Reverse route.
        back = out_w.reshape(E_loc, n_ep, C, D).transpose(1, 0, 2, 3) \
            .reshape(n_ep, E_loc * C, D)
        ret = jax.lax.all_to_all(back, "model", split_axis=0,
                                 concat_axis=0, tiled=False)
        flat_out = ret.reshape(E * C, D)

        vals = jnp.take(flat_out, jnp.minimum(dest, E * C - 1), axis=0)
        vals = jnp.where(keep[:, None], vals, 0.0) * sw[:, None]
        out = jnp.zeros((T_disp, D), adt).at[st].add(vals)
        if seq_shard:
            out = jax.lax.all_gather(
                out, "model", axis=0, tiled=True)  # (T_loc, D)
        return out.reshape(x_loc.shape), aux, load

    bspec = batch_axes[0] if len(batch_axes) == 1 else batch_axes
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(P(bspec, None, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None), P(None)),
        out_specs=(P(bspec, None, None), P(), P()),
        check_rep=False)
    out, aux, load = fn(x, p["router"], p["w_gate"], p["w_up"],
                        p["w_down"], perm)

    if cfg.n_shared_experts > 0:
        out = out + swiglu(x, p["shared"])
    return (lc(out, "batch", "seq", "act_embed"), aux.astype(jnp.float32),
            load)
