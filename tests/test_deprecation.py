"""Legacy class-ladder shims: every pre-facade entry point warns, routes
to the same machinery the facade drives, and behaves identically."""

import dataclasses
import warnings

import pytest

from repro import cep
from repro.cep import OrderPlan, RuntimeConfig
from repro.core.engine import EngineConfig, MonitoredEngine, make_engine
from repro.core.fleet import FleetRunner, MonitoredFleetRunner, stacked_streams
from repro.core.patterns import chain_predicates, seq_pattern
from repro.data.cep_streams import StreamConfig, make_stream
from repro.serving.engine import (CEPFleetServingEngine,
                                  MonitoredCEPFleetServingEngine)

PAT = seq_pattern([0, 1, 2], 4.0, chain_predicates([0, 1, 2], theta=-0.3))
CFG = EngineConfig(b_cap=64, m_cap=512)


def _one_warning(record):
    msgs = [str(w.message) for w in record
            if issubclass(w.category, DeprecationWarning)]
    assert len(msgs) == 1, msgs
    assert "repro.cep" in msgs[0]


def test_legacy_constructors_warn():
    for ctor in (
        lambda: make_engine("order", PAT, CFG),
        lambda: MonitoredEngine("order", PAT, CFG),
        lambda: FleetRunner(PAT, 2, engine_cfg=CFG),
        lambda: MonitoredFleetRunner(PAT, 2, engine_cfg=CFG),
        lambda: CEPFleetServingEngine(PAT, 2, OrderPlan((0, 1, 2)), CFG),
        lambda: MonitoredCEPFleetServingEngine(PAT, 2, CFG),
    ):
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            ctor()
        _one_warning(rec)


def test_facade_is_warning_free():
    """Internal construction through the facade must not surface the
    ladder deprecation warnings to the user."""
    scfg = StreamConfig(n_types=3, n_chunks=4, chunk_cap=64, base_rate=6.0,
                        seed=2)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        sess = cep.open(PAT, partitions=1, plan="order", monitor=True,
                        config=RuntimeConfig(buffer_capacity=64,
                                             match_capacity=512))
        sess.run(make_stream("traffic", scfg))
        sess.step(next(iter(make_stream("traffic", scfg))).chunk, 0.0, 1.0)


def test_legacy_runner_equivalent_to_session():
    """Shim equivalence: the deprecated FleetRunner and the facade produce
    bit-identical per-partition counts on the same drifting streams."""
    k = 2
    scfg = StreamConfig(n_types=3, n_chunks=8, chunk_cap=128, base_rate=8.0)

    def streams():
        return [make_stream("stocks", dataclasses.replace(scfg, seed=41 + p))
                for p in range(k)]

    with pytest.warns(DeprecationWarning, match="repro.cep"):
        legacy = FleetRunner(PAT, k, planner="greedy",
                             engine_cfg=EngineConfig(b_cap=64, m_cap=1024))
    legacy_m = legacy.run(stacked_streams(streams()))

    sess = cep.open(PAT, partitions=k, plan="order",
                    config=RuntimeConfig(buffer_capacity=64,
                                         match_capacity=1024, policy=None))
    tel = sess.run(streams())
    assert (tel.per_partition_matches.tolist()
            == legacy_m.per_partition_matches.tolist())
    assert tel.matches == legacy_m.full_matches
