"""``cep.Session`` differentials: the facade must cover every legacy
configuration bit-identically.

The acceptance grid: plan ∈ {order, tree} × monitored ∈ {on, off} ×
K ∈ {1, 4} — eight configurations that used to be eight classes.  For each,
the session's per-partition match counts must equal (a) the legacy
runner's, constructed with the same knobs and seed, and (b) the brute-force
``ref_engine`` oracle.  OR-composite sessions must match the per-branch
oracle sums end-to-end over drifting streams."""

import dataclasses
import warnings

import numpy as np
import pytest

from repro import cep
from repro.cep import P, RefEngine, RuntimeConfig
from repro.core.decision import InvariantPolicy, make_policy
from repro.core.engine import EngineConfig
from repro.core.fleet import FleetRunner, MonitoredFleetRunner, stacked_streams
from repro.core.plans import OrderPlan
from repro.data.cep_streams import StreamConfig, make_stream

PATTERN = (P.seq(0, 1, 2)
           .where(P.attr(0) < P.attr(1) - 0.3,
                  P.attr(1) < P.attr(2) - 0.3)
           .within(4.0))
SCFG = StreamConfig(n_types=3, n_chunks=10, chunk_cap=128, base_rate=8.0)
CONFIG = RuntimeConfig(buffer_capacity=64, match_capacity=1024,
                       max_invariants=8, max_terms=16)


def streams(k, seed=11, kind="traffic"):
    return [make_stream(kind, dataclasses.replace(SCFG, seed=seed + p))
            for p in range(k)]


def oracle_counts(pattern, k, seed=11, kind="traffic"):
    return [RefEngine(pattern).run(s).full_matches
            for s in streams(k, seed, kind)]


# plan × monitored × K × superchunk.  superchunk > 1 applies to monitored
# sessions only (host decision policies need per-chunk statistics); the
# scanned tree-plan combinations are the compile-heaviest of the suite and
# ride under the `slow` marker.
_GRID = [
    pytest.param(plan, monitored, k, s,
                 marks=((pytest.mark.slow,)
                        if s > 1 and (plan == "tree" or k == 1) else ()))
    for plan in ("order", "tree")
    for monitored in (False, True)
    for k in (1, 4)
    for s in ((1, 8) if monitored else (1,))
]


@pytest.mark.parametrize("plan,monitored,k,superchunk", _GRID)
def test_session_covers_legacy_grid(plan, monitored, k, superchunk):
    """One facade, eight legacy configurations (plus the scanned variants):
    session == legacy per-chunk runner == oracle, bit-identical."""
    sess = cep.open(PATTERN, partitions=k, plan=plan, monitor=monitored,
                    config=CONFIG, superchunk=superchunk)
    tel = sess.run(streams(k))

    planner = "greedy" if plan == "order" else "zstream"
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        if monitored:
            legacy = MonitoredFleetRunner(
                PATTERN.build(), k, planner=planner,
                policy_factory=lambda: InvariantPolicy(k=1, d=0.0),
                engine_cfg=EngineConfig(b_cap=64, m_cap=1024),
                max_inv=8, max_terms=16, seed=0)
        else:
            legacy = FleetRunner(
                PATTERN.build(), k, planner=planner,
                policy_factory=lambda: make_policy("invariant", k=1, d=0.0),
                engine_cfg=EngineConfig(b_cap=64, m_cap=1024), seed=0)
    legacy_m = legacy.run(stacked_streams(streams(k)))

    oracle = oracle_counts(PATTERN.build(), k)
    got = tel.per_partition_matches.tolist()
    assert got == legacy_m.per_partition_matches.tolist()
    assert got == oracle
    assert tel.matches == sum(oracle)
    assert tel.chunks == SCFG.n_chunks
    if monitored:
        assert tel.host_syncs == tel.violations  # O(violations) host work
        # Scanned control must hit the per-chunk loop's exact replan
        # points and deployments, not just its match counts.
        assert tel.violations == legacy_m.violations
        assert tel.replans == legacy_m.replans
        assert tel.deployments == legacy_m.deployments


@pytest.mark.parametrize("k", [1, 4])
def test_or_composite_session_vs_oracle(k):
    """Satellite: session-built OR patterns over drifting streams == oracle,
    branch by branch and in aggregate, for K in {1, 4}."""
    b_seq = PATTERN
    b_and = (P.and_(0, 2)
             .where(abs(P.attr(0) - P.attr(1)) <= 1.0)
             .within(3.0))
    sess = cep.open(P.or_(b_seq, b_and), partitions=k, plan="order",
                    config=CONFIG)
    tel = sess.run(streams(k, seed=23, kind="stocks"))

    per_branch_oracle = [
        np.asarray(oracle_counts(b.build(), k, seed=23, kind="stocks"))
        for b in (b_seq, b_and)
    ]
    assert tel.branches is not None and len(tel.branches) == 2
    for branch_tel, want in zip(tel.branches, per_branch_oracle):
        assert branch_tel.per_partition_matches.tolist() == want.tolist()
    total = sum(per_branch_oracle)
    assert tel.per_partition_matches.tolist() == total.tolist()
    assert tel.matches == int(total.sum())


def test_or_composite_serving_plane(rng):
    """Keyed batches through a composite session: aggregated counts match
    the per-branch oracles on the routed sub-streams."""
    k = 2
    b1 = P.seq(0, 1).within(6.0)
    b2 = P.seq(2, 1).within(6.0)
    sess = cep.open(P.or_(b1, b2), partitions=k, plan="order",
                    config=dataclasses.replace(CONFIG, policy=None))
    n = 120
    ts = np.sort(rng.uniform(0, 12, n)).astype(np.float32)
    tid = rng.integers(0, 3, n).astype(np.int32)
    attr = rng.normal(size=(n, 1)).astype(np.float32)
    keys = rng.integers(0, 50, n)
    got = np.zeros(k, np.int64)
    for s in range(3):
        t0, t1 = 4.0 * s, 4.0 * (s + 1)
        m = (ts > t0) & (ts <= t1)
        got += sess.process(tid[m], ts[m], attr[m], keys[m], t0, t1)
    want = np.zeros(k, np.int64)
    for b in (b1, b2):
        for p in range(k):
            ref = RefEngine(b.build())
            sel = (keys % k) == p
            for s in range(3):
                t0, t1 = 4.0 * s, 4.0 * (s + 1)
                m = sel & (ts > t0) & (ts <= t1)
                want[p] += ref.process_chunk(tid[m], ts[m], attr[m],
                                             t0, t1).full_matches
    assert got.tolist() == want.tolist()
    assert sess.telemetry().matches == int(want.sum())


def test_session_step_deploy_reset():
    """Incremental plane: step == run counts; deploy is a row write;
    reset clears stream state but keeps deployed plans."""
    sess = cep.open(PATTERN, partitions=1, plan="order",
                    config=dataclasses.replace(CONFIG, policy=None))
    sess.deploy(0, OrderPlan((2, 1, 0)))
    recs = list(streams(1)[0])
    total = np.zeros(1, np.int64)
    for rec in recs:
        total += sess.step(rec.chunk, rec.t0, rec.t1)
    oracle = oracle_counts(PATTERN.build(), 1)
    assert total.tolist() == oracle
    tel = sess.telemetry()
    assert tel.matches == oracle[0]
    assert tel.chunks == len(recs)
    assert tel.deployments == 1

    sess.reset()
    assert sess.telemetry().matches == 0
    for rec in recs:
        sess.step(rec.chunk, rec.t0, rec.t1)
    assert sess.telemetry().matches == oracle[0]  # plans survived the reset


def test_composite_mixed_plane_chunk_accounting():
    """Composite telemetry counts shared input once, across both planes."""
    comp = P.or_(P.seq(0, 1).within(5.0), P.seq(2, 1).within(5.0))
    sess = cep.open(comp, partitions=1, plan="order",
                    config=dataclasses.replace(CONFIG, policy=None))
    recs = list(streams(1, seed=53)[0])
    sess.run(recs)
    for rec in recs[:3]:
        sess.step(rec.chunk, rec.t0, rec.t1)
    tel = sess.telemetry()
    assert tel.chunks == len(recs) + 3
    assert tel.events == sum(r.n_events for r in recs)  # step() skips events


def test_monitored_serving_matches_plain(rng):
    """Monitored incremental plane: fused monitoring + violation-triggered
    replans never change which matches are counted."""
    k = 4
    plain = cep.open(PATTERN, partitions=k, plan="order",
                     config=dataclasses.replace(CONFIG, policy=None))
    mon = cep.open(PATTERN, partitions=k, plan="order", monitor=True,
                   config=CONFIG)
    for fc in stacked_streams(streams(k, seed=31)):
        a = plain.step(fc.chunk, fc.t0, fc.t1)
        b = mon.step(fc.chunk, fc.t0, fc.t1)
        assert a.tolist() == b.tolist()
    tel = mon.telemetry()
    assert tel.matches == plain.telemetry().matches
    assert tel.host_syncs == tel.violations
    assert tel.last_drift is not None and tel.last_drift.shape == (k,)
