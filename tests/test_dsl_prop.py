"""Property tests: random ``P`` DSL trees vs the brute-force oracle.

Seed-driven generation (``tests/_prop.py``: hypothesis when installed, a
deterministic sweep otherwise) over the full representable tree space —
``seq``/``and_``/``or_`` with optional negation, Kleene closure, chained
attribute predicates and per-tree windows, depth <= 3 (an ``or_`` of
decorated sequence/conjunction branches).  Whatever the tree, a Session's
match count on a short random stream must equal the per-branch oracle sum.
"""

import numpy as np
import pytest

from _prop import given, settings, st
from repro import cep
from repro.cep import P, RuntimeConfig
from repro.core.ref_engine import RefEngine
from repro.data.cep_streams import emit_chunk

N_TYPES = 4


def random_branch(rng):
    """One non-composite builder: a decorated seq or and_ pattern."""
    n = int(rng.integers(2, 4))
    type_ids = list(rng.choice(N_TYPES, n, replace=False))
    kind = rng.random()
    window = float(rng.uniform(3.0, 8.0))
    if kind < 0.35:                       # plain AND conjunction
        b = P.and_(*type_ids)
    else:                                 # sequence, maybe neg/kleene
        elements = [int(t) for t in type_ids]
        deco = rng.random()
        if deco < 0.3 and n >= 2:
            spare = [t for t in range(N_TYPES) if t not in type_ids]
            if spare:
                pos = int(rng.integers(0, n + 1))
                elements.insert(pos, P.neg(int(spare[0])))
        elif deco < 0.6:
            pos = int(rng.integers(0, n))
            elements[pos] = P.kleene(elements[pos],
                                     bound=int(rng.integers(2, 4)))
        b = P.seq(*elements)
    # chained pairwise predicates between adjacent positive positions
    conds = []
    for p in range(n - 1):
        if rng.random() < 0.7:
            theta = float(rng.uniform(-0.5, 0.8))
            a, c = P.attr(p), P.attr(p + 1)
            conds.append(a < c + theta if rng.random() < 0.5
                         else a > c - theta)
    if conds:
        b = b.where(*conds)
    return b.within(window)


def random_tree(rng):
    """A random DSL tree of depth <= 3; returns (builder, branch builders)."""
    if rng.random() < 0.35:
        branches = [random_branch(rng) for _ in range(int(rng.integers(2, 4)))]
        return P.or_(*branches), branches
    b = random_branch(rng)
    return b, [b]


def random_records(rng, n_chunks=3):
    recs = []
    for c in range(n_chunks):
        rates = rng.uniform(1.0, 6.0, N_TYPES)
        attr_mean = rng.normal(0.0, 0.5, (N_TYPES, 1))
        recs.append(emit_chunk(rng, rates, attr_mean, float(c),
                               chunk_duration=1.0, chunk_cap=128))
    return recs


@settings(max_examples=12, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000))
def test_random_tree_session_equals_oracle(seed):
    rng = np.random.default_rng(seed)
    tree, branches = random_tree(rng)
    recs = random_records(rng)
    s = cep.open(tree, partitions=1,
                 config=RuntimeConfig(buffer_capacity=64,
                                      match_capacity=512))
    tel = s.run(recs)
    ref = sum(RefEngine(b.build()).run(recs).full_matches for b in branches)
    assert tel.matches == ref, (
        f"seed={seed}: session {tel.matches} != oracle {ref} for "
        + " | ".join(str(b.build()) for b in branches))


@settings(max_examples=6, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000))
def test_random_tree_fleet_equals_per_partition_oracle(seed):
    """Same property through the vmapped fleet plane (K=3): the stacked
    session must equal the sum of independent per-partition oracles."""
    rng = np.random.default_rng(seed + 77)
    tree, branches = random_tree(rng)
    streams = [random_records(rng) for _ in range(3)]
    s = cep.open(tree, partitions=3,
                 config=RuntimeConfig(buffer_capacity=64,
                                      match_capacity=512))
    tel = s.run(streams)
    ref = sum(RefEngine(b.build()).run(recs).full_matches
              for b in branches for recs in streams)
    assert tel.matches == ref


def test_dsl_validation_rejects_malformed_trees():
    with pytest.raises(ValueError):
        P.seq(0).within(5.0).build()                 # < 2 primitives
    with pytest.raises(ValueError):
        P.seq(0, 0, 1).within(5.0).build()           # duplicate type ids
    with pytest.raises(ValueError):
        P.seq(0, P.neg(2), P.kleene(1)).within(5.0).build()  # neg + kleene
    with pytest.raises(TypeError):
        bool(P.attr(0) < P.attr(1))                  # Cond is not a bool
