"""Logical-axis resolver: divisibility + duplicate-axis fallbacks; and a
subprocess lowering test on a multi-device host mesh."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.sharding import DEFAULT_RULES, MeshRules


class FakeMesh:
    """Just enough of a Mesh for the resolver (shape dict lookups)."""

    def __init__(self, shape):
        self.shape = shape


def rules(shape=None):
    return MeshRules(mesh=FakeMesh(shape or {"data": 4, "model": 8}),
                     rules=dict(DEFAULT_RULES))


def test_divisible_dims_shard():
    r = rules()
    spec = r.resolve((64, 32), ("embed", "heads"), "w")
    assert tuple(spec) == ("data", "model")
    assert not r.fallbacks


def test_indivisible_falls_back():
    r = rules()
    spec = r.resolve((64, 7), ("embed", "heads"), "w")  # 7 % 8 != 0
    assert tuple(spec) == ("data", None)
    assert len(r.fallbacks) == 1


def test_duplicate_axis_falls_back():
    r = rules()
    # experts -> model, ff -> model: second use must replicate.
    spec = r.resolve((16, 64, 128), ("experts", "embed", "ff"), "moe")
    assert tuple(spec) == ("model", "data", None)
    assert any("already used" in f for f in r.fallbacks)


def test_missing_mesh_axis_dropped():
    r = rules({"data": 4, "model": 8})  # no "pod" on single-pod mesh
    spec = r.resolve((32,), ("batch",), "tokens")
    assert tuple(spec) == ("data",)


def test_multi_axis_batch():
    r = rules({"pod": 2, "data": 4, "model": 8})
    spec = r.resolve((32, 128), ("batch", "seq"), "tokens")
    assert tuple(spec) == (("pod", "data"), None)


def test_unknown_logical_name_replicates():
    r = rules()
    spec = r.resolve((10,), ("no_such_axis",), "x")
    assert tuple(spec) == (None,)


@pytest.mark.slow
def test_small_mesh_lowering_subprocess():
    """Exercise real pjit lowering on an 8-device host platform — kept in
    a subprocess so the test session's jax stays single-device."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax
        from jax.sharding import AxisType
        from repro.configs import get_smoke
        from repro.models.model import Model
        from repro.train.optimizer import AdamWConfig
        from repro.train.train_step import lower_train_step, \\
            lower_serve_step
        from repro.launch import shapes as SL
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        SL.SHAPES["t"] = SL.ShapeSpec("t", "train", 64, 8)
        SL.SHAPES["d"] = SL.ShapeSpec("d", "decode", 64, 8)
        for arch in ("olmo-1b", "deepseek-moe-16b", "zamba2-1.2b"):
            cfg = get_smoke(arch).with_(param_dtype="bf16", dtype="bf16")
            m = Model(cfg, remat="full")
            lowered, _ = lower_train_step(m, AdamWConfig(), mesh, "t")
            lowered.compile()
            lowered, _ = lower_serve_step(m, mesh, "d")
            lowered.compile()
        print("LOWER_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=900)
    assert "LOWER_OK" in out.stdout, out.stderr[-2000:]
