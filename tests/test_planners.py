"""Plan generators: correctness vs brute force + DCS structure."""

import itertools

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.greedy import greedy_order_plan
from repro.core.patterns import chain_predicates, seq_pattern, and_pattern
from repro.core.plans import (OrderPlan, TreeNode, plan_cost, tree_cost)
from repro.core.stats import Stat
from repro.core.zstream import zstream_tree_plan


def rand_stat(rng, n, pattern=None, skew=1.0):
    """Random stats; pairs without a defined predicate get selectivity 1
    (the estimator's behaviour, paper §4.1) so the symbolic planners and
    the numeric cost oracle agree."""
    rates = rng.uniform(0.5, 20.0, n) ** skew
    sel = rng.uniform(0.05, 0.95, (n, n))
    sel = (sel + sel.T) / 2
    if pattern is not None:
        mask = np.ones((n, n), bool)
        for p, q in pattern.selectivity_pairs():
            mask[p, q] = mask[q, p] = False
        sel[mask] = 1.0
    np.fill_diagonal(sel, 1.0)
    return Stat(rates, sel)


def test_greedy_no_preds_sorts_by_rate(rng):
    pat = seq_pattern([0, 1, 2, 3], 10.0)
    stat = Stat(np.array([7.0, 1.0, 9.0, 3.0]), np.ones((4, 4)))
    plan, dcs = greedy_order_plan(pat, stat)
    assert plan.order == (1, 3, 0, 2)
    # min-sort DCS sizes: n-1, n-2, ..., 0 (paper §3.1)
    assert [len(c) for _, c in dcs] == [3, 2, 1, 0]


def test_greedy_step_objective(rng):
    """Each greedy step must pick the argmin of the §4.1 expression."""
    pat = seq_pattern([0, 1, 2, 3], 10.0,
                      chain_predicates([0, 1, 2, 3], theta=0.2))
    pred_pairs = set(pat.selectivity_pairs())
    for trial in range(5):
        stat = rand_stat(np.random.default_rng(trial), 4)
        plan, _ = greedy_order_plan(pat, stat)
        chosen = []
        for step, j in enumerate(plan.order):
            remaining = [x for x in range(4) if x not in chosen]

            def score(c):
                # selectivity 1 where no predicate is defined (§4.1)
                v = stat.rates[c]
                for k in chosen:
                    if (min(k, c), max(k, c)) in pred_pairs:
                        v *= stat.sel[k, c]
                return v
            best = min(remaining, key=lambda c: (score(c), c))
            assert j == best
            chosen.append(j)


def _all_interval_trees(lo, hi):
    if hi - lo == 1:
        yield TreeNode(leaf=lo)
        return
    for k in range(lo + 1, hi):
        for left in _all_interval_trees(lo, k):
            for right in _all_interval_trees(k, hi):
                yield TreeNode(left=left, right=right)


@pytest.mark.parametrize("n", [2, 3, 4, 5])
def test_zstream_dp_optimal_vs_enumeration(n, rng):
    pat = seq_pattern(list(range(n)), 10.0,
                      chain_predicates(list(range(n)), theta=0.1))
    for trial in range(3):
        stat = rand_stat(np.random.default_rng(100 + trial), n, pat)
        plan, dcs = zstream_tree_plan(pat, stat)
        best = min(_all_interval_trees(0, n),
                   key=lambda t: tree_cost(t, stat))
        assert abs(tree_cost(plan.root, stat)
                   - tree_cost(best, stat)) < 1e-9


def test_zstream_dcs_counts():
    """Interval of length L has L-1 splits -> L-2 conditions per node."""
    n = 5
    pat = seq_pattern(list(range(n)), 10.0)
    stat = rand_stat(np.random.default_rng(7), n, pat)
    plan, dcs = zstream_tree_plan(pat, stat)
    assert len(dcs) == n - 1  # one DCS per internal node
    for block, conds in dcs:
        lo, hi = block.split(":")[1].split("..")
        length = int(hi) - int(lo) + 1
        assert len(conds) == length - 2


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_planners_deterministic(n, seed):
    rng = np.random.default_rng(seed)
    pat = and_pattern(list(range(n)), 10.0,
                      chain_predicates(list(range(n)), theta=0.3))
    stat = rand_stat(rng, n, pat)
    p1, _ = greedy_order_plan(pat, stat)
    p2, _ = greedy_order_plan(pat, stat)
    assert p1 == p2
    t1, _ = zstream_tree_plan(pat, stat)
    t2, _ = zstream_tree_plan(pat, stat)
    assert t1 == t2


def test_deciding_conditions_hold_at_creation(rng):
    pat = seq_pattern([0, 1, 2, 3, 4], 10.0,
                      chain_predicates(list(range(5)), theta=0.1))
    stat = rand_stat(rng, 5, pat)
    for planner in (greedy_order_plan, zstream_tree_plan):
        _, dcs = planner(pat, stat)
        for _, conds in dcs:
            for c in conds:
                assert c.margin(stat) >= -1e-9, str(c)


def test_expr_str_keeps_factors_with_scale():
    """Regression: operator precedence in ``Expr.__str__`` bound the
    rate/sel factor lists into the ``else`` branch, so any expression with
    ``scale != 1`` printed as the bare scale, dropping every factor."""
    from repro.core.plans import Expr

    e = Expr(rate_idx=(0, 2), sel_pairs=((0, 2),), scale=0.5)
    assert str(e) == "0.5*r0*r2*s02"
    assert str(Expr(rate_idx=(1,))) == "r1"
    assert str(Expr(const_add=2.0, scale=3.0)) == "2 + 3"
    assert str(Expr()) == "1"
