"""Sub-join sharing lattice: pure work elimination, bitwise-equal counts.

``config.sharing`` selects how much interior join work a bucket's rules
share ("lattice" / "prefix" / "none"); a shared node's partial-match set
fans out to every extension, so NO counter may move when the mode
changes.  The property test drives random rule sets through all three
modes; the flowsense regression pins the structural claim of the PR —
the full lattice shares strictly more than opening-prefix-only sharing
on that scenario's 3-rule tenant set.
"""

import numpy as np
import pytest

from repro.cep import P, RuntimeConfig
from repro.cep.rulebook import open_rulebook

from test_rulebook import A, K, make_chunks, rule_pool

MODES = ("lattice", "prefix", "none")


def _cfg(mode):
    return RuntimeConfig(buffer_capacity=24, match_capacity=512,
                         estimator_buckets=8, sharing=mode)


def random_rules(rng, q):
    """Random mixed-arity rule set, depth <= 3 (arity <= 4), biased toward
    shared chains: types and thresholds are drawn from small pools so
    independent rules collide on opening joins and deeper sub-joins."""
    rules = []
    for _ in range(q):
        n = int(rng.integers(2, 5))
        types = [int(t) for t in rng.choice(4, size=n, replace=False)]
        th = float(rng.choice([0.2, 0.4]))
        builder = (P.seq(*types) if rng.random() < 0.7
                   else P.and_(*types))
        if n >= 2 and rng.random() < 0.8:
            builder = builder.where(P.attr(0, 0) < P.attr(1, 0) + th)
        rules.append(builder.within(2.0).attrs(A))
    return rules


@pytest.mark.parametrize("q", [2, 8])
def test_sharing_modes_bit_identical(rng, q):
    rule_seed = np.random.default_rng(int(rng.integers(1 << 30)))
    rules = random_rules(rule_seed, q)
    chunks = make_chunks(rng, 8)
    books = {m: open_rulebook(rules, partitions=K, monitor=True,
                              config=_cfg(m)) for m in MODES}
    outs = {m: [] for m in MODES}
    for stacked, _, t0, t1 in chunks:
        for m, rb in books.items():
            outs[m].append(np.asarray(rb.step(stacked, t0, t1)))
    for m in MODES:
        assert books[m].telemetry().overflow == 0
    base = books["lattice"]
    for m in ("prefix", "none"):
        assert np.array_equal(
            np.stack(outs[m]), np.stack(outs["lattice"])), m
        assert np.array_equal(books[m].match_counts, base.match_counts), m
        assert books[m].telemetry().violations == \
            base.telemetry().violations, m
    # the lattice never executes MORE nodes than the weaker modes
    assert base.sharing_ratio() >= books["prefix"].sharing_ratio()
    assert books["none"].sharing_ratio() == 1.0


def test_deep_pair_lattice_beats_prefix_structurally():
    """Two 4-arity rules sharing positions 0-1-2 (same types, same
    predicate rows) diverge only at the last join: the lattice shares two
    depths (ratio 6/4 = 1.5), prefix-only shares one (6/5 = 1.2)."""
    rules = [
        P.seq(0, 1, 2, 3).where(P.attr(0, 0) < P.attr(1, 0) + 0.4)
            .within(3.0).attrs(A),
        P.seq(0, 1, 2, 4).where(P.attr(0, 0) < P.attr(1, 0) + 0.4)
            .within(3.0).attrs(A),
    ]
    lat = open_rulebook(rules, partitions=K, monitor=False,
                        config=_cfg("lattice"))
    pre = open_rulebook(rules, partitions=K, monitor=False,
                        config=_cfg("prefix"))
    assert lat.sharing_ratio() > pre.sharing_ratio() > 1.0


def test_flowsense_lattice_regression():
    """The flowsense tenant's 3-rule set pins BOTH directions of the
    lattice contract: the ratio must be >= opening-prefix-only (the PR's
    claim), and — because alert/ack/combo are structurally disjoint
    (different arities, types, windows) — every mode must report exactly
    1.0: the chain keys may never manufacture sharing between distinct
    sub-joins."""
    from repro.data.scenarios.flowsense import rulebook_patterns

    rules = rulebook_patterns()
    assert len(rules) == 3
    ratios = {}
    for m in MODES:
        rb = open_rulebook(rules, partitions=2, monitor=False,
                           config=_cfg(m))
        ratios[m] = rb.sharing_ratio()
    assert ratios["lattice"] >= ratios["prefix"]
    assert ratios["lattice"] == ratios["prefix"] == ratios["none"] == 1.0


def test_sharing_survives_hot_add_remove(rng):
    """Hot-added rules start singleton chains; removing a shared class's
    representative reroutes the class without disturbing counters."""
    rules = rule_pool()[:4]
    chunks = make_chunks(rng, 6)
    rb = open_rulebook(rules, partitions=K, monitor=True,
                       config=_cfg("lattice"), spare_slots=1)
    solo = open_rulebook(rules, partitions=K, monitor=True,
                         config=_cfg("none"), spare_slots=1)
    for stacked, _, t0, t1 in chunks[:3]:
        rb.step(stacked, t0, t1)
        solo.step(stacked, t0, t1)
    before = rb.sharing_ratio()
    rb.add_rule(rule_pool()[6])
    solo.add_rule(rule_pool()[6])
    rb.remove_rule(0)          # representative of the shared (0, 1) class
    solo.remove_rule(0)
    assert rb.sharing_ratio() <= before
    for stacked, _, t0, t1 in chunks[3:]:
        rb.step(stacked, t0, t1)
        solo.step(stacked, t0, t1)
    assert rb.telemetry().overflow == 0
    assert np.array_equal(rb.match_counts, solo.match_counts)
