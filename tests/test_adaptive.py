"""Framework-level invariant governors: expert placement + batch plans."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.adaptive.batching import AdaptiveBatchPlanner, greedy_batch_plan
from repro.adaptive.placement import (ExpertPlacementGovernor, imbalance,
                                      lpt_placement, permute_expert_params,
                                      relocation)
from repro.configs import get_smoke
from repro.models.moe import moe_defs, moe_ffn
from repro.models.params import init_params


def test_lpt_balances(rng):
    loads = rng.uniform(1, 10, 16)
    placement, dcs = lpt_placement(loads, 4)
    assert sorted(placement.perm) == list(range(16))
    assert imbalance(loads, placement) < 1.35
    # block-building structure: E rank blocks (sort) + E assignment blocks
    assert len(dcs) == 32


def test_lpt_theorem1_style(rng):
    """No-FP property for the placement generator: whenever the invariant
    set fires, a fresh LPT run must produce a DIFFERENT assignment."""
    from repro.adaptive.placement import _load_stat
    from repro.core.invariants import InvariantSet, select_invariants
    loads = rng.uniform(1, 10, 16)
    p0, dcs = lpt_placement(loads, 4)
    iset = InvariantSet(
        select_invariants(dcs, _load_stat(loads), strategy="all"), d=0.0)
    fired = changed = fp = 0
    for i in range(200):
        l2 = loads * np.exp(np.random.default_rng(i).normal(0, 0.4, 16))
        f = iset.check(_load_stat(l2))
        p1, _ = lpt_placement(l2, 4)
        c = p1.groups != p0.groups
        fired += f
        changed += c
        if f and not c:
            fp += 1
    assert fp == 0, (fired, changed, fp)
    assert fired > 0  # the drift scale actually exercises the invariants


def test_governor_stable_loads_no_replans(rng):
    gov = ExpertPlacementGovernor(16, 4, d=0.05)
    loads = rng.uniform(1, 10, 16)
    gov.observe(loads)
    for _ in range(30):
        assert gov.observe(loads + rng.normal(0, 0.01, 16)) is None
    assert gov.replans == 1  # only the initial plan


def test_governor_reacts_to_shift(rng):
    gov = ExpertPlacementGovernor(16, 4, d=0.05)
    loads = rng.uniform(1, 10, 16)
    gov.observe(loads)
    shifted = loads.copy()
    shifted[np.argsort(loads)[:4]] += 40.0  # cold experts become hot
    got = None
    for _ in range(20):
        got = gov.observe(shifted) or got
    assert got is not None
    assert imbalance(gov._loads, got) < 1.5


def test_permute_roundtrip(rng):
    E, D, F = 8, 4, 6
    prm = {k: jnp.asarray(rng.normal(size=s), jnp.float32)
           for k, s in (("router", (D, E)), ("w_gate", (E, D, F)),
                        ("w_up", (E, D, F)), ("w_down", (E, F, D)))}
    perm = rng.permutation(E)
    out = permute_expert_params(prm, perm)
    for e in range(E):
        assert np.allclose(out["w_gate"][perm[e]], prm["w_gate"][e])
        assert np.allclose(out["router"][:, perm[e]], prm["router"][:, e])
    # relocation composition: applying rel after cur lands on new
    cur = rng.permutation(E)
    new = rng.permutation(E)
    rel = relocation(cur, new)
    assert (rel[cur] == new).all()


def test_moe_output_invariant_under_placement(rng):
    """Relocating experts (weights + router columns) must not change the
    layer's function — only which device computes what."""
    cfg = get_smoke("deepseek-moe-16b")
    prm = init_params(moe_defs(cfg), jax.random.PRNGKey(0), jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    y0, _, load0 = moe_ffn(x, prm, cfg)
    perm = rng.permutation(cfg.n_experts)
    y1, _, load1 = moe_ffn(x, permute_expert_params(prm, perm), cfg)
    assert float(jnp.abs(y0 - y1).max()) < 1e-4
    assert np.allclose(np.asarray(load0), np.asarray(load1)[perm])


def test_batch_plan_orders_by_demand():
    rates = np.array([10.0, 1.0, 5.0])
    plan, dcs = greedy_batch_plan(rates, [16, 64, 32], 1024)
    # demand: 160, 64, 160 -> tie broken toward lower class id
    assert plan.order == (0, 2, 1)
    assert len(dcs) == 3 and [len(c) for _, c in dcs] == [2, 1, 0]


def test_batch_planner_adapts_to_burst(rng):
    p = AdaptiveBatchPlanner([16, 64], token_budget=512, d=0.1, ema=0.5)
    p.observe(np.array([20.0, 1.0]))
    assert p.plan.order[0] == 0
    deployed = None
    for _ in range(10):
        deployed = p.observe(np.array([1.0, 30.0])) or deployed
    assert deployed is not None and deployed.order[0] == 1


def test_batch_planner_stable_no_replans(rng):
    p = AdaptiveBatchPlanner([16, 64], token_budget=512, d=0.2)
    p.observe(np.array([20.0, 5.0]))
    base = p.replans
    for _ in range(20):
        p.observe(np.array([20.0, 5.0]) + rng.normal(0, 0.2, 2))
    assert p.replans == base
