"""Pallas window_join kernel vs the pure-jnp oracle.

The Pallas kernel body runs in interpret mode on CPU (TPU is the target);
shapes and dtypes are swept and a hypothesis property test fuzzes the
constraint semantics.
"""

import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels import ops
from repro.kernels.ref import window_join_ref


def _case(rng, C, M, B):
    L = rng.normal(size=(C, M)).astype(np.float32)
    R = rng.normal(size=(C, B)).astype(np.float32)
    op = rng.integers(0, 4, size=(C,)).astype(np.int32)
    th = rng.normal(scale=0.5, size=(C,)).astype(np.float32)
    return L, R, op, th


@pytest.mark.parametrize("C,M,B", [
    (1, 1, 1), (2, 7, 5), (4, 128, 128), (9, 130, 257),
    (16, 64, 300), (32, 256, 384),
])
def test_pallas_matches_ref_shapes(C, M, B, rng):
    L, R, op, th = _case(rng, C, M, B)
    a = np.asarray(ops.window_join(L, R, op, th, backend="ref"))
    b = np.asarray(ops.window_join(L, R, op, th, backend="interpret"))
    assert (a == b).all()


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_pallas_dtypes(dtype, rng):
    L, R, op, th = _case(rng, 4, 33, 65)
    L, R, th = L.astype(dtype), R.astype(dtype), th.astype(dtype)
    a = np.asarray(ops.window_join(L, R, op, th, backend="ref"))
    b = np.asarray(ops.window_join(L, R, op, th, backend="interpret"))
    assert (a == b).all()


def test_count_kernel(rng):
    L, R, op, th = _case(rng, 6, 100, 140)
    want = int(np.asarray(
        ops.window_join(L, R, op, th, backend="ref")).sum())
    got = int(ops.window_join_count(L, R, op, th, backend="interpret"))
    assert want == got


@pytest.mark.parametrize("C,M,B", [(2, 130, 140), (1, 9, 129), (3, 257, 5)])
def test_count_kernel_padding_exact_all_ops(C, M, B, rng):
    """Regression: padded (m, b) cells must never count, for ANY op mix.

    The old NaN-padding scheme relied on pad values failing a comparison;
    a vacuous-True row (op NONE) never compares, so a stack of NONE rows
    counted the full padded tile.  The kernel now masks padding explicitly.
    """
    L = rng.normal(size=(C, M)).astype(np.float32)
    R = rng.normal(size=(C, B)).astype(np.float32)
    th = np.zeros(C, np.float32)
    # Worst case: every row vacuous-True -> count must be exactly M*B.
    op = np.zeros(C, np.int32)
    got = int(ops.window_join_count(L, R, op, th, backend="interpret"))
    assert got == M * B
    # Mixed codes (incl. NONE) against the dense oracle.
    op = rng.integers(0, 4, size=C).astype(np.int32)
    th = rng.normal(scale=0.5, size=C).astype(np.float32)
    want = int(np.asarray(
        ops.window_join(L, R, op, th, backend="ref")).sum())
    assert int(ops.window_join_count(L, R, op, th,
                                     backend="interpret")) == want


def test_opcode_semantics():
    L = np.array([[0.0, 1.0, 2.0]], np.float32)
    R = np.array([[1.0]], np.float32)
    # op LT theta 0: l < r
    ok = np.asarray(ops.window_join(
        L, R, np.array([1], np.int32), np.array([0.0], np.float32),
        backend="interpret"))
    assert ok[:, 0].tolist() == [True, False, False]
    # op GT theta 0: l > r
    ok = np.asarray(ops.window_join(
        L, R, np.array([2], np.int32), np.array([0.0], np.float32),
        backend="interpret"))
    assert ok[:, 0].tolist() == [False, False, True]
    # op ABS theta 0.5
    ok = np.asarray(ops.window_join(
        L, R, np.array([3], np.int32), np.array([0.5], np.float32),
        backend="interpret"))
    assert ok[:, 0].tolist() == [False, True, False]
    # op NONE
    ok = np.asarray(ops.window_join(
        L, R, np.array([0], np.int32), np.array([0.0], np.float32),
        backend="interpret"))
    assert ok.all()


@settings(max_examples=30, deadline=None)
@given(
    C=st.integers(1, 8), M=st.integers(1, 40), B=st.integers(1, 40),
    seed=st.integers(0, 1000),
)
def test_property_and_of_rows(C, M, B, seed):
    """ok must equal the row-wise AND of single-row evaluations."""
    rng = np.random.default_rng(seed)
    L, R, op, th = _case(rng, C, M, B)
    full = np.asarray(ops.window_join(L, R, op, th, backend="interpret"))
    acc = np.ones((M, B), bool)
    for c in range(C):
        acc &= np.asarray(window_join_ref(
            L[c:c + 1], R[c:c + 1], op[c:c + 1], th[c:c + 1]))
    assert (full == acc).all()


def test_superchunk_scan_interpret_parity(rng):
    """The superchunk scan drives the kernel through vmap + lax.scan +
    cond; the Pallas body (interpret mode on CPU) must agree with the jnp
    oracle chunk for chunk through that whole pipeline."""
    import jax
    import jax.numpy as jnp

    from repro.core.engine import Chunk, EngineConfig
    from repro.core.fleet import FleetEngine
    from repro.core.patterns import chain_predicates, seq_pattern
    from repro.core.scan import stack_window, static_control

    pat = seq_pattern([0, 1, 2], 10.0, chain_predicates([0, 1, 2],
                                                        theta=0.4))
    k, s, cap = 2, 4, 24

    chunks, edges = [], []
    for i in range(3):
        t0, t1 = 4.0 * i, 4.0 * (i + 1)
        tid = rng.integers(0, 3, (k, cap)).astype(np.int32)
        ts = np.sort(rng.uniform(t0, t1, (k, cap)), axis=1).astype(
            np.float32)
        attr = rng.normal(size=(k, cap, 1)).astype(np.float32)
        chunks.append(Chunk(jnp.asarray(tid), jnp.asarray(ts),
                            jnp.asarray(attr), jnp.ones((k, cap), bool)))
        edges.append((t0, t1))
    xs = stack_window(chunks, [e[0] for e in edges],
                      [e[1] for e in edges], static_control(k, s), s)

    rows = jnp.asarray(np.stack([(0, 1, 2), (2, 1, 0)]).astype(np.int32))
    results = []
    for backend in ("ref", "interpret"):
        fleet = FleetEngine("order", pat, k,
                            EngineConfig(b_cap=32, m_cap=64,
                                         backend=backend))
        scan = fleet.superchunk_scan(monitored=False)
        state, _, ys = scan(fleet.init_state(), None, rows, rows, None, xs)
        results.append(jax.device_get(ys))
    a, b = results
    for f in ("full", "pm", "overflow", "closure", "neg"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f
    assert a.full[:3].sum() > 0  # the case must actually join something


def test_backend_selection():
    import os
    env = os.environ.get("REPRO_KERNEL_BACKEND")
    if env:
        # CI's parity matrix pins the default through the environment.
        assert ops.default_backend() == env
    else:
        assert ops.default_backend() in ("ref", "pallas")
    ops.set_backend("interpret")
    try:
        assert ops.get_backend() == "interpret"
    finally:
        ops.set_backend(None)
