"""Per-architecture smoke tests (reduced configs, CPU) + numerics:
forward/loss/grad finite, prefill+decode == full forward, flash == direct,
SSD == naive recurrence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke, list_archs
from repro.models.config import ModelConfig
from repro.models.layers import attention, attn_defs
from repro.models.model import Model
from repro.models.params import count_params, init_params
from repro.models.ssm import ssd_chunked

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def make_inputs(cfg, rng, with_labels=True):
    batch = {}
    if cfg.family == "vlm":
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
        batch["patch_embeds"] = jnp.asarray(rng.normal(
            size=(B, cfg.n_frontend_tokens, cfg.d_model)), jnp.float32)
    elif cfg.frontend_is_embedding:
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(B, S, cfg.d_model)), jnp.float32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if with_labels:
        batch["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke(arch, rng):
    """Assignment requirement: reduced config, one forward/train step on
    CPU, asserting output shapes + no NaNs."""
    cfg = get_smoke(arch)
    m = Model(cfg, remat="none")
    prm = m.init(KEY)
    batch = make_inputs(cfg, rng)
    logits, _ = m.forward(prm, batch)
    assert logits.shape == (B, S, cfg.vocab)
    loss, metrics = m.loss(prm, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: m.loss(p, batch)[0])(prm)
    gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
    # decode path
    logits_p, cache = m.prefill(prm, make_inputs(cfg, rng, False), 32)
    step_in = (jnp.asarray(rng.normal(size=(B, 1, cfg.d_model)),
                           jnp.float32) if cfg.frontend_is_embedding
               else batch.get("tokens", jnp.zeros((B, 1), jnp.int32))[:, :1])
    logits_d, cache = m.decode_step(prm, cache, step_in)
    assert logits_d.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits_d, np.float32)).all()


@pytest.mark.parametrize("arch", list_archs())
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "olmo-1b": (16, 2048, 16, 16, 8192, 50304),
        "yi-34b": (60, 7168, 56, 8, 20480, 64000),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "deepseek-moe-16b": (28, 2048, 16, 16, 1408, 102400),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    L, d, h, kv, ff, v = spec
    assert cfg.n_layers == L and cfg.d_model == d and cfg.vocab == v
    if h:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if ff:
        assert cfg.d_ff == ff
    if arch == "deepseek-moe-16b":
        assert (cfg.n_experts, cfg.n_shared_experts, cfg.top_k) == (64, 2, 6)
    if arch == "dbrx-132b":
        assert (cfg.n_experts, cfg.top_k) == (16, 4)
    if arch == "mamba2-1.3b":
        assert cfg.ssm_state == 128
    if arch == "zamba2-1.2b":
        assert cfg.ssm_state == 64
    if arch == "olmo-1b":
        assert cfg.norm == "np_ln"


def test_param_count_matches_defs():
    for arch in ("phi3-mini-3.8b", "deepseek-moe-16b", "mamba2-1.3b",
                 "zamba2-1.2b"):
        cfg = get_config(arch)
        m = Model(cfg)
        assert count_params(m.param_defs()) == cfg.param_count(), arch


def test_full_param_counts_plausible():
    """Sanity vs the published model sizes (loose bounds; exact configs
    differ in vocab/ties but must land in the right ballpark)."""
    expect = {"phi3-mini-3.8b": (3.0e9, 4.6e9), "olmo-1b": (0.9e9, 1.6e9),
              "yi-34b": (30e9, 38e9), "stablelm-12b": (10e9, 14e9),
              "deepseek-moe-16b": (14e9, 20e9), "dbrx-132b": (120e9, 145e9),
              "mamba2-1.3b": (1.0e9, 1.7e9)}
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_prefill_decode_consistency_dense(rng):
    cfg = get_smoke("phi3-mini-3.8b")
    m = Model(cfg, remat="none")
    prm = m.init(KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full, _ = m.forward(prm, {"tokens": toks})
    lp, cache = m.prefill(prm, {"tokens": toks[:, :S - 2]}, S + 4)
    outs = [lp]
    for t in range(S - 2, S):
        ld, cache = m.decode_step(prm, cache, toks[:, t:t + 1])
        outs.append(ld)
    got = jnp.concatenate(outs, axis=1)
    assert float(jnp.abs(got - full[:, S - 3:, :]).max()) < 1e-3


def test_prefill_decode_consistency_ssm(rng):
    cfg = get_smoke("mamba2-1.3b")
    m = Model(cfg, remat="none")
    prm = m.init(KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    full, _ = m.forward(prm, {"tokens": toks})
    lp, cache = m.prefill(prm, {"tokens": toks[:, :8]}, S)
    outs = [lp]
    for t in range(8, S):
        ld, cache = m.decode_step(prm, cache, toks[:, t:t + 1])
        outs.append(ld)
    got = jnp.concatenate(outs, axis=1)
    assert float(jnp.abs(got - full[:, 7:, :]).max()) < 1e-3


def test_flash_equals_direct(rng):
    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab=64,
                      attn_kv_block=16)
    prm = init_params(attn_defs(cfg), KEY, jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, 50, 64)), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(50, dtype=jnp.int32), (B, 50))
    for prefix, win in [(0, 0), (7, 0), (0, 20), (5, 13)]:
        o1, _ = attention(x, prm, cfg.with_(attn_direct_max=4096), pos,
                          prefix_len=prefix, window=win)
        o2, _ = attention(x, prm, cfg.with_(attn_direct_max=1), pos,
                          prefix_len=prefix, window=win)
        assert float(jnp.abs(o1 - o2).max()) < 1e-5


def test_ssd_matches_naive_recurrence(rng):
    b, s, h, p, n = 2, 16, 3, 4, 5
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (b, s, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.1, 1.0, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(b, s, n)), jnp.float32)
    hst = np.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        dec = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None, :])
        xd = np.asarray(x[:, t]) * np.asarray(dt[:, t])[..., None]
        hst = hst * dec[..., None, None] + np.einsum(
            "bhp,bn->bhpn", xd, np.asarray(Bm[:, t]))
        ys.append(np.einsum("bhpn,bn->bhp", hst, np.asarray(Cm[:, t])))
    y_ref = np.stack(ys, 1)
    for chunk, unroll in [(4, 1), (8, 2), (16, 16)]:
        y, hf = ssd_chunked(x, dt, A, Bm, Cm, chunk, unroll=unroll)
        assert np.abs(np.asarray(y) - y_ref).max() < 1e-4
        assert np.abs(np.asarray(hf) - hst).max() < 1e-4


def test_vlm_prefix_is_bidirectional(rng):
    """Changing a LATER patch embedding must affect EARLIER prefix
    positions' logits path (prefix-LM), but never text causality."""
    cfg = get_smoke("paligemma-3b")
    m = Model(cfg, remat="none")
    prm = m.init(KEY)
    batch = make_inputs(cfg, rng, with_labels=False)
    l1, _ = m.forward(prm, batch)
    pe = np.asarray(batch["patch_embeds"]).copy()
    pe[:, -1] += 10.0  # bump the LAST patch
    l2, _ = m.forward(prm, dict(batch, patch_embeds=jnp.asarray(pe)))
    # all text logits may change (text attends to the prefix)...
    assert float(jnp.abs(l1 - l2).max()) > 0
    # ...and causality within text: perturbing the last TEXT token leaves
    # earlier text logits unchanged.
    tk = np.asarray(batch["tokens"]).copy()
    tk[:, -1] = (tk[:, -1] + 1) % cfg.vocab
    l3, _ = m.forward(prm, dict(batch, tokens=jnp.asarray(tk)))
    assert float(jnp.abs(l1[:, :-1] - l3[:, :-1]).max()) < 1e-5


def test_hybrid_shared_block_actually_shared():
    cfg = get_smoke("zamba2-1.2b")
    m = Model(cfg)
    defs = m.param_defs()
    assert "shared_attn" in defs
    # shared attn params are NOT stacked per layer
    assert defs["shared_attn"]["attn"]["wq"].shape[0] == cfg.d_model
