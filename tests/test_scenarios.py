"""Scenario-suite differential grid: Session vs the brute-force oracle.

Every bundled scenario's stream prefix runs through ``cep.open`` across the
(K, superchunk) grid and must report exactly the oracle's match count —
monitored adaptivity, superchunk scans, and partition stacking change cost,
never semantics.  Heavy grid points carry ``@pytest.mark.slow``.
"""

import numpy as np
import pytest

from repro import cep
from repro.cep import RuntimeConfig
from repro.core.ref_engine import RefEngine
from repro.data import scenarios

CHUNKS = 12          # prefix length fed to the oracle (warmup + control)
GRID = [
    pytest.param(1, 1, id="k1-s1"),
    pytest.param(4, 1, id="k4-s1", marks=pytest.mark.slow),
    pytest.param(1, 8, id="k1-s8", marks=pytest.mark.slow),
    pytest.param(4, 8, id="k4-s8", marks=pytest.mark.slow),
]


def _config(sc, *, superchunk=1):
    return RuntimeConfig(**sc.runtime, escalate_on_overflow=True,
                         superchunk=superchunk)


def _oracle_matches(sc, k, *, seed=0, chunks=CHUNKS):
    total = 0
    for p in range(k):
        total += RefEngine(sc.pattern.build()).run(
            sc.stream(p, seed=seed, chunks=chunks)).full_matches
    return total


@pytest.mark.parametrize("name", scenarios.names())
@pytest.mark.parametrize("k,superchunk", GRID)
def test_scenario_prefix_matches_oracle(name, k, superchunk):
    sc = scenarios.get(name)
    n = CHUNKS if superchunk == 1 else 16   # superchunk needs n % s == 0
    s = cep.open(sc.pattern, partitions=k, monitor=True,
                 superchunk=superchunk,
                 config=_config(sc, superchunk=superchunk))
    tel = s.run(sc.streams(k, seed=0, chunks=n))
    assert tel.matches == _oracle_matches(sc, k, chunks=n)


@pytest.mark.parametrize("name", scenarios.names())
def test_scenario_stream_deterministic(name):
    sc = scenarios.get(name)
    a = list(sc.stream(0, seed=3, chunks=4))
    b = list(sc.stream(0, seed=3, chunks=4))
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(ra.chunk.ts),
                                      np.asarray(rb.chunk.ts))
        np.testing.assert_array_equal(ra.counts, rb.counts)
    # distinct partitions / seeds draw distinct event noise
    c = list(sc.stream(1, seed=3, chunks=4))
    assert any(ra.n_events != rc.n_events or
               not np.array_equal(np.asarray(ra.chunk.ts),
                                  np.asarray(rc.chunk.ts))
               for ra, rc in zip(a, c))


@pytest.mark.parametrize("name", scenarios.names())
def test_scenario_trajectory_structure(name):
    """Ground-truth drift structure: control is stationary, drift is not,
    and the emitted streams' true rates mirror the trajectory exactly."""
    sc = scenarios.get(name)
    rates = sc.drift_trajectory(0, seed=0)
    assert rates.shape == (sc.n_chunks, sc.n_types)
    for seg, lo, hi in sc.segment_slices():
        if seg.gate == "control":
            assert np.allclose(rates[lo:hi], rates[lo]), (
                f"{name}:{seg.name} control segment must be stationary")
        if seg.gate == "drift":
            assert not np.allclose(rates[lo:hi], rates[lo - 1]), (
                f"{name}:{seg.name} drift segment must leave the control "
                f"regime")
    recs = list(sc.stream(0, seed=0, chunks=6))
    want = sc.drift_trajectory(0, seed=0, chunks=6)
    got = np.stack([r.true_rates for r in recs])
    np.testing.assert_allclose(got, want * sc.rate_scale)


@pytest.mark.parametrize("name", scenarios.names())
def test_scenario_resume_equals_continuous(name):
    """Segment-by-segment replay with ``resume=True`` is the same run as
    one continuous stream — the replay harness's measurement boundaries
    must not be semantic boundaries."""
    sc = scenarios.get(name)
    k = sc.partitions
    full = cep.open(sc.pattern, partitions=k, monitor=True,
                    config=_config(sc))
    t_full = full.run(sc.streams(k, seed=0, chunks=16))

    seg = cep.open(sc.pattern, partitions=k, monitor=True,
                   config=_config(sc))
    tels = []
    for lo, hi in ((0, 6), (6, 11), (11, 16)):
        parts = [list(sc.stream(p, seed=0, chunks=16))[lo:hi]
                 for p in range(k)]
        tels.append(seg.run(parts, resume=bool(tels)))
    assert sum(t.matches for t in tels) == t_full.matches
    assert sum(t.replans for t in tels) == t_full.replans
    assert sum(t.escalations for t in tels) == t_full.escalations


@pytest.mark.slow
@pytest.mark.parametrize("name", scenarios.names())
def test_scenario_drift_prefix_matches_oracle(name):
    """Differential check reaching into the drift segment (plan changes,
    migrations and escalations active) at native K."""
    sc = scenarios.get(name)
    warm = sum(s.n_chunks for s in sc.segments[:2])
    n = warm + 8
    k = sc.partitions
    s = cep.open(sc.pattern, partitions=k, monitor=True, config=_config(sc))
    tel = s.run(sc.streams(k, seed=0, chunks=n))
    assert tel.matches == _oracle_matches(sc, k, chunks=n)


def test_flowsense_rulebook_replay_gates():
    """The 3-rule tenant rulebook (alert + ack + fraud-combo) through
    ``open_rulebook``: the control gate (zero replans under stationary
    statistics) and the oracle differential both survive the move from
    one Session to a stacked rulebook."""
    from repro.cep.rulebook import open_rulebook
    from repro.data.scenarios import flowsense

    sc = scenarios.get("flowsense")
    rules = flowsense.rulebook_patterns()
    k = sc.partitions
    warm = sc.segments[0].n_chunks
    n = warm + 4
    streams = [list(sc.stream(p, seed=0, chunks=n)) for p in range(k)]

    rb = open_rulebook(rules, partitions=k, monitor=True,
                       config=_config(sc))
    rb.run([s[:warm] for s in streams])
    tel_control = rb.run([s[warm:] for s in streams])
    assert tel_control.replans == 0, (
        "control segment must keep every (q, k) cell silent")
    assert rb.telemetry().overflow == 0

    for i, r in enumerate(rules):
        want = np.array([RefEngine(r.build()).run(streams[p]).full_matches
                         for p in range(k)], np.int64)
        np.testing.assert_array_equal(rb.match_counts[i], want)


def test_scenario_registry():
    assert set(scenarios.names()) == {"citibike", "flowsense", "fraud"}
    sc = scenarios.get("citibike")
    assert sc.n_chunks == sum(s.n_chunks for s in sc.segments)
    assert [s.gate for s in sc.segments] == ["none", "control", "drift"]
    with pytest.raises(ValueError):
        scenarios.get("nope")
