"""Serving engine + scheduler integration (tiny configs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke("olmo-1b")
    model = Model(cfg, remat="none")
    params = model.init(KEY)
    return cfg, model, params


def test_padded_prefill_matches_exact(dense_setup, rng):
    """Bucket-padded prefill with true_lens must produce the same decode
    trajectory as exact-length prefill."""
    cfg, model, params = dense_setup
    plen = 11  # pads to 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, plen)), jnp.int32)
    # exact
    l1, c1 = model.prefill(params, {"tokens": toks}, 64)
    # padded
    padded = jnp.zeros((1, 16), jnp.int32).at[:, :plen].set(toks)
    l2, c2 = model.prefill(params, {"tokens": padded}, 64,
                           true_lens=jnp.asarray([plen], jnp.int32))
    assert float(jnp.abs(l1 - l2).max()) < 1e-4
    t = jnp.asarray([[3]], jnp.int32)
    d1, _ = model.decode_step(params, c1, t)
    d2, _ = model.decode_step(params, c2, t)
    assert float(jnp.abs(d1 - d2).max()) < 1e-4


def test_engine_slots_independent(dense_setup, rng):
    cfg, model, params = dense_setup
    eng = ServingEngine(cfg, params, batch_slots=2, cache_len=64)
    p1 = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    t1 = eng.prefill_one(p1, 0)
    t2 = eng.prefill_one(p2, 1)
    # single-request reference
    ref = ServingEngine(cfg, params, batch_slots=1, cache_len=64)
    assert ref.prefill_one(p1, 0) == t1
    nxt = eng.decode(np.array([t1, t2], np.int32))
    ref_nxt = ref.decode(np.array([t1], np.int32))
    assert nxt[0] == ref_nxt[0]


def test_scheduler_drains(dense_setup, rng):
    cfg, model, params = dense_setup
    eng = ServingEngine(cfg, params, batch_slots=3, cache_len=64)
    sched = Scheduler(eng, class_tokens=[16, 32])
    for rid in range(7):
        plen = int(rng.choice([12, 16, 30]))
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(0, cfg.vocab, plen)
                             .astype(np.int32),
                             max_new=4))
    ticks = 0
    while sched.pending or any(s is not None for s in sched.slots):
        sched.tick()
        ticks += 1
        assert ticks < 500
    assert len(sched.completed) == 7
    assert all(len(r.out) >= r.max_new for r in sched.completed)


def test_ssm_serving_exact_buckets(rng):
    cfg = get_smoke("mamba2-1.3b")
    model = Model(cfg, remat="none")
    params = model.init(KEY)
    eng = ServingEngine(cfg, params, batch_slots=1, cache_len=64)
    with pytest.raises(ValueError):
        eng.prefill_one(rng.integers(0, cfg.vocab, 11).astype(np.int32), 0)
    tok = eng.prefill_one(
        rng.integers(0, cfg.vocab, 16).astype(np.int32), 0)
    assert 0 <= tok < cfg.vocab


# ---------------------------------------------------------------------------
# CEP stream router: drop accounting across superchunk boundaries
# ---------------------------------------------------------------------------


def _router_pair(superchunk, chunk_cap=64, m_cap=512):
    from repro.core.engine import EngineConfig
    from repro.core.patterns import chain_predicates, seq_pattern
    from repro.core.plans import OrderPlan
    from repro.serving import CEPFleetServingEngine, CEPStreamRouter

    pat = seq_pattern([0, 1, 2], 3.0,
                      chain_predicates([0, 1, 2], theta=0.6))
    def make():
        eng = CEPFleetServingEngine(
            pat, 2, OrderPlan((0, 1, 2)),
            EngineConfig(b_cap=64, m_cap=m_cap),
            chunk_cap=chunk_cap, superchunk=superchunk)
        return CEPStreamRouter(eng, slice_duration=0.5)
    return make(), make()


def _submit_workload(routers, rng, n=180, t_hi=4.25):
    """Random keyed events, including late (ts <= 0), slice-edge-exact and
    far-future timestamps, submitted identically to every router."""
    ts = rng.uniform(-0.5, t_hi, n).astype(np.float32)
    ts[:4] = [0.0, 0.5, 1.0, 2.5]      # exactly on slice edges
    tid = rng.integers(0, 3, n).astype(np.int32)
    keys = rng.integers(0, 7, n)
    attr = rng.normal(size=(n, 1)).astype(np.float32)
    for i in range(n):
        for r in routers:
            r.submit(keys[i], tid[i], ts[i], attr[i])
    return n


def test_router_superchunk_ticks_equal_sequential(rng):
    """``tick_superchunk(n)`` must be accounting-identical to n ticks:
    same matches, same late drops, same capacity drops, same queue."""
    seq, sup = _router_pair(superchunk=4)
    submitted = _submit_workload((seq, sup), rng)

    full_seq = np.stack([seq.tick() for _ in range(4)])
    full_sup = sup.tick_superchunk(4)
    np.testing.assert_array_equal(full_seq, full_sup)

    # a second round crosses the superchunk boundary with carried state
    submitted += _submit_workload((seq, sup), rng, n=60, t_hi=4.5)
    full_seq = np.stack([seq.tick() for _ in range(4)])
    full_sup = sup.tick_superchunk(4)
    np.testing.assert_array_equal(full_seq, full_sup)

    assert seq.late_dropped == sup.late_dropped > 0
    assert seq.routed == sup.routed
    assert seq.pending == sup.pending
    assert seq.engine.dropped == sup.engine.dropped
    np.testing.assert_array_equal(seq.engine.matches, sup.engine.matches)
    assert seq.slices == sup.slices == 8


def test_router_drop_conservation(rng):
    """Every submitted event is accounted for exactly once:
    submitted == routed + late_dropped + pending, and the engine sees
    routed - engine.dropped of them (capacity clipping)."""
    for superchunk, chunk_cap in ((1, 8), (4, 8)):
        router, _ = _router_pair(superchunk=superchunk,
                                 chunk_cap=chunk_cap)
        submitted = _submit_workload((router,), rng, n=150)
        if superchunk == 1:
            for _ in range(4):
                router.tick()
        else:
            router.tick_superchunk(4)
        assert submitted == (router.routed + router.late_dropped
                             + router.pending)
        assert router.engine.dropped > 0     # tiny cap must clip
        assert router.routed - router.engine.dropped >= 0


def test_router_superchunk_monitored_engine(rng):
    """The monitored serving engine behind ``tick_superchunk`` must agree
    with the per-tick monitored router on matches and drop accounting."""
    from repro.core.engine import EngineConfig
    from repro.core.patterns import chain_predicates, seq_pattern
    from repro.serving import CEPStreamRouter, MonitoredCEPFleetServingEngine

    pat = seq_pattern([0, 1, 2], 3.0,
                      chain_predicates([0, 1, 2], theta=0.6))
    def make(superchunk):
        eng = MonitoredCEPFleetServingEngine(
            pat, 2, EngineConfig(b_cap=64, m_cap=512),
            chunk_cap=64, superchunk=superchunk, monitor_buckets=8)
        return CEPStreamRouter(eng, slice_duration=0.5)
    seq, sup = make(1), make(2)
    _submit_workload((seq, sup), rng)
    full_seq = np.stack([seq.tick() for _ in range(4)])
    full_sup = sup.tick_superchunk(4)
    np.testing.assert_array_equal(full_seq, full_sup)
    assert seq.late_dropped == sup.late_dropped
    assert seq.routed == sup.routed
    np.testing.assert_array_equal(seq.engine.matches, sup.engine.matches)
