"""Serving engine + scheduler integration (tiny configs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models.model import Model
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import Request, Scheduler

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_smoke("olmo-1b")
    model = Model(cfg, remat="none")
    params = model.init(KEY)
    return cfg, model, params


def test_padded_prefill_matches_exact(dense_setup, rng):
    """Bucket-padded prefill with true_lens must produce the same decode
    trajectory as exact-length prefill."""
    cfg, model, params = dense_setup
    plen = 11  # pads to 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, plen)), jnp.int32)
    # exact
    l1, c1 = model.prefill(params, {"tokens": toks}, 64)
    # padded
    padded = jnp.zeros((1, 16), jnp.int32).at[:, :plen].set(toks)
    l2, c2 = model.prefill(params, {"tokens": padded}, 64,
                           true_lens=jnp.asarray([plen], jnp.int32))
    assert float(jnp.abs(l1 - l2).max()) < 1e-4
    t = jnp.asarray([[3]], jnp.int32)
    d1, _ = model.decode_step(params, c1, t)
    d2, _ = model.decode_step(params, c2, t)
    assert float(jnp.abs(d1 - d2).max()) < 1e-4


def test_engine_slots_independent(dense_setup, rng):
    cfg, model, params = dense_setup
    eng = ServingEngine(cfg, params, batch_slots=2, cache_len=64)
    p1 = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    t1 = eng.prefill_one(p1, 0)
    t2 = eng.prefill_one(p2, 1)
    # single-request reference
    ref = ServingEngine(cfg, params, batch_slots=1, cache_len=64)
    assert ref.prefill_one(p1, 0) == t1
    nxt = eng.decode(np.array([t1, t2], np.int32))
    ref_nxt = ref.decode(np.array([t1], np.int32))
    assert nxt[0] == ref_nxt[0]


def test_scheduler_drains(dense_setup, rng):
    cfg, model, params = dense_setup
    eng = ServingEngine(cfg, params, batch_slots=3, cache_len=64)
    sched = Scheduler(eng, class_tokens=[16, 32])
    for rid in range(7):
        plen = int(rng.choice([12, 16, 30]))
        sched.submit(Request(rid=rid,
                             prompt=rng.integers(0, cfg.vocab, plen)
                             .astype(np.int32),
                             max_new=4))
    ticks = 0
    while sched.pending or any(s is not None for s in sched.slots):
        sched.tick()
        ticks += 1
        assert ticks < 500
    assert len(sched.completed) == 7
    assert all(len(r.out) >= r.max_new for r in sched.completed)


def test_ssm_serving_exact_buckets(rng):
    cfg = get_smoke("mamba2-1.3b")
    model = Model(cfg, remat="none")
    params = model.init(KEY)
    eng = ServingEngine(cfg, params, batch_slots=1, cache_len=64)
    with pytest.raises(ValueError):
        eng.prefill_one(rng.integers(0, cfg.vocab, 11).astype(np.int32), 0)
    tok = eng.prefill_one(
        rng.integers(0, cfg.vocab, 16).astype(np.int32), 0)
    assert 0 <= tok < cfg.vocab
