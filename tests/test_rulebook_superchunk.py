"""Superchunked rulebook stepping: bitwise equivalence at every S.

``config.superchunk = S`` rolls S chunks per bucket through one compiled
``lax.scan`` dispatch; the load-bearing property is that NOTHING about
the counters or the adaptation trajectory depends on S.  The grid here
drives the optimistic window re-run hard — a rate-skewed phase-2 stream
makes invariant flags fire mid-window, so accepted prefixes, replan
points and redeployed plans must all land exactly where per-chunk
stepping puts them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.cep as cep
from repro.cep import P, RuntimeConfig
from repro.cep.rulebook import open_rulebook
from repro.core import fleet
from repro.core.engine import Chunk
from repro.core.fleet import FleetChunk

from test_rulebook import A, CAP, K, make_chunks, rule_pool

CFG_KW = dict(buffer_capacity=24, match_capacity=512,
              estimator_buckets=8)


def skewed_chunks(rng, n_chunks, k=K):
    """Two-phase stream: uniform types, then rates skewed to types 3/4 —
    the shift drags selectivity estimates across invariant boundaries so
    flags (and replans) fire inside scan windows, not just at cold start.
    """
    out = []
    for step in range(n_chunks):
        t0, t1 = float(step), float(step + 1)
        phase2 = step >= n_chunks // 2
        parts = []
        for _ in range(k):
            n = int(rng.integers(5, 10))
            if phase2:
                tid = rng.choice(5, size=n,
                                 p=[0.05, 0.05, 0.1, 0.4, 0.4])
            else:
                tid = rng.integers(0, 5, size=n)
            tid = tid.astype(np.int32)
            ts = np.sort(rng.uniform(t0, t1, size=n)).astype(np.float32)
            attr = rng.normal(size=(n, A)).astype(np.float32)
            if phase2:
                attr += 0.8
            pad = CAP - n
            parts.append(Chunk(
                type_id=jnp.asarray(np.pad(tid, (0, pad),
                                           constant_values=-1)),
                ts=jnp.asarray(np.pad(ts, (0, pad))),
                attr=jnp.asarray(np.pad(attr.astype(np.float32),
                                        ((0, pad), (0, 0)))),
                valid=jnp.asarray(np.arange(CAP) < n)))
        out.append((jax.tree.map(lambda *xs: jnp.stack(xs), *parts),
                    t0, t1))
    return out


@pytest.mark.parametrize("s", [2, 3, 8])
def test_superchunk_grid_matches_per_chunk_and_sessions(rng, s):
    """S in {2, 3, 8} over 10 chunks: exercises full windows, a tail
    window shorter than S, and flag-triggered mid-window splits."""
    rules = rule_pool()[:4]
    chunks = skewed_chunks(rng, 10)
    edges = [(t0, t1) for _, t0, t1 in chunks]
    cs = [c for c, _, _ in chunks]

    rb_pc = open_rulebook(rules, partitions=K, monitor=True,
                          config=RuntimeConfig(**CFG_KW))
    sessions = [cep.open(r, partitions=K, monitor=True,
                         config=RuntimeConfig(**CFG_KW)) for r in rules]
    per_chunk = np.stack([rb_pc.step(c, t0, t1) for c, t0, t1 in chunks])
    sess_counts = np.zeros((len(rules), K), np.int64)
    for c, t0, t1 in chunks:
        for i, sess in enumerate(sessions):
            sess_counts[i] += np.asarray(sess.step(c, t0, t1))

    rb_sc = open_rulebook(rules, partitions=K, monitor=True,
                          config=RuntimeConfig(superchunk=s, **CFG_KW))
    out = rb_sc.step_superchunk(cs, edges)

    assert rb_pc.telemetry().overflow == 0
    assert rb_sc.telemetry().overflow == 0
    # the stream must actually exercise the re-run path to mean anything
    assert rb_pc.telemetry().violations > 0
    assert np.array_equal(out, per_chunk)
    assert np.array_equal(rb_sc.match_counts, rb_pc.match_counts)
    assert np.array_equal(rb_sc.match_counts, sess_counts)
    assert rb_sc.telemetry().violations == rb_pc.telemetry().violations
    assert rb_sc.telemetry().replans == rb_pc.telemetry().replans


def test_superchunk_run_segments_match_step(rng):
    """run() windows the stream through step_superchunk; segmented feeds
    and an S that does not divide the stream length stay bit-identical."""
    rules = rule_pool()[:3]
    chunks = make_chunks(rng, 11)
    fcs = [FleetChunk(chunk=c, t0=t0, t1=t1) for c, _, t0, t1 in chunks]

    rb_pc = open_rulebook(rules, partitions=K, monitor=True,
                          config=RuntimeConfig(**CFG_KW))
    for c, _, t0, t1 in chunks:
        rb_pc.step(c, t0, t1)

    rb_sc = open_rulebook(rules, partitions=K, monitor=True,
                          config=RuntimeConfig(superchunk=4, **CFG_KW))
    tel_a = rb_sc.run(fcs[:5])
    tel_b = rb_sc.run(fcs[5:])
    assert np.array_equal(rb_sc.match_counts, rb_pc.match_counts)
    assert tel_a.chunks + tel_b.chunks == 11
    assert rb_sc.telemetry().violations == rb_pc.telemetry().violations


def test_superchunk_unmonitored_path(rng):
    """Non-monitored rulebooks scan too (no flags, no re-runs — the host
    surfaces only at window boundaries) and stay bit-identical."""
    rules = rule_pool()[:4]
    chunks = make_chunks(rng, 9)
    edges = [(t0, t1) for _, _, t0, t1 in chunks]
    cs = [c for c, _, _, _ in chunks]

    rb_pc = open_rulebook(rules, partitions=K, monitor=False,
                          config=RuntimeConfig(**CFG_KW))
    per_chunk = np.stack([rb_pc.step(c, t0, t1)
                          for c, _, t0, t1 in chunks])
    rb_sc = open_rulebook(rules, partitions=K, monitor=False,
                          config=RuntimeConfig(superchunk=4, **CFG_KW))
    out = rb_sc.step_superchunk(cs, edges)
    assert np.array_equal(out, per_chunk)
    assert np.array_equal(rb_sc.match_counts, rb_pc.match_counts)


def test_superchunk_mesh_d1_matches(rng):
    rules = rule_pool()[:2]
    chunks = make_chunks(rng, 6)
    edges = [(t0, t1) for _, _, t0, t1 in chunks]
    cs = [c for c, _, _, _ in chunks]
    rb_mesh = open_rulebook(
        rules, partitions=K, monitor=True,
        config=RuntimeConfig(superchunk=4, mesh=1, **CFG_KW))
    rb_plain = open_rulebook(
        rules, partitions=K, monitor=True,
        config=RuntimeConfig(superchunk=4, **CFG_KW))
    a = rb_mesh.step_superchunk(cs, edges)
    b = rb_plain.step_superchunk(cs, edges)
    assert np.array_equal(a, b)
    assert np.array_equal(rb_mesh.match_counts, rb_plain.match_counts)


def test_growth_under_superchunk_reenters_memo(rng):
    """Bucket growth while scanning: the grown Qb re-enters the SAME
    memoized scan callable — exactly one retrace, zero new memo entries.
    """
    cfg = RuntimeConfig(superchunk=4, buffer_capacity=20,
                        match_capacity=512, estimator_buckets=8)
    rules = [rule_pool()[3], rule_pool()[7]]  # one full n=2 bucket
    rb = open_rulebook(rules, partitions=K, monitor=True, config=cfg)
    chunks = make_chunks(rng, 12)
    edges = [(t0, t1) for _, _, t0, t1 in chunks]
    cs = [c for c, _, _, _ in chunks]
    rb.step_superchunk(cs[:4], edges[:4])
    pre_traces = rb.trace_count()
    pre_memo = len(fleet._TRACE_MEMO)
    rb.add_rule(P.seq(1, 3).within(1.0).attrs(A))  # full bucket -> grow
    rb.step_superchunk(cs[4:8], edges[4:8])
    assert rb.trace_count() == pre_traces + 1
    assert len(fleet._TRACE_MEMO) == pre_memo
    # and the grown shape is now warm: further windows retrace nothing
    rb.step_superchunk(cs[8:], edges[8:])
    assert rb.trace_count() == pre_traces + 1
