"""Detection-adaptation loop (Algorithm 1) end-to-end properties."""

import numpy as np
import pytest

from repro.core.adaptation import AdaptiveRunner, CompositeAdaptiveRunner, \
    merge_metrics
from repro.core.decision import make_policy
from repro.core.engine import EngineConfig
from repro.core.patterns import (CompositePattern, chain_predicates,
                                 seq_pattern)
from repro.data.cep_streams import StreamConfig, make_stream

PAT = seq_pattern([0, 1, 2, 3], window=4.0,
                  predicates=chain_predicates([0, 1, 2, 3], theta=-0.3))
SCFG = StreamConfig(n_types=4, n_attrs=1, n_chunks=60, chunk_cap=256,
                    base_rate=15.0, seed=3)
ECFG = EngineConfig(b_cap=128, m_cap=4096)


def run(policy_name, kind="traffic", planner="greedy", **kw):
    r = AdaptiveRunner(PAT, planner=planner,
                       policy=make_policy(policy_name, **kw),
                       engine_cfg=ECFG)
    return r.run(make_stream(kind, SCFG))


def test_matches_are_policy_independent():
    """Adaptation must never change WHAT is detected, only how fast."""
    results = [run(p, kind="stocks") for p in
               ("static", "unconditional", "invariant")]
    matches = {m.full_matches for m in results}
    assert len(matches) == 1, matches
    assert all(m.overflow == 0 for m in results)


def test_invariant_zero_false_positives_d0():
    m = run("invariant", kind="traffic", k=1, d=0.0)
    assert m.false_positives == 0  # Theorem 1 in the full loop
    assert m.replans <= m.chunks


def test_invariant_replans_far_fewer_than_unconditional():
    mu = run("unconditional", kind="traffic")
    mi = run("invariant", kind="traffic", d=0.0)
    assert mi.replans < mu.replans / 5
    # ... while deploying (almost) as many genuinely-better plans.
    assert mi.deployments >= mu.deployments - 1


def test_distance_d_reduces_deployments():
    m0 = run("invariant", kind="stocks", d=0.0)
    m3 = run("invariant", kind="stocks", d=0.5)
    assert m3.deployments <= m0.deployments


def test_migration_no_duplicate_detection():
    """Unconditional policy migrates constantly; match count must still
    equal the static run's (exactly-once under the [36] split)."""
    ms = run("static", kind="traffic")
    mu = run("unconditional", kind="traffic")
    assert ms.full_matches == mu.full_matches
    assert mu.migration_chunks > 0


@pytest.mark.slow
def test_zstream_loop_runs():
    m = run("invariant", kind="traffic", planner="zstream", d=0.1)
    assert m.chunks == SCFG.n_chunks
    assert m.false_positives == 0


def test_composite_pattern_runs():
    comp = CompositePattern((
        seq_pattern([0, 1], 4.0, chain_predicates([0, 1], theta=-0.3)),
        seq_pattern([2, 3], 4.0, chain_predicates([2, 3], theta=-0.3)),
    ))
    runner = CompositeAdaptiveRunner(
        comp, planner="greedy", policy=None, engine_cfg=ECFG)
    # composite branches need their own policies; rebuild with policies
    for r in runner.runners:
        r.policy = make_policy("invariant")
    cfg2 = StreamConfig(n_types=4, n_attrs=1, n_chunks=30, chunk_cap=256,
                        base_rate=15.0, seed=5)
    ms = runner.run([make_stream("traffic", cfg2),
                     make_stream("traffic", StreamConfig(
                         n_types=4, n_attrs=1, n_chunks=30, chunk_cap=256,
                         base_rate=15.0, seed=6))])
    total = merge_metrics(ms)
    assert total.chunks == 60


def test_regret_measurement():
    r = AdaptiveRunner(PAT, planner="greedy",
                       policy=make_policy("static"), engine_cfg=ECFG,
                       measure_regret=True)
    m = r.run(make_stream("traffic", SCFG))
    assert m.regret_samples > 0
    r2 = AdaptiveRunner(PAT, planner="greedy",
                        policy=make_policy("invariant", d=0.0),
                        engine_cfg=ECFG, measure_regret=True)
    m2 = r2.run(make_stream("traffic", SCFG))
    # The adaptive run tracks the optimum at least as well as static.
    assert m2.regret <= m.regret + 1e-9
