"""Pattern AST + predicate tensor structure."""

import numpy as np
import pytest

from repro.core.patterns import (
    PRED_ABS_LE, PRED_GT, PRED_LT, PRED_NONE,
    CompositePattern, Predicate, and_pattern, chain_predicates,
    kleene_pattern, neg_pattern, seq_pattern,
)


def test_seq_basics():
    p = seq_pattern([3, 1, 7], window=5.0)
    assert p.n == 3 and p.is_sequence and p.window == 5.0


def test_pred_tensor_mirroring():
    preds = (Predicate(0, 1, PRED_LT, 0, 0, 0.5),
             Predicate(2, 1, PRED_GT, 0, 0, 0.1))
    p = seq_pattern([0, 1, 2], 10.0, preds)
    t = p.pred_tensors()
    assert t["op"][0, 1] == PRED_LT and t["op"][1, 0] == PRED_GT
    assert t["op"][2, 1] == PRED_GT and t["op"][1, 2] == PRED_LT
    assert t["theta"][0, 1] == t["theta"][1, 0] == 0.5
    assert t["op"][0, 2] == PRED_NONE


def test_abs_pred_self_mirror():
    p = seq_pattern([0, 1], 1.0, (Predicate(0, 1, PRED_ABS_LE, 0, 0, 2.0),))
    t = p.pred_tensors()
    assert t["op"][0, 1] == t["op"][1, 0] == PRED_ABS_LE


def test_selectivity_pairs_upper_triangle():
    p = seq_pattern([0, 1, 2, 3], 1.0, chain_predicates([0, 1, 2, 3]))
    assert p.selectivity_pairs() == ((0, 1), (1, 2), (2, 3))


def test_chain_predicates_semantics():
    c = chain_predicates([5, 6, 7], op=PRED_LT, theta=0.25)
    assert len(c) == 2
    assert c[0].a_type == 5 and c[0].b_type == 6 and c[0].theta == 0.25


def test_negation_and_kleene_flags():
    n = neg_pattern([0, 1], 5.0, negated_type=2, negated_pos=1)
    assert n.negated_type == 2 and n.negated_pos == 1 and n.is_sequence
    k = kleene_pattern([0, 1, 2], 5.0, kleene_pos=1)
    assert k.kleene_pos == 1 and k.is_sequence
    a = and_pattern([0, 1], 5.0)
    assert not a.is_sequence


def test_composite_window():
    c = CompositePattern((seq_pattern([0, 1], 3.0),
                          seq_pattern([2, 3], 7.0)))
    assert c.window == 7.0
