"""Fleet executor: bit-identical to a Python loop of K single-partition
engines and to the brute-force oracle, for K in {1, 4, 16}."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decision import make_policy
from repro.core.engine import Chunk, EngineConfig, OrderEngine, TreeEngine
from repro.core.fleet import (FleetEngine, FleetRunner, route_events,
                              stack_chunks, stacked_streams)
from repro.core.patterns import (
    PRED_ABS_LE, Predicate, and_pattern, chain_predicates, kleene_pattern,
    neg_pattern, seq_pattern,
)
from repro.core.plans import OrderPlan, TreeNode, TreePlan
from repro.core.ref_engine import RefEngine, brute_force_matches
from repro.data.cep_streams import StreamConfig, make_stream

CFG = EngineConfig(b_cap=64, m_cap=1024)


def gen_partition_streams(rng, k, n_types, n_events):
    out = []
    for _ in range(k):
        ts = np.sort(rng.uniform(0, 100, n_events)).astype(np.float32)
        tid = rng.integers(0, n_types, n_events).astype(np.int32)
        attr = rng.normal(size=(n_events, 1)).astype(np.float32)
        out.append((tid, ts, attr))
    return out


def as_chunk(tid, ts, attr):
    return Chunk(jnp.asarray(tid), jnp.asarray(ts), jnp.asarray(attr),
                 jnp.ones(len(ts), bool))


def fleet_patterns():
    return [
        seq_pattern([0, 1, 2], 20.0, chain_predicates([0, 1, 2],
                                                      theta=0.4)),
        and_pattern([0, 1, 2], 15.0, chain_predicates([0, 1, 2],
                                                      theta=0.3)),
        neg_pattern([0, 1], 20.0, negated_type=2, negated_pos=1,
                    negated_predicates=(
                        Predicate(2, 0, PRED_ABS_LE, 0, 0, 1.5),)),
        kleene_pattern([0, 1, 2], 20.0, kleene_pos=1, kleene_bound=2),
    ]


@pytest.mark.parametrize("k", [1, 4, 16])
@pytest.mark.parametrize("pat_i", [0, 1, 2, 3])
def test_fleet_equals_loop_and_oracle(k, pat_i, rng):
    """The acceptance triangle: fleet == python loop == brute force."""
    pat = fleet_patterns()[pat_i]
    streams = gen_partition_streams(rng, k, 3, 40)
    # Heterogeneous per-partition plans: plans are data, one compiled plane.
    orders = [(0, 1, 2), (2, 1, 0), (1, 0, 2), (2, 0, 1)]
    plans = [OrderPlan(orders[p % len(orders)][:pat.n])
             for p in range(k)]
    if pat.n == 2:
        plans = [OrderPlan((0, 1)) if p % 2 else OrderPlan((1, 0))
                 for p in range(k)]

    loop_eng = OrderEngine(pat, CFG)
    loop = []
    for (tid, ts, attr), plan in zip(streams, plans):
        _, r = loop_eng.process_chunk(
            loop_eng.init_state(), as_chunk(tid, ts, attr), plan,
            0.0, 200.0)
        loop.append(int(r.full_matches))

    fe = FleetEngine("order", pat, k, CFG)
    chunks = stack_chunks([as_chunk(*s) for s in streams])
    _, res = fe.process_chunk(fe.init_state(), chunks, plans, 0.0, 200.0)
    fleet = np.asarray(res.full_matches).tolist()

    oracle = [brute_force_matches(pat, *s, 0.0, 200.0).full_matches
              for s in streams]
    assert fleet == loop == oracle


@pytest.mark.parametrize("k", [1, 4])
def test_tree_fleet_equals_oracle(k, rng):
    pat = seq_pattern([0, 1, 2], 20.0,
                      chain_predicates([0, 1, 2], theta=0.4))
    streams = gen_partition_streams(rng, k, 3, 40)
    N = TreeNode
    tp = TreePlan(N(left=N(left=N(leaf=0), right=N(leaf=1)),
                    right=N(leaf=2)))
    fe = FleetEngine("tree", pat, k, CFG)
    chunks = stack_chunks([as_chunk(*s) for s in streams])
    _, res = fe.process_chunk(fe.init_state(), chunks, tp, 0.0, 200.0)
    oracle = [brute_force_matches(pat, *s, 0.0, 200.0).full_matches
              for s in streams]
    assert np.asarray(res.full_matches).tolist() == oracle


def test_fleet_chunked_exactly_once(rng):
    """Stacked ring buffers carry per-partition history across chunks."""
    k = 4
    pat = seq_pattern([0, 1, 2], 12.0,
                      chain_predicates([0, 1, 2], theta=0.8))
    streams = gen_partition_streams(rng, k, 3, 60)
    fe = FleetEngine("order", pat, k, EngineConfig(b_cap=128, m_cap=2048))
    state = fe.init_state()
    plans = [OrderPlan((2, 1, 0))] * k
    totals = np.zeros(k, np.int64)
    edges = [0.0, 30.0, 55.0, 80.0, 100.0]
    for t0, t1 in zip(edges[:-1], edges[1:]):
        parts = []
        for tid, ts, attr in streams:
            m = (ts > t0) & (ts <= t1)
            cap = 60  # shared static capacity: pad each slice
            pad = cap - int(m.sum())
            parts.append(Chunk(
                jnp.asarray(np.concatenate([tid[m],
                                            np.full(pad, -1, np.int32)])),
                jnp.asarray(np.concatenate([ts[m],
                                            np.zeros(pad, np.float32)])),
                jnp.asarray(np.concatenate(
                    [attr[m], np.zeros((pad, 1), np.float32)])),
                jnp.asarray(np.concatenate([np.ones(int(m.sum()), bool),
                                            np.zeros(pad, bool)])),
            ))
        state, res = fe.process_chunk(state, stack_chunks(parts), plans,
                                      t0, t1)
        totals += np.asarray(res.full_matches, np.int64)
    oracle = [brute_force_matches(pat, *s, 0.0, 100.0).full_matches
              for s in streams]
    assert totals.tolist() == oracle


@pytest.mark.parametrize("k", [1, 4])
def test_fleet_runner_adaptive_vs_oracle(k):
    """Independent per-partition replans + migration stay exactly-once."""
    pat = seq_pattern([0, 1, 2], 4.0,
                      chain_predicates([0, 1, 2], theta=-0.3))
    scfg = StreamConfig(n_types=3, n_chunks=30, chunk_cap=256,
                        base_rate=12.0, seed=5)

    def streams():
        return [make_stream("traffic", dataclasses.replace(scfg, seed=5 + p))
                for p in range(k)]

    runner = FleetRunner(
        pat, k, planner="greedy",
        policy_factory=lambda: make_policy("invariant", k=1, d=0.0),
        engine_cfg=EngineConfig(b_cap=128, m_cap=1024))
    m = runner.run(stacked_streams(streams()))
    oracle = [RefEngine(pat).run(s).full_matches for s in streams()]
    assert m.per_partition_matches.tolist() == oracle
    assert m.full_matches == sum(oracle)


def test_route_events_partitions_by_key(rng):
    k = 4
    n = 100
    tid = rng.integers(0, 3, n).astype(np.int32)
    ts = np.sort(rng.uniform(0, 50, n)).astype(np.float32)
    attr = rng.normal(size=(n, 1)).astype(np.float32)
    keys = rng.integers(0, 1000, n)
    chunk, dropped = route_events(tid, ts, attr, keys, k, cap=n)
    assert dropped == 0
    valid = np.asarray(chunk.valid)
    assert valid.sum() == n
    for p in range(k):
        got = np.asarray(chunk.ts)[p][valid[p]]
        want = ts[keys % k == p]
        assert np.array_equal(np.sort(got), np.sort(want))
    # capacity back-pressure is counted, not silently lost
    _, dropped2 = route_events(tid, ts, attr, keys, k, cap=10)
    per_part = np.bincount(keys % k, minlength=k)
    assert dropped2 == int(np.maximum(per_part - 10, 0).sum())


def test_fleet_serving_router_vs_oracle(rng):
    from repro.core.plans import OrderPlan
    from repro.serving import CEPFleetServingEngine, CEPStreamRouter
    k = 4
    pat = seq_pattern([0, 1, 2], 10.0,
                      chain_predicates([0, 1, 2], theta=0.5))
    eng = CEPFleetServingEngine(pat, k, OrderPlan((2, 1, 0)),
                                EngineConfig(b_cap=128, m_cap=1024),
                                chunk_cap=256)
    router = CEPStreamRouter(eng, slice_duration=5.0)
    n = 200
    ts = np.sort(rng.uniform(0, 20, n)).astype(np.float32)
    tid = rng.integers(0, 3, n).astype(np.int32)
    attr = rng.normal(size=(n, 1)).astype(np.float32)
    keys = rng.integers(0, 9, n)
    for i in range(n):
        router.submit(keys[i], tid[i], ts[i], attr[i])
    for _ in range(4):
        router.tick()
    oracle = []
    for p in range(k):
        ref = RefEngine(pat)
        tot = 0
        sel = (keys % k) == p
        for s in range(4):
            t0, t1 = 5.0 * s, 5.0 * (s + 1)
            m = sel & (ts > t0) & (ts <= t1)
            tot += ref.process_chunk(tid[m], ts[m], attr[m],
                                     t0, t1).full_matches
        oracle.append(tot)
    assert eng.matches.tolist() == oracle
    assert router.pending == 0


def test_router_drops_and_counts_late_events(rng):
    from repro.core.plans import OrderPlan
    from repro.serving import CEPFleetServingEngine, CEPStreamRouter
    pat = seq_pattern([0, 1], 5.0)
    eng = CEPFleetServingEngine(pat, 2, OrderPlan((0, 1)),
                                EngineConfig(b_cap=32, m_cap=32),
                                chunk_cap=32)
    router = CEPStreamRouter(eng, slice_duration=1.0)
    router.tick()  # close slice (0, 1]
    # An event whose slice already closed can never be counted
    # exactly-once; it must be dropped and surfaced, not routed.
    router.submit(0, 0, 0.5, np.zeros(1, np.float32))
    router.submit(0, 1, 1.5, np.zeros(1, np.float32))  # on time
    router.tick()
    assert router.late_dropped == 1
    assert router.pending == 0


def test_fleet_runner_overflow_escalation_vs_oracle():
    """Tiny caps force truncation; escalation must restore exact counts."""
    pat = seq_pattern([0, 1, 2], 4.0,
                      chain_predicates([0, 1, 2], theta=-0.3))
    scfg = StreamConfig(n_types=3, n_chunks=12, chunk_cap=256,
                        base_rate=14.0, seed=9)

    def streams():
        return [make_stream("stocks", dataclasses.replace(scfg, seed=9 + p))
                for p in range(2)]

    runner = FleetRunner(pat, 2, planner="greedy",
                         engine_cfg=EngineConfig(b_cap=64, m_cap=64))
    m = runner.run(stacked_streams(streams()))
    oracle = [RefEngine(pat).run(s).full_matches for s in streams()]
    assert m.escalations > 0
    assert m.per_partition_matches.tolist() == oracle
