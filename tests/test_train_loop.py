"""End-to-end training: loss decreases, resume is bit-exact, data is a
pure function of (seed, step)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.data.lm_data import DataConfig, make_batch
from repro.models.model import Model
from repro.train.optimizer import AdamWConfig, init_state
from repro.train.train_step import make_train_step


def test_data_deterministic():
    cfg = get_smoke("olmo-1b")
    d = DataConfig(batch=4, seq=32, seed=5)
    b1 = make_batch(cfg, d, 7)
    b2 = make_batch(cfg, d, 7)
    assert (b1["tokens"] == b2["tokens"]).all()
    b3 = make_batch(cfg, d, 8)
    assert not (b1["tokens"] == b3["tokens"]).all()


def _train(arch="olmo-1b", steps=30, seed=0, start_params=None,
           start_opt=None, start_step=0):
    cfg = get_smoke(arch)
    model = Model(cfg, remat="none")
    opt_cfg = AdamWConfig(lr_peak=3e-3, warmup_steps=5, total_steps=steps,
                          use_master=False)
    dcfg = DataConfig(batch=4, seq=32, seed=seed)
    params = start_params or model.init(jax.random.PRNGKey(seed))
    opt = start_opt or init_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    losses = []
    for s in range(start_step, steps):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(cfg, dcfg, s).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["ce"]))
    return params, opt, losses


def test_loss_decreases():
    _, _, losses = _train(steps=30)
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_resume_bit_exact():
    """10 straight steps == 5 steps + restart + 5 steps (same data,
    same optimizer state) — the fault-tolerance contract."""
    pA, _, _ = _train(steps=10)
    p5, o5, _ = _train(steps=5)
    # "restart": brand-new step_fn, same state
    pB, _, _ = _train(steps=10, start_params=p5, start_opt=o5,
                      start_step=5)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_moe_train_returns_expert_loads():
    cfg = get_smoke("deepseek-moe-16b")
    model = Model(cfg, remat="none")
    opt_cfg = AdamWConfig(total_steps=3, use_master=False)
    dcfg = DataConfig(batch=2, seq=16)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_state(opt_cfg, params)
    step_fn = jax.jit(make_train_step(model, opt_cfg))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, dcfg, 0).items()}
    params, opt, m = step_fn(params, opt, batch)
    loads = np.asarray(m["expert_load"])
    assert loads.shape == (cfg.n_layers, cfg.n_experts)
    # every routed token accounted for: sum = T * top_k per layer
    t = dcfg.batch * dcfg.seq
    assert np.allclose(loads.sum(-1), t * cfg.top_k, rtol=1e-5)


def test_microbatch_grad_accumulation_matches():
    """2 microbatches must equal the single-shot gradient step."""
    cfg = get_smoke("olmo-1b")
    model = Model(cfg, remat="none")
    opt_cfg = AdamWConfig(total_steps=2, use_master=False)
    dcfg = DataConfig(batch=4, seq=16)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, dcfg, 0).items()}
    outs = {}
    for mb in (1, 2):
        opt = init_state(opt_cfg, params)
        fn = jax.jit(make_train_step(model, opt_cfg, microbatches=mb))
        p2, _, m = fn(params, opt, batch)
        outs[mb] = p2
    for a, b in zip(jax.tree.leaves(outs[1]), jax.tree.leaves(outs[2])):
        assert np.allclose(np.asarray(a), np.asarray(b), atol=2e-5), \
            np.abs(np.asarray(a) - np.asarray(b)).max()
