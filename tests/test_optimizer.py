"""AdamW + schedule + clipping (built from scratch — no optax offline)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.optimizer import (AdamWConfig, apply_update, cosine_lr,
                                   global_norm, init_state)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr_peak=0.1, warmup_steps=5, total_steps=200,
                      weight_decay=0.0, clip_norm=100.0)
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = init_state(cfg, params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = apply_update(cfg, params, g, state)
    assert float(loss(params)) < 1e-3


def test_clip_norm():
    cfg = AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.zeros(4)}
    state = init_state(cfg, params)
    g = {"w": jnp.full(4, 100.0)}
    _, _, m = apply_update(cfg, params, g, state)
    assert float(m["grad_norm"]) == 200.0  # pre-clip norm reported


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr_peak=1.0, warmup_steps=10, total_steps=100,
                      lr_min_ratio=0.1)
    lrs = [float(cosine_lr(cfg, jnp.asarray(s))) for s in range(101)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert abs(lrs[100] - 0.1) < 1e-6
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))


def test_bf16_master_params():
    cfg = AdamWConfig(use_master=True)
    params = {"w": jnp.zeros(4, jnp.bfloat16)}
    state = init_state(cfg, params)
    assert state.master["w"].dtype == jnp.float32
    g = {"w": jnp.full(4, 1e-3, jnp.bfloat16)}
    p2, s2, _ = apply_update(cfg, params, g, state)
    assert p2["w"].dtype == jnp.bfloat16
    # master accumulates at fp32 precision even for sub-bf16 updates
    assert float(jnp.abs(s2.master["w"]).max()) > 0


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
