"""Differential tests: compiled engines vs the brute-force oracle.

Randomized streams sweep pattern size, window length, negation, the Kleene
bound, and chunk boundaries; `ref_engine` is ground truth.  A match must be
counted exactly once — in the chunk of its latest event."""

import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st
from repro.core.engine import Chunk, EngineConfig, OrderEngine, TreeEngine
from repro.core.patterns import (
    PRED_GT, PRED_LT, Predicate, and_pattern, chain_predicates,
    kleene_pattern, neg_pattern, seq_pattern,
)
from repro.core.plans import OrderPlan, TreeNode, TreePlan
from repro.core.ref_engine import RefEngine, brute_force_matches


def gen_stream(rng, n_types, n_events, n_attrs=1, t_end=100.0):
    ts = np.sort(rng.uniform(0, t_end, n_events)).astype(np.float32)
    tid = rng.integers(0, n_types, n_events).astype(np.int32)
    attr = rng.normal(size=(n_events, n_attrs)).astype(np.float32)
    return tid, ts, attr


def as_chunk(tid, ts, attr):
    return Chunk(jnp.asarray(tid), jnp.asarray(ts), jnp.asarray(attr),
                 jnp.ones(len(ts), bool))


def left_deep_tree(n):
    node = TreeNode(leaf=0)
    for p in range(1, n):
        node = TreeNode(left=node, right=TreeNode(leaf=p))
    return TreePlan(node)


@pytest.mark.parametrize("n,window", [(2, 5.0), (3, 12.0), (4, 30.0)])
def test_order_engine_size_window_sweep(n, window, rng):
    pat = seq_pattern(list(range(n)), window,
                      chain_predicates(list(range(n)), theta=0.4))
    tid, ts, attr = gen_stream(rng, n, 15 * n)
    eng = OrderEngine(pat, EngineConfig(b_cap=128, m_cap=4096))
    plan = OrderPlan(tuple(reversed(range(n))))
    _, res = eng.process_chunk(eng.init_state(), as_chunk(tid, ts, attr),
                               plan, 0.0, 200.0)
    ref = brute_force_matches(pat, tid, ts, attr, 0.0, 200.0)
    assert int(res.full_matches) == ref.full_matches


@pytest.mark.parametrize("n", [2, 3, 4])
def test_tree_engine_size_sweep(n, rng):
    pat = seq_pattern(list(range(n)), 20.0,
                      chain_predicates(list(range(n)), theta=0.2))
    tid, ts, attr = gen_stream(rng, n, 12 * n)
    eng = TreeEngine(pat, EngineConfig(b_cap=128, m_cap=4096))
    _, res = eng.process_chunk(eng.init_state(), as_chunk(tid, ts, attr),
                               left_deep_tree(n), 0.0, 200.0)
    ref = brute_force_matches(pat, tid, ts, attr, 0.0, 200.0)
    assert int(res.full_matches) == ref.full_matches


# derandomize: with real hypothesis installed the example seeds are
# otherwise drawn fresh per run, turning capacity/tolerance edge cases
# into one-in-N flakes; the fallback shim is already deterministic.
@settings(max_examples=5, deadline=None, derandomize=True)
@given(seed=st.integers(0, 10_000), n_chunks=st.integers(2, 5))
def test_chunk_boundaries_exactly_once(seed, n_chunks):
    """Chunked totals must equal the single-shot oracle regardless of how
    the timeline is cut — each match lands in its latest event's chunk."""
    rng = np.random.default_rng(seed)
    pat = seq_pattern([0, 1, 2], 15.0,
                      chain_predicates([0, 1, 2], theta=0.8))
    tid, ts, attr = gen_stream(rng, 3, 48)
    eng = OrderEngine(pat, EngineConfig(b_cap=256, m_cap=4096))
    state = eng.init_state()
    ref = RefEngine(pat)
    edges = np.concatenate(
        [[0.0], np.sort(rng.uniform(0, 100, n_chunks - 1)), [100.0]])
    total = ref_total = 0
    for t0, t1 in zip(edges[:-1], edges[1:]):
        m = (ts > t0) & (ts <= t1)
        state, res = eng.process_chunk(
            state, as_chunk(tid[m], ts[m], attr[m]), OrderPlan((2, 1, 0)),
            t0, t1)
        total += int(res.full_matches)
        ref_total += ref.process_chunk(tid[m], ts[m], attr[m],
                                       t0, t1).full_matches
    want = brute_force_matches(pat, tid, ts, attr, 0.0, 100.0).full_matches
    assert total == want
    assert ref_total == want


@pytest.mark.parametrize("negated_pos", [0, 1, 2])
def test_negation_positions(negated_pos, rng):
    pat = neg_pattern(
        [0, 1], 20.0, negated_type=2, negated_pos=negated_pos,
        predicates=(Predicate(0, 1, PRED_LT, 0, 0, 0.5),),
        negated_predicates=(Predicate(2, 0, PRED_GT, 0, 0, 1.0),))
    tid, ts, attr = gen_stream(rng, 3, 60)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=1024))
    _, res = eng.process_chunk(eng.init_state(), as_chunk(tid, ts, attr),
                               OrderPlan((1, 0)), 0.0, 200.0)
    ref = brute_force_matches(pat, tid, ts, attr, 0.0, 200.0)
    assert int(res.full_matches) == ref.full_matches
    assert int(res.neg_rejected) == ref.neg_rejected


@pytest.mark.parametrize("bound", [None, 0, 1, 3])
def test_kleene_bound_sweep(bound, rng):
    pat = kleene_pattern([0, 1, 2], 25.0, kleene_pos=1,
                         predicates=chain_predicates([0, 1, 2], theta=0.9),
                         kleene_bound=bound)
    tid, ts, attr = gen_stream(rng, 3, 45)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=2048))
    _, res = eng.process_chunk(eng.init_state(), as_chunk(tid, ts, attr),
                               OrderPlan((0, 1, 2)), 0.0, 200.0)
    ref = brute_force_matches(pat, tid, ts, attr, 0.0, 200.0)
    assert int(res.full_matches) == ref.full_matches
    assert int(res.closure_expansions) == ref.closure_expansions


def test_and_pattern_vs_oracle(rng):
    pat = and_pattern([0, 1, 2], 18.0,
                      chain_predicates([0, 1, 2], theta=0.3))
    tid, ts, attr = gen_stream(rng, 3, 50)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=2048))
    _, res = eng.process_chunk(eng.init_state(), as_chunk(tid, ts, attr),
                               OrderPlan((1, 2, 0)), 0.0, 200.0)
    assert int(res.full_matches) == brute_force_matches(
        pat, tid, ts, attr, 0.0, 200.0).full_matches
