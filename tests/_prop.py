"""Property-testing shim: hypothesis when available, seeded sweeps otherwise.

The tier-1 container does not ship ``hypothesis``.  Tests import ``given``,
``settings`` and ``st`` from this module instead of from ``hypothesis``;
when the real library is importable we re-export it unchanged, otherwise a
minimal drop-in runs each test over a deterministic seeded-random example
sweep.  Only the strategy surface the suite actually uses is implemented
(``st.integers`` and ``st.floats`` with inclusive bounds).

Fallback semantics mirror the hypothesis behaviours the tests rely on:

* ``@given`` accepts keyword strategies, or positional strategies that are
  right-aligned against the test function's parameters (leftover leading
  parameters stay visible to pytest as fixtures/parametrize arguments);
* ``@settings(max_examples=..., deadline=...)`` bounds the sweep size;
* examples are derived from a per-test deterministic seed, so failures are
  reproducible run-to-run.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import zlib

    HAVE_HYPOTHESIS = False
    _FALLBACK_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng):
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

    st = _Strategies()

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, deadline=None, **_kw):
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco

    def given(*pos_strategies, **kw_strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            kw = dict(kw_strategies)
            if pos_strategies:
                # hypothesis right-aligns positional strategies.
                for name, strat in zip(names[-len(pos_strategies):],
                                       pos_strategies):
                    kw[name] = strat
            fixture_names = [n for n in names if n not in kw]
            seed0 = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                import numpy as np

                # @settings sits above @given, so it stamps the wrapper.
                n_examples = min(
                    getattr(wrapper, "_prop_max_examples",
                            _FALLBACK_MAX_EXAMPLES),
                    _FALLBACK_MAX_EXAMPLES)
                for i in range(n_examples):
                    rng = np.random.default_rng(seed0 + i)
                    drawn = {k: s.sample(rng) for k, s in kw.items()}
                    fn(*args, **kwargs, **drawn)

            # Hide the generated parameters from pytest's fixture resolver.
            wrapper.__signature__ = sig.replace(parameters=[
                sig.parameters[n] for n in fixture_names])
            return wrapper
        return deco
