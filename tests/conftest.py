import os
import sys

# Tests run against the in-tree package; smoke tests must see the real
# (single-device) platform — the 512-device XLA flag belongs ONLY to
# launch/dryrun.py.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
