"""End-to-end behaviour: the paper's headline result reproduced in
miniature — the invariant-based method dominates the alternatives on the
quality/overhead frontier for both data regimes."""

import numpy as np
import pytest

from repro.core.adaptation import AdaptiveRunner
from repro.core.decision import make_policy
from repro.core.engine import EngineConfig
from repro.core.patterns import chain_predicates, seq_pattern
from repro.data.cep_streams import StreamConfig, make_stream

PAT = seq_pattern([0, 1, 2, 3], window=4.0,
                  predicates=chain_predicates([0, 1, 2, 3], theta=-0.3))
ECFG = EngineConfig(b_cap=128, m_cap=4096)


def run(policy, kind, seed=3, **kw):
    cfg = StreamConfig(n_types=4, n_attrs=1, n_chunks=80, chunk_cap=256,
                       base_rate=15.0, seed=seed)
    r = AdaptiveRunner(PAT, planner="greedy",
                       policy=make_policy(policy, **kw), engine_cfg=ECFG,
                       measure_regret=True)
    return r.run(make_stream(kind, cfg))


@pytest.mark.slow
def test_invariant_on_pareto_frontier_traffic():
    """Traffic regime (skewed, rare large shifts): the invariant method
    must match the best plan quality (lowest regret) at a fraction of the
    A-invocations of the unconditional method."""
    inv = run("invariant", "traffic", d=0.0)
    unc = run("unconditional", "traffic")
    sta = run("static", "traffic")
    assert inv.regret <= unc.regret + 1e-6      # same plan quality
    assert inv.replans < unc.replans / 5        # far fewer A runs
    assert inv.regret < sta.regret              # strictly beats static
    assert inv.false_positives == 0             # Theorem 1


@pytest.mark.slow
def test_invariant_beats_threshold_on_regret_or_replans():
    """Against the ZStream-style constant threshold: the invariant method
    must be at least as good on plan quality without more replans, for a
    threshold that wasn't hand-tuned to this stream."""
    inv = run("invariant", "traffic", d=0.0)
    thr = run("threshold", "traffic", t=0.4)
    assert (inv.regret <= thr.regret + 1e-6
            or inv.replans <= thr.replans)


@pytest.mark.slow
def test_stocks_regime_unconditional_overadapts():
    """Stocks regime (uniform, frequent small drift): unconditional pays
    constant plan-generation + migration cost for near-zero gain."""
    unc = run("unconditional", "stocks")
    inv = run("invariant", "stocks", d=0.3)
    assert unc.replans > 10 * max(inv.replans, 1)
    assert unc.migration_chunks >= inv.migration_chunks
    # detection itself identical (exactly-once, plan-independent)
    assert unc.full_matches == inv.full_matches
