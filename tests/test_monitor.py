"""Device-resident invariant monitoring: differential tests.

Three layers of agreement are asserted:

1. the lowered tensor evaluation (`invariants.eval_lowered`) agrees with
   the host ``InvariantSet`` and with the float32 numpy mirror;
2. the ``(K,)`` violation flags coming out of the fused monitored fleet
   step agree with the host ``InvariantPolicy.should_reoptimize`` decision
   on the synced device statistics, for K ∈ {1, 4, 16}, over a drifting
   stream with flag-triggered replans in the loop;
3. end-to-end match counts of the flag-triggered adaptive runners still
   agree with the brute-force oracle (``core/ref_engine``).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core.decision import InvariantPolicy
from repro.core.engine import EngineConfig, MonitoredEngine
from repro.core.fleet import (FleetEngine, MonitoredFleetRunner,
                              stacked_streams)
from repro.core.greedy import greedy_order_plan
from repro.core.invariants import (InvariantSet, check_lowered_np,
                                   eval_lowered, stack_lowered,
                                   write_lowered_row)
from repro.core.patterns import chain_predicates, seq_pattern
from repro.core.ref_engine import RefEngine
from repro.core.stats import (Stat, chunk_observations,
                              exhaustive_selectivities, uniform_stat)
from repro.data.cep_streams import StreamConfig, make_stream

PAT = seq_pattern([0, 1, 2], 4.0, chain_predicates([0, 1, 2], theta=-0.3))
CFG = EngineConfig(b_cap=64, m_cap=512)


def _rand_stat(rng, n):
    sel = np.eye(n) * 0 + rng.uniform(0.05, 1.0, (n, n))
    sel = (sel + sel.T) / 2
    return Stat(rng.uniform(0.1, 20.0, n), sel)


def _low_row(low, p):
    return jax.tree.map(lambda x: np.asarray(x)[p], low)


def _assert_flags_agree(v_dev, v_np, drift_np, host):
    """Device flag == float32 mirror, bit-for-bit (same dtype, same
    operation order).  The float64 host policy must agree everywhere
    except within float32 rounding of an *exact tie* — |drift| below
    f32 resolution — where the strict ``>`` may legitimately flip."""
    assert bool(v_dev) == v_np
    assert host == v_np or abs(drift_np) < 1e-5


def test_lowering_matches_host_invariant_set(rng):
    """eval_lowered / check_lowered_np == InvariantSet.check over random
    statistics, for every selection strategy the planner can emit."""
    n = PAT.n
    for strategy, k in (("tightest", 1), ("tightest", 2), ("all", 99)):
        pol = InvariantPolicy(k=k, d=0.1, strategy=strategy)
        base = _rand_stat(rng, n)
        plan, dcs = greedy_order_plan(PAT, base)
        pol.on_replan(plan, dcs, base)
        low = pol.compile(n)
        iset: InvariantSet = pol.invariant_set
        for _ in range(50):
            stat = _rand_stat(rng, n)
            host = iset.check(stat)
            r32 = stat.rates.astype(np.float32)
            s32 = stat.sel.astype(np.float32)
            v_np, drift_np = check_lowered_np(low, r32, s32)
            v_dev, drift_dev = jax.tree.map(
                np.asarray, eval_lowered(jax.tree.map(np.asarray, low),
                                         r32, s32))
            _assert_flags_agree(v_dev, v_np, drift_np, host)
            assert bool(v_np) == (drift_np > 0.0)
            np.testing.assert_allclose(drift_dev, drift_np, rtol=1e-5)


def test_lowering_cap_overflow_raises(rng):
    pol = InvariantPolicy(k=2, d=0.0)
    plan, dcs = greedy_order_plan(PAT, uniform_stat(PAT.n))
    pol.on_replan(plan, dcs, uniform_stat(PAT.n))
    with pytest.raises(ValueError, match="max_inv"):
        pol.compile(PAT.n, max_inv=1)


def test_chunk_observations_match_host_mirror(rng):
    """Device exhaustive selectivity counting == the numpy twin."""
    import jax.numpy as jnp

    n_ev = 120
    tid = rng.integers(0, 3, n_ev).astype(np.int32)
    attr = rng.normal(size=(n_ev, 1)).astype(np.float32)
    valid = rng.random(n_ev) < 0.8
    counts, trials, hits = jax.tree.map(np.asarray, chunk_observations(
        jnp.asarray(tid), jnp.asarray(attr), jnp.asarray(valid),
        PAT.type_ids, PAT.pred_tensors()))
    trials_h, hits_h = exhaustive_selectivities(
        tid[valid], attr[valid], PAT.pred_tensors(), PAT.type_ids, PAT.n)
    for p, t in enumerate(PAT.type_ids):
        assert counts[p] == ((tid == t) & valid).sum()
    np.testing.assert_array_equal(trials, trials_h)
    np.testing.assert_array_equal(hits, hits_h)


@pytest.mark.parametrize("k", [1, 4, 16])
def test_device_flags_match_host_policy(k):
    """The tentpole differential: on-device violated flags == host
    ``InvariantPolicy.should_reoptimize`` on the synced statistics, chunk
    by chunk over a drifting stream, with flag-triggered replans applied
    (so the invariant sets themselves churn during the run)."""
    scfg = StreamConfig(n_types=3, n_chunks=20, chunk_cap=128,
                       base_rate=8.0, seed=11)
    streams = [make_stream("stocks", dataclasses.replace(scfg, seed=11 + p))
               for p in range(k)]
    fe = FleetEngine("order", PAT, k, CFG)
    state, mon = fe.init_state(), fe.init_monitor()
    stat0 = uniform_stat(PAT.n)
    plan0, dcs0 = greedy_order_plan(PAT, stat0)
    pols = [InvariantPolicy(k=1, d=0.0) for _ in range(k)]
    for pol in pols:
        pol.on_replan(plan0, dcs0, stat0)
    low = stack_lowered([pol.compile(PAT.n) for pol in pols])
    rows = np.tile(np.asarray(plan0.order, np.int32), (k, 1))
    plans = [plan0] * k

    fired_total = 0
    for fc in stacked_streams(streams):
        state, mon, res, violated, drift, rates, sel = \
            fe.process_chunk_monitored(state, mon, fc.chunk, rows, low,
                                       fc.t0, fc.t1)
        v = np.asarray(violated)
        dr = np.asarray(drift)
        for p in range(k):
            synced = Stat(np.asarray(rates[p], np.float64),
                          np.asarray(sel[p], np.float64))
            # float32 bit-level reference: same lowering, same dtype.
            v_np, drift_np = check_lowered_np(
                _low_row(low, p), np.asarray(rates[p]), np.asarray(sel[p]))
            _assert_flags_agree(v[p], v_np, drift_np,
                                pols[p].should_reoptimize(synced))
            np.testing.assert_allclose(dr[p], drift_np, rtol=1e-5)
            if v[p]:
                fired_total += 1
                new_plan, dcs = greedy_order_plan(PAT, synced)
                # Theorem 1 at d=0: a violation implies a new plan.
                assert new_plan != plans[p]
                plans[p] = new_plan
                rows[p] = np.asarray(new_plan.order, np.int32)
                pols[p].on_replan(new_plan, dcs, synced)
                write_lowered_row(low, p, pols[p].compile(PAT.n))
    assert fired_total > 0, "drifting stream never fired — test is vacuous"


@pytest.mark.parametrize("k", [1, 4])
def test_monitored_runner_matches_oracle(k):
    """Flag-triggered (deferred) replans keep exactly-once detection."""
    scfg = StreamConfig(n_types=3, n_chunks=30, chunk_cap=256,
                       base_rate=12.0, seed=5)

    def streams():
        return [make_stream("traffic", dataclasses.replace(scfg, seed=5 + p))
                for p in range(k)]

    runner = MonitoredFleetRunner(
        PAT, k, planner="greedy",
        policy_factory=lambda: InvariantPolicy(k=1, d=0.0),
        engine_cfg=EngineConfig(b_cap=128, m_cap=1024))
    m = runner.run(stacked_streams(streams()))
    oracle = [RefEngine(PAT).run(s).full_matches for s in streams()]
    assert m.per_partition_matches.tolist() == oracle
    assert m.full_matches == sum(oracle)
    # Host control work scales with violations, not with K·chunks.
    assert m.host_syncs == m.violations == m.replans
    assert m.host_syncs < m.chunks * k
    assert m.last_drift is not None and m.last_drift.shape == (k,)


def test_monitored_runner_overflow_escalation_matches_oracle():
    """Tiny caps force truncation; the plain escalation recount must not
    double-update the device statistics ring (counts stay exact)."""
    scfg = StreamConfig(n_types=3, n_chunks=12, chunk_cap=256,
                       base_rate=14.0, seed=9)

    def streams():
        return [make_stream("stocks", dataclasses.replace(scfg, seed=9 + p))
                for p in range(2)]

    runner = MonitoredFleetRunner(
        PAT, 2, planner="greedy",
        engine_cfg=EngineConfig(b_cap=64, m_cap=64))
    m = runner.run(stacked_streams(streams()))
    oracle = [RefEngine(PAT).run(s).full_matches for s in streams()]
    assert m.escalations > 0
    assert m.per_partition_matches.tolist() == oracle


def test_monitored_single_stream_engine(rng):
    """The K = 1 building block: fused step flags == host policy."""
    stream = make_stream("stocks", StreamConfig(
        n_types=3, n_chunks=15, chunk_cap=128, base_rate=8.0, seed=3))
    eng = MonitoredEngine("order", PAT, CFG)
    state, mon = eng.init_state(), eng.init_monitor()
    stat0 = uniform_stat(PAT.n)
    plan, dcs = greedy_order_plan(PAT, stat0)
    pol = InvariantPolicy(k=1, d=0.0)
    pol.on_replan(plan, dcs, stat0)
    low = pol.compile(PAT.n)
    caps = (low.active.shape[0], low.scale.shape[-1])
    fired = 0
    for rec in stream:
        state, mon, res, violated, drift, rates, sel = eng.process_chunk(
            state, mon, rec.chunk, eng.plan_row(plan), low,
            rec.t0, rec.t1)
        synced = Stat(np.asarray(rates, np.float64),
                      np.asarray(sel, np.float64))
        v_np, drift_np = check_lowered_np(
            low, np.asarray(rates), np.asarray(sel))
        _assert_flags_agree(np.asarray(violated), v_np, drift_np,
                            pol.should_reoptimize(synced))
        if np.asarray(violated):
            fired += 1
            plan, dcs = greedy_order_plan(PAT, synced)
            pol.on_replan(plan, dcs, synced)
            low = pol.compile(PAT.n, *caps)
    assert fired > 0


def test_monitored_serving_engine_vs_oracle(rng):
    """Violation-triggered replans in the serving front: counts stay
    oracle-exact and host syncs equal the number of fired flags."""
    from repro.serving import CEPFleetServingEngine  # noqa: F401
    from repro.serving import CEPStreamRouter, MonitoredCEPFleetServingEngine

    k = 4
    pat = seq_pattern([0, 1, 2], 10.0,
                      chain_predicates([0, 1, 2], theta=0.5))
    eng = MonitoredCEPFleetServingEngine(
        pat, k, EngineConfig(b_cap=128, m_cap=1024), chunk_cap=256)
    router = CEPStreamRouter(eng, slice_duration=5.0)
    n = 200
    ts = np.sort(rng.uniform(0, 20, n)).astype(np.float32)
    tid = rng.integers(0, 3, n).astype(np.int32)
    attr = rng.normal(size=(n, 1)).astype(np.float32)
    keys = rng.integers(0, 9, n)
    for i in range(n):
        router.submit(keys[i], tid[i], ts[i], attr[i])
    for _ in range(4):
        router.tick()
    oracle = []
    for p in range(k):
        ref = RefEngine(pat)
        tot = 0
        sel = (keys % k) == p
        for s in range(4):
            t0, t1 = 5.0 * s, 5.0 * (s + 1)
            m = sel & (ts > t0) & (ts <= t1)
            tot += ref.process_chunk(tid[m], ts[m], attr[m],
                                     t0, t1).full_matches
        oracle.append(tot)
    # Plan swaps between slices never change which matches are counted.
    assert eng.matches.tolist() == oracle
    tele = router.monitor_telemetry()
    assert tele is not None
    assert tele["host_syncs"] == int(eng.violations.sum())
    assert tele["last_drift"].shape == (k,)
