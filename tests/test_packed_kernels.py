"""Packed / rowcount kernel variants, block-size grids, autotune table,
and the cached predicate-strip path of the order engine.

Parity contract: the packed kernel must agree BIT-FOR-BIT with the
unpacked kernel over the equivalent stack (validity encoded as two f32
constraint rows), for every block tiling, op mix and shape — that is the
property that lets the engine switch kernels without perturbing a single
counter (asserted end-to-end by the superchunk differential tests).
"""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import autotune, ops
from repro.kernels.ref import (window_join_packed_ref, window_join_ref,
                               window_join_rowcount_ref)
from repro.kernels.window_join import (window_join_count_pallas,
                                       window_join_packed_pallas,
                                       window_join_pallas,
                                       window_join_rowcount_pallas)


def _case(rng, C, M, B):
    L = rng.normal(size=(C, M)).astype(np.float32)
    R = rng.normal(size=(C, B)).astype(np.float32)
    op = rng.integers(0, 4, size=(C,)).astype(np.int32)
    th = rng.normal(scale=0.5, size=(C,)).astype(np.float32)
    mv = (rng.random(M) > 0.3).astype(np.int8)
    bv = (rng.random(B) > 0.3).astype(np.int8)
    return L, R, op, th, mv, bv


def _unpacked_equiv(L, R, op, th, mv, bv):
    """Validity as two f32 rows — the pre-packing engine encoding."""
    C, M = L.shape
    B = R.shape[1]
    Lv = np.concatenate(
        [L, mv[None, :].astype(np.float32), np.ones((1, M), np.float32)])
    Rv = np.concatenate(
        [R, np.ones((1, B), np.float32), bv[None, :].astype(np.float32)])
    opv = np.concatenate([op, [2, 1]]).astype(np.int32)
    thv = np.concatenate([th, [0.5, 0.5]]).astype(np.float32)
    return np.asarray(window_join_ref(Lv, Rv, opv, thv))


# ---------------------------------------------------------------------------
# Packed kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C,M,B", [
    (1, 1, 1), (2, 7, 5), (4, 128, 128), (9, 130, 257),
    (16, 64, 300), (32, 256, 384),
])
def test_packed_matches_unpacked_and_interpret(C, M, B, rng):
    L, R, op, th, mv, bv = _case(rng, C, M, B)
    want = _unpacked_equiv(L, R, op, th, mv, bv)
    got_ref = np.asarray(ops.window_join_packed(
        L, R, op.astype(np.int8), th, mv, bv, backend="ref"))
    got_int = np.asarray(ops.window_join_packed(
        L, R, op.astype(np.int8), th, mv, bv, backend="interpret"))
    assert (want == got_ref).all()
    assert (want == got_int).all()


@pytest.mark.parametrize("bm,bb", [(8, 128), (32, 128), (128, 128),
                                   (128, 256), (256, 128)])
def test_packed_block_grid_parity(bm, bb, rng):
    """Every block tiling must give the identical mask (non-multiple
    M/B exercises the padded edge tiles; validity doubles as padding)."""
    C, M, B = 5, 130, 140
    L, R, op, th, mv, bv = _case(rng, C, M, B)
    want = np.asarray(window_join_packed_ref(L, R, op.astype(np.int8),
                                             th, mv, bv))
    got = np.asarray(window_join_packed_pallas(
        L, R, op.astype(np.int8), th, mv, bv,
        block_m=bm, block_b=bb, interpret=True))
    assert (want == got).all()


def test_packed_all_none_ops_respects_validity(rng):
    """A vacuous-True stack must still be masked by row validity — the
    padding-exactness regression of PR 5, restated for the packed layout
    where zero-padded validity IS the padding mask."""
    C, M, B = 3, 130, 129   # non-multiples: padded edge tiles exist
    L = rng.normal(size=(C, M)).astype(np.float32)
    R = rng.normal(size=(C, B)).astype(np.float32)
    op = np.zeros(C, np.int8)
    th = np.zeros(C, np.float32)
    mv = (rng.random(M) > 0.5).astype(np.int8)
    bv = (rng.random(B) > 0.5).astype(np.int8)
    got = np.asarray(ops.window_join_packed(L, R, op, th, mv, bv,
                                            backend="interpret"))
    want = (mv > 0)[:, None] & (bv > 0)[None, :]
    assert (got == want).all()
    assert got.sum() == int(mv.sum()) * int(bv.sum())


# ---------------------------------------------------------------------------
# Rowcount kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C,M,B", [
    (1, 1, 1), (2, 7, 5), (9, 130, 257), (32, 64, 300),
])
def test_rowcount_matches_dense_sum(C, M, B, rng):
    L, R, op, th, _, _ = _case(rng, C, M, B)
    want = np.asarray(ops.window_join(L, R, op, th,
                                      backend="ref")).sum(axis=1)
    got_ref = np.asarray(ops.window_join_rowcount(L, R, op, th,
                                                  backend="ref"))
    got_int = np.asarray(ops.window_join_rowcount(L, R, op, th,
                                                  backend="interpret"))
    assert (want == got_ref).all()
    assert (want == got_int).all()


@pytest.mark.parametrize("bm,bb", [(8, 128), (128, 128), (32, 256)])
def test_rowcount_block_grid_parity(bm, bb, rng):
    C, M, B = 4, 70, 200
    L, R, op, th, _, _ = _case(rng, C, M, B)
    want = np.asarray(window_join_rowcount_ref(L, R, op, th))
    got = np.asarray(window_join_rowcount_pallas(
        L, R, op, th, block_m=bm, block_b=bb, interpret=True))
    assert (want == got).all()


def test_rowcount_all_none_ops_counts_true_extent(rng):
    """Vacuous-True rows: each m must count exactly B (never the padded
    lane extent) across the j-accumulating grid."""
    C, M, B = 2, 130, 140
    L = rng.normal(size=(C, M)).astype(np.float32)
    R = rng.normal(size=(C, B)).astype(np.float32)
    got = np.asarray(window_join_rowcount_pallas(
        L, R, np.zeros(C, np.int32), np.zeros(C, np.float32),
        block_m=128, block_b=128, interpret=True))
    assert (got == B).all()


# ---------------------------------------------------------------------------
# Unpacked kernels: block-size grid (previously only default blocks)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bm,bb", [(8, 128), (128, 128), (256, 128)])
def test_unpacked_block_grid_parity(bm, bb, rng):
    C, M, B = 3, 130, 140
    L = rng.normal(size=(C, M)).astype(np.float32)
    R = rng.normal(size=(C, B)).astype(np.float32)
    op = rng.integers(0, 4, size=C).astype(np.int32)
    th = rng.normal(scale=0.5, size=C).astype(np.float32)
    want = np.asarray(window_join_ref(L, R, op, th))
    got = np.asarray(window_join_pallas(L, R, op, th, block_m=bm,
                                        block_b=bb, interpret=True))
    assert (want == got).all()
    cnt = int(window_join_count_pallas(L, R, op, th, block_m=bm,
                                       block_b=bb, interpret=True))
    assert cnt == int(want.sum())


# ---------------------------------------------------------------------------
# Small-shape fast path
# ---------------------------------------------------------------------------


def test_small_shape_fast_path_dispatches_to_ref(rng):
    """Below a tile's worth of work the pallas entry points return the
    jnp reference WITHOUT building a pallas_call — so they must work on
    CPU with interpret=False (where a real pallas lowering would fail)
    and agree with the oracle exactly."""
    for (C, M, B) in [(2, 3, 4), (4, 16, 8), (1, 1, 1), (3, 8, 64)]:
        L, R, op, th, mv, bv = _case(rng, C, M, B)
        want = np.asarray(window_join_ref(L, R, op, th))
        got = np.asarray(window_join_pallas(L, R, op, th))
        assert (want == got).all(), (C, M, B)
        assert int(window_join_count_pallas(L, R, op, th)) == want.sum()
        wantp = np.asarray(window_join_packed_ref(
            L, R, op.astype(np.int8), th, mv, bv))
        gotp = np.asarray(window_join_packed_pallas(
            L, R, op.astype(np.int8), th, mv, bv))
        assert (wantp == gotp).all(), (C, M, B)
        gotc = np.asarray(window_join_rowcount_pallas(L, R, op, th))
        assert (gotc == want.sum(axis=1)).all(), (C, M, B)


def test_tile_waste_predicate():
    from repro.kernels.window_join import _tile_waste
    assert _tile_waste(4, 4, 128, 128)        # tiny: under a tile of work
    assert _tile_waste(256, 4, 128, 128)      # B=4 pads 32x
    assert not _tile_waste(256, 128, 128, 128)
    assert not _tile_waste(4096, 256, 128, 128)


# ---------------------------------------------------------------------------
# Autotune table
# ---------------------------------------------------------------------------


def test_autotune_roundtrip_and_fallback(tmp_path, monkeypatch):
    path = str(tmp_path / "tab.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", path)
    autotune.invalidate_cache()
    try:
        # Missing table -> default blocks.
        assert autotune.best_blocks(8, 256, 128, plat="cpu") == (128, 128)
        key = f"cpu/{autotune.shape_class(8, 256, 128)}"
        autotune.save_table(
            {key: {"block_m": 32, "block_b": 256, "us": 1.0,
                   "kernel": "packed"}}, path)
        autotune.invalidate_cache()
        assert autotune.best_blocks(8, 256, 128, plat="cpu") == (32, 256)
        # Shape-class bucketing: nearby shapes share the pow2 bucket.
        assert autotune.best_blocks(8, 200, 100, plat="cpu") == (32, 256)
        # Unknown class / platform -> default.
        assert autotune.best_blocks(9, 256, 128, plat="cpu") == (128, 128)
        assert autotune.best_blocks(8, 256, 128, plat="tpu") == (128, 128)
    finally:
        autotune.invalidate_cache()


def test_autotune_env_disable(monkeypatch):
    """Empty REPRO_AUTOTUNE_TABLE disables the table entirely."""
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", "")
    autotune.invalidate_cache()
    try:
        assert autotune.best_blocks(8, 256, 128, plat="cpu") == (128, 128)
    finally:
        autotune.invalidate_cache()


def test_autotune_corrupt_table_is_ignored(tmp_path, monkeypatch):
    path = str(tmp_path / "bad.json")
    with open(path, "w") as fh:
        fh.write("{not json")
    monkeypatch.setenv("REPRO_AUTOTUNE_TABLE", path)
    autotune.invalidate_cache()
    try:
        assert autotune.load_table(path) == {}
        assert autotune.best_blocks(8, 256, 128, plat="cpu") == (128, 128)
    finally:
        autotune.invalidate_cache()


def test_committed_table_schema():
    """The committed table (if present) must parse and carry the schema
    the kernel wrappers expect."""
    import os
    path = autotune.default_table_path()
    if not os.path.exists(path):
        pytest.skip("no committed autotune table")
    with open(path) as fh:
        payload = json.load(fh)
    assert payload["schema"] == "autotune/v1"
    for key, e in payload["entries"].items():
        assert "/" in key
        assert e["block_m"] in autotune.BLOCK_M_CANDIDATES
        assert e["block_b"] in autotune.BLOCK_B_CANDIDATES


# ---------------------------------------------------------------------------
# Cached predicate strips (order engine)
# ---------------------------------------------------------------------------


def _mk_engine(backend="ref"):
    from repro.core.engine import EngineConfig, OrderEngine
    from repro.core.patterns import chain_predicates, seq_pattern

    pat = seq_pattern([0, 1, 2], 10.0,
                      chain_predicates([0, 1, 2], theta=0.4))
    return OrderEngine(pat, EngineConfig(b_cap=16, m_cap=32,
                                         backend=backend))


def _mk_chunk(rng, cap=24):
    from repro.core.engine import Chunk

    tid = rng.integers(0, 3, cap).astype(np.int32)
    ts = np.sort(rng.uniform(0.0, 4.0, cap)).astype(np.float32)
    attr = rng.normal(size=(cap, 1)).astype(np.float32)
    return Chunk(jnp.asarray(tid), jnp.asarray(ts), jnp.asarray(attr),
                 jnp.ones(cap, bool))


@pytest.mark.parametrize("order", [(0, 1, 2), (2, 1, 0), (1, 0, 2)])
def test_plan_operands_path_bit_identical(order, rng):
    """process(raw row) and process(PlanOperands) must agree exactly —
    the strips derivation commutes with hoisting."""
    import jax

    eng = _mk_engine()
    chunk = _mk_chunk(rng)
    row = jnp.asarray(order, jnp.int32)
    args = (jnp.float32(0.0), jnp.float32(4.0),
            jnp.float32(-3.0e38), jnp.float32(3.0e38))
    buf_a, res_a = jax.jit(eng.process_fn)(
        eng.init_state(), chunk, row, *args)
    buf_b, res_b = jax.jit(eng.process_fn)(
        eng.init_state(), chunk, eng.plan_operands(row), *args)
    for fa, fb in zip(res_a, res_b):
        assert np.array_equal(np.asarray(fa), np.asarray(fb))
    for la, lb in zip(buf_a, buf_b):
        assert np.array_equal(np.asarray(la), np.asarray(lb))


def test_build_order_strips_structure():
    from repro.core.engine import (build_order_strips, packed_row_count)

    eng = _mk_engine()
    spec = eng.spec
    C = packed_row_count(spec)
    # seq + chain predicates (0,1),(1,2): 2 window + 2 order + 4 pred.
    assert C == 8
    strips = build_order_strips(spec, jnp.asarray([0, 1, 2], jnp.int32))
    assert strips.ops8.shape == (2, C)
    ops8 = np.asarray(strips.ops8)
    # Window rows are always LT, GT.
    assert (ops8[:, 0] == 1).all() and (ops8[:, 1] == 2).all()
    # In-order placement: only the lower order anchor fires.
    assert (ops8[:, 2] == 1).all() and (ops8[:, 3] == 0).all()
    assert np.asarray(strips.lo_idx).tolist() == [0, 1]
    # Step 1 joins leaf 1: pred pair (0,1) is active in the (0,1)
    # orientation, pair (1,2) is not yet.
    assert ops8[0, 4] != 0 and ops8[0, 6] == 0
    # Step 2 joins leaf 2: pair (1,2) active in the (1,2) orientation.
    assert ops8[1, 6] != 0 and ops8[1, 4] == 0


def test_plan_operands_stacked(rng):
    """The vmapped (fleet) form: strips row k == strips(row k)."""
    eng = _mk_engine()
    rows = jnp.asarray([[0, 1, 2], [2, 1, 0]], jnp.int32)
    po = eng.plan_operands(rows)
    assert po.row.shape == (2, 3)
    assert po.strips.ops8.shape[0] == 2
    for i, order in enumerate([(0, 1, 2), (2, 1, 0)]):
        one = eng.plan_operands(jnp.asarray(order, jnp.int32))
        assert np.array_equal(np.asarray(po.strips.ops8[i]),
                              np.asarray(one.strips.ops8))
        assert np.array_equal(np.asarray(po.strips.lo_idx[i]),
                              np.asarray(one.strips.lo_idx))
