"""Superchunk scan + sharded fleet differentials.

The scanned data plane (``core/scan.py``) must be **bit-identical** to
per-chunk stepping for every superchunk size — match counts, violation
flags, replan points, deployed plans, escalations — because the optimistic
prefix re-run surfaces the host at exactly the chunks the per-chunk loop
would.  The sharded plane (``shard_map`` over the ``cep`` mesh axis) must
be bit-identical to the unsharded one because partitions are independent.
Both claims are asserted here against the per-chunk runners, which are
themselves pinned to the brute-force oracle by ``tests/test_session.py``.
"""

import dataclasses
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro import cep
from repro.cep import P, RuntimeConfig
from repro.core.decision import InvariantPolicy
from repro.core.engine import EngineConfig
from repro.core.fleet import MonitoredFleetRunner, stacked_streams
from repro.data.cep_streams import StreamConfig, make_stream
from repro.distributed.sharding import cep_mesh, resolve_cep_mesh

PATTERN = (P.seq(0, 1, 2)
           .where(P.attr(0) < P.attr(1) - 0.3,
                  P.attr(1) < P.attr(2) - 0.3)
           .within(4.0))
SCFG = StreamConfig(n_types=3, n_chunks=12, chunk_cap=128, base_rate=8.0)
CONFIG = RuntimeConfig(buffer_capacity=64, match_capacity=1024,
                       max_invariants=8, max_terms=16)

_COUNTER_FIELDS = (
    "chunks", "events", "full_matches", "pm_created", "overflow",
    "closure_expansions", "neg_rejected", "replans", "deployments",
    "escalations", "migration_partition_chunks", "violations", "host_syncs",
)


def streams(k, seed=11, kind="traffic", scfg=SCFG):
    return [make_stream(kind, dataclasses.replace(scfg, seed=seed + p))
            for p in range(k)]


def make_runner(k, superchunk=1, engine_cfg=None, mesh=None):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return MonitoredFleetRunner(
            PATTERN.build(), k, planner="greedy",
            policy_factory=lambda: InvariantPolicy(k=1, d=0.0),
            engine_cfg=engine_cfg or EngineConfig(b_cap=64, m_cap=1024),
            max_inv=8, max_terms=16, seed=0, superchunk=superchunk,
            mesh=mesh)


def assert_metrics_identical(a, b):
    """Every deterministic FleetMetrics field, bitwise."""
    for f in _COUNTER_FIELDS:
        assert getattr(a, f) == getattr(b, f), (
            f, getattr(a, f), getattr(b, f))
    assert a.per_partition_matches.tolist() == \
        b.per_partition_matches.tolist()
    assert a.per_partition_deployments.tolist() == \
        b.per_partition_deployments.tolist()
    if a.last_drift is None:
        assert b.last_drift is None
    else:
        assert np.array_equal(a.last_drift, b.last_drift)


@pytest.fixture(scope="module")
def per_chunk_baseline():
    """One per-chunk reference run shared by the scan grid (compiles are
    the dominant cost of this module; the baseline only needs to happen
    once)."""
    base = make_runner(4)
    m = base.run(stacked_streams(streams(4)))
    return base, m


def _check_scan_vs_baseline(superchunk, per_chunk_baseline):
    base, m1 = per_chunk_baseline
    scan = make_runner(4, superchunk=superchunk)
    ms = scan.run(stacked_streams(streams(4)))
    assert_metrics_identical(m1, ms)
    assert base.cur_plans == scan.cur_plans          # deployed plans
    assert np.array_equal(base._replan_t, scan._replan_t)  # replan points
    assert m1.violations > 0  # the stream must actually exercise the flags


@pytest.mark.parametrize("superchunk", [3, 8])
def test_scanned_equals_per_chunk(superchunk, per_chunk_baseline):
    """Window sizes that straddle and divide the stream both reproduce the
    per-chunk loop exactly — counters, flags, replan points, deployed
    plans and migration bookkeeping."""
    _check_scan_vs_baseline(superchunk, per_chunk_baseline)


@pytest.mark.slow
@pytest.mark.parametrize("superchunk", [2, 16])
def test_scanned_equals_per_chunk_grid(superchunk, per_chunk_baseline):
    """The rest of the size grid (2 = maximal boundary count, 16 = window
    longer than the stream) — compile-heavy, so opt-in via ``-m slow``."""
    _check_scan_vs_baseline(superchunk, per_chunk_baseline)


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["stocks"])
def test_scanned_equals_per_chunk_drifting(kind):
    """Frequent-drift regime: many in-window events -> many prefix
    re-runs; the optimistic restart must stay exact under pressure."""
    k = 4
    scfg = dataclasses.replace(SCFG, n_chunks=20)
    m1 = make_runner(k).run(stacked_streams(streams(k, 23, kind, scfg)))
    m8 = make_runner(k, superchunk=8).run(
        stacked_streams(streams(k, 23, kind, scfg)))
    assert_metrics_identical(m1, m8)


def test_scanned_escalation_differential():
    """Overflow escalation (truncated joins re-run at pow2 capacity) fires
    identically through the scanned plane — the acceptance criterion's
    'including under overflow escalation' clause."""
    k = 4
    cfg = EngineConfig(b_cap=32, m_cap=32)
    m1 = make_runner(k, engine_cfg=cfg).run(
        stacked_streams(streams(k, seed=7)))
    m8 = make_runner(k, superchunk=8, engine_cfg=cfg).run(
        stacked_streams(streams(k, seed=7)))
    assert m1.escalations > 0  # the capacity must actually truncate
    assert_metrics_identical(m1, m8)


def test_serving_superchunk_matches_step():
    """Incremental plane: step_superchunk == a loop of step ticks, for the
    monitored (flag -> immediate replan) serving front."""
    k = 4
    a = cep.open(PATTERN, partitions=k, plan="order", monitor=True,
                 config=CONFIG)
    b = cep.open(PATTERN, partitions=k, plan="order", monitor=True,
                 config=CONFIG, superchunk=4)
    recs = list(stacked_streams(streams(k, seed=31)))
    got_a = np.stack([a.step(fc.chunk, fc.t0, fc.t1) for fc in recs])
    got_b = b.step_superchunk([fc.chunk for fc in recs],
                              [(fc.t0, fc.t1) for fc in recs])
    assert got_a.tolist() == got_b.tolist()
    ta, tb = a.telemetry(), b.telemetry()
    for f in ("matches", "violations", "replans", "host_syncs", "overflow"):
        assert getattr(ta, f) == getattr(tb, f), f
    assert tb.violations > 0
    assert np.array_equal(ta.last_drift, tb.last_drift)


def test_serving_superchunk_plain():
    """Unmonitored serving front: static plans mean every window is one
    dispatch; counts must equal per-tick stepping."""
    k = 2
    a = cep.open(PATTERN, partitions=k, plan="order",
                 config=dataclasses.replace(CONFIG, policy=None))
    b = cep.open(PATTERN, partitions=k, plan="order",
                 config=dataclasses.replace(CONFIG, policy=None,
                                            superchunk=4))
    recs = list(stacked_streams(streams(k, seed=5)))
    got_a = np.stack([a.step(fc.chunk, fc.t0, fc.t1) for fc in recs])
    got_b = b.step_superchunk([fc.chunk for fc in recs],
                              [(fc.t0, fc.t1) for fc in recs])
    assert got_a.tolist() == got_b.tolist()
    assert a.telemetry().matches == b.telemetry().matches


# ---------------------------------------------------------------------------
# Sharded fleet (shard_map over the cep mesh axis)
# ---------------------------------------------------------------------------


def test_sharded_d1_run_smoke():
    """A single-device mesh runs the identical shard_map code path the
    multi-device deployment uses; results must match the unsharded run."""
    k = 4
    plain = make_runner(k, superchunk=8).run(stacked_streams(streams(k)))
    shard = make_runner(k, superchunk=8, mesh=1).run(
        stacked_streams(streams(k)))
    assert_metrics_identical(plain, shard)


def test_sharded_d1_serving_smoke():
    k = 2
    recs = list(stacked_streams(streams(k, seed=31)))
    plain = cep.open(PATTERN, partitions=k, plan="order", monitor=True,
                     config=CONFIG, superchunk=4)
    # mesh=1 rather than "auto": K=2 need not divide an arbitrary local
    # device count, and D=1 runs the same shard_map code path.
    shard = cep.open(PATTERN, partitions=k, plan="order", monitor=True,
                     config=CONFIG, superchunk=4, mesh=1)
    chunks = [fc.chunk for fc in recs]
    edges = [(fc.t0, fc.t1) for fc in recs]
    assert plain.step_superchunk(chunks, edges).tolist() == \
        shard.step_superchunk(chunks, edges).tolist()


def test_mesh_validation():
    import jax

    d = len(jax.devices())
    mesh = cep_mesh()
    assert resolve_cep_mesh(None, 4) is None
    assert resolve_cep_mesh("auto", 4 * d).shape["cep"] == d
    assert resolve_cep_mesh(mesh, 4 * d) is mesh
    with pytest.raises(ValueError, match="cep"):
        from jax.sharding import Mesh
        import jax
        resolve_cep_mesh(Mesh(np.asarray(jax.devices()[:1]), ("data",)), 4)
    with pytest.raises(TypeError):
        resolve_cep_mesh(3.5, 4)
    with pytest.raises(ValueError, match="devices"):
        cep_mesh(4096)


def test_superchunk_requires_monitor_on_batch_plane():
    sess = cep.open(PATTERN, partitions=2, plan="order", superchunk=8)
    with pytest.raises(ValueError, match="monitor=True"):
        sess.run(streams(2))


def test_superchunk_config_validation():
    with pytest.raises(ValueError, match="superchunk"):
        RuntimeConfig(superchunk=0)


@pytest.mark.slow
def test_sharded_d2_subprocess():
    """True multi-device sharding: force a 2-device CPU platform in a
    subprocess (the flag must be set before jax initializes) and assert
    the D=2 scanned run is bit-identical to the unsharded one."""
    script = textwrap.dedent("""
        import dataclasses, warnings
        import jax
        import numpy as np
        from repro import cep
        from repro.cep import P, RuntimeConfig
        from repro.data.cep_streams import StreamConfig, make_stream

        assert len(jax.devices()) == 2, jax.devices()
        pat = (P.seq(0, 1, 2)
               .where(P.attr(0) < P.attr(1) - 0.3,
                      P.attr(1) < P.attr(2) - 0.3)
               .within(4.0))
        scfg = StreamConfig(n_types=3, n_chunks=10, chunk_cap=128,
                            base_rate=8.0)
        cfg = RuntimeConfig(buffer_capacity=64, match_capacity=1024,
                            max_invariants=8, max_terms=16)
        def streams(k):
            return [make_stream("traffic",
                                dataclasses.replace(scfg, seed=11 + p))
                    for p in range(k)]
        # K must divide over the mesh (untestable on a 1-device platform).
        from repro.distributed.sharding import cep_mesh, resolve_cep_mesh
        try:
            resolve_cep_mesh(cep_mesh(2), 3)
        except ValueError as e:
            assert "divide" in str(e)
        else:
            raise AssertionError("K=3 over D=2 must raise")

        t0 = cep.open(pat, partitions=4, plan="order", monitor=True,
                      config=cfg, superchunk=8).run(streams(4))
        t2 = cep.open(pat, partitions=4, plan="order", monitor=True,
                      config=cfg, superchunk=8, mesh=2).run(streams(4))
        assert t0.per_partition_matches.tolist() == \\
            t2.per_partition_matches.tolist()
        assert t0.violations == t2.violations
        assert t0.deployments == t2.deployments
        print("D2OK", t2.per_partition_matches.tolist())
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"),
         env.get("PYTHONPATH", "")])
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "D2OK" in res.stdout
