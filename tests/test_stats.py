"""Sliding-window statistics estimation."""

import numpy as np

from repro.core.patterns import Predicate, PRED_LT, seq_pattern
from repro.core.stats import (SlidingWindowEstimator, Stat,
                              sample_selectivities, uniform_stat)


def test_rate_estimation_converges(rng):
    est = SlidingWindowEstimator(n=3, num_buckets=8)
    true = np.array([10.0, 3.0, 0.5])
    for _ in range(50):
        counts = rng.poisson(true * 2.0)
        est.update(counts, duration=2.0)
    got = est.snapshot().rates
    assert np.allclose(got, true, rtol=0.25)


def test_window_forgets_old_regime(rng):
    est = SlidingWindowEstimator(n=1, num_buckets=4)
    for _ in range(10):
        est.update(np.array([100.0]), 1.0)
    for _ in range(4):  # window length — old buckets fully evicted
        est.update(np.array([1.0]), 1.0)
    assert est.snapshot().rates[0] < 5.0


def test_selectivity_sampling(rng):
    pat = seq_pattern([0, 1], 10.0,
                      (Predicate(0, 1, PRED_LT, 0, 0, 0.0),))
    t = pat.pred_tensors()
    pos_of = {0: 0, 1: 1}
    # attrs of type 0 ~ N(-1), type 1 ~ N(+1): P(a0 < a1) ≈ 0.92
    tid = np.repeat([0, 1], 500).astype(np.int32)
    attrs = np.concatenate([rng.normal(-1, 1, (500, 1)),
                            rng.normal(1, 1, (500, 1))]).astype(np.float32)
    trials, hits = sample_selectivities(
        rng, tid, attrs, t, pos_of, 2, samples_per_pair=512)
    sel = hits[0, 1] / trials[0, 1]
    assert 0.8 < sel < 1.0


def test_unsampled_pairs_default_to_one():
    est = SlidingWindowEstimator(n=2)
    est.update(np.array([1.0, 1.0]), 1.0)
    s = est.snapshot()
    assert s.sel[0, 1] == 1.0


def test_stat_values_flat():
    s = uniform_stat(3, rate=2.0, sel=0.5)
    v = s.values()
    assert v.shape == (3 + 6,)
    assert (v[:3] == 2.0).all() and (v[3:] == 0.5).all()
