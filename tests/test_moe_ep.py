"""Expert-parallel (shard_map) MoE must equal the dense path bit-for-bit
(same routing, same capacity semantics) — subprocess with 8 host devices."""

import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_moe_ep_matches_dense():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.configs import get_smoke
        from repro.distributed.sharding import use_rules
        from repro.models.moe import moe_defs, moe_ffn, _moe_ffn_dense
        from repro.models.params import init_params

        cfg = get_smoke("deepseek-moe-16b")  # E=8 experts
        prm = init_params(moe_defs(cfg), jax.random.PRNGKey(0),
                          jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(4, 8, cfg.d_model)), jnp.float32)

        y_dense, aux_d, load_d = _moe_ffn_dense(x, prm, cfg)

        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(AxisType.Auto,) * 2)
        with use_rules(mesh):
            with mesh:
                y_ep, aux_e, load_e = jax.jit(
                    lambda x_, p_: moe_ffn(x_, p_, cfg))(x, prm)
        # NOTE: dense capacity uses global T, EP uses per-shard T; with
        # capacity_factor head-room and no overflow they agree exactly.
        err = float(jnp.abs(y_dense - y_ep).max())
        assert err < 1e-4, err
        assert np.allclose(np.asarray(load_d), np.asarray(load_e),
                           atol=1e-3), (load_d, load_e)
        # aux loss is computed per data shard then averaged (the standard
        # local-estimate definition) — close to, not equal to, the global
        # product of means.
        assert abs(float(aux_d) - float(aux_e)) < 0.05
        # gradient parity
        def loss_dense(p_):
            return jnp.sum(_moe_ffn_dense(x, p_, cfg)[0] ** 2)
        def loss_ep(p_):
            with use_rules(mesh):
                return jnp.sum(moe_ffn(x, p_, cfg)[0] ** 2)
        g1 = jax.grad(loss_dense)(prm)
        with mesh:
            g2 = jax.jit(jax.grad(loss_ep))(prm)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            d = float(jnp.abs(a - b).max())
            assert d < 2e-3, d
        print("MOE_EP_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=900)
    assert "MOE_EP_OK" in out.stdout, out.stderr[-3000:]
