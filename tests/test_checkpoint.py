"""Checkpoint manager: atomic save, retention, async, bf16, restore."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def make_state(v=1.0):
    return {
        "a": jnp.full((4, 3), v, jnp.float32),
        "nested": {"b": jnp.full((2,), v * 2, jnp.bfloat16),
                   "c": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = make_state(1.5)
    mgr.save(10, state)
    got = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        assert a.dtype == b.dtype
        assert np.allclose(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))


def test_latest_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_state(float(s)))
    assert mgr.steps() == [3, 4]
    got = mgr.restore(make_state(0.0))
    assert float(np.asarray(got["a"])[0, 0]) == 4.0


def test_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=5)
    mgr.save(1, make_state(1.0))
    mgr.save(2, make_state(2.0))
    got = mgr.restore(make_state(0.0), step=1)
    assert float(np.asarray(got["a"])[0, 0]) == 1.0


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_async(5, make_state(5.0))
    mgr.wait()
    assert mgr.latest_step() == 5


def test_no_tmp_left_behind(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state())
    assert not any(n.endswith(".tmp") for n in os.listdir(tmp_path))


def test_structure_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state())
    with pytest.raises(ValueError):
        mgr.restore({"a": jnp.zeros((4, 3))})


def test_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_state())
    bad = make_state()
    bad["a"] = jnp.zeros((5, 5))
    with pytest.raises(ValueError):
        mgr.restore(bad)


def test_resharding_restore_smoke(tmp_path):
    """Restore under an explicit (single-device) sharding — the cross-mesh
    path: leaves are saved unsharded and re-placed per target sharding."""
    from jax.sharding import NamedSharding, PartitionSpec, Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("x",))
    sh = NamedSharding(mesh, PartitionSpec())
    mgr = CheckpointManager(str(tmp_path))
    state = make_state(2.0)
    mgr.save(1, state)
    shardings = jax.tree.map(lambda _: sh, state)
    got = mgr.restore(state, shardings=shardings)
    assert got["a"].sharding == sh
