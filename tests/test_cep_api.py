"""The ``repro.cep`` public surface: ``__all__`` contract + DSL compiler.

The DSL tests assert *structural equality* with the hand-assembled
``core.patterns`` factories — the builder must compile to exactly the
``Pattern`` the engine already understands, with thetas folded per the
engine's op-code semantics (``a < b + θ`` / ``a > b − θ`` /
``|a − b| <= θ``)."""

import pytest

from repro import cep
from repro.cep import P, RuntimeConfig
from repro.core.patterns import (
    PRED_ABS_LE, PRED_GT, PRED_LT, CompositePattern, Operator, Predicate,
    and_pattern, chain_predicates, kleene_pattern, neg_pattern, seq_pattern,
)

# The documented surface (README "Public API"); CI asserts this import
# works and the sets match exactly.
DOCUMENTED_SURFACE = {
    "P", "open", "Session", "Telemetry", "RuntimeConfig",
    "Pattern", "CompositePattern", "OrderPlan", "TreePlan", "RefEngine",
    "open_rulebook", "Rulebook",
}


def test_public_surface_matches_documentation():
    assert set(cep.__all__) == DOCUMENTED_SURFACE
    for name in cep.__all__:
        assert getattr(cep, name) is not None


# ---------------------------------------------------------------------------
# DSL -> Pattern compilation
# ---------------------------------------------------------------------------


def test_seq_dsl_equals_factory():
    built = (P.seq(0, 1, 2)
             .where(P.attr(0) < P.attr(1) - 0.3,
                    P.attr(1) < P.attr(2) - 0.3)
             .within(4.0).named("seq").build())
    assert built == seq_pattern([0, 1, 2], 4.0,
                                chain_predicates([0, 1, 2], theta=-0.3))


def test_and_dsl_equals_factory():
    built = (P.and_(3, 1, 2)
             .where(P.attr(0) < P.attr(1) + 0.4,
                    P.attr(1) < P.attr(2) + 0.4)
             .within(15.0).named("and").build())
    assert built == and_pattern([3, 1, 2], 15.0,
                                chain_predicates([3, 1, 2], theta=0.4))


def test_theta_folding_and_ops():
    built = (P.seq(0, 1)
             .where(P.attr(0, 1) > P.attr(1, 0) - 0.2,
                    abs(P.attr(0) - P.attr(1)) <= 1.5)
             .within(9.0).build())
    assert built.predicates == (
        Predicate(0, 1, PRED_GT, 1, 0, pytest.approx(0.2)),
        Predicate(0, 1, PRED_ABS_LE, 0, 0, 1.5),
    )
    # shift on the left side folds with opposite sign: a - 1 < b  ⇔
    # a < b + 1
    lt = ((P.attr(0) - 1.0) < P.attr(1)).theta
    assert lt == pytest.approx(1.0)


def test_neg_dsl_equals_factory():
    built = (P.seq(0, P.neg(2), 1)
             .where(P.attr(0) < P.attr(1) + 0.5,
                    P.neg_attr(0) > P.attr(0) + 1.0)
             .within(20.0).named("neg").build())
    want = neg_pattern(
        [0, 1], 20.0, negated_type=2, negated_pos=1,
        predicates=(Predicate(0, 1, PRED_LT, 0, 0, 0.5),),
        negated_predicates=(Predicate(2, 0, PRED_GT, 0, 0, -1.0),))
    assert built == want
    assert built.operator is Operator.NEG


def test_kleene_dsl_equals_factory():
    built = (P.seq(0, P.kleene(1, bound=2), 2)
             .within(20.0).attrs(1).named("kleene").build())
    assert built == kleene_pattern([0, 1, 2], 20.0, kleene_pos=1,
                                   kleene_bound=2)


def test_or_composite_build():
    b1 = P.seq(0, 1).within(5.0)
    b2 = P.and_(2, 3).within(7.0)
    comp = P.or_(b1, b2).named("either").build()
    assert isinstance(comp, CompositePattern)
    assert comp.branches == (b1.build(), b2.build())
    assert comp.window == 7.0


def test_n_attrs_inferred_from_predicates():
    built = (P.seq(0, 1)
             .where(P.attr(0, 2) < P.attr(1, 0)).within(5.0).build())
    assert built.n_attrs == 3
    assert P.seq(0, 1).within(5.0).build().n_attrs == 1


def test_builders_are_immutable():
    base = P.seq(0, 1).within(5.0)
    refined = base.where(P.attr(0) < P.attr(1))
    assert base.build().predicates == ()
    assert len(refined.build().predicates) == 1


# ---------------------------------------------------------------------------
# DSL misuse surfaces as errors, never as a silently weaker pattern
# ---------------------------------------------------------------------------


def test_dsl_errors():
    with pytest.raises(ValueError, match="window"):
        P.seq(0, 1).build()
    with pytest.raises(TypeError, match="strict"):
        P.seq(0, 1).where(P.attr(0) <= P.attr(1)).within(5.0)
    with pytest.raises(TypeError, match="two attribute references"):
        P.attr(0) < 1.0
    with pytest.raises(ValueError, match="distinct"):
        P.seq(0, 0, 1).within(5.0).build()
    with pytest.raises(ValueError, match="out of range"):
        P.seq(0, 1).where(P.attr(2) < P.attr(0)).within(5.0).build()
    with pytest.raises(ValueError, match="no negated element"):
        P.seq(0, 1).where(P.neg_attr() < P.attr(0)).within(5.0).build()
    with pytest.raises(ValueError, match="at most one negated"):
        P.seq(0, P.neg(1), P.neg(2), 3).within(5.0).build()
    with pytest.raises(ValueError, match="require P.seq"):
        P.and_(0, P.neg(1), 2).within(5.0).build()
    with pytest.raises(ValueError, match="cannot be combined"):
        P.seq(0, P.neg(1), P.kleene(2), 3).within(5.0).build()
    with pytest.raises(ValueError, match="at least two branches"):
        P.or_(P.seq(0, 1).within(5.0))
    with pytest.raises(ValueError, match="shifts"):
        abs((P.attr(0) + 1.0) - P.attr(1)) <= 0.5
    with pytest.raises(TypeError):
        cep.open(42)
    # Python rewrites a < b < c as (a < b) and (b < c): truth-testing the
    # first Cond would silently drop it, so Cond refuses to be a boolean.
    with pytest.raises(TypeError, match="chained"):
        P.attr(0) < P.attr(1) < P.attr(2)


# ---------------------------------------------------------------------------
# RuntimeConfig consolidation
# ---------------------------------------------------------------------------


def test_runtime_config_adapters():
    cfg = RuntimeConfig(buffer_capacity=32, match_capacity=64,
                        policy="threshold", policy_kw={"t": 0.25})
    eng = cfg.engine()
    assert (eng.b_cap, eng.m_cap) == (32, 64)
    pol = cfg.policy_factory()()
    assert pol.name == "threshold" and pol.t == 0.25
    assert RuntimeConfig(policy=None).policy_factory() is None
    with pytest.raises(ValueError, match="match_capacity"):
        RuntimeConfig(buffer_capacity=128, match_capacity=64)
    with pytest.raises(ValueError, match="unknown policy"):
        RuntimeConfig(policy="bogus")
    with pytest.raises(ValueError, match="invariant"):
        cep.open(P.seq(0, 1).within(5.0), monitor=True,
                 config=RuntimeConfig(policy="threshold"))
    with pytest.raises(ValueError, match="order"):
        cep.open(P.seq(0, 1).within(5.0), plan="sideways")
