"""Rulebook: Q heterogeneous patterns behind one data plane per bucket.

The load-bearing property is *bitwise* equivalence: with zero overflow,
per-rule counters from one stacked dispatch must equal Q independent
monitored Sessions AND the brute-force oracle, through replans, hot
add/remove, and stream resume.  Overflow is asserted zero everywhere —
match-capacity truncation makes counts plan-dependent, so a failure here
means the test sizing is wrong, not the engine.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.cep as cep
from repro.cep import P, RuntimeConfig
from repro.cep.rulebook import open_rulebook
from repro.core import fleet
from repro.core.engine import Chunk
from repro.core.fleet import FleetChunk
from repro.core.greedy import greedy_order_plan
from repro.core.ref_engine import RefEngine
from repro.core.stats import uniform_stat

A = 2
K = 2
CAP = 24
CFG = RuntimeConfig(buffer_capacity=24, match_capacity=512,
                    estimator_buckets=8)


def rule_pool():
    """Mixed shapes: two shared-prefix SEQs, AND, pair, NEG, Kleene."""
    return [
        P.seq(0, 1, 2).where(P.attr(0, 0) < P.attr(1, 0) + 0.4)
            .within(2.0).attrs(A),
        P.seq(0, 1, 4).where(P.attr(0, 0) < P.attr(1, 0) + 0.4,
                             P.attr(1, 1) < P.attr(2, 0) + 0.3)
            .within(2.0).attrs(A),
        P.and_(3, 1, 4).where(P.attr(0, 1) < P.attr(2, 0) + 0.1)
            .within(2.0).attrs(A),
        P.seq(2, 4).within(1.5).attrs(A),
        P.seq(0, P.neg(3), 1, 2).where(P.attr(0, 0) < P.attr(1, 0) + 0.3)
            .within(3.0).attrs(A),
        P.seq(3, P.kleene(4, 2), 1).within(2.5).attrs(A),
        P.seq(4, 2, 0).where(P.attr(0, 1) < P.attr(1, 0) + 0.5)
            .within(1.5).attrs(A),
        P.and_(0, 2).within(1.0).attrs(A),
    ]


def make_chunks(rng, n_chunks, k=K):
    """Stacked chunks + the raw per-partition arrays for the oracle."""
    out = []
    for step in range(n_chunks):
        t0, t1 = float(step), float(step + 1)
        parts, raw = [], []
        for _ in range(k):
            n = int(rng.integers(4, 10))
            tid = rng.integers(0, 5, size=n).astype(np.int32)
            ts = np.sort(rng.uniform(t0, t1, size=n)).astype(np.float32)
            attr = rng.normal(size=(n, A)).astype(np.float32)
            raw.append((tid, ts, attr))
            pad = CAP - n
            parts.append(Chunk(
                type_id=jnp.asarray(np.pad(tid, (0, pad),
                                           constant_values=-1)),
                ts=jnp.asarray(np.pad(ts, (0, pad))),
                attr=jnp.asarray(np.pad(attr, ((0, pad), (0, 0)))),
                valid=jnp.asarray(np.arange(CAP) < n)))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
        out.append((stacked, raw, t0, t1))
    return out


def assert_no_overflow(rb, sessions):
    assert rb.telemetry().overflow == 0
    for s in sessions:
        assert s.telemetry().overflow == 0


@pytest.mark.parametrize("q", [2, 8])
def test_rulebook_equals_sessions_and_oracle(rng, q):
    rules = rule_pool()[:q]
    chunks = make_chunks(rng, 8)
    rb = open_rulebook(rules, partitions=K, monitor=True, config=CFG)
    sessions = [cep.open(r, partitions=K, monitor=True, config=CFG)
                for r in rules]
    refs = [[RefEngine(r.build()) for _ in range(K)] for r in rules]

    sess_counts = np.zeros((q, K), np.int64)
    ref_counts = np.zeros((q, K), np.int64)
    for stacked, raw, t0, t1 in chunks:
        rb.step(stacked, t0, t1)
        for i, s in enumerate(sessions):
            sess_counts[i] += np.asarray(s.step(stacked, t0, t1))
        for i in range(q):
            for k, (tid, ts, attr) in enumerate(raw):
                ref_counts[i, k] += refs[i][k].process_chunk(
                    tid, ts, attr, t0, t1).full_matches

    assert_no_overflow(rb, sessions)
    assert np.array_equal(rb.match_counts, sess_counts)
    assert np.array_equal(rb.match_counts, ref_counts)
    if q >= 2:
        # rules 0 and 1 share their (0, 1) opening join
        assert rb.sharing_ratio() > 1.0


def test_hot_add_remove_midstream(rng):
    rules = rule_pool()[:6]
    chunks = make_chunks(rng, 12)
    rb = open_rulebook(rules, partitions=K, monitor=True, config=CFG,
                       spare_slots=1)
    sessions = [cep.open(r, partitions=K, monitor=True, config=CFG)
                for r in rules]
    sess_counts = np.zeros((len(rules), K), np.int64)

    for stacked, _, t0, t1 in chunks[:5]:
        rb.step(stacked, t0, t1)
        for i, s in enumerate(sessions):
            sess_counts[i] += np.asarray(s.step(stacked, t0, t1))

    # hot add into the pre-provisioned spare slot: zero retraces even
    # after the next dispatch (the trace counter bumps lazily).
    new_rule = rule_pool()[6]
    pre = rb.trace_count()
    rid = rb.add_rule(new_rule)
    s_new = cep.open(new_rule, partitions=K, monitor=True, config=CFG)
    new_counts = np.zeros((K,), np.int64)
    for stacked, _, t0, t1 in chunks[5:9]:
        rb.step(stacked, t0, t1)
        for i, s in enumerate(sessions):
            sess_counts[i] += np.asarray(s.step(stacked, t0, t1))
        new_counts += np.asarray(s_new.step(stacked, t0, t1))
    assert rb.trace_count() == pre
    assert np.array_equal(rb.match_counts[rid], new_counts)
    assert np.array_equal(rb.match_counts[:6], sess_counts)

    # remove one shared-group member and the group's representative;
    # survivors must stay bit-identical and removed rows go silent.
    rb.remove_rule(1)
    rb.remove_rule(0)
    for stacked, _, t0, t1 in chunks[9:]:
        out = rb.step(stacked, t0, t1)
        assert out[0].sum() == 0 and out[1].sum() == 0
        for i, s in enumerate(sessions[2:], start=2):
            sess_counts[i] += np.asarray(s.step(stacked, t0, t1))
    assert np.array_equal(rb.match_counts[2:6], sess_counts[2:])
    assert 0 not in rb.rules and 1 not in rb.rules
    assert_no_overflow(rb, sessions[2:] + [s_new])


def test_bucket_growth_is_the_only_retrace(rng):
    # A buffer_capacity no other test uses: traces are shared process-wide
    # by (bucket, engine-config) key, so a config reused elsewhere may
    # already have the grown shape in cache and absorb the retrace.
    cfg = RuntimeConfig(buffer_capacity=28, match_capacity=512,
                        estimator_buckets=8)
    rules = [rule_pool()[3], rule_pool()[7]]  # one full n=2 bucket, no spare
    rb = open_rulebook(rules, partitions=K, monitor=True, config=cfg)
    chunks = make_chunks(rng, 4)
    stacked, _, t0, t1 = chunks[0]
    rb.step(stacked, t0, t1)
    pre = rb.trace_count()
    rb.add_rule(P.seq(1, 3).within(1.0).attrs(A))  # full -> cap 2 -> 4
    stacked, _, t0, t1 = chunks[1]
    rb.step(stacked, t0, t1)          # growth retraces on next dispatch
    assert rb.trace_count() == pre + 1
    rb.add_rule(P.seq(0, 4).within(1.0).attrs(A))  # doubled cap has room
    stacked, _, t0, t1 = chunks[2]
    rb.step(stacked, t0, t1)
    assert rb.trace_count() == pre + 1


def test_run_resume_segments(rng):
    rules = rule_pool()[:3]
    chunks = make_chunks(rng, 10)
    fcs = [FleetChunk(chunk=stacked, t0=t0, t1=t1)
           for stacked, _, t0, t1 in chunks]
    rb_one = open_rulebook(rules, partitions=K, monitor=True, config=CFG)
    tel = rb_one.run(fcs)
    rb_two = open_rulebook(rules, partitions=K, monitor=True, config=CFG)
    tel_a = rb_two.run(fcs[:5])
    tel_b = rb_two.run(fcs[5:])
    assert np.array_equal(rb_one.match_counts, rb_two.match_counts)
    assert tel.matches == tel_a.matches + tel_b.matches
    assert tel.chunks == tel_a.chunks + tel_b.chunks == 10


def test_mesh_d1_path_matches(rng):
    pytest.importorskip("jax")
    rules = rule_pool()[:2]
    chunks = make_chunks(rng, 4)
    cfg = RuntimeConfig(buffer_capacity=24, match_capacity=512,
                        estimator_buckets=8, mesh=1)
    rb_mesh = open_rulebook(rules, partitions=K, monitor=True, config=cfg)
    rb_plain = open_rulebook(rules, partitions=K, monitor=True, config=CFG)
    for stacked, _, t0, t1 in chunks:
        rb_mesh.step(stacked, t0, t1)
        rb_plain.step(stacked, t0, t1)
    assert np.array_equal(rb_mesh.match_counts, rb_plain.match_counts)


def test_rulebook_input_validation(rng):
    with pytest.raises(ValueError, match="OR"):
        open_rulebook([P.or_(P.seq(0, 1).within(2.0),
                             P.seq(1, 2).within(2.0))])
    with pytest.raises(ValueError, match="sharing"):
        RuntimeConfig(sharing="bogus")
    with pytest.raises(ValueError, match="partitions"):
        open_rulebook([P.seq(0, 1).within(2.0)], partitions=0)
    with pytest.raises(ValueError, match="invariant"):
        open_rulebook([P.seq(0, 1).within(2.0)], monitor=True,
                      config=RuntimeConfig(policy="threshold"))
    rb = open_rulebook([P.seq(0, 1).within(2.0).attrs(A)], partitions=K,
                       monitor=False, config=CFG)
    stacked, _, t0, t1 = make_chunks(rng, 1)[0]
    with pytest.raises(ValueError, match="attribute"):
        rb.step(Chunk(type_id=stacked.type_id, ts=stacked.ts,
                      attr=stacked.attr[..., :1], valid=stacked.valid),
                t0, t1)
    with pytest.raises(ValueError, match="stack"):
        rb.step(jax.tree.map(lambda x: x[0], stacked), t0, t1)


def test_greedy_pin_prefix():
    pat = rule_pool()[1].build()
    stat = uniform_stat(pat.n)
    free_plan, _ = greedy_order_plan(pat, stat)
    pin = tuple(int(o) for o in free_plan.order[:2])
    plan, dcs = greedy_order_plan(pat, stat, pin=pin)
    assert tuple(plan.order[:2]) == pin
    # pinned steps contribute no decision rows (nothing to re-decide)
    assert all(not rows for name, rows in dcs[:2])
    with pytest.raises(ValueError):
        greedy_order_plan(pat, stat, pin=(pat.n + 3,))


def test_trace_memo_lru_cap():
    """Churning engine configs must not grow the memo past its cap."""
    from repro.core.multipattern import BucketSpec, make_rulebook_plane

    fleet.clear_trace_memo()
    assert len(fleet._TRACE_MEMO) == 0
    bspec = BucketSpec(n=2, has_neg=False, has_kleene=False, n_attrs=1)
    cfg = CFG.engine()
    for i in range(fleet._TRACE_MEMO_CAP + 24):
        make_rulebook_plane(bspec, cfg, 1, False, laplace=2.0 + i)
        assert len(fleet._TRACE_MEMO) <= fleet._TRACE_MEMO_CAP
    assert len(fleet._TRACE_MEMO) == fleet._TRACE_MEMO_CAP
    # a hit must not insert a second entry
    size = len(fleet._TRACE_MEMO)
    make_rulebook_plane(bspec, cfg, 1, False,
                        laplace=2.0 + fleet._TRACE_MEMO_CAP + 23)
    assert len(fleet._TRACE_MEMO) == size
    fleet.clear_trace_memo()
    assert len(fleet._TRACE_MEMO) == 0
