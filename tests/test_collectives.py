"""Compressed int8 gradient all-reduce (subprocess: needs >1 device)."""

import os
import subprocess
import sys
import textwrap

import pytest


@pytest.mark.slow
def test_compressed_psum_subprocess():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.distributed.collectives import compressed_psum_tree
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(AxisType.Auto,))
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(size=(64, 32)), jnp.float32),
             "b": jnp.asarray(rng.normal(size=(128,)), jnp.float32)}
        out, ef = compressed_psum_tree(g, (), mesh, axis="data")
        # replicated input on every shard -> mean == input, up to int8
        # quantization error bounded by 2 quant steps
        for k in g:
            scale = float(jnp.abs(g[k]).max()) / 127.0
            err = float(jnp.abs(out[k] - g[k]).max())
            assert err <= 3 * scale, (k, err, scale)
            # error feedback holds the residual
            eerr = float(jnp.abs(ef[k]).max())
            assert eerr <= 2 * scale
        # error feedback compensates over repeated rounds: averaging the
        # outputs of EF-chained rounds converges to the true value
        acc = jax.tree.map(jnp.zeros_like, g)
        ef = ()
        n = 20
        for _ in range(n):
            o, ef = compressed_psum_tree(g, ef, mesh, axis="data")
            acc = jax.tree.map(lambda a, x: a + x / n, acc, o)
        for k in g:
            scale = float(jnp.abs(g[k]).max()) / 127.0
            err = float(jnp.abs(acc[k] - g[k]).max())
            assert err < 1.2 * scale, (k, err, scale)
        # the collectives on the wire are int8
        fn = lambda *leaves: None
        print("COMPRESSED_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=600)
    assert "COMPRESSED_OK" in out.stdout, out.stderr[-2000:]


@pytest.mark.slow
def test_compressed_collectives_are_int8_on_wire():
    """Lower the compressed all-reduce and assert the HLO moves s8."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import sys; sys.path.insert(0, "src")
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import AxisType
        from repro.distributed.collectives import compressed_psum_tree
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(AxisType.Auto,))
        g = {"w": jnp.zeros((256, 256), jnp.float32)}
        f = jax.jit(lambda x: compressed_psum_tree(x, (), mesh, "data"))
        txt = f.lower(g).compile().as_text()
        assert "all-to-all" in txt, "expected all-to-all reduce-scatter"
        import re
        coll_lines = [l for l in txt.splitlines()
                      if re.search(r"= .*(all-to-all|all-gather)", l)]
        assert any("s8[" in l for l in coll_lines), coll_lines[:5]
        print("WIRE_OK")
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=600)
    assert "WIRE_OK" in out.stdout, (out.stdout, out.stderr[-2000:])
