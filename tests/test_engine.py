"""CEP engine vs the brute-force oracle (``core.ref_engine``) over all
operators and both plan families, plus chunked exactly-once counting."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (Chunk, EngineConfig, OrderEngine, TreeEngine)
from repro.core.patterns import (
    PRED_ABS_LE, PRED_LT, Predicate, and_pattern, chain_predicates,
    kleene_pattern, neg_pattern, seq_pattern,
)
from repro.core.plans import OrderPlan, TreeNode, TreePlan
from repro.core.ref_engine import brute_force_matches


def gen_stream(rng, n_types, n_events, n_attrs=1, t_end=100.0):
    ts = np.sort(rng.uniform(0, t_end, n_events)).astype(np.float32)
    tid = rng.integers(0, n_types, n_events).astype(np.int32)
    attr = rng.normal(size=(n_events, n_attrs)).astype(np.float32)
    return tid, ts, attr


def as_chunk(tid, ts, attr):
    return Chunk(jnp.asarray(tid), jnp.asarray(ts), jnp.asarray(attr),
                 jnp.ones(len(ts), bool))


def brute_matches(pattern, tid, ts, attr, t0=-np.inf, t1=np.inf):
    return brute_force_matches(pattern, tid, ts, attr, t0, t1).full_matches


@pytest.mark.parametrize("order", [(0, 1, 2), (2, 1, 0), (1, 0, 2)])
def test_order_engine_seq_any_order(order, rng):
    pat = seq_pattern([0, 1, 2], 30.0,
                      chain_predicates([0, 1, 2], theta=0.3))
    tid, ts, attr = gen_stream(rng, 3, 60)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=512))
    st, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan(order),
        0.0, 200.0)
    assert int(res.full_matches) == brute_matches(pat, tid, ts, attr,
                                                  0.0, 200.0)


def test_order_engine_and(rng):
    pat = and_pattern([0, 1, 2], 20.0,
                      chain_predicates([0, 1, 2], theta=0.5))
    tid, ts, attr = gen_stream(rng, 3, 50)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=1024))
    st, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((2, 0, 1)),
        0.0, 200.0)
    assert int(res.full_matches) == brute_matches(pat, tid, ts, attr,
                                                  0.0, 200.0)


def test_tree_engine_all_shapes(rng):
    pat = seq_pattern([0, 1, 2, 3], 25.0,
                      chain_predicates([0, 1, 2, 3], theta=0.2))
    tid, ts, attr = gen_stream(rng, 4, 48)
    eng = TreeEngine(pat, EngineConfig(b_cap=64, m_cap=1024))
    N = TreeNode
    trees = [
        TreePlan(N(left=N(left=N(leaf=0), right=N(leaf=1)),
                   right=N(left=N(leaf=2), right=N(leaf=3)))),
        TreePlan(N(left=N(leaf=0),
                   right=N(left=N(leaf=1),
                           right=N(left=N(leaf=2), right=N(leaf=3))))),
        TreePlan(N(left=N(left=N(left=N(leaf=0), right=N(leaf=1)),
                          right=N(leaf=2)), right=N(leaf=3))),
    ]
    want = brute_matches(pat, tid, ts, attr, 0.0, 200.0)
    for tp in trees:
        st, res = eng.process_chunk(
            eng.init_state(), as_chunk(tid, ts, attr), tp, 0.0, 200.0)
        assert int(res.full_matches) == want, str(tp)


def test_chunked_counts_each_match_once(rng):
    pat = seq_pattern([0, 1, 2], 15.0,
                      chain_predicates([0, 1, 2], theta=1.0))
    tid, ts, attr = gen_stream(rng, 3, 80)
    eng = OrderEngine(pat, EngineConfig(b_cap=128, m_cap=1024))
    st = eng.init_state()
    total = 0
    edges = [0.0, 25.0, 50.0, 75.0, 100.0]
    for t0, t1 in zip(edges[:-1], edges[1:]):
        m = (ts > t0) & (ts <= t1)
        st, res = eng.process_chunk(
            st, as_chunk(tid[m], ts[m], attr[m]), OrderPlan((2, 1, 0)),
            t0, t1)
        total += int(res.full_matches)
    assert total == brute_matches(pat, tid, ts, attr, 0.0, 100.0)


def test_negation(rng):
    pat = neg_pattern(
        [0, 1], 20.0, negated_type=2, negated_pos=1,
        predicates=(Predicate(0, 1, PRED_LT, 0, 0, 0.5),),
        negated_predicates=(Predicate(2, 0, PRED_ABS_LE, 0, 0, 2.0),))
    tid, ts, attr = gen_stream(rng, 3, 60)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=512))
    st, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((1, 0)),
        0.0, 200.0)
    assert int(res.full_matches) == brute_matches(pat, tid, ts, attr,
                                                  0.0, 200.0)
    assert int(res.neg_rejected) > 0  # the veto actually exercised


def test_kleene_counts(rng):
    pat = kleene_pattern([0, 1, 2], 30.0, kleene_pos=1)
    tid, ts, attr = gen_stream(rng, 3, 40)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=1024))
    st, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((0, 1, 2)),
        0.0, 200.0)
    base = brute_matches(pat, tid, ts, attr, 0.0, 200.0)
    assert int(res.full_matches) == base
    assert int(res.closure_expansions) >= 0


def test_order_tree_agree(rng):
    pat = seq_pattern([0, 1, 2, 3], 25.0,
                      chain_predicates([0, 1, 2, 3], theta=0.4))
    tid, ts, attr = gen_stream(rng, 4, 60)
    oe = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=2048))
    te = TreeEngine(pat, EngineConfig(b_cap=64, m_cap=2048))
    _, r1 = oe.process_chunk(oe.init_state(), as_chunk(tid, ts, attr),
                             OrderPlan((3, 2, 1, 0)), 0.0, 200.0)
    N = TreeNode
    tp = TreePlan(N(left=N(left=N(leaf=0), right=N(leaf=1)),
                    right=N(left=N(leaf=2), right=N(leaf=3))))
    _, r2 = te.process_chunk(te.init_state(), as_chunk(tid, ts, attr),
                             tp, 0.0, 200.0)
    assert int(r1.full_matches) == int(r2.full_matches)


def test_overflow_accounting():
    # Tiny caps force overflow; count must be reported, not silently lost.
    rng = np.random.default_rng(1)
    pat = and_pattern([0, 1], 100.0)
    tid, ts, attr = gen_stream(rng, 2, 120)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=64))
    _, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((0, 1)),
        0.0, 200.0)
    assert int(res.overflow) > 0


def test_pm_created_tracks_plan_quality(rng):
    """The join-work metric must be lower for the rate-sorted order."""
    pat = seq_pattern([0, 1, 2], 10.0)
    # heavily skewed rates: type 0 frequent, type 2 rare
    tid = rng.choice(3, size=300, p=[0.8, 0.15, 0.05]).astype(np.int32)
    ts = np.sort(rng.uniform(0, 100, 300)).astype(np.float32)
    attr = rng.normal(size=(300, 1)).astype(np.float32)
    eng = OrderEngine(pat, EngineConfig(b_cap=256, m_cap=8192))
    _, good = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((2, 1, 0)),
        0.0, 200.0)
    _, bad = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((0, 1, 2)),
        0.0, 200.0)
    assert int(good.full_matches) == int(bad.full_matches)
    assert int(good.pm_created) < int(bad.pm_created)
