"""CEP engine vs a brute-force oracle over all operators and both plan
families, plus chunked exactly-once counting."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (Chunk, EngineConfig, OrderEngine, TreeEngine)
from repro.core.patterns import (
    PRED_ABS_LE, PRED_LT, Predicate, and_pattern, chain_predicates,
    kleene_pattern, neg_pattern, seq_pattern,
)
from repro.core.plans import OrderPlan, TreeNode, TreePlan


def gen_stream(rng, n_types, n_events, n_attrs=1, t_end=100.0):
    ts = np.sort(rng.uniform(0, t_end, n_events)).astype(np.float32)
    tid = rng.integers(0, n_types, n_events).astype(np.int32)
    attr = rng.normal(size=(n_events, n_attrs)).astype(np.float32)
    return tid, ts, attr


def as_chunk(tid, ts, attr):
    return Chunk(jnp.asarray(tid), jnp.asarray(ts), jnp.asarray(attr),
                 jnp.ones(len(ts), bool))


def brute_matches(pattern, tid, ts, attr, t0=-np.inf, t1=np.inf):
    n = pattern.n
    pt = pattern.pred_tensors()
    idx_by_pos = [np.nonzero(tid == t)[0] for t in pattern.type_ids]
    count = 0
    for combo in itertools.product(*idx_by_pos):
        tss = ts[list(combo)]
        if tss.max() - tss.min() > pattern.window:
            continue
        if pattern.is_sequence and not all(
                tss[i] < tss[i + 1] for i in range(n - 1)):
            continue
        ok = True
        for p in range(n):
            for q in range(n):
                if p == q or pt["op"][p, q] == 0:
                    continue
                a = attr[combo[p], pt["a_attr"][p, q]]
                b = attr[combo[q], pt["b_attr"][p, q]]
                th = pt["theta"][p, q]
                o = pt["op"][p, q]
                r = (a < b + th if o == 1 else
                     a > b - th if o == 2 else abs(a - b) <= th)
                if not r:
                    ok = False
                    break
            if not ok:
                break
        if not ok or not (t0 < tss.max() <= t1):
            continue
        if pattern.negated_type is not None:
            npos = pattern.negated_pos
            lo = tss[npos - 1] if npos and npos > 0 else -np.inf
            hi = tss[npos] if npos is not None and npos < n else np.inf
            vetoed = False
            for j in np.nonzero(tid == pattern.negated_type)[0]:
                if not (lo < ts[j] < hi):
                    continue
                if (max(tss.max(), ts[j]) - min(tss.min(), ts[j])
                        > pattern.window):
                    continue
                okn = True
                for pr in pattern.negated_predicates:
                    if pr.a_type == pattern.negated_type:
                        a = attr[j, pr.a_attr]
                        b = attr[combo[list(pattern.type_ids).index(
                            pr.b_type)], pr.b_attr]
                    else:
                        a = attr[combo[list(pattern.type_ids).index(
                            pr.a_type)], pr.a_attr]
                        b = attr[j, pr.b_attr]
                    r = (a < b + pr.theta if pr.op == 1 else
                         a > b - pr.theta if pr.op == 2 else
                         abs(a - b) <= pr.theta)
                    if not r:
                        okn = False
                        break
                if okn:
                    vetoed = True
                    break
            if vetoed:
                continue
        count += 1
    return count


@pytest.mark.parametrize("order", [(0, 1, 2), (2, 1, 0), (1, 0, 2)])
def test_order_engine_seq_any_order(order, rng):
    pat = seq_pattern([0, 1, 2], 30.0,
                      chain_predicates([0, 1, 2], theta=0.3))
    tid, ts, attr = gen_stream(rng, 3, 60)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=512))
    st, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan(order),
        0.0, 200.0)
    assert int(res.full_matches) == brute_matches(pat, tid, ts, attr,
                                                  0.0, 200.0)


def test_order_engine_and(rng):
    pat = and_pattern([0, 1, 2], 20.0,
                      chain_predicates([0, 1, 2], theta=0.5))
    tid, ts, attr = gen_stream(rng, 3, 50)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=1024))
    st, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((2, 0, 1)),
        0.0, 200.0)
    assert int(res.full_matches) == brute_matches(pat, tid, ts, attr,
                                                  0.0, 200.0)


def test_tree_engine_all_shapes(rng):
    pat = seq_pattern([0, 1, 2, 3], 25.0,
                      chain_predicates([0, 1, 2, 3], theta=0.2))
    tid, ts, attr = gen_stream(rng, 4, 48)
    eng = TreeEngine(pat, EngineConfig(b_cap=64, m_cap=1024))
    N = TreeNode
    trees = [
        TreePlan(N(left=N(left=N(leaf=0), right=N(leaf=1)),
                   right=N(left=N(leaf=2), right=N(leaf=3)))),
        TreePlan(N(left=N(leaf=0),
                   right=N(left=N(leaf=1),
                           right=N(left=N(leaf=2), right=N(leaf=3))))),
        TreePlan(N(left=N(left=N(left=N(leaf=0), right=N(leaf=1)),
                          right=N(leaf=2)), right=N(leaf=3))),
    ]
    want = brute_matches(pat, tid, ts, attr, 0.0, 200.0)
    for tp in trees:
        st, res = eng.process_chunk(
            eng.init_state(), as_chunk(tid, ts, attr), tp, 0.0, 200.0)
        assert int(res.full_matches) == want, str(tp)


def test_chunked_counts_each_match_once(rng):
    pat = seq_pattern([0, 1, 2], 15.0,
                      chain_predicates([0, 1, 2], theta=1.0))
    tid, ts, attr = gen_stream(rng, 3, 80)
    eng = OrderEngine(pat, EngineConfig(b_cap=128, m_cap=1024))
    st = eng.init_state()
    total = 0
    edges = [0.0, 25.0, 50.0, 75.0, 100.0]
    for t0, t1 in zip(edges[:-1], edges[1:]):
        m = (ts > t0) & (ts <= t1)
        st, res = eng.process_chunk(
            st, as_chunk(tid[m], ts[m], attr[m]), OrderPlan((2, 1, 0)),
            t0, t1)
        total += int(res.full_matches)
    assert total == brute_matches(pat, tid, ts, attr, 0.0, 100.0)


def test_negation(rng):
    pat = neg_pattern(
        [0, 1], 20.0, negated_type=2, negated_pos=1,
        predicates=(Predicate(0, 1, PRED_LT, 0, 0, 0.5),),
        negated_predicates=(Predicate(2, 0, PRED_ABS_LE, 0, 0, 2.0),))
    tid, ts, attr = gen_stream(rng, 3, 60)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=512))
    st, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((1, 0)),
        0.0, 200.0)
    assert int(res.full_matches) == brute_matches(pat, tid, ts, attr,
                                                  0.0, 200.0)
    assert int(res.neg_rejected) > 0  # the veto actually exercised


def test_kleene_counts(rng):
    pat = kleene_pattern([0, 1, 2], 30.0, kleene_pos=1)
    tid, ts, attr = gen_stream(rng, 3, 40)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=1024))
    st, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((0, 1, 2)),
        0.0, 200.0)
    base = brute_matches(pat, tid, ts, attr, 0.0, 200.0)
    assert int(res.full_matches) == base
    assert int(res.closure_expansions) >= 0


def test_order_tree_agree(rng):
    pat = seq_pattern([0, 1, 2, 3], 25.0,
                      chain_predicates([0, 1, 2, 3], theta=0.4))
    tid, ts, attr = gen_stream(rng, 4, 60)
    oe = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=2048))
    te = TreeEngine(pat, EngineConfig(b_cap=64, m_cap=2048))
    _, r1 = oe.process_chunk(oe.init_state(), as_chunk(tid, ts, attr),
                             OrderPlan((3, 2, 1, 0)), 0.0, 200.0)
    N = TreeNode
    tp = TreePlan(N(left=N(left=N(leaf=0), right=N(leaf=1)),
                    right=N(left=N(leaf=2), right=N(leaf=3))))
    _, r2 = te.process_chunk(te.init_state(), as_chunk(tid, ts, attr),
                             tp, 0.0, 200.0)
    assert int(r1.full_matches) == int(r2.full_matches)


def test_overflow_accounting():
    # Tiny caps force overflow; count must be reported, not silently lost.
    rng = np.random.default_rng(1)
    pat = and_pattern([0, 1], 100.0)
    tid, ts, attr = gen_stream(rng, 2, 120)
    eng = OrderEngine(pat, EngineConfig(b_cap=64, m_cap=64))
    _, res = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((0, 1)),
        0.0, 200.0)
    assert int(res.overflow) > 0


def test_pm_created_tracks_plan_quality(rng):
    """The join-work metric must be lower for the rate-sorted order."""
    pat = seq_pattern([0, 1, 2], 10.0)
    # heavily skewed rates: type 0 frequent, type 2 rare
    tid = rng.choice(3, size=300, p=[0.8, 0.15, 0.05]).astype(np.int32)
    ts = np.sort(rng.uniform(0, 100, 300)).astype(np.float32)
    attr = rng.normal(size=(300, 1)).astype(np.float32)
    eng = OrderEngine(pat, EngineConfig(b_cap=256, m_cap=8192))
    _, good = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((2, 1, 0)),
        0.0, 200.0)
    _, bad = eng.process_chunk(
        eng.init_state(), as_chunk(tid, ts, attr), OrderPlan((0, 1, 2)),
        0.0, 200.0)
    assert int(good.full_matches) == int(bad.full_matches)
    assert int(good.pm_created) < int(bad.pm_created)
