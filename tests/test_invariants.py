"""Property tests for the paper's core guarantees (Theorems 1 & 2)."""

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.decision import (InvariantPolicy, ThresholdPolicy,
                                 UnconditionalPolicy, make_policy)
from repro.core.greedy import greedy_order_plan
from repro.core.invariants import (InvariantSet, d_avg_estimate,
                                   eval_sum, make_variance_violation_prob,
                                   select_invariants)
from repro.core.patterns import chain_predicates, seq_pattern
from repro.core.stats import Stat
from repro.core.zstream import zstream_tree_plan


def rand_stat(rng, n):
    rates = rng.uniform(0.5, 20.0, n)
    sel = rng.uniform(0.05, 0.95, (n, n))
    sel = (sel + sel.T) / 2
    np.fill_diagonal(sel, 1.0)
    return Stat(rates, sel)


def drift(rng, stat, scale):
    rates = stat.rates * np.exp(rng.normal(0, scale, stat.n))
    sel = np.clip(stat.sel * np.exp(rng.normal(0, scale / 2,
                                               stat.sel.shape)), 0.01, 1.0)
    sel = (sel + sel.T) / 2
    np.fill_diagonal(sel, 1.0)
    return Stat(rates, sel)


PLANNERS = [greedy_order_plan, zstream_tree_plan]


@pytest.mark.parametrize("planner", PLANNERS)
@settings(max_examples=60, deadline=None)
@given(n=st.integers(3, 6), seed=st.integers(0, 10_000),
       dscale=st.floats(0.05, 1.0))
def test_theorem1_no_false_positives(planner, n, seed, dscale):
    """K=all, d=0: if D fires, A provably returns a DIFFERENT plan.

    (We verify the strongest variant — every deciding condition as an
    invariant — since Theorem 1 holds a fortiori for the K-selected
    subset.)
    """
    rng = np.random.default_rng(seed)
    pat = seq_pattern(list(range(n)), 10.0,
                      chain_predicates(list(range(n)), theta=0.1))
    stat0 = rand_stat(rng, n)
    plan0, dcs = planner(pat, stat0)
    invs = select_invariants(dcs, stat0, strategy="all")
    iset = InvariantSet(invs, d=0.0)
    for _ in range(5):
        stat1 = drift(rng, stat0, dscale)
        if iset.check(stat1):
            plan1, _ = planner(pat, stat1)
            assert plan1 != plan0, (
                "invariant fired but A returned the same plan "
                f"(seed={seed}, planner={planner.__name__})")


@settings(max_examples=60, deadline=None)
@given(n=st.integers(3, 6), seed=st.integers(0, 10_000),
       dscale=st.floats(0.05, 1.0))
def test_theorem2_no_false_negatives_greedy(n, seed, dscale):
    """All DCS conditions kept: plan change ⟹ some invariant violated."""
    rng = np.random.default_rng(seed)
    pat = seq_pattern(list(range(n)), 10.0,
                      chain_predicates(list(range(n)), theta=0.1))
    stat0 = rand_stat(rng, n)
    plan0, dcs = greedy_order_plan(pat, stat0)
    invs = select_invariants(dcs, stat0, strategy="all")
    iset = InvariantSet(invs, d=0.0)
    for _ in range(5):
        stat1 = drift(rng, stat0, dscale)
        plan1, _ = greedy_order_plan(pat, stat1)
        if plan1 != plan0:
            assert iset.check(stat1), (
                f"plan changed but no invariant fired (seed={seed})")


def test_k_invariant_monotone_sensitivity(rng):
    """Higher K can only catch MORE violations (fewer false negatives)."""
    n = 5
    pat = seq_pattern(list(range(n)), 10.0,
                      chain_predicates(list(range(n)), theta=0.1))
    stat0 = rand_stat(rng, n)
    _, dcs = greedy_order_plan(pat, stat0)
    sets = {
        k: InvariantSet(select_invariants(dcs, stat0, k=k), d=0.0)
        for k in (1, 2, 4)
    }
    fired = {k: 0 for k in sets}
    for i in range(200):
        stat1 = drift(np.random.default_rng(i), stat0, 0.3)
        for k, s in sets.items():
            fired[k] += int(s.check(stat1))
    assert fired[1] <= fired[2] <= fired[4]


def test_distance_d_damps_firing(rng):
    n = 4
    pat = seq_pattern(list(range(n)), 10.0)
    stat0 = rand_stat(rng, n)
    _, dcs = greedy_order_plan(pat, stat0)
    invs = select_invariants(dcs, stat0)
    counts = []
    for d in (0.0, 0.2, 0.5):
        s = InvariantSet(invs, d=d)
        counts.append(sum(
            s.check(drift(np.random.default_rng(i), stat0, 0.25))
            for i in range(200)))
    assert counts[0] >= counts[1] >= counts[2]
    assert counts[0] > counts[2]  # d actually does something


def test_vectorized_check_matches_scalar(rng):
    n = 5
    pat = seq_pattern(list(range(n)), 10.0,
                      chain_predicates(list(range(n)), theta=0.2))
    stat0 = rand_stat(rng, n)
    _, dcs = zstream_tree_plan(pat, stat0)
    invs = select_invariants(dcs, stat0, strategy="all")
    iset = InvariantSet(invs, d=0.1)
    for i in range(20):
        stat1 = drift(np.random.default_rng(i), stat0, 0.4)
        slow = any(not c.holds(stat1, d=0.1) for c in invs)
        assert iset.check(stat1) == slow


def test_d_avg_estimate_positive(rng):
    n = 5
    pat = seq_pattern(list(range(n)), 10.0)
    stat = rand_stat(rng, n)
    _, dcs = greedy_order_plan(pat, stat)
    d = d_avg_estimate(dcs, stat)
    assert d > 0.0


def test_violation_prob_strategy(rng):
    n = 4
    pat = seq_pattern(list(range(n)), 10.0)
    stat = rand_stat(rng, n)
    _, dcs = greedy_order_plan(pat, stat)
    prob = make_variance_violation_prob(
        std_rates=np.full(n, 1.0), std_sel=np.full((n, n), 0.1))
    invs = select_invariants(dcs, stat, strategy="prob",
                             violation_prob=prob)
    assert len(invs) == sum(1 for _, c in dcs if c)
    # a zero-variance estimator gives prob 0 for holding conditions
    prob0 = make_variance_violation_prob(np.zeros(n), np.zeros((n, n)))
    for _, conds in dcs:
        for c in conds:
            assert prob0(c, stat) in (0.0, 1.0)


def test_zstream_exact_vs_paper_freeze():
    """freeze='none' (exact live cost sums) eliminates the false positives
    the paper's frozen-constant trick incurs under large drifts."""
    import functools
    stats = {}
    for mode in ("none", "paper"):
        planner = functools.partial(zstream_tree_plan, freeze=mode)
        fp = 0
        for seed in range(60):
            rng = np.random.default_rng(seed)
            n = int(rng.integers(3, 7))
            pat = seq_pattern(list(range(n)), 10.0,
                              chain_predicates(list(range(n)), theta=0.1))
            s0 = rand_stat(rng, n)
            p0, dcs = planner(pat, s0)
            iset = InvariantSet(
                select_invariants(dcs, s0, strategy="all"), d=0.0)
            for _ in range(4):
                s1 = drift(rng, s0, rng.uniform(0.05, 0.8))
                if iset.check(s1):
                    p1, _ = planner(pat, s1)
                    fp += int(p1 == p0)
        stats[mode] = fp
    assert stats["none"] == 0, stats
    assert stats["paper"] > stats["none"]  # documents the approximation


def test_policy_factory():
    for name in ("static", "unconditional", "threshold", "invariant"):
        p = make_policy(name)
        assert p.name == name
    with pytest.raises(ValueError):
        make_policy("nope")
