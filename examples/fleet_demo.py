"""Partitioned fleet demo: K tenants, one compiled data plane, one facade.

Each tenant (stream partition) has its own statistical regime, its own
invariant monitor and its own evaluation plan; all K advance through ONE
vmapped ``process_chunk`` per tick.  The whole runtime is driven through
``repro.cep``: the pattern is built with the fluent DSL, the fleet is a
``Session`` (partitions/plan/monitoring are configuration, not classes),
and every partition's match count is cross-checked against the
brute-force oracle.

    PYTHONPATH=src python examples/fleet_demo.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

from repro import cep
from repro.cep import P, RefEngine, RuntimeConfig
from repro.data.cep_streams import StreamConfig, make_stream

K = 8
pattern = (P.seq(0, 1, 2)
           .where(P.attr(0) < P.attr(1) - 0.3,
                  P.attr(1) < P.attr(2) - 0.3)
           .within(4.0))
scfg = StreamConfig(n_types=3, n_chunks=60, chunk_cap=256,
                    base_rate=12.0, seed=17)


def tenant_streams():
    # Alternate regimes: even tenants see skewed traffic with rare shocks,
    # odd tenants see near-uniform drifting stocks.
    return [
        make_stream("traffic" if p % 2 == 0 else "stocks",
                    dataclasses.replace(scfg, seed=17 + p))
        for p in range(K)
    ]


session = cep.open(
    pattern, partitions=K, plan="order",
    config=RuntimeConfig(buffer_capacity=128, match_capacity=1024,
                         policy="invariant", policy_kw={"k": 1, "d": 0.0}))
tel = session.run(tenant_streams())

print(f"== fleet of {K} tenants, {tel.chunks} chunks, "
      f"{tel.events} events ==")
print(f"matches={tel.matches}  replans={tel.replans}  "
      f"deployments={tel.deployments}  "
      f"migrating-partition-chunks={tel.migration_partition_chunks}")
print(f"engine {tel.engine_time_s * 1e3:.0f} ms, "
      f"control {tel.control_time_s * 1e3:.0f} ms")

print(f"\n{'tenant':>6s} {'regime':>8s} {'matches':>8s} {'oracle':>8s}")
oracle = [RefEngine(pattern.build()).run(s).full_matches
          for s in tenant_streams()]
for p in range(K):
    got = int(tel.per_partition_matches[p])
    mark = "ok" if got == oracle[p] else "MISMATCH"
    print(f"{p:6d} {'traffic' if p % 2 == 0 else 'stocks':>8s} "
          f"{got:8d} {oracle[p]:8d}  {mark}")
assert tel.per_partition_matches.tolist() == oracle
print("\nfleet == oracle on every partition")
