"""Partitioned fleet demo: K tenants, one compiled data plane.

Each tenant (stream partition) has its own statistical regime, its own
invariant monitor and its own evaluation plan; all K advance through ONE
vmapped ``process_chunk`` per tick.  The demo runs the adaptive fleet,
shows per-partition replan activity, and cross-checks every partition's
match count against the brute-force oracle.

    PYTHONPATH=src python examples/fleet_demo.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

from repro.core import EngineConfig, make_policy
from repro.core.fleet import FleetRunner, stacked_streams
from repro.core.patterns import chain_predicates, seq_pattern
from repro.core.ref_engine import RefEngine
from repro.data.cep_streams import StreamConfig, make_stream

K = 8
pattern = seq_pattern([0, 1, 2], window=4.0,
                      predicates=chain_predicates([0, 1, 2], theta=-0.3))
scfg = StreamConfig(n_types=3, n_chunks=60, chunk_cap=256,
                    base_rate=12.0, seed=17)


def tenant_streams():
    # Alternate regimes: even tenants see skewed traffic with rare shocks,
    # odd tenants see near-uniform drifting stocks.
    return [
        make_stream("traffic" if p % 2 == 0 else "stocks",
                    dataclasses.replace(scfg, seed=17 + p))
        for p in range(K)
    ]


runner = FleetRunner(
    pattern, K, planner="greedy",
    policy_factory=lambda: make_policy("invariant", k=1, d=0.0),
    engine_cfg=EngineConfig(b_cap=128, m_cap=1024))
metrics = runner.run(stacked_streams(tenant_streams()))

print(f"== fleet of {K} tenants, {metrics.chunks} chunks, "
      f"{metrics.events} events ==")
print(f"matches={metrics.full_matches}  replans={metrics.replans}  "
      f"deployments={metrics.deployments}  "
      f"migrating-partition-chunks={metrics.migration_partition_chunks}")
print(f"engine {metrics.engine_time_s * 1e3:.0f} ms, "
      f"control {metrics.control_time_s * 1e3:.0f} ms")

print(f"\n{'tenant':>6s} {'regime':>8s} {'matches':>8s} {'deploys':>8s} "
      f"{'oracle':>8s}")
oracle = [RefEngine(pattern).run(s).full_matches
          for s in tenant_streams()]
for p in range(K):
    got = int(metrics.per_partition_matches[p])
    mark = "ok" if got == oracle[p] else "MISMATCH"
    print(f"{p:6d} {'traffic' if p % 2 == 0 else 'stocks':>8s} "
          f"{got:8d} {int(metrics.per_partition_deployments[p]):8d} "
          f"{oracle[p]:8d}  {mark}")
assert metrics.per_partition_matches.tolist() == oracle
print("\nfleet == oracle on every partition")
