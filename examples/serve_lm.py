"""Batched LM serving with the invariant-governed adaptive batch planner:
requests in three prompt-length classes, continuous batching over a fixed
slot pool, prefill bucketing, one compiled decode step.

    PYTHONPATH=src python examples/serve_lm.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "olmo-1b", "--smoke", "--requests", "16",
          "--slots", "4", "--cache-len", "256", "--max-new", "12"])
