"""The paper's technique as a framework feature: train a (reduced)
DeepSeekMoE model with the invariant-governed expert-placement governor
watching per-expert routing loads — re-placement (the expensive expert
all-to-all + re-entry) triggers only on invariant violation.

    PYTHONPATH=src python examples/adaptive_moe_training.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch.train import main

if __name__ == "__main__":
    main([
        "--arch", "deepseek-moe-16b", "--smoke",
        "--steps", "60", "--batch", "8", "--seq", "64",
        "--adaptive-placement", "--log-every", "10",
    ])
