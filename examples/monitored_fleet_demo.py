"""Device-monitored fleet demo: violation-triggered replans end to end.

K tenants share ONE compiled, vmapped data plane that — in the same jitted
step — joins each chunk, updates per-partition statistics rings, and
verifies each tenant's lowered invariant set (paper §3.3-§3.5).  The host
reads back a single (K,) violation-flag vector per tick; it syncs
statistics and re-runs the planner ONLY for tenants whose flag fired, so
per-chunk host work scales with violations, not with fleet size.  The
whole runtime is one ``repro.cep`` session opened with ``monitor=True``;
match counts are cross-checked against the brute-force oracle.

    PYTHONPATH=src python examples/monitored_fleet_demo.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

from repro import cep
from repro.cep import P, RefEngine, RuntimeConfig

from repro.data.cep_streams import StreamConfig, make_stream

K = 8
pattern = (P.seq(0, 1, 2)
           .where(P.attr(0) < P.attr(1) - 0.3,
                  P.attr(1) < P.attr(2) - 0.3)
           .within(4.0))
scfg = StreamConfig(n_types=3, n_chunks=60, chunk_cap=256,
                    base_rate=12.0, seed=17)


def tenant_streams():
    # Alternate regimes: even tenants see skewed traffic with rare shocks,
    # odd tenants see near-uniform drifting stocks — so different tenants
    # violate their invariants at different times.
    return [
        make_stream("traffic" if p % 2 == 0 else "stocks",
                    dataclasses.replace(scfg, seed=17 + p))
        for p in range(K)
    ]


session = cep.open(
    pattern, partitions=K, plan="order", monitor=True,
    config=RuntimeConfig(buffer_capacity=128, match_capacity=1024,
                         policy="invariant", policy_kw={"k": 1, "d": 0.0}))
tel = session.run(tenant_streams())

print(f"== device-monitored fleet of {K} tenants, {tel.chunks} chunks, "
      f"{tel.events} events ==")
print(f"matches={tel.matches}  violations={tel.violations}  "
      f"replans={tel.replans}  deployments={tel.deployments}")
print(f"host statistic syncs: {tel.host_syncs} "
      f"(vs {tel.chunks * K} for host-side monitoring = K x chunks)")
print(f"last drift per tenant: "
      f"{[f'{d:+.2f}' for d in tel.last_drift]}")

print("\ntenant  matches")
for p in range(K):
    print(f"{p:6d}  {tel.per_partition_matches[p]:7d}")

oracle = [RefEngine(pattern.build()).run(s).full_matches
          for s in tenant_streams()]
assert tel.per_partition_matches.tolist() == oracle, (
    "fleet disagrees with the brute-force oracle")
print("\noracle cross-check: OK "
      "(per-tenant match counts == brute force, replans and all)")
