"""Device-monitored fleet demo: violation-triggered replans end to end.

K tenants share ONE compiled, vmapped data plane that — in the same jitted
step — joins each chunk, updates per-partition statistics rings, and
verifies each tenant's lowered invariant set (paper §3.3-§3.5).  The host
reads back a single (K,) violation-flag vector per tick; it syncs
statistics and re-runs the planner ONLY for tenants whose flag fired, so
per-chunk host work scales with violations, not with fleet size.  Every
deployment is two row writes (plan matrix + invariant matrix), never a
recompile.  Match counts are cross-checked against the brute-force oracle.

    PYTHONPATH=src python examples/monitored_fleet_demo.py
"""

import dataclasses
import sys

sys.path.insert(0, "src")

from repro.core import EngineConfig, MonitoredFleetRunner
from repro.core.decision import InvariantPolicy
from repro.core.fleet import stacked_streams
from repro.core.patterns import chain_predicates, seq_pattern
from repro.core.ref_engine import RefEngine
from repro.data.cep_streams import StreamConfig, make_stream

K = 8
pattern = seq_pattern([0, 1, 2], window=4.0,
                      predicates=chain_predicates([0, 1, 2], theta=-0.3))
scfg = StreamConfig(n_types=3, n_chunks=60, chunk_cap=256,
                    base_rate=12.0, seed=17)


def tenant_streams():
    # Alternate regimes: even tenants see skewed traffic with rare shocks,
    # odd tenants see near-uniform drifting stocks — so different tenants
    # violate their invariants at different times.
    return [
        make_stream("traffic" if p % 2 == 0 else "stocks",
                    dataclasses.replace(scfg, seed=17 + p))
        for p in range(K)
    ]


runner = MonitoredFleetRunner(
    pattern, K, planner="greedy",
    policy_factory=lambda: InvariantPolicy(k=1, d=0.0),
    engine_cfg=EngineConfig(b_cap=128, m_cap=1024))
metrics = runner.run(stacked_streams(tenant_streams()))

print(f"== device-monitored fleet of {K} tenants, {metrics.chunks} chunks, "
      f"{metrics.events} events ==")
print(f"matches={metrics.full_matches}  violations={metrics.violations}  "
      f"replans={metrics.replans}  deployments={metrics.deployments}")
print(f"host statistic syncs: {metrics.host_syncs} "
      f"(vs {metrics.chunks * K} for host-side monitoring = K x chunks)")
print(f"last drift per tenant: "
      f"{[f'{d:+.2f}' for d in metrics.last_drift]}")

print("\ntenant  matches  deployments")
for p in range(K):
    print(f"{p:6d}  {metrics.per_partition_matches[p]:7d}  "
          f"{metrics.per_partition_deployments[p]:11d}")

oracle = [RefEngine(pattern).run(s).full_matches for s in tenant_streams()]
assert metrics.per_partition_matches.tolist() == oracle, (
    "fleet disagrees with the brute-force oracle")
print("\noracle cross-check: OK "
      "(per-tenant match counts == brute force, replans and all)")
