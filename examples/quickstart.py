"""Quickstart: the paper end-to-end in one page.

Detect SEQ(A,B,C,D) with chained attribute predicates over a skewed,
shifting event stream; compare the static plan against the invariant-based
adaptive method (paper §3).  Run:

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import (AdaptiveRunner, EngineConfig, make_policy,
                        seq_pattern)
from repro.core.patterns import chain_predicates
from repro.data.cep_streams import StreamConfig, make_stream

# 1. A pattern: four event types in temporal order, adjacent attributes
#    must decrease (theta < 0 tightens selectivity), 4s time window.
pattern = seq_pattern(
    [0, 1, 2, 3], window=4.0,
    predicates=chain_predicates([0, 1, 2, 3], theta=-0.3))

# 2. A traffic-like stream: skewed arrival rates, rare extreme shifts.
stream_cfg = StreamConfig(n_types=4, n_chunks=120, chunk_cap=512,
                          base_rate=15.0, seed=7)

# 3. Two systems: a static plan vs invariant-governed adaptation.
for name, policy in [
    ("static   ", make_policy("static")),
    ("invariant", make_policy("invariant", k=1, d=0.0)),
]:
    runner = AdaptiveRunner(
        pattern, planner="greedy", policy=policy,
        engine_cfg=EngineConfig(b_cap=128, m_cap=2048),
        adaptive_caps=True, measure_regret=True)
    m = runner.run(make_stream("traffic", stream_cfg))
    print(f"{name}: matches={m.full_matches:5d} "
          f"partial-matches={m.pm_created:7d} "
          f"A-invocations={m.replans:3d} deployments={m.deployments} "
          f"false-positives={m.false_positives} "
          f"plan-regret={m.regret / max(m.regret_samples, 1):.3f}")

print("\nSame detections, fewer partial matches, provably-justified "
      "replans — that is the paper's contribution.")
