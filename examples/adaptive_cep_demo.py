"""Adaptive CEP in depth: all four decision policies × both data regimes,
with the distance-d knob and the d_avg estimator (paper §3.4, §5).

    PYTHONPATH=src python examples/adaptive_cep_demo.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import AdaptiveRunner, EngineConfig, make_policy
from repro.core.decision import InvariantPolicy
from repro.core.patterns import chain_predicates, seq_pattern
from repro.data.cep_streams import StreamConfig, make_stream

pattern = seq_pattern([0, 1, 2, 3], window=4.0,
                      predicates=chain_predicates([0, 1, 2, 3],
                                                  theta=-0.3))


def run(kind, policy):
    cfg = StreamConfig(n_types=4, n_chunks=120, chunk_cap=512,
                       base_rate=15.0, seed=3)
    r = AdaptiveRunner(pattern, planner="greedy", policy=policy,
                       engine_cfg=EngineConfig(b_cap=128, m_cap=2048),
                       adaptive_caps=True)
    return r.run(make_stream(kind, cfg)), r


print("== policy comparison (per data regime) ==")
print(f"{'regime':8s} {'policy':16s} {'matches':>7s} {'pm':>8s} "
      f"{'replans':>7s} {'deploys':>7s} {'fp':>3s} {'D+A ms':>8s}")
for kind in ("traffic", "stocks"):
    for pname, kw in [("static", {}), ("unconditional", {}),
                      ("threshold", {"t": 0.4}),
                      ("invariant", {"k": 1, "d": 0.0}),
                      ("invariant", {"k": 1, "d": 0.3})]:
        m, _ = run(kind, make_policy(pname, **kw))
        tag = pname + (f"(d={kw['d']})" if pname == "invariant" else "")
        print(f"{kind:8s} {tag:16s} {m.full_matches:7d} "
              f"{m.pm_created:8d} {m.replans:7d} {m.deployments:7d} "
              f"{m.false_positives:3d} "
              f"{(m.decision_time_s + m.plan_time_s) * 1e3:8.1f}")

print("\n== d_avg estimator (§3.4 approach 2) ==")
pol = InvariantPolicy(k=1, d_mode="avg")
m, r = run("traffic", pol)
print(f"estimated d_avg = {getattr(pol, 'd_estimated', 0.0):.4f} "
      f"(replans={m.replans}, deployments={m.deployments})")
