"""End-to-end LM training driver: a ~4M-parameter OLMo-family model for a
few hundred steps on CPU, with checkpoints and deterministic resume.
The SAME code path drives the full configs on a TPU mesh — drop --smoke
and point --arch at any of the ten assigned architectures.

    PYTHONPATH=src python examples/train_lm.py
"""

import sys

sys.path.insert(0, "src")

from repro.launch.train import main

if __name__ == "__main__":
    main([
        "--arch", "olmo-1b", "--smoke",
        "--steps", "200", "--batch", "8", "--seq", "64",
        "--lr", "3e-3", "--ckpt-dir", "/tmp/repro_quickstart_ckpt",
        "--ckpt-every", "100", "--log-every", "20",
    ])
